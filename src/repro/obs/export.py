"""JSONL + Chrome-trace exporters and the event schema (tentpole 3).

Two export formats from the same event ring:

* **JSONL** (``write_jsonl``) — one event per line, machine-diffable,
  the artifact format ``benchmarks/run.py --trace-out`` ships and CI
  validates. Line 1 is a ``meta`` event carrying the schema id and the
  handle's drop counter; the tail appends one ``counter`` event per
  registry entry so the file is self-contained.
* **Chrome trace events** (``write_chrome_trace``) — the
  ``{"traceEvents": [...]}`` JSON that chrome://tracing and
  https://ui.perfetto.dev load directly: spans and timed steps become
  ``"X"`` complete events, untimed steps ``"i"`` instants, and the §4
  counter totals a ``"C"`` counter track.

The JSONL contract is **committed** at ``benchmarks/obs_schema.json``
(kept byte-identical to :data:`OBS_EVENT_SCHEMA` by a test) and
enforced by :func:`validate_events` — the same hand-rolled draft-07
subset used by ``benchmarks/validate.py``, so CI needs no jsonschema
dependency.
"""

from __future__ import annotations

import json
from typing import Any, Iterable

__all__ = ["OBS_EVENT_SCHEMA", "write_jsonl", "load_jsonl",
           "write_chrome_trace", "validate_events",
           "validate_trace_file"]

SCHEMA_ID = "repro.obs/v1"

#: Contract for one JSONL trace line. Top-level constraints apply to
#: every event; ``definitions[kind]`` adds the per-kind required keys.
OBS_EVENT_SCHEMA: dict[str, Any] = {
    "$schema": "http://json-schema.org/draft-07/schema#",
    "title": "repro.obs trace event (one JSONL line)",
    "type": "object",
    "required": ["ts_us", "kind"],
    "properties": {
        "ts_us": {"type": "number", "minimum": 0},
        "kind": {"type": "string",
                 "enum": ["meta", "span", "run", "step", "counter",
                          "event", "audit"]},
        "name": {"type": "string"},
        "run": {"type": "integer", "minimum": 0},
        # meta
        "schema": {"type": "string"},
        "dropped": {"type": "integer", "minimum": 0},
        # span
        "dur_us": {"type": "number", "minimum": 0},
        # run
        "algorithm": {"type": "string"},
        "policy": {"type": "string"},
        "backend": {"type": "string"},
        "steps": {"type": "integer", "minimum": 0},
        "push_steps": {"type": "integer", "minimum": 0},
        "pull_steps": {"type": "integer", "minimum": 0},
        "epochs": {"type": "integer", "minimum": 0},
        "converged": {"type": "boolean"},
        "trace_overflow": {"type": "integer", "minimum": 0},
        "counters": {"type": "object"},
        "weighted_total": {"type": "number"},
        # step (StepTrace columns)
        "step": {"type": "integer", "minimum": 0},
        "pushed": {"type": "boolean"},
        "frontier_vertices": {"type": "integer", "minimum": 0},
        "frontier_edges": {"type": "integer", "minimum": 0},
        "pull_touched_edges": {"type": "integer", "minimum": 0},
        "reads": {"type": "integer", "minimum": 0},
        "writes": {"type": "integer", "minimum": 0},
        "atomics": {"type": "integer", "minimum": 0},
        "locks": {"type": "integer", "minimum": 0},
        "predicted_push": {"type": "number", "minimum": 0},
        "predicted_pull": {"type": "number", "minimum": 0},
        "push_wire_bytes": {"type": "integer", "minimum": 0},
        "pull_wire_bytes": {"type": "integer", "minimum": 0},
        "us": {"type": "number", "minimum": 0},
        # counter
        "value": {"type": "number"},
        # audit (summary; per-step rows stay in the report)
        "basis": {"type": "string", "enum": ["wall", "predicted"]},
        "audited_steps": {"type": "integer", "minimum": 0},
        "flagged": {"type": "integer", "minimum": 0},
        "mispredict_rate": {"type": "number", "minimum": 0,
                            "maximum": 1},
    },
    "definitions": {
        "meta": {"type": "object", "required": ["schema"]},
        "span": {"type": "object", "required": ["name", "dur_us"]},
        "run": {"type": "object",
                "required": ["run", "algorithm", "policy", "backend",
                             "steps", "push_steps", "counters",
                             "weighted_total"]},
        "step": {"type": "object",
                 "required": ["run", "step", "pushed", "reads",
                              "writes", "predicted_push",
                              "predicted_pull"]},
        "counter": {"type": "object", "required": ["name", "value"]},
        "event": {"type": "object", "required": ["name"]},
        "audit": {"type": "object",
                  "required": ["run", "basis", "audited_steps",
                               "flagged", "mispredict_rate"]},
    },
}


# ---------------------------------------------------------------------------
# validation — same draft-07 subset as benchmarks/validate.py, kept local
# so `repro` never imports from the benchmarks/ tree
# ---------------------------------------------------------------------------

_TYPES = {"object": dict, "array": list, "string": str,
          "boolean": bool, "integer": int}


def _check(obj: Any, schema: dict, path: str, errors: list[str]) -> None:
    t = schema.get("type")
    if t == "number":
        if not isinstance(obj, (int, float)) or isinstance(obj, bool):
            errors.append(f"{path}: expected number, got {type(obj).__name__}")
            return
    elif t is not None:
        pytype = _TYPES[t]
        if not isinstance(obj, pytype) or (
                t == "integer" and isinstance(obj, bool)):
            errors.append(f"{path}: expected {t}, got {type(obj).__name__}")
            return
    if "enum" in schema and obj not in schema["enum"]:
        errors.append(f"{path}: {obj!r} not in {schema['enum']}")
    if isinstance(obj, bool):
        return
    if isinstance(obj, (int, float)):
        if "minimum" in schema and obj < schema["minimum"]:
            errors.append(f"{path}: {obj} < minimum {schema['minimum']}")
        if "maximum" in schema and obj > schema["maximum"]:
            errors.append(f"{path}: {obj} > maximum {schema['maximum']}")
    if isinstance(obj, dict):
        for req in schema.get("required", ()):
            if req not in obj:
                errors.append(f"{path}: missing required key {req!r}")
        props = schema.get("properties", {})
        for k, sub in props.items():
            if k in obj:
                _check(obj[k], sub, f"{path}.{k}", errors)


def validate_events(events: Iterable[dict[str, Any]],
                    schema: dict[str, Any] | None = None) -> list[str]:
    """Check events against the contract; returns a list of errors
    (empty = valid). Each event is checked against the top-level
    schema, then against its kind's ``definitions`` entry."""
    schema = schema or OBS_EVENT_SCHEMA
    defs = schema.get("definitions", {})
    errors: list[str] = []
    for i, ev in enumerate(events):
        path = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{path}: expected object, got "
                          f"{type(ev).__name__}")
            continue
        _check(ev, schema, path, errors)
        kind = ev.get("kind")
        if isinstance(kind, str) and kind in defs:
            _check(ev, defs[kind], f"{path}<{kind}>", errors)
    return errors


def validate_trace_file(path, schema: dict[str, Any] | None = None) -> int:
    """Validate a JSONL trace file; returns the event count, raises
    ``ValueError`` listing every violation otherwise."""
    events = load_jsonl(path)
    errors = validate_events(events, schema)
    if errors:
        raise ValueError(f"{path}: {len(errors)} schema violation(s):\n  "
                         + "\n  ".join(errors[:20]))
    return len(events)


# ---------------------------------------------------------------------------
# JSONL
# ---------------------------------------------------------------------------

def _final_events(tel) -> list[dict[str, Any]]:
    """meta header + ring + counter snapshot, ready to serialize."""
    now = round(tel.now_us(), 3)
    out: list[dict[str, Any]] = [
        {"ts_us": 0.0, "kind": "meta", "schema": SCHEMA_ID,
         "dropped": tel.dropped}]
    out.extend(tel.events)
    for name, value in tel.counters.as_dict().items():
        out.append({"ts_us": now, "kind": "counter", "name": name,
                    "value": value})
    return out


def write_jsonl(tel, path) -> int:
    """Write a handle's events as JSONL; returns lines written."""
    events = _final_events(tel)
    with open(path, "w") as fh:
        for ev in events:
            fh.write(json.dumps(ev, sort_keys=True) + "\n")
    return len(events)


def load_jsonl(path) -> list[dict[str, Any]]:
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


# ---------------------------------------------------------------------------
# Chrome trace events (Perfetto-loadable)
# ---------------------------------------------------------------------------

_SKIP_ARGS = {"ts_us", "kind", "name", "dur_us", "counters"}


def _args(ev: dict[str, Any]) -> dict[str, Any]:
    return {k: v for k, v in ev.items() if k not in _SKIP_ARGS}


def write_chrome_trace(tel_or_events, path) -> int:
    """Render events as Chrome trace-event JSON; returns event count.

    Load the output in chrome://tracing or https://ui.perfetto.dev:
    spans and wall-timed steps appear as nested slices on one track
    per run, counter totals as a value track. Accepts either a
    :class:`~repro.obs.trace.Telemetry` handle or an already-loaded
    event list (e.g. from :func:`load_jsonl`).
    """
    events = (_final_events(tel_or_events)
              if hasattr(tel_or_events, "events") else list(tel_or_events))
    pid = 1
    out: list[dict[str, Any]] = [
        {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
         "args": {"name": "repro"}}]
    for ev in events:
        kind, ts = ev.get("kind"), ev.get("ts_us", 0.0)
        tid = int(ev.get("run", -1)) + 1  # run n -> track n+1, misc on 0
        if kind == "span":
            out.append({"ph": "X", "name": ev.get("name", "span"),
                        "cat": "span", "ts": ts,
                        "dur": ev.get("dur_us", 0.0), "pid": pid,
                        "tid": tid, "args": _args(ev)})
        elif kind == "step":
            name = (f"step {ev.get('step', '?')} "
                    f"[{'push' if ev.get('pushed') else 'pull'}]")
            base = {"name": name, "cat": "step", "ts": ts, "pid": pid,
                    "tid": tid, "args": _args(ev)}
            if "us" in ev:
                out.append({"ph": "X", "dur": ev["us"], **base})
            else:
                out.append({"ph": "i", "s": "t", **base})
        elif kind == "run":
            counters = ev.get("counters", {})
            out.append({"ph": "C", "name": "engine.cost", "ts": ts,
                        "pid": pid, "tid": tid,
                        "args": {k: counters[k]
                                 for k in ("reads", "writes", "atomics",
                                           "locks") if k in counters}})
        elif kind in ("event", "audit", "counter"):
            out.append({"ph": "i", "s": "t",
                        "name": ev.get("name", kind), "cat": kind,
                        "ts": ts, "pid": pid, "tid": tid,
                        "args": _args(ev)})
    with open(path, "w") as fh:
        json.dump({"traceEvents": out, "displayTimeUnit": "ms"}, fh)
    return len(out)
