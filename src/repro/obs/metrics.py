"""Namespaced counter registry + collectors (tentpole part 2).

One schema for every number the repo already computes but scatters:

    engine.cost.reads / writes / atomics / locks / messages /
        collective_bytes / barriers / iterations   — §4 model totals
    engine.steps / push_steps / pull_steps / runs / trace_overflow
    backend.pallas.kernel_pull / kernel_push / kernel_pull_frontier /
        skip_empty_pull / fallback_pull / fallback_push
    backend.shard.push_wire_bytes / pull_wire_bytes /
        compression_residual_l1
    tuner.mem_hits / disk_hits / misses / probes / writes /
        probe_retries / probe_timeouts / probe_degraded
    service.coalesced / batches_started / chunks_run / force_retired /
        chunk_retries / deadline_expired / admission_rejected
    service.cache.hits / misses / puts / evictions
    resilience.injected.<site> / fallback.* / retry.* / timeout.* /
        degraded.* / breaker.* / resume.*   — fault-injection outcomes
        and what each recovery seam did about them

Counters are **monotone totals across a Telemetry handle's lifetime**;
per-run values live in the ``run``/``step`` events the same collectors
emit. :func:`record_solve` is the main entry: it folds one
``EngineResult`` (cost totals, per-step ``StepTrace`` columns incl. the
predicted push/pull prices, wire bytes, and the overflow counter) into
the handle, emitting one ``run`` event plus one ``step`` event per
traced step — the rows the decision audit and the paper-style counter
table are rendered from.
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp

__all__ = ["MetricRegistry", "record_solve", "collect_backend",
           "collect_tuner", "collect_service", "collect_resilience"]


class MetricRegistry:
    """A flat ``dotted.name -> number`` accumulator.

    ``add`` accumulates (counters), ``put`` overwrites (gauges);
    ``as_dict`` snapshots in sorted-name order so exports are stable.
    """

    def __init__(self) -> None:
        self._vals: dict[str, float] = {}

    def add(self, name: str, value: float = 1) -> None:
        self._vals[name] = self._vals.get(name, 0) + value

    def put(self, name: str, value: float) -> None:
        self._vals[name] = value

    def get(self, name: str, default: float = 0) -> float:
        return self._vals.get(name, default)

    def as_dict(self) -> dict[str, float]:
        return {k: self._vals[k] for k in sorted(self._vals)}

    def add_all(self, prefix: str, values: Mapping[str, Any]) -> None:
        for k, v in values.items():
            self.add(f"{prefix}.{k}", float(v))

    def __len__(self) -> int:
        return len(self._vals)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"MetricRegistry({self._vals!r})"


def _residual_l1(xstate: Any) -> float | None:
    """Total |error feedback| left in a compression exchange state."""
    leaves = [x for x in jax.tree_util.tree_leaves(xstate)
              if hasattr(x, "dtype") and jnp.issubdtype(x.dtype,
                                                        jnp.floating)]
    if not leaves:
        return None
    return float(sum(jnp.abs(x).sum() for x in leaves))


def record_solve(tel, *, algorithm: str, policy, backend, result,
                 run: int | None = None,
                 step_times: Mapping[int, float] | None = None,
                 t0_us: float | None = None,
                 converged: bool | None = None) -> int:
    """Fold one engine result into ``tel``; returns the run id.

    Emits one ``step`` event per traced step (counter deltas, the
    predictor's push/pull prices, wire-byte charges, and — when the
    stepwise loop timed them — measured ``us``), then one ``run`` event
    with the §4 cost totals, and accumulates everything into
    ``tel.counters``. ``step_times`` maps step index → host
    microseconds from :meth:`Engine.run_stepwise`; ``t0_us`` anchors
    the step timeline for the Chrome exporter (defaults to emission
    time).
    """
    if run is None:
        run = tel.new_run()
    steps = int(result.steps)
    pushes = int(result.push_steps)
    cost = result.cost.as_dict()
    pol = policy if isinstance(policy, str) else getattr(
        policy, "name", type(policy).__name__)
    bname = getattr(backend, "name", None) or type(backend).__name__

    overflow = 0
    cursor = tel.now_us() if t0_us is None else t0_us
    trace = getattr(result, "trace", None)
    if trace is not None:
        rows = trace.as_dict(steps)
        overflow = int(rows.pop("overflow", 0))
        # trace slot i holds step i (record() indexes by step), so the
        # row index *is* the step number
        k = len(rows.get("pushed", ()))
        for i in range(k):
            us = None if step_times is None else step_times.get(i)
            ev = {"step": i}
            ev.update((key, rows[key][i]) for key in rows)
            if us is not None:
                ev["us"] = round(us, 3)
            tel.emit("step", run=run, ts_us=cursor, **ev)
            cursor += us or 0.0

    tel.emit("run", run=run, algorithm=algorithm, policy=pol,
             backend=bname, steps=steps, push_steps=pushes,
             pull_steps=steps - pushes, epochs=int(result.epochs),
             converged=bool(result.converged if converged is None
                            else converged),
             trace_overflow=overflow, counters=cost,
             weighted_total=float(result.cost.weighted_total()))

    c = tel.counters
    c.add_all("engine.cost", cost)
    c.add("engine.runs")
    c.add("engine.steps", steps)
    c.add("engine.push_steps", pushes)
    c.add("engine.pull_steps", steps - pushes)
    c.add("engine.trace_overflow", overflow)

    residual = _residual_l1(getattr(result, "xstate", ()))
    if residual is not None:
        c.add("backend.shard.compression_residual_l1", residual)
    collect_backend(tel, backend)
    return run


def collect_backend(tel, backend) -> dict[str, float]:
    """Snapshot a backend's dispatch/layout counters into the registry.

    Backends describe themselves via
    :meth:`~repro.core.backend.ExchangeBackend.telemetry_counters`:
    Pallas reports kernel-dispatch vs fallback tallies, the sharded
    backend its shard geometry and cut size. Monotone counters are
    ``put`` (the backend already accumulates), gauges too — so calling
    this repeatedly never double-counts.
    """
    if backend is None:
        return {}
    counters = getattr(backend, "telemetry_counters", lambda: {})()
    bname = getattr(backend, "name", None) or type(backend).__name__
    for k, v in counters.items():
        tel.counters.put(f"backend.{bname}.{k}", float(v))
    return counters


def collect_tuner(tel) -> dict[str, int]:
    """Fold the autotuner's global probe/cache outcomes into ``tel``."""
    from ..kernels import tune
    stats = tune.tune_stats()
    for k, v in stats.items():
        tel.counters.put(f"tuner.{k}", float(v))
    return stats


def collect_resilience(tel) -> dict[str, float]:
    """Fold the resilience layer into ``tel``: the process-wide
    fault/recovery counters become ``resilience.*`` gauges, and every
    queued fault/fallback/retry/timeout event drains into the handle's
    event ring (kind ``event``, names like ``resilience.fault``,
    ``resilience.fallback.pallas.pull``)."""
    from ..resilience import drain_events, resilience_stats
    stats = resilience_stats()
    for k, v in stats.items():
        tel.counters.put(f"resilience.{k}", float(v))
    for ev in drain_events():
        fields = dict(ev)
        name = fields.pop("name", "resilience.event")
        # "kind"/"name" are the event envelope's own keys — rename any
        # payload field that would collide with emit()'s signature
        for reserved in ("kind", "ts_us"):
            if reserved in fields:
                fields[f"f_{reserved}"] = fields.pop(reserved)
        tel.emit("event", name, **fields)
    return stats


def collect_service(tel, service) -> dict[str, Any]:
    """Fold a ``QueryService``'s scheduler + cache stats into ``tel``."""
    stats = service.stats()
    for k, v in stats.items():
        if isinstance(v, Mapping):
            for kk, vv in v.items():
                tel.counters.put(f"service.{k}.{kk}", float(vv))
        elif isinstance(v, (int, float)):
            tel.counters.put(f"service.{k}", float(v))
    return stats
