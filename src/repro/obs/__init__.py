"""repro.obs — unified telemetry: spans, counters, traces, reports.

The observability layer every other subsystem emits into (the paper's
method *is* measurement — §5/§6 argue push vs pull from hardware
counters, and this package is where those counters become visible):

  * :mod:`~repro.obs.trace`   — the :class:`Telemetry` handle: a
    jit-aware span/timer API plus a bounded event ring. Zero-cost when
    absent: ``api.solve(..., telemetry=None)`` takes the untouched
    fast path.
  * :mod:`~repro.obs.metrics` — the namespaced counter registry and the
    collectors that unify engine ``StepTrace``/``Cost`` totals, backend
    dispatch/fallback stats, sharded wire bytes and compression
    residuals, autotuner probe outcomes, and ``QueryService`` stats.
  * :mod:`~repro.obs.export`  — JSONL and Chrome-trace-event
    (Perfetto-loadable) exporters, with the committed
    ``benchmarks/obs_schema.json`` contract and a validator.
  * :mod:`~repro.obs.report`  — ``python -m repro.obs.report`` renders
    a markdown run report: the paper-style counter table and the
    AutoSwitch decision audit (predicted push vs pull vs chosen vs
    measured, mispredicted steps flagged).

Typical use::

    from repro.obs import Telemetry
    tel = Telemetry()
    r = api.solve(g, "bfs", root=0, policy="auto", telemetry=tel)
    from repro.obs.export import write_jsonl, write_chrome_trace
    write_jsonl(tel, "trace.jsonl")          # one event per line
    write_chrome_trace(tel, "trace.json")    # open in ui.perfetto.dev
"""

from .export import (load_jsonl, validate_events, validate_trace_file,
                     write_chrome_trace, write_jsonl)
from .metrics import (MetricRegistry, collect_backend,
                      collect_resilience, collect_service,
                      collect_tuner, record_solve)
from .report import decision_audit, render_report
from .trace import Telemetry

__all__ = ["Telemetry", "MetricRegistry", "record_solve",
           "collect_backend", "collect_service", "collect_tuner",
           "collect_resilience", "write_jsonl", "write_chrome_trace",
           "load_jsonl", "validate_events", "validate_trace_file",
           "decision_audit", "render_report"]
