"""Span/timer API and the bounded event ring (tentpole part 1).

Design constraints, in order:

1. **Zero-cost when absent.** Telemetry is an *opt-in handle*, not a
   global: ``api.solve(..., telemetry=None)`` never imports this module
   on the hot path and runs the one-``jax.jit``-call fast path
   untouched, so disabled telemetry is bit-identical by construction
   (tested in ``tests/test_obs.py``).
2. **jit-aware.** Host wall clocks cannot live inside a traced
   ``lax.while_loop`` — a jitted body runs asynchronously and a Python
   ``time.perf_counter()`` inside it would time tracing, not execution.
   Step-level timing therefore uses the engine's host-driven
   :meth:`~repro.core.engine.Engine.run_stepwise` loop, which jits the
   *body once* and calls ``jax.block_until_ready`` at every step
   boundary; :class:`Telemetry` only ever stamps timestamps on the
   host side of that boundary. In-loop *counters* (the exact §4 cost
   numbers) ride the jitted carry in
   :class:`~repro.core.cost_model.StepTrace` and are merged in
   afterwards by :func:`repro.obs.metrics.record_solve`.
3. **Bounded.** The event ring holds at most ``capacity`` events; once
   full, new events are dropped and counted in :attr:`Telemetry.dropped`
   (mirroring ``StepTrace.overflow`` on the device side) — telemetry
   must never turn a long run into an OOM.
"""

from __future__ import annotations

import contextlib
import time
from typing import Any, Iterator

from .metrics import MetricRegistry

__all__ = ["Telemetry"]


class Telemetry:
    """A per-session telemetry handle: event ring + counter registry.

    Pass one instance to ``api.solve`` / ``api.solve_batch`` /
    ``QueryService`` (or set ``benchmarks.common.TELEMETRY``) and every
    layer appends structured events to it:

        >>> tel = Telemetry()
        >>> r = api.solve(g, "bfs", root=0, policy="auto",
        ...               telemetry=tel)              # doctest: +SKIP
        >>> [e["kind"] for e in tel.events][:3]       # doctest: +SKIP
        ['step', 'step', 'step']

    Events are plain dicts with at least ``ts_us`` (microseconds since
    this handle's ``t0``) and ``kind`` (one of ``meta | span | run |
    step | counter | event | audit`` — see ``benchmarks/obs_schema.json``
    for the full contract). ``counters`` is a
    :class:`~repro.obs.metrics.MetricRegistry` accumulating namespaced
    totals across runs; exporters append its snapshot as ``counter``
    events.
    """

    def __init__(self, *, capacity: int = 65536,
                 step_timing: bool = True) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        #: When True (default), ``api.solve`` routes eligible runs
        #: through the engine's host-driven stepwise loop so ``step``
        #: events carry measured ``us`` wall times (the decision
        #: audit's wall basis). Set False to keep the single-dispatch
        #: fast path and get predicted-basis audits only.
        self.step_timing = bool(step_timing)
        self.events: list[dict[str, Any]] = []
        self.dropped = 0
        self.counters = MetricRegistry()
        self._runs = 0
        self._t0 = time.perf_counter()

    # -- clock -----------------------------------------------------------
    def now_us(self) -> float:
        """Microseconds since this handle was created (host clock)."""
        return (time.perf_counter() - self._t0) * 1e6

    # -- event ring ------------------------------------------------------
    def emit(self, kind: str, name: str = "", *,
             ts_us: float | None = None, **fields: Any) -> None:
        """Append one event; drop (and count) once the ring is full."""
        if len(self.events) >= self.capacity:
            self.dropped += 1
            return
        ev: dict[str, Any] = {
            "ts_us": round(self.now_us() if ts_us is None else ts_us, 3),
            "kind": kind}
        if name:
            ev["name"] = name
        ev.update(fields)
        self.events.append(ev)

    def new_run(self) -> int:
        """Allocate the next run id (events from one solve share it)."""
        run = self._runs
        self._runs = run + 1
        return run

    @property
    def last_run(self) -> int | None:
        """Id of the most recently started run, or None before any."""
        return self._runs - 1 if self._runs else None

    def events_for(self, run: int, kind: str | None = None
                   ) -> list[dict[str, Any]]:
        """All events of one run (optionally one kind), in emit order."""
        return [e for e in self.events if e.get("run") == run
                and (kind is None or e["kind"] == kind)]

    # -- spans -----------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, **fields: Any) -> Iterator[dict[str, Any]]:
        """Time a host-side region; emits one ``span`` event on exit.

        The yielded dict is live — mutate it to attach result fields::

            with tel.span("solve", algorithm="bfs") as sp:
                r = engine.run(...)
                sp["steps"] = int(r.steps)

        The event's ``ts_us`` is the span *start*, ``dur_us`` the
        elapsed host wall time — exactly the (ts, dur) pair the Chrome
        ``"X"`` (complete-event) exporter needs. Spans around jitted
        work should end after a ``jax.block_until_ready``, else they
        time dispatch, not execution.
        """
        t0 = self.now_us()
        sp = dict(fields)
        try:
            yield sp
        finally:
            sp.setdefault("dur_us", round(self.now_us() - t0, 3))
            self.emit("span", name, ts_us=t0, **sp)
