"""Markdown run reports + the AutoSwitch decision audit (tentpole 4).

``python -m repro.obs.report TRACE.jsonl`` renders, from one JSONL
trace (see :mod:`repro.obs.export`):

* the **paper-style counter table** — reads / writes / "atomics" /
  "locks" per algorithm × direction mix, the §5 presentation the
  push-vs-pull argument is made in;
* the **decision audit** — per step: predicted push cost vs predicted
  pull cost vs chosen direction vs measured wall time, mispredicted
  steps flagged, with a summary misprediction rate per run.

The audit answers "was the cost model right?" on two bases:

* ``wall`` — when the stepwise loop measured both directions at least
  once, each direction gets a calibration rate (median measured-µs per
  predicted-cost-unit over its own steps); a step is flagged when the
  *other* direction's predicted cost, priced at the other direction's
  rate, is strictly cheaper than the step's measured time. Using
  medians keeps one straggler step from recalibrating the whole run.
* ``predicted`` — fallback when wall times are missing or one-sided:
  a step is flagged when the unchosen direction's predicted cost is
  strictly lower than the chosen one's (i.e. the policy overrode the
  raw prediction — hysteresis holds, or a fixed policy ignored it).
"""

from __future__ import annotations

import argparse
import statistics
import sys
from typing import Any, Iterable

from .export import load_jsonl, validate_events

__all__ = ["decision_audit", "counter_table", "render_report", "main"]


# ---------------------------------------------------------------------------
# decision audit
# ---------------------------------------------------------------------------

def decision_audit(events: Iterable[dict[str, Any]],
                   run: int | None = None) -> dict[str, Any] | None:
    """Audit one run's direction decisions; None if it has no steps.

    ``events`` may be a whole trace (``run`` selects which solve; the
    default is the first run with step events) or just its step events.
    Returns ``{"run", "basis", "audited_steps", "flagged",
    "mispredict_rate", "steps": [per-step rows]}`` — the summary half
    is what :func:`repro.obs.metrics.record_solve` callers emit as the
    ``audit`` event.
    """
    steps = [e for e in events if e.get("kind", "step") == "step"
             and "pushed" in e]
    if run is None:
        runs = sorted({e.get("run", 0) for e in steps})
        if not runs:
            return None
        run = runs[0]
    steps = [e for e in steps if e.get("run", run) == run]
    if not steps:
        return None

    timed_push = [e for e in steps if e.get("us") is not None
                  and e["pushed"]]
    timed_pull = [e for e in steps if e.get("us") is not None
                  and not e["pushed"]]

    def _rate(rows: list[dict], key: str) -> float:
        return statistics.median(
            e["us"] / max(e[key], 1.0) for e in rows)

    wall = bool(timed_push and timed_pull)
    if wall:
        rate = {True: _rate(timed_push, "predicted_push"),
                False: _rate(timed_pull, "predicted_pull")}

    rows = []
    flagged = 0
    for e in steps:
        pushed = bool(e["pushed"])
        pp, pl = float(e["predicted_push"]), float(e["predicted_pull"])
        chosen, other = (pp, pl) if pushed else (pl, pp)
        if wall and e.get("us") is not None:
            # counterfactual wall time of the unchosen direction
            alt_us = other * rate[not pushed]
            mis = alt_us < e["us"]
        else:
            mis = other < chosen
        flagged += mis
        rows.append({"step": int(e["step"]), "pushed": pushed,
                     "predicted_push": pp, "predicted_pull": pl,
                     "us": e.get("us"), "mispredict": bool(mis)})
    return {"run": int(run), "basis": "wall" if wall else "predicted",
            "audited_steps": len(rows), "flagged": int(flagged),
            "mispredict_rate": flagged / len(rows), "steps": rows}


# ---------------------------------------------------------------------------
# tables
# ---------------------------------------------------------------------------

def _direction(run_ev: dict[str, Any]) -> str:
    steps, pushes = run_ev.get("steps", 0), run_ev.get("push_steps", 0)
    if steps == 0 or pushes == steps:
        return "push"
    if pushes == 0:
        return "pull"
    return f"mixed ({pushes}p/{steps - pushes}l)"


def counter_table(events: Iterable[dict[str, Any]]) -> list[str]:
    """Paper-style §5 table: one row per run, §4 counters as columns."""
    runs = [e for e in events if e.get("kind") == "run"]
    if not runs:
        return []
    lines = [
        "| run | algorithm | policy | backend | direction | steps "
        "| reads | writes | atomics | locks | msgs | wire B | weighted |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for e in runs:
        c = e.get("counters", {})
        lines.append(
            f"| {e.get('run', 0)} | {e.get('algorithm', '?')} "
            f"| {e.get('policy', '?')} | {e.get('backend', '?')} "
            f"| {_direction(e)} | {e.get('steps', 0)} "
            f"| {int(c.get('reads', 0))} | {int(c.get('writes', 0))} "
            f"| {int(c.get('atomics', 0))} | {int(c.get('locks', 0))} "
            f"| {int(c.get('messages', 0))} "
            f"| {int(c.get('collective_bytes', 0))} "
            f"| {e.get('weighted_total', 0):.0f} |")
    return lines


def _audit_table(audit: dict[str, Any]) -> list[str]:
    lines = [
        "| step | chosen | predicted push | predicted pull | wall µs "
        "| mispredict |",
        "|---|---|---|---|---|---|"]
    for r in audit["steps"]:
        us = "—" if r["us"] is None else f"{r['us']:.1f}"
        lines.append(
            f"| {r['step']} | {'push' if r['pushed'] else 'pull'} "
            f"| {r['predicted_push']:.0f} | {r['predicted_pull']:.0f} "
            f"| {us} | {'⚠️' if r['mispredict'] else ''} |")
    return lines


def render_report(events: Iterable[dict[str, Any]],
                  title: str = "repro.obs run report") -> str:
    """Render a full markdown report from a trace's events."""
    events = list(events)
    out = [f"# {title}", ""]

    meta = next((e for e in events if e.get("kind") == "meta"), None)
    if meta:
        out.append(f"Schema `{meta.get('schema', '?')}` · "
                   f"{len(events)} events · "
                   f"{meta.get('dropped', 0)} dropped by the ring.")
        out.append("")

    table = counter_table(events)
    if table:
        out += ["## Counter totals (paper §5 style)", "",
                "Exact §4 model charges per run — the counters the "
                "paper's push-vs-pull argument is made in.", ""]
        out += table + [""]

    overflows = [e for e in events if e.get("kind") == "run"
                 and e.get("trace_overflow", 0) > 0]
    if overflows:
        out += ["## Trace overflow", ""]
        for e in overflows:
            out.append(f"- run {e.get('run', 0)} "
                       f"({e.get('algorithm', '?')}): "
                       f"{e['trace_overflow']} step(s) beyond the "
                       f"trace capacity were dropped — raise "
                       f"`trace=`/`_DEFAULT_TRACE_CAPACITY` to audit "
                       f"them.")
        out.append("")

    run_ids = sorted({e.get("run") for e in events
                      if e.get("kind") == "step"
                      and e.get("run") is not None})
    audits = [a for a in (decision_audit(events, run=r)
                          for r in run_ids) if a]
    if audits:
        out += ["## Decision audit", ""]
        by_run = {e.get("run"): e for e in events
                  if e.get("kind") == "run"}
        for a in audits:
            rv = by_run.get(a["run"], {})
            out.append(
                f"### run {a['run']} — {rv.get('algorithm', '?')} / "
                f"{rv.get('policy', '?')}")
            out.append("")
            out.append(
                f"{a['flagged']}/{a['audited_steps']} steps "
                f"mispredicted ({a['mispredict_rate']:.1%}, "
                f"{a['basis']} basis).")
            out.append("")
            out += _audit_table(a) + [""]

    res_counters = [e for e in events if e.get("kind") == "counter"
                    and str(e.get("name", "")).startswith("resilience.")]
    res_events = [e for e in events if e.get("kind") == "event"
                  and str(e.get("name", "")).startswith("resilience.")]
    if res_counters or res_events:
        out += ["## Resilience", ""]
        if res_counters:
            out += ["Fault-injection and recovery totals "
                    "(`resilience.*` namespace).", "",
                    "| counter | value |", "|---|---|"]
            for e in res_counters:
                out.append(f"| `{e.get('name', '?')}` | "
                           f"{e.get('value', 0):g} |")
            out.append("")
        if res_events:
            out += ["| event | details |", "|---|---|"]
            for e in res_events:
                detail = ", ".join(
                    f"{k}={v}" for k, v in sorted(e.items())
                    if k not in ("kind", "name", "ts_us", "pid", "tid"))
                out.append(f"| `{e.get('name', '?')}` | {detail} |")
            out.append("")

    counters = [e for e in events if e.get("kind") == "counter"]
    if counters:
        out += ["## Session counters", "", "| counter | value |",
                "|---|---|"]
        for e in counters:
            v = e.get("value", 0)
            out.append(f"| `{e.get('name', '?')}` | "
                       f"{v:g} |")
        out.append("")
    return "\n".join(out)


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a markdown report (counter table + "
                    "AutoSwitch decision audit) from a repro.obs "
                    "JSONL trace.")
    p.add_argument("trace", help="JSONL trace (benchmarks/run.py "
                                 "--trace-out / obs.export.write_jsonl)")
    p.add_argument("--out", help="write markdown here (default: stdout)")
    p.add_argument("--no-validate", action="store_true",
                   help="skip schema validation before rendering")
    args = p.parse_args(argv)
    events = load_jsonl(args.trace)
    if not args.no_validate:
        errors = validate_events(events)
        if errors:
            print(f"{args.trace}: {len(errors)} schema violation(s)",
                  file=sys.stderr)
            for e in errors[:10]:
                print(f"  {e}", file=sys.stderr)
            return 1
    md = render_report(events)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(md + "\n")
    else:
        print(md)
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised by subprocess
    sys.exit(main())
