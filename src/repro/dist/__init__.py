"""Distributed-memory layer (paper §6): sharding rules, PA exchanges,
overlap primitives, and gradient compression.

The graph side consumes ``collectives`` through
``repro.core.backend.DistributedBackend``; the training side consumes
``compression``/``overlap`` through ``repro.train.loop``.
"""

from .compression import (CompressionConfig, compress_tree,
                          compressed_bytes, init_error_state)
from .overlap import microbatch_grads, ring_allreduce_psum
from .sharding import (BATCH, batch_axes, get_activation_mesh, hint,
                       make_sharding, set_activation_mesh)
from . import collectives, compression, overlap, sharding

__all__ = [
    "CompressionConfig", "compress_tree", "compressed_bytes",
    "init_error_state",
    "microbatch_grads", "ring_allreduce_psum",
    "BATCH", "batch_axes", "get_activation_mesh", "hint", "make_sharding",
    "set_activation_mesh",
    "collectives", "compression", "overlap", "sharding",
]
