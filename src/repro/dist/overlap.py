"""Communication/computation overlap primitives (paper §2.3, §6).

``ring_allreduce_psum`` is an explicit ring all-reduce (reduce-scatter +
all-gather over ``ppermute`` hops) that equals ``lax.psum`` bit-for-bit
on the values it moves — the schedule the paper's DM push variants
overlap with local relaxation work. ``microbatch_grads`` is the
training-side overlap: gradients of microbatch i are ready to exchange
while microbatch i+1 is still in backward.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["ring_allreduce_psum", "microbatch_grads"]


def ring_allreduce_psum(x: jax.Array, axis_name: str,
                        axis_size: int) -> jax.Array:
    """All-reduce ``x`` (flat, per-device) over ``axis_name`` with an
    explicit ring. Must be called inside shard_map/pmap. When the length
    divides ``axis_size`` this is the bandwidth-optimal two-phase ring
    (reduce-scatter then all-gather); otherwise a rotate-accumulate ring.
    """
    if axis_size == 1:
        return x
    ring = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    n = x.shape[0]
    if n % axis_size != 0:
        acc, cur = x, x
        for _ in range(axis_size - 1):
            cur = jax.lax.ppermute(cur, axis_name, ring)
            acc = acc + cur
        return acc

    own = jax.lax.axis_index(axis_name)
    chunks = x.reshape(axis_size, -1)
    # reduce-scatter: after P-1 hops device i holds chunk (i+1) % P
    # fully reduced (each hop: forward the partial, add the local copy).
    acc = jnp.take(chunks, own, axis=0)
    for s in range(axis_size - 1):
        acc = jax.lax.ppermute(acc, axis_name, ring)
        idx = (own - 1 - s) % axis_size
        acc = acc + jnp.take(chunks, idx, axis=0)
    # all-gather: circulate the reduced chunks around the same ring.
    out = jnp.zeros_like(chunks)
    out = jax.lax.dynamic_update_index_in_dim(
        out, acc, (own + 1) % axis_size, axis=0)
    cur = acc
    for s in range(axis_size - 1):
        cur = jax.lax.ppermute(cur, axis_name, ring)
        out = jax.lax.dynamic_update_index_in_dim(
            out, cur, (own - s) % axis_size, axis=0)
    return out.reshape(x.shape)


def microbatch_grads(loss_fn: Callable, params: Any, batch: Any,
                     num_micro: int) -> tuple[Any, jax.Array]:
    """Gradient accumulation over ``num_micro`` equal slices of ``batch``.

    Returns ``(grads, loss)`` — both means over microbatches, equal to the
    full-batch quantities when the loss is a batch mean. Microbatches run
    sequentially (lax.scan), so on a mesh the gradient exchange of slice i
    overlaps the backward of slice i+1.
    """

    def split(leaf):
        b = leaf.shape[0]
        if b % num_micro != 0:
            raise ValueError(
                f"batch dim {b} not divisible by num_micro={num_micro}")
        return leaf.reshape(num_micro, b // num_micro, *leaf.shape[1:])

    micro = jax.tree.map(split, batch)
    g0 = jax.tree.map(jnp.zeros_like, params)

    def step(carry, mb):
        g_acc, l_acc = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        g_acc = jax.tree.map(jnp.add, g_acc, g)
        return (g_acc, l_acc + loss), None

    (g_sum, l_sum), _ = jax.lax.scan(step, (g0, jnp.zeros(())), micro)
    inv = 1.0 / num_micro
    return jax.tree.map(lambda g: g * inv, g_sum), l_sum * inv
