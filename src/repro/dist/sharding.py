"""Sharding rules + activation-sharding hints (DM layer, paper §2.2).

The paper's distributed-memory setting assigns each process a contiguous
block of vertices (1D decomposition); here the same convention governs how
tensors spread over a device mesh:

  * batch-like leading dims shard over the flattened ('pod', 'data') axes
    (whichever exist in the active mesh) — ``batch_axes``/``BATCH``;
  * model-parallel dims shard over 'model' (Megatron split for
    transformer blocks, expert-parallel for MoE, table rows for recsys).

``hint`` is the in-model annotation primitive: a no-op until a cell
builder installs a mesh with ``set_activation_mesh``, after which it
lowers to ``with_sharding_constraint`` against that mesh. Axis entries
that don't exist in the mesh or don't divide the dim are dropped, so the
same model code traces on 1 CPU device and on a pod.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "BATCH", "hint", "set_activation_mesh", "get_activation_mesh",
    "batch_axes", "make_sharding", "transformer_param_specs",
    "recsys_param_specs",
]

# Sentinel axis name: "the flattened batch axes of the active mesh".
BATCH = "__batch__"

# Installed by cell builders (configs.steps / configs.registry) right
# before tracing; models read it through `hint` at trace time.
_ACT_MESH: Optional[Mesh] = None


def set_activation_mesh(mesh: Optional[Mesh]) -> None:
    """Install (or clear, with None) the mesh `hint` annotates against."""
    global _ACT_MESH
    _ACT_MESH = mesh


def get_activation_mesh() -> Optional[Mesh]:
    return _ACT_MESH


def batch_axes(mesh: Mesh) -> tuple:
    """The data-parallel axes present in ``mesh`` (flattened in specs)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    size = 1
    for a in names:
        size *= mesh.shape[a]
    return size


def _sanitize_entry(mesh: Mesh, entry, dim_size: int):
    """Keep only mesh axes that exist and evenly divide ``dim_size``."""
    if entry is None:
        return None
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    names = tuple(a for a in names if a in mesh.axis_names)
    if not names:
        return None
    if dim_size % _axis_size(mesh, names) != 0:
        return None
    return names[0] if len(names) == 1 else names


def make_sharding(mesh: Mesh, spec: P, shape: tuple) -> NamedSharding:
    """NamedSharding for ``shape`` with non-dividing axes dropped."""
    entries = []
    for dim, size in enumerate(shape):
        entry = spec[dim] if dim < len(spec) else None
        entries.append(_sanitize_entry(mesh, entry, size))
    return NamedSharding(mesh, P(*entries))


def hint(x: jax.Array, *axes) -> jax.Array:
    """Annotate ``x`` with a PartitionSpec against the activation mesh.

    ``axes`` entries: None (replicated), a mesh axis name, a tuple of axis
    names, or the BATCH sentinel (resolved to ``batch_axes(mesh)``).
    No-op when no mesh is installed.
    """
    mesh = _ACT_MESH
    if mesh is None:
        return x
    resolved = []
    for entry in axes:
        if entry == BATCH:
            ba = batch_axes(mesh)
            resolved.append(ba if ba else None)
        else:
            resolved.append(entry)
    sharding = make_sharding(mesh, P(*resolved), x.shape)
    return jax.lax.with_sharding_constraint(x, sharding)


# ------------------------------------------------------- param specs --
def _zero_axes(mesh: Mesh, zero: str) -> tuple:
    """ZeRO-style parameter sharding axes.

    'pull' — optimizer/parameter state shards over the data axes and is
    all-gathered (pulled) at use; 'push' — parameters stay replicated and
    gradients are pushed (reduce-scattered) only. Mirrors the paper's
    read-redundancy vs write-combining trade."""
    return batch_axes(mesh) if zero == "pull" else ()


def _spec_for_transformer_leaf(mesh: Mesh, path: tuple, leaf,
                               zero_ax: tuple) -> NamedSharding:
    keys = [p.key for p in path if hasattr(p, "key")]
    shape = leaf.shape
    nd = len(shape)
    entries = [None] * nd
    name = keys[-1] if keys else ""
    if name == "w":
        name = keys[-2] if len(keys) >= 2 else ""
    if name == "embed":
        entries[0] = "model"                     # [V, D] vocab-parallel
    elif name in ("wq", "wk", "wv", "wi", "wg", "unembed"):
        entries[nd - 1] = "model"                # output-dim split
    elif name == "wo" and nd >= 2:
        entries[nd - 2] = "model"                # input-dim split
    elif zero_ax and nd >= 1:
        entries[0] = zero_ax
    if zero_ax and nd >= 2 and entries[0] is None and name in (
            "wq", "wk", "wv", "wi", "wg", "wo"):
        entries[0] = zero_ax                     # leading L axis over data
    return make_sharding(mesh, P(*entries), shape)


def transformer_param_specs(mesh: Mesh, params: Any,
                            zero: str = "pull") -> Any:
    """Megatron-style NamedSharding tree for transformer params.

    Column-parallel wq/wk/wv/wi/wg + embeddings, row-parallel wo,
    replicated norms; ``zero='pull'`` additionally spreads the stacked
    layer axis over the data axes when divisible."""
    zero_ax = _zero_axes(mesh, zero)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_transformer_leaf(mesh, path, leaf,
                                                      zero_ax), params)


def recsys_param_specs(mesh: Mesh, params: Any) -> Any:
    """xDeepFM: embedding tables row-shard over 'model' (the dominant
    memory), dense towers replicate."""

    def one(path, leaf):
        keys = [p.key for p in path if hasattr(p, "key")]
        entries = [None] * len(leaf.shape)
        if any(k in ("tables", "table", "embed") for k in keys) and entries:
            entries[0] = "model"
        return make_sharding(mesh, P(*entries), leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params)
