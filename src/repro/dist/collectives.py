"""Distributed k-relaxation exchanges (paper §6, Fig 3).

The paper's DM variants of push/pull map onto two shard_map schedules
over a Partition-Awareness edge split (graphs.partition.pa_split):

  * ``push_exchange`` — the combined-alltoall "MP" push: every shard
    reduces its outgoing remote messages into a full-length private
    accumulator, then one ``psum_scatter`` both combines and delivers the
    owner slices. Bytes/device stay O(n/P · P) = O(n), flat in P.
  * ``pull_exchange`` — the RMA-style pull: owners all_gather the source
    values (O(n·(P-1)/P) bytes) and privately combine their in-edges —
    redundant reads, zero remote combining writes.

Both return ``(combined [n_padded], bytes_per_device)`` and are
numerically identical; they differ exactly in the communication structure
the paper measures. Edge payloads follow the PartitionedEdges layout:
``[P, cap]`` rows grouped by the owner shard, sentinel-padded, with a
``valid`` mask.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.primitives import combine_identity
from ..graphs.partition import Partition, PartitionedEdges
from ..sparse.segment import segment_max, segment_min, segment_sum

__all__ = ["push_exchange", "pull_exchange", "pa_exchange",
           "merge_combine"]

_SEGMENT = {"sum": segment_sum, "min": segment_min, "max": segment_max}


def merge_combine(combine: str, a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise ⊕ of two partial relaxation results."""
    if combine == "sum":
        return a + b
    if combine == "min":
        return jnp.minimum(a, b)
    return jnp.maximum(a, b)


def _messages(vals, w, msg_fn, combine, valid):
    """Per-edge payloads; default message is ``value * weight``."""
    msg = vals * w if msg_fn is None else msg_fn(vals, w)
    return jnp.where(valid, msg, combine_identity(combine, msg.dtype))


def push_exchange(mesh: Mesh, part: Partition, edges: PartitionedEdges,
                  vals: jax.Array, msg_fn: Optional[Callable] = None,
                  combine: str = "sum", axis: str = "data"
                  ) -> tuple[jax.Array, int]:
    """MP-style combining push over remote edges grouped by SRC owner.

    vals: ``[n_padded]`` source values (conceptually sharded over
    ``axis``; each shard only dereferences the sources it owns).
    Returns the per-destination combination of all remote messages,
    ``[n_padded]``, plus the analytic bytes each device moves.
    """
    Pn = part.num_parts
    shard = part.shard_size
    npad = part.n_padded

    @jax.shard_map(mesh=mesh,
                   in_specs=(P(axis), P(axis, None), P(axis, None),
                             P(axis, None), P(axis, None)),
                   out_specs=P(axis), check_vma=False)
    def block(vb, sb, db, wb, okb):
        src = sb.reshape(-1)
        dst = db.reshape(-1)
        w = wb.reshape(-1)
        ok = okb.reshape(-1)
        base = jax.lax.axis_index(axis) * shard
        v = vb[jnp.clip(src - base, 0, shard - 1)]
        msg = _messages(v, w, msg_fn, combine, ok)
        partial = _SEGMENT[combine](msg, jnp.clip(dst, 0, npad - 1), npad)
        if combine == "sum":
            return jax.lax.psum_scatter(partial, axis, scatter_dimension=0,
                                        tiled=True)
        red = (jax.lax.pmin if combine == "min"
               else jax.lax.pmax)(partial, axis)
        return jax.lax.dynamic_slice_in_dim(
            red, jax.lax.axis_index(axis) * shard, shard)

    out = block(vals, edges.src, edges.dst, edges.w, edges.valid)
    nbytes = npad * vals.dtype.itemsize          # combined all-to-all
    return out, nbytes


def pull_exchange(mesh: Mesh, part: Partition, edges: PartitionedEdges,
                  vals: jax.Array, msg_fn: Optional[Callable] = None,
                  combine: str = "sum", axis: str = "data"
                  ) -> tuple[jax.Array, int]:
    """RMA-style pull over remote edges grouped by DST owner.

    Each owner all_gathers the source values and privately combines its
    incoming remote edges — redundant reads instead of combining writes.
    """
    Pn = part.num_parts
    shard = part.shard_size
    npad = part.n_padded

    @jax.shard_map(mesh=mesh,
                   in_specs=(P(axis), P(axis, None), P(axis, None),
                             P(axis, None), P(axis, None)),
                   out_specs=P(axis), check_vma=False)
    def block(vb, sb, db, wb, okb):
        full = jax.lax.all_gather(vb, axis, tiled=True)       # [n_padded]
        src = sb.reshape(-1)
        dst = db.reshape(-1)
        w = wb.reshape(-1)
        ok = okb.reshape(-1)
        v = full[jnp.clip(src, 0, npad - 1)]
        msg = _messages(v, w, msg_fn, combine, ok)
        base = jax.lax.axis_index(axis) * shard
        ldst = jnp.clip(dst - base, 0, shard - 1)
        return _SEGMENT[combine](msg, ldst, shard)

    out = block(vals, edges.src, edges.dst, edges.w, edges.valid)
    nbytes = npad * vals.dtype.itemsize * (Pn - 1) // max(Pn, 1)
    return out, nbytes


def pa_exchange(mesh: Mesh, part: Partition, local: PartitionedEdges,
                remote: PartitionedEdges, vals: jax.Array,
                direction: str = "push",
                msg_fn: Optional[Callable] = None,
                combine: str = "sum", axis: str = "data"
                ) -> tuple[jax.Array, int]:
    """Full PA relaxation (paper Algorithm 8 structure): local edges are
    plain per-owner writes (no collective), remote edges go through the
    chosen exchange; results combine elementwise."""
    npad = part.n_padded
    src = local.src.reshape(-1)
    dst = local.dst.reshape(-1)
    w = local.w.reshape(-1)
    ok = local.valid.reshape(-1)
    v = jnp.pad(vals, (0, max(0, npad + 1 - vals.shape[0])),
                constant_values=0)[jnp.clip(src, 0, npad)]
    msg = _messages(v, w, msg_fn, combine, ok)
    loc = _SEGMENT[combine](msg, jnp.clip(dst, 0, npad - 1), npad)
    exch = push_exchange if direction == "push" else pull_exchange
    rem, nbytes = exch(mesh, part, remote, vals, msg_fn=msg_fn,
                       combine=combine, axis=axis)
    return merge_combine(combine, loc, rem), nbytes
