"""Gradient compression with error feedback (DM traffic reduction).

The paper reduces DM traffic by *combining* messages; for the training
side the analogous lever is compressing the gradient exchange. Both
schemes here keep an error-feedback accumulator so the compressed stream
is unbiased over time:

  * ``topk``  — keep the largest ``topk_frac`` entries per leaf (value +
    int32 index on the wire);
  * ``int8``  — symmetric per-leaf quantization (1 byte/entry + scale);
  * ``none``  — identity.

``compress_tree`` returns the *decompressed* gradients (what the
optimizer consumes after the exchange) plus the new error state;
``compressed_bytes`` is the analytic wire footprint the benchmarks and
the roofline collective term consume.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["CompressionConfig", "init_error_state", "compress_tree",
           "compressed_bytes"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"            # 'none' | 'topk' | 'int8'
    topk_frac: float = 0.01


def init_error_state(params: Any) -> Any:
    """Zero error-feedback accumulator shaped like ``params``."""
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def _topk_leaf(x: jax.Array, frac: float) -> jax.Array:
    flat = x.reshape(-1)
    k = max(1, int(frac * flat.shape[0]))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return kept.reshape(x.shape)


def _int8_leaf(x: jax.Array) -> jax.Array:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q.astype(x.dtype) * scale


def compress_tree(grads: Any, err_state: Any,
                  cfg: CompressionConfig) -> tuple[Any, Any]:
    """Error-feedback compression: compress (grad + carried error), carry
    the residual forward. Returns (decompressed_grads, new_err_state)."""
    if cfg.kind == "none":
        return grads, err_state

    if cfg.kind == "topk":
        compress = lambda acc: _topk_leaf(acc, cfg.topk_frac)  # noqa: E731
    elif cfg.kind == "int8":
        compress = _int8_leaf
    else:
        raise ValueError(f"unknown compression kind {cfg.kind!r}")

    accs = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                        grads, err_state)
    decs = jax.tree.map(compress, accs)
    err = jax.tree.map(jnp.subtract, accs, decs)
    dec = jax.tree.map(lambda d, g: d.astype(g.dtype), decs, grads)
    return dec, err


def compressed_bytes(tree: Any, cfg: CompressionConfig) -> int:
    """Analytic wire bytes of one compressed exchange of ``tree``."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = int(leaf.size)
        if cfg.kind == "none":
            total += n * 4
        elif cfg.kind == "int8":
            total += n * 1 + 4                      # payload + scale
        elif cfg.kind == "topk":
            k = max(1, int(cfg.topk_frac * n))
            total += k * (4 + 4)                    # value + index
        else:
            raise ValueError(f"unknown compression kind {cfg.kind!r}")
    return total
