"""Serving launcher: batched decode for an LM arch (smoke config on CPU;
the full config follows the decode cells' shardings on a pod).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b \
        --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_FAMILY, smoke_config
from repro.models.transformer import init_params
from repro.serve import ServeConfig, ServeLoop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=[a for a, f in ARCH_FAMILY.items()
                             if f == "lm"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--cache", choices=["bf16", "int8", "f32"],
                    default="bf16")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    loop = ServeLoop(params, cfg,
                     ServeConfig(max_len=args.max_len, batch=args.slots,
                                 cache_kind=args.cache))
    rids = [loop.submit([1 + i, 5 + i, 9 + i])
            for i in range(args.requests)]
    t0 = time.perf_counter()
    steps = 0
    while (loop.active.any() or loop.queue) and steps < 10_000:
        loop.step(max_new=args.max_new)
        steps += 1
    dt = time.perf_counter() - t0
    done = sum(1 for r in rids if len(loop.outputs[r]) >= args.max_new)
    total_toks = sum(len(v) for v in loop.outputs.values())
    print(f"{args.arch} ({cfg.name}): {done}/{args.requests} requests, "
          f"{total_toks} tokens in {steps} steps / {dt:.2f}s "
          f"({total_toks/max(dt,1e-9):.1f} tok/s, {args.slots} slots, "
          f"{args.cache} cache)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
