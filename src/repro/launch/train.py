"""Cluster training launcher: --arch/--shape selects an assigned cell and
runs real steps (synthetic data) with checkpointing + watchdog.

On this CPU container full configs do not execute; ``--smoke`` (default)
substitutes the reduced same-family config so the launcher is verifiable
end-to-end. On a real pod: drop --smoke, point --ckpt-dir at durable
storage, and the same code path trains the full config under
make_production_mesh() with the cell's shardings.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --shape train_4k --steps 20 --smoke
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_FAMILY, smoke_config, full_config
from repro.data import prefetch, recsys_batches, token_batches
from repro.dist import CompressionConfig
from repro.graphs import erdos_renyi
from repro.models import gnn as gnn_mod
from repro.models.recsys import xdeepfm_apply, xdeepfm_init
from repro.models.transformer import init_params, lm_loss
from repro.train import LoopConfig, OptConfig, TrainLoop
from repro.train.losses import bce_with_logits, mse


def _lm_setup(arch, smoke, batch, seq):
    cfg = smoke_config(arch) if smoke else full_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    data = prefetch(token_batches(batch, seq, cfg.vocab), 2)
    loss_fn = lambda p, b: lm_loss(p, cfg, b["tokens"], b["labels"])  # noqa
    return params, loss_fn, data


def _gnn_setup(arch, smoke, batch, seq):
    cfg = smoke_config(arch) if smoke else full_config(arch)
    g = erdos_renyi(256, 4.0, seed=0, weighted=True)
    rng = np.random.default_rng(0)
    init_fn = {"egnn": gnn_mod.egnn_init, "gin-tu": gnn_mod.gin_init,
               "graphsage-reddit": gnn_mod.sage_init,
               "graphcast": gnn_mod.graphcast_init}[arch]
    params = init_fn(jax.random.PRNGKey(0), cfg)
    if arch == "graphcast":
        nv = jax.numpy.asarray(
            rng.normal(size=(g.n, cfg.n_vars)).astype(np.float32))

        def loss_fn(p, b):
            return mse(gnn_mod.graphcast_apply(p, cfg, g, b["x"]), b["x"])

        def batches():
            while True:
                yield {"x": nv}
    else:
        feats = jax.numpy.asarray(
            rng.normal(size=(g.n, cfg.d_in)).astype(np.float32))
        coords = jax.numpy.asarray(
            rng.normal(size=(g.n, 3)).astype(np.float32))
        target = jax.numpy.asarray(
            rng.normal(size=(g.n, cfg.d_out)).astype(np.float32))

        def loss_fn(p, b):
            if arch == "egnn":
                out, _ = gnn_mod.egnn_apply(p, cfg, g, b["h"], coords)
            elif arch == "gin-tu":
                out = gnn_mod.gin_apply(p, cfg, g, b["h"])
            else:
                out = gnn_mod.sage_apply(p, cfg, g, b["h"])
            return mse(out, target)

        def batches():
            while True:
                yield {"h": feats}
    return params, loss_fn, batches()


def _recsys_setup(arch, smoke, batch, seq):
    cfg = smoke_config(arch) if smoke else full_config(arch)
    params = xdeepfm_init(jax.random.PRNGKey(0), cfg)
    data = prefetch(recsys_batches(batch, cfg.n_fields,
                                   cfg.vocab_per_field), 2)
    loss_fn = lambda p, b: bce_with_logits(  # noqa: E731
        xdeepfm_apply(p, cfg, b["ids"]), b["labels"])
    return params, loss_fn, data


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_FAMILY))
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compression", choices=["none", "topk", "int8"],
                    default="none")
    args = ap.parse_args(argv)

    family = ARCH_FAMILY[args.arch]
    setup = {"lm": _lm_setup, "gnn": _gnn_setup,
             "recsys": _recsys_setup}[family]
    params, loss_fn, data = setup(args.arch, args.smoke, args.batch,
                                  args.seq)
    loop = TrainLoop(
        loss_fn, params,
        OptConfig(lr=args.lr, total_steps=args.steps, warmup_steps=2),
        LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                   ckpt_every=max(5, args.steps // 2), log_every=5,
                   compression=CompressionConfig(kind=args.compression)))
    res = loop.run(data)
    print(f"{args.arch}: step={res['final_step']} "
          f"loss={res['final_loss']:.4f} "
          f"median_step={res['median_dt']*1e3:.1f}ms "
          f"stragglers={len(res['stragglers'])}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
