"""Production meshes. Functions only — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips per pod; multi_pod adds a leading
    2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has (tests/examples): 1D data mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
