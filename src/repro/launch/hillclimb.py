import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver: run named variants of the three chosen cells,
record roofline terms per iteration (EXPERIMENTS.md §Perf feeds from the
JSON this writes).

    PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen --variant v1
    PYTHONPATH=src python -m repro.launch.hillclimb --all
"""

import argparse
import dataclasses
import json
import sys

from repro.configs.archs import full_config  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402

OUT = "/root/repo/hillclimb_results.json"


def _moe_override(**kw):
    moe = full_config("deepseek-moe-16b").moe
    return dataclasses.replace(moe, **kw)


# variant registry: cell -> variant -> (description, kwargs for run_cell)
VARIANTS = {
    "qwen": {
        "_cell": ("qwen1.5-32b", "train_4k"),
        "v1_pad_heads": (
            "pad 40->48 heads (+20% attn FLOPs) so heads shard 16-way "
            "instead of replicating attention on every TP rank",
            dict(overrides={"n_heads": 48, "n_kv_heads": 48,
                            "head_dim": 128})),
        "v2_loss_chunk": (
            "v1 + loss_chunk 512->4096: one unembed pass per sequence "
            "(8x fewer streamed reads of the [5120,152064] matrix)",
            dict(overrides={"n_heads": 48, "n_kv_heads": 48,
                            "head_dim": 128, "loss_chunk": 4096})),
        "v3_attn_chunks": (
            "v2 + blockwise attention chunks 512/1024 -> 2048/2048 "
            "(4x fewer q-block iterations; less carry re-materialization)",
            dict(overrides={"n_heads": 48, "n_kv_heads": 48,
                            "head_dim": 128, "loss_chunk": 4096,
                            "q_chunk": 2048, "kv_chunk": 2048})),
        "v4_bf16_mxu": (
            "v1 + bf16 q/k/v streamed straight to the MXU "
            "(preferred_element_type=f32) instead of materializing f32 "
            "copies of every attention operand",
            dict(overrides={"n_heads": 48, "n_kv_heads": 48,
                            "head_dim": 128})),
    },
    "gin": {
        "_cell": ("gin-tu", "ogb_products"),
        "v1_shard_all": (
            "shard nodes/edges over all 256 devices (model axis was 16x "
            "replicated work+memory)",
            dict(overrides={"shard_axes": "all"})),
        "v2_bf16": (
            "v1 + bf16 feature payloads (halve the pull-exchange "
            "all-gather bytes)",
            dict(overrides={"shard_axes": "all", "dtype": "bfloat16"})),
        "v3_pa_exchange": (
            "v2 + the paper's PA pull-exchange via shard_map: edges "
            "pre-grouped by destination owner -> one all_gather/layer, "
            "no scatter all-reduce (GSPMD's generic lowering pays both)",
            dict(overrides={"mp_exchange": True, "dtype": "bfloat16"})),
    },
    "deepseek": {
        "_cell": ("deepseek-moe-16b", "train_4k"),
        "v1_bf16_combine": (
            "EP combine psum in bf16 (<= top_k contributions per token: "
            "halves the dominant expert-combine collective)",
            dict(overrides={"moe": _moe_override(combine_dtype="bf16")})),
        "v2_loss_attn": (
            "v1 + loss_chunk 4096 + attention chunks 1024/2048",
            dict(overrides={"moe": _moe_override(combine_dtype="bf16"),
                            "loss_chunk": 4096, "q_chunk": 1024,
                            "kv_chunk": 2048})),
        "v3_a2a": (
            "a2a EP: ranks split the token sequence and route via "
            "all_to_all (paper's MP combined-alltoall push) — 16x less "
            "redundant dispatch gather/scatter traffic than psum-EP",
            dict(overrides={"moe": _moe_override(ep_mode="a2a")})),
        "v4_a2a_shared": (
            "v3 + shared experts computed on the sequence slice (they "
            "were 16x redundant across model ranks; now folded into the "
            "a2a block before its all_gather)",
            dict(overrides={"moe": _moe_override(ep_mode="a2a")})),
        "v5_bf16_mxu": (
            "v4 + bf16 attention operands straight to the MXU (no f32 "
            "copies of q/k/v)",
            dict(overrides={"moe": _moe_override(ep_mode="a2a"),
                            "q_chunk": 512})),
    },
}


def load():
    try:
        with open(OUT) as f:
            return json.load(f)
    except FileNotFoundError:
        return {"runs": []}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(VARIANTS), default=None)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args(argv)

    todo = []
    for cell, table in VARIANTS.items():
        if args.cell and cell != args.cell:
            continue
        for vname, (desc, kw) in table.items():
            if vname == "_cell":
                continue
            if args.variant and vname != args.variant:
                continue
            todo.append((cell, vname, desc, kw, table["_cell"]))

    data = load()
    done = {(r["cell_key"], r["variant"]) for r in data["runs"]}
    for cell, vname, desc, kw, (arch, shape) in todo:
        if (cell, vname) in done:
            print(f"skip {cell}/{vname} (already recorded)")
            continue
        print(f"=== {cell}/{vname}: {desc}", flush=True)
        try:
            r = run_cell(arch, shape, multi_pod=False, **kw)
        except Exception as e:  # noqa: BLE001
            print(f"FAIL {cell}/{vname}: {e!r}")
            continue
        rec = {"cell_key": cell, "variant": vname, "description": desc,
               "result": r}
        data["runs"].append(rec)
        with open(OUT, "w") as f:
            json.dump(data, f, indent=1)
        rf = r["roofline"]
        print(f"    compute={rf['compute_s']:.3e} memory={rf['memory_s']:.3e} "
              f"collective={rf['collective_s']:.3e} dom={rf['dominant']}",
              flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
