import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every assigned (arch × shape) cell
on the production meshes and dump memory/cost analysis + collective stats.

MUST keep the two lines above first — jax locks the device count at first
init, so no repro/jax import may precede them.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b \
        --shape train_4k --mesh single --out results.json
    PYTHONPATH=src python -m repro.launch.dryrun --list
"""

import argparse
import json
import sys
import time
import traceback

import jax  # noqa: E402  (after XLA_FLAGS on purpose)

from repro.configs.registry import all_cells, build_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline.analysis import (collective_bytes_from_hlo,  # noqa: E402
                                     roofline_report)


def run_cell(arch: str, shape: str, multi_pod: bool,
             direction: str = "pull", zero: str = "pull",
             overrides=None, want_text: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, direction=direction, zero=zero,
                      overrides=overrides)
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate)
    with mesh:
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    n_dev = mesh.devices.size

    def _get(obj, name):
        v = getattr(obj, name, None)
        return int(v) if v is not None else None

    result = {
        "cell": f"{arch}@{shape}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "direction": direction,
        "zero": zero,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": _get(mem, "argument_size_in_bytes"),
            "output_bytes": _get(mem, "output_size_in_bytes"),
            "temp_bytes": _get(mem, "temp_size_in_bytes"),
            "generated_code_bytes": _get(mem, "generated_code_size_in_bytes"),
            "alias_bytes": _get(mem, "alias_size_in_bytes"),
        },
        "cost": {
            "flops": float(cost.get("flops", 0.0)) if cost else None,
            "bytes_accessed": (float(cost.get("bytes accessed", 0.0))
                               if cost else None),
        },
        "collectives": coll,
    }
    # scan-over-layers cells: cost_analysis counts the loop body once
    cfg_meta = cell.meta.get("cfg")
    loop_factor = getattr(cfg_meta, "n_layers", 1) \
        if type(cfg_meta).__name__ == "TransformerConfig" else 1
    result["roofline"] = roofline_report(result, loop_factor=loop_factor)
    if want_text:
        result["hlo_head"] = hlo[:4000]
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--direction", default="pull")
    ap.add_argument("--zero", default="pull")
    ap.add_argument("--out", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    cells = all_cells()
    if args.list:
        for a, s in cells:
            print(f"{a}@{s}")
        return 0
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    if not cells:
        print("no matching cells", file=sys.stderr)
        return 2

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    results, failures = [], []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}@{shape} [{'multi' if mp else 'single'}]"
            try:
                r = run_cell(arch, shape, mp, direction=args.direction,
                             zero=args.zero)
                results.append(r)
                mb = (r["memory"]["argument_bytes"] or 0) / (1 << 20)
                print(f"OK   {tag:55s} lower={r['t_lower_s']:6.1f}s "
                      f"compile={r['t_compile_s']:6.1f}s "
                      f"args/dev={mb:9.1f}MiB "
                      f"coll={r['collectives']['total_bytes']/(1<<20):9.1f}MiB",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — report, keep going
                failures.append({"cell": tag, "error": repr(e),
                                 "trace": traceback.format_exc()[-2000:]})
                print(f"FAIL {tag}: {e!r}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results, "failures": failures}, f,
                      indent=1)
    print(f"\n{len(results)} ok, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
