"""k-relaxation / k-filter as JAX primitives (paper §4 'Cost Derivations').

The paper reduces every algorithm to two primitives:

  * **k-relaxation** — propagate updates along k edges. Push: from the k
    active sources to their neighbors (combining writes). Pull: into each
    destination from its neighbors (private accumulation).
  * **k-filter** — compact the set of updated vertices (only needed when
    pushing; pulling inspects every vertex anyway).

Here both directions are dense-frontier JAX ops with identical *results*
and different *memory-access structure*; each returns (value, Cost) where
the Cost charges exactly what the paper's Table 1 counts:

  push: reads = Σ out_deg(frontier); combining writes = same (atomics for
        int payloads, locks for float payloads — CPUs lack float atomics).
  pull: reads = Σ in_deg(touched dst) (all m when dst set is dense);
        writes = |touched dst|, zero atomics/locks.

TPU note: on static-shape hardware the dense-masked formulation touches
all m lanes regardless; the Cost model charges the *algorithmic* counts
(what a frontier-compacted CPU/DM implementation moves), which is what the
roofline's collective term consumes. Wall-clock CPU benchmarks measure the
dense formulation; kernels/coo_push.py exploits frontier block sparsity.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..graphs.structure import Graph
from ..sparse.segment import segment_max, segment_min, segment_sum
from .cost_model import Cost, counter, counter_dtype

__all__ = [
    "push_relax", "pull_relax", "pull_relax_ell", "k_filter",
    "frontier_out_edges", "frontier_in_edges", "COMBINE_FNS",
    "combine_identity", "mask_untouched",
]

COMBINE_FNS = {
    "sum": segment_sum,
    "max": segment_max,
    "min": segment_min,
}


def combine_identity(combine: str, dtype) -> jax.Array:
    """Reduce identity: what an edge contributes when masked out, and what
    an empty segment holds after the reduce (callers test against it)."""
    if combine == "sum":
        return jnp.zeros((), dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        val = jnp.inf if combine == "min" else -jnp.inf
        return jnp.asarray(val, dtype)
    info = jnp.iinfo(dtype)
    return jnp.asarray(info.max if combine == "min" else info.min, dtype)


def mask_untouched(out: jax.Array, touched: jax.Array,
                   combine: str) -> jax.Array:
    """Set untouched destinations to the reduce identity (= 'no update');
    broadcasts a bool[n] mask over [n] or [n, d] outputs."""
    tb = touched.reshape((-1,) + (1,) * (out.ndim - 1))
    return jnp.where(tb, out, combine_identity(combine, out.dtype))


def frontier_out_edges(g: Graph, frontier: jax.Array) -> jax.Array:
    """Counter-typed count of frontier-incident out-edges = push work."""
    return jnp.sum(jnp.where(frontier, g.out_deg, 0).astype(counter_dtype()))


def frontier_in_edges(g: Graph, touched: jax.Array) -> jax.Array:
    """Counter-typed count of in-edges of touched destinations = pull work."""
    return jnp.sum(jnp.where(touched, g.in_deg, 0).astype(counter_dtype()))


def _edge_messages(values: jax.Array, src: jax.Array, w: jax.Array,
                   msg_fn: Optional[Callable]) -> jax.Array:
    """Per-edge message = msg_fn(value[src], w); default value*1."""
    x = jnp.take(values, src, axis=0, mode="fill", fill_value=0)
    if msg_fn is None:
        return x
    return msg_fn(x, w)


def push_relax(g: Graph, values: jax.Array, frontier: jax.Array,
               combine: str = "sum",
               msg_fn: Optional[Callable] = None,
               cost: Cost = Cost()) -> tuple[jax.Array, Cost]:
    """Push k-relaxation over the push-major (CSC) edge order.

    values: float/int [n] or [n, d] source payloads.
    frontier: bool[n]; only edges whose src is active contribute.
    Returns combined updates per destination, [n] or [n, d].
    """
    active_e = jnp.take(frontier, g.push_src, axis=0, mode="fill",
                        fill_value=False)
    msgs = _edge_messages(values, g.push_src, g.push_w, msg_fn)
    ident = combine_identity(combine, msgs.dtype)
    if msgs.ndim > 1:
        active_b = active_e.reshape((-1,) + (1,) * (msgs.ndim - 1))
    else:
        active_b = active_e
    msgs = jnp.where(active_b, msgs, ident)
    out = COMBINE_FNS[combine](msgs, g.push_dst, g.n)
    k = frontier_out_edges(g, frontier)
    width = 1 if values.ndim == 1 else values.shape[-1]
    cost = cost.charge(reads=k * width).charge_combining_writes(
        k * width, float_data=jnp.issubdtype(values.dtype, jnp.floating))
    return out, cost


def pull_relax(g: Graph, values: jax.Array, touched: Optional[jax.Array] = None,
               combine: str = "sum",
               msg_fn: Optional[Callable] = None,
               cost: Cost = Cost()) -> tuple[jax.Array, Cost]:
    """Pull k-relaxation over the pull-major (CSR) edge order.

    Each destination privately combines messages from ALL of its
    in-neighbors; ``touched`` (bool[n]) restricts which destinations are
    updated (their reads are still charged — pull must scan to know).
    """
    msgs = _edge_messages(values, g.coo_src, g.coo_w, msg_fn)
    out = COMBINE_FNS[combine](msgs, g.coo_dst, g.n)
    if touched is None:
        k = counter(g.m)
        wr = counter(g.n)
    else:
        out = mask_untouched(out, touched, combine)
        k = frontier_in_edges(g, touched)
        wr = jnp.sum(touched.astype(counter_dtype()))
    width = 1 if values.ndim == 1 else values.shape[-1]
    cost = cost.charge(reads=k * width, writes=wr * width)
    return out, cost


def pull_relax_ell(g: Graph, values: jax.Array,
                   combine: str = "sum",
                   msg_fn: Optional[Callable] = None,
                   cost: Cost = Cost()) -> tuple[jax.Array, Cost]:
    """Pull relaxation in ELL layout — dense [n, d_ell] gather+reduce.
    Mathematically equals pull_relax with touched=None; this is the layout
    the `ell_spmv` Pallas kernel tiles (rectangular VMEM blocks)."""
    v_pad = jnp.pad(values, [(0, 1)] + [(0, 0)] * (values.ndim - 1))
    gathered = jnp.take(v_pad, g.ell_idx, axis=0)  # [n, d_ell, ...]
    if msg_fn is not None:
        w = g.ell_w
        if gathered.ndim == 3:
            w = w[..., None]
        gathered = msg_fn(gathered, w)
    valid = (g.ell_idx < g.n)
    if gathered.ndim == 3:
        valid = valid[..., None]
    ident = combine_identity(combine, gathered.dtype)
    gathered = jnp.where(valid, gathered, ident)
    if combine == "sum":
        out = gathered.sum(axis=1)
    elif combine == "max":
        out = gathered.max(axis=1)
    else:
        out = gathered.min(axis=1)
    width = 1 if values.ndim == 1 else values.shape[-1]
    cost = cost.charge(reads=counter(g.m) * width,
                       writes=counter(g.n) * width)
    return out, cost


def k_filter(updated: jax.Array, cost: Cost = Cost()) -> tuple[jax.Array, Cost]:
    """k-filter: extract the updated-vertex set. Dense-mask world: identity
    on the mask, but charges the prefix-sum cost O(min(k, n)) the paper
    assigns (push only — pull checks every vertex anyway)."""
    k = jnp.sum(updated.astype(counter_dtype()))
    return updated, cost.charge(reads=k, writes=k, barriers=1)
