"""Boman Graph Coloring — paper §3.6 / §4.6 / Algorithm 6 + §5 strategies
(FE Frontier-Exploit, GS Generic-Switch, GrS Greedy-Switch, CR
Conflict-Removal, Algorithm 9).

Structure per iteration (Algorithm 6):
  phase 1  seq_color_partition: each partition (thread) greedily first-fit
           colors its own uncolored vertices — *sequential within,
           parallel across* partitions. JAX realization: fori_loop over
           the local slot i; slot i of every partition colors in parallel
           (a [P]-vector step), which is exactly the PRAM schedule.
  phase 2  fix_conflicts over border vertices:
           push — the iterating endpoint *writes the other endpoint's*
                  state (cross-partition CAS; O(Lm) combining writes);
           pull — each endpoint re-checks and demotes *itself* (remote
                  reads only).
           The loser of a conflict is the higher vertex id (deterministic,
           direction-independent result).

The baseline BGC now runs as a two-:class:`~repro.core.engine.Phase`
:class:`~repro.core.engine.PhaseProgram` (engine epoch = Algorithm 6
iteration); both phases are ``local_fn`` steps — they never touch the
exchange backend, but the DirectionPolicy still decides push/pull per
step and the phases charge the matching Table-1 cost. Registered with
``repro.api`` as ``"coloring"``; :func:`boman_coloring` is the thin
legacy wrapper. FE / GS / CR variants remain standalone strategies.

Colors are 1..C; 0 = uncolored. All strategies return identical-validity
colorings; they differ in iterations and Cost — Table 6b's subject.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.partition import partition_1d
from ...graphs.structure import Graph
from ..backend import DenseBackend, EllBackend, require_backend
from ..cost_model import Cost, counter, counter_dtype
from ..direction import Direction, Fixed
from ..engine import Phase, PhaseProgram, VertexProgram

__all__ = ["boman_coloring", "fe_coloring", "greedy_sequential",
           "conflict_removal_coloring", "ColoringResult",
           "validate_coloring", "coloring_program", "coloring_init",
           "coloring_finalize"]


class ColoringResult(NamedTuple):
    colors: jax.Array      # int32[n] in 1..C (0 only if C exhausted)
    cost: Cost
    iterations: jax.Array
    num_colors: jax.Array


def validate_coloring(g: Graph, colors: jax.Array) -> jax.Array:
    """True iff no edge joins two equal nonzero colors."""
    cs = jnp.take(colors, g.coo_src, mode="fill", fill_value=0)
    cd = jnp.take(colors, g.coo_dst, mode="fill", fill_value=0)
    bad = (cs == cd) & (cs > 0)
    return ~jnp.any(bad)


def _used_mask(g: Graph, v_ids: jax.Array, colors: jax.Array, C: int):
    """bool[k, C+1]: colors already used in N(v) for each v in v_ids."""
    nbrs = g.ell_idx[jnp.minimum(v_ids, g.n - 1)]          # [k, d_ell]
    ncol = jnp.take(jnp.pad(colors, (0, 1)), nbrs, axis=0)  # sentinel -> 0
    ncol = jnp.where(nbrs < g.n, ncol, 0)
    return (jax.nn.one_hot(ncol, C + 1, dtype=jnp.int32).sum(axis=1) > 0)


def _first_fit(used: jax.Array) -> jax.Array:
    """Smallest color in 1..C not present in `used` [k, C+1]; 0 if none."""
    C = used.shape[-1] - 1
    free = ~used[:, 1:]                                     # colors 1..C
    any_free = jnp.any(free, axis=-1)
    pick = jnp.argmax(free, axis=-1).astype(jnp.int32) + 1
    return jnp.where(any_free, pick, 0)


def _phase1(g: Graph, colors: jax.Array, P: int, C: int, cost: Cost,
            only_mask: jax.Array | None = None):
    """seq_color_partition for all partitions (slot-synchronous greedy)."""
    part = partition_1d(g.n, P)
    S = part.shard_size

    def slot(i, carry):
        colors_c, cost_c = carry
        v = jnp.minimum(i + S * jnp.arange(P, dtype=jnp.int32), g.n - 1)
        valid = (i + S * jnp.arange(P, dtype=jnp.int32)) < g.n
        todo = (jnp.take(colors_c, v) == 0) & valid
        if only_mask is not None:
            todo &= jnp.take(only_mask, v)
        used = _used_mask(g, v, colors_c, C)
        pick = _first_fit(used)
        new = jnp.where(todo, pick, jnp.take(colors_c, v))
        colors_c = colors_c.at[v].set(new)
        # reads: neighbor color scan; writes: one private write per vertex
        cost_c = cost_c.charge(
            reads=jnp.sum(jnp.where(todo, g.in_deg[v],
                                    0).astype(counter_dtype())),
            writes=jnp.sum(todo.astype(counter_dtype())))
        return colors_c, cost_c

    return jax.lax.fori_loop(0, S, slot, (colors, cost))


def _fix_conflicts(g: Graph, colors: jax.Array, P: int, do_push,
                   cost: Cost):
    """Phase 2: demote the higher-id endpoint of every conflicting
    cross-partition edge. The demotion is direction-independent; push
    writes the neighbor (combining int writes), pull re-checks and writes
    self (remote reads) — only the Cost structure differs."""
    part = partition_1d(g.n, P)
    own_s = part.owner(g.coo_src)
    own_d = part.owner(g.coo_dst)
    cs = jnp.take(colors, g.coo_src, mode="fill", fill_value=0)
    cd = jnp.take(colors, g.coo_dst, mode="fill", fill_value=0)
    cross = own_s != own_d
    conflict = cross & (cs == cd) & (cs > 0)
    n_conf = jnp.sum(conflict.astype(counter_dtype()))
    # loser = higher id endpoint; symmetric edge list covers both roles
    loser_is_dst = g.coo_dst > g.coo_src
    demote_dst = conflict & loser_is_dst
    demote = jax.ops.segment_max(
        demote_dst.astype(jnp.int32), g.coo_dst, num_segments=g.n) > 0
    colors = jnp.where(demote, 0, colors)
    # border scan reads both endpoint colors
    cost = cost.charge(reads=2 * jnp.sum(cross.astype(counter_dtype())))
    n_demoted = jnp.sum(demote.astype(counter_dtype()))
    cost = jax.lax.cond(
        jnp.asarray(do_push),
        # push: iterating endpoint CASes the other endpoint's color slot
        lambda c: c.charge_combining_writes(n_conf, float_data=False),
        # pull: loser re-reads neighbors and demotes itself (private)
        lambda c: c.charge(reads=n_conf, writes=n_demoted),
        cost)
    return colors, cost, n_conf


def coloring_program(g: Graph, num_parts: int = 16, C: int = 64,
                     max_iters: int = 64, policy=None, backend=None
                     ) -> tuple[PhaseProgram, int]:
    """Baseline BGC (Algorithm 6) as a two-phase engine program."""
    require_backend("coloring", backend, DenseBackend, EllBackend)

    def color_enter(g_, state, frontier, epoch):
        return state, state["colors"] == 0

    def color_local(g_, state, frontier, step, do_push, cost):
        colors, cost = _phase1(g_, state["colors"], num_parts, C, cost)
        return ({"colors": colors, "conf": state["conf"]}, frontier,
                jnp.bool_(True), cost)

    def fix_enter(g_, state, frontier, epoch):
        return state, jnp.ones((g_.n,), bool)

    def fix_local(g_, state, frontier, step, do_push, cost):
        colors, cost, conf = _fix_conflicts(g_, state["colors"],
                                            num_parts, do_push, cost)
        return {"colors": colors, "conf": conf}, frontier, \
            jnp.bool_(True), cost

    def epoch_cond(g_, state, epoch):
        return (epoch == 0) | (state["conf"] > 0)

    pp = PhaseProgram(
        phases=(Phase(program=VertexProgram(local_fn=color_local),
                      max_steps=1, name="color", enter_fn=color_enter),
                Phase(program=VertexProgram(local_fn=fix_local),
                      max_steps=1, name="fix", enter_fn=fix_enter)),
        epoch_cond=epoch_cond)
    return pp, max_iters


def coloring_init(g: Graph, **_):
    state0 = {"colors": jnp.zeros((g.n,), jnp.int32), "conf": counter(1)}
    return state0, jnp.ones((g.n,), bool)


def coloring_finalize(g: Graph, state):
    return {"colors": state["colors"],
            "num_colors": jnp.max(state["colors"])}


def boman_coloring(g: Graph, num_parts: int = 16, C: int = 64,
                   direction: str = "push", max_iters: int = 64
                   ) -> ColoringResult:
    """Legacy entry point — now a thin wrapper over ``repro.api.solve``."""
    from ... import api
    policy = Fixed(Direction.PUSH if direction == "push"
                   else Direction.PULL)
    r = api.solve(g, "coloring", policy=policy, num_parts=num_parts, C=C,
                  max_iters=max_iters)
    return ColoringResult(colors=r.state["colors"], cost=r.cost,
                          iterations=r.epochs,
                          num_colors=r.state["num_colors"])


@partial(jax.jit, static_argnames=("direction", "max_iters", "gs_threshold",
                                   "use_gs"))
def fe_coloring(g: Graph, key: jax.Array, direction: str = "push",
                max_iters: int = 256, use_gs: bool = False,
                gs_threshold: float = 0.1) -> ColoringResult:
    """Frontier-Exploit BGC (§5-FE), optional Generic-Switch (§5-GS).

    Round i colors the uncolored neighbors of the frontier with color c_i.
      push mode: all candidates grab c_i; adjacent candidate pairs conflict
                 and the higher id reverts (stays for a later round) —
                 fewer reads, more rounds (Table 6b: orc 49 -> 173);
      pull/GS mode: a candidate takes c_i only if it out-prioritizes all
                 uncolored neighbors (Jones–Plassmann style) — conflict-
                 free by construction, used once the uncolored tail drops
                 below `gs_threshold * n` when `use_gs`.
    """
    n = g.n
    prio = jax.random.permutation(key, n).astype(jnp.int32)

    # initial stable set: local priority maxima (one Luby step)
    nbr_prio = jnp.take(jnp.pad(prio, (0, 1), constant_values=-1),
                        g.ell_idx, axis=0)
    nbr_prio = jnp.where(g.ell_idx < n, nbr_prio, -1)
    stable = prio > nbr_prio.max(axis=1)
    colors0 = jnp.where(stable, 1, 0).astype(jnp.int32)

    def cond(st):
        colors, frontier, c_i, cost, it = st
        return (it < max_iters) & jnp.any(colors == 0)

    def body(st):
        colors, frontier, c_i, cost, it = st
        # candidates: uncolored vertices adjacent to the frontier
        fpad = jnp.pad(frontier, (0, 1))
        adj_f = jnp.take(fpad, g.ell_idx, axis=0) & (g.ell_idx < n)
        cand = (colors == 0) & jnp.any(adj_f, axis=1)
        cand = cand | ((colors == 0) & ~jnp.any(frontier))  # restart islands
        uncol_pad = jnp.pad(colors == 0, (0, 1))
        nbr_uncol = jnp.take(uncol_pad, g.ell_idx, axis=0)   # [n, d_ell]
        nbr_uncol = jnp.where(g.ell_idx < n, nbr_uncol, False)

        do_pull = jnp.asarray(use_gs) & (
            jnp.sum((colors == 0).astype(jnp.int32))
            < jnp.int32(gs_threshold * n))

        # pull / JP: take c_i only when out-prioritizing uncolored nbrs
        nbr_prio_u = jnp.where(nbr_uncol, nbr_prio, -1)
        wins = prio > nbr_prio_u.max(axis=1)
        take_pull = cand & wins

        # push: everyone grabs c_i; the higher-id endpoint of each
        # candidate-candidate edge conflicts and reverts to uncolored
        cpad = jnp.pad(cand, (0, 1))
        nbr_cand = jnp.take(cpad, g.ell_idx, axis=0) & (g.ell_idx < n)
        min_cand_nbr = jnp.where(nbr_cand, g.ell_idx, n).min(axis=1)
        take_push = cand & (min_cand_nbr > jnp.arange(n, dtype=jnp.int32))

        take = jnp.where(do_pull, take_pull, take_push)
        colors = jnp.where(take, c_i, colors)
        frontier = take
        reads = jnp.sum(jnp.where(cand, g.in_deg,
                                  0).astype(counter_dtype()))
        cost = cost.charge(reads=reads,
                           writes=jnp.sum(take.astype(counter_dtype())),
                           iterations=1, barriers=1)
        conflicts = jnp.sum((cand & ~take).astype(counter_dtype()))
        cost = jax.lax.cond(
            do_pull, lambda c: c,
            lambda c: c.charge_combining_writes(conflicts, float_data=False),
            cost)
        return colors, frontier, c_i + 1, cost, it + 1

    init = (colors0, stable, jnp.int32(2), Cost().charge(iterations=1),
            jnp.int32(0))
    colors, _, _, cost, iters = jax.lax.while_loop(cond, body, init)
    return ColoringResult(colors=colors, cost=cost, iterations=iters + 1,
                          num_colors=jnp.max(colors))


def greedy_sequential(g: Graph, colors: jax.Array, mask: jax.Array, C: int,
                      cost: Cost):
    """One-at-a-time first-fit over `mask` vertices (the GrS tail / CR
    border pre-pass). Sequential ⇒ conflict-free by construction."""
    n = g.n

    def step(i, carry):
        colors_c, cost_c = carry
        v = jnp.int32(i)
        todo = jnp.take(mask, v) & (jnp.take(colors_c, v) == 0)
        used = _used_mask(g, v[None], colors_c, C)
        pick = _first_fit(used)[0]
        colors_c = colors_c.at[v].set(
            jnp.where(todo, pick, jnp.take(colors_c, v)))
        cost_c = cost_c.charge(
            reads=jnp.where(todo, g.in_deg[v], 0).astype(counter_dtype()),
            writes=todo.astype(counter_dtype()))
        return colors_c, cost_c

    return jax.lax.fori_loop(0, n, step, (colors, cost))


@partial(jax.jit, static_argnames=("num_parts", "C"))
def conflict_removal_coloring(g: Graph, num_parts: int = 16, C: int = 64
                              ) -> ColoringResult:
    """§5-CR (Algorithm 9): greedily pre-color the border set B, then color
    partition interiors in parallel — zero conflicts, one iteration."""
    part = partition_1d(g.n, num_parts)
    own_s = part.owner(g.coo_src)
    own_d = part.owner(g.coo_dst)
    cross = own_s != own_d
    border = (jax.ops.segment_max(cross.astype(jnp.int32), g.coo_dst,
                                  num_segments=g.n) > 0)
    colors = jnp.zeros((g.n,), jnp.int32)
    colors, cost = greedy_sequential(g, colors, border, C, Cost())
    cost = cost.charge(barriers=1)
    colors, cost = _phase1(g, colors, num_parts, C, cost,
                           only_mask=~border)
    cost = cost.charge(iterations=1)
    return ColoringResult(colors=colors, cost=cost,
                          iterations=jnp.int32(1),
                          num_colors=jnp.max(colors))
