"""Data-driven (residual) PageRank — Whang et al. [60], the paper's §3.1
source for the PR push/pull observation, in its *incremental* form:

only vertices with residual above tolerance are active; they distribute
damp·res/d(v) to their neighbors and bank res into their rank. Work per
round ∝ active out-edges — Frontier-Exploit applied to PR, and the
natural habitat of pushing (the paper's 'pushing can often be done with
less work when only a subset of vertices needs to update').

push: active vertices scatter residual shares (float combining writes on
      the active edge set only);
pull: every vertex gathers the active residual shares (reads all m).

Both converge to the same fixpoint as power iteration.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ..cost_model import Cost
from ..primitives import pull_relax, push_relax

__all__ = ["pagerank_delta", "PRDeltaResult"]


class PRDeltaResult(NamedTuple):
    ranks: jax.Array
    cost: Cost
    rounds: jax.Array
    max_residual: jax.Array


@partial(jax.jit, static_argnames=("direction", "max_rounds"))
def pagerank_delta(g: Graph, tol: float = 1e-6, damp: float = 0.85,
                   direction: str = "push", max_rounds: int = 10_000
                   ) -> PRDeltaResult:
    n = g.n
    deg = jnp.maximum(g.out_deg, 1).astype(jnp.float32)

    def cond(st):
        _r, res, _c, rnd = st
        return (rnd < max_rounds) & jnp.any(jnp.abs(res) > tol)

    def body(st):
        rank, res, cost, rnd = st
        active = jnp.abs(res) > tol
        share = jnp.where(active, damp * res / deg, 0.0)
        if direction == "push":
            delta, cost = push_relax(g, share, active, combine="sum",
                                     cost=cost)
        else:
            delta, cost = pull_relax(
                g, share, combine="sum", cost=cost)
        rank = rank + jnp.where(active, res, 0.0)
        res = jnp.where(active, 0.0, res) + delta
        cost = cost.charge(iterations=1, barriers=1,
                           writes=jnp.sum(active.astype(jnp.int64)))
        return rank, res, cost, rnd + 1

    rank0 = jnp.zeros((n,), jnp.float32)
    res0 = jnp.full((n,), (1.0 - damp) / n, jnp.float32)
    rank, res, cost, rounds = jax.lax.while_loop(
        cond, body, (rank0, res0, Cost(), jnp.int32(0)))
    return PRDeltaResult(ranks=rank + res, cost=cost, rounds=rounds,
                         max_residual=jnp.max(jnp.abs(res)))
