"""Data-driven (residual) PageRank — Whang et al. [60], the paper's §3.1
source for the PR push/pull observation, in its *incremental* form:

only vertices with residual above tolerance are active; they distribute
damp·res/d(v) to their neighbors and bank res into their rank. Work per
round ∝ active out-edges — Frontier-Exploit applied to PR, and the
natural habitat of pushing (the paper's 'pushing can often be done with
less work when only a subset of vertices needs to update').

push: active vertices scatter residual shares (float combining writes on
      the active edge set only);
pull: every vertex gathers the active residual shares (reads all m).

Both converge to the same fixpoint as power iteration. Registered with
``repro.api`` as ``"pr_delta"``; :func:`pagerank_delta` is the thin
legacy wrapper.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ..cost_model import Cost
from ..direction import Direction, Fixed
from ..engine import VertexProgram

__all__ = ["pagerank_delta", "PRDeltaResult", "pr_delta_program",
           "pr_delta_init", "pr_delta_finalize"]


class PRDeltaResult(NamedTuple):
    ranks: jax.Array
    cost: Cost
    rounds: jax.Array
    max_residual: jax.Array


def pr_delta_program(g: Graph, tol: float = 1e-6, damp: float = 0.85,
                     policy=None, backend=None
                     ) -> tuple[VertexProgram, int]:
    def values_fn(g_, state, frontier):
        deg = jnp.maximum(g_.out_deg, 1).astype(jnp.float32)
        return jnp.where(frontier, damp * state["res"] / deg, 0.0)

    def update(state, msgs, step):
        # `active` equals the frontier the engine just relaxed: the
        # residual field is untouched since it was derived.
        active = jnp.abs(state["res"]) > tol
        rank = state["rank"] + jnp.where(active, state["res"], 0.0)
        res = jnp.where(active, 0.0, state["res"]) + msgs
        nxt = jnp.abs(res) > tol
        return {"rank": rank, "res": res}, nxt, ~jnp.any(nxt)

    def charge_fn(g_, state, frontier):
        # banking res into rank: one write per active vertex
        return {"writes": jnp.sum(frontier.astype(jnp.int64))}

    prog = VertexProgram(combine="sum", update_fn=update,
                         values_fn=values_fn, charge_fn=charge_fn)
    return prog, 10_000


def pr_delta_init(g: Graph, tol: float = 1e-6, damp: float = 0.85, **_):
    n = g.n
    state0 = {"rank": jnp.zeros((n,), jnp.float32),
              "res": jnp.full((n,), (1.0 - damp) / n, jnp.float32)}
    return state0, jnp.abs(state0["res"]) > tol


def pr_delta_finalize(g, state):
    return {"ranks": state["rank"] + state["res"],
            "max_residual": jnp.max(jnp.abs(state["res"]))}


def pagerank_delta(g: Graph, tol: float = 1e-6, damp: float = 0.85,
                   direction: str = "push", max_rounds: int = 10_000
                   ) -> PRDeltaResult:
    """Legacy entry point — now a thin wrapper over ``repro.api.solve``."""
    from ... import api
    policy = Fixed(Direction.PUSH if direction == "push"
                   else Direction.PULL)
    r = api.solve(g, "pr_delta", policy=policy, max_steps=max_rounds,
                  tol=tol, damp=damp)
    return PRDeltaResult(ranks=r.state["ranks"], cost=r.cost,
                         rounds=r.steps,
                         max_residual=r.state["max_residual"])
