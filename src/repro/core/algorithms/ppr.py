"""Personalized PageRank — the source-parameterized PR variant the
service layer batches (paper §3.1's iteration with a personalization
vector).

    r = (1-f)·e_s + f · Σ_{w∈N(v)} r(w)/d(w)

Identical exchange structure to power-iteration PageRank — every vertex
active every step, wire values are rank/out-degree contributions — but
the teleport mass restarts at a single *source* vertex, so each query is
parameterized like BFS/SSSP. That makes it the natural third member of
the batched multi-query family (``repro.service``): B personalization
vectors ride as B payload columns over one shared graph scan.

push: every vertex scatters r(v)/d(v) into each neighbor's accumulator
      (float combining writes — O(m) locks per iteration, Table 1);
pull: every vertex gathers neighbors' contributions privately
      (0 atomics, O(m) reads per iteration).

Unlike plain ``pagerank`` (fixed iteration count), the iteration stops
at a residual fixed point: ``converged`` is True once the max rank
change drops below ``tol``. Registered with ``repro.api`` as ``"ppr"``;
:func:`personalized_pagerank` is the thin convenience wrapper.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ..backend import DenseBackend, EllBackend, require_backend
from ..cost_model import Cost
from ..engine import VertexProgram

__all__ = ["personalized_pagerank", "PPRResult", "ppr_program",
           "ppr_init", "ppr_finalize"]


class PPRResult(NamedTuple):
    ranks: jax.Array     # float32[n]
    cost: Cost
    iterations: jax.Array
    residual: jax.Array


def ppr_program(g: Graph, iters: int = 100, damp: float = 0.85,
                tol: float = 1e-6, policy=None, backend=None
                ) -> tuple[VertexProgram, int]:
    """Personalized power iteration as a vertex program.

    The personalization (teleport) vector lives in the state as
    ``base = (1-damp)·e_source`` so the program itself closes over
    static scalars only; convergence is the residual fixed point
    ``max|r' - r| < tol`` (bounded by ``iters`` steps).
    """
    require_backend("ppr", backend, DenseBackend, EllBackend)
    n = g.n
    damp = float(damp)
    tol = float(tol)

    def values_fn(g_, state, frontier):
        deg = jnp.maximum(g_.out_deg, 1).astype(jnp.float32)
        return state["rank"] / deg

    def update(state, msgs, step):
        rank = state["base"] + jnp.float32(damp) * msgs
        resid = jnp.max(jnp.abs(rank - state["rank"]))
        new = {"rank": rank, "base": state["base"], "resid": resid}
        return new, jnp.ones((n,), bool), resid < tol

    prog = VertexProgram(combine="sum", update_fn=update,
                         values_fn=values_fn,
                         # reading own rank + degree for the contribution
                         step_charges=(("reads", 2 * n),))
    return prog, iters


def ppr_init(g: Graph, source=0, damp: float = 0.85, **_):
    source = jnp.asarray(source, jnp.int32)
    base = jnp.zeros((g.n,), jnp.float32).at[source].set(
        jnp.float32(1.0 - damp))
    state0 = {"rank": base, "base": base, "resid": jnp.float32(jnp.inf)}
    return state0, jnp.ones((g.n,), bool)


def ppr_finalize(g: Graph, state):
    return {"ranks": state["rank"], "residual": state["resid"]}


def personalized_pagerank(g: Graph, source: int | jax.Array,
                          iters: int = 100, damp: float = 0.85,
                          tol: float = 1e-6,
                          direction: str = "pull") -> PPRResult:
    """Convenience wrapper over ``repro.api.solve`` (policy = Fixed)."""
    from ... import api
    r = api.solve(g, "ppr", policy=direction, source=source, iters=iters,
                  damp=damp, tol=tol)
    return PPRResult(ranks=r.state["ranks"], cost=r.cost,
                     iterations=r.steps, residual=r.state["residual"])
