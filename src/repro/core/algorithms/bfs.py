"""BFS — paper §3.3 / §4.3 / Algorithm 3, with direction optimization.

push (top-down): frontier vertices mark unvisited out-neighbors
      (CAS combining writes: O(m) atomics total; work O(m));
pull (bottom-up): every unvisited vertex scans in-neighbors for a parent
      (no atomics; O(D·m) reads worst case — Table 1);
auto: GenericSwitch — the Beamer direction-optimizing rule, the paper's
      canonical GS instance (≈2.4x on power-law graphs).

Parents are chosen with a combining-min over candidate parent ids, which
makes the result deterministic and direction-independent (same BFS tree
levels; parent = min-id neighbor in the previous level).

The algorithm is expressed as a :class:`~repro.core.engine.VertexProgram`
and registered with ``repro.api`` as ``"bfs"``; :func:`bfs` is the thin
legacy wrapper around ``repro.api.solve``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ..cost_model import Cost
from ..direction import DirectionPolicy, Fixed, Direction
from ..engine import VertexProgram

__all__ = ["bfs", "BFSResult", "bfs_program", "bfs_init"]

_UNREACHED = jnp.int32(2147483647)


class BFSResult(NamedTuple):
    dist: jax.Array        # int32[n], _UNREACHED if unreachable
    parent: jax.Array      # int32[n], n for none
    cost: Cost
    levels: jax.Array      # int32 number of frontier expansions
    push_steps: jax.Array  # int32 how many levels ran in push mode


def bfs_program(g: Graph, policy=None, backend=None
                ) -> tuple[VertexProgram, int]:
    """Level-synchronous BFS as a vertex program.

    Wire values are candidate parent ids (frontier vertices advertise
    their own id, everyone else a >n sentinel); combining-min picks the
    deterministic min-id parent in either direction. Pull only inspects
    unvisited destinations (paper: the bottom-up scan).
    """
    n = g.n

    def values_fn(g_, state, frontier):
        ids = jnp.arange(g_.n, dtype=jnp.int32)
        return jnp.where(frontier, ids, jnp.int32(g_.n + 7))

    def update(state, msgs, step):
        visited = state["visited"]
        nxt = (~visited) & (msgs < n)
        new = {"dist": jnp.where(nxt, (step + 1).astype(jnp.int32),
                                 state["dist"]),
               "parent": jnp.where(nxt, msgs, state["parent"]),
               "visited": visited | nxt}
        return new, nxt, ~jnp.any(nxt)

    prog = VertexProgram(combine="min", update_fn=update,
                         values_fn=values_fn, pull_touched="unvisited",
                         k_filter_push=True)
    return prog, n + 1


def bfs_init(g: Graph, root=0, **_):
    root = jnp.asarray(root, jnp.int32)
    n = g.n
    frontier0 = jnp.zeros((n,), bool).at[root].set(True)
    state0 = {
        "dist": jnp.full((n,), _UNREACHED, jnp.int32).at[root].set(0),
        "parent": jnp.full((n,), n, jnp.int32).at[root].set(root),
        "visited": frontier0,
    }
    return state0, frontier0


def bfs(g: Graph, root: int | jax.Array,
        policy: DirectionPolicy = Fixed(Direction.PUSH)) -> BFSResult:
    """Legacy entry point — now a thin wrapper over ``repro.api.solve``."""
    from ... import api
    r = api.solve(g, "bfs", policy=policy, root=root)
    return BFSResult(dist=r.state["dist"], parent=r.state["parent"],
                     cost=r.cost, levels=r.steps, push_steps=r.push_steps)
