"""BFS — paper §3.3 / §4.3 / Algorithm 3, with direction optimization.

push (top-down): frontier vertices mark unvisited out-neighbors
      (CAS combining writes: O(m) atomics total; work O(m));
pull (bottom-up): every unvisited vertex scans in-neighbors for a parent
      (no atomics; O(D·m) reads worst case — Table 1);
auto: GenericSwitch — the Beamer direction-optimizing rule, the paper's
      canonical GS instance (≈2.4x on power-law graphs).

Parents are chosen with a combining-min over candidate parent ids, which
makes the result deterministic and direction-independent (same BFS tree
levels; parent = min-id neighbor in the previous level).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ..cost_model import Cost
from ..direction import DirectionPolicy, Fixed, Direction
from ..primitives import (frontier_in_edges, frontier_out_edges, k_filter,
                          push_relax, pull_relax)

__all__ = ["bfs", "BFSResult"]

_UNREACHED = jnp.int32(2147483647)


class BFSResult(NamedTuple):
    dist: jax.Array        # int32[n], _UNREACHED if unreachable
    parent: jax.Array      # int32[n], n for none
    cost: Cost
    levels: jax.Array      # int32 number of frontier expansions
    push_steps: jax.Array  # int32 how many levels ran in push mode


def _step_push(g: Graph, visited, frontier, cost):
    """next-frontier flags + parent candidates via push (scatter)."""
    ids = jnp.arange(g.n, dtype=jnp.int32)
    cand, cost = push_relax(g, ids, frontier, combine="min", cost=cost)
    has_parent = cand < g.n  # min over empty segment = int32 max
    nxt = has_parent & ~visited
    # k-filter compacts the pushed updates (paper: needed only for push)
    _, cost = k_filter(nxt, cost)
    return nxt, jnp.where(nxt, cand, g.n), cost


def _step_pull(g: Graph, visited, frontier, cost):
    """each unvisited vertex pulls: is any in-neighbor in the frontier?"""
    ids = jnp.arange(g.n, dtype=jnp.int32)
    cand_src = jnp.where(frontier, ids, jnp.int32(g.n + 7))
    cand, cost = pull_relax(g, cand_src, touched=~visited, combine="min",
                            cost=cost)
    nxt = (~visited) & (cand < g.n)
    return nxt, jnp.where(nxt, cand, g.n), cost


@partial(jax.jit, static_argnames=("policy",))
def bfs(g: Graph, root: int | jax.Array, policy: DirectionPolicy = Fixed(Direction.PUSH)
        ) -> BFSResult:
    n = g.n
    root = jnp.asarray(root, jnp.int32)
    dist0 = jnp.full((n,), _UNREACHED, jnp.int32).at[root].set(0)
    parent0 = jnp.full((n,), n, jnp.int32).at[root].set(root)
    frontier0 = jnp.zeros((n,), bool).at[root].set(True)
    visited0 = frontier0

    def cond(state):
        _, _, frontier, *_ = state
        return jnp.any(frontier)

    def body(state):
        dist, parent, frontier, visited, level, cost, pushes = state
        unvisited_edges = frontier_in_edges(g, ~visited)
        do_push = policy.decide_push(g, frontier, unvisited_edges)

        # lax.cond executes only the chosen direction at runtime — the
        # direction switch must not pay for both variants.
        nxt, par, cost = jax.lax.cond(
            do_push,
            lambda v, f, c: _step_push(g, v, f, c),
            lambda v, f, c: _step_pull(g, v, f, c),
            visited, frontier, cost)

        parent = jnp.where(nxt, par, parent)
        dist = jnp.where(nxt, level + 1, dist)
        visited = visited | nxt
        cost = cost.charge(iterations=1, barriers=1)
        return (dist, parent, nxt, visited, level + 1, cost,
                pushes + do_push.astype(jnp.int32))

    init = (dist0, parent0, frontier0, visited0, jnp.int32(0), Cost(),
            jnp.int32(0))
    dist, parent, _, _, level, cost, pushes = jax.lax.while_loop(
        cond, body, init)
    return BFSResult(dist=dist, parent=parent, cost=cost, levels=level,
                     push_steps=pushes)
