"""Boruvka MST — paper §3.7 / §4.7 / Algorithm 7.

Each round: (FM) every supervertex finds its minimum-weight outgoing edge;
(BMT/M) incident supervertices hook along those edges and contract via
pointer jumping. Rounds at least halve the component count: O(log n).

push (FM): every edge offers its key to *both* incident supervertices'
      shared minimum slots — cross-component combining-min writes (CAS
      loops on CPU; O(n²)-bounded atomics, Table 1);
pull (FM): each supervertex privately min-reduces over its own incident
      edges — reads only, no combining writes.

Determinism: edge keys pack (weight bits, undirected-pair rank) into one
int64, so comparison is orientation-invariant — the per-cycle global
minimum is picked identically from both sides, hence hooking only creates
mutual 2-cycles (broken toward the lower root) and pointer jumping always
terminates. Both directions return the same MST.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ...sparse.segment import segment_min
from ..cost_model import Cost

__all__ = ["boruvka_mst", "MSTResult"]


class MSTResult(NamedTuple):
    in_mst: jax.Array       # bool[m] over pull-major edge slots (1 orient.)
    weight: jax.Array       # float32 total MST weight
    components: jax.Array   # int32 final component count (1 if connected)
    cost: Cost
    rounds: jax.Array


@partial(jax.jit, static_argnames=("direction", "max_rounds"))
def boruvka_mst(g: Graph, direction: str = "pull", max_rounds: int = 64
                ) -> MSTResult:
    n, m = g.n, g.m
    eid = jnp.arange(m, dtype=jnp.int64)
    src, dst, w = g.coo_src, g.coo_dst, g.coo_w
    BIG = jnp.iinfo(jnp.int64).max

    # orientation-invariant undirected pair rank in [0, m)
    lo = jnp.minimum(src, dst).astype(jnp.int64)
    hi = jnp.maximum(src, dst).astype(jnp.int64)
    pair = lo * (n + 1) + hi
    _, pair_rank = jnp.unique(pair, return_inverse=True, size=m)
    # weights are positive floats: int32 bit pattern preserves order
    wbits = jax.lax.bitcast_convert_type(w, jnp.int32).astype(jnp.int64)
    pairkey = wbits * (m + 1) + pair_rank

    def cond(st):
        comp, in_mst, cost, rnd, done = st
        return (~done) & (rnd < max_rounds)

    def body(st):
        comp, in_mst, cost, rnd, _ = st
        cs = jnp.take(comp, src)
        cd = jnp.take(comp, dst)
        external = cs != cd
        key = jnp.where(external, pairkey, BIG)

        # --- FM: orientation-invariant min key per component ------------
        if direction == "pull":
            min_key = segment_min(key, cs, n)
            cost = cost.charge(reads=jnp.asarray(m, jnp.int64),
                               writes=jnp.asarray(n, jnp.int64))
        else:
            min_a = segment_min(key, cs, n)
            min_b = segment_min(key, cd, n)
            min_key = jnp.minimum(min_a, min_b)
            k_ext = jnp.sum(external.astype(jnp.int64))
            cost = cost.charge(reads=jnp.asarray(m, jnp.int64))
            cost = cost.charge_combining_writes(k_ext, float_data=False)
        cost = cost.charge(barriers=1)
        has_edge = min_key < BIG

        # representative slot (src-side orientation always exists because
        # the edge list is symmetric): min slot among winners
        winner = key == jnp.take(min_key, cs)
        sel_slot = segment_min(jnp.where(winner, eid, BIG), cs, n)
        sel_slot_c = jnp.where(has_edge, sel_slot, 0).astype(jnp.int32)
        hit = jnp.zeros((m,), bool).at[sel_slot_c].set(has_edge)
        in_mst = in_mst | hit

        # --- BMT/M: hook to the other side's component, contract --------
        other = jnp.take(comp, jnp.take(dst, sel_slot_c))
        parent = jnp.where(has_edge, other, jnp.arange(n, dtype=jnp.int32))
        # mutual 2-cycles: the lower root wins and becomes a root
        pp = jnp.take(parent, parent)
        me = jnp.arange(n, dtype=jnp.int32)
        parent = jnp.where((pp == me) & (me < parent), me, parent)

        # pointer jumping: depth halves per step -> ceil(log2 n)+1 bounds
        # convergence; fori (not while) so malformed hooks can never hang
        n_jumps = max(1, math.ceil(math.log2(max(2, n))) + 1)
        parent = jax.lax.fori_loop(
            0, n_jumps, lambda _, p: jnp.take(p, p), parent)
        comp_new = jnp.take(parent, comp)
        cost = cost.charge(writes=jnp.asarray(n, jnp.int64), barriers=1,
                           iterations=1)
        done = ~jnp.any(has_edge)
        return comp_new, in_mst, cost, rnd + 1, done

    comp0 = jnp.arange(n, dtype=jnp.int32)
    comp, in_mst, cost, rounds, _ = jax.lax.while_loop(
        cond, body, (comp0, jnp.zeros((m,), bool), Cost(), jnp.int32(0),
                     jnp.bool_(False)))

    # total weight with undirected dedup (both orientations may be marked)
    order = jnp.argsort(pair)
    pair_s = pair[order]
    sel_s = in_mst[order]
    w_s = w[order]
    first = jnp.concatenate([jnp.array([True]), pair_s[1:] != pair_s[:-1]])
    grp = jnp.cumsum(first.astype(jnp.int32)) - 1
    any_sel = jax.ops.segment_max(sel_s.astype(jnp.int32), grp,
                                  num_segments=m) > 0
    pair_w = jax.ops.segment_max(w_s, grp, num_segments=m)
    weight = jnp.sum(jnp.where(any_sel, pair_w, 0.0))

    roots = jax.ops.segment_max(jnp.ones((n,), jnp.int32), comp,
                                num_segments=n) > 0
    components = jnp.sum(roots.astype(jnp.int32))
    return MSTResult(in_mst=in_mst, weight=weight, components=components,
                     cost=cost, rounds=rounds)
