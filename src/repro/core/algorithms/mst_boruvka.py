"""Boruvka MST — paper §3.7 / §4.7 / Algorithm 7.

Each round: (FM) every supervertex finds its minimum-weight outgoing edge;
(BMT/M) incident supervertices hook along those edges and contract via
pointer jumping. Rounds at least halve the component count: O(log n).

push (FM): every edge offers its key to *both* incident supervertices'
      shared minimum slots — cross-component combining-min writes (CAS
      loops on CPU; O(n²)-bounded atomics, Table 1);
pull (FM): each supervertex privately min-reduces over its own incident
      edges — reads only, no combining writes.

The round structure is a two-:class:`~repro.core.engine.Phase`
:class:`~repro.core.engine.PhaseProgram`: a *find-min* phase (a local
edge map over the contracted supervertex ids — the reduce is keyed by
``comp``, not vertex id, so it bypasses the exchange backend) and a
*contract* phase (the per-round graph-contraction hook: pointer jumping +
supervertex relabel). The engine epoch loop is Algorithm 7's round loop.
Registered with ``repro.api`` as ``"mst_boruvka"``; :func:`boruvka_mst`
is the thin legacy wrapper.

Determinism: edge keys pack (weight bits, undirected-pair rank) into one
int64, so comparison is orientation-invariant — the per-cycle global
minimum is picked identically from both sides, hence hooking only creates
mutual 2-cycles (broken toward the lower root) and pointer jumping always
terminates. Both directions return the same MST.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ...sparse.segment import segment_min
from ..backend import DenseBackend, EllBackend, require_backend
from ..cost_model import Cost, counter, counter_dtype
from ..direction import Direction, Fixed
from ..engine import Phase, PhaseProgram, VertexProgram

__all__ = ["boruvka_mst", "MSTResult", "mst_program", "mst_init",
           "mst_finalize"]

_BIG = jnp.iinfo(jnp.int64).max


class MSTResult(NamedTuple):
    in_mst: jax.Array       # bool[m] over pull-major edge slots (1 orient.)
    weight: jax.Array       # float32 total MST weight
    components: jax.Array   # int32 final component count (1 if connected)
    cost: Cost
    rounds: jax.Array


def mst_program(g: Graph, policy=None, backend=None
                ) -> tuple[PhaseProgram, int]:
    """Borůvka as find-min + contract phases per engine epoch."""
    require_backend("mst_boruvka", backend, DenseBackend, EllBackend)
    n, m = g.n, g.m

    def fm_enter(g_, state, frontier, epoch):
        return state, jnp.ones((n,), bool)

    def fm_local(g_, state, frontier, step, do_push, cost):
        comp = state["comp"]
        src, dst = g_.coo_src, g_.coo_dst
        eid = jnp.arange(m, dtype=jnp.int64)
        cs = jnp.take(comp, src)
        cd = jnp.take(comp, dst)
        external = cs != cd
        key = jnp.where(external, state["pairkey"], _BIG)

        # FM: orientation-invariant min key per supervertex. Pull is a
        # private min-reduce over own incident edges; push offers the key
        # to both endpoints' shared slots — same value (the key is
        # orientation-invariant on a symmetric edge list), combining-min
        # writes instead of reads in the Cost.
        min_key = segment_min(key, cs, n)
        k_ext = jnp.sum(external.astype(counter_dtype()))
        cost = jax.lax.cond(
            jnp.asarray(do_push),
            lambda c: c.charge(reads=counter(m)).charge_combining_writes(
                k_ext, float_data=False),
            lambda c: c.charge(reads=counter(m), writes=counter(n)),
            cost)
        has_edge = min_key < _BIG

        # representative slot (src-side orientation always exists because
        # the edge list is symmetric): min slot among winners
        winner = key == jnp.take(min_key, cs)
        sel_slot = segment_min(jnp.where(winner, eid, _BIG), cs, n)
        sel_slot_c = jnp.where(has_edge, sel_slot, 0).astype(jnp.int32)
        # scatter only the selected slots: an edgeless supervertex must
        # not write False into slot 0, where it could race a real hit
        hit = jnp.zeros((m,), bool).at[
            jnp.where(has_edge, sel_slot, m).astype(jnp.int32)].set(
                True, mode="drop")

        # BMT: hook to the other side's component; mutual 2-cycles break
        # toward the lower root
        other = jnp.take(comp, jnp.take(dst, sel_slot_c))
        me = jnp.arange(n, dtype=jnp.int32)
        parent = jnp.where(has_edge, other, me)
        pp = jnp.take(parent, parent)
        parent = jnp.where((pp == me) & (me < parent), me, parent)

        state = dict(state, in_mst=state["in_mst"] | hit, parent=parent,
                     done=~jnp.any(has_edge))
        # supervertices that found an edge are the live frontier the
        # contraction (and any switching policy) sees
        return state, has_edge, jnp.bool_(True), cost

    def contract_local(g_, state, frontier, step, do_push, cost):
        # pointer jumping: depth halves per step -> ceil(log2 n)+1 bounds
        # convergence; fori (not while) so malformed hooks can never hang
        n_jumps = max(1, math.ceil(math.log2(max(2, n))) + 1)
        parent = jax.lax.fori_loop(
            0, n_jumps, lambda _, p: jnp.take(p, p), state["parent"])
        comp = jnp.take(parent, state["comp"])
        cost = cost.charge(writes=counter(n))
        return dict(state, comp=comp, parent=parent), frontier, \
            jnp.bool_(True), cost

    def epoch_cond(g_, state, epoch):
        return ~state["done"]

    pp = PhaseProgram(
        phases=(Phase(program=VertexProgram(local_fn=fm_local),
                      max_steps=1, name="find_min", enter_fn=fm_enter),
                # contract inherits the find-min frontier: a done round
                # leaves it empty and the contraction is skipped
                Phase(program=VertexProgram(local_fn=contract_local),
                      max_steps=1, name="contract")),
        epoch_cond=epoch_cond)
    return pp, 64


def mst_init(g: Graph, **_):
    n, m = g.n, g.m
    src, dst, w = g.coo_src, g.coo_dst, g.coo_w
    # orientation-invariant undirected pair rank in [0, m)
    lo = jnp.minimum(src, dst).astype(jnp.int64)
    hi = jnp.maximum(src, dst).astype(jnp.int64)
    pair = lo * (n + 1) + hi
    _, pair_rank = jnp.unique(pair, return_inverse=True, size=m)
    # weights are positive floats: int32 bit pattern preserves order
    wbits = jax.lax.bitcast_convert_type(w, jnp.int32).astype(jnp.int64)
    pairkey = wbits * (m + 1) + pair_rank
    state0 = {
        "comp": jnp.arange(n, dtype=jnp.int32),
        "parent": jnp.arange(n, dtype=jnp.int32),
        "in_mst": jnp.zeros((m,), bool),
        "done": jnp.bool_(False),
        "pair": pair,
        "pairkey": pairkey,
    }
    return state0, jnp.ones((n,), bool)


def mst_finalize(g: Graph, state):
    n, m = g.n, g.m
    pair, in_mst, comp = state["pair"], state["in_mst"], state["comp"]
    # total weight with undirected dedup (both orientations may be marked)
    order = jnp.argsort(pair)
    pair_s = pair[order]
    sel_s = in_mst[order]
    w_s = g.coo_w[order]
    first = jnp.concatenate([jnp.array([True]), pair_s[1:] != pair_s[:-1]])
    grp = jnp.cumsum(first.astype(jnp.int32)) - 1
    any_sel = jax.ops.segment_max(sel_s.astype(jnp.int32), grp,
                                  num_segments=m) > 0
    pair_w = jax.ops.segment_max(w_s, grp, num_segments=m)
    weight = jnp.sum(jnp.where(any_sel, pair_w, 0.0))

    roots = jax.ops.segment_max(jnp.ones((n,), jnp.int32), comp,
                                num_segments=n) > 0
    return {"in_mst": in_mst, "weight": weight,
            "components": jnp.sum(roots.astype(jnp.int32))}


def boruvka_mst(g: Graph, direction: str = "pull", max_rounds: int = 64
                ) -> MSTResult:
    """Legacy entry point — now a thin wrapper over ``repro.api.solve``."""
    from ... import api
    policy = Fixed(Direction.PUSH if direction == "push"
                   else Direction.PULL)
    r = api.solve(g, "mst_boruvka", policy=policy, max_steps=max_rounds)
    return MSTResult(in_mst=r.state["in_mst"], weight=r.state["weight"],
                     components=r.state["components"], cost=r.cost,
                     rounds=r.epochs)
