"""Weakly Connected Components — min-label propagation as the canonical
PushPullEngine instance.

push: changed vertices push their label to neighbors (combining-min; the
      frontier shrinks as labels settle — Frontier-Exploit for free);
pull: every vertex re-reduces over in-neighbors (no combining writes).
GenericSwitch direction-optimizes like BFS. Registered with ``repro.api``
as ``"wcc"``; :func:`wcc` is the thin legacy wrapper.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ..cost_model import Cost
from ..direction import Direction, DirectionPolicy, Fixed
from ..engine import VertexProgram

__all__ = ["wcc", "WCCResult", "wcc_program", "wcc_init"]


class WCCResult(NamedTuple):
    labels: jax.Array       # int32[n] min vertex id of the component
    num_components: jax.Array
    cost: Cost
    steps: jax.Array


def wcc_program(g: Graph, max_steps: int = 10_000, policy=None,
                backend=None) -> tuple[VertexProgram, int]:
    def update(state, msgs, step):
        new = jnp.minimum(state, msgs)
        frontier = new < state
        return new, frontier, ~jnp.any(frontier)

    return VertexProgram(combine="min", update_fn=update), max_steps


def wcc_init(g: Graph, **_):
    return jnp.arange(g.n, dtype=jnp.int32), jnp.ones((g.n,), bool)


def wcc(g: Graph, policy: DirectionPolicy = Fixed(Direction.PULL),
        max_steps: int = 10_000) -> WCCResult:
    """Legacy entry point — now a thin wrapper over ``repro.api.solve``."""
    from ... import api
    r = api.solve(g, "wcc", policy=policy, max_steps=max_steps)
    roots = r.state == jnp.arange(g.n, dtype=jnp.int32)
    return WCCResult(labels=r.state,
                     num_components=jnp.sum(roots.astype(jnp.int32)),
                     cost=r.cost, steps=r.steps)
