"""Weakly Connected Components — a PushPullEngine instance (min-label
propagation), showing the engine carries whole algorithms.

push: changed vertices push their label to neighbors (combining-min; the
      frontier shrinks as labels settle — Frontier-Exploit for free);
pull: every vertex re-reduces over in-neighbors (no combining writes).
GenericSwitch direction-optimizes like BFS.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ..cost_model import Cost
from ..direction import Direction, DirectionPolicy, Fixed
from ..engine import PushPullEngine, VertexProgram

__all__ = ["wcc", "WCCResult"]


class WCCResult(NamedTuple):
    labels: jax.Array       # int32[n] min vertex id of the component
    num_components: jax.Array
    cost: Cost
    steps: jax.Array


@partial(jax.jit, static_argnames=("policy", "max_steps"))
def wcc(g: Graph, policy: DirectionPolicy = Fixed(Direction.PULL),
        max_steps: int = 10_000) -> WCCResult:
    def update(state, msgs, step):
        new = jnp.minimum(state, msgs)
        frontier = new < state
        return new, frontier, ~jnp.any(frontier)

    prog = VertexProgram(combine="min", update_fn=update)
    eng = PushPullEngine(program=prog, policy=policy, max_steps=max_steps)
    init = jnp.arange(g.n, dtype=jnp.int32)
    res = eng.run(g, init, jnp.ones((g.n,), bool))
    roots = res.state == jnp.arange(g.n, dtype=jnp.int32)
    return WCCResult(labels=res.state,
                     num_components=jnp.sum(roots.astype(jnp.int32)),
                     cost=res.cost, steps=res.steps)
