"""Betweenness Centrality (Brandes) — paper §3.5 / §4.5 / Algorithm 5.

Two phases per source s:
  1. forward: BFS computing level(v) and σ(v) = #shortest s-v paths. This
     is the paper's *generalized BFS with an accumulation operator* (⊕=+):
     push scatters σ into the next level (float combining writes => locks),
     pull gathers σ from predecessor-level in-neighbors (reads only).
  2. backward: dependency accumulation
        δ(v) = Σ_{w: v ∈ pred(w)} σ(v)/σ(w) · (1 + δ(w))
     push sends partial centralities to predecessors; pull uses Madduri's
     successor trick [39] — each v pulls from its successors, turning
     float locks into plain reads (the paper's key BC observation).

The two phases are literally a forward/backward :class:`~repro.core
.engine.Phase` pair inside one :class:`~repro.core.engine.PhaseProgram`:
the forward phase records its trace (level, σ) in the carry, the backward
phase's ``enter_fn`` seeds the deepest level from that trace, and the
engine's epoch loop walks the sources. bc(v) = Σ_{s≠v} δ_s(v); exact when
the epoch count covers V, else the standard sampled approximation (Bader
et al.). Registered with ``repro.api`` as ``"betweenness"``;
:func:`betweenness_centrality` is the thin legacy wrapper.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ..backend import DenseBackend, EllBackend, require_backend
from ..cost_model import Cost
from ..direction import Direction, Fixed
from ..engine import Phase, PhaseProgram, VertexProgram

__all__ = ["betweenness_centrality", "BCResult", "betweenness_program",
           "betweenness_init", "betweenness_finalize"]

_UNREACHED = jnp.int32(2147483647)


class BCResult(NamedTuple):
    bc: jax.Array     # float32[n]
    cost: Cost
    max_level: jax.Array


def betweenness_program(g: Graph, num_sources: int = 8,
                        source_offset: int = 0, policy=None, backend=None
                        ) -> tuple[PhaseProgram, int]:
    """Brandes BC as a forward/backward phase pair, one source per epoch.

    Graph must be symmetric (undirected), mirroring the paper's SM
    experiments (N_in = N_out, so push on the same edge list is the
    reverse-edge scatter)."""
    require_backend("betweenness", backend, DenseBackend, EllBackend)
    n = g.n

    # -- phase 1: forward BFS accumulating σ ------------------------------
    def fwd_enter(g_, state, frontier, epoch):
        s = ((epoch + jnp.int32(source_offset)) % n).astype(jnp.int32)
        ids = jnp.arange(n, dtype=jnp.int32)
        state = dict(state)
        state["src"] = s
        state["level"] = jnp.where(ids == s, 0, _UNREACHED)
        state["sigma"] = jnp.where(ids == s, 1.0, 0.0).astype(jnp.float32)
        state["visited"] = ids == s
        state["delta"] = jnp.zeros((n,), jnp.float32)
        state["lvl"] = jnp.int32(0)
        return state, ids == s

    def fwd_values(g_, state, frontier):
        return jnp.where(frontier, state["sigma"], 0.0)

    def fwd_update(state, msgs, step):
        visited = state["visited"]
        nxt = (~visited) & (msgs > 0)
        state = dict(state)
        state["sigma"] = jnp.where(nxt, msgs, state["sigma"])
        state["level"] = jnp.where(nxt, (step + 1).astype(jnp.int32),
                                   state["level"])
        state["visited"] = visited | nxt
        return state, nxt, ~jnp.any(nxt)

    forward = VertexProgram(combine="sum", update_fn=fwd_update,
                            values_fn=fwd_values, pull_touched="unvisited")

    # -- phase 2: backward dependency accumulation, deepest level first ---
    def bwd_enter(g_, state, frontier, epoch):
        level = state["level"]
        max_level = jnp.max(jnp.where(level == _UNREACHED, 0, level))
        state = dict(state)
        state["lvl"] = max_level
        state["ml"] = jnp.maximum(state["ml"], max_level)
        return state, (level == max_level) & (max_level > 0)

    def bwd_values(g_, state, frontier):
        # contribution of each vertex w at the current level to its
        # predecessors: (1 + δ(w)) / σ(w)  (the σ(v) factor lands at v)
        safe_sigma = jnp.maximum(state["sigma"], 1e-30)
        return jnp.where(frontier, (1.0 + state["delta"]) / safe_sigma,
                         0.0)

    def bwd_touched(g_, state, frontier, visited):
        # Madduri successor trick: predecessors pull from successors
        return state["level"] == state["lvl"] - 1

    def bwd_update(state, msgs, step):
        lvl = state["lvl"]
        v_mask = state["level"] == lvl - 1
        state = dict(state)
        state["delta"] = state["delta"] + jnp.where(
            v_mask, state["sigma"] * msgs, 0.0)
        new_lvl = lvl - 1
        state["lvl"] = new_lvl
        frontier = (state["level"] == new_lvl) & (new_lvl >= 1)
        return state, frontier, ~jnp.any(frontier)

    backward = VertexProgram(combine="sum", update_fn=bwd_update,
                             values_fn=bwd_values, touched_fn=bwd_touched)

    # -- per-source epilogue: fold δ_s into bc ----------------------------
    def epoch_exit(g_, state, frontier, epoch):
        ids = jnp.arange(n, dtype=jnp.int32)
        contrib = jnp.where(ids == state["src"], 0.0, state["delta"])
        contrib = jnp.where(state["level"] == _UNREACHED, 0.0, contrib)
        state = dict(state)
        state["bc"] = state["bc"] + contrib
        return state, frontier

    pp = PhaseProgram(
        phases=(Phase(program=forward, max_steps=n + 1, name="forward",
                      enter_fn=fwd_enter),
                Phase(program=backward, max_steps=n + 1, name="backward",
                      enter_fn=bwd_enter)),
        epoch_exit_fn=epoch_exit)
    return pp, num_sources


def betweenness_init(g: Graph, **_):
    n = g.n
    state0 = {
        "bc": jnp.zeros((n,), jnp.float32),
        "ml": jnp.int32(0),
        "src": jnp.int32(0),
        "lvl": jnp.int32(0),
        "level": jnp.full((n,), _UNREACHED, jnp.int32),
        "sigma": jnp.zeros((n,), jnp.float32),
        "visited": jnp.zeros((n,), bool),
        "delta": jnp.zeros((n,), jnp.float32),
    }
    return state0, jnp.zeros((n,), bool)


def betweenness_finalize(g: Graph, state):
    return {"bc": state["bc"], "max_level": state["ml"]}


def betweenness_centrality(g: Graph, direction: str = "pull",
                           num_sources: int = 8,
                           source_offset: int = 0) -> BCResult:
    """Legacy entry point — now a thin wrapper over ``repro.api.solve``."""
    from ... import api
    policy = Fixed(Direction.PUSH if direction == "push"
                   else Direction.PULL)
    r = api.solve(g, "betweenness", policy=policy,
                  num_sources=num_sources, source_offset=source_offset)
    return BCResult(bc=r.state["bc"], cost=r.cost,
                    max_level=r.state["max_level"])
