"""Betweenness Centrality (Brandes) — paper §3.5 / §4.5 / Algorithm 5.

Two phases per source s:
  1. forward: BFS computing level(v) and σ(v) = #shortest s-v paths. This
     is the paper's *generalized BFS with an accumulation operator* (⊕=+):
     push scatters σ into the next level (float combining writes => locks),
     pull gathers σ from predecessor-level in-neighbors (reads only).
  2. backward: dependency accumulation
        δ(v) = Σ_{w: v ∈ pred(w)} σ(v)/σ(w) · (1 + δ(w))
     push sends partial centralities to predecessors; pull uses Madduri's
     successor trick [39] — each v pulls from its successors, turning
     float locks into plain reads (the paper's key BC observation).

bc(v) = Σ_{s≠v} δ_s(v); exact when `sources` covers V, else the standard
sampled approximation (Bader et al.).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ..cost_model import Cost
from ..primitives import pull_relax, push_relax

__all__ = ["betweenness_centrality", "BCResult"]

_UNREACHED = jnp.int32(2147483647)


class BCResult(NamedTuple):
    bc: jax.Array     # float32[n]
    cost: Cost
    max_level: jax.Array


def _forward(g: Graph, source, direction: str, cost: Cost):
    """Level + sigma computation (one source)."""
    n = g.n
    level = jnp.full((n,), _UNREACHED, jnp.int32).at[source].set(0)
    sigma = jnp.zeros((n,), jnp.float32).at[source].set(1.0)
    frontier = jnp.zeros((n,), bool).at[source].set(True)
    visited = frontier

    def cond(st):
        return jnp.any(st[2])

    def body(st):
        level_a, sigma_a, frontier_a, visited_a, lvl, cost_a = st
        if direction == "push":
            acc, cost_a = push_relax(
                g, jnp.where(frontier_a, sigma_a, 0.0), frontier_a,
                combine="sum", cost=cost_a)
        else:
            acc, cost_a = pull_relax(
                g, jnp.where(frontier_a, sigma_a, 0.0), touched=~visited_a,
                combine="sum", cost=cost_a)
        nxt = (~visited_a) & (acc > 0)
        sigma_a = jnp.where(nxt, acc, sigma_a)
        level_a = jnp.where(nxt, lvl + 1, level_a)
        visited_a = visited_a | nxt
        cost_a = cost_a.charge(iterations=1, barriers=1)
        return level_a, sigma_a, nxt, visited_a, lvl + 1, cost_a

    level, sigma, _, _, lvl, cost = jax.lax.while_loop(
        cond, body, (level, sigma, frontier, visited, jnp.int32(0), cost))
    return level, sigma, lvl, cost


def _backward(g: Graph, level, sigma, max_level, direction: str, cost: Cost):
    """Dependency accumulation, deepest level first."""
    n = g.n
    delta = jnp.zeros((n,), jnp.float32)
    safe_sigma = jnp.maximum(sigma, 1e-30)

    def cond(st):
        return st[1] > 0

    def body(st):
        delta_a, lvl, cost_a = st
        # contribution of each vertex w at level `lvl` to predecessors:
        #   (σ(v)/σ(w)) (1 + δ(w)) for edge (v,w), level(v) = lvl-1
        w_mask = level == lvl
        payload = jnp.where(w_mask, (1.0 + delta_a) / safe_sigma, 0.0)
        if direction == "push":
            # w pushes payload to in-neighbors v (scatter on reverse edges:
            # use pull-major edges w=dst -> v=src flipped via push_relax on
            # the reversed orientation; graph is symmetric so N_in = N_out)
            acc, cost_a = push_relax(g, payload, w_mask, combine="sum",
                                     cost=cost_a)
        else:
            # Madduri successor trick: each v pulls from successors w
            v_mask = level == (lvl - 1)
            acc, cost_a = pull_relax(g, payload, touched=v_mask,
                                     combine="sum", cost=cost_a)
        v_mask = level == (lvl - 1)
        delta_a = delta_a + jnp.where(v_mask, sigma * acc, 0.0)
        cost_a = cost_a.charge(iterations=1, barriers=1)
        return delta_a, lvl - 1, cost_a

    delta, _, cost = jax.lax.while_loop(cond, body, (delta, max_level, cost))
    return delta, cost


@partial(jax.jit, static_argnames=("direction", "num_sources"))
def betweenness_centrality(g: Graph, direction: str = "pull",
                           num_sources: int = 8,
                           source_offset: int = 0) -> BCResult:
    """Brandes BC over `num_sources` sources (ids offset..offset+k-1
    modulo n). Graph must be symmetric (undirected), mirroring the paper's
    SM experiments."""
    n = g.n
    sources = (jnp.arange(num_sources, dtype=jnp.int32) + source_offset) % n

    def per_source(carry, s):
        bc, cost, ml = carry
        level, sigma, max_level, cost = _forward(g, s, direction, cost)
        delta, cost = _backward(g, level, sigma, max_level, direction, cost)
        contrib = jnp.where(jnp.arange(n) == s, 0.0, delta)
        contrib = jnp.where(level == _UNREACHED, 0.0, contrib)
        return (bc + contrib, cost, jnp.maximum(ml, max_level)), None

    (bc, cost, ml), _ = jax.lax.scan(
        per_source, (jnp.zeros((n,), jnp.float32), Cost(), jnp.int32(0)),
        sources)
    return BCResult(bc=bc, cost=cost, max_level=ml)
