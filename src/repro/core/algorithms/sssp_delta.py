"""Δ-Stepping SSSP — paper §3.4 / §4.4 / Algorithm 4.

Vertices are grouped into distance buckets of width Δ; epoch b settles all
vertices with tentative distance in [bΔ, (b+1)Δ) by repeated relaxation.

push: active bucket vertices relax their out-edges — CAS-combining float
      writes (locks in the paper's Table 1: O(m·l_Δ));
pull: every unsettled vertex scans in-edges for sources in the current
      bucket and relaxes privately — O((L/Δ)·m·l_Δ) reads, no locks.

The algorithm is now a phase-structured :class:`~repro.core.engine
.PhaseProgram`: the engine's *epoch* loop is Algorithm 4's bucket loop,
one relaxation :class:`~repro.core.engine.Phase` per epoch is its inner
iteration, and the phase's ``enter_fn`` computes the paper's ``active[]``
array (the current-bucket frontier) from the distance carry. Registered
with ``repro.api`` as ``"sssp_delta"``; :func:`sssp_delta` is the thin
legacy wrapper.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ...shard.backend import ShardedBackend
from ..backend import DenseBackend, EllBackend, require_backend
from ..cost_model import Cost
from ..direction import Direction, Fixed
from ..engine import Phase, PhaseProgram, VertexProgram

__all__ = ["sssp_delta", "SSSPResult", "sssp_delta_program",
           "sssp_delta_init", "sssp_delta_finalize"]

_INF = jnp.float32(jnp.inf)


class SSSPResult(NamedTuple):
    dist: jax.Array      # float32[n]
    cost: Cost
    epochs: jax.Array    # int32 buckets processed
    inner_iters: jax.Array


def _in_bucket(d: jax.Array, lo, delta: float) -> jax.Array:
    return jnp.isfinite(d) & (d >= lo) & (d < lo + jnp.float32(delta))


def sssp_delta_program(g: Graph, delta: float = 2.0, max_inner: int = 64,
                       max_epochs: int = 1 << 14, policy=None, backend=None
                       ) -> tuple[PhaseProgram, int]:
    """Δ-stepping as a phase program (bucket epochs × inner relaxations).

    Wire values are tentative distances of current-bucket-active sources
    (∞ elsewhere); combine=min with msg ⊗ = d+w is the relaxation. Pull
    only touches the unsettled set (d ≥ bΔ), exactly the paper's scan.
    """
    require_backend("sssp_delta", backend, DenseBackend, EllBackend,
                    ShardedBackend)
    delta = float(delta)

    def enter(g_, state, frontier, epoch):
        lo = epoch.astype(jnp.float32) * jnp.float32(delta)
        state = {"dist": state["dist"], "lo": lo}
        return state, _in_bucket(state["dist"], lo, delta)

    def values_fn(g_, state, frontier):
        return jnp.where(frontier, state["dist"], _INF)

    def touched_fn(g_, state, frontier, visited):
        return state["dist"] >= state["lo"]      # unsettled: bucket+beyond

    def update(state, msgs, step):
        d = state["dist"]
        d_new = jnp.minimum(d, msgs)
        changed = d_new < d
        frontier = _in_bucket(d_new, state["lo"], delta)
        return ({"dist": d_new, "lo": state["lo"]}, frontier,
                ~jnp.any(changed))

    def epoch_cond(g_, state, epoch):
        # any unsettled vertex left at or beyond this bucket?
        d = state["dist"]
        lo = epoch.astype(jnp.float32) * jnp.float32(delta)
        return jnp.any(jnp.isfinite(d) & (d >= lo))

    prog = VertexProgram(combine="min", msg_fn=lambda x, w: x + w,
                         update_fn=update, values_fn=values_fn,
                         touched_fn=touched_fn,
                         # push compacts the vertices whose distance
                         # actually improved, not the whole re-activated
                         # bucket (paper: pull scans everything anyway)
                         k_filter_push=True,
                         k_filter_set_fn=lambda old, new, f:
                             new["dist"] < old["dist"])
    pp = PhaseProgram(phases=(Phase(program=prog, max_steps=max_inner,
                                    name="relax", enter_fn=enter),),
                      epoch_cond=epoch_cond)
    return pp, max_epochs


def sssp_delta_init(g: Graph, source=0, **_):
    source = jnp.asarray(source, jnp.int32)
    d0 = jnp.full((g.n,), _INF, jnp.float32).at[source].set(0.0)
    state0 = {"dist": d0, "lo": jnp.float32(0.0)}
    # the phase's enter_fn recomputes the bucket frontier every epoch
    return state0, jnp.zeros((g.n,), bool)


def sssp_delta_finalize(g: Graph, state):
    return {"dist": state["dist"]}


def sssp_delta(g: Graph, source: int | jax.Array, delta: float = 2.0,
               direction: str = "push", max_epochs: int = 1 << 14,
               max_inner: int = 64) -> SSSPResult:
    """Legacy entry point — now a thin wrapper over ``repro.api.solve``."""
    from ... import api
    policy = Fixed(Direction.PUSH if direction == "push"
                   else Direction.PULL)
    r = api.solve(g, "sssp_delta", policy=policy, source=source,
                  delta=delta, max_inner=max_inner, max_steps=max_epochs)
    return SSSPResult(dist=r.state["dist"], cost=r.cost, epochs=r.epochs,
                      inner_iters=r.steps)
