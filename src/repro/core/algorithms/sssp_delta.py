"""Δ-Stepping SSSP — paper §3.4 / §4.4 / Algorithm 4.

Vertices are grouped into distance buckets of width Δ; epoch b settles all
vertices with tentative distance in [bΔ, (b+1)Δ) by repeated relaxation.

push: active bucket vertices relax their out-edges — CAS-combining float
      writes (locks in the paper's Table 1: O(m·l_Δ));
pull: every unsettled vertex scans in-edges for sources in the current
      bucket and relaxes privately — O((L/Δ)·m·l_Δ) reads, no locks.

The dual while_loop mirrors Algorithm 4's epoch/inner-iteration structure;
`active` marks vertices (re)inserted into the current bucket, exactly the
paper's `active[]` array.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ..cost_model import Cost
from ..primitives import (frontier_in_edges, k_filter, pull_relax,
                          push_relax)

__all__ = ["sssp_delta", "SSSPResult"]

_INF = jnp.float32(jnp.inf)


class SSSPResult(NamedTuple):
    dist: jax.Array      # float32[n]
    cost: Cost
    epochs: jax.Array    # int32 buckets processed
    inner_iters: jax.Array


def _relax_push(g, d, in_bucket_active, cost):
    """Relax out-edges of active current-bucket vertices (scatter-min)."""
    cand, cost = push_relax(
        g, d, in_bucket_active, combine="min",
        msg_fn=lambda x, w: x + w, cost=cost)
    _, cost = k_filter(cand < d, cost)
    return jnp.minimum(d, cand), cost


def _relax_pull(g, d, in_bucket_active, bucket_lo, cost):
    """Unsettled vertices pull from current-bucket in-neighbors."""
    unsettled = d >= bucket_lo  # includes current bucket + beyond
    src_val = jnp.where(in_bucket_active, d, _INF)
    cand, cost = pull_relax(
        g, src_val, touched=unsettled, combine="min",
        msg_fn=lambda x, w: x + w, cost=cost)
    return jnp.minimum(d, cand), cost


@partial(jax.jit, static_argnames=("direction", "max_epochs", "max_inner"))
def sssp_delta(g: Graph, source: int | jax.Array, delta: float = 2.0,
               direction: str = "push", max_epochs: int = 1 << 14,
               max_inner: int = 64) -> SSSPResult:
    n = g.n
    source = jnp.asarray(source, jnp.int32)
    d0 = jnp.full((n,), _INF, jnp.float32).at[source].set(0.0)
    delta = jnp.float32(delta)

    def epoch_cond(state):
        d, b, cost, inner = state
        # any unsettled vertex left? (finite distance >= bΔ or untouched
        # vertices reachable later — we stop when no finite d >= bΔ and no
        # vertex entered bucket b)
        has_work = jnp.any(jnp.isfinite(d) & (d >= b * delta))
        return (b < max_epochs) & has_work

    def epoch_body(state):
        d, b, cost, inner_total = state
        lo, hi = b * delta, (b + 1) * delta

        def inner_cond(s):
            d_cur, d_prev, it, _ = s
            changed = jnp.any(d_cur < d_prev)
            return (it < max_inner) & ((it == 0) | changed)

        def inner_body(s):
            d_cur, _, it, cost_in = s
            in_bucket = jnp.isfinite(d_cur) & (d_cur >= lo) & (d_cur < hi)
            if direction == "push":
                d_new, cost_in = _relax_push(g, d_cur, in_bucket, cost_in)
            else:
                d_new, cost_in = _relax_pull(g, d_cur, in_bucket, lo, cost_in)
            cost_in = cost_in.charge(barriers=1)
            return d_new, d_cur, it + 1, cost_in

        d_fin, _, iters, cost = jax.lax.while_loop(
            inner_cond, inner_body, (d, d + 0.0, jnp.int32(0), cost))
        cost = cost.charge(iterations=1)
        return d_fin, b + 1, cost, inner_total + iters

    d, epochs, cost, inner = jax.lax.while_loop(
        epoch_cond, epoch_body, (d0, jnp.int32(0), Cost(), jnp.int32(0)))
    return SSSPResult(dist=d, cost=cost, epochs=epochs, inner_iters=inner)
