"""Triangle Counting — paper §3.2 / §4.2 / Algorithm 2 (NodeIterator).

For every edge (v,u) intersect N(v) ∩ N(u). Each triangle {v,u,w} is seen
6 times over directed edge enumerations, so Σ/ per-vertex counts divide
accordingly:

  pull: t[v] accumulates |N(v) ∩ N(u)| into tc(v) — private accumulation
        (0 atomics; O(m·d̂) reads);
  push: the intersection size is credited to the *other* endpoints (u / w)
        — combining integer writes (FAA; O(m·d̂) atomics, Table 1).

The algorithm is the engine's *one-shot edge map*: a single
:class:`~repro.core.engine.VertexProgram` whose ``local_fn`` processes
one edge block per engine step, with no fixed point — the step bound IS
the block count. Per-vertex counts end up *identical* across directions
(the edge list is symmetric, so crediting src vs dst sums the same
multiset); only the Cost differs per Table 1. Registered with
``repro.api`` as ``"triangle_count"``; :func:`triangle_count` is the
thin legacy wrapper.

Implementation: ELL rows give rectangular [d_ell] neighbor lists; the
intersection is an all-pairs compare of two gathered rows (O(m·d_ell²)
dense work — TPU-friendly, MXU-independent).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ...sparse.segment import segment_sum
from ..backend import DenseBackend, EllBackend, require_backend
from ..cost_model import Cost, counter, counter_dtype
from ..direction import Direction, Fixed
from ..engine import VertexProgram

__all__ = ["triangle_count", "TriangleCountResult", "triangle_program",
           "triangle_init", "triangle_finalize"]


class TriangleCountResult(NamedTuple):
    per_vertex: jax.Array   # int32[n] triangles through each vertex
    total: jax.Array        # int64 total triangle count
    cost: Cost


def triangle_program(g: Graph, edge_block: int = 4096, policy=None,
                     backend=None) -> tuple[VertexProgram, int]:
    """NodeIterator TC as a one-shot blocked edge map (no fixed point)."""
    require_backend("triangle_count", backend, DenseBackend, EllBackend)
    n, d_ell = g.n, g.d_ell
    num_blocks = -(-g.m // edge_block)
    m_pad = num_blocks * edge_block

    def local_fn(g_, state, frontier, step, do_push, cost):
        # the padded edge list lives in the carry (built once in init),
        # keeping the loop body free of O(m) loop-invariant rebuilds
        s = jax.lax.dynamic_slice(state["src"], (step * edge_block,),
                                  (edge_block,))
        d = jax.lax.dynamic_slice(state["dst"], (step * edge_block,),
                                  (edge_block,))
        nv = g_.ell_idx[jnp.minimum(s, n - 1)]       # [B, d_ell]
        nu = g_.ell_idx[jnp.minimum(d, n - 1)]       # [B, d_ell]
        # all-pairs equality; ELL's own sentinel (=n) never matches a
        # real id, and pad edges (s or d == n) are zeroed below
        eq = (nv[:, :, None] == nu[:, None, :]) & (nv[:, :, None] < n)
        common = eq.sum(axis=(1, 2)).astype(jnp.int32)     # |N(v) ∩ N(u)|
        common = jnp.where((s < n) & (d < n), common, 0)
        # accumulate into the iterating endpoint; the symmetric edge list
        # makes crediting src (push) and dst (pull) the same total — only
        # the access structure (FAA vs private write) differs
        new_state = dict(state, tc=state["tc"]
                         + segment_sum(common, jnp.minimum(d, n - 1), n))
        cost = jax.lax.cond(
            jnp.asarray(do_push),
            lambda c: c.charge(
                reads=2 * edge_block * d_ell).charge_combining_writes(
                    jnp.sum(common).astype(counter_dtype()),
                    float_data=False),
            lambda c: c.charge(reads=2 * edge_block * d_ell,
                               writes=edge_block),
            cost)
        return new_state, frontier, step + 1 >= num_blocks, cost

    return VertexProgram(local_fn=local_fn), num_blocks


def triangle_init(g: Graph, edge_block: int = 4096, **_):
    num_blocks = -(-g.m // edge_block)
    m_pad = num_blocks * edge_block
    state0 = {
        "tc": jnp.zeros((g.n,), jnp.int32),
        "src": jnp.pad(g.coo_src, (0, m_pad - g.m), constant_values=g.n),
        "dst": jnp.pad(g.coo_dst, (0, m_pad - g.m), constant_values=g.n),
    }
    return state0, jnp.ones((g.n,), bool)


def triangle_finalize(g: Graph, state):
    # each triangle at v is counted once per ordered pair of its two other
    # vertices adjacent to v => 2x per vertex
    per_vertex = state["tc"] // 2
    total = jnp.sum(per_vertex.astype(counter_dtype())) // 3
    return {"per_vertex": per_vertex, "total": total}


def triangle_count(g: Graph, direction: str = "pull",
                   edge_block: int = 4096) -> TriangleCountResult:
    """Legacy entry point — now a thin wrapper over ``repro.api.solve``."""
    from ... import api
    policy = Fixed(Direction.PUSH if direction == "push"
                   else Direction.PULL)
    r = api.solve(g, "triangle_count", policy=policy,
                  edge_block=edge_block)
    return TriangleCountResult(per_vertex=r.state["per_vertex"],
                               total=r.state["total"], cost=r.cost)
