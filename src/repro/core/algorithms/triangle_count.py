"""Triangle Counting — paper §3.2 / §4.2 / Algorithm 2 (NodeIterator).

For every edge (v,u) intersect N(v) ∩ N(u). Each triangle {v,u,w} is seen
6 times over directed edge enumerations, so Σ/ per-vertex counts divide
accordingly:

  pull: t[v] accumulates |N(v) ∩ N(u)| into tc(v) — private accumulation
        (0 atomics; O(m·d̂) reads);
  push: the intersection size is credited to the *other* endpoints (u / w)
        — combining integer writes (FAA; O(m·d̂) atomics, Table 1).

Implementation: ELL rows give rectangular [d_ell] neighbor lists; the
intersection is an all-pairs compare of two gathered rows (O(m·d_ell²)
dense work — TPU-friendly, MXU-independent). Per-vertex counts tc[v] end
up *identical* across directions; Cost differs per Table 1.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.structure import Graph
from ...sparse.segment import segment_sum
from ..cost_model import Cost

__all__ = ["triangle_count", "TriangleCountResult"]


class TriangleCountResult(NamedTuple):
    per_vertex: jax.Array   # int32[n] triangles through each vertex
    total: jax.Array        # int64 total triangle count
    cost: Cost


@partial(jax.jit, static_argnames=("direction", "edge_block"))
def triangle_count(g: Graph, direction: str = "pull",
                   edge_block: int = 4096) -> TriangleCountResult:
    """Count per-vertex and total triangles (undirected simple graph)."""
    n, d_ell = g.n, g.d_ell
    idx_pad = jnp.concatenate(
        [g.ell_idx, jnp.full((1, d_ell), n, jnp.int32)], axis=0)

    num_blocks = -(-g.m // edge_block)
    m_pad = num_blocks * edge_block
    src = jnp.pad(g.coo_src, (0, m_pad - g.m), constant_values=n)
    dst = jnp.pad(g.coo_dst, (0, m_pad - g.m), constant_values=n)

    def block_body(carry, blk):
        tc, cost = carry
        s = jax.lax.dynamic_slice(src, (blk * edge_block,), (edge_block,))
        d = jax.lax.dynamic_slice(dst, (blk * edge_block,), (edge_block,))
        nv = idx_pad[jnp.minimum(s, n)]              # [B, d_ell]
        nu = idx_pad[jnp.minimum(d, n)]              # [B, d_ell]
        # all-pairs equality, sentinel (=n) never matches a real id
        eq = (nv[:, :, None] == nu[:, None, :]) & (nv[:, :, None] < n)
        common = eq.sum(axis=(1, 2)).astype(jnp.int32)     # |N(v) ∩ N(u)|
        common = jnp.where((s < n) & (d < n), common, 0)
        if direction == "pull":
            # accumulate into the iterating vertex v=dst of pull-major edges
            tc = tc + segment_sum(common, jnp.minimum(d, n - 1), n)
            cost = cost.charge(
                reads=2 * edge_block * d_ell, writes=edge_block)
        else:
            # push: credit the two *other* endpoints (scatter, FAA)
            tc_u = segment_sum(common, jnp.minimum(s, n - 1), n)
            tc = tc + tc_u
            cost = cost.charge(reads=2 * edge_block * d_ell)
            cost = cost.charge_combining_writes(
                jnp.sum(common).astype(jnp.int64), float_data=False)
        return (tc, cost), None

    tc0 = jnp.zeros((n,), jnp.int32)
    (tc_raw, cost), _ = jax.lax.scan(
        block_body, (tc0, Cost()), jnp.arange(num_blocks))
    # each triangle at v is counted once per ordered pair of its two other
    # vertices adjacent to v => 2x per vertex
    per_vertex = tc_raw // 2
    total = jnp.sum(per_vertex.astype(jnp.int64)) // 3
    return TriangleCountResult(per_vertex=per_vertex, total=total, cost=cost)
