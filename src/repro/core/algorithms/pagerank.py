"""PageRank — paper §3.1 / §4.1 / Algorithm 1 + §5-PA (Algorithm 8).

r(v) = (1-f)/n + f * Σ_{w∈N(v)} r(w)/d(w)

push: every vertex scatters r(v)/d(v) into each neighbor's accumulator
      (float combining writes ⇒ O(Lm) locks, Table 1);
pull: every vertex gathers neighbors' r(w)/d(w) privately (0 atomics,
      O(Lm) reads).

Partition-Awareness (push+PA): adjacency split into local/remote halves;
phase 1 updates owned neighbors with plain writes, phase 2 pushes across
partitions (only those edges are charged as combining writes), separated
by a barrier — Algorithm 8 verbatim.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...graphs.partition import Partition, pa_split, partition_1d
from ...graphs.structure import Graph
from ...sparse.segment import segment_sum
from ..cost_model import Cost
from ..direction import Direction, Fixed
from ..engine import VertexProgram

__all__ = ["pagerank", "pagerank_pa", "PageRankResult",
           "pagerank_program", "pagerank_init"]


class PageRankResult(NamedTuple):
    ranks: jax.Array
    cost: Cost
    iterations: int


def _contrib(r: jax.Array, out_deg: jax.Array) -> jax.Array:
    return r / jnp.maximum(out_deg, 1).astype(r.dtype)


def pagerank_program(g: Graph, iters: int = 20, damp: float = 0.85,
                     policy=None, backend=None
                     ) -> tuple[VertexProgram, int]:
    """Power iteration as a vertex program: every vertex is active every
    step; wire values are rank/out-degree contributions."""
    n = g.n
    base = (1.0 - damp) / n

    def values_fn(g_, state, frontier):
        return _contrib(state, g_.out_deg)

    def update(state, msgs, step):
        return base + damp * msgs, jnp.ones((n,), bool), jnp.bool_(False)

    prog = VertexProgram(combine="sum", update_fn=update,
                         values_fn=values_fn,
                         # reading own rank + degree for the contribution
                         step_charges=(("reads", 2 * n),))
    return prog, iters


def pagerank_init(g: Graph, **_):
    n = g.n
    return (jnp.full((n,), 1.0 / n, jnp.float32), jnp.ones((n,), bool))


def pagerank(g: Graph, iters: int = 20, damp: float = 0.85,
             direction: str = "pull", use_ell: bool = False) -> PageRankResult:
    """Power iteration; `direction` in {'push','pull'}; `use_ell` selects
    the ELL (kernel-shaped) pull layout. Thin wrapper over
    ``repro.api.solve`` (policy = Fixed direction, backend = dense/ELL)."""
    from ... import api
    from ..backend import DenseBackend, EllBackend
    policy = Fixed(Direction.PUSH if direction == "push"
                   else Direction.PULL)
    backend = EllBackend() if use_ell else DenseBackend()
    r = api.solve(g, "pagerank", policy=policy, backend=backend,
                  iters=iters, damp=damp)
    return PageRankResult(ranks=r.state, cost=r.cost, iterations=iters)


def pagerank_pa_prepare(g: Graph, num_parts: int, iters: int = 20,
                        damp: float = 0.85):
    """Push-based PR with Partition-Awareness (Algorithm 8).

    Returns a zero-arg jitted runner: the host-side PA split (graph
    transformation, paid once per graph like the paper's representation
    change) is excluded from the per-iteration work.

    Phase 1 — each partition pushes along *local* edges (plain writes);
    barrier; phase 2 — pushes along *remote* edges only (combining
    writes). Atomized updates drop from 2m to cut(m), the paper's bound.
    """
    part = partition_1d(g.n, num_parts)
    local, remote, stats = pa_split(g, part)
    n = g.n
    cut_w = int(jnp.sum(remote.count))
    loc_w = int(jnp.sum(local.count))

    l_src = local.src.reshape(-1)
    l_dst = local.dst.reshape(-1)
    r_src = remote.src.reshape(-1)
    r_dst = remote.dst.reshape(-1)

    @jax.jit
    def run():
        base = (1.0 - damp) / n

        def body(carry, _):
            r, cost = carry
            x = jnp.pad(_contrib(r, g.out_deg), (0, 1))
            # phase 1: local edges — private writes, no conflicts
            acc_l = segment_sum(x[jnp.minimum(l_src, n)]
                                * (l_src < n), jnp.minimum(l_dst, n - 1), n)
            cost = cost.charge(reads=loc_w, writes=loc_w, barriers=1)
            # phase 2: remote edges — combining (float -> lock-equivalent)
            acc_r = segment_sum(x[jnp.minimum(r_src, n)]
                                * (r_src < n), jnp.minimum(r_dst, n - 1), n)
            cost = cost.charge(reads=cut_w).charge_combining_writes(
                cut_w, float_data=True)
            r_new = base + damp * (acc_l + acc_r)
            cost = cost.charge(reads=2 * n, iterations=1, barriers=1)
            return (r_new, cost), None

        r0v = jnp.full((n,), 1.0 / n, jnp.float32)
        (r, cost), _ = jax.lax.scan(body, (r0v, Cost()), None, length=iters)
        return r, cost

    return run, stats


def pagerank_pa(g: Graph, num_parts: int, iters: int = 20,
                damp: float = 0.85) -> PageRankResult:
    run, _ = pagerank_pa_prepare(g, num_parts, iters, damp)
    r, cost = run()
    return PageRankResult(ranks=r, cost=cost, iterations=iters)
