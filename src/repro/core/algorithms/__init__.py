from .pagerank import pagerank, pagerank_pa, PageRankResult
from .triangle_count import triangle_count, TriangleCountResult
from .bfs import bfs, BFSResult
from .sssp_delta import sssp_delta, SSSPResult
from .betweenness import betweenness_centrality, BCResult
from .coloring import (boman_coloring, fe_coloring, greedy_sequential,
                       conflict_removal_coloring, ColoringResult,
                       validate_coloring)
from .mst_boruvka import boruvka_mst, MSTResult
from .wcc import wcc, WCCResult
from .pr_delta import pagerank_delta, PRDeltaResult

__all__ = [
    "wcc", "WCCResult", "pagerank_delta", "PRDeltaResult",
    "pagerank", "pagerank_pa", "PageRankResult",
    "triangle_count", "TriangleCountResult",
    "bfs", "BFSResult",
    "sssp_delta", "SSSPResult",
    "betweenness_centrality", "BCResult",
    "boman_coloring", "fe_coloring", "greedy_sequential",
    "conflict_removal_coloring", "ColoringResult", "validate_coloring",
    "boruvka_mst", "MSTResult",
]
