from .pagerank import (pagerank, pagerank_pa, PageRankResult,
                       pagerank_program, pagerank_init)
from .triangle_count import (triangle_count, TriangleCountResult,
                             triangle_program, triangle_init,
                             triangle_finalize)
from .bfs import bfs, BFSResult, bfs_program, bfs_init
from .sssp_delta import (sssp_delta, SSSPResult, sssp_delta_program,
                         sssp_delta_init, sssp_delta_finalize)
from .betweenness import (betweenness_centrality, BCResult,
                          betweenness_program, betweenness_init,
                          betweenness_finalize)
from .coloring import (boman_coloring, fe_coloring, greedy_sequential,
                       conflict_removal_coloring, ColoringResult,
                       validate_coloring, coloring_program, coloring_init,
                       coloring_finalize)
from .mst_boruvka import (boruvka_mst, MSTResult, mst_program, mst_init,
                          mst_finalize)
from .ppr import (personalized_pagerank, PPRResult, ppr_program,
                  ppr_init, ppr_finalize)
from .wcc import wcc, WCCResult, wcc_program, wcc_init
from .pr_delta import (pagerank_delta, PRDeltaResult, pr_delta_program,
                       pr_delta_init, pr_delta_finalize)

__all__ = [
    "wcc", "WCCResult", "pagerank_delta", "PRDeltaResult",
    "pagerank", "pagerank_pa", "PageRankResult",
    "triangle_count", "TriangleCountResult",
    "bfs", "BFSResult",
    "sssp_delta", "SSSPResult",
    "betweenness_centrality", "BCResult",
    "boman_coloring", "fe_coloring", "greedy_sequential",
    "conflict_removal_coloring", "ColoringResult", "validate_coloring",
    "boruvka_mst", "MSTResult",
    "bfs_program", "bfs_init", "pagerank_program", "pagerank_init",
    "wcc_program", "wcc_init", "pr_delta_program", "pr_delta_init",
    "pr_delta_finalize", "sssp_delta_program", "sssp_delta_init",
    "sssp_delta_finalize", "betweenness_program", "betweenness_init",
    "betweenness_finalize", "coloring_program", "coloring_init",
    "coloring_finalize", "mst_program", "mst_init", "mst_finalize",
    "triangle_program", "triangle_init", "triangle_finalize",
    "personalized_pagerank", "PPRResult", "ppr_program", "ppr_init",
    "ppr_finalize",
]
