"""PRAM cost counters and the per-step cost *predictor* (paper §4,
Table 1; §5 switching strategies).

On CPU the paper counts reads, writes, atomics (combining writes to ints),
and locks (combining writes to floats, since CPUs lack float atomics).
On TPU those categories map to gather bytes, private writes, combining
scatter elements, and float combining-scatter elements respectively; the
*counts* are architecture-independent, so we track them exactly as the
paper defines them and validate Table 1's structure analytically.

Counters are jnp int64 scalars inside a registered-dataclass pytree so they
can ride through jit / while_loop carries.

Three layers live here:

  * :class:`Cost` — the accumulated counters (what actually happened);
    ``Cost.weighted_total`` collapses them to one comparable scalar.
  * :class:`CostPredictor` — the forward model: *predicted* weighted cost
    of a push vs a pull step from cheap frontier statistics (frontier
    size, out/in-degree sums of the active set, backend layout), before
    the step runs. ``AutoSwitch`` compares the two predictions each step.
  * :class:`StepTrace` — a fixed-capacity per-step record of what each
    step actually did (direction, frontier stats, counter deltas),
    carried through ``lax.while_loop`` and surfaced on ``RunResult``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Paper-scale counters (reads on orc-TC ≈ 3·10^12, Table 1) need 64-bit
# integers. All framework tensors use explicit dtypes, so flipping the
# default is safe and keeps the counter pytrees honest.
jax.config.update("jax_enable_x64", True)

__all__ = ["Cost", "zero_cost", "counter_dtype", "counter",
           "CostWeights", "DEFAULT_WEIGHTS", "CostPredictor", "StepStats",
           "StepTrace"]


def counter_dtype():
    """The widest integer dtype JAX will actually honor for counters.

    With ``jax_enable_x64`` off, ``astype(jnp.int64)`` silently produces
    int32 (the warning is routinely filtered); asking for the dtype
    through this helper keeps every counter cast honest instead of
    silently truncating paper-scale counts.
    """
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def counter(x) -> jax.Array:
    """Cast ``x`` to the live counter dtype (see :func:`counter_dtype`)."""
    return jnp.asarray(x, counter_dtype())


_I = lambda: jnp.zeros((), counter_dtype())  # noqa: E731


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Cost:
    """Operation counts, paper §2.4 categories.

    reads / writes: plain memory accesses to shared vertex state.
    atomics: combining writes to integer data (CPU: FAA/CAS; TPU:
        int scatter-add elements).
    locks: combining writes to float data (CPU: lock-guarded update; TPU:
        float scatter-add elements).
    messages / collective_bytes: DM-setting traffic (MP / RMA emulation).
    barriers: bulk-synchronous phase boundaries.
    iterations: outer-loop rounds (Table 6b).
    """
    reads: jax.Array = dataclasses.field(default_factory=_I)
    writes: jax.Array = dataclasses.field(default_factory=_I)
    atomics: jax.Array = dataclasses.field(default_factory=_I)
    locks: jax.Array = dataclasses.field(default_factory=_I)
    messages: jax.Array = dataclasses.field(default_factory=_I)
    collective_bytes: jax.Array = dataclasses.field(default_factory=_I)
    barriers: jax.Array = dataclasses.field(default_factory=_I)
    iterations: jax.Array = dataclasses.field(default_factory=_I)

    def __add__(self, other: "Cost") -> "Cost":
        return jax.tree.map(lambda a, b: a + b, self, other)

    def charge(self, **kw) -> "Cost":
        """Return a new Cost with the given fields incremented."""
        vals = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        for k, v in kw.items():
            vals[k] = vals[k] + counter(v)
        return Cost(**vals)

    def charge_combining_writes(self, count, float_data: bool) -> "Cost":
        """Push-side conflict resolution: ints -> atomics, floats -> locks
        (paper §4.1 'no CPUs offer atomics operating on such values')."""
        if float_data:
            return self.charge(locks=count, writes=count)
        return self.charge(atomics=count, writes=count)

    def as_dict(self) -> dict:
        return {f.name: int(getattr(self, f.name))
                for f in dataclasses.fields(self)}

    def weighted_total(self, weights: "CostWeights" = None) -> jax.Array:
        """Collapse the §4 memory counters to one comparable scalar.

        This is the repo's scalar "counter cost": the number AutoSwitch
        minimizes and the benchmark matrix reports per cell.

            >>> c = Cost().charge(reads=10).charge_combining_writes(
            ...     4, float_data=False)
            >>> int(c.weighted_total())    # 10r + 4w + 4 atomics * 2
            22
        """
        w = DEFAULT_WEIGHTS if weights is None else weights
        return (self.reads * w.read + self.writes * w.write
                + self.atomics * w.atomic + self.locks * w.lock
                + self.collective_bytes * w.collective_byte)


def zero_cost() -> Cost:
    return Cost()


@dataclasses.dataclass(frozen=True)
class CostWeights:
    """Relative price of the paper's §4 access categories.

    A plain read/write is the unit; an atomic (int combining write,
    CPU FAA/CAS) costs a few units; a lock (float combining write — 'no
    CPUs offer atomics operating on such values', §4.1) costs more. The
    defaults are deliberately coarse: AutoSwitch only needs the *ordering*
    of push vs pull per step, which is robust to the exact ratios.

    ``collective_byte`` prices one inter-device wire byte (the paper's
    §6 DM traffic) relative to a local access — zero on a single device,
    where the distinction vanishes. With a multi-shard backend the two
    directions put *different* byte counts on the wire (push sends the
    remote-update stream, optionally compressed; pull gathers the whole
    frontier row), so this weight is what lets ``AutoSwitch`` flip
    direction for distributed reasons alone.
    """
    read: float = 1.0
    write: float = 1.0
    atomic: float = 2.0
    lock: float = 4.0
    collective_byte: float = 0.5


DEFAULT_WEIGHTS = CostWeights()


class StepStats(NamedTuple):
    """Cheap pre-step statistics the engine computes for switching
    policies (one pass over degree arrays — no edge traversal).

    ``frontier_edges`` is the push work bound (Σ out-degree of the
    frontier); ``pull_edges``/``pull_vertices`` bound the pull side for
    the *program's actual* destination set under the *backend's actual*
    layout — the engine fills them from ``backend.predict_pull_scan``,
    so a full-scan layout reports all ``m`` edges while the
    frontier-aware kernel pull reports its restricted ``touched ×
    d_ell`` gather; ``pull_touched_edges`` is the layout-independent
    restriction (Σ in-degree of the touched destination set, ``m`` when
    every destination is touched) — what an ideal CSR pull would read,
    kept alongside the layout-priced ``pull_edges`` so traces show how
    much of the algorithmic restriction the backend's layout actually
    captures; ``unvisited_edges`` is Beamer's unexplored-edge count
    used by ``GenericSwitch``. ``float_data`` and ``k_filter_push`` are static
    (trace-time) facts about the step: whether push conflicts resolve as
    locks or atomics, and whether a push step pays the paper's k-filter.

    ``width`` is the number of per-vertex payload elements on the wire
    (static: the trailing dimension of the wire values, 1 for plain
    vectors). Batched multi-query runs (``repro.service``) put one
    column per query on the wire and drive the engine with the *union*
    of the per-query frontiers, so for them ``frontier_edges`` is a
    union-frontier degree sum and every payload count scales by
    ``width`` — the batch-aware pricing the service layer's AutoSwitch
    decisions rest on.

    ``push_wire_bytes`` / ``pull_wire_bytes`` are the *inter-device*
    bytes a push or pull step of this backend would move (0 on
    single-device backends). The engine fills them from
    ``backend.predict_comm_bytes`` so distributed comm asymmetry —
    compressed push updates vs full-row pull gathers — reaches the
    predictor; they are priced by ``CostWeights.collective_byte``.
    """
    frontier_vertices: jax.Array
    frontier_edges: jax.Array
    pull_edges: jax.Array
    pull_vertices: jax.Array
    unvisited_edges: jax.Array
    step: jax.Array
    prev_push: jax.Array
    float_data: bool = False
    k_filter_push: bool = False
    width: int = 1
    push_wire_bytes: jax.Array | int = 0
    pull_wire_bytes: jax.Array | int = 0
    pull_touched_edges: jax.Array | int = 0


@dataclasses.dataclass(frozen=True)
class CostPredictor:
    """Forward model of one k-relaxation step — §4's cost derivations
    turned into a predictor.

    Predicts the :meth:`Cost.weighted_total` a push or pull step will
    charge, from :class:`StepStats` alone:

      push: k·width reads + k·width combining writes over the
            frontier's k incident out-edges (atomics for int payloads,
            locks for float), plus the k-filter compaction when the
            program declares one;
      pull: width reads per edge the backend's layout will actually
            scan (``StepStats.pull_edges``, via
            ``backend.predict_pull_scan`` — all m for a dense
            destination set or a full-scan layout, the restricted
            ``touched × d_ell`` gather for the frontier-aware kernel
            pull) plus width private writes per written destination.

    Both formulas add the backend's predicted inter-device wire bytes
    (``StepStats.push_wire_bytes`` / ``pull_wire_bytes``, priced by
    ``CostWeights.collective_byte``) — zero on single-device backends,
    and the §6 DM asymmetry (compressed push updates vs full-row pull
    gathers) on sharded ones.

    The engine charges the *same* formulas after the step runs, so the
    prediction is exact for exchange steps — which is what lets tests
    assert AutoSwitch's totals (provably at ``hysteresis=1.0``, and in
    practice at the default) never exceed the better fixed direction.

    Batch awareness (``repro.service``): a batched run of B queries
    drives the engine with the *union* frontier and B-wide payloads, so
    ``frontier_edges`` here is the union-frontier degree sum and both
    formulas scale by ``stats.width == B`` — except the k-filter, which
    compacts the union mask once per step regardless of B. Per query,
    pull therefore costs the same amortized scan at every batch width
    while push pays for the whole union, which is what moves the
    push→pull crossover toward pull as batches widen (frontiers of
    distinct sources overlap sublinearly, so the union grows with B).
    """
    weights: CostWeights = DEFAULT_WEIGHTS

    def predict_push(self, stats: StepStats) -> jax.Array:
        w = self.weights
        combining = w.lock if stats.float_data else w.atomic
        k = stats.frontier_edges * stats.width
        cost = k * (w.read + w.write + combining)
        if stats.k_filter_push:
            # k-filter compacts the updated set (≤ the frontier's edge
            # span; its size is only known post-step, so bound it by the
            # frontier size — the compacted set rarely exceeds it). One
            # mask compaction per step, batch-width-independent.
            cost = cost + stats.frontier_vertices * (w.read + w.write)
        # inter-device traffic of the push exchange (0 on one device)
        return cost + stats.push_wire_bytes * w.collective_byte

    def predict_pull(self, stats: StepStats) -> jax.Array:
        w = self.weights
        return ((stats.pull_edges * w.read
                 + stats.pull_vertices * w.write) * stats.width
                + stats.pull_wire_bytes * w.collective_byte)


_B = lambda c: jnp.zeros((c,), bool)              # noqa: E731
_C = lambda c: jnp.zeros((c,), counter_dtype())   # noqa: E731


def _pred_dtype():
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


_F = lambda c: jnp.zeros((c,), _pred_dtype())     # noqa: E731


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StepTrace:
    """Fixed-capacity per-step record of what the engine actually did.

    One slot per executed step (across all phases and epochs, in order):
    the chosen direction, the frontier statistics the decision saw, the
    cost model's *predicted* push/pull prices for the step (the two
    numbers AutoSwitch compared — the decision-audit raw material), the
    per-direction wire bytes the backend predicted, and the step's
    *delta* of the four §4 memory counters. Rides through
    ``lax.while_loop`` carries, so capacity is static; steps beyond
    capacity are dropped from the per-step columns but *counted* in the
    ``overflow`` scalar, so a truncated trace is detectable
    (``RunResult.steps`` still counts them too).

        >>> r = api.solve(g, "bfs", root=0, policy="auto", trace=64)
        >>> r.trace.as_dict(int(r.steps))["pushed"]   # doctest: +SKIP
        [True, True, False, False, True]
        >>> int(r.trace.overflow)                     # doctest: +SKIP
        0
    """
    pushed: jax.Array
    frontier_vertices: jax.Array
    frontier_edges: jax.Array
    pull_touched_edges: jax.Array
    reads: jax.Array
    writes: jax.Array
    atomics: jax.Array
    locks: jax.Array
    predicted_push: jax.Array
    predicted_pull: jax.Array
    push_wire_bytes: jax.Array
    pull_wire_bytes: jax.Array
    # scalar: how many record() calls fell past capacity (dropped steps)
    overflow: jax.Array

    @classmethod
    def empty(cls, capacity: int) -> "StepTrace":
        return cls(pushed=_B(capacity), frontier_vertices=_C(capacity),
                   frontier_edges=_C(capacity),
                   pull_touched_edges=_C(capacity), reads=_C(capacity),
                   writes=_C(capacity), atomics=_C(capacity),
                   locks=_C(capacity), predicted_push=_F(capacity),
                   predicted_pull=_F(capacity),
                   push_wire_bytes=_C(capacity),
                   pull_wire_bytes=_C(capacity), overflow=_I())

    @property
    def capacity(self) -> int:
        return self.pushed.shape[0]

    def record(self, idx, pushed, stats: StepStats, delta: Cost,
               predicted_push=0.0, predicted_pull=0.0) -> "StepTrace":
        """Write one step's record at ``idx`` (out-of-range increments
        ``overflow`` instead of writing)."""
        put = lambda arr, v: arr.at[idx].set(  # noqa: E731
            jnp.asarray(v, arr.dtype), mode="drop")
        return StepTrace(
            pushed=put(self.pushed, pushed),
            frontier_vertices=put(self.frontier_vertices,
                                  stats.frontier_vertices),
            frontier_edges=put(self.frontier_edges, stats.frontier_edges),
            pull_touched_edges=put(self.pull_touched_edges,
                                   stats.pull_touched_edges),
            reads=put(self.reads, delta.reads),
            writes=put(self.writes, delta.writes),
            atomics=put(self.atomics, delta.atomics),
            locks=put(self.locks, delta.locks),
            predicted_push=put(self.predicted_push, predicted_push),
            predicted_pull=put(self.predicted_pull, predicted_pull),
            push_wire_bytes=put(self.push_wire_bytes,
                                stats.push_wire_bytes),
            pull_wire_bytes=put(self.pull_wire_bytes,
                                stats.pull_wire_bytes),
            overflow=self.overflow + counter(idx >= self.capacity))

    def as_dict(self, steps: int = None) -> dict:
        """Python-native view, trimmed to the first ``steps`` slots.

        Per-step columns become lists; the ``overflow`` scalar comes
        through as a plain int (dropped-step count)."""
        k = self.capacity if steps is None else min(steps, self.capacity)
        out = {}
        for f in dataclasses.fields(self):
            val = getattr(self, f.name)
            if val.ndim == 0:                      # scalars: overflow
                out[f.name] = int(val)
                continue
            col = jax.device_get(val[:k])
            if f.name == "pushed":
                out[f.name] = [bool(x) for x in col]
            elif jnp.issubdtype(val.dtype, jnp.floating):
                out[f.name] = [float(x) for x in col]
            else:
                out[f.name] = [int(x) for x in col]
        return out
