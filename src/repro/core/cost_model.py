"""PRAM cost counters — the analytic replacement for the paper's PAPI
tables (paper §4, Table 1).

On CPU the paper counts reads, writes, atomics (combining writes to ints),
and locks (combining writes to floats, since CPUs lack float atomics).
On TPU those categories map to gather bytes, private writes, combining
scatter elements, and float combining-scatter elements respectively; the
*counts* are architecture-independent, so we track them exactly as the
paper defines them and validate Table 1's structure analytically.

Counters are jnp int64 scalars inside a registered-dataclass pytree so they
can ride through jit / while_loop carries.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Paper-scale counters (reads on orc-TC ≈ 3·10^12, Table 1) need 64-bit
# integers. All framework tensors use explicit dtypes, so flipping the
# default is safe and keeps the counter pytrees honest.
jax.config.update("jax_enable_x64", True)

__all__ = ["Cost", "zero_cost", "counter_dtype", "counter"]


def counter_dtype():
    """The widest integer dtype JAX will actually honor for counters.

    With ``jax_enable_x64`` off, ``astype(jnp.int64)`` silently produces
    int32 (the warning is routinely filtered); asking for the dtype
    through this helper keeps every counter cast honest instead of
    silently truncating paper-scale counts.
    """
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def counter(x) -> jax.Array:
    """Cast ``x`` to the live counter dtype (see :func:`counter_dtype`)."""
    return jnp.asarray(x, counter_dtype())


_I = lambda: jnp.zeros((), counter_dtype())  # noqa: E731


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Cost:
    """Operation counts, paper §2.4 categories.

    reads / writes: plain memory accesses to shared vertex state.
    atomics: combining writes to integer data (CPU: FAA/CAS; TPU:
        int scatter-add elements).
    locks: combining writes to float data (CPU: lock-guarded update; TPU:
        float scatter-add elements).
    messages / collective_bytes: DM-setting traffic (MP / RMA emulation).
    barriers: bulk-synchronous phase boundaries.
    iterations: outer-loop rounds (Table 6b).
    """
    reads: jax.Array = dataclasses.field(default_factory=_I)
    writes: jax.Array = dataclasses.field(default_factory=_I)
    atomics: jax.Array = dataclasses.field(default_factory=_I)
    locks: jax.Array = dataclasses.field(default_factory=_I)
    messages: jax.Array = dataclasses.field(default_factory=_I)
    collective_bytes: jax.Array = dataclasses.field(default_factory=_I)
    barriers: jax.Array = dataclasses.field(default_factory=_I)
    iterations: jax.Array = dataclasses.field(default_factory=_I)

    def __add__(self, other: "Cost") -> "Cost":
        return jax.tree.map(lambda a, b: a + b, self, other)

    def charge(self, **kw) -> "Cost":
        """Return a new Cost with the given fields incremented."""
        vals = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)}
        for k, v in kw.items():
            vals[k] = vals[k] + counter(v)
        return Cost(**vals)

    def charge_combining_writes(self, count, float_data: bool) -> "Cost":
        """Push-side conflict resolution: ints -> atomics, floats -> locks
        (paper §4.1 'no CPUs offer atomics operating on such values')."""
        if float_data:
            return self.charge(locks=count, writes=count)
        return self.charge(atomics=count, writes=count)

    def as_dict(self) -> dict:
        return {f.name: int(getattr(self, f.name))
                for f in dataclasses.fields(self)}


def zero_cost() -> Cost:
    return Cost()
