"""Algebraic (semiring) formulation — paper §7.1.

  pull  ≡ CSR SpMV: each output row privately reduces A's row — great for
          dense x, cannot exploit x's sparsity;
  push  ≡ CSC SpMSpV: iterate only the columns where x is nonzero, scatter-
          combine into y — exploits x sparsity, needs combining writes.

`Semiring` carries (⊕, ⊗, 0̄). plus-times gives PR; min-plus gives SSSP
relaxation; or-and gives BFS reachability. Both products return the same
vector; they differ in layout, access order, and Cost — which is the whole
point of the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..graphs.structure import Graph
from ..sparse.segment import segment_max, segment_min, segment_sum
from .cost_model import Cost
from .primitives import frontier_out_edges

__all__ = ["Semiring", "PLUS_TIMES", "MIN_PLUS", "OR_AND",
           "spmv_pull", "spmspv_push"]


@dataclasses.dataclass(frozen=True)
class Semiring:
    name: str
    combine: str                      # 'sum' | 'min' | 'max' segment reduce
    mul: Callable[[jax.Array, jax.Array], jax.Array]
    zero: float

    def segment_reduce(self, vals, ids, n):
        fn = {"sum": segment_sum, "min": segment_min, "max": segment_max}[
            self.combine]
        return fn(vals, ids, n)


PLUS_TIMES = Semiring("plus_times", "sum", lambda x, w: x * w, 0.0)
MIN_PLUS = Semiring("min_plus", "min", lambda x, w: x + w, jnp.inf)
OR_AND = Semiring("or_and", "max", lambda x, w: x, 0.0)


def spmv_pull(g: Graph, x: jax.Array, sr: Semiring = PLUS_TIMES,
              cost: Cost = Cost()) -> tuple[jax.Array, Cost]:
    """y = A ⊗ x in CSR (pull) order: y[v] = ⊕_{u in N_in(v)} x[u] ⊗ w."""
    vals = sr.mul(jnp.take(x, g.coo_src, axis=0, mode="fill",
                           fill_value=float(sr.zero)), g.coo_w)
    y = sr.segment_reduce(vals, g.coo_dst, g.n)
    if sr.combine in ("min", "max") and jnp.issubdtype(y.dtype, jnp.floating):
        y = jnp.where(jnp.isfinite(y), y, jnp.asarray(sr.zero, y.dtype))
    cost = cost.charge(reads=jnp.asarray(g.m, jnp.int64),
                       writes=jnp.asarray(g.n, jnp.int64))
    return y, cost


def spmspv_push(g: Graph, x: jax.Array, nonzero: jax.Array,
                sr: Semiring = PLUS_TIMES,
                cost: Cost = Cost()) -> tuple[jax.Array, Cost]:
    """y = A ⊗ x in CSC (push) order, exploiting x's sparsity mask.

    Only columns with ``nonzero[u]`` contribute; combining writes are
    charged per touched edge (int payload -> atomics, float -> locks).
    """
    xe = jnp.take(x, g.push_src, axis=0, mode="fill",
                  fill_value=float(sr.zero))
    active = jnp.take(nonzero, g.push_src, axis=0, mode="fill",
                      fill_value=False)
    vals = sr.mul(xe, g.push_w)
    vals = jnp.where(active, vals, jnp.asarray(sr.zero, vals.dtype))
    if sr.combine == "min":
        vals = jnp.where(active, vals, jnp.asarray(jnp.inf, vals.dtype))
    y = sr.segment_reduce(vals, g.push_dst, g.n)
    if sr.combine in ("min", "max") and jnp.issubdtype(y.dtype, jnp.floating):
        y = jnp.where(jnp.isfinite(y), y, jnp.asarray(sr.zero, y.dtype))
    k = frontier_out_edges(g, nonzero)
    cost = cost.charge(reads=k).charge_combining_writes(
        k, float_data=jnp.issubdtype(x.dtype, jnp.floating))
    return y, cost
