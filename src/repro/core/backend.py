"""ExchangeBackend — pluggable k-relaxation execution (paper §4, §6, §7).

The paper's thesis is that push and pull are two *implementations* of one
abstract primitive; this module adds the third axis: the same primitive
over different *memory systems*. A backend answers one question — "given
wire values and a frontier, combine messages per destination" — and the
engine/API never care how:

  * ``DenseBackend``       — the dense-frontier segment ops
    (``push_relax`` / ``pull_relax``), shared-memory semantics.
  * ``EllBackend``         — pull in the ELL (padded-row) layout the
    Pallas ``ell_spmv`` kernel tiles; push falls back to the CSC
    (push-major) segment scatter (ELL is a pull-major layout).
  * ``PallasBackend``      — the ELL semantics executed by the actual
    Pallas kernels (``ell_spmv_pallas`` pull, ``coo_push_pallas`` push)
    with autotuned block sizes; cells the kernels do not cover
    (exotic ``msg_fn``, unsupported combine/dtype/payload rank) fall
    back transparently to the jnp primitives, so every registered
    algorithm and policy string still runs.
  * ``DistributedBackend`` — the paper's §6 DM setting: a 1D partition +
    PA edge split; local edges are plain per-owner writes, remote edges
    go through ``dist.collectives`` (combined-alltoall push or
    all_gather pull), with collective bytes charged to the Cost.

``relax`` accepts ``direction`` as a static ``Direction`` or a traced
boolean (True = push) so direction-switching policies can pick per step
inside jitted loops.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..graphs.structure import Graph
from ..resilience import CircuitBreaker, fault_point, note
from .cost_model import Cost, counter, counter_dtype
from .direction import Direction
from .primitives import (COMBINE_FNS, combine_identity, frontier_in_edges,
                         frontier_out_edges, mask_untouched, pull_relax,
                         pull_relax_ell, push_relax)

__all__ = ["ExchangeBackend", "DenseBackend", "EllBackend",
           "PallasBackend", "DistributedBackend", "require_backend",
           "classify_msg_fn"]


def require_backend(algorithm: str, backend, *allowed) -> None:
    """Raise when ``backend`` is not one of the ``allowed`` classes.

    Algorithm ``build`` hooks call this to reject (policy, backend)
    combinations they have no execution path for; ``api.solve`` converts
    the raise into a ValueError naming the combination.
    """
    if backend is None or isinstance(backend, tuple(allowed)):
        return
    names = ", ".join(c.__name__ for c in allowed)
    raise NotImplementedError(
        f"{algorithm} supports only [{names}] backends, "
        f"not {type(backend).__name__}")


@dataclasses.dataclass(frozen=True)
class ExchangeBackend:
    """Protocol: how one k-relaxation step touches memory.

    Subclasses implement ``push`` (scatter from the frontier, combining
    writes at destinations) and ``pull`` (private gather into touched
    destinations); ``relax`` dispatches between them, including runtime
    (traced-bool) direction switching so direction policies can choose
    per step inside jitted loops. Both must return
    ``(combined_msgs, cost)`` with the §4 counters charged.

        >>> from repro.core import EllBackend
        >>> r = api.solve(g, "pagerank", iters=20,
        ...               backend=EllBackend())      # doctest: +SKIP

    ``pull_scans_all`` tells the cost predictor whether this backend's
    pull reads every edge regardless of the touched destination set
    (true for rectangular layouts like ELL); the engine folds it into
    the :class:`~repro.core.cost_model.StepStats` it hands to switching
    policies.
    """

    # ELL-style layouts gather all m edges even for sparse destination
    # sets; dense/distributed pulls only scan the touched rows. Class
    # attribute, not a field: it is a property of the layout, not of an
    # instance.
    pull_scans_all = False

    def push(self, g: Graph, values: jax.Array, frontier: jax.Array,
             combine: str, msg_fn: Optional[Callable],
             cost: Cost) -> tuple[jax.Array, Cost]:
        raise NotImplementedError

    def pull(self, g: Graph, values: jax.Array,
             touched: Optional[jax.Array], combine: str,
             msg_fn: Optional[Callable],
             cost: Cost) -> tuple[jax.Array, Cost]:
        raise NotImplementedError

    def relax(self, g: Graph, values: jax.Array, frontier: jax.Array, *,
              direction, combine: str = "sum",
              msg_fn: Optional[Callable] = None,
              touched: Optional[jax.Array] = None,
              cost: Cost = Cost()) -> tuple[jax.Array, Cost]:
        if isinstance(direction, Direction):
            if direction == Direction.PUSH:
                return self.push(g, values, frontier, combine, msg_fn, cost)
            return self.pull(g, values, touched, combine, msg_fn, cost)
        return jax.lax.cond(
            direction,
            lambda v, f, c: self.push(g, v, f, combine, msg_fn, c),
            lambda v, f, c: self.pull(g, v, touched, combine, msg_fn, c),
            values, frontier, cost)

    # -- cross-step exchange state (sharded/compressed backends) ----------
    def init_exchange_state(self, g: Graph):
        """Initial exchange-carried state for a run on ``g``.

        Backends whose exchange is stateful *across steps* — e.g. the
        sharded push's error-feedback compression accumulator — return a
        pytree here; the engine threads it through the loop carry and
        hands it back to every :meth:`relax_ex` call. The default is an
        empty pytree: stateless, zero carry overhead.
        """
        return ()

    def relax_ex(self, g: Graph, values: jax.Array, frontier: jax.Array,
                 *, direction, combine: str = "sum",
                 msg_fn: Optional[Callable] = None,
                 touched: Optional[jax.Array] = None,
                 cost: Cost = Cost(), xstate=()) -> tuple:
        """``relax`` with exchange-state threading: returns
        ``(combined_msgs, cost, new_xstate)``. The default forwards to
        :meth:`relax` and passes ``xstate`` through unchanged — the
        engine always calls this surface, so stateless backends pay
        nothing while stateful ones override it."""
        out, cost = self.relax(g, values, frontier, direction=direction,
                               combine=combine, msg_fn=msg_fn,
                               touched=touched, cost=cost)
        return out, cost, xstate

    def predict_comm_bytes(self, g: Graph, values, frontier) -> tuple:
        """Predicted inter-device wire bytes of one (push, pull) step.

        The engine folds the pair into ``StepStats.push_wire_bytes`` /
        ``pull_wire_bytes`` so ``AutoSwitch`` prices the §6 comm
        asymmetry; the formulas must match what the backend's own
        ``push``/``pull`` then charge to ``Cost.collective_bytes``
        (keeping the predictor exact for exchange steps). Single-device
        backends move nothing: (0, 0).
        """
        return counter(0), counter(0)

    def predict_pull_scan(self, g: Graph, touched, values=None,
                          combine: str = "sum",
                          msg_fn: Optional[Callable] = None) -> tuple:
        """Predicted ``(edges_read, vertices_written)`` of one pull step
        of this backend, per payload column.

        The engine folds the pair into ``StepStats.pull_edges`` /
        ``pull_vertices``, so this is where a backend's *layout* enters
        the crossover: full-scan layouts (``pull_scans_all``) report all
        ``m`` edges regardless of the touched set, dense pulls the
        touched in-degree sum, and the frontier-aware kernel pull its
        restricted ``touched × d_ell`` gather. Must mirror exactly what
        this backend's ``pull`` then charges (× width) — predictor
        exactness is what the AutoSwitch never-worse guarantees rest on.
        ``values``/``combine``/``msg_fn`` let kernel backends fold their
        trace-time dispatch (kernel vs jnp fallback) into the price.
        """
        if touched is None or self.pull_scans_all:
            return counter(g.m), counter(g.n)
        return (frontier_in_edges(g, touched),
                jnp.sum(touched.astype(counter_dtype())))

    @property
    def name(self) -> str:
        return type(self).__name__

    def telemetry_counters(self) -> dict:
        """Backend-specific counters for :mod:`repro.obs` — dispatch
        tallies, layout geometry, anything the backend accumulates that
        a trace should surface under ``backend.<name>.*``. Values must
        already be totals (the obs registry ``put``s, never re-adds).
        Stateless backends report nothing."""
        return {}


@dataclasses.dataclass(frozen=True)
class DenseBackend(ExchangeBackend):
    """Shared-memory dense-frontier segment ops (the seed primitives)."""

    def push(self, g, values, frontier, combine, msg_fn, cost):
        return push_relax(g, values, frontier, combine=combine,
                          msg_fn=msg_fn, cost=cost)

    def pull(self, g, values, touched, combine, msg_fn, cost):
        return pull_relax(g, values, touched=touched, combine=combine,
                          msg_fn=msg_fn, cost=cost)


@dataclasses.dataclass(frozen=True)
class EllBackend(ExchangeBackend):
    """Pull in the ELL layout (rectangular VMEM tiles — what the
    ``ell_spmv`` Pallas kernel consumes); push falls back to the CSC
    (push-major) segment scatter."""

    pull_scans_all = True

    def push(self, g, values, frontier, combine, msg_fn, cost):
        return push_relax(g, values, frontier, combine=combine,
                          msg_fn=msg_fn, cost=cost)

    def pull(self, g, values, touched, combine, msg_fn, cost):
        out, cost = pull_relax_ell(g, values, combine=combine,
                                   msg_fn=msg_fn, cost=cost)
        if touched is not None:
            out = mask_untouched(out, touched, combine)
        return out, cost


# -- Pallas kernel dispatch --------------------------------------------
# msg_fn classification: the kernels implement the three wire-message
# shapes every registered algorithm uses. A msg_fn is classified by
# probing it on concrete values (msg_fns are pure elementwise jnp
# lambdas, so the probe runs eagerly even while an outer jit trace is
# being built) and matching the result against the candidate modes. The
# probe mixes signs, zero, and large magnitudes so functions that only
# coincide with a mode on tame inputs (clipping/saturation, piecewise
# definitions) are rejected rather than silently mis-dispatched.
_MSG_PROBE_X = (0.5, -1.25, 2.0, 0.0, 3e6, -7e5, 1e-4, 64.0)
_MSG_PROBE_W = (1.5, 0.25, -3.0, 2.0, -2e6, 4e5, 5e3, -0.125)
_MSG_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def classify_msg_fn(msg_fn: Optional[Callable]) -> Optional[str]:
    """Kernel message mode for ``msg_fn``: ``"copy"`` (msg = value,
    the primitives' ``msg_fn=None`` convention), ``"mul"`` (value ×
    weight — SpMV), ``"add"`` (value + weight — the min-plus
    relaxation), or None when the function matches none of them (the
    caller falls back to the jnp primitives)."""
    if msg_fn is None:
        return "copy"
    try:
        return _MSG_CACHE[msg_fn]
    except (KeyError, TypeError):
        pass
    import numpy as np
    mode = None
    try:
        # escape any ambient jit trace: the probe must execute eagerly
        # even while the engine's loop is being traced
        with jax.ensure_compile_time_eval():
            x = jnp.asarray(_MSG_PROBE_X, jnp.float32)
            w = jnp.asarray(_MSG_PROBE_W, jnp.float32)
            got = np.asarray(msg_fn(x, w))
            cands = (("copy", x), ("mul", x * w), ("add", x + w))
            for cand, want in cands:
                if got.shape == x.shape and np.allclose(
                        got, np.asarray(want), rtol=1e-6, atol=1e-6):
                    mode = cand
                    break
    except Exception:      # arbitrary callables may reject the probe
        mode = None
    try:
        _MSG_CACHE[msg_fn] = mode
    except TypeError:      # non-weakrefable callables skip the cache
        pass
    return mode


_PALLAS_DTYPES = ("float32", "float64", "int32", "int64")


@dataclasses.dataclass(frozen=True, eq=False)
class PallasBackend(EllBackend):
    """The ELL backend's semantics executed by the Pallas kernels.

    ``pull`` is frontier-aware: with a touched destination set it
    compacts the set to row ids and dispatches to
    ``ell_pull_frontier_pallas`` (gather/reduce of *touched rows only*
    over the dual layout's ELL-in side — ``touched × d_ell`` work),
    falling back to the full-scan ``ell_spmv_pallas`` when the set is
    too dense for the restriction to pay (or, in-trace, overflows the
    static row capacity — ``pull_frontier_cap``, default
    ``default_pull_cap``); an empty touched set returns the combine
    identity without launching any kernel. ``push`` dispatches to
    ``coo_push_pallas`` (two-phase contention-free bin reduce over a
    per-graph bin layout). Both directions therefore run on their
    native rectangular layout, held by a per-graph ``DualEllLayout``
    (ELL-in + ELL-out) cached here alongside the bin plans — and
    ``pull_scans_all`` is **False**: ``predict_pull_scan`` prices pull
    steps by the restricted gather the kernel will actually do, which
    moves AutoSwitch's predicted push/pull crossover pull-ward.

    Block sizes and the push reduce strategy come from
    ``kernels/tune.py`` — probed once per (graph shape, payload shape,
    platform; the frontier pull additionally keys on the compacted row
    capacity), cached on this instance and on disk — unless pinned via
    ``block_n``/``block_e``/``push_block_n``/``push_strategy``.
    ``interpret=None`` auto-detects (compiled on TPU, interpreter
    elsewhere).

    Cells outside the kernels' coverage — a ``msg_fn`` that is not one
    of the three wire-message shapes, a combine outside {sum, max, min},
    payload rank > 2, or a dtype outside float32/float64/int32/int64 —
    fall back to the jnp primitives (``EllBackend``'s paths), charging
    identical costs, so every (algorithm × policy) cell keeps running.

        >>> r = api.solve(g, "bfs", root=0, backend="pallas")  # doctest: +SKIP

    ``stats`` counts trace-time dispatch decisions (kernel vs fallback,
    per direction) — observability for tests and benchmarks.
    """
    # the ELL-in gather no longer scans all edges when a touched set is
    # given: the frontier kernel restricts it, and predict_pull_scan
    # prices the restriction
    pull_scans_all = False

    interpret: Optional[bool] = None
    block_n: Optional[int] = None     # pull tile rows (None = autotune)
    block_e: Optional[int] = None     # push edge-chunk size
    push_block_n: Optional[int] = None  # push destination-bin width
    push_strategy: Optional[str] = None  # phase-2 reduce ("scan"|"mxu")
    push_bin_cap: Optional[int] = None  # traced-bin capacity override
    pull_frontier_cap: Optional[int] = None  # traced touched-row capacity
    autotune: bool = True
    stats: dict = dataclasses.field(
        default_factory=lambda: {"kernel_pull": 0, "kernel_push": 0,
                                 "kernel_pull_frontier": 0,
                                 "skip_empty_pull": 0,
                                 "fallback_pull": 0, "fallback_push": 0,
                                 "fault_fallback_pull": 0,
                                 "fault_fallback_push": 0,
                                 "breaker_skip_pull": 0,
                                 "breaker_skip_push": 0,
                                 "breaker_open": 0})
    # the degradation ladder's middle rung: a (kernel, shape) cell that
    # keeps *failing* at dispatch (not merely unsupported) opens here
    # and skips straight to the jnp fallback for a call-counted
    # cooldown — see repro.resilience.breaker
    breaker: CircuitBreaker = dataclasses.field(
        default_factory=CircuitBreaker, repr=False)
    _tuned: dict = dataclasses.field(default_factory=dict, repr=False)
    _plans: dict = dataclasses.field(default_factory=dict, repr=False)
    _layouts: dict = dataclasses.field(default_factory=dict, repr=False)

    # identity eq/hash, explicitly: instances carry mutable caches and
    # distinct block/interpret configs, and the engine cache keys on the
    # backend. eq=False alone would inherit EllBackend's *value*-based
    # __eq__/__hash__ (which see no PallasBackend fields), making every
    # instance compare equal and collide in the cache.
    __hash__ = object.__hash__

    def __eq__(self, other):
        return self is other

    # -- dispatch help -----------------------------------------------------
    def _mode(self, values, combine, msg_fn) -> Optional[str]:
        if combine not in ("sum", "max", "min"):
            return None
        if values.ndim not in (1, 2):
            return None
        if str(values.dtype) not in _PALLAS_DTYPES:
            return None
        return classify_msg_fn(msg_fn)

    def _pull_block_n(self, g: Graph, values, combine, mode) -> int:
        if self.block_n is not None:
            return self.block_n
        from ..kernels.tune import pull_candidates, tune_pull
        width = 1 if values.ndim == 1 else int(values.shape[-1])
        key = ("pull", g.n, g.d_ell, width, str(values.dtype), combine,
               mode)
        if key not in self._tuned:
            self._tuned[key] = (
                tune_pull(g.n, g.d_ell, width, values.dtype, combine,
                          mode, self.interpret)
                if self.autotune else pull_candidates(g.n)[0])
        return self._tuned[key]

    def _push_blocks(self, g: Graph, values, combine,
                     mode) -> tuple[int, int, str]:
        if (self.block_e is not None and self.push_block_n is not None
                and self.push_strategy is not None):
            return self.block_e, self.push_block_n, self.push_strategy
        from ..kernels.tune import push_candidates, tune_push
        width = 1 if values.ndim == 1 else int(values.shape[-1])
        key = ("push", g.n, g.m, width, str(values.dtype), combine, mode)
        if key not in self._tuned:
            self._tuned[key] = (
                tune_push(g.n, g.m, width, values.dtype, combine, mode,
                          self.interpret)
                if self.autotune else push_candidates(g.n, g.m)[0])
        be, bn, strat = self._tuned[key]
        # partial pins override only their own component
        if self.block_e is not None:
            be = self.block_e
        if self.push_block_n is not None:
            bn = self.push_block_n
        if self.push_strategy is not None:
            strat = self.push_strategy
        return be, bn, strat

    def _push_plan(self, g: Graph, block_n: int, block_e: int):
        """Cached phase-1 bin layout for a concrete graph — built once
        per (graph, bin width, edge block) via the host regroup and
        stored alongside the tuner results. Keys carry a weakref so an
        id() reused by a new Graph cannot resurrect a stale plan."""
        from ..kernels.coo_push import build_push_plan
        key = (id(g), block_n, block_e)
        hit = self._plans.get(key)
        if hit is not None and hit[0]() is g:
            return hit[1]
        plan = build_push_plan(g.coo_src, g.coo_dst, g.coo_w, g.n,
                               block_n, align=block_e)
        self._plans[key] = (weakref.ref(g), plan)
        return plan

    def dual_layout(self, g: Graph):
        """Cached dual ELL-in/ELL-out layout for a concrete graph —
        built once per graph on the host (the in side shares the
        graph's own ELL arrays) and stored next to the bin plans, with
        the same weakref guard against id() reuse. Traced graphs use
        ``g.ell_idx``/``g.ell_w`` directly (the layout's in side *is*
        those arrays)."""
        from ..kernels.layout import build_dual_ell
        key = ("dual", id(g))
        hit = self._layouts.get(key)
        if hit is not None and hit[0]() is g:
            return hit[1]
        layout = build_dual_ell(g)
        self._layouts[key] = (weakref.ref(g), layout)
        return layout

    def _pull_cap(self, g: Graph) -> int:
        if self.pull_frontier_cap is not None:
            return self.pull_frontier_cap
        from ..kernels.ell_pull_frontier import default_pull_cap
        return default_pull_cap(g.n, g.m, g.d_ell)

    def _pull_frontier_block(self, g: Graph, rows: int, values, combine,
                             mode) -> int:
        from ..kernels.tune import (pull_frontier_candidates,
                                    tune_pull_frontier)
        width = 1 if values.ndim == 1 else int(values.shape[-1])
        key = ("pullf", g.n, g.d_ell, rows, width, str(values.dtype),
               combine, mode)
        if key not in self._tuned:
            self._tuned[key] = (
                tune_pull_frontier(g.n, g.d_ell, rows, width,
                                   values.dtype, combine, mode,
                                   self.interpret)
                if self.autotune
                else pull_frontier_candidates(g.n, rows)[0])
        return self._tuned[key]

    def _pull_scan_stats(self, g: Graph, touched):
        """(edges_read, rows_written, count, fits) of a kernel pull
        with this touched set — the single formula behind both
        ``predict_pull_scan`` and the charge ``pull`` makes, so the
        predictor stays exact. The restriction pays only when the
        touched rows fit the static capacity AND their rectangular
        gather (``count × d_ell``) undercuts the full scan's ``m`` —
        otherwise the full-scan price (m, n) applies, which is exactly
        the old ``pull_scans_all`` pricing (a 100%-touched frontier can
        never be priced worse than before)."""
        cnt = jnp.sum(touched.astype(counter_dtype()))
        cap = self._pull_cap(g)
        fits = (cnt > 0) & (cnt <= cap) & (cnt * g.d_ell < g.m)
        edges = jnp.where(cnt == 0, counter(0),
                          jnp.where(fits, cnt * g.d_ell, counter(g.m)))
        verts = jnp.where(cnt == 0, counter(0),
                          jnp.where(fits, cnt, counter(g.n)))
        return edges, verts, cnt, fits

    def predict_pull_scan(self, g, touched, values=None, combine="sum",
                          msg_fn=None):
        # a step the kernels cannot cover falls back to the full-scan
        # jnp ELL path, so it must be priced as one
        if (touched is None or values is None
                or self._mode(values, combine, msg_fn) is None):
            return counter(g.m), counter(g.n)
        edges, verts, _, _ = self._pull_scan_stats(g, touched)
        return edges, verts

    def telemetry_counters(self) -> dict:
        out = dict(self.stats)
        out.update({f"breaker_{k}": v
                    for k, v in self.breaker.stats().items()})
        return out

    def _kernel_failed(self, cell, direction: str, exc) -> None:
        """One rung down the ladder: count the failure, inform the
        breaker, surface a resilience event. The caller then serves
        this call from the jnp fallback."""
        self.stats[f"fault_fallback_{direction}"] += 1
        opened = self.breaker.record_failure(cell)
        note(f"fallback.pallas.{direction}",
             error=type(exc).__name__, cell=str(cell))
        if opened:
            self.stats["breaker_open"] += 1
            note("breaker.open", cell=str(cell),
                 cooldown=self.breaker.cooldown)

    # -- ExchangeBackend ---------------------------------------------------
    def pull(self, g, values, touched, combine, msg_fn, cost):
        mode = self._mode(values, combine, msg_fn)
        if mode is None:
            self.stats["fallback_pull"] += 1
            return super().pull(g, values, touched, combine, msg_fn, cost)
        width = 1 if values.ndim == 1 else int(values.shape[-1])
        cell = ("pull", g.n, g.d_ell, width, str(values.dtype), combine,
                mode)
        if not self.breaker.allow(cell):
            self.stats["breaker_skip_pull"] += 1
            return super().pull(g, values, touched, combine, msg_fn, cost)
        try:
            fault_point("pallas.pull")
            out = self._pull_kernel(g, values, touched, combine, mode,
                                    cost)
        except Exception as exc:   # noqa: BLE001 — the ladder catches
            # dispatch/trace-time kernel failure: degrade to the jnp
            # path (identical semantics, full-scan pricing)
            self._kernel_failed(cell, "pull", exc)
            return super().pull(g, values, touched, combine, msg_fn, cost)
        self.breaker.record_success(cell)
        return out

    def _pull_kernel(self, g, values, touched, combine, mode, cost):
        from ..graphs.structure import pad_values
        from ..kernels.ell_spmv import _out_dtype, ell_spmv_pallas
        width = 1 if values.ndim == 1 else values.shape[-1]

        def full_scan():
            out = ell_spmv_pallas(
                pad_values(values), g.ell_idx, g.ell_w, combine=combine,
                msg=mode,
                block_n=self._pull_block_n(g, values, combine, mode),
                interpret=self.interpret)
            if touched is not None:
                out = mask_untouched(out, touched, combine)
            return out

        if touched is None:
            # every destination is live: the rectangular full scan is
            # the native path (identical charge to pull_relax_ell)
            self.stats["kernel_pull"] += 1
            return full_scan(), cost.charge(reads=counter(g.m) * width,
                                            writes=counter(g.n) * width)

        from ..kernels.ell_pull_frontier import (ell_pull_frontier_full,
                                                 frontier_rows)
        edges, verts, cnt, fits = self._pull_scan_stats(g, touched)
        odt = _out_dtype(values.dtype, g.ell_w.dtype, mode, combine)
        ident = combine_identity(combine, odt)

        def identity_out():
            return jnp.full((g.n,) + values.shape[1:], ident, odt)

        def frontier(rows, in_idx, in_w, block_r):
            return ell_pull_frontier_full(
                pad_values(values), in_idx, in_w, rows, combine=combine,
                msg=mode, block_r=block_r, interpret=self.interpret)

        if not isinstance(touched, jax.core.Tracer) and not isinstance(
                g.ell_idx, jax.core.Tracer):
            # concrete call (direct use, benchmarks): dispatch eagerly.
            # The compaction is sized to the actual touched count,
            # rounded to a power of two so the kernel's jit cache and
            # the tuner see a bounded family of row capacities.
            cnt_c = int(cnt)
            if cnt_c == 0:
                self.stats["skip_empty_pull"] += 1
                out = identity_out()
            elif bool(fits):
                self.stats["kernel_pull_frontier"] += 1
                layout = self.dual_layout(g)
                rows_n = max(8, 1 << (cnt_c - 1).bit_length())
                block_r = self._pull_frontier_block(g, rows_n, values,
                                                    combine, mode)
                out = frontier(frontier_rows(touched, rows_n),
                               layout.in_idx, layout.in_w, block_r)
            else:
                self.stats["kernel_pull"] += 1
                out = full_scan()
        else:
            # in-trace (the engine jits the graph): compact under the
            # static capacity and guard on the runtime fits bit —
            # mirroring the push bin plan's lax.cond capacity guard.
            # An empty touched set short-circuits to the identity
            # without any kernel launch.
            self.stats["kernel_pull_frontier"] += 1
            cap = self._pull_cap(g)
            block_r = self._pull_frontier_block(g, cap, values, combine,
                                                mode)
            rows = frontier_rows(touched, cap)
            out = jax.lax.cond(
                cnt == 0, identity_out,
                lambda: jax.lax.cond(
                    fits,
                    lambda: frontier(rows, g.ell_idx, g.ell_w, block_r),
                    full_scan))
        # exactly what predict_pull_scan promised (× payload width)
        return out, cost.charge(reads=edges * width,
                                writes=verts * width)

    def push(self, g, values, frontier, combine, msg_fn, cost):
        mode = self._mode(values, combine, msg_fn)
        if mode is None:
            self.stats["fallback_push"] += 1
            return super().push(g, values, frontier, combine, msg_fn,
                                cost)
        width = 1 if values.ndim == 1 else int(values.shape[-1])
        cell = ("push", g.n, g.m, width, str(values.dtype), combine,
                mode)
        if not self.breaker.allow(cell):
            self.stats["breaker_skip_push"] += 1
            return super().push(g, values, frontier, combine, msg_fn,
                                cost)
        try:
            fault_point("pallas.push")
            out = self._push_kernel(g, values, frontier, combine, mode,
                                    cost)
        except Exception as exc:   # noqa: BLE001 — the ladder catches
            self._kernel_failed(cell, "push", exc)
            return super().push(g, values, frontier, combine, msg_fn,
                                cost)
        self.breaker.record_success(cell)
        return out

    def _push_kernel(self, g, values, frontier, combine, mode, cost):
        from ..kernels.coo_push import (bin_plan_traced, coo_push_pallas,
                                        default_bin_cap)
        self.stats["kernel_push"] += 1
        block_e, block_n, strategy = self._push_blocks(g, values,
                                                       combine, mode)

        def kernel(v, f, plan):
            return coo_push_pallas(
                v, f, g.coo_src, g.coo_dst, g.coo_w, g.n, combine=combine,
                msg=mode, block_e=block_e, block_n=block_n,
                interpret=self.interpret, plan=plan, strategy=strategy)

        if not isinstance(g.coo_src, jax.core.Tracer):
            # concrete graph (direct calls, benchmarks): the host
            # regroup builds the exact bin layout once; cached per
            # (graph, bin width, edge block) next to the tuner results
            out = kernel(values, frontier,
                         self._push_plan(g, block_n, block_e))
        else:
            # the engine jits the graph: bin in-trace from in_ptr (one
            # gather, no scatter) under a static capacity, guarded by
            # the plan's fits bit. The guard is traced per step on
            # purpose: engines are cached per graph *shape* — deciding
            # eagerly per concrete graph would bake one graph's answer
            # into an engine other same-shape graphs reuse.
            cap = (self.push_bin_cap
                   or default_bin_cap(g.n, g.m, g.d_ell, block_n,
                                      block_e))
            plan, fits = bin_plan_traced(
                g.coo_src, g.coo_dst, g.coo_w, g.in_ptr, g.n, block_n,
                cap=cap, align=block_e, max_run=g.d_ell)
            out = jax.lax.cond(
                fits,
                lambda v, f: kernel(v, f, plan),
                lambda v, f: _coo_push_jnp(g, v, f, combine, mode),
                values, frontier)
        k = frontier_out_edges(g, frontier)
        width = 1 if values.ndim == 1 else values.shape[-1]
        # the phase-1 binning pass reads and rewrites every edge once
        # (frontier-independent: the layout covers the whole edge list)
        cost = cost.charge(reads=counter(g.m), writes=counter(g.m))
        cost = cost.charge(reads=k * width).charge_combining_writes(
            k * width,
            float_data=jnp.issubdtype(values.dtype, jnp.floating))
        return out, cost


def _coo_push_jnp(g: Graph, values, frontier, combine: str, mode: str):
    """Segment-op push over the *dst-sorted* edge order — the runtime
    fallback branch when the traced binning pass's static capacity
    cannot hold the skewest bin (same combine, same order, so the two
    branches agree)."""
    x = jnp.take(values, g.coo_src, axis=0, mode="fill", fill_value=0)
    if mode == "mul":
        w = g.coo_w
        msgs = x * (w[:, None] if x.ndim == 2 else w)
    elif mode == "add":
        w = g.coo_w
        msgs = x + (w[:, None] if x.ndim == 2 else w)
    else:
        msgs = x
    active_e = jnp.take(frontier, g.coo_src, axis=0, mode="fill",
                        fill_value=False)
    if msgs.ndim == 2:
        active_e = active_e[:, None]
    msgs = jnp.where(active_e, msgs, combine_identity(combine, msgs.dtype))
    return COMBINE_FNS[combine](msgs, g.coo_dst, g.n)


@dataclasses.dataclass(frozen=True, eq=False)
class DistributedBackend(ExchangeBackend):
    """DM k-relaxation over a 1D partition + PA split (paper §6).

    Local edges (both endpoints owned) are plain segment writes; only the
    cut crosses shards, by the combined-alltoall push or the all_gather
    pull. Build with :meth:`prepare`; the instance is graph-specific.

    Restriction: messages must be a function of the *wire value only*
    (``msg_fn(v, w)`` with masked sources carrying the combine identity),
    which holds for every algorithm in ``repro.api``.
    """
    mesh: object = None
    part: object = None
    local: object = None          # edges grouped by owner (src==dst owner)
    remote_by_src: object = None  # cut edges grouped by src owner (push)
    remote_by_dst: object = None  # cut edges grouped by dst owner (pull)
    cut_edges: int = 0
    axis: str = "data"

    # identity hash/eq, explicitly (eq=False would inherit the parent
    # dataclass's value-based comparison, which sees none of this
    # class's fields — two backends prepared for different same-shape
    # graphs would collide in the engine cache): instances hold jnp
    # arrays, and jit static-arg hashing only needs per-instance
    # identity.
    __hash__ = object.__hash__

    def __eq__(self, other):
        return self is other

    @classmethod
    def prepare(cls, g: Graph, mesh=None, num_parts: Optional[int] = None,
                axis: str = "data") -> "DistributedBackend":
        from ..graphs.partition import (pa_regroup_by_dst, pa_split,
                                        partition_1d)
        if mesh is None:
            mesh = jax.make_mesh((jax.device_count(), 1), (axis, "model"))
        if num_parts is None:
            num_parts = mesh.shape[axis]
        if num_parts != mesh.shape[axis]:
            raise ValueError(
                f"num_parts={num_parts} must equal the mesh '{axis}' axis "
                f"size ({mesh.shape[axis]}): the exchanges map partitions "
                "to mesh shards 1:1.")
        part = partition_1d(g.n, num_parts)
        local, remote_src, stats = pa_split(g, part)
        # only the cut needs the pull grouping; the local set and stats
        # are grouping-independent (local edges share one owner)
        remote_dst = pa_regroup_by_dst(part, remote_src, g.n)
        return cls(mesh=mesh, part=part, local=local,
                   remote_by_src=remote_src, remote_by_dst=remote_dst,
                   cut_edges=int(stats["cut_edges"]), axis=axis)

    # -- helpers -----------------------------------------------------------
    def _pad(self, values: jax.Array, fill) -> jax.Array:
        extra = max(0, self.part.n_padded - values.shape[0])
        widths = ((0, extra),) + ((0, 0),) * (values.ndim - 1)
        return jnp.pad(values, widths, constant_values=fill)

    def _wire_msg_fn(self, msg_fn):
        # primitives treat msg_fn=None as "value, unweighted"; collectives
        # default to value*weight — normalize to the primitive convention.
        return msg_fn if msg_fn is not None else (lambda v, w: v)

    # -- ExchangeBackend ---------------------------------------------------
    def push(self, g, values, frontier, combine, msg_fn, cost):
        from ..dist.collectives import pa_exchange
        ident = combine_identity(combine, values.dtype)
        vpad = self._pad(jnp.where(frontier, values, ident), ident)
        out, nbytes = pa_exchange(
            self.mesh, self.part, self.local, self.remote_by_src, vpad,
            direction="push", msg_fn=self._wire_msg_fn(msg_fn),
            combine=combine, axis=self.axis)
        k = frontier_out_edges(g, frontier)
        cost = cost.charge(reads=k).charge_combining_writes(
            jnp.minimum(k, self.cut_edges),
            float_data=jnp.issubdtype(values.dtype, jnp.floating))
        cost = cost.charge(messages=jnp.minimum(k, self.cut_edges),
                           collective_bytes=nbytes * self.part.num_parts)
        return out[:g.n], cost

    def pull(self, g, values, touched, combine, msg_fn, cost):
        from ..dist.collectives import pa_exchange
        ident = combine_identity(combine, values.dtype)
        vpad = self._pad(values, ident)
        out, nbytes = pa_exchange(
            self.mesh, self.part, self.local, self.remote_by_dst, vpad,
            direction="pull", msg_fn=self._wire_msg_fn(msg_fn),
            combine=combine, axis=self.axis)
        out = out[:g.n]
        if touched is not None:
            out = mask_untouched(out, touched, combine)
            k = frontier_in_edges(g, touched)
            wr = jnp.sum(touched.astype(counter_dtype()))
        else:
            k = counter(g.m)
            wr = counter(g.n)
        cost = cost.charge(reads=k, writes=wr,
                           collective_bytes=nbytes * self.part.num_parts)
        return out, cost

    def predict_comm_bytes(self, g, values, frontier):
        # mirror exactly what push/pull charge: the combined-alltoall
        # moves n_padded·itemsize per device, the all_gather
        # n_padded·itemsize·(P-1)/P — both scaled by P devices
        Pn = self.part.num_parts
        npad = self.part.n_padded
        item = values.dtype.itemsize
        push_b = counter(npad * item) * Pn
        pull_b = counter(npad * item * (Pn - 1) // max(Pn, 1)) * Pn
        return push_b, pull_b
