"""ExchangeBackend — pluggable k-relaxation execution (paper §4, §6, §7).

The paper's thesis is that push and pull are two *implementations* of one
abstract primitive; this module adds the third axis: the same primitive
over different *memory systems*. A backend answers one question — "given
wire values and a frontier, combine messages per destination" — and the
engine/API never care how:

  * ``DenseBackend``       — the dense-frontier segment ops
    (``push_relax`` / ``pull_relax``), shared-memory semantics.
  * ``EllBackend``         — pull in the ELL (padded-row) layout the
    Pallas ``ell_spmv`` kernel tiles; push falls back to the COO scatter
    (ELL is a pull-major layout).
  * ``DistributedBackend`` — the paper's §6 DM setting: a 1D partition +
    PA edge split; local edges are plain per-owner writes, remote edges
    go through ``dist.collectives`` (combined-alltoall push or
    all_gather pull), with collective bytes charged to the Cost.

``relax`` accepts ``direction`` as a static ``Direction`` or a traced
boolean (True = push) so direction-switching policies can pick per step
inside jitted loops.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..graphs.structure import Graph
from .cost_model import Cost, counter, counter_dtype
from .direction import Direction
from .primitives import (combine_identity, frontier_in_edges,
                         frontier_out_edges, mask_untouched, pull_relax,
                         pull_relax_ell, push_relax)

__all__ = ["ExchangeBackend", "DenseBackend", "EllBackend",
           "DistributedBackend", "require_backend"]


def require_backend(algorithm: str, backend, *allowed) -> None:
    """Raise when ``backend`` is not one of the ``allowed`` classes.

    Algorithm ``build`` hooks call this to reject (policy, backend)
    combinations they have no execution path for; ``api.solve`` converts
    the raise into a ValueError naming the combination.
    """
    if backend is None or isinstance(backend, tuple(allowed)):
        return
    names = ", ".join(c.__name__ for c in allowed)
    raise NotImplementedError(
        f"{algorithm} supports only [{names}] backends, "
        f"not {type(backend).__name__}")


@dataclasses.dataclass(frozen=True)
class ExchangeBackend:
    """Protocol: how one k-relaxation step touches memory.

    Subclasses implement ``push`` (scatter from the frontier, combining
    writes at destinations) and ``pull`` (private gather into touched
    destinations); ``relax`` dispatches between them, including runtime
    (traced-bool) direction switching so direction policies can choose
    per step inside jitted loops. Both must return
    ``(combined_msgs, cost)`` with the §4 counters charged.

        >>> from repro.core import EllBackend
        >>> r = api.solve(g, "pagerank", iters=20,
        ...               backend=EllBackend())      # doctest: +SKIP

    ``pull_scans_all`` tells the cost predictor whether this backend's
    pull reads every edge regardless of the touched destination set
    (true for rectangular layouts like ELL); the engine folds it into
    the :class:`~repro.core.cost_model.StepStats` it hands to switching
    policies.
    """

    # ELL-style layouts gather all m edges even for sparse destination
    # sets; dense/distributed pulls only scan the touched rows. Class
    # attribute, not a field: it is a property of the layout, not of an
    # instance.
    pull_scans_all = False

    def push(self, g: Graph, values: jax.Array, frontier: jax.Array,
             combine: str, msg_fn: Optional[Callable],
             cost: Cost) -> tuple[jax.Array, Cost]:
        raise NotImplementedError

    def pull(self, g: Graph, values: jax.Array,
             touched: Optional[jax.Array], combine: str,
             msg_fn: Optional[Callable],
             cost: Cost) -> tuple[jax.Array, Cost]:
        raise NotImplementedError

    def relax(self, g: Graph, values: jax.Array, frontier: jax.Array, *,
              direction, combine: str = "sum",
              msg_fn: Optional[Callable] = None,
              touched: Optional[jax.Array] = None,
              cost: Cost = Cost()) -> tuple[jax.Array, Cost]:
        if isinstance(direction, Direction):
            if direction == Direction.PUSH:
                return self.push(g, values, frontier, combine, msg_fn, cost)
            return self.pull(g, values, touched, combine, msg_fn, cost)
        return jax.lax.cond(
            direction,
            lambda v, f, c: self.push(g, v, f, combine, msg_fn, c),
            lambda v, f, c: self.pull(g, v, touched, combine, msg_fn, c),
            values, frontier, cost)

    @property
    def name(self) -> str:
        return type(self).__name__


@dataclasses.dataclass(frozen=True)
class DenseBackend(ExchangeBackend):
    """Shared-memory dense-frontier segment ops (the seed primitives)."""

    def push(self, g, values, frontier, combine, msg_fn, cost):
        return push_relax(g, values, frontier, combine=combine,
                          msg_fn=msg_fn, cost=cost)

    def pull(self, g, values, touched, combine, msg_fn, cost):
        return pull_relax(g, values, touched=touched, combine=combine,
                          msg_fn=msg_fn, cost=cost)


@dataclasses.dataclass(frozen=True)
class EllBackend(ExchangeBackend):
    """Pull in the ELL layout (rectangular VMEM tiles — what the
    ``ell_spmv`` Pallas kernel consumes); push falls back to COO."""

    pull_scans_all = True

    def push(self, g, values, frontier, combine, msg_fn, cost):
        return push_relax(g, values, frontier, combine=combine,
                          msg_fn=msg_fn, cost=cost)

    def pull(self, g, values, touched, combine, msg_fn, cost):
        out, cost = pull_relax_ell(g, values, combine=combine,
                                   msg_fn=msg_fn, cost=cost)
        if touched is not None:
            out = mask_untouched(out, touched, combine)
        return out, cost


@dataclasses.dataclass(frozen=True, eq=False)
class DistributedBackend(ExchangeBackend):
    """DM k-relaxation over a 1D partition + PA split (paper §6).

    Local edges (both endpoints owned) are plain segment writes; only the
    cut crosses shards, by the combined-alltoall push or the all_gather
    pull. Build with :meth:`prepare`; the instance is graph-specific.

    Restriction: messages must be a function of the *wire value only*
    (``msg_fn(v, w)`` with masked sources carrying the combine identity),
    which holds for every algorithm in ``repro.api``.
    """
    mesh: object = None
    part: object = None
    local: object = None          # edges grouped by owner (src==dst owner)
    remote_by_src: object = None  # cut edges grouped by src owner (push)
    remote_by_dst: object = None  # cut edges grouped by dst owner (pull)
    cut_edges: int = 0
    axis: str = "data"

    # identity-based hash/eq (eq=False): instances hold jnp arrays, and
    # jit static-arg hashing only needs per-instance identity.

    @classmethod
    def prepare(cls, g: Graph, mesh=None, num_parts: Optional[int] = None,
                axis: str = "data") -> "DistributedBackend":
        from ..graphs.partition import (pa_regroup_by_dst, pa_split,
                                        partition_1d)
        if mesh is None:
            mesh = jax.make_mesh((jax.device_count(), 1), (axis, "model"))
        if num_parts is None:
            num_parts = mesh.shape[axis]
        if num_parts != mesh.shape[axis]:
            raise ValueError(
                f"num_parts={num_parts} must equal the mesh '{axis}' axis "
                f"size ({mesh.shape[axis]}): the exchanges map partitions "
                "to mesh shards 1:1.")
        part = partition_1d(g.n, num_parts)
        local, remote_src, stats = pa_split(g, part)
        # only the cut needs the pull grouping; the local set and stats
        # are grouping-independent (local edges share one owner)
        remote_dst = pa_regroup_by_dst(part, remote_src, g.n)
        return cls(mesh=mesh, part=part, local=local,
                   remote_by_src=remote_src, remote_by_dst=remote_dst,
                   cut_edges=int(stats["cut_edges"]), axis=axis)

    # -- helpers -----------------------------------------------------------
    def _pad(self, values: jax.Array, fill) -> jax.Array:
        extra = max(0, self.part.n_padded - values.shape[0])
        widths = ((0, extra),) + ((0, 0),) * (values.ndim - 1)
        return jnp.pad(values, widths, constant_values=fill)

    def _wire_msg_fn(self, msg_fn):
        # primitives treat msg_fn=None as "value, unweighted"; collectives
        # default to value*weight — normalize to the primitive convention.
        return msg_fn if msg_fn is not None else (lambda v, w: v)

    # -- ExchangeBackend ---------------------------------------------------
    def push(self, g, values, frontier, combine, msg_fn, cost):
        from ..dist.collectives import pa_exchange
        ident = combine_identity(combine, values.dtype)
        vpad = self._pad(jnp.where(frontier, values, ident), ident)
        out, nbytes = pa_exchange(
            self.mesh, self.part, self.local, self.remote_by_src, vpad,
            direction="push", msg_fn=self._wire_msg_fn(msg_fn),
            combine=combine, axis=self.axis)
        k = frontier_out_edges(g, frontier)
        cost = cost.charge(reads=k).charge_combining_writes(
            jnp.minimum(k, self.cut_edges),
            float_data=jnp.issubdtype(values.dtype, jnp.floating))
        cost = cost.charge(messages=jnp.minimum(k, self.cut_edges),
                           collective_bytes=nbytes * self.part.num_parts)
        return out[:g.n], cost

    def pull(self, g, values, touched, combine, msg_fn, cost):
        from ..dist.collectives import pa_exchange
        ident = combine_identity(combine, values.dtype)
        vpad = self._pad(values, ident)
        out, nbytes = pa_exchange(
            self.mesh, self.part, self.local, self.remote_by_dst, vpad,
            direction="pull", msg_fn=self._wire_msg_fn(msg_fn),
            combine=combine, axis=self.axis)
        out = out[:g.n]
        if touched is not None:
            out = mask_untouched(out, touched, combine)
            k = frontier_in_edges(g, touched)
            wr = jnp.sum(touched.astype(counter_dtype()))
        else:
            k = counter(g.m)
            wr = counter(g.n)
        cost = cost.charge(reads=k, writes=wr,
                           collective_bytes=nbytes * self.part.num_parts)
        return out, cost
