"""Core push-pull machinery (the paper's contribution)."""

from .backend import (DenseBackend, DistributedBackend, EllBackend,
                      ExchangeBackend, PallasBackend, classify_msg_fn,
                      require_backend)
from .cost_model import (Cost, CostPredictor, CostWeights, DEFAULT_WEIGHTS,
                         StepStats, StepTrace, zero_cost, counter,
                         counter_dtype)
from .direction import (AutoSwitch, Direction, DirectionPolicy, Fixed,
                        GenericSwitch, GreedySwitch)
from .engine import (PushPullEngine, VertexProgram, EngineResult, Phase,
                     PhaseProgram)
from .linalg import (Semiring, PLUS_TIMES, MIN_PLUS, OR_AND, spmv_pull,
                     spmspv_push)
from .primitives import (push_relax, pull_relax, pull_relax_ell, k_filter,
                         frontier_out_edges, frontier_in_edges,
                         combine_identity)

__all__ = [
    "ExchangeBackend", "DenseBackend", "EllBackend", "PallasBackend",
    "DistributedBackend", "require_backend", "classify_msg_fn",
    "Cost", "CostPredictor", "CostWeights", "DEFAULT_WEIGHTS", "StepStats",
    "StepTrace", "zero_cost", "counter", "counter_dtype",
    "Direction", "DirectionPolicy", "Fixed", "GenericSwitch", "GreedySwitch",
    "AutoSwitch",
    "PushPullEngine", "VertexProgram", "EngineResult", "Phase",
    "PhaseProgram",
    "Semiring", "PLUS_TIMES", "MIN_PLUS", "OR_AND", "spmv_pull",
    "spmspv_push",
    "push_relax", "pull_relax", "pull_relax_ell", "k_filter",
    "frontier_out_edges", "frontier_in_edges", "combine_identity",
]
