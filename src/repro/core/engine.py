"""PushPullEngine — the paper's contribution as a composable JAX runtime.

A *vertex program* is (msg_fn, combine, update_fn) plus optional hooks:

    msg_fn(src_value, edge_weight) -> message          (⊗ of §7.1)
    combine ∈ {sum, min, max}                          (⊕ / CRCW-CB)
    update_fn(old_state, combined_msgs, step) -> (new_state, frontier,
                                                  converged)
    values_fn(g, state, frontier) -> wire values       (default: state)
    touched_fn(g, state, frontier, visited) -> bool[n] pull destinations
    local_fn(g, state, frontier, step, do_push, cost)  (non-exchange step:
        -> (state, frontier, converged, cost)           sequential/greedy
                                                        sub-phases, edge
                                                        maps with private
                                                        accumulation)
    tail_fn(g, state, frontier, cost) -> (state, cost) (GreedySwitch
                                                        hand-off, §5-GrS)

Beyond the single flat fixed-point loop, the engine executes
*phase-structured* programs (:class:`PhaseProgram`): a sequence of
:class:`Phase`\\ s — each with its own ``VertexProgram``, step bound, and
carry-rewrite hooks — optionally wrapped in a nested *epoch* loop. This
covers every control shape in the paper:

  * flat fixed point            — BFS, PageRank, WCC, δ-PR (one phase);
  * nested epochs               — Δ-stepping's bucket loop around an
                                  inner relaxation loop (§3.4);
  * forward/backward pairs      — Brandes BC: the backward phase replays
                                  the forward trace (levels/σ) recorded
                                  in the carry (§3.5);
  * per-round contraction       — Borůvka's supervertex relabel as a
                                  second phase per round (§3.7);
  * one-shot edge maps          — triangle counting: a fixed number of
                                  steps, no fixed point (§3.2).

Each step runs as either a push k-relaxation (scatter from the frontier)
or a pull k-relaxation (gather into destinations) under a
DirectionPolicy, with only the chosen direction evaluated at runtime
(``lax.cond``) — and, orthogonally, through a pluggable
:class:`~repro.core.backend.ExchangeBackend` (dense / ELL / distributed).
Before each step of a *switching* policy the engine assembles
:class:`~repro.core.cost_model.StepStats` — frontier size, the frontier's
out-edge sum, the in-edge sum of the program's actual pull destination
set under the backend's actual layout, and the unvisited-edge count —
and hands it to ``policy.decide``; ``AutoSwitch`` prices both directions
with the §4 cost model from exactly these statistics. ``Fixed`` policies
keep their static fast path: only the chosen direction is traced and no
statistics are computed.

Every phase loop carries a real *visited* mask (the union of every
frontier so far), so ``GenericSwitch``'s growing-phase test sees the
actual unvisited edge count, and push steps pay the paper's k-filter
compaction. With ``trace_capacity > 0`` the loop also carries a
:class:`~repro.core.cost_model.StepTrace` recording, per executed step,
the chosen direction, the frontier statistics, and the step's counter
deltas — the raw material for ``BENCH_*.json`` trajectories. ``state``
may be any pytree; it is the only channel between phases and epochs, so
the carry structure must be stable across them.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from ..graphs.structure import Graph
from .backend import DenseBackend, ExchangeBackend
from .cost_model import Cost, StepStats, StepTrace, counter, counter_dtype
from .direction import Direction, DirectionPolicy, Fixed, GreedySwitch
from .primitives import frontier_in_edges, frontier_out_edges, k_filter

__all__ = ["VertexProgram", "Phase", "PhaseProgram", "PushPullEngine",
           "EngineResult", "Checkpoint"]


class Checkpoint(NamedTuple):
    """A stepwise solve's resumable snapshot: the full loop carry after
    ``step`` completed steps. The carry is a device pytree — holding it
    is a reference, not a copy — and resuming re-enters the identical
    jitted body, so a resumed run is bit-identical to an uninterrupted
    one."""
    step: int
    carry: Any


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    combine: str = "sum"
    msg_fn: Optional[Callable] = None
    # update_fn(state, msgs, step) -> (state, frontier, converged)
    update_fn: Callable = None  # type: ignore[assignment]
    # values_fn(g, state, frontier) -> values put on the wire (default:
    # state itself — the label-propagation case)
    values_fn: Optional[Callable] = None
    # what pull inspects: 'all' destinations, or only the 'unvisited' ones
    # (BFS-style programs where settled vertices never update again)
    pull_touched: str = "all"
    # touched_fn(g, state, frontier, visited) -> bool[n]: state-derived
    # pull destination set (Δ-stepping's unsettled set, BC's level masks);
    # overrides pull_touched when set
    touched_fn: Optional[Callable] = None
    # static per-iteration charges, e.g. (("reads", 2 * n),) for reading
    # own state + degree when forming contributions
    step_charges: tuple = ()
    # dynamic per-iteration charges: charge_fn(g, state, frontier) -> dict
    # of traced counter increments (state/frontier are pre-update)
    charge_fn: Optional[Callable] = None
    # charge the paper's k-filter (frontier compaction) after push steps —
    # only meaningful for sparse-frontier programs (BFS); dense programs
    # (PR) never filter, matching the paper's accounting
    k_filter_push: bool = False
    # k_filter_set_fn(old_state, new_state, frontier) -> bool[n]: the set
    # the push k-filter compacts, when it differs from the next frontier
    # (Δ-stepping filters the *updated* vertices; its frontier is the
    # whole re-activated bucket). Default: the frontier itself.
    k_filter_set_fn: Optional[Callable] = None
    # GreedySwitch terminal hand-off (paper §5-GrS): invoked once when the
    # active set drops below the policy's tail threshold
    tail_fn: Optional[Callable] = None
    # local_fn(g, state, frontier, step, do_push, cost)
    #   -> (state, frontier, converged, cost)
    # replaces the relax+update step entirely: the step never touches the
    # exchange backend (partition-sequential coloring, Borůvka's find-min
    # over contracted supervertices, blocked triangle edge maps). The
    # decided direction still arrives as `do_push` so the step can charge
    # the paper's direction-dependent cost.
    local_fn: Optional[Callable] = None


@dataclasses.dataclass(frozen=True)
class Phase:
    """One fixed-point (or bounded) loop inside a program.

    enter_fn(g, state, frontier, epoch) -> (state, frontier) rewrites the
        carry before the phase's first step (Δ-stepping's bucket frontier,
        BC's per-source reset / backward-level seeding).
    exit_fn(g, state, frontier, cost) -> (state, frontier, cost) runs
        after the phase's loop (contraction, trace post-processing).
    """
    program: VertexProgram
    max_steps: int = 100
    name: str = ""
    enter_fn: Optional[Callable] = None
    exit_fn: Optional[Callable] = None


@dataclasses.dataclass(frozen=True)
class PhaseProgram:
    """A sequence of phases, optionally iterated as epochs.

    epoch_cond(g, state, epoch) -> bool: run another epoch? (checked
        before each epoch; None = run exactly ``max_epochs``).
    epoch_exit_fn(g, state, frontier, epoch) -> (state, frontier): carry
        rewrite after each epoch (BC's per-source accumulation, Borůvka's
        component relabel when not expressed as a phase).
    max_epochs: epoch bound; None defers to the engine's ``max_steps``.
    """
    phases: tuple
    max_epochs: Optional[int] = None
    epoch_cond: Optional[Callable] = None
    epoch_exit_fn: Optional[Callable] = None


class EngineResult(NamedTuple):
    state: Any
    cost: Cost
    steps: jax.Array
    push_steps: jax.Array
    converged: jax.Array = jnp.bool_(True)
    epochs: jax.Array = jnp.int32(1)
    trace: Any = None            # StepTrace when trace_capacity > 0
    # final backend exchange-carried state (e.g. the compression
    # error-feedback accumulator); () for stateless backends — surfaced
    # so telemetry can report compression residuals post-run
    xstate: Any = ()


class _Loop(NamedTuple):
    state: Any
    frontier: jax.Array
    visited: jax.Array
    converged: jax.Array
    handoff: jax.Array
    step: jax.Array
    cost: Cost
    pushes: jax.Array
    last_push: jax.Array
    trace: StepTrace
    # backend exchange-carried state (compression error feedback); an
    # empty pytree for stateless backends
    xstate: Any = ()


@dataclasses.dataclass(frozen=True)
class PushPullEngine:
    program: Union[VertexProgram, PhaseProgram]
    policy: DirectionPolicy = Fixed(Direction.PULL)
    max_steps: int = 100
    backend: ExchangeBackend = DenseBackend()
    # > 0 allocates a StepTrace of that many slots and records every
    # executed step into it (overflow steps are dropped); 0 = no tracing,
    # no overhead
    trace_capacity: int = 0

    def _step_stats(self, g: Graph, prog: VertexProgram, st: _Loop,
                    unvisited, touched, values) -> StepStats:
        """The decision inputs for this step — §4's quantities, computed
        from degree sums only (no edge traversal)."""
        # what THIS backend's pull would actually traverse — full scan
        # (m, n) for scan-all backends, the frontier restriction for
        # backends that can gather only touched rows
        pull_edges, pull_vertices = self.backend.predict_pull_scan(
            g, touched, values=values, combine=prog.combine,
            msg_fn=prog.msg_fn)
        # the layout-independent Σ in-degree over touched destinations
        # (what an ideal CSR pull would read), kept for analysis
        pull_touched = (counter(g.m) if touched is None
                        else frontier_in_edges(g, touched))
        float_data = bool(values is not None
                          and jnp.issubdtype(values.dtype, jnp.floating))
        # payload elements per vertex on the wire — B for batched
        # multi-query values [n, B] (repro.service), 1 for plain vectors
        width = (1 if values is None or values.ndim == 1
                 else int(values.shape[-1]))
        # inter-device bytes each direction would move (0 on one device)
        push_wb = pull_wb = counter(0)
        if values is not None:
            push_wb, pull_wb = self.backend.predict_comm_bytes(
                g, values, st.frontier)
        return StepStats(
            frontier_vertices=jnp.sum(
                st.frontier.astype(counter_dtype())),
            frontier_edges=frontier_out_edges(g, st.frontier),
            pull_edges=pull_edges, pull_vertices=pull_vertices,
            unvisited_edges=frontier_in_edges(g, unvisited),
            step=st.step, prev_push=st.last_push,
            float_data=float_data, k_filter_push=prog.k_filter_push,
            width=width, push_wire_bytes=push_wb, pull_wire_bytes=pull_wb,
            pull_touched_edges=pull_touched)

    # -- one phase: the classic fixed-point loop --------------------------
    def _phase_loop(self, g: Graph, phase: Phase, state0, frontier0,
                    epoch, cost0: Cost, steps0, pushes0,
                    trace0: StepTrace, xstate0=()):
        """Build one phase's ``(cond, body, init)`` loop pieces.

        ``_run_phase`` feeds them to ``lax.while_loop``;
        :meth:`run_stepwise` instead jits ``body`` once and drives it
        from the host, blocking between steps so telemetry can stamp
        per-step wall times — same closures, same arithmetic, same
        result."""
        prog = phase.program
        values_fn = prog.values_fn or (lambda g_, s, f: s)
        greedy = (isinstance(self.policy, GreedySwitch)
                  and prog.tail_fn is not None)
        # Fixed policies dispatch statically: only the chosen direction is
        # traced/compiled (switching policies pay the lax.cond).
        fixed_dir = (self.policy.direction
                     if isinstance(self.policy, Fixed) else None)
        tracing = self.trace_capacity > 0
        predictor = self.policy.trace_predictor() if tracing else None

        if phase.enter_fn is not None:
            state0, frontier0 = phase.enter_fn(g, state0, frontier0, epoch)

        def cond(st: _Loop):
            return ((~st.converged) & (~st.handoff)
                    & (st.step < phase.max_steps))

        def body(st: _Loop):
            unvisited = ~st.visited
            # the program's pull destination set and wire values are
            # direction-independent, so they can inform the decision
            if prog.local_fn is not None:
                values = touched = None
            else:
                values = values_fn(g, st.state, st.frontier)
                if prog.touched_fn is not None:
                    touched = prog.touched_fn(g, st.state, st.frontier,
                                              st.visited)
                elif prog.pull_touched == "unvisited":
                    touched = unvisited
                else:
                    touched = None
            stats = (self._step_stats(g, prog, st, unvisited, touched,
                                      values)
                     if (fixed_dir is None or tracing) else None)
            if fixed_dir is not None:
                direction = fixed_dir
                do_push = jnp.bool_(fixed_dir == Direction.PUSH)
            else:
                direction = do_push = self.policy.decide(
                    g, st.frontier, stats)
            cost = st.cost
            xstate = st.xstate
            if prog.local_fn is not None:
                state, frontier, conv, cost = prog.local_fn(
                    g, st.state, st.frontier, st.step, do_push, cost)
            else:
                msgs, cost, xstate = self.backend.relax_ex(
                    g, values, st.frontier, direction=direction,
                    combine=prog.combine, msg_fn=prog.msg_fn,
                    touched=touched, cost=cost, xstate=xstate)
                state, frontier, conv = prog.update_fn(st.state, msgs,
                                                       st.step)
                if prog.k_filter_push:
                    # push produced a sparse updated set -> k-filter
                    # compacts it (paper: pull inspects every vertex)
                    kf_set = (frontier if prog.k_filter_set_fn is None
                              else prog.k_filter_set_fn(st.state, state,
                                                        frontier))
                    _, cost = jax.lax.cond(
                        do_push, k_filter, lambda f, c: (f, c), kf_set,
                        cost)
            cost = cost.charge(iterations=1, barriers=1,
                               **dict(prog.step_charges))
            if prog.charge_fn is not None:
                cost = cost.charge(**prog.charge_fn(g, st.state,
                                                    st.frontier))
            handoff = st.handoff
            if greedy:
                active = jnp.sum(frontier.astype(counter_dtype()))
                handoff = (~conv) & self.policy.should_handoff(g, active)
            trace = st.trace
            if tracing:
                delta = jax.tree.map(lambda a, b: a - b, cost, st.cost)
                trace = st.trace.record(
                    steps0 + st.step, do_push, stats, delta,
                    predicted_push=predictor.predict_push(stats),
                    predicted_pull=predictor.predict_pull(stats))
            return _Loop(state=state, frontier=frontier,
                         visited=st.visited | frontier, converged=conv,
                         handoff=handoff, step=st.step + 1, cost=cost,
                         pushes=st.pushes + do_push.astype(jnp.int32),
                         last_push=do_push, trace=trace, xstate=xstate)

        # an empty entering frontier is already converged (matches the
        # seed loops, whose cond checked the frontier before any work)
        init = _Loop(state=state0, frontier=frontier0, visited=frontier0,
                     converged=~jnp.any(frontier0),
                     handoff=jnp.bool_(False), step=jnp.int32(0),
                     cost=cost0, pushes=jnp.int32(0),
                     last_push=jnp.bool_(False), trace=trace0,
                     xstate=xstate0)
        return cond, body, init

    def _finish_phase(self, g: Graph, phase: Phase, fin: _Loop, steps0,
                      pushes0):
        """Post-loop phase epilogue: greedy tail hand-off and exit_fn."""
        prog = phase.program
        greedy = (isinstance(self.policy, GreedySwitch)
                  and prog.tail_fn is not None)
        state, frontier, cost = fin.state, fin.frontier, fin.cost
        converged = fin.converged
        if greedy:
            state, cost = jax.lax.cond(
                fin.handoff,
                lambda s, f, c: prog.tail_fn(g, s, f, c),
                lambda s, f, c: (s, c),
                fin.state, fin.frontier, fin.cost)
            converged = converged | fin.handoff
        if phase.exit_fn is not None:
            state, frontier, cost = phase.exit_fn(g, state, frontier, cost)
        return (state, frontier, cost, steps0 + fin.step,
                pushes0 + fin.pushes, converged, fin.trace, fin.xstate)

    def _run_phase(self, g: Graph, phase: Phase, state0, frontier0, epoch,
                   cost0: Cost, steps0, pushes0, trace0: StepTrace,
                   xstate0=()):
        cond, body, init = self._phase_loop(
            g, phase, state0, frontier0, epoch, cost0, steps0, pushes0,
            trace0, xstate0)
        fin = jax.lax.while_loop(cond, body, init)
        return self._finish_phase(g, phase, fin, steps0, pushes0)

    # -- the full program: phases under an epoch loop ---------------------
    @partial(jax.jit, static_argnames=("self",))
    def run(self, g: Graph, init_state: Any,
            init_frontier: jax.Array) -> EngineResult:
        if isinstance(self.program, PhaseProgram):
            pp = self.program
            phases = tuple(pp.phases)
            max_epochs = (self.max_steps if pp.max_epochs is None
                          else pp.max_epochs)
            epoch_cond, epoch_exit = pp.epoch_cond, pp.epoch_exit_fn
        else:
            phases = (Phase(program=self.program,
                            max_steps=self.max_steps),)
            max_epochs, epoch_cond, epoch_exit = 1, None, None

        trace0 = StepTrace.empty(self.trace_capacity)
        xstate0 = self.backend.init_exchange_state(g)

        def run_epoch(state, frontier, epoch, cost, steps, pushes, trace,
                      xstate):
            conv = jnp.bool_(True)
            for ph in phases:         # statically unrolled: phases differ
                (state, frontier, cost, steps, pushes, conv, trace,
                 xstate) = self._run_phase(g, ph, state, frontier, epoch,
                                           cost, steps, pushes, trace,
                                           xstate)
            if epoch_exit is not None:
                state, frontier = epoch_exit(g, state, frontier, epoch)
            return state, frontier, cost, steps, pushes, conv, trace, \
                xstate

        def result(state, cost, steps, pushes, converged, epochs, trace,
                   xstate=()):
            return EngineResult(
                state=state, cost=cost, steps=steps, push_steps=pushes,
                converged=converged, epochs=epochs,
                trace=trace if self.trace_capacity > 0 else None,
                xstate=xstate)

        if max_epochs == 1 and epoch_cond is None:
            # single-epoch programs (the PR-1 algorithms) skip the outer
            # loop entirely — same trace as the old flat engine
            state, frontier, cost, steps, pushes, conv, trace, xs = \
                run_epoch(init_state, init_frontier, jnp.int32(0), Cost(),
                          jnp.int32(0), jnp.int32(0), trace0, xstate0)
            return result(state, cost, steps, pushes, conv, jnp.int32(1),
                          trace, xs)

        def cond(carry):
            (state, frontier, epoch, cost, steps, pushes, conv,
             trace, xstate) = carry
            go = epoch < max_epochs
            if epoch_cond is not None:
                go = go & epoch_cond(g, state, epoch)
            return go

        def body(carry):
            (state, frontier, epoch, cost, steps, pushes, _, trace,
             xstate) = carry
            state, frontier, cost, steps, pushes, conv, trace, xstate = \
                run_epoch(state, frontier, epoch, cost, steps, pushes,
                          trace, xstate)
            return (state, frontier, epoch + 1, cost, steps, pushes, conv,
                    trace, xstate)

        init = (init_state, init_frontier, jnp.int32(0), Cost(),
                jnp.int32(0), jnp.int32(0), jnp.bool_(True), trace0,
                xstate0)
        state, frontier, epochs, cost, steps, pushes, conv, trace, xs = \
            jax.lax.while_loop(cond, body, init)
        if epoch_cond is not None:
            # converged iff the work test (not the epoch bound) ended it
            converged = ~epoch_cond(g, state, epochs)
        else:
            converged = conv
        return result(state, cost, steps, pushes, converged, epochs,
                      trace, xs)

    # -- host-driven stepwise execution (telemetry timing path) -----------
    @property
    def supports_stepwise(self) -> bool:
        """True when :meth:`run_stepwise` can execute this program —
        flat (single-phase, single-epoch) programs only."""
        return not isinstance(self.program, PhaseProgram)

    @staticmethod
    def _check_finite(state: Any, mode, step: int) -> None:
        """Abort on non-finite float state: ``mode`` ``"nan"`` trips on
        NaN only (the default — BFS/SSSP legitimately carry ±Inf
        sentinels), ``"all"``/True on NaN or ±Inf. Raises the
        structured :class:`repro.resilience.DivergenceError` naming the
        step, instead of burning the remaining ``max_steps`` budget on
        poisoned values."""
        from ..resilience import DivergenceError
        strict = mode in ("all", True)
        for leaf in jax.tree_util.tree_leaves(state):
            if not (hasattr(leaf, "dtype")
                    and jnp.issubdtype(leaf.dtype, jnp.floating)):
                continue
            bad = (not bool(jnp.isfinite(leaf).all()) if strict
                   else bool(jnp.isnan(leaf).any()))
            if bad:
                raise DivergenceError(
                    step=step, mode="all" if strict else "nan")

    def run_stepwise(self, g: Graph, init_state: Any,
                     init_frontier: jax.Array,
                     on_step: Optional[Callable] = None,
                     check_finite=None,
                     checkpoint_every: int = 0,
                     resume_from: Optional[Checkpoint] = None
                     ) -> EngineResult:
        """Run a flat program one step at a time from the host.

        Semantically identical to :meth:`run` — the loop body is the
        same closure ``_phase_loop`` hands to ``lax.while_loop``, jitted
        once and called repeatedly — but the host blocks on every step
        (``jax.block_until_ready``), so ``on_step(step_index,
        wall_us)`` observes a real per-step wall time. This is the
        telemetry timing path: the jitted-loop path cannot see host
        timestamps at step boundaries from inside ``lax.while_loop``.

        The host loop is also where the resilience guards live:

        * ``check_finite``: ``"nan"``/True/``"all"`` enables the
          divergence detector (:meth:`_check_finite`) after every step.
        * ``checkpoint_every=N``: snapshot the loop carry every N
          completed steps. A failure mid-loop (an injected
          ``engine.step`` fault, a poisoned device buffer) then raises
          :class:`~repro.resilience.SolveInterrupted` carrying the last
          :class:`Checkpoint` instead of losing the run.
        * ``resume_from``: re-enter the loop from a checkpoint; the
          remaining steps replay the identical jitted body, so the
          final result is bit-identical to an uninterrupted run.

        The same ops run in the same order, so results are bit-identical
        to :meth:`run` (deterministic backends). Each call re-traces the
        step body (one compile per call); use :meth:`run` when timing is
        not needed.

        Raises:
            ValueError: for :class:`PhaseProgram` programs — their
                epoch/phase structure runs under :meth:`run`.
            DivergenceError: ``check_finite`` tripped.
            SolveInterrupted: the loop died with ``checkpoint_every``
                set (or on an injected ``engine.step`` fault).
        """
        if not self.supports_stepwise:
            raise ValueError(
                "run_stepwise executes flat (single-VertexProgram) "
                "programs only; phase-structured programs run under "
                "run() — check supports_stepwise before dispatching")
        import time

        from ..resilience import (DivergenceError, SolveInterrupted,
                                  fault_point)
        phase = Phase(program=self.program, max_steps=self.max_steps)
        trace0 = StepTrace.empty(self.trace_capacity)
        xstate0 = self.backend.init_exchange_state(g)
        cond, body, init = self._phase_loop(
            g, phase, init_state, init_frontier, jnp.int32(0), Cost(),
            jnp.int32(0), jnp.int32(0), trace0, xstate0)
        body_j = jax.jit(body)
        st, i = init, 0
        last_ckpt = resume_from
        if resume_from is not None:
            st, i = resume_from.carry, resume_from.step
        if on_step is not None and bool(cond(st)):
            # pay tracing/compilation outside the timed loop (the body is
            # pure, so a discarded warmup execution is free of effects) —
            # otherwise step 0's wall time is dominated by the compile
            # and the decision audit flags it spuriously
            jax.block_until_ready(body_j(st))
        while True:
            try:
                fault_point("engine.step")
                if not bool(cond(st)):
                    break
                t0 = time.perf_counter()
                nxt = body_j(st)
                jax.block_until_ready(nxt)
                dt_us = (time.perf_counter() - t0) * 1e6
            except (DivergenceError, SolveInterrupted):
                raise
            except Exception as exc:  # noqa: BLE001 — resumable seam
                raise SolveInterrupted(step=i,
                                       checkpoint=last_ckpt) from exc
            st = nxt
            i += 1
            if check_finite:
                self._check_finite(st.state, check_finite, i - 1)
            if checkpoint_every and i % checkpoint_every == 0:
                last_ckpt = Checkpoint(step=i, carry=st)
            if on_step is not None:
                on_step(i - 1, dt_us)
        state, frontier, cost, steps, pushes, conv, trace, xs = \
            self._finish_phase(g, phase, st, jnp.int32(0), jnp.int32(0))
        return EngineResult(
            state=state, cost=cost, steps=steps, push_steps=pushes,
            converged=conv, epochs=jnp.int32(1),
            trace=trace if self.trace_capacity > 0 else None, xstate=xs)
