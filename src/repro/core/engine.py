"""PushPullEngine — the paper's contribution as a composable JAX module.

A *vertex program* is (msg_fn, combine, update_fn):

    msg_fn(src_value, edge_weight) -> message          (⊗ of §7.1)
    combine ∈ {sum, min, max}                          (⊕ / CRCW-CB)
    update_fn(old_state, combined_msgs, step) -> (new_state, frontier)

The engine runs it to a fixed point (or `max_steps`) under a
DirectionPolicy, executing each step as either a push k-relaxation
(scatter from the frontier) or a pull k-relaxation (gather into all
vertices), with only the chosen direction evaluated at runtime
(`lax.cond`). Everything the framework's GNN layers and graph algorithms
need reduces to this loop; PR/BFS/etc. in `algorithms/` are hand-tuned
instances with richer carries.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..graphs.structure import Graph
from .cost_model import Cost
from .direction import DirectionPolicy, Fixed, Direction
from .primitives import frontier_in_edges, pull_relax, push_relax

__all__ = ["VertexProgram", "PushPullEngine", "EngineResult"]


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    combine: str
    msg_fn: Optional[Callable] = None
    # update_fn(state, msgs, step) -> (state, frontier, converged)
    update_fn: Callable = None  # type: ignore[assignment]


class EngineResult(NamedTuple):
    state: jax.Array
    cost: Cost
    steps: jax.Array
    push_steps: jax.Array


@dataclasses.dataclass(frozen=True)
class PushPullEngine:
    program: VertexProgram
    policy: DirectionPolicy = Fixed(Direction.PULL)
    max_steps: int = 100

    @partial(jax.jit, static_argnames=("self",))
    def run(self, g: Graph, init_state: jax.Array,
            init_frontier: jax.Array) -> EngineResult:
        prog = self.program

        def cond(st):
            _state, _frontier, conv, step, *_ = st
            return (~conv) & (step < self.max_steps)

        def body(st):
            state, frontier, _conv, step, cost, pushes = st
            unvisited_edges = frontier_in_edges(g, jnp.ones((g.n,), bool))
            do_push = self.policy.decide_push(g, frontier, unvisited_edges)
            msgs, cost = jax.lax.cond(
                do_push,
                lambda s, f, c: push_relax(g, s, f, combine=prog.combine,
                                           msg_fn=prog.msg_fn, cost=c),
                lambda s, f, c: pull_relax(g, s, combine=prog.combine,
                                           msg_fn=prog.msg_fn, cost=c),
                state, frontier, cost)
            state, frontier, conv = prog.update_fn(state, msgs, step)
            cost = cost.charge(iterations=1, barriers=1)
            return (state, frontier, conv, step + 1, cost,
                    pushes + do_push.astype(jnp.int32))

        init = (init_state, init_frontier, jnp.bool_(False), jnp.int32(0),
                Cost(), jnp.int32(0))
        state, _, _, steps, cost, pushes = jax.lax.while_loop(
            cond, body, init)
        return EngineResult(state=state, cost=cost, steps=steps,
                            push_steps=pushes)
