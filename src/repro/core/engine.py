"""PushPullEngine — the paper's contribution as a composable JAX module.

A *vertex program* is (msg_fn, combine, update_fn) plus optional hooks:

    msg_fn(src_value, edge_weight) -> message          (⊗ of §7.1)
    combine ∈ {sum, min, max}                          (⊕ / CRCW-CB)
    update_fn(old_state, combined_msgs, step) -> (new_state, frontier,
                                                  converged)
    values_fn(g, state, frontier) -> wire values       (default: state)
    tail_fn(g, state, frontier, cost) -> (state, cost) (GreedySwitch
                                                        hand-off, §5-GrS)

The engine runs the program to a fixed point (or ``max_steps``) under a
DirectionPolicy, executing each step as either a push k-relaxation
(scatter from the frontier) or a pull k-relaxation (gather into
destinations), with only the chosen direction evaluated at runtime
(``lax.cond``) — and, orthogonally, through a pluggable
:class:`~repro.core.backend.ExchangeBackend` (dense / ELL / distributed).

The loop carries a real *visited* mask (the union of every frontier so
far), so ``GenericSwitch``'s growing-phase test sees the actual
unvisited edge count instead of the total edge count, and push steps pay
the paper's k-filter compaction. ``state`` may be any pytree.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..graphs.structure import Graph
from .backend import DenseBackend, ExchangeBackend
from .cost_model import Cost
from .direction import Direction, DirectionPolicy, Fixed, GreedySwitch
from .primitives import frontier_in_edges, k_filter

__all__ = ["VertexProgram", "PushPullEngine", "EngineResult"]


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    combine: str
    msg_fn: Optional[Callable] = None
    # update_fn(state, msgs, step) -> (state, frontier, converged)
    update_fn: Callable = None  # type: ignore[assignment]
    # values_fn(g, state, frontier) -> values put on the wire (default:
    # state itself — the label-propagation case)
    values_fn: Optional[Callable] = None
    # what pull inspects: 'all' destinations, or only the 'unvisited' ones
    # (BFS-style programs where settled vertices never update again)
    pull_touched: str = "all"
    # static per-iteration charges, e.g. (("reads", 2 * n),) for reading
    # own state + degree when forming contributions
    step_charges: tuple = ()
    # dynamic per-iteration charges: charge_fn(g, state, frontier) -> dict
    # of traced counter increments (state/frontier are pre-update)
    charge_fn: Optional[Callable] = None
    # charge the paper's k-filter (frontier compaction) after push steps —
    # only meaningful for sparse-frontier programs (BFS); dense programs
    # (PR) never filter, matching the paper's accounting
    k_filter_push: bool = False
    # GreedySwitch terminal hand-off (paper §5-GrS): invoked once when the
    # active set drops below the policy's tail threshold
    tail_fn: Optional[Callable] = None


class EngineResult(NamedTuple):
    state: Any
    cost: Cost
    steps: jax.Array
    push_steps: jax.Array
    converged: jax.Array = jnp.bool_(True)


class _Loop(NamedTuple):
    state: Any
    frontier: jax.Array
    visited: jax.Array
    converged: jax.Array
    handoff: jax.Array
    step: jax.Array
    cost: Cost
    pushes: jax.Array


@dataclasses.dataclass(frozen=True)
class PushPullEngine:
    program: VertexProgram
    policy: DirectionPolicy = Fixed(Direction.PULL)
    max_steps: int = 100
    backend: ExchangeBackend = DenseBackend()

    @partial(jax.jit, static_argnames=("self",))
    def run(self, g: Graph, init_state: Any,
            init_frontier: jax.Array) -> EngineResult:
        prog = self.program
        values_fn = prog.values_fn or (lambda g_, s, f: s)
        greedy = (isinstance(self.policy, GreedySwitch)
                  and prog.tail_fn is not None)
        # Fixed policies dispatch statically: only the chosen direction is
        # traced/compiled (switching policies pay the lax.cond).
        fixed_dir = (self.policy.direction
                     if isinstance(self.policy, Fixed) else None)

        def cond(st: _Loop):
            return (~st.converged) & (~st.handoff) & (st.step < self.max_steps)

        def body(st: _Loop):
            unvisited = ~st.visited
            if fixed_dir is not None:
                direction = fixed_dir
                do_push = jnp.bool_(fixed_dir == Direction.PUSH)
            else:
                unvisited_edges = frontier_in_edges(g, unvisited)
                direction = do_push = self.policy.decide_push(
                    g, st.frontier, unvisited_edges)
            values = values_fn(g, st.state, st.frontier)
            touched = unvisited if prog.pull_touched == "unvisited" else None
            msgs, cost = self.backend.relax(
                g, values, st.frontier, direction=direction,
                combine=prog.combine, msg_fn=prog.msg_fn, touched=touched,
                cost=st.cost)
            state, frontier, conv = prog.update_fn(st.state, msgs, st.step)
            if prog.k_filter_push:
                # push produced a sparse updated set -> k-filter compacts
                # it (paper: pull inspects every vertex anyway)
                _, cost = jax.lax.cond(
                    do_push, k_filter, lambda f, c: (f, c), frontier, cost)
            cost = cost.charge(iterations=1, barriers=1,
                               **dict(prog.step_charges))
            if prog.charge_fn is not None:
                cost = cost.charge(**prog.charge_fn(g, st.state,
                                                    st.frontier))
            handoff = st.handoff
            if greedy:
                active = jnp.sum(frontier.astype(jnp.int64))
                handoff = (~conv) & self.policy.should_handoff(g, active)
            return _Loop(state=state, frontier=frontier,
                         visited=st.visited | frontier, converged=conv,
                         handoff=handoff, step=st.step + 1, cost=cost,
                         pushes=st.pushes + do_push.astype(jnp.int32))

        # an empty initial frontier is already converged (matches the
        # seed loops, whose cond checked the frontier before any work)
        init = _Loop(state=init_state, frontier=init_frontier,
                     visited=init_frontier,
                     converged=~jnp.any(init_frontier),
                     handoff=jnp.bool_(False), step=jnp.int32(0),
                     cost=Cost(), pushes=jnp.int32(0))
        fin = jax.lax.while_loop(cond, body, init)

        state, cost, converged = fin.state, fin.cost, fin.converged
        if greedy:
            state, cost = jax.lax.cond(
                fin.handoff,
                lambda s, f, c: prog.tail_fn(g, s, f, c),
                lambda s, f, c: (s, c),
                fin.state, fin.frontier, fin.cost)
            converged = converged | fin.handoff
        return EngineResult(state=state, cost=cost, steps=fin.step,
                            push_steps=fin.pushes, converged=converged)
