"""Direction selection: push / pull / auto (paper §3.8, §5 GS/GrS).

`DirectionPolicy` implements the paper's switching strategies as pure
functions of cheap frontier statistics, so they can run inside jitted
loops (`lax.cond` on the boolean they return):

  * ``Fixed``            — always push or always pull.
  * ``GenericSwitch``    — the direction-optimizing heuristic (Beamer's
    BFS rule generalized per paper §5-GS): push while the frontier's
    incident out-edges are below ``alpha * m`` (sparse frontier ⇒ push does
    less work), pull once the frontier densifies; switch back for the
    shrinking tail using ``beta * n``.
  * ``GreedySwitch``     — GS plus a terminal hand-off: when the active set
    drops below ``tail_frac * n`` the algorithm exits the parallel loop and
    a sequential/greedy tail finishes the job (paper §5-GrS; the tail
    runner is supplied by each algorithm).
  * ``AutoSwitch``       — cost-model-driven: each step the engine hands
    the policy :class:`~repro.core.cost_model.StepStats` (frontier size,
    out/in-degree sums of the active set, backend layout facts) and a
    :class:`~repro.core.cost_model.CostPredictor` prices a push step vs a
    pull step in the paper's §4 counter categories; the cheaper predicted
    direction wins, with hysteresis so a marginal difference never
    thrashes the direction back and forth.

The engine calls :meth:`DirectionPolicy.decide` (stats-based); the legacy
:meth:`decide_push` surface remains for policies written against PR-1/2
and for direct use in notebooks.
"""

from __future__ import annotations

import dataclasses
import enum

import jax
import jax.numpy as jnp

from ..graphs.structure import Graph
from .cost_model import CostPredictor, StepStats
from .primitives import frontier_out_edges

__all__ = ["Direction", "Fixed", "GenericSwitch", "GreedySwitch",
           "AutoSwitch", "DirectionPolicy"]


class Direction(enum.Enum):
    PUSH = "push"
    PULL = "pull"
    AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class DirectionPolicy:
    """Per-step direction chooser — the strategy axis of ``api.solve``.

    Subclasses implement :meth:`decide` (or the legacy
    :meth:`decide_push`): a pure, trace-friendly function returning a
    boolean scalar (True = push) that the engine feeds to ``lax.cond``.

        >>> from repro.core import GenericSwitch
        >>> r = api.solve(g, "bfs", root=0,
        ...               policy=GenericSwitch())    # doctest: +SKIP

    ``api.solve`` also accepts the string shorthands ``"push"``,
    ``"pull"``, ``"gs"``, ``"grs"``, and ``"auto"`` in place of an
    instance.
    """

    def decide_push(self, g: Graph, frontier: jax.Array,
                    unvisited_edges: jax.Array) -> jax.Array:
        """Legacy surface: decide from (frontier, unvisited edges)."""
        raise NotImplementedError

    def decide(self, g: Graph, frontier: jax.Array,
               stats: StepStats) -> jax.Array:
        """Decide from the engine's full :class:`StepStats`.

        Default delegates to :meth:`decide_push`, so policies written
        against the PR-1 interface keep working unchanged.
        """
        return self.decide_push(g, frontier, stats.unvisited_edges)

    def trace_predictor(self) -> CostPredictor:
        """The cost model whose push/pull prices land in
        :class:`~repro.core.cost_model.StepTrace` slots when tracing.

        Policies that *are* predictor-driven (``AutoSwitch``) return
        their own predictor, so traces audit the exact numbers the
        decision compared; everything else gets a default-weight
        :class:`CostPredictor`, so even Fixed/GS traces carry the
        counterfactual prices the obs decision audit reports against.
        """
        return CostPredictor()

    @property
    def name(self) -> str:
        return type(self).__name__


@dataclasses.dataclass(frozen=True)
class Fixed(DirectionPolicy):
    """Always run one direction — the paper's baseline columns.

        >>> api.solve(g, "pagerank", iters=20,
        ...           policy=Fixed(Direction.PULL))  # doctest: +SKIP

    ``Fixed(Direction.AUTO)`` is rejected: "auto" is a switching
    strategy (use ``AutoSwitch()`` / ``policy="auto"``), not a direction
    a fixed policy can run.
    """
    direction: Direction = Direction.PUSH

    def __post_init__(self):
        if self.direction == Direction.AUTO:
            raise ValueError(
                "Fixed(Direction.AUTO) is not a policy: Fixed always runs "
                "one direction. Use AutoSwitch() (or GenericSwitch() / "
                "GreedySwitch()) for automatic direction optimization.")

    def decide_push(self, g, frontier, unvisited_edges):
        return jnp.asarray(self.direction == Direction.PUSH)

    @property
    def name(self) -> str:
        return self.direction.value


@dataclasses.dataclass(frozen=True)
class GenericSwitch(DirectionPolicy):
    """Beamer-style direction optimization (paper §5-GS).

    push iff  m_frontier < unvisited_edges / alpha   (growing phase)
          or  m_frontier < m / beta                  (shrinking tail).
    Defaults follow Beamer et al. (alpha=14, beta=24).

        >>> api.solve(g, "bfs", root=0, policy="gs")  # doctest: +SKIP
    """
    alpha: float = 14.0
    beta: float = 24.0

    def decide_push(self, g, frontier, unvisited_edges):
        mf = frontier_out_edges(g, frontier)
        grow_push = mf * self.alpha < unvisited_edges
        tail_push = mf * self.beta < g.m
        return grow_push | tail_push


@dataclasses.dataclass(frozen=True)
class GreedySwitch(DirectionPolicy):
    """GS + terminal greedy hand-off once the active set is tiny
    (paper §5-GrS). Algorithms opt in by supplying a ``tail_fn``; without
    one the policy behaves exactly like its inner ``GenericSwitch``.

        >>> api.solve(g, "wcc", policy="grs")         # doctest: +SKIP
    """
    inner: GenericSwitch = dataclasses.field(default_factory=GenericSwitch)
    tail_frac: float = 0.001

    def decide_push(self, g, frontier, unvisited_edges):
        return self.inner.decide_push(g, frontier, unvisited_edges)

    def should_handoff(self, g: Graph, active_count: jax.Array) -> jax.Array:
        return active_count < jnp.maximum(1, int(self.tail_frac * g.n))


@dataclasses.dataclass(frozen=True)
class AutoSwitch(DirectionPolicy):
    """Cost-model-driven direction optimization.

    Where ``GenericSwitch`` hard-codes Beamer's BFS thresholds, AutoSwitch
    prices both directions with the §4 cost model each step and takes the
    cheaper one:

        push ≈ k·(read + write + combining)   over the frontier's k
                                              incident out-edges
        pull ≈ in-edges(touched)·read + |touched|·write

    using the *program's* actual pull destination set and the *backend's*
    actual layout (ELL pull scans all m edges), both delivered by the
    engine in :class:`~repro.core.cost_model.StepStats`. Because the
    engine charges the same formulas after the step, the prediction is
    exact for exchange steps: with ``hysteresis=1.0`` every step takes
    the per-step optimum, so the counter total is provably never worse
    than the better of Fixed(PUSH)/Fixed(PULL) on a fixed frontier
    trajectory.

    ``hysteresis`` > 1 keeps the current direction unless the other side
    is cheaper by that factor, so near-ties don't thrash (a direction
    flip costs a layout change on real hardware even though the counter
    model prices it at zero). It also relaxes the bound: each step is
    then only within that factor of the per-step optimum, so the default
    of 1.1 trades a ≤10% worst-case slack for decision stability.

        >>> r = api.solve(g, "bfs", root=0, policy="auto")  # doctest: +SKIP
        >>> r = api.solve(g, "bfs", root=0,
        ...               policy=AutoSwitch(hysteresis=1.5))  # doctest: +SKIP
    """
    predictor: CostPredictor = CostPredictor()
    hysteresis: float = 1.1

    def predict(self, stats: StepStats) -> tuple[jax.Array, jax.Array]:
        """(predicted push cost, predicted pull cost) for this step."""
        return (self.predictor.predict_push(stats),
                self.predictor.predict_pull(stats))

    def trace_predictor(self) -> CostPredictor:
        return self.predictor

    def decide(self, g, frontier, stats: StepStats):
        pp, pl = self.predict(stats)
        pp = pp.astype(jnp.float64 if jax.config.jax_enable_x64
                       else jnp.float32)
        pl = pl.astype(pp.dtype)
        # the first step has no incumbent: compare raw predictions
        h = jnp.where(stats.step == 0, 1.0, self.hysteresis)
        pp_eff = jnp.where(stats.prev_push, pp, pp * h)
        pl_eff = jnp.where(stats.prev_push, pl * h, pl)
        return pp_eff < pl_eff

    def decide_push(self, g, frontier, unvisited_edges):
        # degraded legacy surface: price pull as the unvisited scan (the
        # BFS-style case) and compare raw predictions — there is no real
        # incumbent here, so step=0 routes around the hysteresis; exact
        # stats arrive via decide()
        mf = frontier_out_edges(g, frontier)
        stats = StepStats(
            frontier_vertices=jnp.sum(frontier), frontier_edges=mf,
            pull_edges=unvisited_edges, pull_vertices=jnp.sum(~frontier),
            unvisited_edges=unvisited_edges, step=jnp.int32(0),
            prev_push=jnp.bool_(False))
        return self.decide(g, frontier, stats)
