"""Direction selection: push / pull / auto (paper §3.8, §5 GS/GrS).

`DirectionPolicy` implements the paper's switching strategies as pure
functions of cheap frontier statistics, so they can run inside jitted
loops (`lax.cond` on the boolean they return):

  * ``Fixed``            — always push or always pull.
  * ``GenericSwitch``    — the direction-optimizing heuristic (Beamer's
    BFS rule generalized per paper §5-GS): push while the frontier's
    incident out-edges are below ``alpha * m`` (sparse frontier ⇒ push does
    less work), pull once the frontier densifies; switch back for the
    shrinking tail using ``beta * n``.
  * ``GreedySwitch``     — GS plus a terminal hand-off: when the active set
    drops below ``tail_frac * n`` the algorithm exits the parallel loop and
    a sequential/greedy tail finishes the job (paper §5-GrS; the tail
    runner is supplied by each algorithm).
"""

from __future__ import annotations

import dataclasses
import enum

import jax
import jax.numpy as jnp

from ..graphs.structure import Graph
from .primitives import frontier_out_edges

__all__ = ["Direction", "Fixed", "GenericSwitch", "GreedySwitch",
           "DirectionPolicy"]


class Direction(enum.Enum):
    PUSH = "push"
    PULL = "pull"
    AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class DirectionPolicy:
    """Base: decide_push(graph, frontier, unvisited) -> bool[] (traced)."""

    def decide_push(self, g: Graph, frontier: jax.Array,
                    unvisited_edges: jax.Array) -> jax.Array:
        raise NotImplementedError

    @property
    def name(self) -> str:
        return type(self).__name__


@dataclasses.dataclass(frozen=True)
class Fixed(DirectionPolicy):
    direction: Direction = Direction.PUSH

    def __post_init__(self):
        if self.direction == Direction.AUTO:
            raise ValueError(
                "Fixed(Direction.AUTO) is not a policy: Fixed always runs "
                "one direction. Use GenericSwitch() (or GreedySwitch()) "
                "for automatic direction optimization.")

    def decide_push(self, g, frontier, unvisited_edges):
        return jnp.asarray(self.direction == Direction.PUSH)

    @property
    def name(self) -> str:
        return self.direction.value


@dataclasses.dataclass(frozen=True)
class GenericSwitch(DirectionPolicy):
    """Beamer-style direction optimization.

    push iff  m_frontier < unvisited_edges / alpha   (growing phase)
          or  m_frontier < m / beta                  (shrinking tail).
    Defaults follow Beamer et al. (alpha=14, beta=24).
    """
    alpha: float = 14.0
    beta: float = 24.0

    def decide_push(self, g, frontier, unvisited_edges):
        mf = frontier_out_edges(g, frontier)
        grow_push = mf * self.alpha < unvisited_edges
        tail_push = mf * self.beta < g.m
        return grow_push | tail_push


@dataclasses.dataclass(frozen=True)
class GreedySwitch(DirectionPolicy):
    """GS + terminal greedy hand-off once the active set is tiny."""
    inner: GenericSwitch = dataclasses.field(default_factory=GenericSwitch)
    tail_frac: float = 0.001

    def decide_push(self, g, frontier, unvisited_edges):
        return self.inner.decide_push(g, frontier, unvisited_edges)

    def should_handoff(self, g: Graph, active_count: jax.Array) -> jax.Array:
        return active_count < jnp.maximum(1, int(self.tail_frac * g.n))
