"""Acceleration strategies (paper §5) — index + shared helpers.

Implementations live with their algorithms; this module is the map:

  PA  Partition-Awareness   -> graphs.partition.pa_split (the split) +
                               algorithms.pagerank.pagerank_pa (Algorithm 8)
                               + dist.collectives.pa_exchange (DM variant)
  FE  Frontier-Exploit      -> algorithms.coloring.fe_coloring
                               graphs.sampling (GraphSAGE fanout = FE)
  GS  Generic-Switch        -> direction.GenericSwitch (BFS/engine) +
                               fe_coloring(use_gs=True)
  GrS Greedy-Switch         -> direction.GreedySwitch + greedy_tail below
  CR  Conflict-Removal      -> algorithms.coloring.conflict_removal_coloring
"""

from __future__ import annotations

import jax.numpy as jnp

from ..graphs.structure import Graph
from .cost_model import Cost
from .algorithms.coloring import greedy_sequential

__all__ = ["greedy_tail_coloring"]


def greedy_tail_coloring(g: Graph, colors, C: int, cost: Cost):
    """GrS terminal hand-off for coloring: finish all still-uncolored
    vertices with the sequential greedy scheme (conflict-free)."""
    mask = colors == 0
    colors, cost = greedy_sequential(g, colors, mask, C, cost)
    return colors, cost.charge(iterations=1)
