"""Synthetic graph generators.

The paper evaluates on power-law Kronecker (R-MAT) and Erdős–Rényi
synthetics plus SNAP real-world graphs. Offline we cannot download SNAP, so
`standins` builds structurally matched synthetics (same n, target d̄, and
diameter regime) for each graph id used in the paper's tables:

    orc  (Orkut social,   n=3.07M, d̄=39, D=9)    -> kronecker, dense
    pok  (Pokec social,   n=1.63M, d̄=18.75, D=11) -> kronecker
    ljn  (LiveJournal,    n=3.99M, d̄=8.67, D=17)  -> kronecker
    am   (Amazon purchase n=262k,  d̄=3.43, D=32)  -> kronecker, sparse
    rca  (CA road network n=1.96M, d̄=1.4,  D=849) -> road grid

Benchmarks default to scaled-down versions (CPU container); scale=1.0
reproduces the paper's sizes.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .structure import Graph, build_graph

__all__ = [
    "kronecker", "erdos_renyi", "road_grid", "ring", "star",
    "standin", "STANDIN_SPECS",
]


def _dedup_simple(src: np.ndarray, dst: np.ndarray, n: int):
    """Drop self loops + duplicate directed edges."""
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * n + dst
    _, idx = np.unique(key, return_index=True)
    return src[idx], dst[idx]


def _symmetrize(src: np.ndarray, dst: np.ndarray):
    return np.concatenate([src, dst]), np.concatenate([dst, src])


def _pair_weights(src: np.ndarray, dst: np.ndarray, n: int, rng,
                  low: float, high: float) -> np.ndarray:
    """Weights drawn per *undirected pair* so both orientations agree —
    required for undirected-algorithm correctness (MST/SSSP)."""
    lo = np.minimum(src, dst).astype(np.int64)
    hi = np.maximum(src, dst).astype(np.int64)
    uniq, inv = np.unique(lo * (n + 1) + hi, return_inverse=True)
    wu = rng.uniform(low, high, size=len(uniq)).astype(np.float32)
    return wu[inv]


def kronecker(scale: int, edge_factor: int = 16, seed: int = 0,
              a: float = 0.57, b: float = 0.19, c: float = 0.19,
              undirected: bool = True, weighted: bool = False,
              d_ell: Optional[int] = None) -> Graph:
    """R-MAT / stochastic-Kronecker power-law generator (Graph500 params).

    n = 2**scale vertices, ~edge_factor * n undirected edges.
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        src_bit = r >= ab  # falls in c or d quadrant -> src high bit set
        r2 = rng.random(m)
        thr = np.where(src_bit, c / (1.0 - ab), b / ab)
        dst_bit = np.where(src_bit, r2 >= thr, r2 >= (a / ab))
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    # permute vertex ids to decorrelate degree from id
    perm = rng.permutation(n)
    src, dst = perm[src], perm[dst]
    src, dst = _dedup_simple(src.astype(np.int64), dst.astype(np.int64), n)
    if undirected:
        src, dst = _symmetrize(src, dst)
        src, dst = _dedup_simple(src, dst, n)
    w = _pair_weights(src, dst, n, rng, 1.0, 10.0) if weighted else None
    return build_graph(src, dst, n=n, weights=w, d_ell=d_ell)


def erdos_renyi(n: int, avg_degree: float, seed: int = 0,
                undirected: bool = True, weighted: bool = False,
                d_ell: Optional[int] = None) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    src, dst = _dedup_simple(src, dst, n)
    if undirected:
        src, dst = _symmetrize(src, dst)
        src, dst = _dedup_simple(src, dst, n)
    w = _pair_weights(src, dst, n, rng, 1.0, 10.0) if weighted else None
    return build_graph(src, dst, n=n, weights=w, d_ell=d_ell)


def road_grid(side: int, diag_prob: float = 0.05, seed: int = 0,
              weighted: bool = True, d_ell: Optional[int] = None) -> Graph:
    """Road-network stand-in: 2D grid (d̄≈2, huge diameter) with a few
    diagonal shortcuts. Matches the low-d̄/large-D regime of `rca`."""
    rng = np.random.default_rng(seed)
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    vid = (ii * side + jj).astype(np.int64)
    right_s, right_d = vid[:, :-1].ravel(), vid[:, 1:].ravel()
    down_s, down_d = vid[:-1, :].ravel(), vid[1:, :].ravel()
    src = np.concatenate([right_s, down_s])
    dst = np.concatenate([right_d, down_d])
    if diag_prob > 0:
        diag_s, diag_d = vid[:-1, :-1].ravel(), vid[1:, 1:].ravel()
        keep = rng.random(len(diag_s)) < diag_prob
        src = np.concatenate([src, diag_s[keep]])
        dst = np.concatenate([dst, diag_d[keep]])
    src, dst = _symmetrize(src, dst)
    w = _pair_weights(src, dst, n, rng, 1.0, 5.0) if weighted else None
    return build_graph(src, dst, n=n, weights=w, d_ell=d_ell)


def ring(n: int, weighted: bool = False, d_ell: Optional[int] = None) -> Graph:
    """Cycle graph — worst case diameter; handy for BFS/SSSP tests."""
    v = np.arange(n, dtype=np.int64)
    src, dst = _symmetrize(v, (v + 1) % n)
    w = None
    if weighted:
        rng = np.random.default_rng(n)
        w = _pair_weights(src, dst, n, rng, 1.0, 7.0)
    return build_graph(src, dst, n=n, weights=w, d_ell=d_ell)


def star(n: int, d_ell: Optional[int] = None) -> Graph:
    """Hub-and-spoke — max-degree stress test for push combining."""
    leaves = np.arange(1, n, dtype=np.int64)
    hub = np.zeros(n - 1, dtype=np.int64)
    src, dst = _symmetrize(hub, leaves)
    return build_graph(src, dst, n=n, d_ell=d_ell)


# name -> (kind, paper n, paper d̄, paper D) ; see module docstring
STANDIN_SPECS = {
    "orc": ("kron", 3_072_000, 39.0, 9),
    "pok": ("kron", 1_630_000, 18.75, 11),
    "ljn": ("kron", 3_990_000, 8.67, 17),
    "am": ("kron", 262_000, 3.43, 32),
    "rca": ("road", 1_960_000, 1.4, 849),
}


def standin(name: str, scale: float = 1.0 / 64, seed: int = 0,
            weighted: bool = False) -> Graph:
    """Structurally matched stand-in for a paper graph, optionally scaled
    down by ``scale`` in vertex count (degree structure preserved)."""
    kind, n_full, dbar, _D = STANDIN_SPECS[name]
    n = max(256, int(n_full * scale))
    if kind == "road":
        side = max(16, int(np.sqrt(n)))
        return road_grid(side, seed=seed, weighted=True)
    log2n = max(8, int(np.round(np.log2(n))))
    ef = max(1, int(round(dbar / 2.0)))
    return kronecker(log2n, edge_factor=ef, seed=seed, weighted=weighted)
