"""1D vertex decomposition + Partition-Awareness (paper §2.2, §5-PA).

A partition assigns each vertex ``v`` an owner ``t[v] = v // shard_size``
(contiguous blocks — the paper's layout, and what a sharded jnp array gives
us for free). Partition-Awareness (PA) splits every adjacency into

  * **local** edges: ``t[src] == t[dst]`` — updated with plain writes
    (no collective, no combining scatter across shards), and
  * **remote** edges: ``t[src] != t[dst]`` — the only edges whose updates
    cross the shard boundary (atomics on CPU; all_to_all bytes on TPU).

All outputs are padded to static shapes (TPU requirement); ``*_count``
fields carry the true sizes. The split is computed host-side once per
(graph, P) pair.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .structure import Graph

__all__ = ["Partition", "partition_1d", "PartitionedEdges", "pa_split",
           "pa_regroup_by_dst"]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Partition:
    """Owner map for a 1D contiguous decomposition."""
    n: int = dataclasses.field(metadata=dict(static=True))
    num_parts: int = dataclasses.field(metadata=dict(static=True))
    shard_size: int = dataclasses.field(metadata=dict(static=True))
    n_padded: int = dataclasses.field(metadata=dict(static=True))

    def owner_np(self, v: np.ndarray) -> np.ndarray:
        return np.minimum(v // self.shard_size, self.num_parts - 1)

    def owner(self, v: jax.Array) -> jax.Array:
        return jnp.minimum(v // self.shard_size, self.num_parts - 1)


def partition_1d(n: int, num_parts: int) -> Partition:
    """Contiguous 1D decomposition of ``n`` vertices into ``num_parts``
    owner blocks.

    ``num_parts`` must lie in ``[1, n]``: fewer than one part is
    meaningless, and more parts than vertices would leave empty shards
    whose collective slices silently alias the last real owner. When
    ``n`` is not divisible by ``num_parts`` the decomposition pads
    explicitly — ``n_padded = shard_size * num_parts >= n`` — and every
    consumer (exchanges, sharded backends) masks the ``[n, n_padded)``
    tail; vertices are never truncated.
    """
    if num_parts < 1:
        raise ValueError(
            f"num_parts={num_parts} is invalid: a partition needs at "
            "least one part")
    if num_parts > n:
        raise ValueError(
            f"num_parts={num_parts} exceeds the vertex count n={n}: "
            "every part must own at least one vertex (empty shards would "
            "alias the last owner's slice)")
    shard = _round_up(n, num_parts) // num_parts
    return Partition(n=n, num_parts=num_parts, shard_size=shard,
                     n_padded=shard * num_parts)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionedEdges:
    """PA edge split, shard-major and padded for shard_map consumption.

    Arrays are shaped ``[P, cap]``: row ``p`` holds the edges whose *owner
    shard* is ``p`` — for push that is the shard owning ``src`` (it sends),
    for pull the shard owning ``dst`` (it receives). Padding slots point at
    the sentinel vertex ``n`` with weight 0 and ``valid=False``.
    """
    src: jax.Array   # int32[P, cap]
    dst: jax.Array   # int32[P, cap]
    w: jax.Array     # float32[P, cap]
    valid: jax.Array  # bool[P, cap]
    count: jax.Array  # int32[P] true number of edges per shard
    cap: int = dataclasses.field(metadata=dict(static=True))
    num_parts: int = dataclasses.field(metadata=dict(static=True))


def _pack(rows: list[np.ndarray], cols: list[np.ndarray],
          ws: list[np.ndarray], P: int, n: int, align: int) -> PartitionedEdges:
    cap = max(1, _round_up(max((len(r) for r in rows), default=1), align))
    src = np.full((P, cap), n, dtype=np.int32)
    dst = np.full((P, cap), n, dtype=np.int32)
    w = np.zeros((P, cap), dtype=np.float32)
    valid = np.zeros((P, cap), dtype=bool)
    cnt = np.zeros((P,), dtype=np.int32)
    for p in range(P):
        k = len(rows[p])
        src[p, :k] = rows[p]
        dst[p, :k] = cols[p]
        w[p, :k] = ws[p]
        valid[p, :k] = True
        cnt[p] = k
    return PartitionedEdges(
        src=jnp.asarray(src), dst=jnp.asarray(dst), w=jnp.asarray(w),
        valid=jnp.asarray(valid), count=jnp.asarray(cnt),
        cap=int(cap), num_parts=P)


def pa_regroup_by_dst(part: Partition, edges: PartitionedEdges, n: int,
                      align: int = 128) -> PartitionedEdges:
    """Regroup a packed edge set by the *destination* owner — the pull
    layout `dist.collectives.pull_exchange` consumes. Host-side, sized by
    the edge set itself (for PA: the cut, not the full graph)."""
    ok = np.asarray(edges.valid).reshape(-1)
    src = np.asarray(edges.src).reshape(-1)[ok]
    dst = np.asarray(edges.dst).reshape(-1)[ok]
    w = np.asarray(edges.w).reshape(-1)[ok]
    own_d = part.owner_np(dst)
    P = part.num_parts
    rows = [src[own_d == p] for p in range(P)]
    cols = [dst[own_d == p] for p in range(P)]
    ws = [w[own_d == p] for p in range(P)]
    return _pack(rows, cols, ws, P, n, align)


def pa_split(g: Graph, part: Partition, align: int = 128
             ) -> tuple[PartitionedEdges, PartitionedEdges, dict]:
    """Partition-Awareness split of ``g`` under ``part``.

    Returns ``(local, remote, stats)`` where both edge sets are grouped
    by the **source** owner (push layout; pull consumers regroup the cut
    with `pa_regroup_by_dst`). ``stats`` reports the cut size — the
    paper's bound: remote combining writes ∈ [0, 2m].
    """
    P = part.num_parts
    src = np.asarray(g.push_src)
    dst = np.asarray(g.push_dst)
    w = np.asarray(g.push_w)
    own_s = part.owner_np(src)
    own_d = part.owner_np(dst)
    is_local = own_s == own_d

    loc_rows, loc_cols, loc_ws = [], [], []
    rem_rows, rem_cols, rem_ws = [], [], []
    for p in range(P):
        sel_l = (own_s == p) & is_local
        sel_r = (own_s == p) & ~is_local
        loc_rows.append(src[sel_l]); loc_cols.append(dst[sel_l]); loc_ws.append(w[sel_l])
        rem_rows.append(src[sel_r]); rem_cols.append(dst[sel_r]); rem_ws.append(w[sel_r])

    local = _pack(loc_rows, loc_cols, loc_ws, P, g.n, align)
    remote = _pack(rem_rows, rem_cols, rem_ws, P, g.n, align)
    cut = int((~is_local).sum())
    stats = {
        "m": g.m,
        "cut_edges": cut,
        "cut_fraction": cut / max(1, g.m),
        "border_vertices": int(np.unique(np.concatenate(
            [src[~is_local], dst[~is_local]])).size) if cut else 0,
    }
    return local, remote, stats
