from .structure import Graph, build_graph, pad_values
from .generators import (kronecker, erdos_renyi, road_grid, ring, star,
                         standin, STANDIN_SPECS)
from .partition import Partition, partition_1d, PartitionedEdges, pa_split
from .sampling import SampledBlocks, sample_neighbors, sample_blocks

__all__ = [
    "Graph", "build_graph", "pad_values",
    "kronecker", "erdos_renyi", "road_grid", "ring", "star", "standin",
    "STANDIN_SPECS",
    "Partition", "partition_1d", "PartitionedEdges", "pa_split",
    "SampledBlocks", "sample_neighbors", "sample_blocks",
]
