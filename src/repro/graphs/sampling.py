"""Neighbor sampling (GraphSAGE-style fanout) — paper §5 Frontier-Exploit
made into a data-pipeline primitive.

Sampling *is* Frontier-Exploit: instead of touching all m edges per layer
(pull over the full graph), we push outward from a seed frontier and touch
only ``batch * prod(fanouts)`` edges. The sampler is pure JAX (jittable,
static output shapes) so it can run on-device inside the input pipeline.

Output layout per hop k (seeds = hop 0):
  nodes[k]: int32[batch * prod(fanout[:k])] node ids (sentinel n = pad)
  For each hop k>=1, edge (nodes[k][i], nodes[k-1][i // fanout[k-1]])
  is a sampled in-edge of its parent — exactly the bipartite block a
  GraphSAGE layer consumes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from .structure import Graph

__all__ = ["SampledBlocks", "sample_neighbors", "sample_blocks"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SampledBlocks:
    """Layered bipartite blocks for an L-hop sampled minibatch."""
    node_ids: tuple[jax.Array, ...]   # per hop, int32[n_k]
    valid: tuple[jax.Array, ...]      # per hop, bool[n_k]
    fanouts: tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    sentinel: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_hops(self) -> int:
        return len(self.fanouts)


def sample_neighbors(g: Graph, nodes: jax.Array, valid: jax.Array,
                     fanout: int, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Uniformly sample ``fanout`` in-neighbors of each node (with
    replacement, the standard GraphSAGE estimator). Invalid/isolated nodes
    yield sentinel children."""
    deg = jnp.concatenate([g.in_deg, jnp.zeros((1,), jnp.int32)])[
        jnp.minimum(nodes, g.n)]
    start = jnp.concatenate([g.in_ptr[:-1], jnp.zeros((1,), jnp.int32)])[
        jnp.minimum(nodes, g.n)]
    u = jax.random.uniform(key, (nodes.shape[0], fanout))
    offs = jnp.floor(u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
    slots = start[:, None] + offs
    child = g.coo_src[jnp.clip(slots, 0, g.m - 1)]
    ok = jnp.broadcast_to((valid & (deg > 0))[:, None],
                          (nodes.shape[0], fanout))
    child = jnp.where(ok, child, g.n)
    return child.reshape(-1), ok.reshape(-1)


@partial(jax.jit, static_argnames=("fanouts",))
def sample_blocks(g: Graph, seeds: jax.Array, fanouts: Sequence[int],
                  key: jax.Array) -> SampledBlocks:
    """L-hop fanout sampling from ``seeds`` (int32[batch])."""
    fanouts = tuple(int(f) for f in fanouts)
    nodes = [seeds.astype(jnp.int32)]
    valid = [seeds < g.n]
    for k, f in enumerate(fanouts):
        key, sub = jax.random.split(key)
        child, ok = sample_neighbors(g, nodes[-1], valid[-1], f, sub)
        nodes.append(child)
        valid.append(ok)
    return SampledBlocks(node_ids=tuple(nodes), valid=tuple(valid),
                         fanouts=fanouts, sentinel=g.n)
