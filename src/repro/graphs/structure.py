"""Graph data structures.

The paper's push/pull dichotomy is a *layout* dichotomy (§7.1):

  * pull  <-> CSR (in-edges grouped by destination; gather-reduce)
  * push  <-> CSC (out-edges grouped by source; scatter-combine)

On TPU we additionally keep an ELL (padded-row) view because rectangular
tiles are what VMEM/BlockSpecs want, and a raw COO view because edge-
parallel `segment_sum` formulations want flat index vectors.

All views are materialized once on the host (numpy) and stored as jnp
arrays inside a frozen pytree, so jitted code can pick whichever layout the
chosen direction needs without retracing.

Conventions
-----------
* Vertices are ``int32`` ids in ``[0, n)``.
* ``coo_src/coo_dst`` are sorted by ``dst`` (pull-major). ``csc_*``
  describes the same edges sorted by ``src`` (push-major).
* For undirected graphs every edge appears in both directions, i.e. ``m``
  counts *directed* edges (2x the undirected edge count).
* ELL rows are padded with the sentinel ``n`` (one past the last vertex);
  gathers index into value vectors padded with a zero row at index ``n``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Graph", "build_graph", "pad_values"]


def _to_i32(x) -> np.ndarray:
    return np.asarray(x, dtype=np.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """Multi-layout immutable graph container (a JAX pytree).

    Attributes
    ----------
    n, m: static python ints (auxiliary data, not traced).
    coo_src, coo_dst: ``int32[m]`` edges sorted by ``dst`` (pull-major).
    coo_w: ``float32[m]`` weights aligned with ``coo_src/dst``.
    in_ptr: ``int32[n+1]`` CSR row pointer over the pull-major edges, i.e.
        in-edges of vertex ``v`` are slots ``in_ptr[v]:in_ptr[v+1]``.
    push_src, push_dst, push_w: the same edges sorted by ``src``.
    out_ptr: ``int32[n+1]`` pointer for the push-major order.
    ell_idx: ``int32[n, d_ell]`` padded in-neighbor lists (sentinel ``n``).
    ell_w: ``float32[n, d_ell]`` weights aligned with ``ell_idx`` (0 pad).
    in_deg, out_deg: ``int32[n]``.
    """

    coo_src: jax.Array
    coo_dst: jax.Array
    coo_w: jax.Array
    in_ptr: jax.Array
    push_src: jax.Array
    push_dst: jax.Array
    push_w: jax.Array
    out_ptr: jax.Array
    ell_idx: jax.Array
    ell_w: jax.Array
    in_deg: jax.Array
    out_deg: jax.Array
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))
    d_ell: int = dataclasses.field(metadata=dict(static=True))

    # -- convenience -------------------------------------------------------
    @property
    def num_vertices(self) -> int:
        return self.n

    @property
    def num_edges(self) -> int:
        return self.m

    def out_neighbors_slice(self, v: int) -> tuple[int, int]:
        """Host-side helper (numpy semantics) for tests/greedy tails."""
        ptr = np.asarray(self.out_ptr)
        return int(ptr[v]), int(ptr[v + 1])

    def reverse(self) -> "Graph":
        """Graph with every edge direction flipped (for directed use)."""
        return build_graph(
            np.asarray(self.coo_dst),
            np.asarray(self.coo_src),
            n=self.n,
            weights=np.asarray(self.coo_w),
            d_ell=self.d_ell,
        )


def _ell_from_ptr(ptr: np.ndarray, nbr: np.ndarray, w: np.ndarray, n: int,
                  d_ell: int) -> tuple[np.ndarray, np.ndarray]:
    """Pack CSR-ordered neighbor lists into a padded [n, d_ell] matrix."""
    deg = np.diff(ptr)
    d_max = int(deg.max()) if n else 0
    if d_ell < d_max:
        raise ValueError(f"d_ell={d_ell} < max degree {d_max}")
    idx = np.full((n, d_ell), n, dtype=np.int32)
    val = np.zeros((n, d_ell), dtype=w.dtype)
    # vectorized ragged fill: position of each edge within its row
    within = np.arange(len(nbr), dtype=np.int64) - np.repeat(ptr[:-1], deg)
    rows = np.repeat(np.arange(n, dtype=np.int64), deg)
    idx[rows, within] = nbr
    val[rows, within] = w
    return idx, val


def build_graph(src, dst, n: int, weights=None, d_ell: Optional[int] = None,
                pad_rows_to: int = 8) -> Graph:
    """Build all layouts from a COO edge list.

    ``d_ell`` may be given to force a specific (e.g. tile-aligned) padded
    width; otherwise max in-degree rounded up to ``pad_rows_to``.

    Edge endpoints must lie in ``[0, n)`` and weights must be finite;
    violations raise ``ValueError`` naming the first offending edge.
    (An out-of-range endpoint would otherwise corrupt the CSR pointer
    build silently; a NaN/Inf weight poisons every distance it touches.)
    """
    src = _to_i32(src)
    dst = _to_i32(dst)
    m = int(src.shape[0])
    if dst.shape != src.shape:
        raise ValueError(
            f"build_graph: src has {m} edges but dst has "
            f"{int(dst.shape[0])} — the COO views must be aligned")
    for name, arr in (("src", src), ("dst", dst)):
        if m and (arr.min() < 0 or arr.max() >= n):
            bad = int(np.flatnonzero((arr < 0) | (arr >= n))[0])
            raise ValueError(
                f"build_graph: {name}[{bad}] = {int(arr[bad])} is "
                f"outside the vertex range [0, {n}) — every edge "
                f"endpoint must name an existing vertex")
    if weights is None:
        weights = np.ones(m, dtype=np.float32)
    w = np.asarray(weights, dtype=np.float32)
    if w.shape != (m,):
        raise ValueError(
            f"build_graph: weights shape {w.shape} does not match the "
            f"{m} edges")
    if m and not np.isfinite(w).all():
        bad = int(np.flatnonzero(~np.isfinite(w))[0])
        raise ValueError(
            f"build_graph: weights[{bad}] = {w[bad]} is not finite — "
            f"NaN/Inf edge weights are rejected at construction")

    # pull-major: sort by dst (stable keeps generator order within a row)
    order = np.argsort(dst, kind="stable")
    p_src, p_dst, p_w = src[order], dst[order], w[order]
    in_ptr = np.zeros(n + 1, dtype=np.int32)
    np.add.at(in_ptr, p_dst + 1, 1)
    in_ptr = np.cumsum(in_ptr, dtype=np.int64).astype(np.int32)

    # push-major: sort by src
    order2 = np.argsort(src, kind="stable")
    q_src, q_dst, q_w = src[order2], dst[order2], w[order2]
    out_ptr = np.zeros(n + 1, dtype=np.int32)
    np.add.at(out_ptr, q_src + 1, 1)
    out_ptr = np.cumsum(out_ptr, dtype=np.int64).astype(np.int32)

    in_deg = np.diff(in_ptr).astype(np.int32)
    out_deg = np.diff(out_ptr).astype(np.int32)

    d_max = int(in_deg.max()) if n else 0
    if d_ell is None:
        d_ell = max(pad_rows_to, -(-d_max // pad_rows_to) * pad_rows_to)
    ell_idx, ell_w = _ell_from_ptr(in_ptr, p_src, p_w, n, d_ell)

    dev = jnp.asarray
    return Graph(
        coo_src=dev(p_src), coo_dst=dev(p_dst), coo_w=dev(p_w),
        in_ptr=dev(in_ptr),
        push_src=dev(q_src), push_dst=dev(q_dst), push_w=dev(q_w),
        out_ptr=dev(out_ptr),
        ell_idx=dev(ell_idx), ell_w=dev(ell_w),
        in_deg=dev(in_deg), out_deg=dev(out_deg),
        n=n, m=m, d_ell=int(d_ell),
    )


@partial(jax.jit, static_argnames=())
def pad_values(x: jax.Array) -> jax.Array:
    """Append a zero row/scalar at index ``n`` so ELL sentinel gathers
    read zeros. Works for [n] vectors and [n, d] matrices."""
    pad_width = [(0, 1)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad_width)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeView:
    """Duck-typed Graph stand-in for GNN layers: one edge order, shared by
    both directions (the provider chooses pull- or push-major order).
    Used by the dry-run where full multi-layout Graphs would waste input
    memory, and by sampled-subgraph training."""
    src: jax.Array
    dst: jax.Array
    w: jax.Array
    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))

    @property
    def coo_src(self):
        return self.src

    @property
    def coo_dst(self):
        return self.dst

    @property
    def coo_w(self):
        return self.w

    @property
    def push_src(self):
        return self.src

    @property
    def push_dst(self):
        return self.dst

    @property
    def push_w(self):
        return self.w
