"""repro — a push/pull-native graph + ML training/serving framework in JAX.

Reproduction of Besta et al., "To Push or To Pull: On Reducing
Communication and Synchronization in Graph Computations" (HPDC 17),
adapted to TPU/XLA semantics, plus the assigned architecture zoo.
"""

__version__ = "1.0.0"

# --- jax.shard_map compatibility -------------------------------------
# The framework (models, dist collectives, tests) targets the modern
# `jax.shard_map(..., check_vma=...)` spelling. On older jax releases the
# function lives in jax.experimental.shard_map and the kwarg is named
# `check_rep`; alias it once here so every repro import sees one API.
import jax as _jax

if not hasattr(_jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def _compat_shard_map(f=None, mesh=None, in_specs=None, out_specs=None,
                          check_vma=None, **kw):
        if check_vma is not None:
            kw["check_rep"] = bool(check_vma)
        else:
            # legacy check_rep rejects valid ppermute/axis_index patterns
            # our exchanges use; modern check_vma handles them.
            kw.setdefault("check_rep", False)
        if f is None:          # decorator-factory style
            return lambda fn: _shard_map(fn, mesh=mesh, in_specs=in_specs,
                                         out_specs=out_specs, **kw)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)

    _jax.shard_map = _compat_shard_map

del _jax


def __getattr__(name):
    # `repro.api` without forcing the full algorithm import at package
    # import time (models/train/dist users never pay for it).
    if name == "api":
        import importlib
        return importlib.import_module(".api", __name__)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
