"""repro — a push/pull-native graph + ML training/serving framework in JAX.

Reproduction of Besta et al., "To Push or To Pull: On Reducing
Communication and Synchronization in Graph Computations" (HPDC 17),
adapted to TPU/XLA semantics, plus the assigned architecture zoo.
"""

__version__ = "1.0.0"
