"""Assigned input-shape sets, verbatim from the task spec.

Every (arch × shape) pair is one dry-run/roofline cell; kinds decide which
step gets lowered ('train' -> train_step, 'prefill'/'decode'/'serve'/
'retrieval' -> the serving path).
"""

from __future__ import annotations

import dataclasses

__all__ = ["LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES", "ShapeSpec",
           "shape_table"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                    # train | prefill | decode | serve | retrieval
    params: dict


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train",
                          dict(seq_len=4096, global_batch=256)),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                             dict(seq_len=32768, global_batch=32)),
    "decode_32k": ShapeSpec("decode_32k", "decode",
                            dict(seq_len=32768, global_batch=128)),
    # decode shape: 1 new token against a 512k cache (cost O(cache));
    # a 500k *prefill* would be quadratic and is out of scope for the
    # full-attention archs — see DESIGN.md §5.
    "long_500k": ShapeSpec("long_500k", "decode",
                           dict(seq_len=524288, global_batch=1)),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train",
        dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7)),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train",
        dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
             fanout=(15, 10), d_feat=602, n_classes=41)),
    "ogb_products": ShapeSpec(
        "ogb_products", "train",
        dict(n_nodes=2449029, n_edges=61859140, d_feat=100, n_classes=47)),
    "molecule": ShapeSpec(
        "molecule", "train",
        dict(n_nodes=30, n_edges=64, batch=128)),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
    "serve_p99": ShapeSpec("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                dict(batch=1, n_candidates=1_000_000)),
}


def shape_table(family: str) -> dict[str, ShapeSpec]:
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
            "recsys": RECSYS_SHAPES}[family]
