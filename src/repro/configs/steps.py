"""Cell builders: (arch × shape × mesh) → lowered-ready step functions.

Each builder returns a `BuiltCell`:
    fn            the step function to jit
    args          tuple of ShapeDtypeStruct pytrees (NO allocation)
    in_shardings  matching tuple of NamedSharding pytrees
    out_shardings for state-carrying outputs (params/opt/cache: same as in)
    donate        argnums whose buffers alias outputs

Conventions: batch dims shard over the flattened ('pod','data') axes;
parameters follow dist.sharding rules; graph cells pad node/edge counts to
the batch-axis multiple (padded tail is masked; true sizes stay in meta).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist.sharding import (batch_axes, make_sharding,
                             recsys_param_specs, set_activation_mesh,
                             transformer_param_specs)
from ..graphs.structure import EdgeView
from ..models import gnn as gnn_mod
from ..models.recsys import (XDeepFMConfig, retrieval_score, xdeepfm_apply,
                             xdeepfm_init)
from ..models.transformer import (TransformerConfig, decode_step,
                                  init_kv_cache, init_params, lm_loss,
                                  prefill)
from ..train.losses import bce_with_logits, mse, softmax_xent_dense
from ..train.optimizer import OptConfig, apply_updates, init_opt
from .archs import full_config
from .shapes import ShapeSpec

__all__ = ["BuiltCell", "build_lm_cell", "build_gnn_cell",
           "build_recsys_cell", "OPT_CFG"]

OPT_CFG = OptConfig(lr=3e-4, total_steps=10_000)

F32 = jnp.float32
I32 = jnp.int32
SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class BuiltCell:
    name: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)


def _repl(mesh):
    return NamedSharding(mesh, P())


def _shard_tree_like(mesh, spec_fn, tree):
    """Apply a params-spec rule fn to any tree with the same structure."""
    return spec_fn(mesh, tree)


def _batch_sharding(mesh, shape, extra=()):
    ba = batch_axes(mesh)
    spec = P(ba, *extra)
    return make_sharding(mesh, spec, shape)


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


# ------------------------------------------------------------------ LM --
def _lm_state_specs(mesh, cfg: TransformerConfig, zero: str = "pull"):
    params_spec = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    opt_spec = jax.eval_shape(lambda p: init_opt(p, OPT_CFG), params_spec)
    p_sh = transformer_param_specs(mesh, params_spec, zero=zero)
    o_sh = type(opt_spec)(
        step=_repl(mesh),
        mu=transformer_param_specs(mesh, opt_spec.mu, zero=zero),
        nu=transformer_param_specs(mesh, opt_spec.nu, zero=zero))
    return params_spec, opt_spec, p_sh, o_sh


def _cache_shardings(mesh, cache_spec, batch: int, seq_shard: bool):
    """KV cache sharding: batch over batch axes when divisible, sequence
    over 'model' (flash-decoding split) — or over everything for B=1."""
    ba = batch_axes(mesh)

    def one(leaf):
        # leaf: [L, B, S, Hk, Dh] or scale [L, B, S, Hk, 1]
        if seq_shard:
            axes_for_seq = tuple(ba) + ("model",)
            spec = P(None, None, axes_for_seq, None, None)
        else:
            spec = P(None, ba, "model", None, None)
        return make_sharding(mesh, spec, leaf.shape)

    return jax.tree.map(one, cache_spec)


def build_lm_cell(arch: str, shape: ShapeSpec, mesh: Mesh,
                  zero: str = "pull",
                  overrides: Optional[dict] = None) -> BuiltCell:
    cfg = full_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    T = shape.params["seq_len"]
    B = shape.params["global_batch"]
    ba = batch_axes(mesh)

    if shape.kind == "train":
        params_spec, opt_spec, p_sh, o_sh = _lm_state_specs(mesh, cfg, zero)
        batch_spec = {"tokens": SDS((B, T), I32),
                      "labels": SDS((B, T), I32)}
        batch_sh = {k: _batch_sharding(mesh, (B, T), (None,))
                    for k in batch_spec}

        def step(params, opt_state, batch):
            def loss_fn(p):
                return lm_loss(p, cfg, batch["tokens"], batch["labels"])
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state = apply_updates(params, grads, opt_state,
                                              OPT_CFG)
            return params, opt_state, loss

        return BuiltCell(
            name=f"{arch}@{shape.name}", fn=step,
            args=(params_spec, opt_spec, batch_spec),
            in_shardings=(p_sh, o_sh, batch_sh),
            out_shardings=(p_sh, o_sh, _repl(mesh)),
            donate=(0, 1), meta={"cfg": cfg, "kind": "train"})

    if shape.kind == "prefill":
        params_spec = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))
        p_sh = transformer_param_specs(mesh, params_spec, zero="push")
        tokens_spec = SDS((B, T), I32)
        cache_kind = "int8" if arch == "qwen1.5-32b" else "bf16"

        def step(params, tokens):
            return prefill(params, cfg, tokens, cache_kind=cache_kind)

        out_cache_spec = jax.eval_shape(step, params_spec, tokens_spec)[1]
        cache_sh = _cache_shardings(mesh, out_cache_spec, B,
                                    seq_shard=False)
        return BuiltCell(
            name=f"{arch}@{shape.name}", fn=step,
            args=(params_spec, tokens_spec),
            in_shardings=(p_sh, _batch_sharding(mesh, (B, T), (None,))),
            out_shardings=(make_sharding(mesh, P(ba, "model"), (B, cfg.vocab)),
                           cache_sh),
            meta={"cfg": cfg, "kind": "prefill", "cache_kind": cache_kind})

    # decode (decode_32k / long_500k)
    params_spec = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    p_sh = transformer_param_specs(mesh, params_spec, zero="push")
    int8 = arch in ("qwen1.5-32b",) or T >= 262144
    kind = "int8" if int8 else "bf16"
    cache_spec = jax.eval_shape(
        lambda: init_kv_cache(cfg, B, T, kind=kind))
    seq_shard = B == 1           # long_500k: flash-decoding over sequence
    cache_sh = _cache_shardings(mesh, cache_spec, B, seq_shard=seq_shard)
    tok_spec = SDS((B, 1), I32)
    len_spec = SDS((), I32)

    def step(params, tokens, cache, cur_len):
        return decode_step(params, cfg, tokens, cache, cur_len)

    return BuiltCell(
        name=f"{arch}@{shape.name}", fn=step,
        args=(params_spec, tok_spec, cache_spec, len_spec),
        in_shardings=(p_sh, _batch_sharding(mesh, (B, 1), (None,)),
                      cache_sh, _repl(mesh)),
        out_shardings=(make_sharding(mesh, P(ba, "model"), (B, cfg.vocab)),
                       cache_sh),
        donate=(2,), meta={"cfg": cfg, "kind": "decode",
                           "cache_kind": kind})


# ----------------------------------------------------------------- GNN --
def _gnn_batch_spec(arch: str, shape: ShapeSpec, mesh: Mesh,
                    shard_axes: str = "batch"):
    """Padded node/edge buffers + per-arch extras. Returns (spec, sh,
    meta). shard_axes='all' spreads nodes/edges over every mesh axis
    (removes the 16x model-replica waste — hillclimb lever)."""
    ba = (tuple(mesh.axis_names) if shard_axes == "all"
          else batch_axes(mesh))
    mult = max(1, __import__("math").prod(
        mesh.shape[a] for a in ba)) * 8
    p = shape.params

    if shape.name == "minibatch_lg":
        seeds = p["batch_nodes"]
        f1, f2 = p["fanout"]
        n1, n2 = seeds * f1, seeds * f1 * f2
        N = seeds + n1 + n2
        E = n1 + n2
        Np, Ep = _pad_to(N, mult), _pad_to(E, mult)
        d = p["d_feat"]
        spec = {"feats": SDS((Np, d), F32),
                "src": SDS((Ep,), I32), "dst": SDS((Ep,), I32),
                "w": SDS((Ep,), F32),
                "labels": SDS((seeds,), I32)}
        meta = dict(n=Np, m=Ep, n_true=N, labeled=seeds,
                    n_classes=p["n_classes"], d_feat=d, task="node_class")
    elif shape.name == "molecule":
        Bg = p["batch"]
        N, E = p["n_nodes"] * Bg, p["n_edges"] * Bg
        Np, Ep = _pad_to(N, mult), _pad_to(E, mult)
        d = 16     # synthetic atom features
        spec = {"feats": SDS((Np, d), F32),
                "src": SDS((Ep,), I32), "dst": SDS((Ep,), I32),
                "w": SDS((Ep,), F32),
                "graph_ids": SDS((Np,), I32),
                "labels": SDS((Bg,), F32)}
        meta = dict(n=Np, m=Ep, n_true=N, n_graphs=Bg, d_feat=d,
                    task="graph_reg")
    else:
        N, E, d = p["n_nodes"], p["n_edges"], p["d_feat"]
        Np, Ep = _pad_to(N, mult), _pad_to(E, mult)
        spec = {"feats": SDS((Np, d), F32),
                "src": SDS((Ep,), I32), "dst": SDS((Ep,), I32),
                "w": SDS((Ep,), F32),
                "labels": SDS((Np,), I32)}
        meta = dict(n=Np, m=Ep, n_true=N, labeled=N,
                    n_classes=p["n_classes"], d_feat=d, task="node_class")

    if arch == "egnn":
        spec["coords"] = SDS((meta["n"],), F32)
        spec["coords"] = SDS((meta["n"], 3), F32)
    if arch == "graphcast":
        # graphcast defines its own variable set (227 vars in/out)
        spec["feats"] = SDS((meta["n"], 227), F32)
        spec["target"] = SDS((meta["n"], 227), F32)
        meta["task"] = "var_reg"

    sh = {}
    for k, v in spec.items():
        sh[k] = make_sharding(mesh, P(ba, *(None,) * (len(v.shape) - 1)),
                              v.shape)
    return spec, sh, meta


def build_gnn_mp_cell(arch: str, shape: ShapeSpec, mesh: Mesh,
                      overrides: dict) -> BuiltCell:
    """gin-tu with the explicit PA pull-exchange (edges pre-grouped by
    destination owner, shard_map all_gather + local combine)."""
    import math
    base = full_config(arch)
    p = shape.params
    axes = tuple(mesh.axis_names)
    nparts = math.prod(mesh.shape[a] for a in axes)
    N, E, d = p["n_nodes"], p["n_edges"], p["d_feat"]
    Np = _pad_to(N, nparts * 8)
    cap = _pad_to(int(E * 1.2 // nparts) + 1, 8)
    gcfg = dataclasses.replace(base, d_in=d, d_out=p["n_classes"],
                               **{k: v for k, v in overrides.items()
                                  if k not in ("mp_exchange",)})
    batch_spec = {
        "feats": SDS((Np, d), F32),
        "e_src": SDS((nparts, cap), I32),
        "e_dst": SDS((nparts, cap), I32),
        "labels": SDS((Np,), I32),          # -1 = padding
    }
    row = make_sharding(mesh, P(axes, None), (Np, d))
    erow = make_sharding(mesh, P(axes, None), (nparts, cap))
    lrow = make_sharding(mesh, P(axes), (Np,))
    batch_sh = {"feats": row, "e_src": erow, "e_dst": erow, "labels": lrow}

    from ..models.gnn import gin_apply_mp
    params_spec = jax.eval_shape(
        lambda: gnn_mod.gin_init(jax.random.PRNGKey(0), gcfg))
    p_sh = jax.tree.map(lambda _: _repl(mesh), params_spec)
    opt_spec = jax.eval_shape(lambda q: init_opt(q, OPT_CFG), params_spec)
    o_sh = jax.tree.map(lambda _: _repl(mesh), opt_spec)

    def loss_fn(params, batch):
        out = gin_apply_mp(params, gcfg, batch["feats"], batch["e_src"],
                           batch["e_dst"], mesh)
        lse = jax.nn.logsumexp(out.astype(jnp.float32), axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, out.shape, 1)
        picked = jnp.sum(
            jnp.where(iota == batch["labels"][:, None], out, 0.0), axis=-1)
        valid = batch["labels"] >= 0
        return (jnp.where(valid, lse - picked, 0.0).sum()
                / jnp.maximum(jnp.sum(valid, dtype=jnp.int32), 1))

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = apply_updates(params, grads, opt_state, OPT_CFG)
        return params, opt_state, loss

    return BuiltCell(
        name=f"{arch}@{shape.name}", fn=step,
        args=(params_spec, opt_spec, batch_spec),
        in_shardings=(p_sh, o_sh, batch_sh),
        out_shardings=(p_sh, o_sh, _repl(mesh)),
        donate=(0, 1),
        meta={"cfg": gcfg, "kind": "train", "n": Np, "m": nparts * cap,
              "mp_exchange": True})


def build_gnn_cell(arch: str, shape: ShapeSpec, mesh: Mesh,
                   direction: str = "pull",
                   overrides: Optional[dict] = None) -> BuiltCell:
    base = full_config(arch)
    overrides = dict(overrides or {})
    if overrides.get("mp_exchange"):
        return build_gnn_mp_cell(arch, shape, mesh, overrides)
    shard_axes = overrides.pop("shard_axes", "batch")
    batch_spec, batch_sh, meta = _gnn_batch_spec(arch, shape, mesh,
                                                 shard_axes=shard_axes)
    d_feat = meta["d_feat"]
    if meta["task"] == "node_class":
        d_out = meta["n_classes"]
    elif meta["task"] == "graph_reg":
        d_out = 1
    else:
        d_out = 0
    gcfg = dataclasses.replace(base, d_in=d_feat, d_out=d_out,
                               direction=direction, **overrides)

    init_fn = {"egnn": gnn_mod.egnn_init, "gin-tu": gnn_mod.gin_init,
               "graphsage-reddit": gnn_mod.sage_init,
               "graphcast": gnn_mod.graphcast_init}[arch]
    params_spec = jax.eval_shape(
        lambda: init_fn(jax.random.PRNGKey(0), gcfg))
    p_sh = jax.tree.map(lambda _: _repl(mesh), params_spec)
    opt_spec = jax.eval_shape(lambda p: init_opt(p, OPT_CFG), params_spec)
    o_sh = jax.tree.map(lambda _: _repl(mesh), opt_spec)
    N, M = meta["n"], meta["m"]

    def apply_model(params, batch):
        ev = EdgeView(src=batch["src"], dst=batch["dst"], w=batch["w"],
                      n=N, m=M)
        if arch == "egnn":
            out, _ = gnn_mod.egnn_apply(params, gcfg, ev, batch["feats"],
                                        batch["coords"])
        elif arch == "gin-tu":
            out = gnn_mod.gin_apply(
                params, gcfg, ev, batch["feats"],
                graph_ids=batch.get("graph_ids"),
                num_graphs=meta.get("n_graphs", 1))
        elif arch == "graphsage-reddit":
            out = gnn_mod.sage_apply(params, gcfg, ev, batch["feats"])
        else:
            out = gnn_mod.graphcast_apply(params, gcfg, ev, batch["feats"])
        return out

    def loss_fn(params, batch):
        out = apply_model(params, batch)
        if meta["task"] == "node_class":
            k = meta["labeled"]
            return softmax_xent_dense(out[:k], batch["labels"][:k])
        if meta["task"] == "graph_reg":
            if out.shape[0] == meta["n"]:      # per-node output: pool
                from ..sparse.segment import segment_sum
                out = segment_sum(out, batch["graph_ids"],
                                  meta["n_graphs"])
            return mse(out[:, 0] if out.ndim > 1 else out, batch["labels"])
        return mse(out[:meta["n_true"]], batch["target"][:meta["n_true"]])

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = apply_updates(params, grads, opt_state, OPT_CFG)
        return params, opt_state, loss

    return BuiltCell(
        name=f"{arch}@{shape.name}", fn=step,
        args=(params_spec, opt_spec, batch_spec),
        in_shardings=(p_sh, o_sh, batch_sh),
        out_shardings=(p_sh, o_sh, _repl(mesh)),
        donate=(0, 1), meta={"cfg": gcfg, "kind": "train", **meta})


# -------------------------------------------------------------- recsys --
def build_recsys_cell(arch: str, shape: ShapeSpec, mesh: Mesh) -> BuiltCell:
    cfg: XDeepFMConfig = full_config(arch)
    params_spec = jax.eval_shape(
        lambda: xdeepfm_init(jax.random.PRNGKey(0), cfg))
    p_sh = recsys_param_specs(mesh, params_spec)
    ba = batch_axes(mesh)

    if shape.kind == "train":
        B = shape.params["batch"]
        opt_spec = jax.eval_shape(lambda p: init_opt(p, OPT_CFG),
                                  params_spec)
        o_sh = type(opt_spec)(
            step=_repl(mesh),
            mu=recsys_param_specs(mesh, opt_spec.mu),
            nu=recsys_param_specs(mesh, opt_spec.nu))
        batch_spec = {"ids": SDS((B, cfg.n_fields), I32),
                      "labels": SDS((B,), F32)}
        batch_sh = {"ids": _batch_sharding(mesh, (B, cfg.n_fields),
                                           (None,)),
                    "labels": _batch_sharding(mesh, (B,))}

        def step(params, opt_state, batch):
            def loss_fn(p):
                logits = xdeepfm_apply(p, cfg, batch["ids"])
                return bce_with_logits(logits, batch["labels"])
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state = apply_updates(params, grads, opt_state,
                                              OPT_CFG)
            return params, opt_state, loss

        return BuiltCell(
            name=f"{arch}@{shape.name}", fn=step,
            args=(params_spec, opt_spec, batch_spec),
            in_shardings=(p_sh, o_sh, batch_sh),
            out_shardings=(p_sh, o_sh, _repl(mesh)),
            donate=(0, 1), meta={"cfg": cfg, "kind": "train"})

    if shape.kind == "serve":
        B = shape.params["batch"]
        ids_spec = SDS((B, cfg.n_fields), I32)

        def step(params, ids):
            return jax.nn.sigmoid(xdeepfm_apply(params, cfg, ids))

        return BuiltCell(
            name=f"{arch}@{shape.name}", fn=step,
            args=(params_spec, ids_spec),
            in_shardings=(p_sh, _batch_sharding(mesh, (B, cfg.n_fields),
                                                (None,))),
            out_shardings=_batch_sharding(mesh, (B,)),
            meta={"cfg": cfg, "kind": "serve"})

    # retrieval: 1 user row vs n_candidates item rows
    NC = shape.params["n_candidates"]
    Fu = cfg.n_fields // 2
    Fc = cfg.n_fields - Fu
    user_spec = SDS((1, Fu), I32)
    cand_spec = SDS((NC, Fc), I32)

    def step(params, user_ids, cand_ids):
        return retrieval_score(params, cfg, user_ids, cand_ids)

    return BuiltCell(
        name=f"{arch}@{shape.name}", fn=step,
        args=(params_spec, user_spec, cand_spec),
        in_shardings=(p_sh, _repl(mesh),
                      _batch_sharding(mesh, (NC, Fc), (None,))),
        out_shardings=_batch_sharding(mesh, (NC,)),
        meta={"cfg": cfg, "kind": "retrieval"})
