"""Cell registry: enumerate and build every assigned (arch × shape) cell."""

from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh

from .archs import ALL_ARCHS, ARCH_FAMILY, full_config, smoke_config
from .shapes import shape_table
from .steps import BuiltCell, build_gnn_cell, build_lm_cell, build_recsys_cell

__all__ = ["all_cells", "build_cell", "ALL_ARCHS", "ARCH_FAMILY",
           "full_config", "smoke_config"]


def all_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch, shape) pairs."""
    cells = []
    for arch in ALL_ARCHS:
        for shape_name in shape_table(ARCH_FAMILY[arch]):
            cells.append((arch, shape_name))
    return cells


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               overrides: Optional[dict] = None,
               direction: str = "pull", zero: str = "pull") -> BuiltCell:
    from ..dist.sharding import set_activation_mesh
    family = ARCH_FAMILY[arch]
    shape = shape_table(family)[shape_name]
    # activation-sharding hints trace against this mesh at lower time
    set_activation_mesh(mesh)
    if family == "lm":
        return build_lm_cell(arch, shape, mesh, zero=zero,
                             overrides=overrides)
    if family == "gnn":
        return build_gnn_cell(arch, shape, mesh, direction=direction,
                              overrides=overrides)
    return build_recsys_cell(arch, shape, mesh)
