"""The 10 assigned architecture configs, verbatim from the task spec.

One builder per arch id returning the exact full config, plus a reduced
smoke config of the same family for CPU tests. Sources are cited in the
spec ([hf]/[paper] tiers); deviations are noted inline.
"""

from __future__ import annotations

from ..models.gnn import GNNConfig
from ..models.moe import MoEConfig
from ..models.recsys import XDeepFMConfig
from ..models.transformer import TransformerConfig

__all__ = ["ARCH_FAMILY", "full_config", "smoke_config", "ALL_ARCHS"]

ARCH_FAMILY = {
    "llama3.2-1b": "lm",
    "qwen1.5-32b": "lm",
    "gemma2-9b": "lm",
    "moonshot-v1-16b-a3b": "lm",
    "deepseek-moe-16b": "lm",
    "egnn": "gnn",
    "gin-tu": "gnn",
    "graphsage-reddit": "gnn",
    "graphcast": "gnn",
    "xdeepfm": "recsys",
}
ALL_ARCHS = list(ARCH_FAMILY)


def full_config(arch: str):
    if arch == "llama3.2-1b":
        # 16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256
        return TransformerConfig(
            name=arch, n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
            d_ff=8192, vocab=128256, rope_theta=500000.0)
    if arch == "qwen1.5-32b":
        # 64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064, QKV bias
        return TransformerConfig(
            name=arch, n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
            d_ff=27392, vocab=152064, qkv_bias=True, rope_theta=1000000.0)
    if arch == "gemma2-9b":
        # 42L d_model=3584 16H (kv=8) d_ff=14336 vocab=256000,
        # local(4096)+global alternating, logit softcaps, head_dim=256
        return TransformerConfig(
            name=arch, n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
            d_ff=14336, vocab=256000, head_dim=256, local_window=4096,
            attn_softcap=50.0, final_softcap=30.0, embed_scale=True,
            rope_theta=10000.0)
    if arch == "moonshot-v1-16b-a3b":
        # 48L d_model=2048 16H (kv=16) d_ff=1408 vocab=163840, MoE 64e top-6
        return TransformerConfig(
            name=arch, n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
            d_ff=1408, vocab=163840,
            moe=MoEConfig(d_model=2048, d_ff_expert=1408, n_experts=64,
                          top_k=6, n_shared=2, dispatch="pull"))
    if arch == "deepseek-moe-16b":
        # 28L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400,
        # 2 shared + 64 routed top-6 (fine-grained)
        return TransformerConfig(
            name=arch, n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
            d_ff=1408, vocab=102400,
            moe=MoEConfig(d_model=2048, d_ff_expert=1408, n_experts=64,
                          top_k=6, n_shared=2, dispatch="pull"))
    if arch == "egnn":
        return GNNConfig(arch=arch, n_layers=4, d_hidden=64,
                         d_in=0, d_out=0)        # d_in/out set per shape
    if arch == "gin-tu":
        return GNNConfig(arch=arch, n_layers=5, d_hidden=64,
                         d_in=0, d_out=0, aggregator="sum")
    if arch == "graphsage-reddit":
        return GNNConfig(arch=arch, n_layers=2, d_hidden=128,
                         d_in=0, d_out=0, aggregator="mean",
                         fanouts=(25, 10))
    if arch == "graphcast":
        return GNNConfig(arch=arch, n_layers=16, d_hidden=512,
                         d_in=0, d_out=0, n_vars=227)
    if arch == "xdeepfm":
        # vocab per field: 2^20 (≈1M Criteo-scale; power of two so the
        # row-sharded tables divide every mesh)
        return XDeepFMConfig(n_fields=39, vocab_per_field=1 << 20,
                             embed_dim=10, cin_layers=(200, 200, 200),
                             mlp_dims=(400, 400))
    raise KeyError(arch)


def smoke_config(arch: str):
    """Reduced same-family config: runs a CPU forward/train step."""
    if arch == "llama3.2-1b":
        return TransformerConfig(
            name=arch + "-smoke", n_layers=2, d_model=64, n_heads=8,
            n_kv_heads=2, d_ff=128, vocab=256, dtype="float32",
            loss_chunk=32, attn_impl="naive")
    if arch == "qwen1.5-32b":
        return TransformerConfig(
            name=arch + "-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=4, d_ff=160, vocab=256, qkv_bias=True,
            dtype="float32", loss_chunk=32, attn_impl="naive")
    if arch == "gemma2-9b":
        return TransformerConfig(
            name=arch + "-smoke", n_layers=4, d_model=64, n_heads=4,
            n_kv_heads=2, d_ff=128, vocab=256, head_dim=32, local_window=8,
            attn_softcap=50.0, final_softcap=30.0, embed_scale=True,
            dtype="float32", loss_chunk=32, attn_impl="naive")
    if arch in ("moonshot-v1-16b-a3b", "deepseek-moe-16b"):
        return TransformerConfig(
            name=arch + "-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=4, d_ff=96, vocab=256, dtype="float32",
            loss_chunk=32, attn_impl="naive",
            moe=MoEConfig(d_model=64, d_ff_expert=96, n_experts=8, top_k=2,
                          n_shared=2, dispatch="pull"))
    if arch == "egnn":
        return GNNConfig(arch=arch, n_layers=2, d_hidden=16, d_in=8,
                         d_out=4)
    if arch == "gin-tu":
        return GNNConfig(arch=arch, n_layers=2, d_hidden=16, d_in=8,
                         d_out=4)
    if arch == "graphsage-reddit":
        return GNNConfig(arch=arch, n_layers=2, d_hidden=16, d_in=8,
                         d_out=4, fanouts=(5, 3))
    if arch == "graphcast":
        return GNNConfig(arch=arch, n_layers=2, d_hidden=16, d_in=0,
                         d_out=0, n_vars=9)
    if arch == "xdeepfm":
        return XDeepFMConfig(n_fields=7, vocab_per_field=64, embed_dim=6,
                             cin_layers=(8, 8), mlp_dims=(16, 16))
    raise KeyError(arch)
