from .archs import ALL_ARCHS, ARCH_FAMILY, full_config, smoke_config
from .shapes import LM_SHAPES, GNN_SHAPES, RECSYS_SHAPES, shape_table
from .registry import all_cells, build_cell

__all__ = [
    "ALL_ARCHS", "ARCH_FAMILY", "full_config", "smoke_config",
    "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES", "shape_table",
    "all_cells", "build_cell",
]
