"""Block-size autotuner for the push/pull Pallas kernels.

The right tile shape depends on the execution mode and the graph shape:
compiled TPU kernels want VMEM-sized tiles; the interpreter (CPU CI)
amortizes per-grid-step overhead with the largest block that fits. A
static choice is wrong for one of the two, so the ``PallasBackend``
probes a small candidate ladder **once per (graph shape, payload
shape)** and caches the winner on the backend instance.

Probing is eager and synthetic: candidates are timed on random data of
the *shape* being solved (gather/scatter cost is shape-dominated, not
value-dominated), so the tuner can run while an outer ``jit`` trace is
being built — which is exactly when the backend discovers a new shape.
Each probe is one warmup (compile) + one timed call; the ladder is kept
short (≤ 4 rungs) so tuning stays a per-shape one-off.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .coo_push import coo_push_pallas
from .ell_spmv import default_interpret, ell_spmv_pallas

__all__ = ["pull_candidates", "push_candidates", "tune_pull", "tune_push"]

_LADDER = (256, 1024, 4096)


def _round_up(x: int, q: int) -> int:
    return -(-x // q) * q


def pull_candidates(n: int) -> tuple[int, ...]:
    """block_n ladder for the ELL pull kernel: fixed rungs below n plus
    the whole (padded) vertex range (grid of 1 — what the interpreter
    prefers; real TPUs pick a VMEM-sized rung)."""
    n_pad = _round_up(max(n, 8), 8)
    cands = [c for c in _LADDER if c < n_pad]
    cands.append(n_pad)
    return tuple(cands)


def push_candidates(n: int, m: int) -> tuple[int, ...]:
    """(block_e, block_n) ladder for the COO push kernel. Every rung
    keeps ``block_e + block_n >= n`` so the window precondition holds
    statically and no rung silently drops edges."""
    m_pad = _round_up(max(m, 8), 8)
    n_pad = _round_up(max(n, 8), 8)
    cands = [(c, n_pad) for c in _LADDER if c < m_pad]
    cands.append((m_pad, n_pad))
    return tuple(cands)


def _time(fn, *args) -> float:
    jax.block_until_ready(fn(*args))              # warmup = compile
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


# Probes run while the backend is being traced into an engine loop, and
# JAX's trace context is ambient (thread-local): any op issued here —
# even on concrete arrays — would be spliced into the engine's jaxpr
# instead of executing. A single worker thread has no ambient trace, so
# candidates execute (and are timed) for real.
_EXECUTOR = None


def _escaped(fn):
    global _EXECUTOR
    if _EXECUTOR is None:
        from concurrent.futures import ThreadPoolExecutor
        _EXECUTOR = ThreadPoolExecutor(max_workers=1,
                                       thread_name_prefix="kernel-tune")
    return _EXECUTOR.submit(fn).result()


def tune_pull(n: int, d_ell: int, width: int, dtype, combine: str,
              msg: str, interpret: bool | None = None) -> int:
    """Best ``block_n`` for an ELL pull of this shape (synthetic probe)."""
    if interpret is None:
        interpret = default_interpret()
    cands = pull_candidates(n)
    if len(cands) == 1:                   # nothing to probe
        return cands[0]

    def probe():
        key = jax.random.PRNGKey(0)
        idx = jax.random.randint(key, (n, d_ell), 0, n + 1, jnp.int32)
        w = jnp.ones((n, d_ell), jnp.float32)
        shape = (n + 1,) if width == 1 else (n + 1, width)
        x = jnp.ones(shape, dtype)
        best, best_t = None, None
        for block_n in cands:
            t = _time(lambda b=block_n: ell_spmv_pallas(
                x, idx, w, combine=combine, msg=msg, block_n=b,
                interpret=interpret))
            if best_t is None or t < best_t:
                best, best_t = block_n, t
        return best

    return _escaped(probe)


def tune_push(n: int, m: int, width: int, dtype, combine: str,
              msg: str, interpret: bool | None = None) -> tuple[int, int]:
    """Best ``(block_e, block_n)`` for a COO push of this shape."""
    if interpret is None:
        interpret = default_interpret()
    cands = push_candidates(n, m)
    if len(cands) == 1:
        return cands[0]

    def probe():
        key = jax.random.PRNGKey(1)
        dst = jnp.sort(jax.random.randint(key, (m,), 0, n, jnp.int32))
        src = jax.random.randint(jax.random.fold_in(key, 1), (m,), 0, n,
                                 jnp.int32)
        w = jnp.ones((m,), jnp.float32)
        shape = (n,) if width == 1 else (n, width)
        x = jnp.ones(shape, dtype)
        active = jnp.ones((n,), bool)
        best, best_t = None, None
        for block_e, block_n in cands:
            t = _time(lambda be=block_e, bn=block_n: coo_push_pallas(
                x, active, src, dst, w, n, combine=combine, msg=msg,
                block_e=be, block_n=bn, interpret=interpret))
            if best_t is None or t < best_t:
                best, best_t = (block_e, block_n), t
        return best

    return _escaped(probe)
