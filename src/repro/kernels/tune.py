"""Autotuner for the push/pull Pallas kernels: real search, disk cache.

The right configuration depends on the execution mode and the shape:
compiled TPU kernels want VMEM-sized tiles and the MXU reduce; the
interpreter (CPU CI) wants the largest block that amortizes per-step
overhead and the bandwidth-bound scan reduce. A static choice is wrong
for one of the two, so the ``PallasBackend`` probes a candidate grid
once per (graph shape, payload shape, platform) and caches the winner
twice over:

  * **on disk** under ``~/.cache/repro/tune.json`` (override with
    ``$REPRO_CACHE_DIR``), keyed by platform × kernel × shape × dtype ×
    combine × msg, so repeated runs (benchmarks, CI, services) skip the
    probe entirely;
  * **in memory** (module-level dict), which also serves as the
    fallback when the cache directory is unwritable.

Search space — pull: ``block_n`` rungs; push: the (block_e, block_n
= bin width, strategy) grid over both phase-2 reduce strategies
(``"scan"`` | ``"mxu"``). Probes time each candidate on synthetic data
of the shape being solved (one warmup + one timed call) with **early
pruning**: candidates are grouped by (strategy, bin width), and a
group whose first rung lands ≥ ``_PRUNE``× behind the incumbent is
abandoned — the rest of its rungs only move block_e, which never
recovers that much.

Probing is eager and runs in a single worker thread so it escapes any
ambient jit trace (the backend discovers new shapes mid-trace).
"""

from __future__ import annotations

import json
import os
import threading
import time

import jax
import jax.numpy as jnp

from ..resilience import FaultInjected, ProbeTimeout, fault_point, note
from .coo_push import build_push_plan, coo_push_pallas
from .ell_spmv import default_interpret, ell_spmv_pallas

__all__ = ["pull_candidates", "pull_frontier_candidates",
           "push_candidates", "tune_pull", "tune_pull_frontier",
           "tune_push", "cache_dir", "clear_memory_cache"]

_PULL_LADDER = (128, 256, 512, 1024, 2048, 4096)
_EDGE_LADDER = (1024, 4096, 16384)
_BIN_LADDER = (128, 256, 1024)
_PRUNE = 2.0


def _round_up(x: int, q: int) -> int:
    return -(-x // q) * q


def pull_candidates(n: int, width: int | None = None) -> tuple[int, ...]:
    """``block_n`` rungs for the ELL pull kernel: the ladder below n
    plus the whole (padded) vertex range. Single-column payloads
    (``width == 1``) drop the full-row rung whenever sub-n rungs
    exist — the b1 gather is too thin to amortize a grid of one, and
    the full-row rung measurably loses to jnp there (the
    kernel_pull_*_b1 regression)."""
    n_pad = _round_up(max(n, 8), 8)
    cands = [c for c in _PULL_LADDER if c < n_pad]
    if not (width == 1 and cands):
        cands.append(n_pad)
    return tuple(cands)


def pull_frontier_candidates(n: int, rows: int) -> tuple[int, ...]:
    """``block_r`` rungs for the frontier pull kernel, keyed by frontier
    density: the grid tiles the compacted ``rows`` touched-row list (not
    the vertex range), so the useful rungs shrink with ``rows / n``. A
    sparse frontier (few hundred rows) wants one or two tiles; only
    near-full frontiers see the deep ladder. Rungs are the pull ladder
    clipped below the padded row count, plus the whole-range rung."""
    r_pad = _round_up(max(rows, 8), 8)
    cands = [c for c in _PULL_LADDER if c < r_pad]
    cands.append(r_pad)
    return tuple(cands)


def push_candidates(n: int, m: int) -> tuple[tuple[int, int, str], ...]:
    """(block_e, block_n, strategy) grid for the two-phase push kernel.

    ``block_n`` is the destination-bin width (phase 1), ``block_e`` the
    streamed edge-chunk size (phase 2), ``strategy`` the reduce. Scan
    rungs cover the full bin ladder; MXU rungs are limited to bins the
    window/one-hot expansion can afford (its work is bin_n × cap).
    Ordered scan-first so pruning meets the incumbent early.
    """
    n_pad = _round_up(max(n, 8), 8)
    m_pad = _round_up(max(m, 8), 8)
    bins = sorted({min(b, n_pad) for b in _BIN_LADDER} | {n_pad})
    edges = sorted({min(e, m_pad) for e in _EDGE_LADDER} | {m_pad})
    cands = [(e, b, "scan") for b in bins for e in edges]
    cands += [(e, b, "mxu") for b in bins if b <= 256 for e in edges]
    return tuple(cands)


# -- persistent cache ---------------------------------------------------
_MEM_CACHE: dict[str, tuple] = {}
_DISK: dict | None = None
_LOCK = threading.Lock()

# probe/cache outcome counters, exported to repro.obs as `tuner.*` —
# the cheap answer to "did this run pay autotuning, or ride the cache?"
# plus the resilience tail: probe retries/timeouts/failures and how
# often the tuner degraded to the default candidate
_STATS = {"mem_hits": 0, "disk_hits": 0, "misses": 0, "probes": 0,
          "writes": 0, "write_errors": 0, "probe_retries": 0,
          "probe_timeouts": 0, "probe_failures": 0, "probe_degraded": 0}


def tune_stats() -> dict[str, int]:
    """Snapshot of the process-wide tuner counters (copies, safe to
    mutate)."""
    with _LOCK:
        return dict(_STATS)


def clear_stats() -> None:
    with _LOCK:
        for k in _STATS:
            _STATS[k] = 0


def cache_dir() -> str:
    return os.environ.get(
        "REPRO_CACHE_DIR",
        os.path.join(os.path.expanduser("~"), ".cache", "repro"))


def _cache_path() -> str:
    return os.path.join(cache_dir(), "tune.json")


def clear_memory_cache() -> None:
    """Drop the in-memory tier (tests re-point $REPRO_CACHE_DIR)."""
    global _DISK
    with _LOCK:
        _MEM_CACHE.clear()
        _DISK = None


def _platform(interpret: bool) -> str:
    return "interpret" if interpret else jax.default_backend()


def _cache_key(kernel: str, interpret: bool, shape: tuple, width: int,
               dtype, combine: str, msg: str) -> str:
    dims = "x".join(str(s) for s in shape)
    return (f"{_platform(interpret)}|{kernel}|{dims}|w{width}|"
            f"{jnp.dtype(dtype).name}|{combine}|{msg}")


def _load_disk() -> dict:
    """Load the on-disk tier, surviving anything a crashed or racing
    writer can leave behind: a missing/unreadable file, truncated or
    garbage JSON, or a file that parses to a non-dict value. Every
    failure mode degrades to an empty dict — the in-memory tier keeps
    serving, and the next ``_cache_put`` atomically rewrites a valid
    file over the corpse."""
    try:
        fault_point("tune.cache.load")
        with open(_cache_path()) as f:
            data = json.load(f)
    except (OSError, ValueError, FaultInjected):
        return {}
    return data if isinstance(data, dict) else {}


def _cache_get(key: str):
    global _DISK
    with _LOCK:
        if key in _MEM_CACHE:
            _STATS["mem_hits"] += 1
            return _MEM_CACHE[key]
        if _DISK is None:
            _DISK = _load_disk()
        hit = _DISK.get(key)
        if hit is not None:
            _STATS["disk_hits"] += 1
            hit = tuple(hit) if isinstance(hit, list) else hit
            _MEM_CACHE[key] = hit
        else:
            _STATS["misses"] += 1
        return hit


def _cache_put(key: str, value) -> None:
    global _DISK
    with _LOCK:
        _STATS["writes"] += 1
        _MEM_CACHE[key] = value
        if _DISK is None:
            _DISK = {}
        _DISK[key] = list(value) if isinstance(value, tuple) else value
        path = _cache_path()
        try:
            fault_point("tune.cache.write")
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = f"{path}.{os.getpid()}.tmp"
            with open(tmp, "w") as f:
                json.dump(_DISK, f, indent=0, sort_keys=True)
            os.replace(tmp, path)
        except (OSError, FaultInjected):
            # unwritable home (or an injected disk fault): the
            # in-memory tier still serves
            _STATS["write_errors"] += 1


def _time(fn, *args) -> float:
    jax.block_until_ready(fn(*args))              # warmup = compile
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


# Probes run while the backend is being traced into an engine loop, and
# JAX's trace context is ambient (thread-local): any op issued here —
# even on concrete arrays — would be spliced into the engine's jaxpr
# instead of executing. A fresh thread has no ambient trace, so
# candidates execute (and are timed) for real. One daemon thread per
# probe (probes are once-per-shape rare) so a *hung* probe can be
# abandoned at the deadline without wedging later probes or process
# exit — a shared worker would stay stuck behind the corpse.


def _escaped(fn, deadline=None, kernel: str = "?"):
    box: dict = {}

    def run():
        try:
            box["value"] = fn()
        except BaseException as e:  # noqa: BLE001 — re-raised below
            box["error"] = e

    t = threading.Thread(target=run, name="kernel-tune", daemon=True)
    t.start()
    t.join(deadline)
    if t.is_alive():
        raise ProbeTimeout(kernel, deadline)
    if "error" in box:
        raise box["error"]
    return box["value"]


def _probe_deadline_s() -> float:
    return float(os.environ.get("REPRO_TUNE_DEADLINE_S", "120"))


def _probe_retries() -> int:
    return int(os.environ.get("REPRO_TUNE_RETRIES", "2"))


def _probe_guarded(kernel: str, probe, default):
    """Run ``probe`` off-thread under the wall deadline with bounded
    retry-with-backoff; returns ``(winner, probed)``. Exhausted
    attempts degrade to ``default`` (``probed=False`` — the caller must
    NOT persist it, so a healthy later run re-probes)."""
    deadline, retries = _probe_deadline_s(), _probe_retries()

    def attempt_fn():
        fault_point("tune.probe")
        return probe()

    for attempt in range(retries + 1):
        try:
            return _escaped(attempt_fn, deadline=deadline,
                            kernel=kernel), True
        except Exception as e:   # noqa: BLE001 — chaos/flake seam
            timed_out = isinstance(e, ProbeTimeout)
            with _LOCK:
                _STATS["probe_timeouts" if timed_out
                       else "probe_failures"] += 1
            if attempt < retries:
                with _LOCK:
                    _STATS["probe_retries"] += 1
                note("retry.tune.probe", kernel=kernel,
                     attempt=attempt + 1, error=type(e).__name__)
                time.sleep(min(0.02 * (2 ** attempt), 0.5))
    with _LOCK:
        _STATS["probe_degraded"] += 1
    note("degraded.tune.probe", kernel=kernel, default=str(default))
    return default, False


def tune_pull(n: int, d_ell: int, width: int, dtype, combine: str,
              msg: str, interpret: bool | None = None) -> int:
    """Best ``block_n`` for an ELL pull of this shape (synthetic probe,
    shape-and-platform-keyed, persisted)."""
    if interpret is None:
        interpret = default_interpret()
    cands = pull_candidates(n, width)
    if len(cands) == 1:                   # nothing to probe
        return cands[0]
    key = _cache_key("pull", interpret, (n, d_ell), width, dtype,
                     combine, msg)
    hit = _cache_get(key)
    if hit is not None:
        try:
            return int(hit)
        except (TypeError, ValueError):
            pass   # poisoned cache entry: fall through and re-probe

    def probe():
        key_ = jax.random.PRNGKey(0)
        idx = jax.random.randint(key_, (n, d_ell), 0, n + 1, jnp.int32)
        w = jnp.ones((n, d_ell), jnp.float32)
        shape = (n + 1,) if width == 1 else (n + 1, width)
        x = jnp.ones(shape, dtype)
        best, best_t = None, None
        for block_n in cands:
            t = _time(lambda b=block_n: ell_spmv_pallas(
                x, idx, w, combine=combine, msg=msg, block_n=b,
                interpret=interpret))
            if best_t is None or t < best_t:
                best, best_t = block_n, t
        return best

    with _LOCK:
        _STATS["probes"] += 1
    best, probed = _probe_guarded("pull", probe, cands[0])
    if probed:
        _cache_put(key, best)
    return best


def tune_pull_frontier(n: int, d_ell: int, rows: int, width: int, dtype,
                       combine: str, msg: str,
                       interpret: bool | None = None) -> int:
    """Best ``block_r`` for a frontier pull of this shape. Keyed by the
    compacted row capacity (= frontier density × n) on top of the usual
    shape key: a 64-row BFS tail and a half-full frontier over the same
    graph want different tiles, so they tune — and cache — separately."""
    if interpret is None:
        interpret = default_interpret()
    cands = pull_frontier_candidates(n, rows)
    if len(cands) == 1:
        return cands[0]
    key = _cache_key("pullf", interpret, (n, d_ell, rows), width, dtype,
                     combine, msg)
    hit = _cache_get(key)
    if hit is not None:
        try:
            return int(hit)
        except (TypeError, ValueError):
            pass   # poisoned cache entry: fall through and re-probe

    def probe():
        from .ell_pull_frontier import ell_pull_frontier_pallas
        key_ = jax.random.PRNGKey(2)
        idx = jax.random.randint(key_, (n, d_ell), 0, n + 1, jnp.int32)
        w = jnp.ones((n, d_ell), jnp.float32)
        shape = (n + 1,) if width == 1 else (n + 1, width)
        x = jnp.ones(shape, dtype)
        rids = jax.random.permutation(
            jax.random.fold_in(key_, 1), n)[:rows].astype(jnp.int32)
        rids = jnp.pad(rids, (0, max(0, rows - n)), constant_values=n)
        best, best_t = None, None
        for block_r in cands:
            t = _time(lambda b=block_r: ell_pull_frontier_pallas(
                x, idx, w, rids, combine=combine, msg=msg, block_r=b,
                interpret=interpret))
            if best_t is None or t < best_t:
                best, best_t = block_r, t
        return best

    with _LOCK:
        _STATS["probes"] += 1
    best, probed = _probe_guarded("pullf", probe, cands[0])
    if probed:
        _cache_put(key, best)
    return best


def tune_push(n: int, m: int, width: int, dtype, combine: str,
              msg: str, interpret: bool | None = None
              ) -> tuple[int, int, str]:
    """Best ``(block_e, block_n, strategy)`` for a two-phase push of
    this shape: grid search with early pruning, shape-and-platform-
    keyed, persisted to the on-disk cache."""
    if interpret is None:
        interpret = default_interpret()
    cands = push_candidates(n, m)
    if len(cands) == 1:
        return cands[0]
    key = _cache_key("push", interpret, (n, m), width, dtype, combine,
                     msg)
    hit = _cache_get(key)
    if hit is not None:
        try:
            be, bn, strat = hit
            return int(be), int(bn), str(strat)
        except (TypeError, ValueError):
            pass   # poisoned cache entry: fall through and re-probe

    def probe():
        key_ = jax.random.PRNGKey(1)
        dst = jnp.sort(jax.random.randint(key_, (m,), 0, n, jnp.int32))
        src = jax.random.randint(jax.random.fold_in(key_, 1), (m,), 0,
                                 n, jnp.int32)
        w = jnp.ones((m,), jnp.float32)
        shape = (n,) if width == 1 else (n, width)
        x = jnp.ones(shape, dtype)
        active = jnp.ones((n,), bool)
        plans: dict[tuple[int, int], object] = {}
        best, best_t = None, None
        pruned: set[tuple[str, int]] = set()
        group_seen: set[tuple[str, int]] = set()
        for block_e, block_n, strategy in cands:
            group = (strategy, block_n)
            if group in pruned:
                continue
            pkey = (block_n, block_e)
            if pkey not in plans:
                plans[pkey] = build_push_plan(src, dst, w, n, block_n,
                                              align=block_e)
            t = _time(lambda be=block_e, bn=block_n, st=strategy,
                      p=plans[pkey]: coo_push_pallas(
                x, active, src, dst, w, n, combine=combine, msg=msg,
                block_e=be, block_n=bn, interpret=interpret, plan=p,
                strategy=st))
            first = group not in group_seen
            group_seen.add(group)
            if best_t is None or t < best_t:
                best, best_t = (block_e, block_n, strategy), t
            elif first and t > _PRUNE * best_t:
                pruned.add(group)    # the rest of the group only moves
                continue             # block_e; it won't close a 2x gap
        return best

    with _LOCK:
        _STATS["probes"] += 1
    best, probed = _probe_guarded("push", probe, cands[0])
    if probed:
        _cache_put(key, best)
    return best
