"""Pallas TPU kernel: frontier-masked push relaxation (segment combine).

Paper hot spot: the push k-relaxation — active sources scatter combined
updates into destination slots (CSC SpMSpV, §7.1). On CPU this is an
atomic per edge; the TPU adaptation replaces atomics with **tile-serial
combining**: edges arrive sorted by destination, the grid walks edge
tiles *sequentially*, and each tile accumulates into an output vector
held resident across grid steps. Combining a sum inside a tile uses a
one-hot matmul (MXU-friendly CRCW-CB combine); max/min combine via a
masked window reduce; cross-tile conflicts are resolved by the
sequential grid — deterministic, atomic-free.

Window invariant: ``block_e`` consecutive dst-sorted edges touch at most
``block_e`` distinct destinations, so a window of ``block_e + block_n``
anchored at the tile's first destination block covers the tile **when
the tile's destination span fits the window** (always true when
``block_e + block_n >= n``; :func:`push_window_fits` checks the general
case so callers can guard with ``lax.cond`` — the PallasBackend does).

Frontier masking implements the SpMSpV sparsity: edges whose source is
inactive contribute the identity. Padded edges carry the sentinel
``n`` on *both* endpoints and are masked on both (padding used to aim
at the real vertex ``n - 1``; see tests/test_pallas_backend.py for the
regression). The accumulator is kept whole (fits VMEM for the
kernel-benchmark sizes; a production variant would shard nodes over
cores — see DESIGN.md §9).

Production surface matches ``ell_spmv_pallas``: combine ∈
{sum, max, min}, payloads [n] or [n, B], float32/float64/int32/int64,
msg ∈ {"mul", "copy", "add"}, ``interpret=None`` auto-detect.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.primitives import combine_identity
from .ell_spmv import default_interpret

__all__ = ["coo_push_pallas", "push_window_fits"]


def push_window_fits(dst: jax.Array, n: int, block_e: int,
                     block_n: int) -> jax.Array:
    """True iff every ``block_e`` edge tile's destination span fits the
    ``block_e + block_n`` accumulation window — the kernel's coverage
    precondition. Statically true when the window covers all of [0, n);
    otherwise a cheap traced reduction over the dst vector (callers
    guard the kernel with ``lax.cond`` on it)."""
    win = block_e + block_n
    if win >= n:
        return jnp.bool_(True)
    m = dst.shape[0]
    m_pad = -(-m // block_e) * block_e
    dstp = jnp.pad(dst, (0, m_pad - m), constant_values=n).reshape(
        -1, block_e)
    first = dstp[:, 0]
    anchors = (first // block_n) * block_n
    last = jnp.max(jnp.where(dstp < n, dstp, -1), axis=1)
    return jnp.all(last - anchors < win)


def _combine_window(window, local, combine: str):
    if combine == "sum":
        return window + local
    if combine == "max":
        return jnp.maximum(window, local)
    return jnp.minimum(window, local)


def _kernel(x_ref, active_ref, src_ref, dst_ref, w_ref, dstblk_ref,
            acc_ref, *, n: int, combine: str, msg: str, win: int):
    e = pl.program_id(0)
    ident = combine_identity(combine, acc_ref.dtype)

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, ident)

    src = src_ref[...]
    dst = dst_ref[...]
    w = w_ref[...]
    # sentinel-padded edges carry n on both endpoints: mask on both
    valid = (src < n) & (dst < n)
    safe_src = jnp.where(valid, src, 0)
    x = x_ref[safe_src]                    # [block_e(, B)]
    act = active_ref[safe_src] > 0
    if msg == "copy":
        m_val = x
    else:
        wb = w[..., None] if x.ndim == 2 else w
        m_val = x * wb if msg == "mul" else x + wb
    base = dstblk_ref[0]
    rel = dst - base                       # in [0, win) when it fits
    ok = valid & act & (rel >= 0) & (rel < win)
    rel = jnp.clip(rel, 0, win - 1)
    if m_val.ndim == 2:
        ok = ok[:, None]
    m_val = jnp.where(ok, m_val, ident)
    if combine == "sum" and jnp.issubdtype(acc_ref.dtype, jnp.floating):
        # CRCW-CB combine inside the tile: one-hot matmul (MXU on TPU)
        onehot = (rel[None, :] == jnp.arange(win)[:, None]).astype(
            acc_ref.dtype)
        local = onehot @ m_val             # [win(, B)]
    else:
        # masked window reduce (max/min and integer sums)
        sel = rel[None, :] == jnp.arange(win)[:, None]   # [win, block_e]
        if m_val.ndim == 2:
            sel = sel[..., None]
        expanded = jnp.where(sel, m_val[None, ...], ident)
        if combine == "sum":
            # cast back: segment_sum (the primitive this must match)
            # accumulates in the message dtype, unlike jnp.sum
            local = expanded.sum(axis=1).astype(acc_ref.dtype)
        elif combine == "max":
            local = expanded.max(axis=1)
        else:
            local = expanded.min(axis=1)
    if acc_ref.ndim == 2:
        zero = jnp.zeros((), base.dtype)
        window = jax.lax.dynamic_slice(
            acc_ref[...], (base, zero), (win, acc_ref.shape[1]))
        acc_ref[...] = jax.lax.dynamic_update_slice(
            acc_ref[...], _combine_window(window, local, combine),
            (base, zero))
    else:
        window = jax.lax.dynamic_slice(acc_ref[...], (base,), (win,))
        acc_ref[...] = jax.lax.dynamic_update_slice(
            acc_ref[...], _combine_window(window, local, combine),
            (base,))


@functools.partial(jax.jit,
                   static_argnames=("n", "combine", "msg", "block_e",
                                    "block_n", "interpret"))
def coo_push_pallas(x: jax.Array, active: jax.Array, src: jax.Array,
                    dst: jax.Array, w: jax.Array, n: int,
                    combine: str = "sum", msg: str = "mul",
                    block_e: int = 512, block_n: int = 256,
                    interpret: bool | None = None) -> jax.Array:
    """Push-combine over dst-sorted COO edges.

    x: [n] or [n, B] source payloads; active: bool[n] frontier;
    src/dst: i32[m] (sorted by dst); w: f32[m]. Returns combined
    updates per destination ([n] or [n, B]); destinations with no
    active in-edge hold the combine identity.

    Precondition: :func:`push_window_fits` — callers with graphs that
    can violate it guard with ``lax.cond`` (see PallasBackend.push).
    """
    if interpret is None:
        interpret = default_interpret()
    m = src.shape[0]
    out_dtype = (x.dtype if msg == "copy"
                 else jnp.result_type(x.dtype, w.dtype))
    if m == 0:
        # edgeless graph: grid=(0,) would never run the init step (and
        # pallas rejects empty edge operands) — no edges means every
        # destination holds the combine identity, like segment ops
        shape = (n,) if x.ndim == 1 else (n, x.shape[1])
        return jnp.full(shape, combine_identity(combine, out_dtype),
                        out_dtype)
    win = block_e + block_n
    m_pad = -(-m // block_e) * block_e
    srcp = jnp.pad(src, (0, m_pad - m), constant_values=n)
    # sentinel >= n on the destination too — padding must never alias a
    # real vertex (n - 1 previously; masked only via src, fragile)
    dstp = jnp.pad(dst, (0, m_pad - m), constant_values=n)
    wp = jnp.pad(w, (0, m_pad - m))
    n_pad = -(-n // block_n) * block_n + win
    first_dst = dstp.reshape(-1, block_e)[:, 0]
    anchors = jnp.minimum((first_dst // block_n) * block_n,
                          n_pad - win).astype(jnp.int32)
    grid = (m_pad // block_e,)
    batched = x.ndim == 2
    if batched:
        b = x.shape[1]
        acc_spec = pl.BlockSpec((n_pad, b), lambda e: (0, 0))
        acc_shape = jax.ShapeDtypeStruct((n_pad, b), out_dtype)
        x_spec = pl.BlockSpec(x.shape, lambda e: (0, 0))
    else:
        acc_spec = pl.BlockSpec((n_pad,), lambda e: (0,))
        acc_shape = jax.ShapeDtypeStruct((n_pad,), out_dtype)
        x_spec = pl.BlockSpec(x.shape, lambda e: (0,))
    acc = pl.pallas_call(
        functools.partial(_kernel, n=n, combine=combine, msg=msg,
                          win=win),
        grid=grid,
        in_specs=[
            x_spec,
            pl.BlockSpec(active.shape, lambda e: (0,)),
            pl.BlockSpec((block_e,), lambda e: (e,)),
            pl.BlockSpec((block_e,), lambda e: (e,)),
            pl.BlockSpec((block_e,), lambda e: (e,)),
            pl.BlockSpec((1,), lambda e: (e,)),
        ],
        out_specs=acc_spec,
        out_shape=acc_shape,
        interpret=interpret,
    )(x, active.astype(jnp.int32), srcp, dstp, wp, anchors)
    return acc[:n]
