"""Pallas TPU kernel: frontier-masked push relaxation (segment combine).

Paper hot spot: the push k-relaxation — active sources scatter combined
updates into destination slots (CSC SpMSpV, §7.1). On CPU this is an
atomic per edge; the TPU adaptation replaces atomics with **tile-serial
combining**: edges arrive sorted by destination, the grid walks edge
tiles *sequentially*, and each tile accumulates into an output vector
held resident across grid steps. Combining inside a tile uses a one-hot
matmul (MXU-friendly CRCW-CB combine); cross-tile conflicts are resolved
by the sequential grid — deterministic, atomic-free.

Window invariant: ``block_e`` consecutive dst-sorted edges touch at most
``block_e`` distinct destinations, so a window of ``block_e + block_n``
anchored at the tile's first destination block always covers the tile.

Frontier masking implements the SpMSpV sparsity: edges whose source is
inactive contribute the identity. The accumulator is kept whole (fits
VMEM for the kernel-benchmark sizes; a production variant would shard
nodes over cores — see DESIGN.md §9).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["coo_push_pallas"]


def _kernel(x_ref, active_ref, src_ref, dst_ref, w_ref, dstblk_ref,
            acc_ref, *, n: int, block_e: int, block_n: int, win: int):
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    src = src_ref[...]
    dst = dst_ref[...]
    w = w_ref[...]
    valid = src < n
    safe_src = jnp.where(valid, src, 0)
    x = x_ref[safe_src]
    act = active_ref[safe_src] > 0
    msg = jnp.where(valid & act, x * w, 0.0)
    base = dstblk_ref[0]
    rel = dst - base                       # in [0, win) by construction
    ok = (rel >= 0) & (rel < win)
    rel = jnp.clip(rel, 0, win - 1)
    msg = jnp.where(ok, msg, 0.0)
    # CRCW-CB combine inside the tile: one-hot matmul (MXU path on TPU)
    onehot = (rel[None, :] == jnp.arange(win)[:, None]).astype(jnp.float32)
    local = onehot @ msg                   # [win]
    window = jax.lax.dynamic_slice(acc_ref[...], (base,), (win,))
    acc_ref[...] = jax.lax.dynamic_update_slice(
        acc_ref[...], window + local, (base,))


@functools.partial(jax.jit,
                   static_argnames=("n", "block_e", "block_n", "interpret"))
def coo_push_pallas(x: jax.Array, active: jax.Array, src: jax.Array,
                    dst: jax.Array, w: jax.Array, n: int,
                    block_e: int = 512, block_n: int = 256,
                    interpret: bool = True) -> jax.Array:
    """Push-combine sum over dst-sorted COO edges.

    x: f32[n] source payloads; active: bool[n] frontier; src/dst: i32[m]
    (sorted by dst); w: f32[m]. Returns f32[n] (sum semiring).
    """
    m = src.shape[0]
    win = block_e + block_n
    m_pad = -(-m // block_e) * block_e
    srcp = jnp.pad(src, (0, m_pad - m), constant_values=n)
    dstp = jnp.pad(dst, (0, m_pad - m), constant_values=n - 1)
    wp = jnp.pad(w, (0, m_pad - m))
    n_pad = -(-n // block_n) * block_n + win
    first_dst = dstp.reshape(-1, block_e)[:, 0]
    anchors = ((first_dst // block_n) * block_n).astype(jnp.int32)
    grid = (m_pad // block_e,)
    acc = pl.pallas_call(
        functools.partial(_kernel, n=n, block_e=block_e, block_n=block_n,
                          win=win),
        grid=grid,
        in_specs=[
            pl.BlockSpec(x.shape, lambda e: (0,)),
            pl.BlockSpec(active.shape, lambda e: (0,)),
            pl.BlockSpec((block_e,), lambda e: (e,)),
            pl.BlockSpec((block_e,), lambda e: (e,)),
            pl.BlockSpec((block_e,), lambda e: (e,)),
            pl.BlockSpec((1,), lambda e: (e,)),
        ],
        out_specs=pl.BlockSpec((n_pad,), lambda e: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(x, active.astype(jnp.int32), srcp, dstp, wp, anchors)
    return acc[:n]
