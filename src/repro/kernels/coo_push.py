"""Pallas TPU kernel: two-phase contention-free push relaxation.

Paper hot spot: the push k-relaxation — active sources scatter combined
updates into destination slots (CSC SpMSpV, §7.1). On CPU this is an
atomic per edge; the old TPU adaptation serialized the whole grid to
avoid write conflicts and lost to jnp ``segment_sum`` everywhere. This
version removes the contention instead of serializing around it:

**Phase 1 — binning.** Edges are regrouped by destination *bin*: bin
``b`` owns destinations ``[b·bin_n, (b+1)·bin_n)``. Because the COO
edges are already dst-sorted, each bin is a *contiguous slice* of the
edge list; the layout is a padded ``[nb, cap]`` matrix plus a per-bin
CSR row pointer ``ptr[nb, bin_n+1]`` locating every destination's run
of edges inside its bin. Host-side the regroup goes through the
existing :func:`~repro.graphs.partition.pa_regroup_by_dst` primitive
(:func:`build_push_plan`); under a trace (the engine jits the graph)
the same layout is gathered from ``in_ptr`` (:func:`bin_plan_traced`)
with a static capacity and a runtime fits guard.

**Phase 2 — per-bin reduce.** The grid runs *in parallel over
destination bins* (axis 0) while streaming edge blocks (axis 1, which
Pallas double-buffers); each bin owns a private ``[bin_n(, B)]``
accumulator block, so no two grid cells ever write the same
destination — contention-free by construction, no atomics, no
sequential grid. Two reduce strategies, selected by the autotuner:

  * ``"scan"`` — bandwidth-bound: float/int sums gather two prefix
    sums at each destination's run boundaries (``cumsum`` + ``ptr``
    difference; floats accumulate the prefix in float64 so the
    difference never cancels below the parity tolerance), min/max use
    a segmented log-step (Hillis–Steele) scan over the dst runs. Work
    is O(cap + bin_n) per bin — what the roofline says this memory-
    bound kernel should cost.
  * ``"mxu"`` — compute-bound: the one-hot-matmul sum (float sums hit
    the MXU) and the masked window reduce (min/max, integer sums).
    O(bin_n × cap) multiply-accumulates per bin, which the MXU does
    essentially for free on real TPUs but the interpreter does not.

Sentinel discipline: padded slots carry ``n`` on both endpoints and
weight 0; they are masked to the combine identity and, in the scan
strategy, live beyond ``ptr[bin_n]`` so the boundary gathers never
read them. Destinations with no (active) in-edge hold the combine
identity — including whole edgeless bins (all-padding blocks).

Production surface matches ``ell_spmv_pallas``: combine ∈
{sum, max, min}, payloads [n] or [n, B], float32/float64/int32/int64,
msg ∈ {"mul", "copy", "add"}, ``interpret=None`` auto-detect.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.primitives import combine_identity
from .ell_spmv import default_interpret

__all__ = ["PushBinPlan", "build_push_plan", "bin_plan_traced",
           "default_bin_cap", "coo_push_pallas", "PUSH_STRATEGIES"]

PUSH_STRATEGIES = ("scan", "mxu")


def _round_up(x: int, q: int) -> int:
    return -(-x // q) * q


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PushBinPlan:
    """Phase-1 output: the per-graph bin layout the reduce phase tiles.

    ``src/dst/w`` are ``[nb, cap]`` — row ``b`` holds the (dst-sorted)
    edges whose destination falls in bin ``b``, padded with the
    sentinel ``n`` / weight 0. ``ptr`` is ``int32[nb, bin_n+1]``: the
    within-bin CSR row pointer (destination ``b·bin_n + j`` owns slots
    ``ptr[b, j]:ptr[b, j+1]`` of row ``b``; ``ptr[b, bin_n]`` is the
    bin's true edge count, so everything at or beyond it is padding).
    ``max_run`` bounds the longest single-destination run — it sizes
    the segmented scan's static pass count.
    """
    src: jax.Array   # int32[nb, cap]
    dst: jax.Array   # int32[nb, cap]
    w: jax.Array     # [nb, cap]
    ptr: jax.Array   # int32[nb, bin_n+1]
    bin_n: int = dataclasses.field(metadata=dict(static=True))
    cap: int = dataclasses.field(metadata=dict(static=True))
    nb: int = dataclasses.field(metadata=dict(static=True))
    max_run: int = dataclasses.field(metadata=dict(static=True))


def build_push_plan(src, dst, w, n: int, bin_n: int,
                    align: int = 128) -> PushBinPlan:
    """Host-side (concrete-graph) binning pass.

    Promotes :func:`~repro.graphs.partition.pa_regroup_by_dst` into the
    kernel path: the destination-owner regroup that packs the
    distributed pull exchange is exactly the phase-1 bin layout, with
    ``shard_size = bin_n`` and the row capacity aligned to the edge
    block so the reduce grid divides evenly. The within-bin order is
    the dst-sorted input order (the regroup is stable), which the scan
    strategy's run pointers require.
    """
    from ..graphs.partition import (Partition, PartitionedEdges,
                                    pa_regroup_by_dst)
    nb = max(1, _round_up(n, bin_n) // bin_n)
    part = Partition(n=n, num_parts=nb, shard_size=bin_n,
                     n_padded=nb * bin_n)
    src = np.asarray(src)
    dst = np.asarray(dst)
    w = np.asarray(w)
    m = int(src.shape[0])
    flat = PartitionedEdges(
        src=jnp.asarray(src.reshape(1, -1), jnp.int32)
        if m else jnp.full((1, 1), n, jnp.int32),
        dst=jnp.asarray(dst.reshape(1, -1), jnp.int32)
        if m else jnp.full((1, 1), n, jnp.int32),
        w=jnp.asarray(w.reshape(1, -1), jnp.float32)
        if m else jnp.zeros((1, 1), jnp.float32),
        valid=jnp.asarray(np.ones((1, max(m, 1)), bool)
                          if m else np.zeros((1, 1), bool)),
        count=jnp.asarray([m], jnp.int32), cap=max(m, 1), num_parts=1)
    binned = pa_regroup_by_dst(part, flat, n, align=align)
    bd = np.asarray(binned.dst)
    # per-bin CSR over the bin-relative destinations (rows are sorted:
    # the regroup preserves the dst-sorted input order)
    rel = np.where(bd < n, bd - np.arange(nb)[:, None] * bin_n, bin_n)
    ptr = np.stack([np.searchsorted(rel[b], np.arange(bin_n + 1))
                    for b in range(nb)]).astype(np.int32)
    runs = np.diff(ptr, axis=1)
    max_run = int(runs.max()) if runs.size else 1
    return PushBinPlan(src=binned.src, dst=binned.dst, w=binned.w,
                       ptr=jnp.asarray(ptr), bin_n=int(bin_n),
                       cap=int(binned.cap), nb=int(nb),
                       max_run=max(max_run, 1))


def default_bin_cap(n: int, m: int, d_ell: int, bin_n: int,
                    align: int) -> int:
    """Static bin capacity for the traced binning pass: twice the mean
    bin load with at least one full max-degree row, never more than the
    whole edge list. Real skew on the benchmark families is ~1.2–1.5×
    the mean, so the 2× slack fits; callers guard the residual risk
    with ``lax.cond`` on the plan's ``fits`` bit."""
    nb = max(1, _round_up(n, bin_n) // bin_n)
    mean = -(-max(m, 1) // nb)
    return _round_up(min(max(m, 1), max(d_ell, 2 * mean)),
                     max(align, 1))


def bin_plan_traced(src, dst, w, in_ptr, n: int, bin_n: int, cap: int,
                    align: int = 128, max_run: int | None = None
                    ) -> tuple[PushBinPlan, jax.Array]:
    """In-trace binning pass (the engine jits the graph, so the host
    regroup is unavailable). dst-sorted edges make every bin a
    contiguous slice of the edge list: the layout is one gather at
    ``in_ptr``-derived offsets — O(nb·cap) reads, no scatter. Returns
    ``(plan, fits)`` where ``fits`` is the runtime guard (true iff no
    bin overflows the static ``cap``); callers branch to the jnp
    segment fallback when it fails. ``max_run`` must be a static upper
    bound on any destination's in-degree (the graph's ``d_ell``
    qualifies); it defaults to ``cap``."""
    m = src.shape[0]
    nb = max(1, _round_up(n, bin_n) // bin_n)
    cap = _round_up(max(cap, 1), max(align, 1))
    if m == 0:     # edgeless graph: all-sentinel layout, trivially fits
        return PushBinPlan(
            src=jnp.full((nb, cap), n, jnp.int32),
            dst=jnp.full((nb, cap), n, jnp.int32),
            w=jnp.zeros((nb, cap), w.dtype),
            ptr=jnp.zeros((nb, bin_n + 1), jnp.int32),
            bin_n=int(bin_n), cap=int(cap), nb=int(nb),
            max_run=1), jnp.bool_(True)
    starts = jnp.minimum(jnp.arange(nb + 1, dtype=jnp.int32) * bin_n, n)
    off = in_ptr[starts]                             # [nb+1]
    counts = off[1:] - off[:-1]
    fits = jnp.max(counts) <= cap
    pos = off[:-1, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]
    in_bin = jnp.arange(cap, dtype=jnp.int32)[None, :] < counts[:, None]
    pos = jnp.where(in_bin, pos, m)                  # padding -> fill
    bsrc = jnp.take(src, pos, mode="fill", fill_value=n)
    bdst = jnp.take(dst, pos, mode="fill", fill_value=n)
    bw = jnp.take(w, pos, mode="fill", fill_value=0)
    ridx = jnp.minimum(
        starts[:-1, None] + jnp.arange(bin_n + 1, dtype=jnp.int32)[None],
        n)
    ptr = jnp.minimum(in_ptr[ridx] - off[:-1, None], cap).astype(
        jnp.int32)
    return PushBinPlan(src=bsrc, dst=bdst, w=bw, ptr=ptr,
                       bin_n=int(bin_n), cap=int(cap), nb=int(nb),
                       max_run=int(cap if max_run is None
                                   else min(max_run, cap))), fits


def _acc_combine(acc, local, combine: str):
    if combine == "sum":
        return acc + local
    if combine == "max":
        return jnp.maximum(acc, local)
    return jnp.minimum(acc, local)


def _kernel(x_ref, active_ref, src_ref, dst_ref, w_ref, ptr_ref, acc_ref,
            *, n: int, bin_n: int, combine: str, msg: str, block_e: int,
            passes: int, strategy: str):
    b = pl.program_id(0)
    e = pl.program_id(1)
    ident = combine_identity(combine, acc_ref.dtype)

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, ident)

    src = src_ref[0]
    dst = dst_ref[0]
    w = w_ref[0]
    # sentinel-padded slots carry n on both endpoints: mask everything
    valid = dst < n
    safe_src = jnp.where(valid, src, 0)
    x = x_ref[safe_src]                       # [block_e(, B)]
    act = active_ref[safe_src] > 0
    if msg == "copy":
        m_val = x
    else:
        wb = w[..., None] if x.ndim == 2 else w
        m_val = x * wb if msg == "mul" else x + wb
    ok = valid & act
    if m_val.ndim == 2:
        ok = ok[:, None]
    m_val = jnp.where(ok, m_val.astype(acc_ref.dtype), ident)
    # bin-relative destination; padding gets the one-past row bin_n so
    # it can never merge into (or one-hot onto) a real destination's run
    rel = jnp.where(valid, dst - b * bin_n, bin_n)

    if strategy == "mxu":
        in_bin = rel < bin_n
        relc = jnp.clip(rel, 0, bin_n - 1)
        if combine == "sum" and jnp.issubdtype(acc_ref.dtype,
                                               jnp.floating):
            # CRCW-CB combine on the MXU: one-hot matmul
            onehot = ((relc[None, :] == jnp.arange(bin_n)[:, None])
                      & in_bin[None, :]).astype(acc_ref.dtype)
            local = onehot @ m_val            # [bin_n(, B)]
        else:
            sel = ((relc[None, :] == jnp.arange(bin_n)[:, None])
                   & in_bin[None, :])          # [bin_n, block_e]
            if m_val.ndim == 2:
                sel = sel[..., None]
            expanded = jnp.where(sel, m_val[None, ...], ident)
            if combine == "sum":
                # segment_sum accumulates in the message dtype
                local = expanded.sum(axis=1).astype(acc_ref.dtype)
            elif combine == "max":
                local = expanded.max(axis=1)
            else:
                local = expanded.min(axis=1)
    else:
        # "scan": run boundaries from the plan's per-bin row pointer,
        # rebased to this edge chunk
        ptr = ptr_ref[0]
        base = e * block_e
        lo = jnp.clip(ptr[:-1] - base, 0, block_e)   # [bin_n]
        hi = jnp.clip(ptr[1:] - base, 0, block_e)
        if combine == "sum":
            # prefix-sum difference: O(block_e + bin_n). Floats carry
            # the prefix in f64 so cs[hi] - cs[lo] never cancels below
            # the parity tolerance; ints are exact under wraparound.
            acc_dt = (jnp.float64
                      if jnp.issubdtype(acc_ref.dtype, jnp.floating)
                      else acc_ref.dtype)
            cs = jnp.cumsum(m_val.astype(acc_dt), axis=0)
            cs = jnp.concatenate(
                [jnp.zeros((1,) + cs.shape[1:], cs.dtype), cs], axis=0)
            local = (cs[hi] - cs[lo]).astype(acc_ref.dtype)
        else:
            # segmented Hillis-Steele min/max scan over dst runs:
            # passes = ceil(log2(longest run within a chunk))
            y = m_val
            for s in (1 << k for k in range(passes)):
                if s >= block_e:
                    break
                same = rel[s:] == rel[:-s]
                if y.ndim == 2:
                    same = same[:, None]
                red = (jnp.minimum(y[s:], y[:-s]) if combine == "min"
                       else jnp.maximum(y[s:], y[:-s]))
                y = jnp.concatenate([y[:s], jnp.where(same, red, y[s:])],
                                    axis=0)
            last = jnp.clip(hi - 1, 0, block_e - 1)
            got = y[last]                     # run tails, one per dst
            nonempty = hi > lo
            if got.ndim == 2:
                nonempty = nonempty[:, None]
            local = jnp.where(nonempty, got, ident)
    acc_ref[...] = _acc_combine(acc_ref[...], local, combine)


def _scan_passes(max_run: int, block_e: int) -> int:
    span = max(2, min(max_run, block_e))
    return max(1, math.ceil(math.log2(span)))


@functools.partial(jax.jit,
                   static_argnames=("n", "combine", "msg", "block_e",
                                    "block_n", "interpret", "strategy"))
def coo_push_pallas(x: jax.Array, active: jax.Array, src: jax.Array,
                    dst: jax.Array, w: jax.Array, n: int,
                    combine: str = "sum", msg: str = "mul",
                    block_e: int = 512, block_n: int = 256,
                    interpret: bool | None = None,
                    plan: PushBinPlan | None = None,
                    strategy: str = "scan") -> jax.Array:
    """Two-phase push-combine over dst-sorted COO edges.

    x: [n] or [n, B] source payloads; active: bool[n] frontier;
    src/dst: i32[m] (sorted by dst); w: f32[m]. Returns combined
    updates per destination ([n] or [n, B]); destinations with no
    active in-edge hold the combine identity.

    ``block_n`` is the destination-bin width, ``block_e`` the streamed
    edge-chunk size, ``strategy`` the phase-2 reduce ("scan" |
    "mxu") — all three are the autotuner's search axes. ``plan`` is
    the phase-1 bin layout; pass one built by :func:`build_push_plan`
    (the PallasBackend caches it per graph) to skip re-binning. The
    plan-free path bins in-trace with ``cap = m`` — always correct,
    sized for tests and small graphs, not the hot path.
    """
    if strategy not in PUSH_STRATEGIES:
        raise ValueError(f"strategy={strategy!r} not in "
                         f"{PUSH_STRATEGIES}")
    if interpret is None:
        interpret = default_interpret()
    m = src.shape[0]
    out_dtype = (x.dtype if msg == "copy"
                 else jnp.result_type(x.dtype, w.dtype))
    if m == 0:
        # edgeless graph: no edges means every destination holds the
        # combine identity, like the segment primitives
        shape = (n,) if x.ndim == 1 else (n, x.shape[1])
        return jnp.full(shape, combine_identity(combine, out_dtype),
                        out_dtype)
    if plan is None:
        in_ptr = jnp.searchsorted(dst, jnp.arange(n + 1, dtype=dst.dtype)
                                  ).astype(jnp.int32)
        plan, _ = bin_plan_traced(src, dst, w, in_ptr, n, block_n,
                                  cap=m, align=block_e)
    bin_n, cap, nb = plan.bin_n, plan.cap, plan.nb
    block_e = min(block_e, cap)
    if cap % block_e:
        raise ValueError(
            f"plan cap={cap} not a multiple of block_e={block_e}: "
            "build the plan with align=block_e")
    passes = _scan_passes(plan.max_run, block_e)
    grid = (nb, cap // block_e)
    n_pad = nb * bin_n
    if x.ndim == 2:
        b_width = x.shape[1]
        acc_spec = pl.BlockSpec((bin_n, b_width), lambda b, e: (b, 0))
        acc_shape = jax.ShapeDtypeStruct((n_pad, b_width), out_dtype)
        x_spec = pl.BlockSpec(x.shape, lambda b, e: (0, 0))
    else:
        acc_spec = pl.BlockSpec((bin_n,), lambda b, e: (b,))
        acc_shape = jax.ShapeDtypeStruct((n_pad,), out_dtype)
        x_spec = pl.BlockSpec(x.shape, lambda b, e: (0,))
    acc = pl.pallas_call(
        functools.partial(_kernel, n=n, bin_n=bin_n, combine=combine,
                          msg=msg, block_e=block_e, passes=passes,
                          strategy=strategy),
        grid=grid,
        in_specs=[
            x_spec,
            pl.BlockSpec(active.shape, lambda b, e: (0,)),
            pl.BlockSpec((1, block_e), lambda b, e: (b, e)),
            pl.BlockSpec((1, block_e), lambda b, e: (b, e)),
            pl.BlockSpec((1, block_e), lambda b, e: (b, e)),
            pl.BlockSpec((1, bin_n + 1), lambda b, e: (b, 0)),
        ],
        out_specs=acc_spec,
        out_shape=acc_shape,
        interpret=interpret,
    )(x, active.astype(jnp.int32), plan.src, plan.dst, plan.w, plan.ptr)
    return acc[:n]
