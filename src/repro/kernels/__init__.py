from .ops import pull_spmv, push_combine, flash_attention, cin_layer
from .tune import tune_pull, tune_push
from . import ref

__all__ = ["pull_spmv", "push_combine", "flash_attention", "cin_layer",
           "tune_pull", "tune_push", "ref"]
