from .ops import pull_spmv, push_combine, flash_attention, cin_layer
from . import ref

__all__ = ["pull_spmv", "push_combine", "flash_attention", "cin_layer",
           "ref"]
