from .ops import pull_spmv, push_combine, flash_attention, cin_layer
from .tune import tune_pull, tune_pull_frontier, tune_push
from .layout import DualEllLayout, build_dual_ell, touched_out_mask
from . import ref

__all__ = ["pull_spmv", "push_combine", "flash_attention", "cin_layer",
           "tune_pull", "tune_pull_frontier", "tune_push",
           "DualEllLayout", "build_dual_ell", "touched_out_mask", "ref"]
