"""DualEllLayout — both edge directions in their native rectangular form.

The paper's §7.1 point is that push and pull want *different* layouts:
pull gathers over in-edges (CSR-shaped), push scatters over out-edges
(CSC-shaped). The :class:`~repro.graphs.structure.Graph` container
carries an ELL view of the in-edges only (``ell_idx``/``ell_w`` — what
the pull kernels tile); the out-edges exist only as the flat push-major
COO order. This module completes the pair: a ``DualEllLayout`` holds
the graph's ELL-in matrices *plus* an ELL-out matrix packed once from
the push-major CSR (``out_ptr``), so both directions have a rectangular
[rows, width] view — built on the host, cached per graph on
``PallasBackend`` exactly like PR 7's ``PushBinPlan``.

What each side is for:

  * **ELL-in** (``in_idx``/``in_w``) feeds the pull kernels —
    ``ell_spmv_pallas`` full scans and ``ell_pull_frontier_pallas``
    touched-row gathers.
  * **ELL-out** (``out_idx``/``out_w``) answers the frontier side of
    the same question: *which destinations can a pull step skip?* A
    destination outside the frontier's out-neighborhood has no active
    in-neighbor, so for frontier-driven monotone programs its combined
    message cannot change. :func:`touched_out_mask` computes that
    N_out(frontier) expansion as a ``|F| × d_out`` gather + scatter
    instead of an m-edge scan — the Grossman & Kozyrakis touched-set
    derivation that benchmark touched sets and program ``touched_fn``
    hooks build on.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.structure import Graph, _ell_from_ptr

__all__ = ["DualEllLayout", "build_dual_ell", "touched_out_mask"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DualEllLayout:
    """ELL-in + ELL-out rectangular views of one graph (a pytree).

    ``in_idx``/``in_w`` are the graph's own ELL-in arrays (shared, not
    copied — [n, d_in], sentinel ``n``); ``out_idx``/``out_w`` are the
    padded out-neighbor matrix ([n, d_out], same sentinel/zero-pad
    conventions) packed from the push-major CSR.
    """
    in_idx: jax.Array
    in_w: jax.Array
    out_idx: jax.Array
    out_w: jax.Array
    n: int = dataclasses.field(metadata=dict(static=True))
    d_in: int = dataclasses.field(metadata=dict(static=True))
    d_out: int = dataclasses.field(metadata=dict(static=True))


def build_dual_ell(g: Graph, pad_rows_to: int = 8) -> DualEllLayout:
    """Host-side builder: reuse the graph's ELL-in, pack ELL-out from
    ``out_ptr``/``push_dst`` (max out-degree rounded up to
    ``pad_rows_to``). Concrete graphs only — the backend builds this
    once per graph and caches it, like the push bin plan."""
    out_ptr = np.asarray(g.out_ptr)
    out_deg = np.diff(out_ptr)
    d_max = int(out_deg.max()) if g.n else 0
    d_out = max(pad_rows_to, -(-d_max // pad_rows_to) * pad_rows_to)
    out_idx, out_w = _ell_from_ptr(out_ptr, np.asarray(g.push_dst),
                                   np.asarray(g.push_w), g.n, d_out)
    return DualEllLayout(
        in_idx=g.ell_idx, in_w=g.ell_w,
        out_idx=jnp.asarray(out_idx), out_w=jnp.asarray(out_w),
        n=g.n, d_in=g.d_ell, d_out=int(d_out))


def touched_out_mask(layout: DualEllLayout, frontier: jax.Array,
                     cap: int | None = None) -> jax.Array:
    """bool[n] mask of N_out(frontier) — the destinations a
    frontier-driven pull step can actually change.

    Compacts the frontier to row ids, gathers their ELL-out rows, and
    scatters a mark per destination: ``cap × d_out`` work instead of an
    m-edge scan. ``cap`` bounds the compacted frontier (default n —
    exact); frontier vertices beyond ``cap`` are dropped, so callers
    restricting ``cap`` must guard on the frontier count, exactly as
    the frontier pull kernel does."""
    n = layout.n
    size = n if cap is None else cap
    rows = jnp.nonzero(frontier, size=size, fill_value=n)[0]
    nbrs = jnp.take(layout.out_idx, rows, axis=0, mode="fill",
                    fill_value=n)                    # [size, d_out]
    return jnp.zeros((n,), bool).at[nbrs.ravel()].set(True, mode="drop")
