"""Pallas TPU kernel: ELL-format pull relaxation (gather + combine).

Paper hot spot: the pull k-relaxation — every destination vertex privately
combines messages from its in-neighbors (CSR SpMV, §7.1). TPU adaptation
(DESIGN.md §9): CSR row-pointer chasing is hostile to VMEM tiling, so the
graph substrate materializes an ELL view — a rectangular [n, d_ell]
padded neighbor matrix — and the kernel becomes a dense-shaped
gather+reduce with sentinel masking:

    out[v] = combine_{j < d_ell} msg(x[ell_idx[v, j]], ell_w[v, j])

Grid: one program per (node-block); the padded value vector x lives in
ANY/HBM and is gathered per tile; indices/weights stream through VMEM
blocks of shape [block_n, d_ell]. The gather itself uses dynamic indexing
into the x ref — irregular reads stay inside the tile (the paper's
"communication" axis), while writes are private per block (zero
synchronization — the pull property).

Production surface (the PallasBackend hot path):

  * combine ∈ {sum, max, min};
  * payloads [n] or [n, B] (the service layer's batched multi-query
    columns ride the same tile, amortizing the structure scan);
  * float32/float64/int32/int64 payloads (BFS parent ids are int32);
  * msg ∈ {"mul", "copy", "add"} — the wire-message shapes every
    registered algorithm uses (x·w SpMV, unweighted label copy, min-plus
    x+w relaxation);
  * empty rows return the combine identity (exactly what
    ``pull_relax_ell`` returns, so ``mask_untouched``/convergence checks
    agree bit-for-bit);
  * ``interpret=None`` auto-detects: compiled on TPU, interpreter
    elsewhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.primitives import combine_identity

__all__ = ["ell_spmv_pallas", "default_interpret"]


def default_interpret() -> bool:
    """Interpret unless a real TPU backs the default device."""
    return jax.default_backend() != "tpu"


def _apply_msg(gathered, w, msg: str):
    """gathered: [block_n, d_ell] or [block_n, d_ell, B]; w: [block_n,
    d_ell]. Mirrors the promotion the jnp primitives' msg_fn performs."""
    if msg == "copy":
        return gathered
    if gathered.ndim == 3:
        w = w[..., None]
    return gathered * w if msg == "mul" else gathered + w


def _kernel(x_ref, idx_ref, w_ref, out_ref, *, combine: str, msg: str,
            n: int):
    # idx_ref/w_ref: [block_n, d_ell] VMEM tiles; x_ref: full padded
    # value vector/matrix in ANY
    idx = idx_ref[...]
    valid = idx < n
    safe = jnp.where(valid, idx, 0)
    gathered = x_ref[safe]            # [block_n, d_ell(, B)] gather
    msgs = _apply_msg(gathered, w_ref[...], msg)
    ident = combine_identity(combine, msgs.dtype)
    if msgs.ndim == 3:
        valid = valid[..., None]
    masked = jnp.where(valid, msgs, ident)
    if combine == "sum":
        out = masked.sum(axis=1)
    elif combine == "max":
        out = masked.max(axis=1)
    else:
        out = masked.min(axis=1)
    out_ref[...] = out.astype(out_ref.dtype)


def _out_dtype(x_dtype, w_dtype, msg: str, combine: str):
    """Mirror pull_relax_ell exactly: msg_fn promotion plus jnp.sum's
    sub-default-int widening (int32 sums accumulate as int64 under x64)."""
    d = x_dtype if msg == "copy" else jnp.result_type(x_dtype, w_dtype)
    if combine == "sum":
        d = jnp.zeros((1,), d).sum().dtype
    return d


@functools.partial(jax.jit,
                   static_argnames=("combine", "msg", "block_n",
                                    "interpret", "num_sources"))
def ell_spmv_pallas(x_padded: jax.Array, ell_idx: jax.Array,
                    ell_w: jax.Array, combine: str = "sum",
                    msg: str = "mul", block_n: int = 256,
                    interpret: bool | None = None,
                    num_sources: int | None = None) -> jax.Array:
    """Pull k-relaxation over the ELL layout.

    x_padded: [n+1] or [n+1, B] payloads (sentinel row at index n);
    ell_idx: i32[n, d_ell]; ell_w: f32[n, d_ell]. Returns [n] or [n, B]
    combined messages; empty rows hold the combine identity.

    ``num_sources`` decouples the index validity bound from the row
    count: by default indices are valid below ``n`` (the square-matrix
    case), but a *row block* of a larger graph — the sharded backend's
    per-shard ELL slice, whose rows gather from the full gathered value
    vector — passes the global vertex count here. Requires
    ``x_padded.shape[0] > max valid index`` as usual.
    """
    if interpret is None:
        interpret = default_interpret()
    n, d_ell = ell_idx.shape
    n_src = n if num_sources is None else num_sources
    batched = x_padded.ndim == 2
    n_pad = -(-n // block_n) * block_n
    idx = jnp.pad(ell_idx, ((0, n_pad - n), (0, 0)),
                  constant_values=n_src)
    w = jnp.pad(ell_w, ((0, n_pad - n), (0, 0)))
    grid = (n_pad // block_n,)
    out_dtype = _out_dtype(x_padded.dtype, ell_w.dtype, msg, combine)
    if batched:
        b = x_padded.shape[1]
        out_spec = pl.BlockSpec((block_n, b), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((n_pad, b), out_dtype)
        x_spec = pl.BlockSpec(x_padded.shape, lambda i: (0, 0))
    else:
        out_spec = pl.BlockSpec((block_n,), lambda i: (i,))
        out_shape = jax.ShapeDtypeStruct((n_pad,), out_dtype)
        x_spec = pl.BlockSpec(x_padded.shape, lambda i: (0,))
    out = pl.pallas_call(
        functools.partial(_kernel, combine=combine, msg=msg, n=n_src),
        grid=grid,
        in_specs=[
            x_spec,                                    # full vector
            pl.BlockSpec((block_n, d_ell), lambda i: (i, 0)),
            pl.BlockSpec((block_n, d_ell), lambda i: (i, 0)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(x_padded, idx, w)
    return out[:n]
