"""Pallas TPU kernel: ELL-format pull relaxation (gather + combine).

Paper hot spot: the pull k-relaxation — every destination vertex privately
combines messages from its in-neighbors (CSR SpMV, §7.1). TPU adaptation
(DESIGN.md §9): CSR row-pointer chasing is hostile to VMEM tiling, so the
graph substrate materializes an ELL view — a rectangular [n, d_ell]
padded neighbor matrix — and the kernel becomes a dense-shaped
gather+reduce with sentinel masking:

    out[v] = combine_{j < d_ell} x[ell_idx[v, j]] * ell_w[v, j]

Grid: one program per (node-block); the padded value vector x lives in
ANY/HBM and is gathered per tile; indices/weights stream through VMEM
blocks of shape [block_n, d_ell]. The gather itself uses dynamic indexing
into the x ref — irregular reads stay inside the tile (the paper's
"communication" axis), while writes are private per block (zero
synchronization — the pull property).

Supports combine in {sum, max, min} over f32 payloads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ell_spmv_pallas"]


def _kernel(x_ref, idx_ref, w_ref, out_ref, *, combine: str, n: int,
            block_n: int, d_ell: int):
    # idx_ref/w_ref: [block_n, d_ell] VMEM tiles; x_ref: [n+1] in ANY/VMEM
    idx = idx_ref[...]
    w = w_ref[...]
    valid = idx < n
    safe = jnp.where(valid, idx, 0)
    gathered = x_ref[safe]                  # [block_n, d_ell] gather
    msgs = gathered * w
    if combine == "sum":
        out = jnp.where(valid, msgs, 0.0).sum(axis=1)
    elif combine == "max":
        out = jnp.where(valid, msgs, -jnp.inf).max(axis=1)
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    else:
        out = jnp.where(valid, msgs, jnp.inf).min(axis=1)
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    out_ref[...] = out


@functools.partial(jax.jit,
                   static_argnames=("combine", "block_n", "interpret"))
def ell_spmv_pallas(x_padded: jax.Array, ell_idx: jax.Array,
                    ell_w: jax.Array, combine: str = "sum",
                    block_n: int = 256, interpret: bool = True
                    ) -> jax.Array:
    """x_padded: f32[n+1] (sentinel row 0.0 at index n);
    ell_idx: i32[n, d_ell]; ell_w: f32[n, d_ell]. Returns f32[n]."""
    n, d_ell = ell_idx.shape
    n_pad = -(-n // block_n) * block_n
    idx = jnp.pad(ell_idx, ((0, n_pad - n), (0, 0)), constant_values=n)
    w = jnp.pad(ell_w, ((0, n_pad - n), (0, 0)))
    grid = (n_pad // block_n,)
    out = pl.pallas_call(
        functools.partial(_kernel, combine=combine, n=n, block_n=block_n,
                          d_ell=d_ell),
        grid=grid,
        in_specs=[
            pl.BlockSpec(x_padded.shape, lambda i: (0,)),   # full vector
            pl.BlockSpec((block_n, d_ell), lambda i: (i, 0)),
            pl.BlockSpec((block_n, d_ell), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(x_padded, idx, w)
    return out[:n]
