"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ell_spmv_ref", "coo_push_ref", "flash_attention_ref",
           "cin_layer_ref"]


def ell_spmv_ref(x_padded, ell_idx, ell_w, combine: str = "sum"):
    # empty rows hold the combine identity (±inf for max/min), matching
    # pull_relax_ell — what mask_untouched/convergence checks expect
    n = ell_idx.shape[0]
    gathered = x_padded[jnp.minimum(ell_idx, n)] * ell_w
    valid = ell_idx < n
    if combine == "sum":
        return jnp.where(valid, gathered, 0.0).sum(axis=1)
    if combine == "max":
        return jnp.where(valid, gathered, -jnp.inf).max(axis=1)
    return jnp.where(valid, gathered, jnp.inf).min(axis=1)


def coo_push_ref(x, active, src, dst, w, n):
    ok = src < n
    msg = jnp.where(ok & active[jnp.minimum(src, n - 1)],
                    x[jnp.minimum(src, n - 1)] * w, 0.0)
    return jax.ops.segment_sum(msg, jnp.minimum(dst, n - 1),
                               num_segments=n)


def flash_attention_ref(q, k, v, causal_window: int = 1 << 30,
                        softcap: float = 0.0):
    """q,k,v: [B, H, T, d]; plain materialized-scores attention."""
    B, H, T, d = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(T)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = (k_pos <= q_pos) & (k_pos > q_pos - causal_window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def cin_layer_ref(xk, x0, w):
    z = jnp.einsum("bid,bjd->bijd", xk.astype(jnp.float32),
                   x0.astype(jnp.float32))
    return jnp.einsum("hij,bijd->bhd", w.astype(jnp.float32), z
                      ).astype(xk.dtype)
