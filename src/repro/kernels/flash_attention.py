"""Pallas TPU kernel: blocked online-softmax attention (FlashAttention).

The decode/prefill hot spot of the LM cells. Grid (batch*heads, q_blocks);
the kv loop runs inside the kernel body with running (m, l, o) statistics
held in VMEM scratch — the [T, T] score matrix never exists. Supports
causal masking, sliding window, and gemma2 logit soft-capping.

BlockSpec tiling: q/o blocks [block_q, d]; k/v stream in [block_k, d]
tiles via an inner fori_loop over kv blocks (all KV of one (b, h) is
mapped into the kernel; MXU-aligned block sizes: multiples of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
            seq_len: int, window: int, softcap: float, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # [block_q, d]
    d = q.shape[-1]
    n_kv = seq_len // block_k
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)

    def body(ki, carry):
        m, l, o = carry
        k = k_ref[0, pl.dslice(ki * block_k, block_k), :].astype(
            jnp.float32)
        v = v_ref[0, pl.dslice(ki * block_k, block_k), :].astype(
            jnp.float32)
        s = q @ k.T                                   # [block_q, block_k]
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = (k_pos <= q_pos) & (k_pos > q_pos - window)
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        o_new = o * corr[:, None] + p @ v
        return m_new, l_new, o_new

    m0 = jnp.full((block_q,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    o0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, o = jax.lax.fori_loop(0, n_kv, body, (m0, l0, o0))
    o_ref[0] = (o / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal_window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal_window: int = 1 << 30,
                           softcap: float = 0.0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q,k,v: [B, H, T, d] (kv heads pre-broadcast to H). Causal, optional
    sliding window + softcap. Returns [B, H, T, d] in q.dtype."""
    B, H, T, d = q.shape
    bq = min(block_q, T)
    bk = min(block_k, T)
    T_pad = -(-T // max(bq, bk)) * max(bq, bk)
    if T_pad != T:
        pad = ((0, 0), (0, 0), (0, T_pad - T), (0, 0))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    qf = q.reshape(B * H, T_pad, d)
    kf = k.reshape(B * H, T_pad, d)
    vf = v.reshape(B * H, T_pad, d)
    grid = (B * H, T_pad // bq)
    scale = d ** -0.5
    out = pl.pallas_call(
        functools.partial(_kernel, block_q=bq, block_k=bk, seq_len=T_pad,
                          window=causal_window, softcap=softcap,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, T_pad, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, T_pad, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T_pad, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, T_pad, d)[:, :, :T]
