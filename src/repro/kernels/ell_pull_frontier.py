"""Pallas TPU kernel: frontier-aware pull over the ELL in-edge layout.

The paper's pull primitive is a private gather per destination — but the
rectangular ELL kernel (``ell_spmv_pallas``) gathers *every* row, so a
step whose program only needs a sparse touched-destination set (BFS's
unvisited set late in the run, an incremental recompute's affected set)
still pays the full ``n × d_ell`` scan. Grossman & Kozyrakis ("A New
Frontier for Pull-Based Graph Processing", PAPERS.md) show that
restricting pull to the touched frontier recovers the asymptotics that
make direction switching worthwhile; this kernel is that restriction on
the TPU layout:

    out[rows[r]] = combine_{j < d_ell} msg(x[ell_idx[rows[r], j]],
                                           ell_w[rows[r], j])

``rows`` is the *compacted* touched-destination id list (sentinel ``n``
in padding slots — see :func:`frontier_rows`). The grid tiles ``rows``,
not the vertex range: each step gathers its row ids, then the ELL rows
of those ids, then the payloads of those neighbors — three levels of
irregular read that all stay inside the tile, while writes remain
private per touched row (the pull property, unchanged). Work is
``R_pad × d_ell`` instead of ``n × d_ell``: at a 10% frontier the
kernel does a tenth of the full scan's gathers.

Coverage matches ``ell_spmv_pallas`` exactly — combine ∈
{sum, max, min}, payloads ``[n]``/``[n, B]``, float32/float64/int32/
int64, msg ∈ {copy, mul, add} — and untouched rows come back as the
combine identity, so the full-vector result
(:func:`ell_pull_frontier_full`) equals
``mask_untouched(ell_spmv_pallas(...), touched)``: bit-identical for
the order-independent combines (min/max, integer sums); float sums
agree to reduction-order rounding (XLA schedules the row reduce per
tile shape, so even the full kernel differs in ULPs across block
sizes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.primitives import combine_identity
from .ell_spmv import _apply_msg, _out_dtype, default_interpret

__all__ = ["ell_pull_frontier_pallas", "ell_pull_frontier_full",
           "frontier_rows", "default_pull_cap"]


def _round_up(x: int, q: int) -> int:
    return -(-x // q) * q


def default_pull_cap(n: int, m: int, d_ell: int) -> int:
    """Static row capacity for the traced frontier-pull path.

    Engines are compiled per graph *shape*, so the compacted row list
    needs a static size; the capacity is the point where the restricted
    gather is still guaranteed cheaper than the full scan — at most
    half the full kernel's ``n × d_ell`` slot reads (``cap × d_ell ≤
    m/2`` ⇒ touched sets that fit do at most half the scan's work).
    Denser touched sets overflow the capacity and take the full-scan
    kernel, which is exactly how they would have been priced before.
    """
    cap = min(n, m // (2 * max(d_ell, 1)))
    return max(8, _round_up(cap, 8))


def frontier_rows(touched: jax.Array, size: int) -> jax.Array:
    """Compact a bool[n] touched mask into int32 row ids, padded with
    the sentinel ``n`` to the static ``size``. Rows beyond ``size`` are
    dropped — callers guard with a fits bit (count ≤ size) before
    trusting the compaction."""
    n = touched.shape[0]
    rows = jnp.nonzero(touched, size=size, fill_value=n)[0]
    return rows.astype(jnp.int32)


def _kernel(rows_ref, x_ref, idx_ref, w_ref, out_ref, *, combine: str,
            msg: str, n: int):
    # rows_ref: [block_r] streamed tile of touched row ids; idx_ref /
    # w_ref: the full [n, d_ell] ELL-in matrices resident in ANY;
    # x_ref: the full padded payload. Three-level gather: row ids ->
    # ELL rows -> neighbor payloads, all inside the tile.
    rows = rows_ref[...]
    live = rows < n
    safe_rows = jnp.where(live, rows, 0)
    idx = idx_ref[safe_rows]                 # [block_r, d_ell]
    w = w_ref[safe_rows]
    valid = live[:, None] & (idx < n)
    safe = jnp.where(valid, idx, 0)
    gathered = x_ref[safe]                   # [block_r, d_ell(, B)]
    msgs = _apply_msg(gathered, w, msg)
    ident = combine_identity(combine, msgs.dtype)
    if msgs.ndim == 3:
        valid = valid[..., None]
    masked = jnp.where(valid, msgs, ident)
    if combine == "sum":
        out = masked.sum(axis=1)
    elif combine == "max":
        out = masked.max(axis=1)
    else:
        out = masked.min(axis=1)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("combine", "msg", "block_r",
                                    "interpret", "num_sources"))
def ell_pull_frontier_pallas(x_padded: jax.Array, ell_idx: jax.Array,
                             ell_w: jax.Array, rows: jax.Array,
                             combine: str = "sum", msg: str = "mul",
                             block_r: int = 256,
                             interpret: bool | None = None,
                             num_sources: int | None = None) -> jax.Array:
    """Frontier-restricted pull: combined messages for ``rows`` only.

    x_padded: [n+1] or [n+1, B] payloads (zero row at index n);
    ell_idx/ell_w: the [n, d_ell] ELL-in layout; rows: int32[R]
    compacted touched row ids (sentinel ``n`` in padding slots).
    Returns the *compacted* [R] or [R, B] combined messages, aligned
    with ``rows``; sentinel slots hold the combine identity. Use
    :func:`ell_pull_frontier_full` for the scattered full-vector form.
    """
    if interpret is None:
        interpret = default_interpret()
    n, d_ell = ell_idx.shape
    n_src = n if num_sources is None else num_sources
    batched = x_padded.ndim == 2
    (r,) = rows.shape
    r_pad = _round_up(max(r, 1), block_r)
    rows = jnp.pad(rows, (0, r_pad - r), constant_values=n_src)
    grid = (r_pad // block_r,)
    out_dtype = _out_dtype(x_padded.dtype, ell_w.dtype, msg, combine)
    if batched:
        b = x_padded.shape[1]
        out_spec = pl.BlockSpec((block_r, b), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((r_pad, b), out_dtype)
        x_spec = pl.BlockSpec(x_padded.shape, lambda i: (0, 0))
    else:
        out_spec = pl.BlockSpec((block_r,), lambda i: (i,))
        out_shape = jax.ShapeDtypeStruct((r_pad,), out_dtype)
        x_spec = pl.BlockSpec(x_padded.shape, lambda i: (0,))
    out = pl.pallas_call(
        functools.partial(_kernel, combine=combine, msg=msg, n=n_src),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r,), lambda i: (i,)),   # row-id tile
            x_spec,                                     # full payload
            pl.BlockSpec(ell_idx.shape, lambda i: (0, 0)),
            pl.BlockSpec(ell_w.shape, lambda i: (0, 0)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(rows, x_padded, ell_idx, ell_w)
    return out[:r]


@functools.partial(jax.jit,
                   static_argnames=("combine", "msg", "block_r",
                                    "interpret"))
def ell_pull_frontier_full(x_padded: jax.Array, ell_idx: jax.Array,
                           ell_w: jax.Array, rows: jax.Array,
                           combine: str = "sum", msg: str = "mul",
                           block_r: int = 256,
                           interpret: bool | None = None) -> jax.Array:
    """Frontier pull scattered back to the full vertex range: touched
    rows carry their combined messages, every other row the combine
    identity — equal to ``mask_untouched(ell_spmv_pallas(...),
    touched)`` when ``rows`` compacts ``touched`` (bit-identical for
    order-independent combines; see the module docstring)."""
    n = ell_idx.shape[0]
    compact = ell_pull_frontier_pallas(
        x_padded, ell_idx, ell_w, rows, combine=combine, msg=msg,
        block_r=block_r, interpret=interpret)
    out_dtype = _out_dtype(x_padded.dtype, ell_w.dtype, msg, combine)
    shape = (n,) + compact.shape[1:]
    base = jnp.full(shape, combine_identity(combine, out_dtype),
                    out_dtype)
    # sentinel slots (rows == n) fall outside [0, n) and are dropped
    return base.at[rows].set(compact, mode="drop")
