"""jit'd public wrappers over the Pallas kernels.

The graph kernels auto-detect `interpret` (compiled on TPU, interpreter
elsewhere — this container is CPU-only); the model kernels keep the
explicit `interpret=True` default. Wrappers adapt framework-level
structures (Graph, GQA heads) to kernel-level layouts. The engine hot
path does not go through these wrappers — it dispatches via
``repro.core.backend.PallasBackend`` (autotuned blocks, fallbacks).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..graphs.structure import Graph
from .cin import cin_layer_pallas
from .coo_push import coo_push_pallas
from .ell_spmv import ell_spmv_pallas
from .flash_attention import flash_attention_pallas

__all__ = ["pull_spmv", "push_combine", "flash_attention", "cin_layer"]


def pull_spmv(g: Graph, x: jax.Array, combine: str = "sum",
              interpret: bool | None = None) -> jax.Array:
    """Pull k-relaxation via the ELL kernel. x: f32[n] -> f32[n]."""
    x_pad = jnp.pad(x.astype(jnp.float32), (0, 1))
    return ell_spmv_pallas(x_pad, g.ell_idx, g.ell_w, combine=combine,
                           interpret=interpret)


def push_combine(g: Graph, x: jax.Array, active: jax.Array,
                 combine: str = "sum",
                 interpret: bool | None = None) -> jax.Array:
    """Push k-relaxation via the COO kernel over dst-sorted edges."""
    return coo_push_pallas(x.astype(jnp.float32), active, g.coo_src,
                           g.coo_dst, g.coo_w, g.n, combine=combine,
                           interpret=interpret)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal_window: int = 1 << 30, softcap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """GQA-aware flash attention. q: [B, T, H, d]; k/v: [B, T, Hk, d]
    (broadcast to H inside). Returns [B, T, H, d]."""
    B, T, H, d = q.shape
    Hk = k.shape[2]
    group = H // Hk
    kb = jnp.repeat(k, group, axis=2)
    vb = jnp.repeat(v, group, axis=2)
    out = flash_attention_pallas(
        q.transpose(0, 2, 1, 3), kb.transpose(0, 2, 1, 3),
        vb.transpose(0, 2, 1, 3), causal_window=causal_window,
        softcap=softcap, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out.transpose(0, 2, 1, 3)


def cin_layer(xk: jax.Array, x0: jax.Array, w: jax.Array,
              interpret: bool = True) -> jax.Array:
    return cin_layer_pallas(xk, x0, w, interpret=interpret)
