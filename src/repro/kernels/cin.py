"""Pallas TPU kernel: xDeepFM CIN layer (outer product + compress).

The recsys interaction hot spot:
    X^k[b,h,d] = Σ_{i,j} W[h,i,j] · X^{k-1}[b,i,d] · X^0[b,j,d]

Grid over batch tiles; per tile the [i, j, d] outer product and the
[h, i·j] compression matmul are fused in VMEM (outer product never hits
HBM). h_prev·F·D per tile is small (≤ 200·39·10 floats), so one batch
tile holds the whole interaction in registers/VMEM and the compression
runs on the MXU as a [H, h_prev·F] × [h_prev·F, block_b·D] matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cin_layer_pallas"]


def _kernel(xk_ref, x0_ref, w_ref, o_ref, *, block_b: int):
    xk = xk_ref[...].astype(jnp.float32)      # [block_b, Hp, D]
    x0 = x0_ref[...].astype(jnp.float32)      # [block_b, F,  D]
    w = w_ref[...].astype(jnp.float32)        # [H, Hp, F]
    bb, hp, d = xk.shape
    f = x0.shape[1]
    z = xk[:, :, None, :] * x0[:, None, :, :]          # [bb, Hp, F, D]
    zf = z.reshape(bb, hp * f, d)
    wf = w.reshape(-1, hp * f)                          # [H, Hp*F]
    # compress on the MXU: [H, Hp*F] @ [Hp*F, bb*D]
    out = wf @ zf.transpose(1, 0, 2).reshape(hp * f, bb * d)
    o_ref[...] = out.reshape(-1, bb, d).transpose(1, 0, 2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def cin_layer_pallas(xk: jax.Array, x0: jax.Array, w: jax.Array,
                     block_b: int = 128, interpret: bool = True
                     ) -> jax.Array:
    """xk: [B, Hp, D]; x0: [B, F, D]; w: [H, Hp, F] -> [B, H, D]."""
    B, Hp, D = xk.shape
    F = x0.shape[1]
    H = w.shape[0]
    bb = min(block_b, B)
    B_pad = -(-B // bb) * bb
    xkp = jnp.pad(xk, ((0, B_pad - B), (0, 0), (0, 0)))
    x0p = jnp.pad(x0, ((0, B_pad - B), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, block_b=bb),
        grid=(B_pad // bb,),
        in_specs=[
            pl.BlockSpec((bb, Hp, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, F, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((H, Hp, F), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, H, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B_pad, H, D), xk.dtype),
        interpret=interpret,
    )(xkp, x0p, w)
    return out[:B]
