"""EmbeddingBag in pure JAX (take + segment_sum) — required substrate for
the recsys arch; JAX has no native EmbeddingBag.

Push/pull framing (paper §3.8 applied to embeddings):
  * the **lookup** is a pull: each output row gathers (reads) the rows it
    needs and reduces privately — zero write conflicts;
  * the **gradient** is a push: every bag scatters into shared table rows —
    combining writes (segment_sum in the VJP, CRCW-CB semantics).

`PartitionAwareEmbeddingBag` additionally applies the paper's PA strategy
to a model-parallel table: ids are split into locally-owned rows (plain
gather) and remote rows (communicated), mirroring local/remote adjacency
arrays. The dense fallback path is what the dry-run shards with GSPMD.
"""

from __future__ import annotations

from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from .segment import segment_max, segment_mean, segment_sum

__all__ = ["embedding_bag", "one_hot_matmul_lookup"]

Combiner = Literal["sum", "mean", "max"]


@partial(jax.jit, static_argnames=("num_bags", "combiner"))
def embedding_bag(table: jax.Array, ids: jax.Array, bag_ids: jax.Array,
                  num_bags: int, weights: jax.Array | None = None,
                  combiner: Combiner = "sum") -> jax.Array:
    """Gather ``table[ids]`` and combine per bag.

    table: float[V, d]; ids: int32[k]; bag_ids: int32[k] sorted or not;
    returns float[num_bags, d]. Out-of-range ids (>= V) contribute zeros
    (padding convention).
    """
    V = table.shape[0]
    ok = ids < V
    rows = jnp.take(table, jnp.minimum(ids, V - 1), axis=0)
    rows = jnp.where(ok[:, None], rows, 0.0)
    if weights is not None:
        rows = rows * weights[:, None]
    if combiner == "sum":
        return segment_sum(rows, bag_ids, num_bags)
    if combiner == "mean":
        return segment_mean(rows, bag_ids, num_bags)
    if combiner == "max":
        out = segment_max(jnp.where(ok[:, None], rows, -jnp.inf), bag_ids, num_bags)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(combiner)


def one_hot_matmul_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """MXU-friendly lookup: onehot(ids) @ table. O(k·V·d) FLOPs but runs on
    the systolic array — wins over gather for tiny vocab shards; used by the
    hillclimb as a candidate layout for the smallest recsys tables."""
    oh = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
    return oh @ table
