from .segment import (segment_sum, segment_max, segment_min, segment_mean,
                      segment_softmax, segment_logsumexp, count_segments)
from .embedding import embedding_bag, one_hot_matmul_lookup

__all__ = [
    "segment_sum", "segment_max", "segment_min", "segment_mean",
    "segment_softmax", "segment_logsumexp", "count_segments",
    "embedding_bag", "one_hot_matmul_lookup",
]
