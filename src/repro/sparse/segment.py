"""Segment ops — the CRCW-CB combining primitive (paper §2.1, §2.3).

``segment_sum`` over an edge->vertex index IS the paper's combining
concurrent write: XLA lowers it to a deterministic scatter-add, which is
exactly the semantics the CRCW-CB PRAM assumes for push k-relaxations.
``segment_min/max`` give the other combining flavors (SSSP relaxation,
Boruvka minimum-edge selection). Everything here is shape-static, jittable.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "segment_sum", "segment_max", "segment_min", "segment_mean",
    "segment_softmax", "segment_logsumexp", "count_segments",
]


@partial(jax.jit, static_argnames=("num_segments",))
def segment_sum(data, segment_ids, num_segments: int):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


@partial(jax.jit, static_argnames=("num_segments",))
def segment_max(data, segment_ids, num_segments: int):
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


@partial(jax.jit, static_argnames=("num_segments",))
def segment_min(data, segment_ids, num_segments: int):
    return jax.ops.segment_min(data, segment_ids, num_segments=num_segments)


@partial(jax.jit, static_argnames=("num_segments",))
def count_segments(segment_ids, num_segments: int):
    return jax.ops.segment_sum(
        jnp.ones(segment_ids.shape[:1], jnp.int32), segment_ids,
        num_segments=num_segments)


@partial(jax.jit, static_argnames=("num_segments",))
def segment_mean(data, segment_ids, num_segments: int):
    s = segment_sum(data, segment_ids, num_segments)
    c = count_segments(segment_ids, num_segments)
    c = jnp.maximum(c, 1).astype(s.dtype)
    return s / c.reshape((-1,) + (1,) * (s.ndim - 1))


@partial(jax.jit, static_argnames=("num_segments",))
def segment_logsumexp(data, segment_ids, num_segments: int):
    mx = segment_max(data, segment_ids, num_segments)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    shifted = data - mx[segment_ids]
    s = segment_sum(jnp.exp(shifted), segment_ids, num_segments)
    return jnp.log(jnp.maximum(s, 1e-30)) + mx


@partial(jax.jit, static_argnames=("num_segments",))
def segment_softmax(data, segment_ids, num_segments: int):
    """Softmax within each segment (GAT edge-softmax primitive)."""
    mx = segment_max(data, segment_ids, num_segments)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    e = jnp.exp(data - mx[segment_ids])
    z = segment_sum(e, segment_ids, num_segments)
    return e / jnp.maximum(z, 1e-30)[segment_ids]
