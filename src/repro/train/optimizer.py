"""Optimizers in pure JAX (no optax dependency): AdamW + SGD-momentum,
global-norm clipping, and warmup-cosine schedule.

State is a pytree shaped like params, so the same sharding rules apply —
ZeRO-'pull' shards optimizer moments across 'data' for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "OptState", "init_opt", "apply_updates",
           "warmup_cosine"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    momentum: float = 0.9       # sgd


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_opt(params: Any, cfg: OptConfig) -> OptState:
    zeros = lambda: jax.tree.map(  # noqa: E731
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    mu = zeros()
    nu = zeros() if cfg.kind == "adamw" else jax.tree.map(
        lambda p: jnp.zeros((), jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)


def warmup_cosine(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / max(1, cfg.warmup_steps)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(params: Any, grads: Any, state: OptState, cfg: OptConfig
                  ) -> tuple[Any, OptState]:
    step = state.step + 1
    lr = warmup_cosine(cfg, step)

    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    if cfg.kind == "adamw":
        mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                          state.nu, grads)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.weight_decay and p.ndim >= 2:   # decay matrices only
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, OptState(step=step, mu=mu, nu=nu)

    # sgd + momentum
    mu = jax.tree.map(lambda m, g: cfg.momentum * m + g, state.mu, grads)
    new_params = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
        params, mu)
    return new_params, OptState(step=step, mu=mu, nu=state.nu)
