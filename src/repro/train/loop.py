"""Training loop: checkpoint/restart, straggler watchdog, compressed DP.

`TrainLoop` is deliberately framework-grade rather than example-grade:

  * resumes from the latest valid checkpoint automatically (crash =
    restart the launcher, nothing else);
  * async checkpoints every `ckpt_every` steps + terminal sync save;
  * a step-time watchdog maintains a robust running median and flags
    stragglers (steps > `straggler_factor` x median). On a real cluster
    the flag feeds the controller that reschedules the slow host; here it
    is surfaced in the step log and tested;
  * optional gradient compression with error feedback (dist.compression);
  * microbatch gradient accumulation (dist.overlap) so the gradient
    exchange of microbatch i overlaps the backward of microbatch i+1.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..dist.compression import (CompressionConfig, compress_tree,
                                init_error_state)
from ..dist.overlap import microbatch_grads
from . import checkpoint as ckpt
from .optimizer import OptConfig, OptState, apply_updates, init_opt

__all__ = ["LoopConfig", "Watchdog", "TrainLoop"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    num_micro: int = 1
    straggler_factor: float = 3.0
    compression: CompressionConfig = dataclasses.field(
        default_factory=CompressionConfig)


class Watchdog:
    """Robust step-time tracker; flags straggler steps."""

    def __init__(self, factor: float = 3.0, window: int = 64):
        self.factor = factor
        self.window = window
        self.times: list[float] = []
        self.stragglers: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        hist = self.times[-self.window:]
        is_straggler = False
        if len(hist) >= 8:
            med = sorted(hist)[len(hist) // 2]
            is_straggler = dt > self.factor * med
        if is_straggler:
            self.stragglers.append((step, dt))
        self.times.append(dt)
        return is_straggler

    @property
    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times[-self.window:])
        return s[len(s) // 2]


class TrainLoop:
    def __init__(self, loss_fn: Callable, params: Any, opt_cfg: OptConfig,
                 loop_cfg: LoopConfig, donate: bool = True):
        self.loss_fn = loss_fn
        self.loop_cfg = loop_cfg
        self.opt_cfg = opt_cfg
        # own our copy: the jitted step donates param buffers, and the
        # caller's tree must stay usable (e.g. to seed another loop)
        self.params = jax.tree.map(jnp.copy, params)
        self.opt_state = init_opt(params, opt_cfg)
        self.err_state = (init_error_state(params)
                          if loop_cfg.compression.kind != "none" else None)
        self.start_step = 0
        self.watchdog = Watchdog(loop_cfg.straggler_factor)
        self.history: list[dict] = []
        self._step_fn = self._build_step()
        self._maybe_resume()

    # ------------------------------------------------------------------
    def _build_step(self):
        comp = self.loop_cfg.compression
        num_micro = self.loop_cfg.num_micro

        def step(params, opt_state, err_state, batch):
            if num_micro > 1:
                grads, loss = microbatch_grads(
                    self.loss_fn, params, batch, num_micro)
            else:
                loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
            if comp.kind != "none":
                grads, err_state = compress_tree(grads, err_state, comp)
            params, opt_state = apply_updates(params, grads, opt_state,
                                              self.opt_cfg)
            return params, opt_state, err_state, loss

        return jax.jit(step, donate_argnums=(0, 1, 2))

    # ------------------------------------------------------------------
    def _state_tree(self):
        tree = {"params": self.params, "opt": self.opt_state._asdict()}
        if self.err_state is not None:
            tree["err"] = self.err_state
        return tree

    def _maybe_resume(self):
        cfg = self.loop_cfg
        if cfg.ckpt_dir is None:
            return
        step = ckpt.latest_step(cfg.ckpt_dir)
        if step is None:
            return
        like = self._state_tree()
        restored, meta = ckpt.restore(cfg.ckpt_dir, step, like)
        self.params = restored["params"]
        self.opt_state = OptState(**restored["opt"])
        if self.err_state is not None:
            self.err_state = restored["err"]
        self.start_step = int(meta.get("next_step", step))

    # ------------------------------------------------------------------
    def run(self, batch_iter, steps: Optional[int] = None) -> dict:
        cfg = self.loop_cfg
        total = steps if steps is not None else cfg.total_steps
        err = self.err_state if self.err_state is not None else {}
        step = self.start_step
        last_loss = None
        while step < total:
            batch = next(batch_iter)
            t0 = time.perf_counter()
            self.params, self.opt_state, err, loss = self._step_fn(
                self.params, self.opt_state, err, batch)
            jax.block_until_ready(loss)
            dt = time.perf_counter() - t0
            step += 1
            straggler = self.watchdog.observe(step, dt)
            last_loss = float(loss)
            if step % cfg.log_every == 0 or straggler:
                self.history.append(
                    {"step": step, "loss": last_loss, "dt": dt,
                     "straggler": straggler})
            if cfg.ckpt_dir and step % cfg.ckpt_every == 0:
                self.err_state = err if self.err_state is not None else None
                ckpt.save_async(cfg.ckpt_dir, step, self._state_tree(),
                                meta={"next_step": step})
        if self.err_state is not None:
            self.err_state = err
        if cfg.ckpt_dir:
            ckpt.wait_pending()      # async writers finish before GC/final
            ckpt.save(cfg.ckpt_dir, step, self._state_tree(),
                      meta={"next_step": step})
            ckpt.gc_tmp(cfg.ckpt_dir)
        return {"final_step": step, "final_loss": last_loss,
                "stragglers": self.watchdog.stragglers,
                "median_dt": self.watchdog.median,
                "history": self.history}
