from .optimizer import OptConfig, OptState, init_opt, apply_updates, warmup_cosine
from .loop import LoopConfig, TrainLoop, Watchdog
from .losses import bce_with_logits, mse, softmax_xent_dense
from . import checkpoint

__all__ = [
    "OptConfig", "OptState", "init_opt", "apply_updates", "warmup_cosine",
    "LoopConfig", "TrainLoop", "Watchdog",
    "bce_with_logits", "mse", "softmax_xent_dense",
    "checkpoint",
]
