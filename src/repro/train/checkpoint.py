"""Fault-tolerant checkpointing: sharded-safe, async, atomic, elastic.

Format (directory per step):
    ckpt_dir/step_000123.tmp-<nonce>/   (written, fsynced)
        arrays.npz        flattened path->array (host-gathered)
        manifest.msgpack  {step, time, tree structure, meta, crc}
    -> atomic rename to ckpt_dir/step_000123/   (commit point)

* **Crash safety**: readers only ever see fully-committed directories;
  torn writes stay behind the `.tmp-` prefix and are garbage-collected.
* **Async**: `save_async` snapshots to host memory synchronously (cheap)
  and writes in a daemon thread — the train loop never blocks on disk.
* **Elastic restore**: arrays are restored host-side and `device_put` with
  whatever sharding the *new* mesh prescribes — checkpoints carry logical
  state only, so restarts may change device count/topology freely.
"""

from __future__ import annotations

import io
import os
import re
import shutil
import threading
import time
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "gc_tmp",
           "wait_pending"]

_PENDING: list[threading.Thread] = []


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(p): np.asarray(l) for p, l in flat}


def _paths_and_treedef(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(p) for p, _ in leaves], treedef


def save(ckpt_dir: str, step: int, tree: Any, meta: Optional[dict] = None
         ) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten(tree)
    return _write(ckpt_dir, step, arrays, meta or {})


def save_async(ckpt_dir: str, step: int, tree: Any,
               meta: Optional[dict] = None) -> threading.Thread:
    """Snapshot now (host copy), write in the background."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten(tree)          # synchronous device->host snapshot
    t = threading.Thread(
        target=_write, args=(ckpt_dir, step, arrays, meta or {}),
        daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in list(_PENDING):
        t.join()
        _PENDING.remove(t)


def _write(ckpt_dir: str, step: int, arrays: dict, meta: dict) -> str:
    nonce = f"{os.getpid()}-{int(time.time() * 1e6) % 10**9}"
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + f".tmp-{nonce}"
    os.makedirs(tmp, exist_ok=True)
    buf = io.BytesIO()
    np.savez(buf, **{k: v for k, v in arrays.items()})
    payload = buf.getvalue()
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": list(arrays.keys()),
        "meta": meta,
        "crc": zlib.crc32(payload) & 0xFFFFFFFF,
        "nbytes": len(payload),
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.rename(tmp, final)            # commit point
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and _valid(os.path.join(ckpt_dir, name)):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def _valid(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
            man = msgpack.unpackb(f.read())
        with open(os.path.join(path, "arrays.npz"), "rb") as f:
            payload = f.read()
        return (zlib.crc32(payload) & 0xFFFFFFFF) == man["crc"]
    except Exception:
        return False


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Optional[Any] = None) -> tuple[Any, dict]:
    """Restore into the structure of `like`; `shardings` (same structure)
    triggers elastic re-placement onto the current mesh."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    if not _valid(path):
        raise IOError(f"checkpoint {path} missing or corrupt")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        man = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    paths, treedef = _paths_and_treedef(like)
    like_leaves = jax.tree_util.tree_flatten_with_path(like)[0]
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(paths))
    out = []
    for (p, leaf), sh in zip(like_leaves, shard_leaves):
        key = jax.tree_util.keystr(p)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.asarray(data[key]).astype(leaf.dtype)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), man["meta"]


def gc_tmp(ckpt_dir: str, keep_last: int = 3):
    """Remove torn writes and old steps beyond `keep_last`."""
    if not os.path.isdir(ckpt_dir):
        return
    for name in os.listdir(ckpt_dir):
        if ".tmp-" in name:
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
    steps = sorted(
        int(m.group(1)) for m in
        (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(ckpt_dir)) if m)
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)
