"""Task losses shared by examples / launchers / smoke tests."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["bce_with_logits", "mse", "softmax_xent_dense"]


def bce_with_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Numerically stable sigmoid cross-entropy, mean over batch."""
    z = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def mse(pred: jax.Array, target: jax.Array) -> jax.Array:
    d = pred.astype(jnp.float32) - target.astype(jnp.float32)
    return jnp.mean(d * d)


def softmax_xent_dense(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Plain CE for small-vocab heads (GNN node classification)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
