"""Fused shard_map exchanges — the whole k-relaxation step on-mesh.

Unlike ``dist.collectives.pa_exchange`` (which computes local edges
replicated, outside the shard_map), both schedules here run local AND
remote work inside one shard_map block, so every shard touches only its
own slice — the paper's §6 DM execution model, end to end:

  * ``sharded_push`` — per shard: frontier-masked local scatter into the
    owned slice, frontier-masked remote scatter into a full-length
    private accumulator, then one combining collective delivers the
    owner slices (``psum_scatter`` for sum; ``pmin``/``pmax`` + slice
    otherwise). The remote accumulator can pass through error-feedback
    top-k compression (``dist.compression``) before the collective —
    the paper's "reduce what crosses the wire" lever applied to the
    message exchange itself.
  * ``sharded_pull`` — per shard: all_gather the value vector, then
    privately combine ALL in-edges of the owned destinations. Three
    interchangeable inner executors: ``dense`` (segment ops over the
    dst-grouped COO rows, preserving the single-device combine order),
    ``ell`` (rectangular gather+reduce over the per-shard ELL row
    block), and ``pallas`` (the ``ell_spmv`` kernel on the same block).

Message convention matches ``core.primitives``: ``msg_fn=None`` means
copy (the wire value itself); ``msg_fn(x, w)`` receives the raw
per-edge weight vector (un-broadcast — batched algorithms broadcast
inside their own msg_fn). Frontiers mask per *edge* inside the block,
so non-frontier sources contribute the combine identity exactly as
``push_relax`` does.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core.backend import classify_msg_fn
from ..core.primitives import combine_identity
from ..dist.collectives import merge_combine
from ..dist.compression import CompressionConfig, compress_tree
from ..sparse.segment import segment_max, segment_min, segment_sum
from .topology import ShardTopology

__all__ = ["sharded_push", "sharded_pull", "active_remote_edges"]

_SEGMENT = {"sum": segment_sum, "min": segment_min, "max": segment_max}


def _edge_messages(vals, w, msg_fn, combine, active):
    """Per-edge payloads, inactive slots carrying the combine identity."""
    msg = vals if msg_fn is None else msg_fn(vals, w)
    if msg.ndim == 2:
        active = active[:, None]
    return jnp.where(active, msg, combine_identity(combine, msg.dtype))


def active_remote_edges(topo: ShardTopology, frontier: jax.Array):
    """Number of cut edges whose source is in the frontier — the sparse
    wire-message count a real DM push would send as (index, value)
    pairs. ``frontier`` is the unpadded ``[n]`` mask; sentinel slots
    fall outside it and count as inactive."""
    from ..core.cost_model import counter_dtype
    src = topo.remote.src.reshape(-1)
    ok = topo.remote.valid.reshape(-1)
    act = jnp.take(frontier, src, axis=0, mode="fill", fill_value=False)
    return jnp.sum((act & ok).astype(counter_dtype()))


def _scatter(msg, dst, ok, base, num_local, npad, combine, local: bool):
    """Segment-combine ``msg`` by destination; padding slots go to a
    trailing scratch row that is dropped (never aliasing a real
    vertex, which would perturb sum combine order)."""
    if local:
        seg = jnp.where(ok, dst - base, num_local)
        return _SEGMENT[combine](
            msg, jnp.clip(seg, 0, num_local), num_local + 1)[:num_local]
    seg = jnp.where(ok, dst, npad)
    return _SEGMENT[combine](msg, jnp.clip(seg, 0, npad), npad + 1)[:npad]


def sharded_push(mesh: Mesh, topo: ShardTopology, values_pad: jax.Array,
                 frontier_pad: jax.Array,
                 combine: str = "sum",
                 msg_fn: Optional[Callable] = None,
                 axis: str = "data",
                 cfg: Optional[CompressionConfig] = None,
                 err: Optional[jax.Array] = None):
    """Fused PA push step. ``values_pad``: ``[n_padded(,B)]``;
    ``frontier_pad``: ``bool[n_padded]``. When ``cfg``/``err`` are given
    (sum combine, 1-D float payload) the remote accumulator is
    compressed with error feedback before the collective. Returns
    ``(out [n_padded(,B)], new_err)`` — ``new_err`` is ``err`` (possibly
    None) when compression is off."""
    part = topo.part
    shard, npad = part.shard_size, part.n_padded
    loc_e, rem_e = topo.local, topo.remote
    compressing = (cfg is not None and cfg.kind != "none"
                   and err is not None)

    edge_spec = P(axis, None)

    def body(vb, fb, ls, ld, lw, lok, rs, rd, rw, rok, eb):
        base = jax.lax.axis_index(axis) * shard

        def gather_side(sb, db, wb, okb, local):
            src = sb.reshape(-1)
            ok = okb.reshape(-1)
            lidx = jnp.clip(src - base, 0, shard - 1)
            act = ok & fb[lidx]
            msg = _edge_messages(vb[lidx], wb.reshape(-1), msg_fn,
                                 combine, act)
            return _scatter(msg, db.reshape(-1), ok, base, shard, npad,
                            combine, local)

        loc = gather_side(ls, ld, lw, lok, local=True)
        acc = gather_side(rs, rd, rw, rok, local=False)

        new_err = eb
        if compressing:
            dec, res = compress_tree(acc + eb.reshape(-1),
                                     jnp.zeros_like(acc), cfg)
            # error feedback: carry acc + err - sent forward
            new_err = res.reshape(eb.shape)
            acc = dec

        if combine == "sum":
            rem = jax.lax.psum_scatter(acc, axis, scatter_dimension=0,
                                       tiled=True)
        else:
            red = (jax.lax.pmin if combine == "min"
                   else jax.lax.pmax)(acc, axis)
            rem = jax.lax.dynamic_slice_in_dim(red, base, shard)
        return merge_combine(combine, loc, rem), new_err

    in_specs = (P(axis), P(axis)) + (edge_spec,) * 8 + (edge_spec,)
    out_specs = (P(axis), edge_spec)
    if err is None:
        # keep a uniform body signature; feed a zero-size dummy carry
        err_in = jnp.zeros((part.num_parts, 0), jnp.float32)
    else:
        err_in = err
    block = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    out, err_out = block(values_pad, frontier_pad,
                         loc_e.src, loc_e.dst, loc_e.w, loc_e.valid,
                         rem_e.src, rem_e.dst, rem_e.w, rem_e.valid,
                         err_in)
    return out, (err_out if err is not None else err)


def sharded_pull(mesh: Mesh, topo: ShardTopology, values_pad: jax.Array,
                 combine: str = "sum",
                 msg_fn: Optional[Callable] = None,
                 axis: str = "data", inner: str = "dense",
                 n: int = 0,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Fused pull step: all_gather + private per-shard combine of ALL
    in-edges. ``inner`` picks the per-shard executor (``dense`` |
    ``ell`` | ``pallas``); ``n`` is the true vertex count (the ELL
    sentinel / index validity bound). Returns ``[n_padded(,B)]``."""
    part = topo.part
    shard, npad = part.shard_size, part.n_padded
    mode = classify_msg_fn(msg_fn) if inner == "pallas" else None
    if inner == "pallas" and mode is None:
        inner = "ell"     # exotic msg_fn: same layout, jnp executor

    edges = topo.pull_edges

    def dense_body(vb, sb, db, wb, okb):
        full = jax.lax.all_gather(vb, axis, tiled=True)   # [npad(,B)]
        base = jax.lax.axis_index(axis) * shard
        src = sb.reshape(-1)
        ok = okb.reshape(-1)
        msg = _edge_messages(full[jnp.clip(src, 0, npad - 1)],
                             wb.reshape(-1), msg_fn, combine, ok)
        return _scatter(msg, db.reshape(-1), ok, base, shard, npad,
                        combine, local=True)

    def ell_body(vb, idxb, wb):
        full = jax.lax.all_gather(vb, axis, tiled=True)
        fullp = jnp.pad(full, [(0, 1)] + [(0, 0)] * (full.ndim - 1))
        idx = idxb.reshape((shard,) + idxb.shape[2:])
        w = wb.reshape((shard,) + wb.shape[2:])
        if inner == "pallas":
            from ..kernels.ell_spmv import ell_spmv_pallas
            return ell_spmv_pallas(
                fullp, idx, w, combine=combine, msg=mode,
                block_n=min(256, shard), interpret=interpret,
                num_sources=n).astype(vb.dtype)
        gathered = jnp.take(fullp, jnp.clip(idx, 0, npad), axis=0)
        if msg_fn is not None:
            we = w[..., None] if gathered.ndim == 3 else w
            gathered = msg_fn(gathered, we)
        valid = idx < n
        if gathered.ndim == 3:
            valid = valid[..., None]
        ident = combine_identity(combine, gathered.dtype)
        gathered = jnp.where(valid, gathered, ident)
        if combine == "sum":
            return gathered.sum(axis=1).astype(vb.dtype)
        if combine == "max":
            return gathered.max(axis=1)
        return gathered.min(axis=1)

    if inner == "dense":
        block = jax.shard_map(
            dense_body, mesh=mesh,
            in_specs=(P(axis),) + (P(axis, None),) * 4,
            out_specs=P(axis), check_vma=False)
        return block(values_pad, edges.src, edges.dst, edges.w,
                     edges.valid)
    block = jax.shard_map(
        ell_body, mesh=mesh,
        in_specs=(P(axis), P(axis, None, None), P(axis, None, None)),
        out_specs=P(axis), check_vma=False)
    return block(values_pad, topo.ell_idx, topo.ell_w)
