"""ShardedBackend — the engine's k-relaxation under shard_map (§6).

Where ``DistributedBackend`` demonstrates the paper's DM *exchanges*
(local work replicated, remote through collectives), this backend runs
the whole step on-mesh: values live sharded ``[P × shard_size]``, local
and remote edges are both processed inside one shard_map block per
direction (``shard.exchange``), and only the remote accumulator crosses
devices. It is the production surface behind ``api.solve(...,
backend="shard")``.

Wire-byte accounting is *adaptive*, mirroring the paper's sparse/dense
message tradeoff: a push step charges
``min(dense alltoall, active_cut_edges · (index + payload))`` per device
— so a frontier-sparse push (BFS early steps) prices below the flat
all_gather pull, and ``AutoSwitch`` can flip direction for distributed
reasons alone. ``predict_comm_bytes`` computes the identical formulas,
keeping the predictor exact for exchange steps.

Optional push-side compression (``dist.compression``): the remote
accumulator passes through error-feedback top-k / int8 before the
combining collective. The error carry rides the engine loop via
``init_exchange_state``/``relax_ex``. Compression applies to sum
combines with 1-D float32 payloads (PageRank-shaped exchanges); other
cells pass the carry through untouched.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..core.backend import ExchangeBackend
from ..core.cost_model import Cost, counter, counter_dtype
from ..resilience import resilient_call
from ..core.direction import Direction
from ..core.primitives import (combine_identity, frontier_out_edges,
                               mask_untouched)
from ..dist.compression import CompressionConfig
from ..graphs.structure import Graph
from .exchange import active_remote_edges, sharded_pull, sharded_push
from .mesh import make_shard_mesh
from .topology import ShardTopology, build_topology

__all__ = ["ShardedBackend"]

_IDX_BYTES = 4          # int32 vertex index on the sparse push wire


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedBackend(ExchangeBackend):
    """Multi-device k-relaxation over a 1D vertex partition.

    Build with :meth:`prepare`; instances are graph-specific (they hold
    the per-shard topology). ``inner`` selects the pull executor:
    ``"dense"`` (order-preserving segment ops — bit-compatible with the
    single-device dense pull), ``"ell"`` or ``"pallas"`` (rectangular
    per-shard row blocks — the ELL/kernel semantics).
    """
    mesh: object = None
    topo: Optional[ShardTopology] = None
    axis: str = "data"
    inner: str = "dense"
    compression: Optional[CompressionConfig] = None
    interpret: Optional[bool] = None

    # the pull gathers the full vector and (for ELL inners) scans every
    # row; dense inner also reads all m edges — rectangular semantics
    pull_scans_all = True

    # identity hash/eq (see DistributedBackend: instances hold jnp
    # arrays; engine caches key on backend identity, and value-based
    # dataclass comparison would collide across same-shape graphs)
    __hash__ = object.__hash__

    def __eq__(self, other):
        return self is other

    @classmethod
    def prepare(cls, g: Graph, mesh=None, num_shards: Optional[int] = None,
                axis: str = "data", inner: str = "dense",
                compression: Optional[CompressionConfig] = None,
                interpret: Optional[bool] = None) -> "ShardedBackend":
        from ..graphs.partition import partition_1d
        if mesh is None:
            mesh = make_shard_mesh(num_shards, axis=axis)
        P = mesh.shape[axis]
        if num_shards is not None and num_shards != P:
            raise ValueError(
                f"num_shards={num_shards} must equal the mesh '{axis}' "
                f"axis size ({P}): partitions map to mesh shards 1:1.")
        if inner not in ("dense", "ell", "pallas"):
            raise ValueError(f"unknown inner executor {inner!r}")
        part = partition_1d(g.n, P)      # validates 1 <= P <= n
        topo = build_topology(g, part)
        return cls(mesh=mesh, topo=topo, axis=axis, inner=inner,
                   compression=compression, interpret=interpret)

    # -- helpers -----------------------------------------------------------
    @property
    def part(self):
        return self.topo.part

    @property
    def cut_edges(self) -> int:
        return self.topo.cut_edges

    def telemetry_counters(self) -> dict:
        """Shard geometry for obs traces: the facts the §6 wire-byte
        charges are priced from (device count, the PA cut, padded row
        count), plus whether compression is on."""
        return {"num_shards": self.part.num_parts,
                "cut_edges": self.cut_edges,
                "n_padded": self.part.n_padded,
                "compression": int(self.compression is not None)}

    def _pad(self, values: jax.Array, fill) -> jax.Array:
        extra = max(0, self.part.n_padded - values.shape[0])
        widths = ((0, extra),) + ((0, 0),) * (values.ndim - 1)
        return jnp.pad(values, widths, constant_values=fill)

    def _compresses(self, values, combine: str) -> bool:
        """Trace-time gate: compression covers the PageRank-shaped
        exchange — sum combine over a 1-D float32 payload."""
        return (self.compression is not None
                and self.compression.kind != "none"
                and combine == "sum" and values.ndim == 1
                and values.dtype == jnp.float32)

    def _zero_err(self) -> jax.Array:
        return jnp.zeros((self.part.num_parts, self.part.n_padded),
                         jnp.float32)

    def _wire_push_bytes(self, values, frontier):
        """Per-run total push wire bytes: adaptive min(dense combined
        alltoall, sparse (index, payload) pairs over the active cut),
        or the compressed top-k/int8 footprint."""
        Pn = self.part.num_parts
        npad = self.part.n_padded
        width = 1 if values.ndim == 1 else values.shape[-1]
        item = values.dtype.itemsize * width
        if (self.compression is not None
                and self.compression.kind != "none"
                and values.ndim == 1 and values.dtype == jnp.float32):
            if self.compression.kind == "topk":
                k = max(1, int(self.compression.topk_frac * npad))
                per_dev = counter(k * (_IDX_BYTES + 4))
            else:                               # int8: payload + scale
                per_dev = counter(npad + 4)
            return per_dev * Pn
        dense = counter(npad * item)
        sparse = active_remote_edges(self.topo, frontier) * (
            _IDX_BYTES + item)
        return jnp.minimum(dense, sparse).astype(counter_dtype()) * Pn

    def _wire_pull_bytes(self, values):
        Pn = self.part.num_parts
        npad = self.part.n_padded
        width = 1 if values.ndim == 1 else values.shape[-1]
        item = values.dtype.itemsize * width
        return counter(npad * item * (Pn - 1) // max(Pn, 1)) * Pn

    # -- exchange state (error-feedback carry) ----------------------------
    def init_exchange_state(self, g: Graph):
        if self.compression is not None and self.compression.kind != "none":
            return self._zero_err()
        return ()

    # -- ExchangeBackend ---------------------------------------------------
    def _push_ex(self, g, values, frontier, combine, msg_fn, cost, err):
        vpad = self._pad(values, 0)
        fpad = self._pad(frontier, False)
        compressing = err is not None and self._compresses(values, combine)
        # the collective build is pure trace-time work, so a transient
        # failure (injected or a flaky mesh) is safely retried in place
        out, new_err = resilient_call(
            "shard.exchange.push",
            lambda: sharded_push(
                self.mesh, self.topo, vpad, fpad, combine=combine,
                msg_fn=msg_fn, axis=self.axis,
                cfg=self.compression if compressing else None,
                err=err if compressing else None))
        width = 1 if values.ndim == 1 else values.shape[-1]
        k = frontier_out_edges(g, frontier) * width
        kc = jnp.minimum(k, counter(self.cut_edges) * width)
        cost = cost.charge(reads=k).charge_combining_writes(
            kc, float_data=jnp.issubdtype(values.dtype, jnp.floating))
        cost = cost.charge(
            messages=kc,
            collective_bytes=self._wire_push_bytes(values, frontier))
        return out[:g.n], cost, (new_err if compressing else err)

    def push(self, g, values, frontier, combine, msg_fn, cost):
        # stateless surface: compression (when configured) runs with a
        # zero error carry — single-step view; feedback accumulates only
        # through relax_ex / the engine loop.
        err = (self._zero_err()
               if self._compresses(values, combine) else None)
        out, cost, _ = self._push_ex(g, values, frontier, combine,
                                     msg_fn, cost, err)
        return out, cost

    def pull(self, g, values, touched, combine, msg_fn, cost):
        ident = combine_identity(combine, values.dtype)
        vpad = self._pad(values, ident)
        out = resilient_call(
            "shard.exchange.pull",
            lambda: sharded_pull(
                self.mesh, self.topo, vpad, combine=combine,
                msg_fn=msg_fn, axis=self.axis, inner=self.inner, n=g.n,
                interpret=self.interpret))[:g.n]
        if touched is not None:
            out = mask_untouched(out, touched, combine)
        width = 1 if values.ndim == 1 else values.shape[-1]
        # rectangular semantics: every in-edge is read, every owned
        # vertex written, regardless of the touched set
        cost = cost.charge(
            reads=counter(g.m) * width, writes=counter(g.n) * width,
            collective_bytes=self._wire_pull_bytes(values))
        return out, cost

    def relax_ex(self, g, values, frontier, *, direction,
                 combine: str = "sum",
                 msg_fn: Optional[Callable] = None,
                 touched: Optional[jax.Array] = None,
                 cost: Cost = Cost(), xstate=()):
        stateless = isinstance(xstate, tuple)
        if stateless or not self._compresses(values, combine):
            out, cost = self.relax(g, values, frontier,
                                   direction=direction, combine=combine,
                                   msg_fn=msg_fn, touched=touched,
                                   cost=cost)
            return out, cost, xstate
        if isinstance(direction, Direction):
            if direction == Direction.PUSH:
                return self._push_ex(g, values, frontier, combine,
                                     msg_fn, cost, xstate)
            out, cost = self.pull(g, values, touched, combine, msg_fn,
                                  cost)
            return out, cost, xstate
        return jax.lax.cond(
            direction,
            lambda v, f, c, e: self._push_ex(g, v, f, combine, msg_fn,
                                             c, e),
            lambda v, f, c, e: self.pull(g, v, touched, combine,
                                         msg_fn, c) + (e,),
            values, frontier, cost, xstate)

    def predict_comm_bytes(self, g, values, frontier):
        return (self._wire_push_bytes(values, frontier),
                self._wire_pull_bytes(values))
