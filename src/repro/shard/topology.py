"""Sharded graph topology — the per-shard views every exchange consumes.

Built host-side once per (graph, partition) pair, mirroring how
``graphs.structure`` materializes multi-layout views once per graph:

  * **push layout** — the Partition-Awareness split (paper §5-PA):
    ``local`` edges (both endpoints owned by one shard) grouped by that
    owner, and ``remote`` cut edges grouped by the *source* owner (the
    shard that sends).
  * **pull layout** — ALL edges grouped by the *destination* owner,
    preserving the global dst-sorted COO order. Each destination's
    in-edges therefore stay contiguous and in the same relative order as
    the single-device ``pull_relax`` segment ops, which keeps the
    per-destination combine order identical across shard counts (exact
    reproducibility for order-sensitive sums).
  * **ELL row blocks** — the ``[n, d_ell]`` padded in-neighbor matrix
    cut into ``[P, shard_size, d_ell]`` row slices, so the per-shard
    pull can run the ELL gather+reduce (or the Pallas ``ell_spmv``
    kernel) against the all_gathered value vector.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from ..graphs.partition import (Partition, PartitionedEdges, _pack,
                                pa_split)
from ..graphs.structure import Graph

__all__ = ["ShardTopology", "build_topology"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardTopology:
    """Per-shard edge/row views for one (graph, partition) pair."""
    part: Partition
    local: PartitionedEdges       # PA local edges, by owner (push layout)
    remote: PartitionedEdges      # PA cut edges, by src owner (push layout)
    pull_edges: PartitionedEdges  # ALL edges by dst owner, coo order kept
    ell_idx: jax.Array            # int32[P, shard_size, d_ell]
    ell_w: jax.Array              # float32[P, shard_size, d_ell]
    cut_edges: int = dataclasses.field(metadata=dict(static=True))
    border_vertices: int = dataclasses.field(metadata=dict(static=True))


def build_topology(g: Graph, part: Partition,
                   align: int = 128) -> ShardTopology:
    """Materialize every per-shard view of ``g`` under ``part``."""
    P = part.num_parts
    local, remote, stats = pa_split(g, part, align=align)

    # pull layout: all edges grouped by dst owner. Boolean-mask selection
    # preserves the global coo (dst-sorted) order inside each group, so
    # each destination's in-edges keep their single-device combine order.
    src = np.asarray(g.coo_src)
    dst = np.asarray(g.coo_dst)
    w = np.asarray(g.coo_w)
    own_d = part.owner_np(dst)
    rows = [src[own_d == p] for p in range(P)]
    cols = [dst[own_d == p] for p in range(P)]
    ws = [w[own_d == p] for p in range(P)]
    pull_edges = _pack(rows, cols, ws, P, g.n, align)

    # ELL row blocks: pad the row axis to n_padded (sentinel rows are
    # empty — index n is already the ELL invalid marker) and cut into
    # per-shard slices.
    extra = part.n_padded - g.n
    ell_idx = np.pad(np.asarray(g.ell_idx), ((0, extra), (0, 0)),
                     constant_values=g.n)
    ell_w = np.pad(np.asarray(g.ell_w), ((0, extra), (0, 0)))
    return ShardTopology(
        part=part, local=local, remote=remote, pull_edges=pull_edges,
        ell_idx=jax.numpy.asarray(
            ell_idx.reshape(P, part.shard_size, g.d_ell)),
        ell_w=jax.numpy.asarray(ell_w.reshape(P, part.shard_size, g.d_ell)),
        cut_edges=int(stats["cut_edges"]),
        border_vertices=int(stats["border_vertices"]))
