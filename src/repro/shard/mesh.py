"""Device mesh construction for the sharded engine (paper §6 DM setting).

One mesh shape serves the whole subsystem: ``(P, 1)`` over axes
``(axis, "model")`` — a 1D data decomposition matching the 1D vertex
partition, with a trivial model axis so the same mesh composes with the
training-side utilities in ``repro.dist``. Vertex shard ``p`` lives on
device ``p``; all exchanges run along ``axis``.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_shard_mesh"]


def make_shard_mesh(num_shards: int | None = None,
                    axis: str = "data") -> Mesh:
    """Build the ``(P, 1)`` mesh the sharded backend runs under.

    ``num_shards=None`` takes every visible device. Rejects requests for
    more shards than devices (shard_map cannot oversubscribe an axis)
    and for fewer than one.
    """
    devices = jax.devices()
    P = len(devices) if num_shards is None else num_shards
    if P < 1:
        raise ValueError(
            f"num_shards={P} is invalid: a mesh needs at least one shard")
    if P > len(devices):
        raise ValueError(
            f"num_shards={P} exceeds the {len(devices)} visible devices; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count to fake "
            "more on CPU")
    return Mesh(np.array(devices[:P]).reshape(P, 1), (axis, "model"))
