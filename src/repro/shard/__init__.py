"""repro.shard — the engine's k-relaxation sharded across a device mesh.

The paper's §6 DM setting as a production subsystem: a 1D vertex
partition, Partition-Aware local/remote edge split, fused shard_map
push/pull exchanges (push optionally compressed with error feedback),
and adaptive inter-device wire-byte accounting that lets ``AutoSwitch``
flip direction for communication reasons alone.

Entry points: ``ShardedBackend.prepare(g, ...)`` or
``api.solve(g, algo, backend="shard")``.
"""

from .backend import ShardedBackend
from .exchange import active_remote_edges, sharded_pull, sharded_push
from .mesh import make_shard_mesh
from .topology import ShardTopology, build_topology

__all__ = ["ShardedBackend", "make_shard_mesh", "ShardTopology",
           "build_topology", "sharded_push", "sharded_pull",
           "active_remote_edges"]
