"""Structured error types for the resilience layer.

Every failure the recovery machinery can surface has a named type, so
callers (and tests) match on the class instead of parsing messages:

  * :class:`FaultInjected` — raised *by the injector* at a fault site.
    Subclasses ``RuntimeError`` so un-wired sites fail loudly, but the
    recovery seams catch it explicitly alongside the real error class
    the seam handles (e.g. ``OSError`` at the disk-cache sites).
  * :class:`DivergenceError` — the engine's non-finite detector: a
    float leaf went NaN (or ±Inf in strict mode) at a known step.
  * :class:`ProbeTimeout` — a tuner probe exceeded its wall deadline.
  * :class:`DeadlineExceeded` — a query's ``deadline_ms`` elapsed
    before it finished (queued or mid-solve).
  * :class:`AdmissionError` — the service's bounded queue refused a
    new request (back-pressure, not failure).
  * :class:`SolveInterrupted` — a checkpointed stepwise solve died
    mid-loop; carries the last :attr:`checkpoint` so the caller can
    resume instead of restarting.
"""

from __future__ import annotations

__all__ = ["FaultInjected", "DivergenceError", "ProbeTimeout",
           "DeadlineExceeded", "AdmissionError", "SolveInterrupted"]


class FaultInjected(RuntimeError):
    """An injected fault from an active :class:`FaultPlan`.

    Attributes:
        site: the fault-site name that fired.
        hit: 1-based invocation index of the site when it fired.
    """

    def __init__(self, site: str, hit: int, message: str = ""):
        self.site = site
        self.hit = hit
        super().__init__(
            message or f"injected fault at {site!r} (hit #{hit})")


class DivergenceError(RuntimeError):
    """A solve produced non-finite state — aborted instead of burning
    the remaining step budget on poisoned values.

    Attributes:
        step: the engine step after which the check tripped.
        mode: ``"nan"`` (NaN only) or ``"all"`` (NaN or ±Inf).
    """

    def __init__(self, step: int, mode: str = "nan", detail: str = ""):
        self.step = step
        self.mode = mode
        super().__init__(
            f"non-finite state detected after step {step} "
            f"(check_finite={mode!r}){': ' + detail if detail else ''}")


class ProbeTimeout(RuntimeError):
    """A tuner probe blew its wall-clock deadline; the worker thread is
    abandoned (daemonized) and the tuner degrades to the default
    candidate."""

    def __init__(self, kernel: str, deadline_s: float):
        self.kernel = kernel
        self.deadline_s = deadline_s
        super().__init__(
            f"tuner probe for {kernel!r} exceeded its {deadline_s:g}s "
            f"deadline")


class DeadlineExceeded(RuntimeError):
    """A query's ``deadline_ms`` elapsed before it could be served."""

    def __init__(self, rid: int, deadline_ms: float, waited_ms: float,
                 where: str = "queued"):
        self.rid = rid
        self.deadline_ms = deadline_ms
        self.waited_ms = waited_ms
        self.where = where
        super().__init__(
            f"query {rid} missed its {deadline_ms:g}ms deadline "
            f"({waited_ms:.1f}ms elapsed, {where})")


class AdmissionError(RuntimeError):
    """The service's bounded queue refused a new request — back-pressure
    the caller should respond to (shed load, retry later)."""

    def __init__(self, queued: int, max_queue: int):
        self.queued = queued
        self.max_queue = max_queue
        super().__init__(
            f"admission refused: {queued} requests already queued "
            f"(max_queue={max_queue})")


class SolveInterrupted(RuntimeError):
    """A checkpointed stepwise solve was interrupted mid-loop.

    Attributes:
        checkpoint: the last :class:`repro.core.engine.Checkpoint`
            taken before the failure (None when the failure predates
            the first snapshot).
        step: the step index the loop was on when it died.

    ``__cause__`` carries the original error. ``api.solve`` catches
    this and resumes from the checkpoint automatically (bounded retry
    count); manual callers pass ``checkpoint`` back via
    ``run_stepwise(..., resume_from=...)``.
    """

    def __init__(self, step: int, checkpoint=None):
        self.step = step
        self.checkpoint = checkpoint
        at = (f"resumable from step {checkpoint.step}"
              if checkpoint is not None else "no checkpoint taken")
        super().__init__(
            f"stepwise solve interrupted at step {step} ({at})")
