"""repro.resilience — deterministic fault injection + recovery ladders.

Three pieces (see ``docs/resilience.md``):

  * :mod:`~repro.resilience.faults` — the seeded
    :class:`FaultPlan`/:class:`FaultInjector` and the
    :func:`fault_point` seam wired into kernels, tuner, shards,
    serving, caches, and the stepwise engine loop; plus the
    ``resilience.*`` counter/event bookkeeping ``repro.obs`` drains.
  * :mod:`~repro.resilience.breaker` — the per-(kernel, shape)
    :class:`CircuitBreaker` behind the Pallas degradation ladder.
  * :mod:`~repro.resilience.errors` — structured failure types
    (:class:`DivergenceError`, :class:`DeadlineExceeded`,
    :class:`AdmissionError`, :class:`SolveInterrupted`, ...).
"""

from .breaker import CircuitBreaker
from .errors import (AdmissionError, DeadlineExceeded, DivergenceError,
                     FaultInjected, ProbeTimeout, SolveInterrupted)
from .faults import (SITES, FaultInjector, FaultPlan, FaultSpec,
                     active_plan, clear_resilience_stats, deactivate,
                     drain_events, fault_point, inject, install,
                     named_plans, note, record_event, resilience_stats,
                     resilient_call)

__all__ = [
    "SITES", "FaultSpec", "FaultPlan", "FaultInjector", "fault_point",
    "install", "deactivate", "active_plan", "inject", "named_plans",
    "resilient_call", "note", "record_event", "resilience_stats",
    "drain_events", "clear_resilience_stats", "CircuitBreaker",
    "FaultInjected", "DivergenceError", "ProbeTimeout",
    "DeadlineExceeded", "AdmissionError", "SolveInterrupted",
]
