"""Seeded, deterministic fault injection for chaos testing (PR 10).

A :class:`FaultPlan` names *where* faults fire (registered sites wired
into the real seams — kernel dispatch, tuner probes, shard collectives,
service chunks, disk caches, the stepwise engine loop), *when* (a
deterministic per-site schedule: periodic ``every``/``start``/``count``
or a seeded pseudo-random ``rate``), and *what* (transient vs permanent,
and the raised error class, so a seam that recovers from ``OSError``
can be probed with exactly an ``OSError``).

The injector is process-global and **off by default**: every seam calls
:func:`fault_point`, which is one module-global read and a ``None``
check when no plan is active — no JAX ops, nothing traced, zero
overhead on the production path. Activate a plan with
:func:`install` / the :func:`inject` context manager, or via
``$REPRO_FAULT_PLAN`` (a named plan like ``ci-default``, or a path to a
plan JSON) — read once at import.

Determinism: a site's Nth invocation either faults or doesn't, as a
pure function of (plan, site, N). Runs are reproducible, shrinkable,
and — because transient schedules leave the *next* invocation clean —
retry loops provably recover.

Recovery bookkeeping lives here too: seams report what they did about
a failure (``note``), and :func:`resilience_stats` /
:func:`drain_events` expose it to ``repro.obs`` as ``resilience.*``
counters and ``resilience.*`` events.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import zlib
from collections import deque
from typing import Optional

from .errors import FaultInjected

__all__ = ["SITES", "FaultSpec", "FaultPlan", "FaultInjector",
           "fault_point", "install", "deactivate", "active_plan",
           "inject", "named_plans", "resilient_call", "note",
           "record_event", "resilience_stats", "drain_events",
           "clear_resilience_stats"]

# Every wired injection seam. fault_point() rejects unknown names so a
# renamed seam cannot silently orphan a plan.
SITES = (
    "pallas.pull",          # PallasBackend.pull kernel dispatch
    "pallas.push",          # PallasBackend.push kernel dispatch
    "tune.probe",           # autotuner probe (worker thread)
    "tune.cache.load",      # tune.json disk read
    "tune.cache.write",     # tune.json disk write
    "shard.exchange.push",  # sharded push collective build
    "shard.exchange.pull",  # sharded pull collective build
    "service.chunk",        # QueryService chunk / unbatchable solve
    "service.cache.get",    # ResultCache lookup
    "service.cache.put",    # ResultCache store
    "engine.step",          # stepwise engine loop iteration
)

_ERROR_CLASSES = {
    "FaultInjected": FaultInjected,
    "RuntimeError": RuntimeError,
    "OSError": OSError,
    "ValueError": ValueError,
    "TimeoutError": TimeoutError,
}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One site's fault schedule.

    kind:
        ``"transient"`` — scheduled hits fault, the rest succeed (a
        retry of the same operation lands on the next, clean hit).
        ``"permanent"`` — every hit from ``start`` on faults (the
        degraded path must carry the workload).
    Periodic schedule (default): 1-based hits ``start``, ``start +
    every``, ... fault, ``count`` consecutive hits at a time.
    Seeded schedule: ``rate > 0`` makes each hit fault iff a hash of
    (plan seed, site, hit) falls below ``rate`` — scattered but fully
    deterministic.
    error: raised class name (see keys of the module's error table);
    ``FaultInjected`` by default, or the exact class a seam's recovery
    handles (``OSError`` for the disk-cache sites).
    """
    site: str
    kind: str = "transient"
    every: int = 3
    start: int = 1
    count: int = 1
    rate: float = 0.0
    error: str = "FaultInjected"
    message: str = ""

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; registered: {SITES}")
        if self.kind not in ("transient", "permanent"):
            raise ValueError(
                f"kind must be 'transient' or 'permanent', "
                f"got {self.kind!r}")
        if self.error not in _ERROR_CLASSES:
            raise ValueError(
                f"unknown error class {self.error!r}; valid: "
                f"{sorted(_ERROR_CLASSES)}")
        if self.every < 1 or self.start < 1 or self.count < 1:
            raise ValueError("every/start/count must be >= 1")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A named, seeded set of :class:`FaultSpec` — the unit tests and
    CI select (``$REPRO_FAULT_PLAN``), serialize, and replay."""
    specs: tuple = ()
    name: str = "custom"
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))
        seen = set()
        for s in self.specs:
            if not isinstance(s, FaultSpec):
                raise TypeError(f"specs must be FaultSpec, got {s!r}")
            if s.site in seen:
                raise ValueError(f"duplicate spec for site {s.site!r}")
            seen.add(s.site)

    def to_json(self) -> str:
        return json.dumps(
            {"name": self.name, "seed": self.seed,
             "specs": [dataclasses.asdict(s) for s in self.specs]},
            indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        data = json.loads(text)
        return cls(name=data.get("name", "custom"),
                   seed=int(data.get("seed", 0)),
                   specs=tuple(FaultSpec(**s)
                               for s in data.get("specs", ())))


def named_plans() -> dict[str, FaultPlan]:
    """The built-in plans ``$REPRO_FAULT_PLAN`` can select by name."""
    recoverable = [s for s in SITES]
    return {
        # one transient fault on the first hit of every site, then every
        # 3rd: every recovery seam gets exercised, every retry lands on
        # a clean hit, and results must match the fault-free run
        "ci-default": FaultPlan(
            name="ci-default", seed=7,
            specs=tuple(FaultSpec(site=s, kind="transient", every=3,
                                  start=1,
                                  error=("OSError"
                                         if ".cache." in s
                                         else "FaultInjected"))
                        for s in recoverable)),
        # permanent kernel-dispatch failure: the degradation ladder
        # (jnp fallback + circuit breaker) must carry every solve
        "kernels-down": FaultPlan(
            name="kernels-down", seed=7,
            specs=(FaultSpec(site="pallas.pull", kind="permanent"),
                   FaultSpec(site="pallas.push", kind="permanent"))),
        # scattered transient faults at a seeded 20% rate across the
        # retryable sites — the soak-style schedule
        "soak": FaultPlan(
            name="soak", seed=1234,
            specs=tuple(FaultSpec(site=s, kind="transient", rate=0.2,
                                  error=("OSError" if ".cache." in s
                                         else "FaultInjected"))
                        for s in ("pallas.pull", "pallas.push",
                                  "tune.cache.load", "tune.cache.write",
                                  "service.cache.get",
                                  "service.cache.put"))),
    }


class FaultInjector:
    """Deterministic executor of one :class:`FaultPlan`.

    Thread-safe: per-site hit counters advance under a lock (the tuner
    probes from a worker thread). ``check(site)`` either returns or
    raises the spec's error class; the decision depends only on the
    plan and the site's 1-based hit index.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._by_site = {s.site: s for s in plan.specs}
        self._hits: dict[str, int] = {}
        self._injected: dict[str, int] = {}
        self._lock = threading.Lock()

    def _should_fault(self, spec: FaultSpec, hit: int) -> bool:
        if spec.kind == "permanent":
            return hit >= spec.start
        if spec.rate > 0.0:
            h = zlib.crc32(
                f"{self.plan.seed}:{spec.site}:{hit}".encode())
            return (h / 0xFFFFFFFF) < spec.rate
        if hit < spec.start:
            return False
        return (hit - spec.start) % spec.every < spec.count

    def check(self, site: str) -> None:
        if site not in SITES:
            raise ValueError(
                f"unknown fault site {site!r}; registered: {SITES}")
        spec = self._by_site.get(site)
        if spec is None:
            return
        with self._lock:
            hit = self._hits.get(site, 0) + 1
            self._hits[site] = hit
            fire = self._should_fault(spec, hit)
            if fire:
                self._injected[site] = self._injected.get(site, 0) + 1
        if fire:
            record_event("resilience.fault", site=site, hit=hit,
                         fault_kind=spec.kind)
            note(f"injected.{site}", emit=False)
            cls = _ERROR_CLASSES[spec.error]
            if cls is FaultInjected:
                raise FaultInjected(site, hit, spec.message)
            raise cls(spec.message
                      or f"injected {spec.error} at {site!r} "
                         f"(hit #{hit})")

    def stats(self) -> dict:
        with self._lock:
            return {"hits": dict(self._hits),
                    "injected": dict(self._injected)}


# -- the global seam -----------------------------------------------------
_ACTIVE: Optional[FaultInjector] = None


def fault_point(site: str) -> None:
    """The seam every wired subsystem calls. With no active plan this
    is one global read and a None check — nothing traced, no lock, no
    allocation."""
    inj = _ACTIVE
    if inj is not None:
        inj.check(site)


def install(plan: Optional[FaultPlan]) -> Optional[FaultInjector]:
    """Activate ``plan`` process-wide (None deactivates). Returns the
    new injector (or None)."""
    global _ACTIVE
    _ACTIVE = FaultInjector(plan) if plan is not None else None
    return _ACTIVE


def deactivate() -> None:
    install(None)


def active_plan() -> Optional[FaultPlan]:
    inj = _ACTIVE
    return inj.plan if inj is not None else None


class inject:
    """Context manager: run a block under ``plan``, restoring whatever
    injector (possibly an env-selected one) was active before.

        with inject(plan) as inj:
            api.solve(...)
        inj.stats()["injected"]
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.injector: Optional[FaultInjector] = None

    def __enter__(self) -> FaultInjector:
        global _ACTIVE
        self._prev = _ACTIVE
        self.injector = FaultInjector(self.plan)
        _ACTIVE = self.injector
        return self.injector

    def __exit__(self, *exc):
        global _ACTIVE
        _ACTIVE = self._prev
        return False


def _install_from_env() -> None:
    """``$REPRO_FAULT_PLAN``: a built-in plan name or a path to a plan
    JSON. Read once at import; a bad value fails loudly (a chaos CI job
    silently running fault-free would defeat its purpose)."""
    sel = os.environ.get("REPRO_FAULT_PLAN", "").strip()
    if not sel:
        return
    plans = named_plans()
    if sel in plans:
        install(plans[sel])
        return
    if os.path.exists(sel):
        with open(sel) as f:
            install(FaultPlan.from_json(f.read()))
        return
    raise ValueError(
        f"REPRO_FAULT_PLAN={sel!r} is neither a built-in plan "
        f"({sorted(plans)}) nor a readable plan JSON path")


# -- recovery bookkeeping (counters + events for repro.obs) --------------
_LOCK = threading.Lock()
_RSTATS: dict[str, int] = {}
_EVENTS: deque = deque(maxlen=512)


def note(name: str, n: int = 1, emit: bool = True, **fields) -> None:
    """Count one recovery action (``fallback.pallas.pull``,
    ``retry.service.chunk``, ``timeout.tune.probe``, ...) and, unless
    ``emit=False``, queue a ``resilience.<name>`` event for the next
    telemetry drain."""
    with _LOCK:
        _RSTATS[name] = _RSTATS.get(name, 0) + n
    if emit:
        record_event(f"resilience.{name}", **fields)


def record_event(name: str, **fields) -> None:
    with _LOCK:
        _EVENTS.append({"name": name, **fields})


def resilience_stats() -> dict[str, int]:
    """Merged snapshot: seam recovery counters plus the active
    injector's per-site injection counts (``injected.<site>``)."""
    with _LOCK:
        out = dict(_RSTATS)
    inj = _ACTIVE
    if inj is not None:
        for site, k in inj.stats()["injected"].items():
            out[f"injected.{site}"] = max(out.get(f"injected.{site}", 0),
                                          k)
    return out


def drain_events(limit: Optional[int] = None) -> list[dict]:
    """Pop queued resilience events (oldest first) for telemetry."""
    out = []
    with _LOCK:
        while _EVENTS and (limit is None or len(out) < limit):
            out.append(_EVENTS.popleft())
    return out


def clear_resilience_stats() -> None:
    with _LOCK:
        _RSTATS.clear()
        _EVENTS.clear()


def resilient_call(site: str, fn, retries: int = 2,
                   backoff_s: float = 0.0):
    """``fault_point(site)`` + ``fn()`` with bounded retries.

    The retry loop is the recovery seam for *stateless* call sites
    (trace-time collective builds, functional chunk runs): each attempt
    advances the site's hit counter, so a transient schedule fails the
    scheduled hit and succeeds on the retry. Exhausted attempts
    re-raise the last error — the caller's degraded path (or the user)
    takes over.
    """
    import time as _time
    last = None
    for attempt in range(retries + 1):
        try:
            fault_point(site)
            return fn()
        except Exception as e:            # noqa: BLE001 — chaos seam
            last = e
            if attempt >= retries:
                raise
            note(f"retry.{site}", site=site, attempt=attempt + 1,
                 error=type(e).__name__)
            if backoff_s > 0.0:
                _time.sleep(backoff_s * (2 ** attempt))
    raise last  # pragma: no cover — unreachable


_install_from_env()
