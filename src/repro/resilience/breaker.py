"""Per-cell circuit breaker for the Pallas degradation ladder.

The backend's unsupported-cell fallback handles cells the kernels never
cover; the breaker handles cells that *should* work but keep failing at
dispatch (a flaky platform, an injected permanent fault). After
``failure_threshold`` consecutive failures on one (kernel, shape) cell
the breaker opens: the next ``cooldown`` calls for that cell skip the
kernel entirely (straight to the jnp fallback — no exception churn),
then one half-open probe retries the kernel. Probe success closes the
cell; another failure re-opens it for a fresh cooldown.

Cooldown is counted in *calls*, not seconds, so chaos runs are exactly
reproducible: the Nth call to a cell behaves the same on every machine
and every rerun.
"""

from __future__ import annotations

import threading

__all__ = ["CircuitBreaker"]

_CLOSED, _OPEN, _HALF_OPEN = "closed", "open", "half-open"


class CircuitBreaker:
    """Keyed consecutive-failure breaker with call-counted cooldown."""

    def __init__(self, failure_threshold: int = 3, cooldown: int = 8):
        if failure_threshold < 1 or cooldown < 1:
            raise ValueError("failure_threshold and cooldown must be >= 1")
        self.failure_threshold = failure_threshold
        self.cooldown = cooldown
        # cell -> [state, consecutive_failures, cooldown_remaining]
        self._cells: dict = {}
        self._lock = threading.Lock()
        self.opened = 0          # total open transitions (monotone)

    def allow(self, cell) -> bool:
        """May this call try the protected path? Open cells burn one
        cooldown tick per refusal; the tick that exhausts the cooldown
        flips the cell half-open and lets one probe through."""
        with self._lock:
            st = self._cells.get(cell)
            if st is None or st[0] == _CLOSED:
                return True
            if st[0] == _HALF_OPEN:
                return True
            st[2] -= 1
            if st[2] <= 0:
                st[0] = _HALF_OPEN
                return True
            return False

    def record_success(self, cell) -> None:
        with self._lock:
            self._cells.pop(cell, None)

    def record_failure(self, cell) -> bool:
        """Count one failure; returns True when this failure opened
        (or re-opened) the cell."""
        with self._lock:
            st = self._cells.setdefault(cell, [_CLOSED, 0, 0])
            if st[0] == _HALF_OPEN:          # failed probe: re-open
                st[0] = _OPEN
                st[2] = self.cooldown
                self.opened += 1
                return True
            st[1] += 1
            if st[1] >= self.failure_threshold:
                st[0] = _OPEN
                st[1] = 0
                st[2] = self.cooldown
                self.opened += 1
                return True
            return False

    def state(self, cell) -> str:
        with self._lock:
            st = self._cells.get(cell)
            return st[0] if st is not None else _CLOSED

    def open_cells(self) -> list:
        with self._lock:
            return [c for c, st in self._cells.items() if st[0] != _CLOSED]

    def stats(self) -> dict:
        with self._lock:
            open_now = sum(1 for st in self._cells.values()
                           if st[0] == _OPEN)
            half = sum(1 for st in self._cells.values()
                       if st[0] == _HALF_OPEN)
        return {"opened_total": self.opened, "open_now": open_now,
                "half_open_now": half}

    def reset(self) -> None:
        with self._lock:
            self._cells.clear()
