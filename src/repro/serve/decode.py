"""Serving: batched autoregressive decode over the transformer stack.

`ServeLoop` batches concurrent requests into one jitted decode step
(continuous batching at the step granularity: finished slots are refilled
between steps). The KV cache kind (bf16/int8) and its sharding (batch- vs
sequence-sharded — flash-decoding) come from the cell config.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import (TransformerConfig, decode_step,
                                  init_kv_cache)

__all__ = ["ServeConfig", "ServeLoop", "greedy_decode"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    batch: int
    cache_kind: str = "bf16"       # 'bf16' | 'int8' | 'f32'
    temperature: float = 0.0       # 0 = greedy


def greedy_decode(params, cfg: TransformerConfig, prompt: jax.Array,
                  num_steps: int, cache_kind: str = "bf16"):
    """Teacher-free rollout: feeds the prompt token by token, then samples
    greedily. prompt: [B, T0]. Returns tokens [B, T0 + num_steps]."""
    B, T0 = prompt.shape
    cache = init_kv_cache(cfg, B, T0 + num_steps, kind=cache_kind)
    toks = prompt

    step_fn = jax.jit(
        lambda p, t, c, i: decode_step(p, cfg, t, c, i))

    logits = None
    for t in range(T0):
        logits, cache = step_fn(params, toks[:, t:t + 1], cache,
                                jnp.int32(t))
    for s in range(num_steps):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        toks = jnp.concatenate([toks, nxt], axis=1)
        logits, cache = step_fn(params, nxt, cache,
                                jnp.int32(T0 + s))
    return toks


class ServeLoop:
    """Step-granular continuous batching over a fixed slot budget."""

    def __init__(self, params, cfg: TransformerConfig, scfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.cache = init_kv_cache(cfg, scfg.batch, scfg.max_len,
                                   kind=scfg.cache_kind)
        self.cur_tok = jnp.zeros((scfg.batch, 1), jnp.int32)
        self.lengths = np.zeros((scfg.batch,), np.int64)
        self.active = np.zeros((scfg.batch,), bool)
        self.outputs: dict[int, list[int]] = {}
        self.queue: list[tuple[int, list[int]]] = []
        self._next_rid = 0
        self._step = jax.jit(
            lambda p, t, c, i: decode_step(p, cfg, t, c, i))

    def submit(self, prompt_tokens: list[int]) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append((rid, prompt_tokens))
        self.outputs[rid] = []
        return rid

    def _admit(self):
        for slot in range(self.scfg.batch):
            if not self.active[slot] and self.queue:
                rid, prompt = self.queue.pop(0)
                self.active[slot] = True
                self.lengths[slot] = 0
                self._slot_rid = getattr(self, "_slot_rid", {})
                self._slot_rid[slot] = (rid, prompt)
                self.cur_tok = self.cur_tok.at[slot, 0].set(prompt[0])

    def step(self, max_new: int = 32):
        """One decode step for every active slot (single jitted call —
        the whole point of batched serving). Per-slot cache positions."""
        self._admit()
        if not self.active.any():
            return
        cur = jnp.asarray(self.lengths, jnp.int32)
        logits, self.cache = self._step(
            self.params, self.cur_tok, self.cache, cur)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for slot in range(self.scfg.batch):
            if not self.active[slot]:
                continue
            rid, prompt = self._slot_rid[slot]
            self.lengths[slot] += 1
            pos = int(self.lengths[slot])
            if pos < len(prompt):             # still prefilling
                tok = prompt[pos]
            else:
                tok = int(nxt[slot])
                self.outputs[rid].append(tok)
            self.cur_tok = self.cur_tok.at[slot, 0].set(tok)
            if (len(self.outputs[rid]) >= max_new
                    or self.lengths[slot] >= self.scfg.max_len - 1):
                self.active[slot] = False
