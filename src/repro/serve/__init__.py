from .decode import ServeConfig, ServeLoop, greedy_decode

__all__ = ["ServeConfig", "ServeLoop", "greedy_decode"]
