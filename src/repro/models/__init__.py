from .common import (rms_norm, layer_norm, softcap, mlp_init, mlp_apply,
                     dense_init, dense_apply, embed_init, param_count,
                     tree_size_bytes)
from .attention import AttnConfig, attn_init, attn_apply, rope
from .transformer import (TransformerConfig, init_params, forward, lm_loss,
                          decode_step, init_kv_cache)
from .moe import MoEConfig, moe_init, moe_apply
from .gnn import (GNNConfig, egnn_init, egnn_apply, gin_init, gin_apply,
                  sage_init, sage_apply, sage_apply_blocks,
                  graphcast_init, graphcast_apply)
from .recsys import (XDeepFMConfig, xdeepfm_init, xdeepfm_apply, cin_apply,
                     retrieval_score)

__all__ = [
    "rms_norm", "layer_norm", "softcap", "mlp_init", "mlp_apply",
    "dense_init", "dense_apply", "embed_init", "param_count",
    "tree_size_bytes",
    "AttnConfig", "attn_init", "attn_apply", "rope",
    "TransformerConfig", "init_params", "forward", "lm_loss",
    "decode_step", "init_kv_cache",
    "MoEConfig", "moe_init", "moe_apply",
    "GNNConfig", "egnn_init", "egnn_apply", "gin_init", "gin_apply",
    "sage_init", "sage_apply", "sage_apply_blocks",
    "graphcast_init", "graphcast_apply",
    "XDeepFMConfig", "xdeepfm_init", "xdeepfm_apply", "cin_apply",
    "retrieval_score",
]
