"""xDeepFM (CIN + DNN + linear) over PA-shardable embedding tables.

The embedding lookup is the paper's **pull** (gather + private combine,
`sparse.embedding_bag`); its VJP is the **push** (combining scatter-add
into shared tables). Tables stack as one [F, V, D] tensor so a single
sharding rule model-parallelizes all fields (vocab axis), and the PA
strategy applies: ids resolving to the local vocab shard avoid the
cross-shard gather.

CIN (Compressed Interaction Network), xDeepFM eq. (6):
    X^k[b,h,d] = sum_{i,j} W^k[h,i,j] * X^{k-1}[b,i,d] * X^0[b,j,d]
with per-layer output pooled over d — outer product + contraction, the
compute hot spot the `cin` Pallas kernel tiles.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..sparse.embedding import embedding_bag
from .common import mlp_apply, mlp_init, dense_init, dense_apply

__all__ = ["XDeepFMConfig", "xdeepfm_init", "xdeepfm_apply", "cin_apply",
           "retrieval_score"]


@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    n_fields: int = 39
    vocab_per_field: int = 100_000
    embed_dim: int = 10
    cin_layers: tuple[int, ...] = (200, 200, 200)
    mlp_dims: tuple[int, ...] = (400, 400)
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def xdeepfm_init(key, cfg: XDeepFMConfig):
    dt = cfg.jdtype
    k_emb, k_lin, k_cin, k_mlp, k_out = jax.random.split(key, 5)
    F, V, D = cfg.n_fields, cfg.vocab_per_field, cfg.embed_dim
    params = {
        # stacked tables: one sharding rule covers every field
        "tables": (jax.random.normal(k_emb, (F, V, D), jnp.float32)
                   * 0.01).astype(dt),
        "linear": (jax.random.normal(k_lin, (F, V), jnp.float32)
                   * 0.01).astype(dt),
    }
    cin = []
    h_prev = F
    for i, h in enumerate(cfg.cin_layers):
        k_cin, sub = jax.random.split(k_cin)
        w = (jax.random.normal(sub, (h, h_prev, F), jnp.float32)
             * (2.0 / (h_prev * F)) ** 0.5).astype(dt)
        cin.append(w)
        h_prev = h
    params["cin"] = cin
    params["cin_out"] = dense_init(k_out, sum(cfg.cin_layers), 1, dt)
    params["mlp"] = mlp_init(k_mlp, [F * D, *cfg.mlp_dims], dt)
    k_out2 = jax.random.fold_in(k_out, 1)
    params["mlp_out"] = dense_init(k_out2, cfg.mlp_dims[-1], 1, dt)
    return params


def cin_apply(cin_weights, x0: jax.Array) -> jax.Array:
    """x0: [B, F, D] -> pooled CIN features [B, sum(H_k)]."""
    xs = []
    xk = x0
    for w in cin_weights:
        # z[b,i,j,d] = xk[b,i,d] * x0[b,j,d] ; X^k[b,h,d] = W[h,i,j] z
        z = jnp.einsum("bid,bjd->bijd", xk, x0)
        xk = jnp.einsum("hij,bijd->bhd", w, z)
        xs.append(xk.sum(axis=-1))          # pool over D
    return jnp.concatenate(xs, axis=-1)


def xdeepfm_apply(params, cfg: XDeepFMConfig, ids: jax.Array) -> jax.Array:
    """ids: int32 [B, F] one id per field -> logits [B].

    (Multi-hot bags route through sparse.embedding_bag; the assigned
    Criteo-style shapes are single-valued per field.)
    """
    B, F = ids.shape
    D = cfg.embed_dim
    # pull: gather one row per (b, f) from the field's table
    emb = jax.vmap(lambda tab, idx: jnp.take(tab, idx, axis=0),
                   in_axes=(0, 1))(params["tables"], ids)      # [F, B, D]
    x0 = emb.transpose(1, 0, 2)                                # [B, F, D]

    lin = jax.vmap(lambda tab, idx: jnp.take(tab, idx, axis=0),
                   in_axes=(0, 1))(params["linear"], ids)      # [F, B]
    linear_term = lin.sum(axis=0)                              # [B]

    cin_feat = cin_apply(params["cin"], x0)                    # [B, sumH]
    cin_term = dense_apply(params["cin_out"], cin_feat)[:, 0]

    mlp_feat = mlp_apply(params["mlp"], x0.reshape(B, F * D),
                         act=jax.nn.relu, final_act=True)
    mlp_term = dense_apply(params["mlp_out"], mlp_feat)[:, 0]
    return (linear_term + cin_term + mlp_term).astype(jnp.float32)


def retrieval_score(params, cfg: XDeepFMConfig, user_ids: jax.Array,
                    cand_ids: jax.Array) -> jax.Array:
    """retrieval_cand shape: one query row [1, F_user] against N candidate
    id rows [N, F_cand] — batched dot of pooled tower embeddings, NOT a
    python loop. Towers reuse the shared tables: user fields are the first
    F//2, candidate fields the rest."""
    Fu = user_ids.shape[-1]
    u_emb = jax.vmap(lambda tab, idx: jnp.take(tab, idx, axis=0),
                     in_axes=(0, 1))(params["tables"][:Fu], user_ids)
    u = u_emb.mean(axis=0)                                     # [1, D]
    Fc = cand_ids.shape[-1]
    c_emb = jax.vmap(lambda tab, idx: jnp.take(tab, idx, axis=0),
                     in_axes=(0, 1))(params["tables"][Fu:Fu + Fc], cand_ids)
    c = c_emb.mean(axis=0)                                     # [N, D]
    return (c @ u[0]).astype(jnp.float32)                      # [N]
