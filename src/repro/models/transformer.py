"""Decoder-only transformer LM covering the five assigned LM archs.

Design points
-------------
* Layers are **stacked on a leading axis** and executed with
  ``jax.lax.scan`` + ``jax.checkpoint`` (remat): the compiled HLO stays
  compact (essential for 512-device dry-runs of 64-layer models) and
  activation memory is one layer deep.
* Per-layer heterogeneity (gemma2's local/global alternation) is data-
  driven: a traced int32[L] ``window_arr`` feeds the mask, so one scanned
  body serves all layers.
* MoE layers (moonshot / deepseek) plug in via models.moe with push or
  pull dispatch.
* The LM loss is **vocab-parallel + sequence-chunked**: logits are never
  materialized at [B, T, V]; chunks of tokens are projected, logsumexp'd
  and reduced on the fly (Megatron-style), which is what makes the
  train_4k cells of 32B-class models fit.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..dist.sharding import BATCH, hint
from .attention import (AttnConfig, attn_apply, attn_init, decode_attn_apply)
from .common import dense_init, dense_apply, embed_init, rms_norm, silu, softcap
from .moe import MoEConfig, moe_apply, moe_apply_ep, moe_init

__all__ = ["TransformerConfig", "init_params", "forward", "lm_loss",
           "decode_step", "init_kv_cache"]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_theta: float = 500000.0
    qkv_bias: bool = False
    # gemma2: every other layer local with this window; None = all global
    local_window: Optional[int] = None
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    embed_scale: bool = False          # gemma multiplies embed by sqrt(D)
    moe: Optional[MoEConfig] = None
    dtype: str = "bfloat16"
    loss_chunk: int = 512
    remat: bool = True
    attn_impl: str = "blockwise"       # 'naive' | 'blockwise'
    q_chunk: int = 512
    kv_chunk: int = 1024

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_cfg(self, window: Optional[int] = None) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd,
            rope_theta=self.rope_theta, qkv_bias=self.qkv_bias,
            window=window, logit_softcap=self.attn_softcap)

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def window_array(self, seq_len: int) -> jax.Array:
        """int32[L]: per-layer window (big value = global)."""
        big = jnp.int32(1 << 30)
        if self.local_window is None:
            return jnp.full((self.n_layers,), big, jnp.int32)
        alt = jnp.arange(self.n_layers, dtype=jnp.int32) % 2 == 0
        return jnp.where(alt, jnp.int32(self.local_window), big)


def _ffn_init(key, cfg: TransformerConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, cfg.d_model, cfg.d_ff, dtype),
        "wg": dense_init(k2, cfg.d_model, cfg.d_ff, dtype),
        "wo": dense_init(k3, cfg.d_ff, cfg.d_model, dtype),
    }


def _layer_init(key, cfg: TransformerConfig, dtype):
    ka, kf = jax.random.split(key)
    p = {
        "attn": attn_init(ka, cfg.attn_cfg(), dtype),
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(kf, cfg.moe, dtype)
    else:
        p["ffn"] = _ffn_init(kf, cfg, dtype)
    return p


def init_params(key, cfg: TransformerConfig):
    dtype = cfg.jdtype
    ke, kl, ku = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(layer_keys)
    return {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dtype),
        "layers": layers,
        "final_ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "unembed": dense_init(ku, cfg.d_model, cfg.vocab, dtype),
    }


def _layer_apply(cfg: TransformerConfig, lp, x, window, positions,
                 return_kv: bool = False):
    acfg = cfg.attn_cfg()
    h = rms_norm(x, lp["ln1"])
    # window as traced scalar: rebuild cfg-independent mask inside attn by
    # passing window via the AttnConfig is static — instead inject through
    # the mask path: attn_apply uses cfg.window (static). We reproduce its
    # body here with a dynamic mask to keep one scanned layer body.
    B, T, D = h.shape
    q = dense_apply(lp["attn"]["wq"], h).reshape(B, T, acfg.n_heads, acfg.head_dim)
    k = dense_apply(lp["attn"]["wk"], h).reshape(B, T, acfg.n_kv_heads, acfg.head_dim)
    v = dense_apply(lp["attn"]["wv"], h).reshape(B, T, acfg.n_kv_heads, acfg.head_dim)
    # Megatron activation shardings: heads over 'model' when divisible
    q = hint(q, BATCH, None, "model", None)
    k = hint(k, BATCH, None, "model", None)
    v = hint(v, BATCH, None, "model", None)
    from .attention import rope, _sdpa, blockwise_sdpa  # reuse internals
    q = rope(q, positions, acfg.rope_theta)
    k = rope(k, positions, acfg.rope_theta)
    if cfg.attn_impl == "blockwise":
        attn_out = blockwise_sdpa(q, k, v, acfg, window,
                                  cfg.q_chunk, cfg.kv_chunk)
    else:
        q_pos = jnp.arange(T, dtype=jnp.int32)[:, None]
        k_pos = jnp.arange(T, dtype=jnp.int32)[None, :]
        mask = (k_pos <= q_pos) & (k_pos > q_pos - window)
        attn_out = _sdpa(q, k, v, mask, acfg)
    attn_out = hint(attn_out, BATCH, None, "model", None)
    x = x + dense_apply(lp["attn"]["wo"], attn_out.reshape(B, T, -1))
    x = hint(x, BATCH, None, None)

    h = rms_norm(x, lp["ln2"])
    if cfg.moe is not None:
        ff = moe_apply_ep(lp["moe"], cfg.moe, h)
    else:
        mid = (silu(dense_apply({"w": lp["ffn"]["wg"]["w"]}, h))
               * dense_apply({"w": lp["ffn"]["wi"]["w"]}, h))
        mid = hint(mid, BATCH, None, "model")
        ff = dense_apply({"w": lp["ffn"]["wo"]["w"]}, mid)
    out = hint(x + ff, BATCH, None, None)
    if return_kv:
        return out, (k, v)
    return out


def forward(params, cfg: TransformerConfig, tokens: jax.Array) -> jax.Array:
    """tokens [B, T] -> final hidden states [B, T, D]."""
    B, T = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = hint(x, BATCH, None, None)
    positions = jnp.arange(T, dtype=jnp.int32)[None, :]
    windows = cfg.window_array(T)

    def body(carry, layer_in):
        lp, window = layer_in
        fn = lambda c: _layer_apply(cfg, lp, c, window, positions)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        return fn(carry), None

    x, _ = jax.lax.scan(body, x, (params["layers"], windows))
    return hint(rms_norm(x, params["final_ln"]), BATCH, None, None)


def lm_loss(params, cfg: TransformerConfig, tokens: jax.Array,
            labels: jax.Array) -> jax.Array:
    """Vocab-parallel, sequence-chunked cross entropy (mean over tokens)."""
    B, T = tokens.shape
    x = forward(params, cfg, tokens)               # [B, T, D]
    D = x.shape[-1]
    chunk = min(cfg.loss_chunk, T)
    n_chunks = -(-T // chunk)
    pad = n_chunks * chunk - T
    xf = jnp.pad(x, ((0, 0), (0, pad), (0, 0))).reshape(B, n_chunks, chunk, D)
    lf = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    lf = lf.reshape(B, n_chunks, chunk)
    w = params["unembed"]["w"]

    def chunk_body(carry, inp):
        tot, cnt = carry
        xc, lc = inp                              # [B, chunk, D], [B, chunk]
        # pin operands: batch-sharded activations x replicated-D times
        # vocab-sharded unembed => logits vocab-sharded with NO partial-sum
        # all-reduce (GSPMD otherwise shards the contraction dim)
        xc = hint(xc, BATCH, None, None)
        logits = (xc.astype(jnp.float32)
                  @ hint(w, None, "model").astype(jnp.float32))
        logits = hint(logits, BATCH, None, "model")   # vocab-parallel
        if cfg.final_softcap is not None:
            logits = softcap(logits, cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # vocab-parallel pick: iota-mask + reduce stays sharded (a gather
        # over the vocab-sharded axis would all-gather full logits)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        picked = jnp.sum(
            jnp.where(iota == lc[..., None].astype(jnp.int32), logits, 0.0),
            axis=-1)
        valid = lc >= 0
        tot = tot + jnp.where(valid, lse - picked, 0.0).sum()
        cnt = cnt + jnp.sum(valid, dtype=jnp.int32)  # x64-stable carry
        return (tot, cnt), None

    (total, count), _ = jax.lax.scan(
        chunk_body, (jnp.float32(0.0), jnp.int32(0)),
        (xf.transpose(1, 0, 2, 3), lf.transpose(1, 0, 2)))
    return total / jnp.maximum(count, 1).astype(jnp.float32)


def prefill(params, cfg: TransformerConfig, tokens: jax.Array,
            cache_kind: str = "bf16"):
    """Process a full prompt: returns (last-position logits [B, V], cache)
    laid out exactly as init_kv_cache/decode_step expect — gemma2 local
    layers keep only the last-window ring, optional int8 quantization."""
    B, T = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.arange(T, dtype=jnp.int32)[None, :]
    windows = cfg.window_array(T)

    def body(carry, layer_in):
        lp, window = layer_in
        fn = lambda c: _layer_apply(cfg, lp, c, window, positions,
                                    return_kv=True)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x_new, (k, v) = fn(carry)
        return x_new, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows))
    x = rms_norm(x, params["final_ln"])
    logits = (x[:, -1].astype(jnp.float32)
              @ params["unembed"]["w"].astype(jnp.float32))
    if cfg.final_softcap is not None:
        logits = softcap(logits, cfg.final_softcap)

    def package(k_all, v_all):
        # k_all/v_all: [L', B, T, Hk, Dh] -> cache buffers of length S
        if cache_kind == "int8":
            kq, ksc = quantize_kv_tree(k_all)
            vq, vsc = quantize_kv_tree(v_all)
            return {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc}
        dt = {"bf16": jnp.bfloat16, "f32": jnp.float32}[cache_kind]
        return {"k": k_all.astype(dt), "v": v_all.astype(dt)}

    if cfg.local_window is None:
        return logits, package(ks, vs)

    W = min(cfg.local_window, T)
    # ring layout: decode writes token t at slot t % W, so slot s of the
    # surviving last-W window holds token (T - W) + ((s - (T-W)) % W)
    slots = jnp.arange(W, dtype=jnp.int32)
    t_of_slot = (T - W) + jnp.mod(slots - ((T - W) % W), W)
    k_loc = ks[0::2][:, :, t_of_slot]
    v_loc = vs[0::2][:, :, t_of_slot]
    return logits, {"local": package(k_loc, v_loc),
                    "global": package(ks[1::2], vs[1::2])}


def quantize_kv_tree(x):
    """int8 symmetric per-(..., Dh) quantization for stacked caches."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _cache_buf(L, batch, S, Hk, Dh, kind):
    shape = (L, batch, S, Hk, Dh)
    if kind == "int8":
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros((L, batch, S, Hk, 1), jnp.float32),
                "v_scale": jnp.zeros((L, batch, S, Hk, 1), jnp.float32)}
    dt = {"bf16": jnp.bfloat16, "f32": jnp.float32}[kind]
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def init_kv_cache(cfg: TransformerConfig, batch: int, max_len: int,
                  kind: str = "bf16"):
    """Stacked per-layer KV caches.

    Uniform archs: {'k','v',...} with shape [L, B, S, Hk, Dh].
    local/global alternation (gemma2): {'local': ..., 'global': ...} where
    local layers (even idx) keep only a window-sized ring — at long
    contexts half the cache shrinks from S to W (major memory win).
    """
    L, Hk, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    if cfg.local_window is None:
        return _cache_buf(L, batch, max_len, Hk, Dh, kind)
    assert L % 2 == 0, "local/global alternation expects even layer count"
    W = min(cfg.local_window, max_len)
    return {"local": _cache_buf(L // 2, batch, W, Hk, Dh, kind),
            "global": _cache_buf(L // 2, batch, max_len, Hk, Dh, kind)}


def _decode_layer(cfg: TransformerConfig, lp, x, layer_cache, cur_len,
                  window):
    acfg = cfg.attn_cfg(window)
    h = rms_norm(x, lp["ln1"])
    out, new_cache = decode_attn_apply(lp["attn"], acfg, h, layer_cache,
                                       cur_len)
    x = x + out
    h2 = rms_norm(x, lp["ln2"])
    if cfg.moe is not None:
        ff = moe_apply_ep(lp["moe"], cfg.moe, h2)
    else:
        ff = dense_apply({"w": lp["ffn"]["wo"]["w"]},
                         silu(dense_apply({"w": lp["ffn"]["wg"]["w"]}, h2))
                         * dense_apply({"w": lp["ffn"]["wi"]["w"]}, h2))
    return x + ff, new_cache


def decode_step(params, cfg: TransformerConfig, tokens: jax.Array,
                cache: dict, cur_len: jax.Array):
    """One decode step. tokens [B, 1] -> (logits [B, V], new cache)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    if cfg.local_window is None:
        def body(carry, layer_in):
            lp, layer_cache = layer_in
            return _decode_layer(cfg, lp, carry, layer_cache, cur_len, None)

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        # gemma2: scan over (local, global) layer pairs with split caches
        loc = jax.tree.map(lambda a: a[0::2], params["layers"])
        glo = jax.tree.map(lambda a: a[1::2], params["layers"])

        def body(carry, layer_in):
            lp_l, lp_g, c_l, c_g = layer_in
            h, c_l2 = _decode_layer(cfg, lp_l, carry, c_l, cur_len,
                                    cfg.local_window)
            h, c_g2 = _decode_layer(cfg, lp_g, h, c_g, cur_len, None)
            return h, (c_l2, c_g2)

        x, (cl, cg) = jax.lax.scan(
            body, x, (loc, glo, cache["local"], cache["global"]))
        new_cache = {"local": cl, "global": cg}

    x = rms_norm(x, params["final_ln"])
    logits = (x[:, 0].astype(jnp.float32)
              @ params["unembed"]["w"].astype(jnp.float32))
    if cfg.final_softcap is not None:
        logits = softcap(logits, cfg.final_softcap)
    return logits, new_cache
