"""GNN architectures: EGNN, GIN, GraphSAGE (full + sampled), GraphCast-EPD.

Message passing is built on the paper's machinery: with every vertex
active, a layer is one k-relaxation — **pull** reduces the pull-major
(CSR) edge order per destination, **push** scatter-combines the push-major
(CSC) order. Identical math, different access structure; `direction`
selects it per layer and the Cost/roofline machinery sees the difference.

Edge messages that need BOTH endpoints (EGNN, GraphCast) are computed
edge-parallel (gather src + gather dst -> edge MLP -> segment reduce);
`direction` then picks which sorted edge order the reduction runs over —
exactly the CSR/CSC dichotomy of §7.1 applied to an edge-featured MPNN.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..graphs.structure import Graph
from ..graphs.sampling import SampledBlocks
from ..sparse.segment import segment_mean, segment_sum
from .common import mlp_apply, mlp_init, layer_norm

__all__ = ["GNNConfig",
           "egnn_init", "egnn_apply", "gin_init", "gin_apply",
           "gin_apply_mp",
           "sage_init", "sage_apply", "sage_apply_blocks",
           "graphcast_init", "graphcast_apply"]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    arch: str
    n_layers: int
    d_hidden: int
    d_in: int
    d_out: int
    direction: str = "pull"          # 'pull' | 'push' edge-reduce order
    aggregator: str = "sum"          # gin: sum; sage: mean
    gin_eps_learnable: bool = True
    n_vars: int = 227                # graphcast
    fanouts: tuple[int, ...] = (25, 10)   # sage sampling
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def _edge_order(g: Graph, direction: str):
    """(src, dst) edge ids in the direction's memory order."""
    if direction == "push":
        return g.push_src, g.push_dst
    return g.coo_src, g.coo_dst


def _reduce(vals, dst, n, how="sum"):
    return (segment_sum(vals, dst, n) if how == "sum"
            else segment_mean(vals, dst, n))


# ---------------------------------------------------------------- EGNN --
def egnn_init(key, cfg: GNNConfig):
    dt = cfg.jdtype
    keys = jax.random.split(key, cfg.n_layers * 3 + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        ke, kx, kh = keys[3 * i: 3 * i + 3]
        layers.append({
            # phi_e(h_i, h_j, ||xi-xj||^2) -> message
            "phi_e": mlp_init(ke, [2 * d + 1, d, d], dt),
            # phi_x: message -> scalar coordinate gate
            "phi_x": mlp_init(kx, [d, d, 1], dt),
            # phi_h(h_i, agg) -> h update
            "phi_h": mlp_init(kh, [2 * d, d, d], dt),
        })
    return {"encode": mlp_init(keys[-2], [cfg.d_in, d], dt),
            "decode": mlp_init(keys[-1], [d, cfg.d_out], dt),
            "layers": layers}


def egnn_apply(params, cfg: GNNConfig, g: Graph, h: jax.Array,
               x: jax.Array):
    """h: [n, d_in] node features; x: [n, 3] coordinates (E(n) equivariant
    coordinate updates). Returns (node_out [n, d_out], x')."""
    n = g.n
    src, dst = _edge_order(g, cfg.direction)
    h = mlp_apply(params["encode"], h, act=jax.nn.silu, final_act=True)
    for lp in params["layers"]:
        hs = jnp.take(h, src, axis=0)
        hd = jnp.take(h, dst, axis=0)
        xs = jnp.take(x, src, axis=0)
        xd = jnp.take(x, dst, axis=0)
        diff = xd - xs
        r2 = jnp.sum(diff * diff, axis=-1, keepdims=True).astype(h.dtype)
        m = mlp_apply(lp["phi_e"], jnp.concatenate([hd, hs, r2], -1),
                      act=jax.nn.silu, final_act=True)
        gate = mlp_apply(lp["phi_x"], m, act=jax.nn.silu)      # [m, 1]
        # coordinate update: mean over neighbors keeps scale stable
        x = x + _reduce((diff.astype(h.dtype) * gate), dst, n, "mean"
                        ).astype(x.dtype)
        agg = _reduce(m, dst, n, "sum")
        h = h + mlp_apply(lp["phi_h"], jnp.concatenate([h, agg], -1),
                          act=jax.nn.silu)
    return mlp_apply(params["decode"], h), x


# ----------------------------------------------------------------- GIN --
def gin_init(key, cfg: GNNConfig):
    dt = cfg.jdtype
    keys = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_in if i == 0 else d
        layers.append({
            "mlp": mlp_init(keys[i], [d_in, d, d], dt),
            "eps": jnp.zeros((), jnp.float32),
        })
    return {"layers": layers,
            "readout": mlp_init(keys[-1], [d, cfg.d_out], dt)}


def gin_apply(params, cfg: GNNConfig, g: Graph, h: jax.Array,
              graph_ids: Optional[jax.Array] = None,
              num_graphs: int = 1):
    """Sum-aggregating GIN; graph_ids enables batched-small-graph readout
    (the `molecule` shape)."""
    src, dst = _edge_order(g, cfg.direction)
    h = h.astype(cfg.jdtype)     # bf16 config halves exchange payloads
    for lp in params["layers"]:
        msgs = jnp.take(h, src, axis=0)
        agg = segment_sum(msgs, dst, g.n)
        eps = lp["eps"] if cfg.gin_eps_learnable else 0.0
        scale = jnp.asarray(1.0 + eps, h.dtype)  # keep bf16 payloads bf16
        h = mlp_apply(lp["mlp"], scale * h + agg,
                      act=jax.nn.relu, final_act=True)
    if graph_ids is not None:
        pooled = segment_sum(h, graph_ids, num_graphs)
    else:
        pooled = h
    return mlp_apply(params["readout"], pooled)


def gin_apply_mp(params, cfg: GNNConfig, h: jax.Array, e_src: jax.Array,
                 e_dst: jax.Array, mesh) -> jax.Array:
    """GIN with the paper's explicit pull exchange (DESIGN.md §6):
    edges arrive grouped by destination OWNER ([P, cap], sentinel-padded —
    the PA layout from graphs.partition), so each layer is exactly

        all_gather(h)  +  owner-local gather/segment-combine

    — one collective per layer instead of GSPMD's gather-all_gather PLUS
    scatter-all_reduce. h: [n, d] row-sharded over every mesh axis.
    """
    import functools
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)
    n = h.shape[0]
    nparts = 1
    for a in axes:
        nparts *= mesh.shape[a]
    shard = n // nparts
    h = h.astype(cfg.jdtype)

    def _flat_idx():
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a).astype(
                jnp.int32)
        return idx

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None), P(axes, None)),
        out_specs=P(axes, None), check_vma=False)
    def exchange(hb, sb, db):
        full = jax.lax.all_gather(hb, axes, tiled=True)     # [n, d]
        src = sb.reshape(-1)
        dst = db.reshape(-1)
        ok = (src < n) & (dst < n)
        msg = jnp.where(ok[:, None],
                        full[jnp.clip(src, 0, n - 1)], 0)
        base = _flat_idx() * shard
        ldst = jnp.where(ok, jnp.clip(dst - base, 0, shard - 1), shard - 1)
        guard = jnp.where(ok[:, None], msg, 0)
        return segment_sum(guard, ldst, shard)

    for lp in params["layers"]:
        agg = exchange(h, e_src, e_dst)
        eps = lp["eps"] if cfg.gin_eps_learnable else 0.0
        scale = jnp.asarray(1.0 + eps, h.dtype)
        h = mlp_apply(lp["mlp"], scale * h + agg,
                      act=jax.nn.relu, final_act=True)
    return mlp_apply(params["readout"], h)


# ----------------------------------------------------------- GraphSAGE --
def sage_init(key, cfg: GNNConfig):
    dt = cfg.jdtype
    keys = jax.random.split(key, 2 * cfg.n_layers)
    layers = []
    for i in range(cfg.n_layers):
        d_in = cfg.d_in if i == 0 else cfg.d_hidden
        d_out = cfg.d_out if i == cfg.n_layers - 1 else cfg.d_hidden
        layers.append({
            "w_self": mlp_init(keys[2 * i], [d_in, d_out], dt),
            "w_neigh": mlp_init(keys[2 * i + 1], [d_in, d_out], dt),
        })
    return {"layers": layers}


def sage_apply(params, cfg: GNNConfig, g: Graph, h: jax.Array):
    """Full-graph GraphSAGE-mean."""
    src, dst = _edge_order(g, cfg.direction)
    h = h.astype(cfg.jdtype)
    for i, lp in enumerate(params["layers"]):
        msgs = jnp.take(h, src, axis=0)
        agg = segment_mean(msgs, dst, g.n)
        h_new = (mlp_apply(lp["w_self"], h)
                 + mlp_apply(lp["w_neigh"], agg))
        h = jax.nn.relu(h_new) if i < len(params["layers"]) - 1 else h_new
    return h


def sage_apply_blocks(params, cfg: GNNConfig, blocks: SampledBlocks,
                      feats: jax.Array):
    """Sampled minibatch GraphSAGE (the paper's Frontier-Exploit applied to
    training): hop-k node features [n_k, d]; aggregate children -> parents
    layer by layer. `feats` holds features for the deepest hop ordering
    concatenated per hop (list aligned with blocks.node_ids)."""
    L = len(params["layers"])
    assert blocks.num_hops == L
    # feats: tuple of per-hop features, index 0 = seeds .. L = deepest hop
    h_per_hop = list(feats)
    for i, lp in enumerate(params["layers"]):
        # layer i refreshes hops 0 .. L-i-1 from their children; deeper
        # hops are no longer needed afterwards (standard minibatch SAGE)
        new_h = []
        for k in range(L - i):
            child_h = h_per_hop[k + 1]
            parent_h = h_per_hop[k]
            fanout = blocks.fanouts[k]
            n_parent = parent_h.shape[0]
            child_ok = blocks.valid[k + 1].reshape(n_parent, fanout)
            ch = child_h.reshape(n_parent, fanout, -1)
            denom = jnp.maximum(child_ok.sum(-1, keepdims=True), 1)
            agg = (ch * child_ok[..., None]).sum(1) / denom
            h_new = (mlp_apply(lp["w_self"], parent_h)
                     + mlp_apply(lp["w_neigh"], agg))
            new_h.append(jax.nn.relu(h_new) if i < L - 1 else h_new)
        h_per_hop = new_h
    return h_per_hop[0]


# ------------------------------------------------------------ GraphCast --
def graphcast_init(key, cfg: GNNConfig):
    """Encoder-processor-decoder deep MPNN (GraphCast-style, adapted: the
    provided graph plays the multi-mesh role; see DESIGN.md §10)."""
    dt = cfg.jdtype
    d = cfg.d_hidden
    keys = jax.random.split(key, 2 * cfg.n_layers + 4)
    proc = []
    for i in range(cfg.n_layers):
        proc.append({
            "edge_mlp": mlp_init(keys[2 * i], [3 * d, d, d], dt),
            "node_mlp": mlp_init(keys[2 * i + 1], [2 * d, d, d], dt),
            "ln_e": (jnp.ones((d,), jnp.float32), jnp.zeros((d,), jnp.float32)),
            "ln_n": (jnp.ones((d,), jnp.float32), jnp.zeros((d,), jnp.float32)),
        })
    return {
        "node_enc": mlp_init(keys[-4], [cfg.n_vars, d, d], dt),
        "edge_enc": mlp_init(keys[-3], [1, d, d], dt),
        "proc": proc,
        "node_dec": mlp_init(keys[-2], [d, d, cfg.n_vars], dt),
    }


def graphcast_apply(params, cfg: GNNConfig, g: Graph, node_vars: jax.Array):
    """node_vars: [n, n_vars] -> next-step prediction [n, n_vars]."""
    src, dst = _edge_order(g, cfg.direction)
    dt = cfg.jdtype
    h = mlp_apply(params["node_enc"], node_vars.astype(dt),
                  act=jax.nn.silu, final_act=True)
    if cfg.direction == "push":
        w = g.push_w
    else:
        w = g.coo_w
    e = mlp_apply(params["edge_enc"], w[:, None].astype(dt),
                  act=jax.nn.silu, final_act=True)
    for lp in params["proc"]:
        hs = jnp.take(h, src, axis=0)
        hd = jnp.take(h, dst, axis=0)
        e_in = jnp.concatenate([e, hs, hd], axis=-1)
        e_upd = mlp_apply(lp["edge_mlp"], e_in, act=jax.nn.silu)
        e = layer_norm(e + e_upd, *lp["ln_e"])
        agg = segment_sum(e, dst, g.n)
        n_upd = mlp_apply(lp["node_mlp"], jnp.concatenate([h, agg], -1),
                          act=jax.nn.silu)
        h = layer_norm(h + n_upd, *lp["ln_n"])
    return node_vars + mlp_apply(params["node_dec"], h,
                                 act=jax.nn.silu).astype(node_vars.dtype)
