"""Shared model building blocks (pure JAX, explicit dtypes everywhere).

Parameters are plain pytrees (nested dicts of jnp arrays). Each module is
(init_fn, apply_fn) style without a framework dependency, so sharding rules
can address parameters by path.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["Dense", "rms_norm", "layer_norm", "gelu", "silu",
           "dense_init", "dense_apply", "embed_init", "mlp_init",
           "mlp_apply", "softcap", "param_count", "tree_size_bytes"]

Params = Any


def _he(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = jnp.sqrt(jnp.asarray(2.0 / max(1, fan_in), jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               bias: bool = False) -> Params:
    p = {"w": _he(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


class Dense:
    """Tiny functional linear layer namespace."""
    init = staticmethod(dense_init)
    apply = staticmethod(dense_apply)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def mlp_init(key, dims: list[int], dtype=jnp.float32, bias: bool = True
             ) -> list[Params]:
    keys = jax.random.split(key, len(dims) - 1)
    return [dense_init(k, dims[i], dims[i + 1], dtype, bias=bias)
            for i, k in enumerate(keys)]


def mlp_apply(layers: list[Params], x: jax.Array,
              act: Callable = jax.nn.relu, final_act: bool = False
              ) -> jax.Array:
    for i, p in enumerate(layers):
        x = dense_apply(p, x)
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out32 = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out32.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    out32 = ((x32 - mu) * jax.lax.rsqrt(var + eps)
             * scale.astype(jnp.float32) + bias.astype(jnp.float32))
    return out32.astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


gelu = jax.nn.gelu
silu = jax.nn.silu


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def tree_size_bytes(params: Params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
