"""Attention: GQA + RoPE, sliding-window/global alternation, logit
soft-capping (gemma2), QKV bias (qwen), and decode paths over KV caches
(bf16 / int8-quantized / sliding-window ring).

Shapes: activations [B, T, D]; heads split as [B, T, H, Dh]. All einsums
keep the head axis explicit so TP sharding rules can target it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .common import dense_init, dense_apply, softcap

__all__ = ["AttnConfig", "attn_init", "attn_apply", "rope",
           "decode_attn_apply", "KVCacheSpec"]


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 500000.0
    qkv_bias: bool = False            # qwen1.5
    window: Optional[int] = None      # sliding window (gemma2 local layers)
    logit_softcap: Optional[float] = None  # gemma2
    query_scale: Optional[float] = None


def attn_init(key, cfg: AttnConfig, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, cfg.d_model, cfg.n_heads * cfg.head_dim,
                         dtype, bias=cfg.qkv_bias),
        "wk": dense_init(kk, cfg.d_model, cfg.n_kv_heads * cfg.head_dim,
                         dtype, bias=cfg.qkv_bias),
        "wv": dense_init(kv, cfg.d_model, cfg.n_kv_heads * cfg.head_dim,
                         dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.n_heads * cfg.head_dim, cfg.d_model, dtype),
    }


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: [..., T, H, Dh], positions: [..., T]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -jnp.log(jnp.asarray(theta, jnp.float32))
        * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    y1 = (x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin)
    y2 = (x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin)
    return jnp.concatenate([y1.astype(dt), y2.astype(dt)], axis=-1)


def _scores_mask(Tq: int, Tk: int, offset, window: Optional[int]):
    """Causal (+ optional sliding window) mask [Tq, Tk]; offset = absolute
    position of query 0 minus key 0."""
    q_pos = jnp.arange(Tq, dtype=jnp.int32)[:, None] + offset
    k_pos = jnp.arange(Tk, dtype=jnp.int32)[None, :]
    mask = k_pos <= q_pos
    if window is not None:
        mask &= k_pos > (q_pos - window)
    return mask


def _sdpa(q, k, v, mask, cfg: AttnConfig):
    """q:[B,Tq,H,Dh] k,v:[B,Tk,Hk,Dh] grouped-query attention core.
    mask: [Tq, Tk] (shared) or any shape broadcastable to
    [B, Hk, group, Tq, Tk] (per-row decode masks)."""
    B, Tq, H, Dh = q.shape
    Hk = k.shape[2]
    group = H // Hk
    scale = cfg.query_scale if cfg.query_scale is not None else Dh ** -0.5
    qg = q.reshape(B, Tq, Hk, group, Dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if cfg.logit_softcap is not None:
        logits = softcap(logits, cfg.logit_softcap)
    if mask.ndim == 2:
        mask = mask[None, None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Tq, H, Dh).astype(q.dtype)


def blockwise_sdpa(q, k, v, cfg: AttnConfig, window, q_chunk: int = 512,
                   kv_chunk: int = 1024):
    """Streaming (flash-style) attention in pure jnp: online softmax over
    KV chunks, scanned over Q chunks. Never materializes [T, T] scores —
    the prefill_32k/long-context cells depend on this. `window` is a
    traced int32 scalar (big value = global); causal.

    This is also the ref oracle shape for kernels/flash_attention.py.
    """
    B, T, H, Dh = q.shape
    Hk = k.shape[2]
    group = H // Hk
    scale = cfg.query_scale if cfg.query_scale is not None else Dh ** -0.5
    qc = min(q_chunk, T)
    kc = min(kv_chunk, T)
    nq, nk = -(-T // qc), -(-T // kc)
    Tq_pad, Tk_pad = nq * qc, nk * kc
    qp = jnp.pad(q, ((0, 0), (0, Tq_pad - T), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tk_pad - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tk_pad - T), (0, 0), (0, 0)))
    # keep q/k/v in their storage dtype: the MXU does bf16 x bf16 -> f32
    # natively (preferred_element_type), so f32 copies would only burn HBM
    qg = qp.reshape(B, nq, qc, Hk, group, Dh)
    kg = kp.reshape(B, nk, kc, Hk, Dh)
    vg = vp.reshape(B, nk, kc, Hk, Dh)

    def q_block(_, qi):
        qb = qg[:, qi]                               # [B, qc, Hk, g, Dh]
        q_pos = qi * qc + jnp.arange(qc, dtype=jnp.int32)

        def kv_block(carry, ki):
            m, l, o = carry
            kb = kg[:, ki]                           # [B, kc, Hk, Dh]
            vb = vg[:, ki]
            k_pos = ki * kc + jnp.arange(kc, dtype=jnp.int32)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if cfg.logit_softcap is not None:
                s = softcap(s, cfg.logit_softcap)
            mask = ((k_pos[None, :] <= q_pos[:, None])
                    & (k_pos[None, :] > q_pos[:, None] - window)
                    & (k_pos[None, :] < T))
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            o_new = (o * corr[..., None]
                     + jnp.einsum("bhgqk,bkhd->bhgqd",
                                  p.astype(vb.dtype), vb,
                                  preferred_element_type=jnp.float32))
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hk, group, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hk, group, qc), jnp.float32)
        o0 = jnp.zeros((B, Hk, group, qc, Dh), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_block, (m0, l0, o0),
                                    jnp.arange(nk))
        out = o / jnp.maximum(l, 1e-30)[..., None]   # [B,Hk,g,qc,Dh]
        return None, out.transpose(0, 3, 1, 2, 4)    # [B,qc,Hk,g,Dh]

    _, outs = jax.lax.scan(q_block, None, jnp.arange(nq))
    # outs: [nq, B, qc, Hk, g, Dh] -> [B, T, H, Dh]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Tq_pad, H, Dh)
    return out[:, :T].astype(q.dtype)


def attn_apply(p, cfg: AttnConfig, x: jax.Array,
               positions: Optional[jax.Array] = None) -> jax.Array:
    """Full (prefill/training) self-attention. x: [B, T, D]."""
    B, T, D = x.shape
    if positions is None:
        positions = jnp.arange(T, dtype=jnp.int32)[None, :]
    q = dense_apply(p["wq"], x).reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = dense_apply(p["wk"], x).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = dense_apply(p["wv"], x).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    mask = _scores_mask(T, T, jnp.int32(0), cfg.window)
    out = _sdpa(q, k, v, mask, cfg)
    return dense_apply(p["wo"], out.reshape(B, T, -1))


@dataclasses.dataclass(frozen=True)
class KVCacheSpec:
    """Static description of a layer's KV cache.

    kind: 'bf16' (plain), 'int8' (per-(token,head) scaled), or the cache
    length may be the sliding window for local layers (ring indexing).
    """
    length: int
    kind: str = "bf16"


def quantize_kv(x: jax.Array):
    """int8 symmetric per-(B, T, H) quantization; x [B,T,Hk,Dh]."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def decode_attn_apply(p, cfg: AttnConfig, x: jax.Array, cache: dict,
                      cur_len: jax.Array) -> tuple[jax.Array, dict]:
    """One-token decode step against a KV cache.

    x: [B, 1, D]; cache holds 'k','v' [B, S, Hk, Dh] (+ 'k_scale','v_scale'
    for int8). ``cur_len``: int32 scalar OR int32[B] per-slot lengths
    (continuous batching). For windowed layers the cache length S is the
    window and writes wrap (ring buffer); RoPE positions stay absolute.
    """
    B, one, D = x.shape
    S = cache["k"].shape[1]
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    pos = cur[:, None]
    q = dense_apply(p["wq"], x).reshape(B, 1, cfg.n_heads, cfg.head_dim)
    k = dense_apply(p["wk"], x).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    v = dense_apply(p["wv"], x).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    slot = jnp.mod(cur, S) if cfg.window is not None else jnp.minimum(
        cur, S - 1)
    rows = jnp.arange(B)
    int8 = "k_scale" in cache
    cache = dict(cache)
    if int8:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        cache["k"] = cache["k"].at[rows, slot].set(kq[:, 0])
        cache["v"] = cache["v"].at[rows, slot].set(vq[:, 0])
        cache["k_scale"] = cache["k_scale"].at[rows, slot].set(ks[:, 0])
        cache["v_scale"] = cache["v_scale"].at[rows, slot].set(vs[:, 0])
        k_all = dequantize_kv(cache["k"], cache["k_scale"], x.dtype)
        v_all = dequantize_kv(cache["v"], cache["v_scale"], x.dtype)
    else:
        cache["k"] = cache["k"].at[rows, slot].set(
            k[:, 0].astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[rows, slot].set(
            v[:, 0].astype(cache["v"].dtype))
        k_all = cache["k"].astype(x.dtype)
        v_all = cache["v"].astype(x.dtype)

    # validity per (row, cache slot): handles ring wrap + unfilled tail
    slots = jnp.arange(S, dtype=jnp.int32)[None, :]
    if cfg.window is not None:
        valid = (slots <= slot[:, None]) | (cur[:, None] >= S)
    else:
        valid = slots <= cur[:, None]
    out = _sdpa(q, k_all, v_all, valid[:, None, None, None, :], cfg)
    return dense_apply(p["wo"], out.reshape(B, 1, -1)), cache
