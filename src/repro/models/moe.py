"""Mixture-of-Experts FFN with push/pull dispatch (paper technique applied
to MoE — DESIGN.md §4).

Token→expert routing is a bipartite graph per microbatch. The paper's
dichotomy maps onto the two standard dispatch schedules:

  * **push dispatch** (default): tokens are scattered into per-expert
    capacity buffers (one_hot combine matmul / segment-style scatter);
    on a sharded mesh the buffers travel by all_to_all — the combining
    "remote write" of §5-PA. Combine back is the transpose.
  * **pull dispatch**: each expert *gathers* its assigned token ids
    (argsort by expert) and writes back only its owned slice — reads
    instead of scatters.

Both produce identical outputs; the dry-run/roofline chooses per cell.
Shared experts (deepseek) run densely for every token — they are the
"local partition" that never pays dispatch (PA analogy).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..dist.sharding import BATCH, hint
from .common import dense_init, dense_apply, silu

__all__ = ["MoEConfig", "moe_init", "moe_apply", "moe_apply_ep"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff_expert: int
    n_experts: int
    top_k: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    dispatch: str = "push"            # 'push' | 'pull'
    router_dtype: str = "float32"
    # EP combine psum payload: 'f32' (exact) or 'bf16' (halves the
    # collective bytes; each token sums <= top_k expert contributions,
    # so precision loss is benign) — hillclimb lever
    combine_dtype: str = "f32"
    # EP schedule: 'psum' — every model rank dispatches every (replicated)
    # token to its local experts, psum combines (pull-style: redundant
    # reads, no routing traffic); 'a2a' — ranks split the token sequence,
    # route via all_to_all, return via all_to_all (+ all_gather) — the
    # paper's MP combined-alltoall push, 16x less dispatch memory traffic
    ep_mode: str = "psum"


def _expert_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, d_ff, dtype),
        "wg": dense_init(k2, d_model, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32):
    kr, ke, ks = jax.random.split(key, 3)
    params = {
        "router": dense_init(kr, cfg.d_model, cfg.n_experts, jnp.float32),
        # experts stacked on a leading axis -> shardable over 'model' (EP)
        "experts": jax.vmap(
            lambda k: _expert_init(k, cfg.d_model, cfg.d_ff_expert, dtype)
        )(jax.random.split(ke, cfg.n_experts)),
    }
    if cfg.n_shared:
        params["shared"] = jax.vmap(
            lambda k: _expert_init(k, cfg.d_model, cfg.d_ff_expert, dtype)
        )(jax.random.split(ks, cfg.n_shared))
    return params


def _expert_ffn(p, x):
    """SwiGLU expert; p leaves have a leading expert axis when vmapped."""
    return dense_apply({"w": p["wo"]["w"]},
                       silu(dense_apply({"w": p["wg"]["w"]}, x))
                       * dense_apply({"w": p["wi"]["w"]}, x))


def moe_apply(params, cfg: MoEConfig, x: jax.Array,
              return_aux: bool = False):
    """x: [B, T, D] -> [B, T, D] (+ aux dict with load-balance loss)."""
    B, T, D = x.shape
    S = B * T
    xf = hint(x.reshape(S, D), BATCH, None)
    E, K = cfg.n_experts, cfg.top_k

    logits = dense_apply(params["router"], xf.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [S, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # [S, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(cfg.capacity_factor * S * K / E))
    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)      # [S, K, E]
    pos_in_e = (jnp.cumsum(onehot.reshape(S * K, E), axis=0) - 1)
    pos_in_e = (pos_in_e.reshape(S, K, E) * onehot).sum(-1)    # [S, K]
    keep = pos_in_e < cap

    if cfg.dispatch == "push":
        # scatter tokens into [E, cap, D] buffers via combine matmul — the
        # all_to_all payload on a sharded mesh
        disp = (jax.nn.one_hot(gate_idx, E, dtype=xf.dtype)[..., :, None]
                * jax.nn.one_hot(pos_in_e, cap, dtype=xf.dtype)[..., None, :]
                )                                              # [S,K,E,cap]
        disp = disp * keep[..., None, None].astype(xf.dtype)
        buf = jnp.einsum("skec,sd->ecd", disp, xf)             # [E, cap, D]
        out_e = jax.vmap(_expert_ffn)(params["experts"], buf)  # [E, cap, D]
        comb = disp * gate_vals[..., None, None].astype(xf.dtype)
        yf = jnp.einsum("skec,ecd->sd", comb, out_e)
    else:
        # pull: experts gather their token ids (argsort by expert id)
        flat_e = gate_idx.reshape(-1)                          # [S*K]
        order = jnp.argsort(flat_e, stable=True)
        # slot j of expert e = j-th smallest order index with expert e
        tok_of_slot = (order // K).reshape(1, -1)              # token ids
        e_sorted = flat_e[order]
        # mark slot boundaries per expert: slots are contiguous after sort
        slot_rank = jnp.arange(S * K) - jnp.searchsorted(
            e_sorted, jnp.arange(E), side="left")[e_sorted]
        gathered = jnp.take(xf, order // K, axis=0)            # [S*K, D]
        in_cap = slot_rank < cap
        # cap+1 slots: overflow writes land in the sacrificial last slot so
        # they can never clobber a legitimate (e, cap-1) entry
        buf = jnp.zeros((E, cap + 1, D), xf.dtype)
        buf = buf.at[e_sorted, jnp.minimum(slot_rank, cap)].set(
            jnp.where(in_cap[:, None], gathered, 0.0))
        buf = hint(buf[:, :cap], "model", None, None)  # expert-parallel
        out_e = jax.vmap(_expert_ffn)(params["experts"], buf)
        out_e = hint(out_e, "model", None, None)
        # write back: each (token,k) pulls its expert output slot
        slot_of_sk = jnp.zeros((S * K,), jnp.int32).at[order].set(
            jnp.minimum(slot_rank, cap - 1).astype(jnp.int32))
        ok_of_sk = jnp.zeros((S * K,), bool).at[order].set(in_cap)
        picked = out_e[flat_e, slot_of_sk]                     # [S*K, D]
        picked = jnp.where(ok_of_sk[:, None], picked, 0.0)
        yf = (picked.reshape(S, K, D)
              * gate_vals[..., None].astype(xf.dtype)
              * keep[..., None].astype(xf.dtype)).sum(axis=1)

    if cfg.n_shared:
        shared_out = jax.vmap(lambda p: _expert_ffn(p, xf))(params["shared"])
        yf = yf + shared_out.sum(axis=0)

    y = yf.reshape(B, T, D).astype(x.dtype)
    if not return_aux:
        return y
    # Switch-style load-balance loss
    density = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32),
                       axis=0)
    router_mean = jnp.mean(probs, axis=0)
    aux = {"lb_loss": E * jnp.sum(density * router_mean),
           "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y, aux


def _local_pull_dispatch(params_router, experts_block, cfg: MoEConfig,
                         xf: jax.Array, e_base, E_local: int):
    """Shard-local pull dispatch: route xf [S, D] to the E_local experts
    owned by this shard, run them, return this shard's partial output.
    Everything here is device-local — the paper's PA 'local arrays'."""
    S, D = xf.shape
    K = cfg.top_k
    logits = dense_apply(params_router, xf.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    cap = max(1, int(cfg.capacity_factor * S * K / cfg.n_experts))

    local = (gate_idx >= e_base) & (gate_idx < e_base + E_local)
    flat_e = jnp.where(local, gate_idx - e_base, E_local).reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    first = jnp.searchsorted(e_sorted, jnp.arange(E_local + 1), side="left")
    slot_rank = jnp.arange(S * K) - first[jnp.minimum(e_sorted, E_local)]
    gathered = jnp.take(xf, order // K, axis=0)
    in_cap = (slot_rank < cap) & (e_sorted < E_local)
    buf = jnp.zeros((E_local + 1, cap + 1, D), xf.dtype)
    buf = buf.at[jnp.minimum(e_sorted, E_local),
                 jnp.clip(slot_rank, 0, cap)].set(
        jnp.where(in_cap[:, None], gathered, 0.0))
    out_e = jax.vmap(_expert_ffn)(experts_block, buf[:E_local, :cap])
    slot_of_sk = jnp.zeros((S * K,), jnp.int32).at[order].set(
        jnp.clip(slot_rank, 0, cap - 1).astype(jnp.int32))
    ok_of_sk = jnp.zeros((S * K,), bool).at[order].set(in_cap)
    e_of_sk = jnp.where(local, gate_idx - e_base, 0).reshape(-1)
    picked = out_e[jnp.clip(e_of_sk, 0, E_local - 1), slot_of_sk]
    picked = jnp.where(ok_of_sk[:, None], picked, 0.0)
    return (picked.reshape(S, K, D)
            * gate_vals[..., None].astype(xf.dtype)).sum(axis=1)


def _a2a_dispatch_block(router_p, experts_block, xf, cfg: MoEConfig,
                        tp: int, E_local: int, shared_p=None):
    """Sequence-split all_to_all EP (inside shard_map over 'model').

    xf: [S, D] tokens (replicated over 'model'). This rank routes ONLY its
    S/tp slice; tokens travel to expert owners via all_to_all and return
    the same way; an all_gather reassembles the replicated activations.
    """
    S, D = xf.shape
    K = cfg.top_k
    E = cfg.n_experts
    m_idx = jax.lax.axis_index("model")
    S_m = S // tp
    # index dtypes must match even under x64 (m_idx is int32)
    xm = jax.lax.dynamic_slice(xf, (m_idx * S_m, jnp.int32(0)), (S_m, D))
    logits = dense_apply(router_p, xm.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # [S_m, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    cap = max(1, int(cfg.capacity_factor * S_m * K / E))   # per (rank, e)

    seg = gate_idx.reshape(-1)                             # [S_m*K] in [0,E)
    order = jnp.argsort(seg, stable=True)
    seg_s = seg[order]
    first = jnp.searchsorted(seg_s, jnp.arange(E + 1), side="left")
    slot = jnp.arange(S_m * K) - first[seg_s]
    in_cap = slot < cap
    gathered = jnp.take(xm, order // K, axis=0)
    send = jnp.zeros((E, cap + 1, D), xf.dtype)
    send = send.at[seg_s, jnp.minimum(slot, cap)].set(
        jnp.where(in_cap[:, None], gathered, 0.0))
    send = send[:, :cap].reshape(tp, E_local, cap, D)
    # tokens -> expert owners (the combined 'MP' push of the paper)
    recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                              tiled=False)                 # [tp, E_l, cap, D]
    bufs = recv.transpose(1, 0, 2, 3).reshape(E_local, tp * cap, D)
    out_e = jax.vmap(_expert_ffn)(experts_block, bufs)
    back = out_e.reshape(E_local, tp, cap, D).transpose(1, 0, 2, 3)
    got = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0,
                             tiled=False)                  # [tp, E_l, cap, D]
    # got[r, e, s] = output for the token this rank queued at (r*E_l+e, s)
    slot_of = jnp.zeros((S_m * K,), jnp.int32).at[order].set(
        jnp.clip(slot, 0, cap - 1).astype(jnp.int32))
    ok_of = jnp.zeros((S_m * K,), bool).at[order].set(in_cap)
    r_of = (gate_idx // E_local).reshape(-1)
    e_of = (gate_idx % E_local).reshape(-1)
    picked = got[r_of, e_of, slot_of]
    picked = jnp.where(ok_of[:, None], picked, 0.0)
    ym = (picked.reshape(S_m, K, D)
          * gate_vals[..., None].astype(xf.dtype)).sum(axis=1)
    if shared_p is not None:
        # shared experts on the sequence slice too: 1/tp of the redundant
        # replicated work+traffic; the all_gather reassembles everything
        sh = jax.vmap(lambda p: _expert_ffn(p, xm))(shared_p)
        ym = ym + sh.sum(axis=0)
    if cfg.combine_dtype == "bf16":
        ym = ym.astype(jnp.bfloat16)
    return jax.lax.all_gather(ym, "model", tiled=True).astype(xf.dtype)


def moe_apply_ep(params, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """Expert-parallel MoE: tokens stay data-sharded (replicated over
    'model'), experts shard over 'model'; each device dispatches its local
    tokens to its local experts and a psum over 'model' combines expert
    contributions. All routing/sort work is shard-local — the GSPMD
    global-argsort trap (an all-gather of every token) never appears.

    Falls back to moe_apply when no activation mesh is installed.
    """
    from ..dist.sharding import _ACT_MESH  # set by cell builders
    mesh = _ACT_MESH
    if mesh is None or "model" not in mesh.axis_names:
        return moe_apply(params, cfg, x)
    tp = mesh.shape["model"]
    if cfg.n_experts % tp != 0:
        return moe_apply(params, cfg, x)
    E_local = cfg.n_experts // tp
    B, T, D = x.shape
    batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsz = 1
    for a in batch:
        bsz *= mesh.shape[a]
    if B % bsz != 0:
        batch = ()   # tiny decode batches: replicate tokens over data

    # per-shard token count must split over 'model' for the a2a schedule
    bsz_eff = 1
    for a in batch:
        bsz_eff *= mesh.shape[a]
    S_shard = (B // max(1, bsz_eff)) * T
    use_a2a = cfg.ep_mode == "a2a" and S_shard % tp == 0 and S_shard >= tp

    shared_in_block = use_a2a and cfg.n_shared > 0

    @partial_shard_map(mesh,
                       in_specs=(P(), P("model"), P(), P(batch, None, None)),
                       out_specs=P(batch, None, None))
    def block(router_p, experts_block, shared_p, xb):
        Bl, Tl, Dl = xb.shape
        xf = xb.reshape(Bl * Tl, Dl)
        if use_a2a:
            yf = _a2a_dispatch_block(
                router_p, experts_block, xf, cfg, tp, E_local,
                shared_p=shared_p if shared_in_block else None)
            return yf.reshape(Bl, Tl, Dl)
        m_idx = jax.lax.axis_index("model")
        e_base = m_idx * E_local
        yf = _local_pull_dispatch(router_p, experts_block, cfg, xf,
                                  e_base, E_local)
        if cfg.combine_dtype == "bf16":
            yf = jax.lax.psum(yf.astype(jnp.bfloat16), "model")
        else:
            yf = jax.lax.psum(yf, "model")
        return yf.astype(xb.dtype).reshape(Bl, Tl, Dl)

    shared_arg = params.get("shared") if cfg.n_shared else None
    if shared_arg is None:
        shared_arg = {"wi": {"w": jnp.zeros((0,), x.dtype)},
                      "wg": {"w": jnp.zeros((0,), x.dtype)},
                      "wo": {"w": jnp.zeros((0,), x.dtype)}}
    y = block(params["router"], params["experts"], shared_arg, x
              ).astype(x.dtype)
    if cfg.n_shared and not shared_in_block:
        # shared experts = the PA 'local partition': dense, never dispatched
        xf = x.reshape(B * T, D)
        shared_out = jax.vmap(lambda p: _expert_ffn(p, xf))(params["shared"])
        y = y + shared_out.sum(axis=0).reshape(B, T, D).astype(x.dtype)
    return y


def partial_shard_map(mesh, in_specs, out_specs):
    def deco(f):
        # check_vma=False: the a2a path's replication over 'model' (via
        # all_gather of axis_index-dependent slices) is correct but not
        # statically inferable by the varying-manual-axes checker
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    return deco
