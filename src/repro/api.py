"""repro.api — one k-relaxation API for every graph workload.

The paper's claim is that every graph algorithm reduces to one abstract
primitive (k-relaxation) with push and pull as interchangeable
implementations. This module is that claim as an interface:

    from repro import api
    from repro.core import Fixed, Direction, GenericSwitch
    from repro.core.backend import EllBackend

    r = api.solve(g, "pagerank", iters=30)                  # GS policy
    r = api.solve(g, "bfs", root=0, policy=Fixed(Direction.PUSH))
    r = api.solve(g, "sssp_delta", source=0, delta=2.0)     # Δ-stepping
    r = api.solve(g, "mst_boruvka", backend=EllBackend())   # ELL layout

Every algorithm is a :class:`~repro.core.engine.VertexProgram` — or a
multi-phase :class:`~repro.core.engine.PhaseProgram` (Δ-stepping's bucket
epochs, Brandes BC's forward/backward pair, Borůvka's find-min/contract
rounds, Boman coloring's color/fix iterations) — executed by the
:class:`~repro.core.engine.PushPullEngine`; ``policy`` chooses the
direction per step (Fixed / GenericSwitch / GreedySwitch) and ``backend``
chooses the memory system (Dense / ELL / Distributed) — any algorithm
runs under any (policy × backend) cell it declares supported and returns
the same states. Unsupported combinations raise a ``ValueError`` naming
the combination.

``solve`` returns a :class:`RunResult` with a unified surface:
``state`` (algorithm-specific pytree), ``cost`` (paper Table-1
counters), ``steps``, ``push_steps``, ``epochs``, ``converged``.

New algorithms register an :class:`AlgorithmSpec`; engines are cached per
(algorithm, policy, backend, static-kwargs, graph shape) so repeated
solves hit the jit cache like the hand-rolled loops they replaced.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax

from .core.algorithms.betweenness import (betweenness_finalize,
                                          betweenness_init,
                                          betweenness_program)
from .core.algorithms.bfs import bfs_init, bfs_program
from .core.algorithms.coloring import (coloring_finalize, coloring_init,
                                       coloring_program)
from .core.algorithms.mst_boruvka import (mst_finalize, mst_init,
                                          mst_program)
from .core.algorithms.pagerank import pagerank_init, pagerank_program
from .core.algorithms.pr_delta import (pr_delta_finalize, pr_delta_init,
                                       pr_delta_program)
from .core.algorithms.sssp_delta import (sssp_delta_finalize,
                                         sssp_delta_init,
                                         sssp_delta_program)
from .core.algorithms.triangle_count import (triangle_finalize,
                                             triangle_init,
                                             triangle_program)
from .core.algorithms.wcc import wcc_init, wcc_program
from .core.backend import (DenseBackend, DistributedBackend, EllBackend,
                           ExchangeBackend)
from .core.cost_model import Cost
from .core.direction import (Direction, DirectionPolicy, Fixed,
                             GenericSwitch, GreedySwitch)
from .core.engine import PhaseProgram, PushPullEngine, VertexProgram
from .graphs.structure import Graph

__all__ = ["RunResult", "AlgorithmSpec", "register", "algorithms",
           "get_spec", "solve",
           "DenseBackend", "EllBackend", "DistributedBackend",
           "ExchangeBackend", "Fixed", "GenericSwitch", "GreedySwitch",
           "Direction"]


class RunResult(NamedTuple):
    """Unified result of ``solve``: the algorithm's state pytree plus the
    engine's run metadata. ``steps`` counts relaxation/local steps across
    all phases; ``epochs`` counts outer rounds (buckets, sources, Borůvka
    rounds, coloring iterations — 1 for flat programs)."""
    state: Any
    cost: Cost
    steps: jax.Array
    push_steps: jax.Array
    converged: jax.Array
    epochs: jax.Array


@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """How an algorithm plugs into the engine.

    build(g, *, policy, backend, **static_kw) -> (program,
        default_max_steps) — ``program`` is a VertexProgram or a
        PhaseProgram (for phase programs the default bounds *epochs*).
        Must close over static graph attributes only (n, m), never
        arrays, so engines cache across graphs of one shape; must raise
        NotImplementedError/ValueError for (policy, backend) combinations
        it has no execution path for (``solve`` surfaces these as a
        ValueError naming the combination).
    init(g, **kw) -> (init_state, init_frontier).
    finalize(g, state) -> public state pytree.
    runtime_keys: kwargs consumed only by ``init`` (e.g. ``root``),
        excluded from the engine cache key.
    backends: declared-supported backend names (introspection only; the
        authoritative check lives in ``build``).
    paper: the paper section this algorithm reproduces.
    """
    name: str
    build: Callable
    init: Callable
    finalize: Callable = staticmethod(lambda g, state: state)
    default_policy: DirectionPolicy = GenericSwitch()
    runtime_keys: tuple = ()
    backends: tuple = ("dense", "ell", "distributed")
    paper: str = ""


_REGISTRY: dict[str, AlgorithmSpec] = {}
# Built engines keyed by (algorithm, policy, backend, static kwargs, graph
# shape). Bounded FIFO: a DistributedBackend key pins graph-sized edge
# arrays, so stale entries must be evictable in long-lived processes.
_ENGINE_CACHE: dict = {}
_ENGINE_CACHE_MAX = 128


def register(spec: AlgorithmSpec) -> AlgorithmSpec:
    _REGISTRY[spec.name] = spec
    return spec


def algorithms() -> list[str]:
    """Names accepted by ``solve``."""
    return sorted(_REGISTRY)


def get_spec(name: str) -> AlgorithmSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: {algorithms()}"
        ) from None


def solve(g: Graph, algorithm: str, *,
          policy: Optional[DirectionPolicy] = None,
          backend: Optional[ExchangeBackend] = None,
          max_steps: Optional[int] = None, **kw) -> RunResult:
    """Run ``algorithm`` on ``g`` under a direction policy and an
    exchange backend. Algorithm-specific kwargs (``root``, ``source``,
    ``iters``, ``damp``, ``tol``, ...) pass through ``**kw``."""
    spec = get_spec(algorithm)
    policy = spec.default_policy if policy is None else policy
    backend = DenseBackend() if backend is None else backend
    static_kw = {k: v for k, v in kw.items() if k not in spec.runtime_keys}

    key: Optional[tuple]
    try:
        # key on the spec itself: re-registering a name invalidates
        # cached engines built from the old spec
        key = (algorithm, spec, policy, backend,
               tuple(sorted(static_kw.items())),
               g.n, g.m, g.d_ell, max_steps)
        hash(key)
    except TypeError:
        key = None
    engine = _ENGINE_CACHE.get(key) if key is not None else None
    if engine is None:
        try:
            program, default_steps = spec.build(
                g, policy=policy, backend=backend, **static_kw)
        except (NotImplementedError, ValueError) as e:
            raise ValueError(
                f"algorithm {algorithm!r} does not support the "
                f"combination policy={policy.name} × "
                f"backend={backend.name}: {e}") from e
        engine = PushPullEngine(
            program=program, policy=policy,
            max_steps=default_steps if max_steps is None else max_steps,
            backend=backend)
        if key is not None:
            while len(_ENGINE_CACHE) >= _ENGINE_CACHE_MAX:
                _ENGINE_CACHE.pop(next(iter(_ENGINE_CACHE)))
            _ENGINE_CACHE[key] = engine
    init_state, init_frontier = spec.init(g, **kw)
    res = engine.run(g, init_state, init_frontier)
    return RunResult(state=spec.finalize(g, res.state), cost=res.cost,
                     steps=res.steps, push_steps=res.push_steps,
                     converged=res.converged, epochs=res.epochs)


# ---------------------------------------------------------------------
# Built-in registrations: all of the paper's workloads.
register(AlgorithmSpec(
    name="bfs", build=bfs_program, init=bfs_init,
    runtime_keys=("root",), paper="§3.3/§4.3 Alg. 3"))

register(AlgorithmSpec(
    name="pagerank", build=pagerank_program, init=pagerank_init,
    default_policy=Fixed(Direction.PULL), paper="§3.1/§4.1 Alg. 1"))

register(AlgorithmSpec(
    name="wcc", build=wcc_program, init=wcc_init,
    paper="§3.3 (label propagation)"))

register(AlgorithmSpec(
    name="pr_delta", build=pr_delta_program, init=pr_delta_init,
    finalize=pr_delta_finalize,
    default_policy=Fixed(Direction.PUSH), paper="§3.1 (Whang [60])"))

register(AlgorithmSpec(
    name="sssp_delta", build=sssp_delta_program, init=sssp_delta_init,
    finalize=sssp_delta_finalize,
    default_policy=Fixed(Direction.PUSH),
    runtime_keys=("source",), backends=("dense", "ell"),
    paper="§3.4/§4.4 Alg. 4"))

register(AlgorithmSpec(
    name="betweenness", build=betweenness_program, init=betweenness_init,
    finalize=betweenness_finalize,
    default_policy=Fixed(Direction.PULL), backends=("dense", "ell"),
    paper="§3.5/§4.5 Alg. 5"))

register(AlgorithmSpec(
    name="coloring", build=coloring_program, init=coloring_init,
    finalize=coloring_finalize,
    default_policy=Fixed(Direction.PUSH), backends=("dense", "ell"),
    paper="§3.6/§4.6 Alg. 6"))

register(AlgorithmSpec(
    name="mst_boruvka", build=mst_program, init=mst_init,
    finalize=mst_finalize,
    default_policy=Fixed(Direction.PULL), backends=("dense", "ell"),
    paper="§3.7/§4.7 Alg. 7"))

register(AlgorithmSpec(
    name="triangle_count", build=triangle_program, init=triangle_init,
    finalize=triangle_finalize,
    default_policy=Fixed(Direction.PULL), backends=("dense", "ell"),
    paper="§3.2/§4.2 Alg. 2"))
