"""repro.api — one k-relaxation API for every graph workload.

The paper's claim is that every graph algorithm reduces to one abstract
primitive (k-relaxation) with push and pull as interchangeable
implementations. This module is that claim as an interface:

    from repro import api
    from repro.core import Fixed, Direction, GenericSwitch
    from repro.core.backend import EllBackend

    r = api.solve(g, "pagerank", iters=30)                  # GS policy
    r = api.solve(g, "bfs", root=0, policy=Fixed(Direction.PUSH))
    r = api.solve(g, "pagerank", backend=EllBackend())      # ELL layout

Every algorithm is a :class:`~repro.core.engine.VertexProgram` executed
by the :class:`~repro.core.engine.PushPullEngine`; ``policy`` chooses the
direction per step (Fixed / GenericSwitch / GreedySwitch) and ``backend``
chooses the memory system (Dense / ELL / Distributed) — any algorithm
runs under any (policy × backend) pair and returns the same states.

``solve`` returns a :class:`RunResult` with a unified surface:
``state`` (algorithm-specific pytree), ``cost`` (paper Table-1
counters), ``steps``, ``push_steps``, ``converged``.

New algorithms register an :class:`AlgorithmSpec`; engines are cached per
(algorithm, policy, backend, static-kwargs, graph shape) so repeated
solves hit the jit cache like the hand-rolled loops they replaced.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax

from .core.algorithms.bfs import bfs_init, bfs_program
from .core.algorithms.pagerank import pagerank_init, pagerank_program
from .core.algorithms.pr_delta import (pr_delta_finalize, pr_delta_init,
                                       pr_delta_program)
from .core.algorithms.wcc import wcc_init, wcc_program
from .core.backend import (DenseBackend, DistributedBackend, EllBackend,
                           ExchangeBackend)
from .core.cost_model import Cost
from .core.direction import (Direction, DirectionPolicy, Fixed,
                             GenericSwitch, GreedySwitch)
from .core.engine import PushPullEngine, VertexProgram
from .graphs.structure import Graph

__all__ = ["RunResult", "AlgorithmSpec", "register", "algorithms", "solve",
           "DenseBackend", "EllBackend", "DistributedBackend",
           "ExchangeBackend", "Fixed", "GenericSwitch", "GreedySwitch",
           "Direction"]


class RunResult(NamedTuple):
    """Unified result of ``solve``: the algorithm's state pytree plus the
    engine's run metadata."""
    state: Any
    cost: Cost
    steps: jax.Array
    push_steps: jax.Array
    converged: jax.Array


@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """How an algorithm plugs into the engine.

    build(g, **static_kw) -> (VertexProgram, default_max_steps) — must
        close over static graph attributes only (n, m), never arrays, so
        engines cache across graphs of one shape.
    init(g, **kw) -> (init_state, init_frontier).
    finalize(state) -> public state pytree.
    runtime_keys: kwargs consumed only by ``init`` (e.g. ``root``),
        excluded from the engine cache key.
    """
    name: str
    build: Callable
    init: Callable
    finalize: Callable = staticmethod(lambda state: state)
    default_policy: DirectionPolicy = GenericSwitch()
    runtime_keys: tuple = ()


_REGISTRY: dict[str, AlgorithmSpec] = {}
# Built engines keyed by (algorithm, policy, backend, static kwargs, graph
# shape). Bounded FIFO: a DistributedBackend key pins graph-sized edge
# arrays, so stale entries must be evictable in long-lived processes.
_ENGINE_CACHE: dict = {}
_ENGINE_CACHE_MAX = 128


def register(spec: AlgorithmSpec) -> AlgorithmSpec:
    _REGISTRY[spec.name] = spec
    return spec


def algorithms() -> list[str]:
    """Names accepted by ``solve``."""
    return sorted(_REGISTRY)


def get_spec(name: str) -> AlgorithmSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: {algorithms()}"
        ) from None


def solve(g: Graph, algorithm: str, *,
          policy: Optional[DirectionPolicy] = None,
          backend: Optional[ExchangeBackend] = None,
          max_steps: Optional[int] = None, **kw) -> RunResult:
    """Run ``algorithm`` on ``g`` under a direction policy and an
    exchange backend. Algorithm-specific kwargs (``root``, ``iters``,
    ``damp``, ``tol``, ...) pass through ``**kw``."""
    spec = get_spec(algorithm)
    policy = spec.default_policy if policy is None else policy
    backend = DenseBackend() if backend is None else backend
    static_kw = {k: v for k, v in kw.items() if k not in spec.runtime_keys}

    key: Optional[tuple]
    try:
        # key on the spec itself: re-registering a name invalidates
        # cached engines built from the old spec
        key = (algorithm, spec, policy, backend,
               tuple(sorted(static_kw.items())),
               g.n, g.m, g.d_ell, max_steps)
        hash(key)
    except TypeError:
        key = None
    engine = _ENGINE_CACHE.get(key) if key is not None else None
    if engine is None:
        program, default_steps = spec.build(g, **static_kw)
        engine = PushPullEngine(
            program=program, policy=policy,
            max_steps=default_steps if max_steps is None else max_steps,
            backend=backend)
        if key is not None:
            while len(_ENGINE_CACHE) >= _ENGINE_CACHE_MAX:
                _ENGINE_CACHE.pop(next(iter(_ENGINE_CACHE)))
            _ENGINE_CACHE[key] = engine

    init_state, init_frontier = spec.init(g, **kw)
    res = engine.run(g, init_state, init_frontier)
    return RunResult(state=spec.finalize(res.state), cost=res.cost,
                     steps=res.steps, push_steps=res.push_steps,
                     converged=res.converged)


# ---------------------------------------------------------------------
# Built-in registrations: the paper's core workloads.
register(AlgorithmSpec(
    name="bfs", build=bfs_program, init=bfs_init,
    runtime_keys=("root",)))

register(AlgorithmSpec(
    name="pagerank", build=pagerank_program, init=pagerank_init,
    default_policy=Fixed(Direction.PULL)))

register(AlgorithmSpec(
    name="wcc", build=wcc_program, init=wcc_init))

register(AlgorithmSpec(
    name="pr_delta", build=pr_delta_program, init=pr_delta_init,
    finalize=pr_delta_finalize,
    default_policy=Fixed(Direction.PUSH)))
