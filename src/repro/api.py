"""repro.api — one k-relaxation API for every graph workload.

The paper's claim is that every graph algorithm reduces to one abstract
primitive (k-relaxation) with push and pull as interchangeable
implementations. This module is that claim as an interface:

    from repro import api
    from repro.core import Fixed, Direction, GenericSwitch
    from repro.core.backend import EllBackend

    r = api.solve(g, "pagerank", iters=30)                  # GS policy
    r = api.solve(g, "bfs", root=0, policy=Fixed(Direction.PUSH))
    r = api.solve(g, "bfs", root=0, policy="auto")          # AutoSwitch
    r = api.solve(g, "sssp_delta", source=0, delta=2.0)     # Δ-stepping
    r = api.solve(g, "mst_boruvka", backend=EllBackend())   # ELL layout
    r = api.solve(g, "bfs", root=0, backend="pallas")       # Pallas kernels

Every algorithm is a :class:`~repro.core.engine.VertexProgram` — or a
multi-phase :class:`~repro.core.engine.PhaseProgram` (Δ-stepping's bucket
epochs, Brandes BC's forward/backward pair, Borůvka's find-min/contract
rounds, Boman coloring's color/fix iterations) — executed by the
:class:`~repro.core.engine.PushPullEngine`; ``policy`` chooses the
direction per step (Fixed / GenericSwitch / GreedySwitch) and ``backend``
chooses the memory system (Dense / ELL / Pallas / Distributed) — any
algorithm runs under any (policy × backend) cell it declares supported
and returns the same states. Unsupported combinations raise a
``ValueError`` naming the combination.

``solve`` returns a :class:`RunResult` with a unified surface:
``state`` (algorithm-specific pytree), ``cost`` (paper Table-1
counters), ``steps``, ``push_steps``, ``epochs``, ``converged``.

New algorithms register an :class:`AlgorithmSpec`; engines are cached per
(algorithm, policy, backend, static-kwargs, graph shape) so repeated
solves hit the jit cache like the hand-rolled loops they replaced.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax

from .core.algorithms.betweenness import (betweenness_finalize,
                                          betweenness_init,
                                          betweenness_program)
from .core.algorithms.bfs import bfs_init, bfs_program
from .core.algorithms.coloring import (coloring_finalize, coloring_init,
                                       coloring_program)
from .core.algorithms.mst_boruvka import (mst_finalize, mst_init,
                                          mst_program)
from .core.algorithms.pagerank import pagerank_init, pagerank_program
from .core.algorithms.ppr import ppr_finalize, ppr_init, ppr_program
from .core.algorithms.pr_delta import (pr_delta_finalize, pr_delta_init,
                                       pr_delta_program)
from .core.algorithms.sssp_delta import (sssp_delta_finalize,
                                         sssp_delta_init,
                                         sssp_delta_program)
from .core.algorithms.triangle_count import (triangle_finalize,
                                             triangle_init,
                                             triangle_program)
from .core.algorithms.wcc import wcc_init, wcc_program
from .core.backend import (DenseBackend, DistributedBackend, EllBackend,
                           ExchangeBackend, PallasBackend)
from .core.cost_model import Cost, StepTrace
from .core.direction import (AutoSwitch, Direction, DirectionPolicy, Fixed,
                             GenericSwitch, GreedySwitch)
from .core.engine import PhaseProgram, PushPullEngine, VertexProgram
from .graphs.structure import Graph

__all__ = ["RunResult", "AlgorithmSpec", "register", "algorithms",
           "get_spec", "solve", "solve_batch", "POLICY_SHORTHANDS",
           "BACKEND_SHORTHANDS", "DenseBackend", "EllBackend",
           "PallasBackend", "DistributedBackend", "ExchangeBackend",
           "Fixed", "GenericSwitch", "GreedySwitch", "AutoSwitch",
           "Direction"]


class RunResult(NamedTuple):
    """Unified result of ``solve``.

    Attributes:
        state: the algorithm's public state pytree (e.g. BFS's
            ``{"dist", "parent", "visited"}`` dict, PageRank's rank
            vector).
        cost: accumulated paper-Table-1 counters
            (:class:`~repro.core.cost_model.Cost`); collapse with
            ``cost.weighted_total()`` for one comparable scalar.
        steps: relaxation/local steps across all phases.
        push_steps: how many of those ran in push direction.
        converged: whether the fixed point (not a step bound) ended the
            run.
        epochs: outer rounds — buckets, sources, Borůvka rounds,
            coloring iterations; 1 for flat programs.
        trace: per-step :class:`~repro.core.cost_model.StepTrace` when
            ``solve(..., trace=N)`` was given, else None.

    Example::

        r = api.solve(g, "bfs", root=0, policy="auto", trace=64)
        int(r.steps), bool(r.converged)
        float(r.cost.weighted_total())
        r.trace.as_dict(int(r.steps))["pushed"]   # per-step directions
    """
    state: Any
    cost: Cost
    steps: jax.Array
    push_steps: jax.Array
    converged: jax.Array
    epochs: jax.Array
    trace: Optional[StepTrace] = None


@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """How an algorithm plugs into the engine.

    build(g, *, policy, backend, **static_kw) -> (program,
        default_max_steps) — ``program`` is a VertexProgram or a
        PhaseProgram (for phase programs the default bounds *epochs*).
        Must close over static graph attributes only (n, m), never
        arrays, so engines cache across graphs of one shape; must raise
        NotImplementedError/ValueError for (policy, backend) combinations
        it has no execution path for (``solve`` surfaces these as a
        ValueError naming the combination).
    init(g, **kw) -> (init_state, init_frontier).
    finalize(g, state) -> public state pytree.
    runtime_keys: kwargs consumed only by ``init`` (e.g. ``root``),
        excluded from the engine cache key.
    backends: declared-supported backend names (introspection only; the
        authoritative check lives in ``build``).
    policies: declared-supported policy shorthands (see
        ``POLICY_SHORTHANDS``) — the (policy × backend) support matrix
        that docs/algorithms.md and the benchmark sweep enumerate.
    paper: the paper section this algorithm reproduces.
    """
    name: str
    build: Callable
    init: Callable
    finalize: Callable = staticmethod(lambda g, state: state)
    default_policy: DirectionPolicy = GenericSwitch()
    runtime_keys: tuple = ()
    backends: tuple = ("dense", "ell", "pallas", "distributed", "shard")
    policies: tuple = ("push", "pull", "gs", "grs", "auto")
    paper: str = ""


_REGISTRY: dict[str, AlgorithmSpec] = {}


class EngineCache:
    """Bounded FIFO of built engines keyed by hashable tuples.

    A DistributedBackend key pins graph-sized edge arrays, so stale
    entries must be evictable in long-lived processes; unhashable keys
    (e.g. unhashable kwargs) skip caching and rebuild every call.
    Shared by ``solve`` and the service layer's batched path.
    """

    def __init__(self, max_size: int = 128):
        self.max_size = max_size
        self._data: dict = {}

    def get_or_build(self, key, build: Callable):
        try:
            hash(key)
        except TypeError:
            return build()
        engine = self._data.get(key)
        if engine is None:
            engine = build()
            while len(self._data) >= self.max_size:
                self._data.pop(next(iter(self._data)))
            self._data[key] = engine
        return engine


# Built engines keyed by (algorithm, policy, backend, static kwargs,
# graph shape).
_ENGINE_CACHE = EngineCache()


def register(spec: AlgorithmSpec) -> AlgorithmSpec:
    _REGISTRY[spec.name] = spec
    return spec


def algorithms() -> list[str]:
    """Names accepted by ``solve``."""
    return sorted(_REGISTRY)


def get_spec(name: str) -> AlgorithmSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: {algorithms()}"
        ) from None


# String shorthands accepted wherever a DirectionPolicy is expected —
# zero-arg factories so each solve gets a fresh default-configured policy.
POLICY_SHORTHANDS: dict[str, Callable[[], DirectionPolicy]] = {
    "push": lambda: Fixed(Direction.PUSH),
    "pull": lambda: Fixed(Direction.PULL),
    "gs": GenericSwitch,
    "grs": GreedySwitch,
    "auto": AutoSwitch,
}

# String shorthands accepted wherever an ExchangeBackend is expected.
# One shared instance per name (not a factory): engines are cached per
# backend instance, and the Pallas backend additionally keeps its
# autotuner cache warm across solves. "distributed" is absent on
# purpose — it is graph-specific and must go through
# DistributedBackend.prepare(g).
BACKEND_SHORTHANDS: dict[str, ExchangeBackend] = {
    "dense": DenseBackend(),
    "ell": EllBackend(),
    "pallas": PallasBackend(),
}

# solve(trace=True) records up to this many steps
_DEFAULT_TRACE_CAPACITY = 256

# runtime kwargs that name vertices and must index into [0, n); JAX
# scatter semantics would otherwise clip/drop bad indices silently
_VERTEX_KEYS = ("root", "source")


def validate_vertex_indices(g: Graph, name: str, value) -> None:
    """Raise ``ValueError`` naming any vertex index outside ``[0, n)``.

    ``value`` may be a python int, a 0-d array, or a sequence/array of
    ints (``solve_batch`` sources). Traced (abstract) values pass
    through unchecked — inside jit the caller owns validity.
    """
    import numpy as np
    try:
        arr = np.asarray(value)
    except Exception:  # traced values have no concrete array view
        return
    if arr.size == 0:  # emptiness is the callee's error to report
        return
    if arr.dtype == object or not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"{name}={value!r} is not a vertex index (expected integer "
            f"in [0, {g.n}))")
    bad = (arr < 0) | (arr >= g.n)
    if bad.any():
        first = int(arr.reshape(-1)[np.flatnonzero(bad.reshape(-1))[0]])
        raise ValueError(
            f"{name} contains vertex index {first} out of range for a "
            f"graph with n={g.n} vertices (valid: 0..{g.n - 1})")


def _resolve_policy(policy) -> DirectionPolicy:
    if not isinstance(policy, str):
        return policy
    try:
        return POLICY_SHORTHANDS[policy]()
    except KeyError:
        raise ValueError(
            f"unknown policy shorthand {policy!r}; valid options: "
            f"{sorted(POLICY_SHORTHANDS)} (or pass a DirectionPolicy "
            "instance)") from None


# Graph-specific "shard" backends, cached per live graph object (keyed
# by id with a weakref guard against id reuse after collection).
_SHARD_BACKENDS: dict[int, tuple] = {}


def _shard_backend_for(g: Graph) -> ExchangeBackend:
    import weakref

    from .shard import ShardedBackend
    key = id(g)
    hit = _SHARD_BACKENDS.get(key)
    if hit is not None and hit[0]() is g:
        return hit[1]
    prepared = ShardedBackend.prepare(g)
    ref = weakref.ref(g, lambda _: _SHARD_BACKENDS.pop(key, None))
    _SHARD_BACKENDS[key] = (ref, prepared)
    return prepared


def _resolve_backend(backend, g: Optional[Graph] = None
                     ) -> ExchangeBackend:
    if backend is None:
        return BACKEND_SHORTHANDS["dense"]
    if not isinstance(backend, str):
        return backend
    if backend == "shard":
        # graph-specific: prepared per graph (mesh over all visible
        # devices), not a shared instance like the other shorthands
        if g is None:
            raise ValueError(
                "backend='shard' is graph-specific; pass it through "
                "solve()/solve_batch(), or prepare an instance with "
                "repro.shard.ShardedBackend.prepare(g)")
        return _shard_backend_for(g)
    try:
        return BACKEND_SHORTHANDS[backend]
    except KeyError:
        raise ValueError(
            f"unknown backend shorthand {backend!r}; valid options: "
            f"{sorted(BACKEND_SHORTHANDS) + ['shard']} (or pass an "
            "ExchangeBackend instance, e.g. "
            "DistributedBackend.prepare(g))") from None


def solve(g: Graph, algorithm: str, *,
          policy: Optional[DirectionPolicy | str] = None,
          backend: Optional[ExchangeBackend | str] = None,
          max_steps: Optional[int] = None,
          trace: int | bool = 0, telemetry=None,
          check_finite=None, checkpoint_every: int = 0,
          **kw) -> RunResult:
    """Run ``algorithm`` on ``g`` under a direction policy and an
    exchange backend.

    Args:
        g: the :class:`~repro.graphs.structure.Graph` to process.
        algorithm: a registered name — see :func:`algorithms`.
        policy: a :class:`~repro.core.direction.DirectionPolicy` instance
            or one of the string shorthands ``"push"``, ``"pull"``,
            ``"gs"`` (GenericSwitch), ``"grs"`` (GreedySwitch), ``"auto"``
            (cost-model-driven AutoSwitch). Default: the algorithm's
            declared default policy.
        backend: an :class:`ExchangeBackend` instance or one of the
            string shorthands ``"dense"``, ``"ell"``, ``"pallas"``
            (kernel-dispatching :class:`PallasBackend`); default
            :class:`DenseBackend`.
        max_steps: per-phase step bound override (bounds *epochs* for
            phase programs).
        trace: record a per-step
            :class:`~repro.core.cost_model.StepTrace` on the result —
            an int capacity, or True for a default of 256 slots.
        telemetry: a :class:`repro.obs.Telemetry` handle, or None
            (default). With a handle, the run emits structured events
            into it — per-step counter/prediction rows, a run summary,
            and a direction-decision audit — and single-phase solves
            route through the engine's host-driven stepwise loop so
            each step also carries measured wall time (set
            ``telemetry.step_timing = False`` to keep single-dispatch
            execution). ``None`` is the untouched fast path:
            bit-identical results, zero events, no obs import.
        check_finite: enable the divergence guard — ``"nan"``/True
            trips on NaN state, ``"all"`` additionally on ±Inf
            (BFS/SSSP carry legitimate Inf sentinels, so ``"all"`` is
            only for algorithms with finite state). Flat programs check
            after every step (via the stepwise loop) and raise a
            structured :class:`repro.resilience.DivergenceError` naming
            the step; phase programs check the final state.
        checkpoint_every: snapshot the loop carry every N steps (flat
            programs only); an interrupted or faulted solve resumes
            from the last checkpoint automatically — bounded resume
            budget, bit-identical result — instead of restarting from
            scratch. 0 (default) disables; the engine's fully-jitted
            ``run`` path is used and nothing changes.
        **kw: algorithm-specific kwargs (``root``, ``source``, ``iters``,
            ``damp``, ``tol``, ...).

    Example::

        r = api.solve(g, "bfs", root=0, policy="auto")
        r = api.solve(g, "pagerank", iters=30, backend="pallas")
        r = api.solve(g, "sssp_delta", source=0, delta=2.0, trace=128)

    Raises:
        KeyError: unknown algorithm name.
        ValueError: unknown policy or backend shorthand, a (policy ×
            backend) combination the algorithm declares unsupported, or
            a ``root``/``source`` vertex index outside ``[0, n)``.
    """
    spec = get_spec(algorithm)
    for vkey in _VERTEX_KEYS:
        if vkey in kw:
            validate_vertex_indices(g, vkey, kw[vkey])
    policy = (spec.default_policy if policy is None
              else _resolve_policy(policy))
    backend = _resolve_backend(backend, g)
    trace_capacity = (_DEFAULT_TRACE_CAPACITY if trace is True
                      else int(trace))
    if telemetry is not None and trace_capacity == 0:
        # telemetry needs the in-loop StepTrace rows to audit against
        trace_capacity = _DEFAULT_TRACE_CAPACITY
    static_kw = {k: v for k, v in kw.items() if k not in spec.runtime_keys}

    def build_engine() -> PushPullEngine:
        try:
            program, default_steps = spec.build(
                g, policy=policy, backend=backend, **static_kw)
        except (NotImplementedError, ValueError) as e:
            raise ValueError(
                f"algorithm {algorithm!r} does not support the "
                f"combination policy={policy.name} × "
                f"backend={backend.name}: {e}") from e
        return PushPullEngine(
            program=program, policy=policy,
            max_steps=default_steps if max_steps is None else max_steps,
            backend=backend, trace_capacity=trace_capacity)

    # key on the spec itself: re-registering a name invalidates cached
    # engines built from the old spec
    engine = _ENGINE_CACHE.get_or_build(
        (algorithm, spec, policy, backend,
         tuple(sorted(static_kw.items())),
         g.n, g.m, g.d_ell, max_steps, trace_capacity), build_engine)
    init_state, init_frontier = spec.init(g, **kw)
    if checkpoint_every and not engine.supports_stepwise:
        raise ValueError(
            f"checkpoint_every is supported for flat programs only; "
            f"{algorithm!r} is phase-structured (its epoch/phase loop "
            "runs fully jitted)")
    guards = bool(check_finite) or checkpoint_every > 0
    if telemetry is None:
        if guards and engine.supports_stepwise:
            res = _run_stepwise_resilient(
                engine, g, init_state, init_frontier,
                check_finite=check_finite,
                checkpoint_every=checkpoint_every)
        else:
            res = engine.run(g, init_state, init_frontier)
            if check_finite:
                # phase programs run fully jitted: the guard still
                # refuses to hand back poisoned state, at run end
                PushPullEngine._check_finite(res.state, check_finite,
                                             int(res.steps))
    else:
        res = _solve_observed(telemetry, engine, g, init_state,
                              init_frontier, algorithm=algorithm,
                              policy=policy, backend=backend,
                              check_finite=check_finite,
                              checkpoint_every=checkpoint_every)
    return RunResult(state=spec.finalize(g, res.state), cost=res.cost,
                     steps=res.steps, push_steps=res.push_steps,
                     converged=res.converged, epochs=res.epochs,
                     trace=res.trace)


def _run_stepwise_resilient(engine: PushPullEngine, g: Graph,
                            init_state, init_frontier, *, on_step=None,
                            check_finite=None, checkpoint_every: int = 0,
                            max_resumes: int = 4):
    """Stepwise execution with checkpoint-resume: a transient failure
    mid-loop (an injected ``engine.step`` fault, a flaky device) resumes
    from the last snapshot — or restarts, when the failure predates the
    first one; the replayed steps run the identical jitted body, so the
    result is bit-identical to an uninterrupted run. ``max_resumes``
    bounds *consecutive resumes without checkpoint progress*: a
    recoverable fault pattern can interrupt a long solve arbitrarily
    often as long as each resume advances the checkpoint, while a
    permanent failure (no progress between interrupts) re-raises the
    structured :class:`~repro.resilience.SolveInterrupted` after
    ``max_resumes`` stalled attempts (``__cause__`` carries the
    original error)."""
    from .resilience import SolveInterrupted, note
    ckpt = None
    stalled = 0
    while True:
        try:
            return engine.run_stepwise(
                g, init_state, init_frontier, on_step=on_step,
                check_finite=check_finite,
                checkpoint_every=checkpoint_every, resume_from=ckpt)
        except SolveInterrupted as e:
            progressed = e.checkpoint is not None and (
                ckpt is None or e.checkpoint.step > ckpt.step)
            stalled = 0 if progressed else stalled + 1
            if stalled > max_resumes:
                raise
            if e.checkpoint is not None:
                ckpt = e.checkpoint
            note("resume.engine.step", failed_step=e.step,
                 resume_from=(ckpt.step if ckpt is not None else 0),
                 stalled=stalled)


def _solve_observed(tel, engine: PushPullEngine, g: Graph, init_state,
                    init_frontier, *, algorithm: str,
                    policy: DirectionPolicy, backend: ExchangeBackend,
                    check_finite=None, checkpoint_every: int = 0):
    """The telemetry glue behind ``solve(..., telemetry=...)``.

    Runs the engine (stepwise + per-step host timing when the handle
    asks for it and the program is single-phase), then folds the result
    into the handle: step/run events via
    :func:`repro.obs.metrics.record_solve`, the tuner's probe counters,
    the resilience layer's fault/recovery counters and events, and a
    direction-decision ``audit`` event whenever the run produced
    auditable step rows.
    """
    from .obs.metrics import collect_resilience, collect_tuner, record_solve
    from .obs.report import decision_audit

    run = tel.new_run()
    step_times: dict[int, float] = {}
    t0 = tel.now_us()
    guards = bool(check_finite) or checkpoint_every > 0
    with tel.span(f"solve:{algorithm}", run=run, algorithm=algorithm,
                  policy=policy.name, backend=backend.name) as sp:
        if ((tel.step_timing or guards) and engine.supports_stepwise):
            res = _run_stepwise_resilient(
                engine, g, init_state, init_frontier,
                on_step=(lambda i, us: step_times.__setitem__(i, us))
                if tel.step_timing else None,
                check_finite=check_finite,
                checkpoint_every=checkpoint_every)
        else:
            res = engine.run(g, init_state, init_frontier)
            jax.block_until_ready(res.state)  # span times execution
            if check_finite:
                PushPullEngine._check_finite(res.state, check_finite,
                                             int(res.steps))
        sp["steps"] = int(res.steps)
    record_solve(tel, algorithm=algorithm, policy=policy,
                 backend=backend, result=res, run=run,
                 step_times=step_times or None, t0_us=t0)
    collect_tuner(tel)
    collect_resilience(tel)
    audit = decision_audit(tel.events_for(run, "step"), run=run)
    if audit is not None:
        tel.emit("audit", run=run, basis=audit["basis"],
                 audited_steps=audit["audited_steps"],
                 flagged=audit["flagged"],
                 mispredict_rate=audit["mispredict_rate"])
    return res


def solve_batch(g: Graph, algorithm: str, *, sources,
                policy: Optional[DirectionPolicy | str] = None,
                backend: Optional[ExchangeBackend | str] = None,
                max_steps: Optional[int] = None, telemetry=None, **kw):
    """Run one *batched* multi-query solve: B queries of ``algorithm``
    (one per entry of ``sources``) over one shared graph and backend.

    The batch rides as B payload columns through a single engine run —
    one jitted program, one graph scan per pull step, one union-frontier
    scatter per push step — so per-query results are bit-identical to a
    loop of single-source :func:`solve` calls while throughput scales
    with the batch width (see ``docs/architecture.md``, service layer).

    Only source-parameterized algorithms with a registered batched
    program support this path (``repro.service.batchable()``: BFS,
    Δ-stepping SSSP, personalized PageRank).

    Example::

        br = api.solve_batch(g, "bfs", sources=[0, 5, 9])
        br.states[1]["dist"]       # == solve(g, "bfs", root=5)["dist"]
        br.cost.weighted_total()   # whole-batch counter total

    Returns a :class:`repro.service.BatchResult`.

    Raises:
        KeyError: unknown algorithm, or one without a batched program.
        ValueError: empty ``sources``, a source index outside
            ``[0, n)``, or an unsupported (policy × backend) cell.
    """
    from .service.batch import solve_batch as _solve_batch
    return _solve_batch(g, algorithm, sources=sources, policy=policy,
                        backend=backend, max_steps=max_steps,
                        telemetry=telemetry, **kw)


# ---------------------------------------------------------------------
# Built-in registrations: all of the paper's workloads.
register(AlgorithmSpec(
    name="bfs", build=bfs_program, init=bfs_init,
    runtime_keys=("root",), paper="§3.3/§4.3 Alg. 3"))

register(AlgorithmSpec(
    name="pagerank", build=pagerank_program, init=pagerank_init,
    default_policy=Fixed(Direction.PULL), paper="§3.1/§4.1 Alg. 1"))

register(AlgorithmSpec(
    name="wcc", build=wcc_program, init=wcc_init,
    paper="§3.3 (label propagation)"))

register(AlgorithmSpec(
    name="ppr", build=ppr_program, init=ppr_init,
    finalize=ppr_finalize,
    default_policy=Fixed(Direction.PULL),
    runtime_keys=("source",),
    backends=("dense", "ell", "pallas", "shard"),
    paper="§3.1 (personalized variant; service-layer batching)"))

register(AlgorithmSpec(
    name="pr_delta", build=pr_delta_program, init=pr_delta_init,
    finalize=pr_delta_finalize,
    default_policy=Fixed(Direction.PUSH),
    paper="§3.1 (Whang [60])"))

register(AlgorithmSpec(
    name="sssp_delta", build=sssp_delta_program, init=sssp_delta_init,
    finalize=sssp_delta_finalize,
    default_policy=Fixed(Direction.PUSH),
    runtime_keys=("source",),
    backends=("dense", "ell", "pallas", "shard"),
    paper="§3.4/§4.4 Alg. 4"))

register(AlgorithmSpec(
    name="betweenness", build=betweenness_program, init=betweenness_init,
    finalize=betweenness_finalize,
    default_policy=Fixed(Direction.PULL),
    backends=("dense", "ell", "pallas"),
    paper="§3.5/§4.5 Alg. 5"))

register(AlgorithmSpec(
    name="coloring", build=coloring_program, init=coloring_init,
    finalize=coloring_finalize,
    default_policy=Fixed(Direction.PUSH),
    backends=("dense", "ell", "pallas"),
    paper="§3.6/§4.6 Alg. 6"))

register(AlgorithmSpec(
    name="mst_boruvka", build=mst_program, init=mst_init,
    finalize=mst_finalize,
    default_policy=Fixed(Direction.PULL),
    backends=("dense", "ell", "pallas"),
    paper="§3.7/§4.7 Alg. 7"))

register(AlgorithmSpec(
    name="triangle_count", build=triangle_program, init=triangle_init,
    finalize=triangle_finalize,
    default_policy=Fixed(Direction.PULL),
    backends=("dense", "ell", "pallas"),
    paper="§3.2/§4.2 Alg. 2"))
