"""Synthetic data pipelines with background prefetch.

Real clusters feed from sharded object stores; the substrate here provides
the same interface (iterator of device-ready batches, prefetched off the
critical path) over deterministic synthetic generators so every example
and benchmark is runnable offline.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["token_batches", "recsys_batches", "molecule_batches",
           "Prefetcher", "prefetch"]


def token_batches(batch: int, seq: int, vocab: int, seed: int = 0
                  ) -> Iterator[dict]:
    """Zipf-ish synthetic LM stream: markov-free but skewed unigram."""
    rng = np.random.default_rng(seed)
    probs = 1.0 / np.arange(1, vocab + 1) ** 1.1
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs)
        yield {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
               "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


def recsys_batches(batch: int, n_fields: int, vocab: int, seed: int = 0
                   ) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    while True:
        ids = rng.integers(0, vocab, size=(batch, n_fields), dtype=np.int32)
        # synthetic CTR: a planted linear rule over a few fields
        sig = (ids[:, 0] % 7 == 0) | (ids[:, 1] % 11 == 0)
        noise = rng.random(batch) < 0.1
        y = (sig ^ noise).astype(np.float32)
        yield {"ids": jnp.asarray(ids), "labels": jnp.asarray(y)}


def molecule_batches(batch: int, n_nodes: int, n_edges: int, d_feat: int,
                     seed: int = 0) -> Iterator[dict]:
    """Batched small graphs (the `molecule` shape): one disjoint union per
    batch with graph_ids for pooling."""
    rng = np.random.default_rng(seed)
    while True:
        srcs, dsts, gids = [], [], []
        for b in range(batch):
            s = rng.integers(0, n_nodes, n_edges // 2)
            d = rng.integers(0, n_nodes, n_edges // 2)
            off = b * n_nodes
            srcs += [s + off, d + off]
            dsts += [d + off, s + off]
            gids.append(np.full(n_nodes, b))
        feats = rng.normal(size=(batch * n_nodes, d_feat)).astype(np.float32)
        coords = rng.normal(size=(batch * n_nodes, 3)).astype(np.float32)
        y = rng.normal(size=(batch,)).astype(np.float32)
        yield {"src": jnp.asarray(np.concatenate(srcs), jnp.int32),
               "dst": jnp.asarray(np.concatenate(dsts), jnp.int32),
               "graph_ids": jnp.asarray(np.concatenate(gids), jnp.int32),
               "feats": jnp.asarray(feats), "coords": jnp.asarray(coords),
               "labels": jnp.asarray(y)}


class Prefetcher:
    """Background-thread prefetch with bounded queue (straggler shield:
    data hiccups don't stall the step as long as the buffer holds)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = False
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                if self._done:
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._done = True


def prefetch(it: Iterator, depth: int = 2) -> Prefetcher:
    return Prefetcher(it, depth)
