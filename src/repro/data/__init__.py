from .pipeline import (token_batches, recsys_batches, molecule_batches,
                       Prefetcher, prefetch)

__all__ = ["token_batches", "recsys_batches", "molecule_batches",
           "Prefetcher", "prefetch"]
