"""Batched multi-query programs for the source-parameterized algorithms.

One :class:`BatchSpec` per batchable algorithm. The batched program runs
on the *same* :class:`~repro.core.engine.PushPullEngine` as the single-
query one, with three conventions:

  * state leaves carry a trailing query axis — ``[n, B]`` per-vertex
    fields, ``[B]`` per-query scalars;
  * the engine-level frontier is the **union** of the per-query
    frontiers (``bool[n]``) — that is what push scatters from, what the
    k-filter compacts, and what :class:`~repro.core.cost_model.StepStats`
    prices (union-frontier degree sums, ``width=B`` payloads);
  * per-query activity is folded into the wire values: columns where a
    query is inactive carry the combine identity (BFS's ``>n`` parent
    sentinel under min, ``inf`` under the SSSP min-plus relaxation,
    ``0`` under PPR's sum), so a union-frontier exchange delivers
    exactly the messages each query's own frontier would have.

Because each column sees the same combine over the same edge order as
its single-source run — and converged queries are frozen, never
re-updated — per-query results are *bit-identical* to a loop of
``api.solve`` calls (covered by ``tests/test_service.py``).

Every spec also supplies the hooks continuous batching needs
(:mod:`~repro.service.scheduler`): a per-query ``done`` mask on top of
the engine loop, and ``admit`` to splice a fresh query into a retired
slot between engine chunks.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..core.backend import DenseBackend, EllBackend, require_backend
from ..core.engine import Phase, PhaseProgram, VertexProgram
from ..graphs.structure import Graph
from ..shard.backend import ShardedBackend

__all__ = ["BatchSpec", "register_batch", "batchable", "get_batch_spec"]


@dataclasses.dataclass(frozen=True)
class BatchSpec:
    """How an algorithm's batched program plugs into the service layer.

    build(g, batch, *, policy, backend, **static_kw) -> (program,
        default_max_steps): the batched Vertex-/PhaseProgram; must close
        over static graph attributes only, like the single-query build.
    init(g, sources, **kw) -> (state0, union_frontier0).
    done(g, state, frontier, **kw) -> bool[B]: per-query done mask
        (True once that query's result can no longer change).
    extract(g, state, i) -> the i-th query's *public* state pytree —
        the same keys and values ``api.solve`` would return for that
        single source.
    admit(g, state, frontier, slot, source, **kw) -> (state, frontier):
        splice a fresh query into column ``slot`` (continuous batching;
        the engine restarts from the returned carry, so epoch-structured
        programs re-establish their outer-loop alignment on resume).
    frontier_of(g, state) -> bool[n]: the union frontier to resume the
        engine from after a chunked run (the engine result carries only
        state, not its final frontier).
    runtime_keys: kwargs consumed only by ``init``/``admit`` — excluded
        from the engine cache key (sources always are).
    bound_unit: which EngineResult field counts against the program's
        default step bound — "steps" for flat programs, "epochs" for
        phase programs (whose ``max_steps``/default bound limits
        epochs). The scheduler charges the matching unit per chunk.
    """
    name: str
    build: Callable
    init: Callable
    done: Callable
    extract: Callable
    admit: Callable
    frontier_of: Callable
    runtime_keys: tuple = ()
    bound_unit: str = "steps"


_BATCH_REGISTRY: dict[str, BatchSpec] = {}


def register_batch(spec: BatchSpec) -> BatchSpec:
    _BATCH_REGISTRY[spec.name] = spec
    return spec


def batchable() -> list[str]:
    """Algorithm names accepted by ``api.solve_batch``."""
    return sorted(_BATCH_REGISTRY)


def get_batch_spec(name: str) -> BatchSpec:
    try:
        return _BATCH_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"algorithm {name!r} has no batched program; batchable: "
            f"{batchable()}") from None


def _sources_array(sources) -> jax.Array:
    src = jnp.asarray(sources, jnp.int32)
    if src.ndim != 1 or src.shape[0] == 0:
        raise ValueError(
            f"sources must be a non-empty 1-D sequence of vertex ids, "
            f"got shape {tuple(src.shape)}")
    return src


# ---------------------------------------------------------------------
# multi-source BFS
_UNREACHED = jnp.int32(2147483647)


def bfs_batch_program(g: Graph, batch: int, policy=None, backend=None
                      ) -> tuple[VertexProgram, int]:
    """Multi-source BFS: one parent-id column per source.

    Wire values are candidate parent ids per query (frontier vertices of
    query b advertise their id in column b, everyone else the ``>n``
    sentinel that min-combine ignores). The per-query ``level`` counter
    lives in the state (not the engine step), so a run resumed from a
    carried state — the scheduler's chunked continuous batching — keeps
    assigning correct distances.
    """
    # DistributedBackend charges width-blind counters, which would break
    # the batch-aware predictor's exactness — batching runs on the
    # dense/ELL layouts or the width-aware sharded backend
    require_backend("bfs (batched)", backend, DenseBackend, EllBackend,
                    ShardedBackend)
    n = g.n

    def values_fn(g_, state, frontier):
        ids = jnp.arange(g_.n, dtype=jnp.int32)[:, None]
        return jnp.where(state["qfront"], ids, jnp.int32(g_.n + 7))

    def touched_fn(g_, state, frontier, visited):
        # pull must inspect vertices unvisited by ANY query (the
        # engine's union-visited mask is too small: a vertex settled for
        # query 1 may still need a parent in query 2)
        return jnp.any(~state["visited"], axis=1)

    def update(state, msgs, step):
        visited = state["visited"]
        nxt = (~visited) & (msgs < n)
        level = state["level"] + 1                       # [B]
        new = {"dist": jnp.where(nxt, level[None, :], state["dist"]),
               "parent": jnp.where(nxt, msgs, state["parent"]),
               "visited": visited | nxt, "qfront": nxt,
               "level": level}
        return new, jnp.any(nxt, axis=1), ~jnp.any(nxt)

    prog = VertexProgram(combine="min", update_fn=update,
                         values_fn=values_fn, touched_fn=touched_fn,
                         k_filter_push=True)
    return prog, n + 1


def bfs_batch_init(g: Graph, sources, **_):
    src = _sources_array(sources)
    b = src.shape[0]
    cols = jnp.arange(b)
    qfront = jnp.zeros((g.n, b), bool).at[src, cols].set(True)
    state = {
        "dist": jnp.full((g.n, b), _UNREACHED, jnp.int32)
                   .at[src, cols].set(0),
        "parent": jnp.full((g.n, b), g.n, jnp.int32)
                     .at[src, cols].set(src),
        "visited": qfront, "qfront": qfront,
        "level": jnp.zeros((b,), jnp.int32),
    }
    return state, jnp.any(qfront, axis=1)


def bfs_batch_done(g: Graph, state, frontier, **_):
    return ~jnp.any(state["qfront"], axis=0)


def bfs_batch_extract(g: Graph, state, i: int):
    return {"dist": state["dist"][:, i], "parent": state["parent"][:, i],
            "visited": state["visited"][:, i]}


def bfs_batch_admit(g: Graph, state, frontier, slot: int, source, **_):
    source = jnp.asarray(source, jnp.int32)
    state = {
        "dist": state["dist"].at[:, slot].set(_UNREACHED)
                             .at[source, slot].set(0),
        "parent": state["parent"].at[:, slot].set(g.n)
                                 .at[source, slot].set(source),
        "visited": state["visited"].at[:, slot].set(False)
                                   .at[source, slot].set(True),
        "qfront": state["qfront"].at[:, slot].set(False)
                                 .at[source, slot].set(True),
        "level": state["level"].at[slot].set(0),
    }
    return state, jnp.any(state["qfront"], axis=1)


register_batch(BatchSpec(
    name="bfs", build=bfs_batch_program, init=bfs_batch_init,
    done=bfs_batch_done, extract=bfs_batch_extract,
    admit=bfs_batch_admit,
    frontier_of=lambda g, state: jnp.any(state["qfront"], axis=1)))


# ---------------------------------------------------------------------
# personalized PageRank (multiple personalization vectors)
def ppr_batch_program(g: Graph, batch: int, iters: int = 100,
                      damp: float = 0.85, tol: float = 1e-6,
                      policy=None, backend=None
                      ) -> tuple[VertexProgram, int]:
    """B personalized power iterations sharing one graph scan per step.

    Converged columns are frozen — their rank stops updating the moment
    their residual drops below ``tol``, exactly where the single-query
    run stops — so batched results stay bit-identical even though the
    engine keeps stepping until every query converges.
    """
    require_backend("ppr (batched)", backend, DenseBackend, EllBackend,
                    ShardedBackend)
    n = g.n
    damp = float(damp)
    tol = float(tol)

    def values_fn(g_, state, frontier):
        deg = jnp.maximum(g_.out_deg, 1).astype(jnp.float32)[:, None]
        return state["rank"] / deg

    def update(state, msgs, step):
        active = state["resid"] >= tol                   # [B]
        rank = jnp.where(active[None, :],
                         state["base"] + jnp.float32(damp) * msgs,
                         state["rank"])
        resid = jnp.where(active,
                          jnp.max(jnp.abs(rank - state["rank"]), axis=0),
                          state["resid"])
        new = {"rank": rank, "base": state["base"], "resid": resid}
        return new, jnp.ones((n,), bool), jnp.all(resid < tol)

    prog = VertexProgram(combine="sum", update_fn=update,
                         values_fn=values_fn,
                         step_charges=(("reads", 2 * n * batch),))
    return prog, iters


def ppr_batch_init(g: Graph, sources, damp: float = 0.85, **_):
    src = _sources_array(sources)
    b = src.shape[0]
    base = jnp.zeros((g.n, b), jnp.float32).at[src, jnp.arange(b)].set(
        jnp.float32(1.0 - damp))
    state = {"rank": base, "base": base,
             "resid": jnp.full((b,), jnp.inf, jnp.float32)}
    return state, jnp.ones((g.n,), bool)


def ppr_batch_done(g: Graph, state, frontier, tol: float = 1e-6, **_):
    # mirrors the program's per-column freeze threshold
    return state["resid"] < float(tol)


def ppr_batch_extract(g: Graph, state, i: int):
    return {"ranks": state["rank"][:, i], "residual": state["resid"][i]}


def ppr_batch_admit(g: Graph, state, frontier, slot: int, source,
                    damp: float = 0.85, **_):
    source = jnp.asarray(source, jnp.int32)
    base = (state["base"].at[:, slot].set(0.0)
                         .at[source, slot].set(jnp.float32(1.0 - damp)))
    state = {"rank": state["rank"].at[:, slot].set(base[:, slot]),
             "base": base,
             "resid": state["resid"].at[slot].set(jnp.inf)}
    return state, jnp.ones((g.n,), bool)


register_batch(BatchSpec(
    name="ppr", build=ppr_batch_program, init=ppr_batch_init,
    done=ppr_batch_done, extract=ppr_batch_extract,
    admit=ppr_batch_admit,
    frontier_of=lambda g, state: jnp.ones((g.n,), bool)))


# ---------------------------------------------------------------------
# multi-source Δ-stepping SSSP
_INF = jnp.float32(jnp.inf)


def _in_bucket(d: jax.Array, lo, delta: float) -> jax.Array:
    return jnp.isfinite(d) & (d >= lo) & (d < lo + jnp.float32(delta))


def sssp_batch_program(g: Graph, batch: int, delta: float = 2.0,
                       max_inner: int = 64, max_epochs: int = 1 << 14,
                       policy=None, backend=None
                       ) -> tuple[PhaseProgram, int]:
    """Multi-source Δ-stepping: bucket epochs advance in lockstep across
    queries (each epoch settles one ``[lo, lo+Δ)`` bucket for every
    column at once).

    The bucket cursor is *state-derived*, not epoch-derived: each epoch
    jumps to the bucket of the smallest distance at or beyond the
    settled boundary ``hi``, skipping empty buckets entirely. That makes
    every epoch productive, so a run resumed from a carried state (the
    scheduler's chunked continuous batching) continues where it stopped
    instead of re-walking settled buckets — and columns whose bucket is
    empty contribute identity values (∞ under min) and are masked
    settled, so lockstep epochs change nothing versus each query's own
    bucket sequence. ``hi`` is per-column, so admitting a fresh query
    (which must start at bucket zero) re-walks only the newcomer's
    buckets; incumbents' settled vertices stay outside their ``qfront``
    and contribute no exchange work.
    """
    require_backend("sssp_delta", backend, DenseBackend, EllBackend,
                    ShardedBackend)
    delta = float(delta)

    def _guard(state):
        # per-column unsettled threshold: at least the current bucket,
        # and never below the column's own settled boundary
        return jnp.maximum(state["lo"], state["hi"])[None, :]

    def enter(g_, state, frontier, epoch):
        d = state["dist"]
        hi = state["hi"]                                 # [B]
        cand = jnp.where(jnp.isfinite(d) & (d >= hi[None, :]), d, _INF)
        mn = jnp.min(cand)
        lo = jnp.float32(delta) * jnp.floor(mn / jnp.float32(delta))
        qf = _in_bucket(d, lo, delta) & (d >= hi[None, :])
        state = {"dist": d, "lo": lo, "hi": hi, "qfront": qf}
        return state, jnp.any(qf, axis=1)

    def exit_fn(g_, state, frontier, cost):
        # this bucket is settled for every column at or behind it (no
        # column holds unsettled vertices below lo — lo is the bucket
        # of the global minimum candidate)
        hi = jnp.maximum(state["hi"],
                         state["lo"] + jnp.float32(delta))
        state = dict(state, hi=hi)
        return state, frontier, cost

    def values_fn(g_, state, frontier):
        return jnp.where(state["qfront"], state["dist"], _INF)

    def touched_fn(g_, state, frontier, visited):
        return jnp.any(state["dist"] >= _guard(state), axis=1)

    def msg(x, w):
        if x.ndim > w.ndim:            # dense paths: w is [m], x [m, B]
            w = w[..., None]
        return x + w

    def update(state, msgs, step):
        d = state["dist"]
        # per-query settled guard: a column settled below this bucket
        # never re-relaxes (single-source pull masks it via `touched`)
        unsettled = d >= _guard(state)
        d_new = jnp.where(unsettled, jnp.minimum(d, msgs), d)
        changed = d_new < d
        qf = _in_bucket(d_new, state["lo"], delta) & unsettled
        new = dict(state, dist=d_new, qfront=qf)
        return new, jnp.any(qf, axis=1), ~jnp.any(changed)

    def epoch_cond(g_, state, epoch):
        d = state["dist"]
        return jnp.any(jnp.isfinite(d) & (d >= state["hi"][None, :]))

    prog = VertexProgram(combine="min", msg_fn=msg, update_fn=update,
                         values_fn=values_fn, touched_fn=touched_fn,
                         k_filter_push=True,
                         k_filter_set_fn=lambda old, new, f:
                             jnp.any(new["dist"] < old["dist"], axis=1))
    pp = PhaseProgram(phases=(Phase(program=prog, max_steps=max_inner,
                                    name="relax", enter_fn=enter,
                                    exit_fn=exit_fn),),
                      epoch_cond=epoch_cond)
    return pp, max_epochs


def sssp_batch_init(g: Graph, sources, **_):
    src = _sources_array(sources)
    b = src.shape[0]
    d0 = jnp.full((g.n, b), _INF, jnp.float32).at[src, jnp.arange(b)].set(
        0.0)
    state = {"dist": d0, "lo": jnp.float32(0.0),
             "hi": jnp.zeros((b,), jnp.float32),
             "qfront": jnp.zeros((g.n, b), bool)}
    # the phase's enter_fn recomputes the bucket frontiers every epoch
    return state, jnp.zeros((g.n,), bool)


def sssp_batch_done(g: Graph, state, frontier, **_):
    # a query is done once nothing lies at or beyond its own settled
    # boundary — the per-column slice of the program's epoch_cond
    d = state["dist"]
    return ~jnp.any(jnp.isfinite(d) & (d >= state["hi"][None, :]),
                    axis=0)


def sssp_batch_extract(g: Graph, state, i: int):
    return {"dist": state["dist"][:, i]}


def sssp_batch_admit(g: Graph, state, frontier, slot: int, source, **_):
    source = jnp.asarray(source, jnp.int32)
    dist = state["dist"].at[:, slot].set(_INF).at[source, slot].set(0.0)
    # only the newcomer's settled boundary drops back to bucket zero;
    # incumbents keep theirs, so the bucket cursor revisits the low
    # range for the new column alone (incumbents' settled vertices stay
    # outside qfront and contribute no exchange work)
    state = {"dist": dist, "lo": jnp.float32(0.0),
             "hi": state["hi"].at[slot].set(0.0),
             "qfront": state["qfront"].at[:, slot].set(False)}
    return state, jnp.any(state["qfront"], axis=1)


register_batch(BatchSpec(
    name="sssp_delta", build=sssp_batch_program, init=sssp_batch_init,
    done=sssp_batch_done, extract=sssp_batch_extract,
    admit=sssp_batch_admit,
    # the relax phase's enter_fn rebuilds bucket frontiers every epoch
    frontier_of=lambda g, state: jnp.zeros((g.n,), bool),
    bound_unit="epochs"))
