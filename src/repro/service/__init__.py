"""repro.service — batched multi-query graph serving.

The serving layer on top of ``repro.api``: many concurrent queries of
the *source-parameterized* algorithms (BFS, Δ-stepping SSSP,
personalized PageRank) ride as payload columns through one shared
engine run, with batch-aware push/pull switching and a
continuous-batching scheduler.

Three layers:

  * :mod:`~repro.service.programs` — batched ``VertexProgram`` /
    ``PhaseProgram`` builders (:class:`BatchSpec` registry): state
    leaves carry a trailing query axis ``[n, B]``, the engine-level
    frontier is the *union* of the per-query frontiers, and per-query
    activity is folded into the wire values (inactive columns carry the
    combine identity), so per-query results are bit-identical to
    single-source ``api.solve`` runs.
  * :mod:`~repro.service.batch` — ``solve_batch`` (also surfaced as
    ``api.solve_batch``): one batched engine run, per-query result
    slicing, per-query done masks.
  * :mod:`~repro.service.scheduler` — :class:`QueryService`:
    submit/poll over fixed query slots refilled as queries converge
    (continuous batching at chunk granularity), request grouping by
    (algorithm, policy, backend, static params), in-flight coalescing,
    and an LRU :class:`ResultCache` keyed by (graph fingerprint,
    algorithm, source, params).

Throughput/latency measurement: ``python -m repro.service.bench``.
"""

from .batch import BatchResult, solve_batch
from .cache import ResultCache, graph_fingerprint
from .programs import (BatchSpec, batchable, get_batch_spec,
                       register_batch)
from .scheduler import QueryService

__all__ = ["solve_batch", "BatchResult", "BatchSpec", "register_batch",
           "batchable", "get_batch_spec", "QueryService", "ResultCache",
           "graph_fingerprint"]
