"""Result caching for the serving layer.

``graph_fingerprint`` gives a cheap stable identity for a
:class:`~repro.graphs.structure.Graph` (shape + edge checksum, computed
once per live graph object), so cache keys survive across
``QueryService`` instances and distinguish different graphs of one
shape. ``ResultCache`` is a plain LRU keyed by
``(fingerprint, algorithm, source, params, policy, backend)`` with
hit/miss counters — the serving loop consults it before admitting a
query into a slot.
"""

from __future__ import annotations

import weakref
import zlib
from collections import OrderedDict
from typing import Any, Hashable, Optional

import numpy as np

__all__ = ["graph_fingerprint", "ResultCache"]

# id -> fingerprint; entries evicted by weakref.finalize when the graph
# object dies, so a recycled id can never alias a stale fingerprint
_FP_BY_ID: dict[int, tuple] = {}


def graph_fingerprint(g) -> tuple:
    """A hashable identity for ``g``: (n, m, d_ell, edge checksum,
    weight checksum). Computed once per live graph object."""
    key = id(g)
    fp = _FP_BY_ID.get(key)
    if fp is None:
        # position-sensitive checksums: permuting edges or weights (or
        # swapping two weights) changes the fingerprint, so a shared
        # ResultCache can never serve one graph's results for another
        src = np.ascontiguousarray(g.coo_src, np.int32)
        dst = np.ascontiguousarray(g.coo_dst, np.int32)
        w = np.ascontiguousarray(g.coo_w, np.float32)
        edges = zlib.crc32(dst.tobytes(), zlib.crc32(src.tobytes()))
        weights = zlib.crc32(w.tobytes())
        fp = (int(g.n), int(g.m), int(g.d_ell), edges, weights)
        _FP_BY_ID[key] = fp
        weakref.finalize(g, _FP_BY_ID.pop, key, None)
    return fp


class ResultCache:
    """Bounded LRU of finished query results.

    Keys are whatever hashable tuple the caller builds (the scheduler
    uses (graph fingerprint, algorithm, source, static params, policy,
    backend)). ``get`` refreshes recency; ``put`` evicts the least
    recently used entry past ``capacity``.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._data: OrderedDict[Hashable, Any] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data

    def get(self, key) -> Optional[Any]:
        if key in self._data:
            self._data.move_to_end(key)
            self.hits += 1
            return self._data[key]
        self.misses += 1
        return None

    def put(self, key, value) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)

    def stats(self) -> dict:
        return {"size": len(self._data), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses}
