"""Service throughput/latency harness — queries/sec vs batch width vs
policy.

For each (algorithm, direction policy, batch width) cell on an RMAT
graph, measures the sequential baseline (a loop of single-source
``api.solve`` calls) against ``api.solve_batch`` over the same sources,
and reports queries/sec for both plus the batched run's weighted
counter total (the scalar the batch-aware AutoSwitch minimizes). Rows
are named ``service_*`` and validate against
``benchmarks/schema.json``'s ``service_cell`` shape — the same contract
the ``benchmarks.run`` suite (``--only service_throughput``) and CI's
smoke step enforce.

    PYTHONPATH=src python -m repro.service.bench [--smoke] [--json PATH]

The committed ``docs/results.md`` snapshot includes the smoke sweep's
service table (``benchmarks.run --only pushpull_matrix,service_throughput
--smoke --markdown docs/results.md``).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

__all__ = ["sweep", "main"]

ALGORITHMS = {
    "bfs": {},
    "ppr": {"tol": 1e-6},
    "sssp_delta": {"delta": 2.0},
}
POLICIES = ("push", "pull", "auto")


def _timeit(fn, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock microseconds of ``fn()`` (blocks on result).

    Defers to ``benchmarks.common.timeit`` when the benchmarks package
    is importable (running from the repo root), so service_* rows are
    timed exactly like the pushpull_* rows in the same report; the
    fallback mirrors it for standalone installs.
    """
    try:
        from benchmarks.common import timeit
    except ImportError:
        pass
    else:
        return timeit(fn, warmup=warmup, iters=iters)
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


def _graph(smoke: bool):
    from repro.graphs import kronecker
    scale = 7 if smoke else 10
    return "rmat", kronecker(scale, edge_factor=8, seed=7, weighted=True)


def _sources(g, width: int) -> list[int]:
    """Distinct query vertices, highest out-degree first (hubs reach the
    bulk of the graph, so every query does real work)."""
    order = np.argsort(-np.asarray(g.out_deg), kind="stable")
    return [int(order[i % g.n]) for i in range(width)]


def sweep(smoke: bool = False, widths=None):
    """Yield ``(name, us_per_call, payload)`` service throughput rows.

    ``us_per_call`` is the batched run's wall time (one call serves the
    whole batch); the payload carries both sides of the comparison.
    """
    from repro import api

    gname, g = _graph(smoke)
    if widths is None:
        widths = (2, 8) if smoke else (1, 2, 4, 8, 16)
    for alg, kw in ALGORITHMS.items():
        keys = api.get_spec(alg).runtime_keys
        src_kw = keys[0] if keys else "source"
        for policy in POLICIES:
            for width in widths:
                sources = _sources(g, width)

                last = {}

                def seq():
                    out = []
                    for s in sources:
                        r = api.solve(g, alg, policy=policy,
                                      **{src_kw: s}, **kw)
                        out.append(r.cost.reads)
                    return out

                def bat():
                    r = api.solve_batch(g, alg, sources=sources,
                                        policy=policy, **kw)
                    last["r"] = r       # reused for the counter payload
                    return r.cost.reads

                us_seq = _timeit(seq)
                us_bat = _timeit(bat)
                r = last["r"]
                payload = {
                    "algorithm": alg, "graph": gname,
                    "n": int(g.n), "m": int(g.m),
                    "policy": policy, "backend": "dense",
                    "batch": width, "queries": width,
                    "us_per_query_batched": round(us_bat / width, 1),
                    "us_per_query_sequential": round(us_seq / width, 1),
                    "qps_batched": round(width / (us_bat * 1e-6), 1),
                    "qps_sequential": round(width / (us_seq * 1e-6), 1),
                    "speedup": round(us_seq / us_bat, 3),
                    "steps": int(r.steps),
                    "push_steps": int(r.push_steps),
                    "weighted_total": float(r.cost.weighted_total()),
                }
                yield (f"service_{alg}_{gname}_{policy}_b{width}",
                       us_bat, payload)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="service layer throughput/latency harness")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized graph and width set")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as a schema-conformant JSON report")
    args = ap.parse_args(argv)

    rows = []
    for name, us, payload in sweep(smoke=args.smoke):
        print(f"{name},{us:.1f},{json.dumps(payload)}", flush=True)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": payload})
    report = {"rows": rows, "failures": []}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"json report: {args.json} ({len(rows)} rows)", flush=True)
        try:
            from benchmarks.validate import validate_report
        except ImportError:
            print("benchmarks.validate not importable; skipping schema "
                  "check", flush=True)
        else:
            validate_report(report)
            print("schema ok: benchmarks/schema.json", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
