"""solve_batch — one engine run answering B queries.

The batched path shares everything a loop of single-source ``solve``
calls would duplicate: the graph layouts, the jitted engine program,
every pull step's full-row scan (one scan, B payload columns — the
amortization the batch-aware cost model prices), and the direction
decision itself (one :class:`~repro.core.cost_model.StepStats` per step,
computed on the union frontier with ``width=B``, so a switching policy
decides *once per step for the whole batch*).

Engines are cached per (algorithm, batch width, policy, backend, static
kwargs, graph shape) exactly like ``api.solve``'s cache, so a serving
process pays tracing once per shape.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax

from .. import api
from ..core.backend import ExchangeBackend
from ..core.cost_model import Cost
from ..core.direction import DirectionPolicy
from ..core.engine import PushPullEngine
from ..graphs.structure import Graph
from .programs import BatchSpec, _sources_array, get_batch_spec

__all__ = ["BatchResult", "solve_batch", "run_chunk"]


class BatchResult(NamedTuple):
    """Result of one batched multi-query run.

    Attributes:
        states: per-query public state pytrees, ``states[i]`` identical
            to ``api.solve(g, algorithm, source=sources[i], ...).state``.
        state: the raw batched state (leaves carry the query axis) —
            what the scheduler resumes from.
        cost: whole-batch accumulated counters (one union-frontier
            scatter / one B-wide scan per step — *not* the sum of B
            single-query costs).
        done: ``bool[B]`` per-query completion mask.
        converged / steps / push_steps / epochs: engine-level, shared
            across the batch (queries step in lockstep).
    """
    states: list
    state: Any
    cost: Cost
    steps: jax.Array
    push_steps: jax.Array
    converged: jax.Array
    epochs: jax.Array
    done: jax.Array
    batch: int


_ENGINE_CACHE = api.EngineCache()


def _engine_for(g: Graph, algorithm: str, bspec: BatchSpec, batch: int,
                policy: DirectionPolicy, backend: ExchangeBackend,
                max_steps: Optional[int], static_kw: dict,
                trace_capacity: int = 0) -> PushPullEngine:
    def build_engine() -> PushPullEngine:
        try:
            program, default_steps = bspec.build(
                g, batch, policy=policy, backend=backend, **static_kw)
        except (NotImplementedError, ValueError) as e:
            raise ValueError(
                f"algorithm {algorithm!r} does not support the batched "
                f"combination policy={policy.name} × "
                f"backend={backend.name}: {e}") from e
        return PushPullEngine(
            program=program, policy=policy,
            max_steps=default_steps if max_steps is None else max_steps,
            backend=backend, trace_capacity=trace_capacity)

    return _ENGINE_CACHE.get_or_build(
        (algorithm, bspec, batch, policy, backend,
         tuple(sorted(static_kw.items())),
         g.n, g.m, g.d_ell, max_steps, trace_capacity), build_engine)


def _resolve(g: Graph, algorithm: str, sources, policy, backend, kw):
    spec = api.get_spec(algorithm)          # KeyError on unknown name
    bspec = get_batch_spec(algorithm)
    if sources is not None:
        api.validate_vertex_indices(g, "sources", sources)
    policy = (spec.default_policy if policy is None
              else api._resolve_policy(policy))
    backend = api._resolve_backend(backend, g)
    static_kw = {k: v for k, v in kw.items()
                 if k not in bspec.runtime_keys}
    return bspec, policy, backend, static_kw


def run_chunk(g: Graph, algorithm: str, batch: int, *, state, frontier,
              policy=None, backend=None, max_steps: Optional[int] = None,
              **kw):
    """One (possibly partial) batched engine run from a carried state —
    the scheduler's chunk primitive. Returns the raw ``EngineResult``
    plus the per-query done mask."""
    bspec, policy, backend, static_kw = _resolve(
        g, algorithm, None, policy, backend, kw)
    engine = _engine_for(g, algorithm, bspec, batch, policy, backend,
                         max_steps, static_kw)
    res = engine.run(g, state, frontier)
    done = bspec.done(g, res.state, None, **kw)
    return res, done


def default_step_bound(g: Graph, algorithm: str, batch: int, *,
                       policy=None, backend=None, **kw) -> int:
    """The step (epoch, for phase programs) bound an unchunked run of
    this batched program would get — what a bounded ``solve`` call with
    the same params enforces (e.g. PPR's ``iters``). The scheduler
    applies it across chunks so continuous batching honors the same
    budget."""
    bspec, policy, backend, static_kw = _resolve(
        g, algorithm, None, policy, backend, kw)
    _, default_steps = bspec.build(g, batch, policy=policy,
                                   backend=backend, **static_kw)
    return int(default_steps)


def solve_batch(g: Graph, algorithm: str, *, sources,
                policy=None, backend=None,
                max_steps: Optional[int] = None, telemetry=None,
                **kw) -> BatchResult:
    """Batched multi-query solve — see :func:`repro.api.solve_batch`
    for the public contract and examples."""
    batch = int(_sources_array(sources).shape[0])
    bspec, policy, backend, static_kw = _resolve(
        g, algorithm, sources, policy, backend, kw)
    tcap = api._DEFAULT_TRACE_CAPACITY if telemetry is not None else 0
    engine = _engine_for(g, algorithm, bspec, batch, policy, backend,
                         max_steps, static_kw, trace_capacity=tcap)
    state0, frontier0 = bspec.init(g, sources, **kw)
    if telemetry is None:
        res = engine.run(g, state0, frontier0)
    else:
        res = api._solve_observed(telemetry, engine, g, state0,
                                  frontier0, algorithm=algorithm,
                                  policy=policy, backend=backend)
    done = bspec.done(g, res.state, None, **kw)
    states = [bspec.extract(g, res.state, i) for i in range(batch)]
    return BatchResult(states=states, state=res.state, cost=res.cost,
                       steps=res.steps, push_steps=res.push_steps,
                       converged=res.converged, epochs=res.epochs,
                       done=done, batch=batch)
