"""QueryService — continuous batching over fixed query slots.

The serving loop for graph queries, mirroring the step-granular
slot-refill shape of classic LM serving loops: a fixed budget of B
query *slots*, one batched engine run per compatible request group, and
between engine *chunks* every converged query retires and frees its
slot for the next queued request (``BatchSpec.admit`` splices the
newcomer's column into the carried state; the engine resumes from the
rewritten carry).

Requests are grouped by (algorithm, policy, backend, static params) —
only queries that can share one engine program batch together. Results
land in an LRU :class:`~repro.service.cache.ResultCache` keyed by
(graph fingerprint, algorithm, source, params, policy, backend);
repeated submissions hit the cache without touching the engine, and
identical *in-flight* requests coalesce onto one slot.

    svc = QueryService(g, slots=8)
    rids = [svc.submit("bfs", source=s) for s in range(16)]
    svc.submit("ppr", source=3)
    svc.run_until_complete()
    svc.poll(rids[0])["dist"]          # == api.solve(g,"bfs",root=0)...
    svc.stats()["cache"]["hits"]

Algorithms without a batched program (``repro.service.batchable()``)
still flow through the same submit/poll surface — each runs as a
single ``api.solve`` when its group is scheduled, and its results cache
the same way.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Optional

import numpy as np

from .. import api
from ..graphs.structure import Graph
from ..resilience import (AdmissionError, DeadlineExceeded, FaultInjected,
                          fault_point, note)
from .batch import default_step_bound, run_chunk
from .cache import ResultCache, graph_fingerprint
from .programs import get_batch_spec, batchable

__all__ = ["QueryService", "QueryRecord"]


def _source_kwarg(algorithm: str) -> str:
    """The kwarg naming the query vertex — the spec's runtime key
    (``root`` for BFS, ``source`` for SSSP/PPR)."""
    keys = api.get_spec(algorithm).runtime_keys
    return keys[0] if keys else "source"


@dataclasses.dataclass
class QueryRecord:
    """One submitted query and, once served, its result."""
    rid: int
    algorithm: str
    source: Optional[int]
    params: tuple
    state: Any = None          # public state pytree once done
    cached: bool = False       # served straight from the result cache
    converged: bool = True     # False when force-retired (best effort)
    error: Optional[Exception] = None   # the failure, if serving failed
    deadline_ms: Optional[float] = None  # wall budget from submit time
    submitted_at: float = 0.0  # clock() at submit (deadline anchor)

    @property
    def done(self) -> bool:
        return self.state is not None or self.error is not None


@dataclasses.dataclass
class _Active:
    """The engine-side carry of the group currently occupying slots."""
    group: tuple
    algorithm: str
    policy: Any
    backend: Any
    params: dict
    width: int
    state: Any
    frontier: Any
    slot_rids: list            # per column: (rid, cache key) or None
    slot_chunks: list          # per column: chunks spent on this query
    step_bound: int            # the unchunked run's step/epoch budget
    total_steps: int = 0       # engine steps consumed by this batch
    slot_steps0: list = dataclasses.field(default_factory=list)
    # per column: total_steps when the query entered its slot


class QueryService:
    """Batched multi-query serving over one graph.

    Args:
        g: the graph every query runs against.
        slots: query slots per batched engine run (the fixed batch
            width the scheduler refills).
        chunk_steps: engine steps (epochs for phase programs) per chunk
            between slot-refill opportunities.
        max_chunks_per_query: chunk budget per query; a query still not
            done after ``max_chunks_per_query * chunk_steps`` engine
            steps is force-retired with its best-effort state (the
            analogue of a bounded single-source solve returning with
            ``converged=False`` — the record says ``converged=False``
            and the state is NOT cached), so a non-converging query can
            never wedge the serving loop.
        max_records: bound on retained finished query records; the
            oldest *done* records (and their result pytrees) are
            evicted past it, so a long-lived serving process does not
            grow without bound. Evicted rids can no longer be polled.
        cache: a :class:`ResultCache`, or None for a fresh 256-entry
            one.
        max_queue: bound on total *queued* (not yet slotted) requests;
            a ``submit`` that would push past it raises
            :class:`~repro.resilience.AdmissionError` without consuming
            a request id. Cache hits and coalesced duplicates are
            always admitted (they add no engine work). None (default)
            means unbounded.
        max_chunk_retries: transient-failure retries per chunk (and per
            unbatchable solve). The retry wraps the
            ``service.chunk`` fault site, so an injected transient
            fault is recovered in place; deterministic errors (bad
            cell, bad kwargs) are never retried.
        clock: monotonic-seconds callable for deadline accounting —
            injectable so tests drive expiry without sleeping.
        telemetry: a :class:`repro.obs.Telemetry` handle, or None. With
            a handle the scheduler emits ``service.*`` events (submit
            outcomes, batch starts, chunk spans, force-retires), serves
            unbatchable queries with the same handle (so they carry
            run/step events), and folds its :meth:`stats` plus the
            process-wide ``resilience.*`` counters into the handle's
            counters every time a batch drains.
    """

    def __init__(self, g: Graph, *, slots: int = 8,
                 chunk_steps: int = 32,
                 max_chunks_per_query: int = 256,
                 max_records: int = 4096,
                 cache: Optional[ResultCache] = None,
                 max_queue: Optional[int] = None,
                 max_chunk_retries: int = 2,
                 clock=time.monotonic,
                 telemetry=None):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.telemetry = telemetry
        self.g = g
        self.slots = slots
        self.chunk_steps = chunk_steps
        self.max_chunks_per_query = max_chunks_per_query
        self.max_records = max_records
        self.max_queue = max_queue
        self.max_chunk_retries = max_chunk_retries
        self.cache = cache if cache is not None else ResultCache()
        self._clock = clock
        self._fp = graph_fingerprint(g)
        self._next_rid = 0
        self._records: dict[int, QueryRecord] = {}
        self._pending = 0
        # group key (algorithm, policy, backend, params) -> FIFO of
        # (rid, cache key, source, params); drained queues are deleted,
        # so long-lived services do not accumulate dead deques
        self._queues: dict[tuple, deque] = {}
        self._inflight: dict[tuple, list[int]] = {}  # cache key -> rids
        self._active: Optional[_Active] = None
        self.coalesced = 0
        self.batches_started = 0
        self.chunks_run = 0
        self.force_retired = 0
        self.chunk_retries = 0
        self.deadline_expired = 0
        self.admission_rejected = 0
        self.cache_errors = 0
        self._failures: deque = deque(maxlen=64)

    def _emit(self, name: str, **fields) -> None:
        if self.telemetry is not None:
            self.telemetry.emit("event", name, **fields)

    # -- submission ------------------------------------------------------
    def submit(self, algorithm: str, source: Optional[int] = None, *,
               policy=None, backend=None,
               deadline_ms: Optional[float] = None, **params) -> int:
        """Enqueue one query; returns a request id for :meth:`poll`.

        ``source`` is the query vertex for source-parameterized
        algorithms (mapped to ``root`` for BFS); global algorithms
        (wcc, pagerank, ...) take ``source=None``. ``deadline_ms``
        bounds the query's wall time from submission: a query still
        queued (or mid-batch) past its deadline is failed with
        :class:`~repro.resilience.DeadlineExceeded` instead of served
        stale. Extra ``params`` are the algorithm's kwargs (``delta``,
        ``damp``, ``iters``, ...).

        Raises :class:`~repro.resilience.AdmissionError` (consuming no
        request id) when ``max_queue`` is set and the backlog is full.
        """
        api.get_spec(algorithm)                      # KeyError if unknown
        if isinstance(policy, str):
            api._resolve_policy(policy)   # bad shorthand fails at submit
        if source is not None:
            api.validate_vertex_indices(self.g, "source", source)
            source = int(source)
        elif algorithm in batchable():
            raise ValueError(
                f"{algorithm!r} is source-parameterized: submit() "
                f"requires a source vertex (0..{self.g.n - 1})")
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be > 0, got {deadline_ms}")
        pkey = tuple(sorted(params.items()))
        ckey = self._cache_key(algorithm, source, pkey, policy, backend)
        hit = self._cache_lookup(ckey)
        coalesce = hit is None and ckey in self._inflight
        if hit is None and not coalesce and self.max_queue is not None:
            queued = sum(len(q) for q in self._queues.values())
            if queued >= self.max_queue:
                self.admission_rejected += 1
                note("admission.service.reject", queued=queued,
                     algorithm=algorithm)
                self._emit("service.admission_reject",
                           algorithm=algorithm, queued=queued)
                raise AdmissionError(queued, self.max_queue)
        rid = self._next_rid
        self._next_rid += 1
        rec = QueryRecord(rid=rid, algorithm=algorithm, source=source,
                          params=pkey, deadline_ms=deadline_ms,
                          submitted_at=self._clock())
        self._records[rid] = rec
        if hit is not None:
            rec.state, rec.converged = hit
            rec.cached = True
            self._emit("service.cache_hit", rid=rid, algorithm=algorithm)
            return rid
        if coalesce:                                 # coalesce duplicates
            self._inflight[ckey].append(rid)
            self.coalesced += 1
            self._pending += 1
            self._emit("service.coalesce", rid=rid, algorithm=algorithm)
            return rid
        self._inflight[ckey] = [rid]
        self._pending += 1
        gkey = (algorithm, policy, backend, rec.params)
        self._queues.setdefault(gkey, deque()).append((rid, ckey, source,
                                                       dict(params)))
        return rid

    def poll(self, rid: int) -> Optional[Any]:
        """The query's public state pytree, or None while pending.

        Raises RuntimeError (chaining the original failure) if serving
        this query failed — e.g. an unsupported (policy × backend)
        combination or bad algorithm kwargs that only surface when the
        engine is built."""
        rec = self._records[rid]
        if rec.error is not None:
            raise RuntimeError(
                f"query {rid} ({rec.algorithm!r}) failed: "
                f"{rec.error}") from rec.error
        return rec.state if rec.state is not None else None

    def status(self, rid: int) -> dict:
        """Non-raising view of one query: always returns a dict, even
        for unknown/evicted rids and failed queries (where :meth:`poll`
        raises) — the surface a serving dashboard polls."""
        rec = self._records.get(rid)
        if rec is None:
            return {"rid": rid, "status": "unknown"}
        if rec.error is not None:
            return {"rid": rid, "status": "failed",
                    "algorithm": rec.algorithm,
                    "error": f"{type(rec.error).__name__}: {rec.error}"}
        if rec.state is not None:
            return {"rid": rid, "status": "done",
                    "algorithm": rec.algorithm, "cached": rec.cached,
                    "converged": rec.converged}
        return {"rid": rid, "status": "pending",
                "algorithm": rec.algorithm}

    def record(self, rid: int) -> QueryRecord:
        return self._records[rid]

    def pending(self) -> int:
        return self._pending

    # -- the serving loop ------------------------------------------------
    def step(self) -> int:
        """One scheduling action: run a chunk of the active batch (or
        start one, or serve one unbatchable query). Returns the number
        of queries completed by this step."""
        if self._active is None and not self._start_next_group():
            return 0
        if self._active is None:                     # served unbatchable
            return 1
        return self._run_chunk()

    def run_until_complete(self, max_rounds: int = 100_000) -> None:
        """Drive :meth:`step` until every submitted query has a result."""
        rounds = 0
        while self.pending():
            rounds += 1
            if rounds > max_rounds:
                raise RuntimeError(
                    f"QueryService did not drain within {max_rounds} "
                    f"rounds ({self.pending()} queries still pending)")
            self.step()

    def stats(self) -> dict:
        return {"submitted": self._next_rid,
                "pending": self.pending(),
                "coalesced": self.coalesced,
                "batches_started": self.batches_started,
                "chunks_run": self.chunks_run,
                "force_retired": self.force_retired,
                "chunk_retries": self.chunk_retries,
                "deadline_expired": self.deadline_expired,
                "admission_rejected": self.admission_rejected,
                "cache_errors": self.cache_errors,
                "failures": list(self._failures),
                "cache": self.cache.stats()}

    # -- internals -------------------------------------------------------
    def _cache_key(self, algorithm, source, params, policy, backend):
        # policy shorthands/instances and backends are hashable (frozen
        # dataclasses; DistributedBackend hashes by identity)
        return (self._fp, algorithm, source, params, policy, backend)

    def _cache_lookup(self, ckey):
        """Guarded ResultCache lookup: a failing cache (injected or
        real) degrades to a miss — the query is recomputed, never
        dropped."""
        try:
            fault_point("service.cache.get")
            return self.cache.get(ckey)
        except (OSError, FaultInjected) as e:
            self.cache_errors += 1
            note("fallback.service.cache.get", error=type(e).__name__)
            return None

    def _cache_store(self, ckey, value):
        """Guarded ResultCache store: a failing put loses the cache
        entry (a later identical submit recomputes), not the result."""
        try:
            fault_point("service.cache.put")
            self.cache.put(ckey, value)
        except (OSError, FaultInjected) as e:
            self.cache_errors += 1
            note("fallback.service.cache.put", error=type(e).__name__)

    def _chunk_call(self, fn):
        """``service.chunk`` fault site + ``fn()`` with bounded retries
        of *transient* failures (injected faults, I/O, timeouts).
        Deterministic errors — bad cell, bad kwargs — raise through on
        the first attempt; retrying them would just re-trace."""
        last = None
        for attempt in range(self.max_chunk_retries + 1):
            try:
                fault_point("service.chunk")
                return fn()
            except (FaultInjected, OSError, TimeoutError,
                    ConnectionError) as e:
                last = e
                if attempt >= self.max_chunk_retries:
                    raise
                self.chunk_retries += 1
                note("retry.service.chunk", attempt=attempt + 1,
                     error=type(e).__name__)
        raise last  # pragma: no cover — unreachable

    def _waited_ms(self, rec) -> Optional[float]:
        """Elapsed ms since submit iff the record's deadline passed."""
        if rec.deadline_ms is None:
            return None
        waited = (self._clock() - rec.submitted_at) * 1e3
        return waited if waited > rec.deadline_ms else None

    def _reap_expired(self, ckey, where: str) -> bool:
        """Fail every deadline-expired rid waiting on ``ckey``; True if
        any live requester remains (the work is still wanted)."""
        rids = self._inflight.get(ckey)
        if not rids:
            return False
        alive = []
        for rid in rids:
            rec = self._records[rid]
            waited = self._waited_ms(rec)
            if waited is None:
                alive.append(rid)
                continue
            self.deadline_expired += 1
            rec.error = DeadlineExceeded(rid, rec.deadline_ms, waited,
                                         where)
            self._pending -= 1
            note("deadline.service", rid=rid, where=where)
            self._emit("service.deadline", rid=rid, where=where,
                       algorithm=rec.algorithm)
        if alive:
            self._inflight[ckey] = alive
            return True
        del self._inflight[ckey]
        return False

    def _finish(self, ckey, algorithm, state, converged=True,
                cacheable=None):
        # force-retired batched states depend on scheduler timing, not
        # just the request params, so they are never cached; cache
        # entries carry the convergence flag so hits report it honestly
        if cacheable is None:
            cacheable = converged
        if cacheable:
            self._cache_store(ckey, (state, converged))
        first = True
        for rid in self._inflight.pop(ckey, ()):
            rec = self._records[rid]
            rec.state, rec.converged = state, converged
            # coalesced followers count as cache-served — but only for
            # reproducible (cacheable) results
            rec.cached = cacheable and not first
            first = False
            self._pending -= 1
        self._evict_records()

    def _fail(self, ckey, exc: Exception, *, slot=None, chunk=None):
        """Serving these queries failed: record the error (poll raises
        it, :meth:`status` reports it), note where it died (batch slot
        and chunk index, surfaced via ``stats()["failures"]``), and
        release the pending/in-flight bookkeeping so one bad request
        can never wedge the loop."""
        for rid in self._inflight.pop(ckey, ()):
            rec = self._records[rid]
            rec.error = exc
            self._pending -= 1
            self._failures.append(
                {"rid": rid, "algorithm": rec.algorithm,
                 "error": type(exc).__name__, "slot": slot,
                 "chunk": chunk})

    def _evict_records(self):
        if len(self._records) <= self.max_records:
            return
        for rid in list(self._records):
            if len(self._records) <= self.max_records:
                break
            if self._records[rid].done:
                del self._records[rid]

    def _start_next_group(self) -> bool:
        """Pick the group whose head request is oldest (FIFO by rid) —
        a steady stream for one group can never starve another, since
        new arrivals always queue behind every already-waiting rid."""
        gkey = min((k for k, q in self._queues.items() if q),
                   key=lambda k: self._queues[k][0][0], default=None)
        if gkey is None:
            return False
        algorithm, policy, backend, _ = gkey
        queue = self._queues[gkey]
        if algorithm not in batchable():
            rid, ckey, source, params = queue.popleft()
            if not queue:
                del self._queues[gkey]
            if not self._reap_expired(ckey, "queued"):
                return True      # every requester timed out while queued
            if source is not None:
                params[_source_kwarg(algorithm)] = source
            try:
                r = self._chunk_call(
                    lambda: api.solve(self.g, algorithm, policy=policy,
                                      backend=backend,
                                      telemetry=self.telemetry,
                                      **params))
            except Exception as e:            # bad cell / bad kwargs
                self._fail(ckey, e)
                return True
            # a single solve is deterministic given its params, so the
            # result is cacheable even at its step bound (pagerank's
            # fixed-iteration converged=False is by design) — but the
            # record reports the true convergence flag
            self._finish(ckey, algorithm, r.state,
                         converged=bool(r.converged), cacheable=True)
            return True
        bspec = get_batch_spec(algorithm)
        width = min(self.slots, len(queue))
        taken = [queue.popleft() for _ in range(width)]
        if not queue:
            del self._queues[gkey]
        taken = [t for t in taken if self._reap_expired(t[1], "queued")]
        if not taken:
            return True          # the whole head timed out while queued
        width = len(taken)
        params = dict(taken[0][3])
        try:
            state, frontier = bspec.init(
                self.g, [t[2] for t in taken], **params)
            step_bound = default_step_bound(
                self.g, algorithm, width, policy=policy,
                backend=backend, **params)
        except Exception as e:   # unsupported cell, bad kwargs, ...
            for j, t in enumerate(taken):
                self._fail(t[1], e, slot=j)
            return True
        self._active = _Active(
            group=gkey, algorithm=algorithm, policy=policy,
            backend=backend, params=params, width=width, state=state,
            frontier=frontier, slot_rids=[(t[0], t[1]) for t in taken],
            slot_chunks=[0] * width, step_bound=step_bound,
            slot_steps0=[0] * width)
        self.batches_started += 1
        self._emit("service.batch_start", algorithm=algorithm,
                   width=width)
        return True

    def _run_chunk(self) -> int:
        act = self._active
        bspec = get_batch_spec(act.algorithm)
        # chunks never exceed the unchunked run's own step budget, so a
        # small bound (e.g. ppr iters=5) is enforced exactly; larger
        # bounds are enforced at chunk granularity (a query may run up
        # to chunk_steps-1 steps past its budget before retiring)
        t0 = (self.telemetry.now_us() if self.telemetry is not None
              else 0.0)
        try:
            res, done = self._chunk_call(
                lambda: run_chunk(
                    self.g, act.algorithm, act.width, state=act.state,
                    frontier=act.frontier, policy=act.policy,
                    backend=act.backend,
                    max_steps=min(self.chunk_steps, act.step_bound),
                    **act.params))
        except Exception as e:
            for i, slot in enumerate(act.slot_rids):
                if slot is not None:
                    self._fail(slot[1], e, slot=i,
                               chunk=act.slot_chunks[i])
            self._active = None
            return 0
        self.chunks_run += 1
        act.state = res.state
        # lockstep batches consume the program's bound unit together
        # (steps for flat programs, epochs for phase programs); each
        # query's budget counts from its admission
        act.total_steps += int(res.epochs
                               if bspec.bound_unit == "epochs"
                               else res.steps)
        if self.telemetry is not None:
            # the int() above synced the chunk, so the span covers
            # execution, not just dispatch
            self.telemetry.emit(
                "span", "service.chunk", ts_us=t0,
                dur_us=round(self.telemetry.now_us() - t0, 3),
                algorithm=act.algorithm, width=act.width,
                steps=int(res.steps))
        done = np.asarray(done) | bool(res.converged)
        finished = 0
        queue = self._queues.get(act.group, deque())
        # refill only a full-width batch with no other group waiting:
        # an under-width batch drains and restarts wider (later arrivals
        # would otherwise serialize through its few columns), and a
        # waiting minority group gets the slots once this batch drains
        # (otherwise a steady majority stream starves it forever)
        others_waiting = any(q for k, q in self._queues.items()
                             if k != act.group and q)
        can_refill = act.width >= self.slots and not others_waiting
        for i in range(act.width):
            if act.slot_rids[i] is not None:
                # mid-batch deadline check: expired requesters are
                # failed; a slot nobody wants anymore is abandoned
                # (its column keeps stepping but the result is dropped)
                if not self._reap_expired(act.slot_rids[i][1],
                                          "running"):
                    act.slot_rids[i] = None
                    finished += 1
            if act.slot_rids[i] is not None:
                act.slot_chunks[i] += 1
                # budget exhausted -> best-effort retire, marked
                # converged=False and NOT cached (the bounded-solve
                # analogue: the result is the state so far)
                consumed = act.total_steps - act.slot_steps0[i]
                exhausted = (act.slot_chunks[i]
                             >= self.max_chunks_per_query
                             or consumed >= act.step_bound)
                if exhausted and not done[i]:
                    self.force_retired += 1
                    self._emit("service.force_retire",
                               rid=act.slot_rids[i][0],
                               algorithm=act.algorithm)
                if done[i] or exhausted:
                    rid, ckey = act.slot_rids[i]
                    self._finish(ckey, act.algorithm,
                                 bspec.extract(self.g, act.state, i),
                                 converged=bool(done[i]))
                    act.slot_rids[i] = None
                    finished += 1
            if act.slot_rids[i] is None and queue and can_refill:
                rid, ckey, source, params = queue.popleft()
                act.state, act.frontier = bspec.admit(
                    self.g, act.state, None, i, source, **act.params)
                act.slot_rids[i] = (rid, ckey)
                act.slot_chunks[i] = 0
                act.slot_steps0[i] = act.total_steps
        act.frontier = bspec.frontier_of(self.g, act.state)
        if not queue:
            self._queues.pop(act.group, None)
        if all(s is None for s in act.slot_rids):
            self._active = None
            if self.telemetry is not None:
                from ..obs.metrics import (collect_resilience,
                                           collect_service)
                collect_service(self.telemetry, self)
                collect_resilience(self.telemetry)
        return finished
