"""Three-term roofline from compiled dry-run artifacts (TPU v5e target).

    compute term    = HLO_FLOPs / (chips × 197e12 FLOP/s bf16)
    memory term     = HLO_bytes / (chips × 819e9 B/s HBM)
    collective term = collective_bytes / (chips × 50e9 B/s ICI link)

`cost_analysis()` supplies FLOPs/bytes (already per-partition under SPMD);
collective bytes come from parsing the compiled HLO: we sum the *output*
shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (per-device payloads post-SPMD).
"""

from __future__ import annotations

import re

__all__ = ["HW", "collective_bytes_from_hlo", "roofline_report",
           "model_flops", "kernel_roofline"]

HW = {
    "peak_flops": 197e12,     # bf16 per chip
    "hbm_bw": 819e9,          # bytes/s per chip
    "ici_bw": 50e9,           # bytes/s per link (~per chip per direction)
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.  %foo = bf16[16,128,2048]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9_]+(?:\[[0-9,]*\])?"
    r"(?:\{[^}]*\})?(?:,\s*[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?)*)\)?\s+"
    r"([a-z0-9-]+)\(")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,128]{1,0}' (or tuple of) -> total bytes."""
    total = 0
    for m in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum per-device output bytes of every collective op in the HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        # normalize fused variants like all-gather-start
        for kind in _COLL_KINDS:
            if opname == kind or opname.startswith(kind + "-"):
                if opname.endswith("-done"):
                    break  # counted at -start
                out[kind]["count"] += 1
                out[kind]["bytes"] += _shape_bytes(shape_str)
                break
    total = sum(v["bytes"] for v in out.values())
    count = sum(v["count"] for v in out.values())
    return {"by_kind": out, "total_bytes": total, "total_count": count}


def model_flops(kind: str, **kw) -> float:
    """Useful-work estimate: 6·N·D for dense LM training (fwd+bwd),
    2·N·D for inference; N = params touched per token (active for MoE)."""
    n_active = kw["n_active_params"]
    tokens = kw["tokens"]
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens


def kernel_roofline(direction: str, *, n: int, d_ell: int = 0,
                    batch: int = 1, itemsize: int = 4, nb: int = 1,
                    cap: int = 0, bin_n: int = 0,
                    measured_us: float = 0.0) -> dict:
    """Analytic roofline bound for one graph-kernel launch.

    Counts the bytes the kernel's tiling *must* move (graph structure +
    payload gathers + destination writes, assuming perfect reuse of
    VMEM-resident blocks) and the combine FLOPs, prices them against
    the HW terms, and reports ``pct_roofline = bound_us /
    measured_us`` — the fraction of the hardware bound actually
    achieved. ``pull`` is the ELL gather (``n × d_ell`` rectangular
    layout); ``pullf`` the frontier-restricted gather over ``rows``
    compacted destinations (pass the padded row capacity as ``n`` —
    only those ELL rows are read and written); ``push`` is the
    two-phase bin reduce (``nb × cap`` padded edge bins + per-bin run
    pointers + ``nb × bin_n`` accumulators). The ratio is clamped to
    the schema's 1.5 ceiling — anything past ~1.0 means timing noise,
    not physics.
    """
    if direction in ("pull", "pullf"):
        bytes_moved = (n * d_ell * (4 + 4)              # ELL idx + w
                       + n * d_ell * batch * itemsize   # payload gather
                       + n * batch * itemsize)          # dst writes
        flops = n * d_ell * batch
        if direction == "pullf":
            bytes_moved += n * 4                        # compacted row ids
    else:
        bytes_moved = (nb * cap * (4 + 4 + 4)           # src / dst / w
                       + nb * cap * batch * itemsize    # payload gather
                       + nb * (bin_n + 1) * 4           # run pointers
                       + nb * bin_n * batch * itemsize)  # accumulators
        flops = nb * cap * batch
    bound_us = 1e6 * max(flops / HW["peak_flops"],
                         bytes_moved / HW["hbm_bw"])
    return {"bytes_moved": int(bytes_moved), "flops": int(flops),
            "bound_us": bound_us,
            "pct_roofline": min(bound_us / max(measured_us, 1e-9), 1.5)}


def roofline_report(result: dict, loop_factor: int = 1) -> dict:
    """Attach the three terms (seconds) + dominant bottleneck to a dry-run
    result dict (cost analysis is per-partition under SPMD).

    loop_factor: XLA's cost_analysis and the HLO text count a while-loop
    body ONCE, so a scan-over-layers model under-reports loop-resident
    FLOPs/bytes/collectives by ~n_layers. Callers pass the scan trip
    count (transformer cells: n_layers; python-unrolled GNN/recsys: 1).
    Applying the factor to the whole program slightly over-scales the
    loop-external parts (loss/optimizer/embedding, a few % of each term)
    and the layer-internal attention/loss sub-scans remain counted once
    (~10-15% residual undercount on LM compute) — both documented in
    EXPERIMENTS.md §Roofline methodology.
    """
    flops = (result["cost"]["flops"] or 0.0) * loop_factor
    bytes_acc = (result["cost"]["bytes_accessed"] or 0.0) * loop_factor
    coll_bytes = result["collectives"]["total_bytes"] * loop_factor
    t_compute = flops / HW["peak_flops"]
    t_memory = bytes_acc / HW["hbm_bw"]
    t_coll = coll_bytes / HW["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=lambda k: terms[k])
    bound = max(terms.values())
    total = max(1e-30, bound)
    return {
        **terms,
        "loop_factor": loop_factor,
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound,
        "compute_fraction_of_bound": t_compute / total,
    }
