"""Quickstart: the push-pull dichotomy in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import bfs, pagerank, triangle_count
from repro.core.direction import Direction, Fixed, GenericSwitch
from repro.graphs import kronecker


def main():
    # a power-law graph (Graph500-style Kronecker)
    g = kronecker(scale=12, edge_factor=8, seed=0, weighted=True)
    print(f"graph: n={g.n} m={g.m} d_ell={g.d_ell}")

    # --- PageRank: same ranks, opposite synchronization profile --------
    push = pagerank(g, iters=10, direction="push")
    pull = pagerank(g, iters=10, direction="pull")
    assert np.allclose(push.ranks, pull.ranks, atol=1e-6)
    print("\nPageRank (10 iters) — identical ranks, different cost:")
    print(f"  push: locks={int(push.cost.locks):>12,} "
          f"reads={int(push.cost.reads):>12,}")
    print(f"  pull: locks={int(pull.cost.locks):>12,} "
          f"reads={int(pull.cost.reads):>12,}")

    # --- BFS: direction optimization (the paper's flagship GS) ---------
    b_push = bfs(g, 0, Fixed(Direction.PUSH))
    b_pull = bfs(g, 0, Fixed(Direction.PULL))
    b_auto = bfs(g, 0, GenericSwitch())
    assert np.array_equal(np.asarray(b_auto.dist), np.asarray(b_push.dist))
    print("\nBFS edge-work (reads):")
    print(f"  push={int(b_push.cost.reads):,}  "
          f"pull={int(b_pull.cost.reads):,}  "
          f"auto={int(b_auto.cost.reads):,} "
          f"({int(b_auto.push_steps)}/{int(b_auto.levels)} push levels)")

    # --- Triangle counting: pull drops the atomics ----------------------
    tc = triangle_count(kronecker(9, 4, seed=1), "pull")
    print(f"\ntriangles: {int(tc.total):,} (pull atomics="
          f"{int(tc.cost.atomics)})")

    # --- The unified API: one solve(), pluggable policy × backend -------
    from repro import api
    from repro.core import EllBackend
    r = api.solve(g, "pagerank", iters=10, backend=EllBackend())
    assert np.allclose(np.asarray(r.state), np.asarray(pull.ranks),
                       atol=1e-6)
    w = api.solve(g, "wcc", policy=GenericSwitch())
    k = api.solve(g, "pagerank", iters=10, backend="pallas")
    assert np.allclose(np.asarray(k.state), np.asarray(pull.ranks),
                       atol=1e-6)
    print(f"\napi.solve: algorithms={api.algorithms()}")
    print(f"  pagerank@ELL == pagerank@Pallas == pagerank@dense; "
          f"wcc converged in "
          f"{int(w.steps)} steps ({int(w.push_steps)} push)")

    # --- Phase-structured programs: every paper workload, one solve() ---
    g2 = kronecker(scale=9, edge_factor=5, seed=2, weighted=True)
    src = int(np.asarray(g2.out_deg).argmax())   # a connected hub vertex
    s = api.solve(g2, "sssp_delta", source=src, delta=2.0)
    print(f"\nsssp_delta: {int(s.epochs)} Δ-buckets, "
          f"{int(s.steps)} inner relaxations")
    bc = api.solve(g2, "betweenness", num_sources=4,
                   policy=Fixed(Direction.PULL))
    print(f"betweenness: 4 sources (epochs={int(bc.epochs)}), "
          f"pull locks={int(bc.cost.locks)} (Madduri successor trick)")
    col = api.solve(g2, "coloring", num_parts=8)
    print(f"coloring: {int(col.state['num_colors'])} colors in "
          f"{int(col.epochs)} Boman iterations")
    mst = api.solve(g2, "mst_boruvka")
    print(f"mst_boruvka: weight={float(mst.state['weight']):.1f} in "
          f"{int(mst.epochs)} rounds "
          f"({int(mst.state['components'])} components)")
    t = api.solve(g2, "triangle_count")
    print(f"triangle_count: {int(t.state['total']):,} triangles in "
          f"{int(t.steps)} edge blocks")

    # --- Pallas kernels (TPU-target, interpret-validated) ---------------
    from repro.kernels import pull_spmv
    y = pull_spmv(g, jnp.ones((g.n,)), "sum")
    print(f"\nPallas ELL-SpMV kernel: out[:4]={np.asarray(y[:4]).round(2)}")
    print("\nOK — see examples/pagerank_pushpull.py for the full story.")


if __name__ == "__main__":
    main()
