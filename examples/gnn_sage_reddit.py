"""Sampled GraphSAGE training — the paper's Frontier-Exploit strategy as
a training-time system feature (sampling = FE; aggregation = pull).

    PYTHONPATH=src python examples/gnn_sage_reddit.py --steps 100
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs import kronecker, sample_blocks
from repro.models.gnn import GNNConfig, sage_apply_blocks, sage_init
from repro.train import OptConfig, apply_updates, init_opt
from repro.train.losses import softmax_xent_dense


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()

    # reddit-like stand-in: power-law, labels from a planted partition
    g = kronecker(scale=12, edge_factor=12, seed=0)
    n_classes, d_feat = 8, 64
    rng = np.random.default_rng(0)
    labels = jnp.asarray(rng.integers(0, n_classes, g.n), jnp.int32)
    # features correlated with labels so learning is visible
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = jnp.asarray(centers[np.asarray(labels)]
                        + 0.8 * rng.normal(size=(g.n, d_feat)))
    feats_pad = jnp.pad(feats, ((0, 1), (0, 0)))

    cfg = GNNConfig(arch="sage", n_layers=2, d_hidden=64, d_in=d_feat,
                    d_out=n_classes, fanouts=(10, 5))
    params = sage_init(jax.random.PRNGKey(0), cfg)
    ocfg = OptConfig(lr=1e-2, total_steps=args.steps, warmup_steps=5)
    opt = init_opt(params, ocfg)

    @jax.jit
    def step(params, opt, seeds, key):
        blocks = sample_blocks(g, seeds, cfg.fanouts, key)
        hs = tuple(feats_pad[jnp.minimum(ids, g.n)]
                   for ids in blocks.node_ids)

        def loss_fn(p):
            out = sage_apply_blocks(p, cfg, blocks, hs)
            return softmax_xent_dense(out, labels[seeds])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = apply_updates(params, grads, opt, ocfg)
        return params, opt, loss

    key = jax.random.PRNGKey(1)
    for i in range(args.steps):
        key, k1, k2 = jax.random.split(key, 3)
        seeds = jax.random.randint(k1, (args.batch,), 0, g.n)
        params, opt, loss = step(params, opt, seeds, k2)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:>4} loss {float(loss):.4f}")
    print("frontier-exploit sampling touched "
          f"{args.batch * (1 + 10 + 50)} nodes/step of {g.n} "
          f"({100 * args.batch * 61 / g.n:.1f}% — the FE win)")


if __name__ == "__main__":
    main()
