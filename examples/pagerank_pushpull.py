"""The paper's full PageRank story on one graph: push vs pull vs push+PA
(Algorithm 8), plus the distributed exchange schedules (Fig 3 analogues).

    PYTHONPATH=src python examples/pagerank_pushpull.py
"""

import numpy as np

from repro.core.algorithms import pagerank
from repro.core.algorithms.pagerank import pagerank_pa_prepare
from repro.graphs import pa_split, partition_1d, standin


def main():
    g = standin("orc", scale=1.0 / 256, weighted=False)
    print(f"orkut stand-in: n={g.n} m={g.m}")

    push = pagerank(g, 20, direction="push")
    pull = pagerank(g, 20, direction="pull")
    run_pa, stats = pagerank_pa_prepare(g, num_parts=16, iters=20)
    ranks_pa, cost_pa = run_pa()

    assert np.allclose(push.ranks, pull.ranks, atol=1e-6)
    assert np.allclose(push.ranks, ranks_pa, atol=1e-6)

    print("\nvariant      locks        reads        writes")
    for name, c in (("push", push.cost), ("pull", pull.cost),
                    ("push+PA", cost_pa)):
        d = c.as_dict()
        print(f"{name:8s} {d['locks']:>12,} {d['reads']:>12,} "
              f"{d['writes']:>12,}")
    print(f"\nPA cut fraction: {stats['cut_fraction']:.3f} "
          f"(locks scale with the cut — paper §5 bound [0, 2m])")

    print("\nDM schedules, bytes/device by P (paper Fig 3 structure):")
    for P in (4, 16, 64, 256):
        part = partition_1d(g.n, P)
        _, remote, s = pa_split(g, part)
        print(f"  P={P:>4}: MP-push={part.n_padded*4:>10,}  "
              f"RMA-pull={part.n_padded*4*(P-1)//P:>10,}  "
              f"RMA-push={s['cut_edges']*8//P:>10,}  "
              f"(cut={s['cut_edges']:,})")
    print("\nMP-style combining keeps bytes flat in P; per-edge RMA-push "
          "explodes with the cut — the paper's >10x PR gap.")


if __name__ == "__main__":
    main()
