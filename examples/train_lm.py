"""End-to-end LM pretraining driver: data pipeline -> TrainLoop with
async checkpointing, crash resume, straggler watchdog, and optional
gradient compression.

    # CPU-friendly default (~20M params, 200 steps):
    PYTHONPATH=src python examples/train_lm.py --steps 200

    # the ~100M-param configuration (same code path, heavier):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

    # kill it mid-run and rerun: it resumes from the latest checkpoint.
"""

import argparse
import dataclasses

import jax

from repro.data import prefetch, token_batches
from repro.dist import CompressionConfig
from repro.models.transformer import (TransformerConfig, init_params,
                                      lm_loss)
from repro.models.common import param_count
from repro.train import LoopConfig, OptConfig, TrainLoop

PRESETS = {
    # ~19M params: fast on CPU
    "20m": TransformerConfig(
        name="lm20m", n_layers=4, d_model=384, n_heads=6, n_kv_heads=2,
        d_ff=1024, vocab=8192, dtype="float32", loss_chunk=128,
        attn_impl="naive"),
    # ~124M params: the e2e driver scale from the deliverable
    "100m": TransformerConfig(
        name="lm100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab=32768, dtype="float32", loss_chunk=256,
        attn_impl="naive"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="20m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--compression", choices=["none", "topk", "int8"],
                    default="none")
    ap.add_argument("--micro", type=int, default=1)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"model: {cfg.name}  params={param_count(params):,}")

    def loss_fn(p, batch):
        return lm_loss(p, cfg, batch["tokens"], batch["labels"])

    loop = TrainLoop(
        loss_fn, params,
        OptConfig(lr=args.lr, total_steps=args.steps, warmup_steps=20),
        LoopConfig(total_steps=args.steps, ckpt_every=50,
                   ckpt_dir=args.ckpt_dir, log_every=10,
                   num_micro=args.micro,
                   compression=CompressionConfig(kind=args.compression)))
    if loop.start_step:
        print(f"resumed from checkpoint at step {loop.start_step}")

    data = prefetch(token_batches(args.batch, args.seq, cfg.vocab), depth=2)
    res = loop.run(data)
    print(f"\ndone: step={res['final_step']} loss={res['final_loss']:.4f} "
          f"median_step={res['median_dt']*1e3:.0f}ms "
          f"stragglers={len(res['stragglers'])}")
    for h in res["history"][-5:]:
        print(f"  step {h['step']:>5} loss {h['loss']:.4f} "
              f"dt {h['dt']*1e3:.0f}ms")


if __name__ == "__main__":
    main()
