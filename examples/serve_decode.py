"""Batched serving demo: continuous batching over a fixed slot budget,
per-slot cache positions, greedy sampling.

    PYTHONPATH=src python examples/serve_decode.py
"""

import jax

from repro.models.transformer import TransformerConfig, init_params
from repro.serve import ServeConfig, ServeLoop, greedy_decode


def main():
    cfg = TransformerConfig(name="serve-demo", n_layers=4, d_model=128,
                            n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
                            dtype="float32", attn_impl="naive")
    params = init_params(jax.random.PRNGKey(0), cfg)

    # one-shot batched rollout
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 512)
    toks = greedy_decode(params, cfg, prompt, num_steps=16,
                         cache_kind="bf16")
    print("greedy_decode output shape:", toks.shape)

    # continuous batching: 8 requests through 4 slots
    loop = ServeLoop(params, cfg,
                     ServeConfig(max_len=64, batch=4, cache_kind="bf16"))
    rids = [loop.submit([1 + i, 2 + i, 3 + i]) for i in range(8)]
    steps = 0
    while (loop.active.any() or loop.queue) and steps < 400:
        loop.step(max_new=12)
        steps += 1
    done = sum(1 for r in rids if len(loop.outputs[r]) >= 12)
    print(f"served {done}/8 requests in {steps} decode steps "
          f"(4 slots, continuous batching)")
    print("sample output tokens:", loop.outputs[rids[0]][:8])


if __name__ == "__main__":
    main()
