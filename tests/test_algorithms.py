"""Algorithm correctness vs networkx + paper counter structure (Table 1)."""

import jax
import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

from repro.core.algorithms import (bfs, betweenness_centrality,
                                   boman_coloring, boruvka_mst,
                                   conflict_removal_coloring, fe_coloring,
                                   pagerank, pagerank_pa, sssp_delta,
                                   triangle_count, validate_coloring)
from repro.core.direction import Direction, Fixed, GenericSwitch, GreedySwitch

UNREACHED = 2147483647


# ---------------------------------------------------------------- PR ----
def test_pagerank_push_pull_ell_agree(small_graph):
    g = small_graph
    rs = [pagerank(g, 15, direction=d, use_ell=e).ranks
          for d, e in (("push", False), ("pull", False), ("pull", True))]
    for r in rs[1:]:
        np.testing.assert_allclose(np.asarray(rs[0]), np.asarray(r),
                                   atol=1e-6)


def test_pagerank_matches_networkx(small_graph, nx_of):
    g = small_graph
    G = nx_of(g)
    want = nx.pagerank(G, alpha=0.85, max_iter=200, weight=None)
    got = np.asarray(pagerank(g, 100, direction="pull").ranks)
    for v in range(g.n):
        assert abs(got[v] - want[v]) < 2e-4


def test_pagerank_counter_structure(small_graph):
    g = small_graph
    push = pagerank(g, 10, direction="push").cost
    pull = pagerank(g, 10, direction="pull").cost
    # Table 1: pull-PR has zero atomics/locks; push-PR O(Lm) locks (floats)
    assert int(pull.atomics) == 0 and int(pull.locks) == 0
    assert int(push.locks) == 10 * g.m


def test_pagerank_pa_reduces_combining_writes(power_graph):
    g = power_graph
    base = pagerank(g, 5, direction="push")
    pa = pagerank_pa(g, num_parts=4, iters=5)
    np.testing.assert_allclose(np.asarray(base.ranks), np.asarray(pa.ranks),
                               atol=1e-6)
    # PA: only cut edges pay combining writes (paper bound [0, 2m])
    assert int(pa.cost.locks) < int(base.cost.locks)


# --------------------------------------------------------------- BFS ----
@pytest.mark.parametrize("policy", [Fixed(Direction.PUSH),
                                    Fixed(Direction.PULL),
                                    GenericSwitch()])
def test_bfs_distances(small_graph, nx_of, policy):
    g = small_graph
    G = nx_of(g)
    res = bfs(g, 3, policy)
    want = np.full(g.n, UNREACHED)
    for k, v in nx.single_source_shortest_path_length(G, 3).items():
        want[k] = v
    assert np.array_equal(np.asarray(res.dist), want)


def test_bfs_parent_tree_valid(small_graph):
    g = small_graph
    res = bfs(g, 0, Fixed(Direction.PUSH))
    dist = np.asarray(res.dist)
    parent = np.asarray(res.parent)
    nbrs = set(zip(np.asarray(g.coo_src).tolist(),
                   np.asarray(g.coo_dst).tolist()))
    for v in range(g.n):
        if dist[v] not in (0, UNREACHED):
            p = parent[v]
            assert dist[p] == dist[v] - 1
            assert (p, v) in nbrs


def test_bfs_counters(small_graph):
    g = small_graph
    push = bfs(g, 0, Fixed(Direction.PUSH)).cost
    pull = bfs(g, 0, Fixed(Direction.PULL)).cost
    # push: O(m) CAS atomics; pull: none but more reads (Table 1)
    assert int(push.atomics) > 0
    assert int(pull.atomics) == 0
    assert int(pull.reads) > int(push.reads)


def test_direction_optimization_switches(power_graph):
    res = bfs(power_graph, 0, GenericSwitch())
    # on a power-law graph the optimizer must use both directions
    assert 0 < int(res.push_steps) < int(res.levels)


# -------------------------------------------------------------- SSSP ----
@pytest.mark.parametrize("direction", ["push", "pull"])
def test_sssp_delta(small_graph, nx_of, direction):
    g = small_graph
    G = nx_of(g)
    res = sssp_delta(g, 2, delta=2.5, direction=direction)
    want = np.full(g.n, np.inf)
    for k, v in nx.single_source_dijkstra_path_length(G, 2).items():
        want[k] = v
    np.testing.assert_allclose(np.asarray(res.dist), want, atol=1e-4)


def test_sssp_counters(small_graph):
    g = small_graph
    push = sssp_delta(g, 0, 2.5, direction="push").cost
    pull = sssp_delta(g, 0, 2.5, direction="pull").cost
    assert int(push.locks) > 0            # CAS on float distances
    assert int(pull.locks) == 0
    assert int(pull.reads) > int(push.reads)


# ---------------------------------------------------------------- TC ----
def test_triangle_count(small_graph, nx_of):
    g = small_graph
    G = nx_of(g)
    want_total = sum(nx.triangles(G).values()) // 3
    per_v = nx.triangles(G)
    for d in ("push", "pull"):
        res = triangle_count(g, d)
        assert int(res.total) == want_total
        got = np.asarray(res.per_vertex)
        assert all(got[v] == per_v[v] for v in range(g.n))
    # atomics: push counts per discovered wedge; pull zero
    assert int(triangle_count(g, "pull").cost.atomics) == 0
    assert int(triangle_count(g, "push").cost.atomics) > 0


# --------------------------------------------------------------- MST ----
def test_boruvka_mst(small_graph, nx_of):
    g = small_graph
    G = nx_of(g)
    F = nx.minimum_spanning_tree(G)
    want = sum(d["weight"] for _, _, d in F.edges(data=True))
    for d in ("push", "pull"):
        res = boruvka_mst(g, d)
        assert np.isclose(float(res.weight), want, rtol=1e-5)
    assert int(boruvka_mst(g, "pull").cost.atomics) == 0
    assert int(boruvka_mst(g, "push").cost.atomics) > 0


def test_boruvka_mst_disconnected(nx_of):
    """Edgeless supervertices must not scatter into in_mst slot 0 — a
    sparse graph with many isolated components exercises the sentinel
    path every round."""
    from repro.graphs import erdos_renyi
    g = erdos_renyi(200, 1.2, seed=5, weighted=True)
    G = nx_of(g)
    F = nx.minimum_spanning_tree(G)
    want = sum(d["weight"] for _, _, d in F.edges(data=True))
    want_comp = nx.number_connected_components(G)
    for d in ("push", "pull"):
        res = boruvka_mst(g, d)
        assert np.isclose(float(res.weight), want, rtol=1e-5)
        assert int(res.components) == want_comp


# ---------------------------------------------------------------- BC ----
def test_betweenness(nx_of):
    from repro.graphs import erdos_renyi
    g = erdos_renyi(50, 3.5, seed=4)
    G = nx_of(g)
    ref = nx.betweenness_centrality(G, normalized=False)
    want = np.array([ref[i] for i in range(g.n)]) * 2  # nx halves undirected
    for d in ("push", "pull"):
        res = betweenness_centrality(g, d, num_sources=g.n)
        np.testing.assert_allclose(np.asarray(res.bc), want, atol=1e-3)
    # Madduri successor trick: pull turns float locks into reads
    assert int(betweenness_centrality(g, "pull", num_sources=8).cost.locks) == 0
    assert int(betweenness_centrality(g, "push", num_sources=8).cost.locks) > 0


# ---------------------------------------------------------- coloring ----
@pytest.mark.parametrize("direction", ["push", "pull"])
def test_boman_coloring_valid(small_graph, direction):
    res = boman_coloring(small_graph, num_parts=8, C=64,
                         direction=direction)
    assert bool(validate_coloring(small_graph, res.colors))
    assert int(res.num_colors) <= 64
    assert np.all(np.asarray(res.colors) > 0)


def test_coloring_counter_structure(small_graph):
    push = boman_coloring(small_graph, 8, 64, direction="push").cost
    pull = boman_coloring(small_graph, 8, 64, direction="pull").cost
    assert int(pull.atomics) == 0


def test_fe_and_cr_coloring(small_graph):
    g = small_graph
    key = jax.random.PRNGKey(0)
    fe = fe_coloring(g, key, direction="push")
    assert bool(validate_coloring(g, fe.colors))
    gs = fe_coloring(g, key, use_gs=True)
    assert bool(validate_coloring(g, gs.colors))
    cr = conflict_removal_coloring(g, num_parts=8, C=64)
    assert bool(validate_coloring(g, cr.colors))
    assert int(cr.iterations) == 1
    assert int(cr.cost.atomics) == 0     # CR removes conflicts entirely
