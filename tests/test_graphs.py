"""Graph substrate: structure invariants, generators, partitioner, sampler."""

import numpy as np
import jax.numpy as jnp
import jax
import pytest
from hypothesis import given, strategies as st

from repro.graphs import (build_graph, erdos_renyi, kronecker, ring,
                          road_grid, star, standin, partition_1d, pa_split,
                          sample_blocks)


def test_build_graph_layout_consistency(small_graph):
    g = small_graph
    # pull-major sorted by dst; push-major sorted by src
    assert np.all(np.diff(np.asarray(g.coo_dst)) >= 0)
    assert np.all(np.diff(np.asarray(g.push_src)) >= 0)
    # same multiset of edges in both orders
    a = set(zip(np.asarray(g.coo_src).tolist(),
                np.asarray(g.coo_dst).tolist()))
    b = set(zip(np.asarray(g.push_src).tolist(),
                np.asarray(g.push_dst).tolist()))
    assert a == b
    # CSR pointers match degree counts
    assert np.all(np.diff(np.asarray(g.in_ptr)) == np.asarray(g.in_deg))
    assert np.all(np.diff(np.asarray(g.out_ptr)) == np.asarray(g.out_deg))


def test_ell_covers_all_in_edges(small_graph):
    g = small_graph
    idx = np.asarray(g.ell_idx)
    valid = idx < g.n
    assert valid.sum() == g.m
    # per-row valid count == in-degree
    assert np.all(valid.sum(1) == np.asarray(g.in_deg))


def test_weights_symmetric_per_pair(small_graph):
    g = small_graph
    src, dst, w = (np.asarray(g.coo_src), np.asarray(g.coo_dst),
                   np.asarray(g.coo_w))
    lookup = {(s, d): ww for s, d, ww in zip(src, dst, w)}
    for (s, d), ww in lookup.items():
        assert lookup[(d, s)] == ww


@pytest.mark.parametrize("gen", [
    lambda: erdos_renyi(200, 3.0, seed=1, weighted=True),
    lambda: kronecker(7, 4, seed=2, weighted=True),
    lambda: road_grid(12, weighted=True),
    lambda: ring(50, weighted=True),
    lambda: star(33),
])
def test_generators_simple_symmetric(gen):
    g = gen()
    src, dst = np.asarray(g.coo_src), np.asarray(g.coo_dst)
    assert np.all(src != dst), "no self loops"
    pairs = set(zip(src.tolist(), dst.tolist()))
    assert len(pairs) == g.m, "no duplicate directed edges"
    assert all((d, s) in pairs for s, d in pairs), "symmetric"


def test_standins_exist():
    for name in ("orc", "pok", "ljn", "am", "rca"):
        g = standin(name, scale=1.0 / 512)
        assert g.n >= 256 and g.m > 0


def test_partition_awareness_split(power_graph):
    g = power_graph
    part = partition_1d(g.n, 4)
    local, remote, stats = pa_split(g, part)
    # every edge lands in exactly one bucket
    assert int(local.count.sum() + remote.count.sum()) == g.m
    assert stats["cut_edges"] == int(remote.count.sum())
    # local edges: same owner; remote: different
    for p in range(4):
        ls = np.asarray(local.src[p])[: int(local.count[p])]
        ld = np.asarray(local.dst[p])[: int(local.count[p])]
        assert np.all(part.owner_np(ls) == part.owner_np(ld))
        assert np.all(part.owner_np(ls) == p)
        rs = np.asarray(remote.src[p])[: int(remote.count[p])]
        rd = np.asarray(remote.dst[p])[: int(remote.count[p])]
        assert np.all(part.owner_np(rs) != part.owner_np(rd))


def test_sampler_shapes_and_validity(small_graph):
    g = small_graph
    seeds = jnp.arange(16, dtype=jnp.int32)
    blocks = sample_blocks(g, seeds, (4, 3), jax.random.PRNGKey(0))
    assert blocks.node_ids[0].shape == (16,)
    assert blocks.node_ids[1].shape == (64,)
    assert blocks.node_ids[2].shape == (192,)
    # sampled children are real in-neighbors of their parents
    ids1 = np.asarray(blocks.node_ids[1]).reshape(16, 4)
    ok1 = np.asarray(blocks.valid[1]).reshape(16, 4)
    in_ptr = np.asarray(g.in_ptr)
    nbr = np.asarray(g.coo_src)
    for i in range(16):
        neigh = set(nbr[in_ptr[i]: in_ptr[i + 1]].tolist())
        for j in range(4):
            if ok1[i, j]:
                assert int(ids1[i, j]) in neigh


@given(n=st.integers(17, 200), p=st.integers(1, 9))
def test_partition_covers(n, p):
    part = partition_1d(n, p)
    owners = part.owner_np(np.arange(n))
    assert owners.min() >= 0 and owners.max() <= p - 1
    # contiguous blocks
    assert np.all(np.diff(owners) >= 0)
