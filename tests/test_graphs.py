"""Graph substrate: structure invariants, generators, partitioner, sampler."""

import numpy as np
import jax.numpy as jnp
import jax
import pytest
from hypothesis import given, strategies as st

from repro.graphs import (build_graph, erdos_renyi, kronecker, ring,
                          road_grid, star, standin, partition_1d, pa_split,
                          sample_blocks)
from repro.graphs.partition import PartitionedEdges, pa_regroup_by_dst


def test_build_graph_layout_consistency(small_graph):
    g = small_graph
    # pull-major sorted by dst; push-major sorted by src
    assert np.all(np.diff(np.asarray(g.coo_dst)) >= 0)
    assert np.all(np.diff(np.asarray(g.push_src)) >= 0)
    # same multiset of edges in both orders
    a = set(zip(np.asarray(g.coo_src).tolist(),
                np.asarray(g.coo_dst).tolist()))
    b = set(zip(np.asarray(g.push_src).tolist(),
                np.asarray(g.push_dst).tolist()))
    assert a == b
    # CSR pointers match degree counts
    assert np.all(np.diff(np.asarray(g.in_ptr)) == np.asarray(g.in_deg))
    assert np.all(np.diff(np.asarray(g.out_ptr)) == np.asarray(g.out_deg))


def test_build_graph_rejects_out_of_range_endpoints():
    with pytest.raises(ValueError, match=r"dst\[1\] = 3 is outside"):
        build_graph(np.array([0, 1]), np.array([1, 3]), 3)
    with pytest.raises(ValueError, match=r"src\[0\] = -1"):
        build_graph(np.array([-1]), np.array([0]), 2)
    with pytest.raises(ValueError, match="must be aligned"):
        build_graph(np.array([0, 1]), np.array([1]), 2)


def test_build_graph_rejects_nonfinite_weights():
    src, dst = np.array([0, 1]), np.array([1, 0])
    with pytest.raises(ValueError, match=r"weights\[1\].*not finite"):
        build_graph(src, dst, 2, weights=np.array([1.0, np.nan]))
    with pytest.raises(ValueError, match="not finite"):
        build_graph(src, dst, 2, weights=np.array([np.inf, 1.0]))
    with pytest.raises(ValueError, match="does not match"):
        build_graph(src, dst, 2, weights=np.array([1.0]))


def test_build_graph_boundary_vertex_still_accepted():
    # an edge into the last vertex (id n-1) sits exactly on the valid
    # boundary — the range check must not off-by-one it away
    g = build_graph(np.array([0, 4]), np.array([4, 0]), 5,
                    weights=np.array([2.0, 2.0]))
    assert g.n == 5 and g.m == 2
    assert int(np.asarray(g.in_deg)[4]) == 1
    # empty graphs skip the scan entirely
    assert build_graph(np.array([]), np.array([]), 3).m == 0


def test_ell_covers_all_in_edges(small_graph):
    g = small_graph
    idx = np.asarray(g.ell_idx)
    valid = idx < g.n
    assert valid.sum() == g.m
    # per-row valid count == in-degree
    assert np.all(valid.sum(1) == np.asarray(g.in_deg))


def test_weights_symmetric_per_pair(small_graph):
    g = small_graph
    src, dst, w = (np.asarray(g.coo_src), np.asarray(g.coo_dst),
                   np.asarray(g.coo_w))
    lookup = {(s, d): ww for s, d, ww in zip(src, dst, w)}
    for (s, d), ww in lookup.items():
        assert lookup[(d, s)] == ww


@pytest.mark.parametrize("gen", [
    lambda: erdos_renyi(200, 3.0, seed=1, weighted=True),
    lambda: kronecker(7, 4, seed=2, weighted=True),
    lambda: road_grid(12, weighted=True),
    lambda: ring(50, weighted=True),
    lambda: star(33),
])
def test_generators_simple_symmetric(gen):
    g = gen()
    src, dst = np.asarray(g.coo_src), np.asarray(g.coo_dst)
    assert np.all(src != dst), "no self loops"
    pairs = set(zip(src.tolist(), dst.tolist()))
    assert len(pairs) == g.m, "no duplicate directed edges"
    assert all((d, s) in pairs for s, d in pairs), "symmetric"


def test_standins_exist():
    for name in ("orc", "pok", "ljn", "am", "rca"):
        g = standin(name, scale=1.0 / 512)
        assert g.n >= 256 and g.m > 0


def test_partition_awareness_split(power_graph):
    g = power_graph
    part = partition_1d(g.n, 4)
    local, remote, stats = pa_split(g, part)
    # every edge lands in exactly one bucket
    assert int(local.count.sum() + remote.count.sum()) == g.m
    assert stats["cut_edges"] == int(remote.count.sum())
    # local edges: same owner; remote: different
    for p in range(4):
        ls = np.asarray(local.src[p])[: int(local.count[p])]
        ld = np.asarray(local.dst[p])[: int(local.count[p])]
        assert np.all(part.owner_np(ls) == part.owner_np(ld))
        assert np.all(part.owner_np(ls) == p)
        rs = np.asarray(remote.src[p])[: int(remote.count[p])]
        rd = np.asarray(remote.dst[p])[: int(remote.count[p])]
        assert np.all(part.owner_np(rs) != part.owner_np(rd))


def test_sampler_shapes_and_validity(small_graph):
    g = small_graph
    seeds = jnp.arange(16, dtype=jnp.int32)
    blocks = sample_blocks(g, seeds, (4, 3), jax.random.PRNGKey(0))
    assert blocks.node_ids[0].shape == (16,)
    assert blocks.node_ids[1].shape == (64,)
    assert blocks.node_ids[2].shape == (192,)
    # sampled children are real in-neighbors of their parents
    ids1 = np.asarray(blocks.node_ids[1]).reshape(16, 4)
    ok1 = np.asarray(blocks.valid[1]).reshape(16, 4)
    in_ptr = np.asarray(g.in_ptr)
    nbr = np.asarray(g.coo_src)
    for i in range(16):
        neigh = set(nbr[in_ptr[i]: in_ptr[i + 1]].tolist())
        for j in range(4):
            if ok1[i, j]:
                assert int(ids1[i, j]) in neigh


@given(n=st.integers(17, 200), p=st.integers(1, 9))
def test_partition_covers(n, p):
    part = partition_1d(n, p)
    owners = part.owner_np(np.arange(n))
    assert owners.min() >= 0 and owners.max() <= p - 1
    # contiguous blocks
    assert np.all(np.diff(owners) >= 0)


# -- pa_regroup_by_dst properties ---------------------------------------
# The destination-owner regroup is both the distributed pull layout and
# the push kernel's phase-1 binning pass (kernels/coo_push.py), so its
# invariants are load-bearing in two subsystems.
def _flat_edges(src, dst, w, n):
    """Wrap a flat edge list as a single-row PartitionedEdges."""
    m = len(src)
    if m == 0:
        return PartitionedEdges(
            src=jnp.full((1, 1), n, jnp.int32),
            dst=jnp.full((1, 1), n, jnp.int32),
            w=jnp.zeros((1, 1), jnp.float32),
            valid=jnp.zeros((1, 1), bool),
            count=jnp.zeros((1,), jnp.int32), cap=1, num_parts=1)
    return PartitionedEdges(
        src=jnp.asarray(src, jnp.int32).reshape(1, -1),
        dst=jnp.asarray(dst, jnp.int32).reshape(1, -1),
        w=jnp.asarray(w, jnp.float32).reshape(1, -1),
        valid=jnp.ones((1, m), bool),
        count=jnp.asarray([m], jnp.int32), cap=m, num_parts=1)


def _bin_rows(part, binned):
    """Per-bin edge tuples, in packed order, padding stripped."""
    out = []
    for p in range(part.num_parts):
        k = int(binned.count[p])
        out.append(list(zip(np.asarray(binned.src[p])[:k].tolist(),
                            np.asarray(binned.dst[p])[:k].tolist(),
                            np.asarray(binned.w[p])[:k].tolist())))
    return out


@given(n=st.integers(9, 120), m=st.integers(0, 240),
       parts=st.integers(1, 6), seed=st.integers(0, 2))
def test_regroup_by_dst_bins_every_edge_in_its_dst_bin(n, m, parts, seed):
    """Totality + ownership: every input edge appears exactly once, in
    the row owned by its destination; padding slots carry the sentinel."""
    rng = np.random.RandomState(seed)
    src = rng.randint(0, n, m)
    dst = rng.randint(0, n, m)
    w = rng.uniform(0.5, 2.0, m).astype(np.float32)
    part = partition_1d(n, parts)
    binned = pa_regroup_by_dst(part, _flat_edges(src, dst, w, n), n,
                               align=8)
    rows = _bin_rows(part, binned)
    assert sum(len(r) for r in rows) == m
    got = sorted(e for r in rows for e in r)
    want = sorted(zip(src.tolist(), dst.tolist(),
                      np.asarray(w, np.float32).tolist()))
    assert got == want
    for p, row in enumerate(rows):
        assert all(int(part.owner_np(np.int64(d))) == p
                   for _, d, _ in row)
        pad_dst = np.asarray(binned.dst[p])[len(row):]
        assert np.all(pad_dst == n)
        assert not np.asarray(binned.valid[p])[len(row):].any()


@given(n=st.integers(9, 120), m=st.integers(1, 240),
       parts=st.integers(1, 6), seed=st.integers(0, 2))
def test_regroup_by_dst_is_stable_and_permutation_invariant(n, m, parts,
                                                            seed):
    """Stability: within a bin, edges keep their input order (the push
    kernel's run pointers require it). Permutation invariance: shuffling
    the input permutes within-bin order but never the per-bin edge
    multiset."""
    rng = np.random.RandomState(seed + 100)
    src = rng.randint(0, n, m)
    dst = rng.randint(0, n, m)
    w = rng.uniform(0.5, 2.0, m).astype(np.float32)
    part = partition_1d(n, parts)
    rows = _bin_rows(part, pa_regroup_by_dst(
        part, _flat_edges(src, dst, w, n), n, align=8))
    own = part.owner_np(dst)
    triples = list(zip(src.tolist(), dst.tolist(),
                       np.asarray(w, np.float32).tolist()))
    for p, row in enumerate(rows):
        assert row == [t for t, o in zip(triples, own) if o == p]
    perm = rng.permutation(m)
    shuf = _bin_rows(part, pa_regroup_by_dst(
        part, _flat_edges(src[perm], dst[perm], w[perm], n), n, align=8))
    for row, srow in zip(rows, shuf):
        assert sorted(row) == sorted(srow)
