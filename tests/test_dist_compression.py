"""dist.compression — error-feedback compression of the DM exchange.

Properties, single-device: round-trip identities (kind="none" and a
top-k that keeps everything are exact), the error-feedback telescoping
invariant (the carried residual is exactly the cumulative
sent-vs-true gap, so the compressed stream is unbiased over time), and
the analytic wire-byte model. Multi-device (fresh interpreter, fake
devices): the raw ``dist.collectives`` exchanges agree with a numpy
reference at 1/2/4 parts, and a compressed sharded PageRank converges
to the uncompressed fixed point.
"""

from __future__ import annotations

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.compression import (CompressionConfig, compress_tree,
                                    compressed_bytes, init_error_state)


def _run_sub(script: str) -> subprocess.CompletedProcess:
    import os
    from pathlib import Path
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900,
                          env=env, cwd=str(root))


def test_none_kind_is_identity():
    g = jnp.arange(8.0)
    e = jnp.ones((8,))
    dec, err = compress_tree(g, e, CompressionConfig(kind="none"))
    assert dec is g and err is e


def test_topk_keeping_everything_is_exact_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (64,),
                          dtype=jnp.float32)
    err0 = init_error_state(x)
    dec, err = compress_tree(x, err0, CompressionConfig(
        kind="topk", topk_frac=1.0))
    assert bool(jnp.all(dec == x))
    assert bool(jnp.all(err == 0.0))


def test_topk_keeps_largest_magnitudes():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -0.3],
                    jnp.float32)
    dec, err = compress_tree(x, jnp.zeros_like(x), CompressionConfig(
        kind="topk", topk_frac=0.25))        # k = 2 of 8
    kept = np.flatnonzero(np.asarray(dec))
    assert set(kept) == {1, 3}
    np.testing.assert_allclose(np.asarray(dec + err), np.asarray(x),
                               rtol=1e-6)


def test_error_feedback_telescoping_invariant():
    """After T compressed steps, sent + carried == true cumulative
    signal exactly: err_T = Σ grads − Σ decs. This is the unbiasedness
    that lets a compressed push converge to the uncompressed fixed
    point."""
    cfg = CompressionConfig(kind="topk", topk_frac=0.1)
    key = jax.random.PRNGKey(7)
    err = init_error_state(jnp.zeros((50,), jnp.float32))
    sent = jnp.zeros((50,), jnp.float32)
    true = jnp.zeros((50,), jnp.float32)
    for t in range(10):
        key, k = jax.random.split(key)
        grad = jax.random.normal(k, (50,), dtype=jnp.float32)
        dec, err = compress_tree(grad, err, cfg)
        sent = sent + dec
        true = true + grad
    np.testing.assert_allclose(np.asarray(sent + err), np.asarray(true),
                               rtol=1e-5, atol=1e-5)


def test_int8_roundtrip_bounded_error():
    x = jax.random.normal(jax.random.PRNGKey(1), (256,),
                          dtype=jnp.float32) * 3.0
    dec, err = compress_tree(x, jnp.zeros_like(x),
                             CompressionConfig(kind="int8"))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(err))) <= scale * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(dec + err), np.asarray(x),
                               rtol=1e-5, atol=1e-6)


def test_compressed_bytes_model():
    tree = {"a": jnp.zeros((100,)), "b": jnp.zeros((10, 10))}
    assert compressed_bytes(tree, CompressionConfig(kind="none")) == 800
    assert compressed_bytes(
        tree, CompressionConfig(kind="int8")) == 2 * (100 + 4)
    assert compressed_bytes(tree, CompressionConfig(
        kind="topk", topk_frac=0.05)) == 2 * 5 * 8


# ---------------------------------------------------------------------
# multi-device: collectives parity + compressed fixed point

COLLECTIVES_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.dist.collectives import push_exchange, pull_exchange
from repro.graphs.generators import erdos_renyi
from repro.graphs.partition import (pa_regroup_by_dst, pa_split,
                                    partition_1d)

g = erdos_renyi(110, 4.0, seed=9, weighted=True)
vals_full = np.random.default_rng(0).random(110).astype(np.float32)
for P in (1, 2, 4):
    mesh = Mesh(np.array(jax.devices()[:P]).reshape(P, 1),
                ("data", "model"))
    part = partition_1d(g.n, P)
    local, remote, _ = pa_split(g, part)
    remote_dst = pa_regroup_by_dst(part, remote, g.n)
    vals = jnp.asarray(np.pad(vals_full, (0, part.n_padded - g.n)))
    # numpy reference: value*weight combined per destination over the
    # cut (the collectives' default message)
    ok = np.asarray(remote.valid).reshape(-1)
    src = np.asarray(remote.src).reshape(-1)[ok]
    dst = np.asarray(remote.dst).reshape(-1)[ok]
    w = np.asarray(remote.w).reshape(-1)[ok]
    want = np.zeros((part.n_padded,), np.float32)
    np.add.at(want, dst, vals_full[src] * w)
    got_push, _ = push_exchange(mesh, part, remote, vals)
    got_pull, _ = pull_exchange(mesh, part, remote_dst, vals)
    okp = np.allclose(np.asarray(got_push), want, atol=1e-5)
    okl = np.allclose(np.asarray(got_pull), want, atol=1e-5)
    print(f"collectives P={P} push ok: {okp} pull ok: {okl}")
"""


@pytest.mark.dist
@pytest.mark.subprocess
def test_collectives_parity_across_parts():
    """push_exchange and pull_exchange agree with a numpy combining
    reference over the cut at 1, 2, and 4 parts."""
    r = _run_sub(COLLECTIVES_PARITY)
    assert r.returncode == 0, r.stdout + r.stderr
    for P in (1, 2, 4):
        line = f"collectives P={P} push ok: True pull ok: True"
        assert line in r.stdout, (line, r.stdout + r.stderr)


COMPRESSED_PAGERANK = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax.numpy as jnp
from repro import api
from repro.dist.compression import CompressionConfig
from repro.graphs.generators import erdos_renyi
from repro.shard import ShardedBackend

g = erdos_renyi(130, 4.0, seed=5, weighted=True)
ref = api.solve(g, "pagerank", policy="push", iters=40)

def run(frac):
    cfg = CompressionConfig(kind="topk", topk_frac=frac)
    sb = ShardedBackend.prepare(g, num_shards=4, compression=cfg)
    return api.solve(g, "pagerank", policy="push", backend=sb, iters=40)

err, mass = {}, {}
for frac in (0.25, 0.5, 0.75, 1.0):
    got = run(frac)
    err[frac] = float(jnp.max(jnp.abs(got.state - ref.state))
                      / jnp.max(ref.state))
    mass[frac] = float(jnp.sum(got.state))
    print(f"frac={frac} sup_rel={err[frac]:.3e} mass={mass[frac]:.4f}")
# once k covers the remote accumulator's support, the compressed
# exchange IS the uncompressed exchange (up to reassociation)
print("exact when support covered ok:",
      err[0.75] < 1e-5 and err[1.0] < 1e-5)
# below that, EF cycling wobble shrinks monotonically with the budget
print("error monotone in budget ok:",
      err[0.25] >= err[0.5] >= err[0.75])
# error feedback conserves rank mass over time even while compressing
print("mass conserved ok:",
      all(abs(mass[f] - 1.0) < 0.05 for f in (0.25, 0.5, 0.75, 1.0)))
un = api.solve(g, "pagerank", policy="push",
               backend=ShardedBackend.prepare(g, num_shards=4), iters=40)
cb = run(0.05)
print("wire bytes shrink ok:",
      int(cb.cost.collective_bytes) < int(un.cost.collective_bytes))
"""


@pytest.mark.dist
@pytest.mark.subprocess
def test_compressed_push_converges_to_uncompressed_fixed_point():
    """PageRank through the sharded push with error-feedback top-k:
    once the top-k budget covers the remote accumulator's support the
    run is the uncompressed fixed point (≤1e-5 sup-norm); below that
    the EF cycling error shrinks monotonically with the budget while
    total rank mass stays conserved; and the charged wire bytes shrink
    versus the uncompressed exchange."""
    r = _run_sub(COMPRESSED_PAGERANK)
    assert r.returncode == 0, r.stdout + r.stderr
    checks = [l for l in r.stdout.splitlines() if "ok:" in l]
    assert len(checks) == 4, r.stdout + r.stderr
    for line in checks:
        assert line.rstrip().endswith("True"), \
            (line, r.stdout + r.stderr)
