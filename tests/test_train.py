"""Training substrate: optimizer, checkpoint fault tolerance, loop,
watchdog, compression."""

import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import (CompressionConfig, compress_tree, compressed_bytes,
                        init_error_state, microbatch_grads)
from repro.train import (LoopConfig, OptConfig, TrainLoop, Watchdog,
                         apply_updates, checkpoint as ckpt, init_opt)


def _quad_problem():
    """min ||Wx - y||^2 — convex, convergence is checkable."""
    key = jax.random.PRNGKey(0)
    W_true = jax.random.normal(key, (8, 8))
    xs = jax.random.normal(jax.random.fold_in(key, 1), (64, 8))
    ys = xs @ W_true.T
    params = {"w": jnp.zeros((8, 8))}

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"].T
        return jnp.mean((pred - batch["y"]) ** 2)

    def batches():
        while True:
            yield {"x": xs, "y": ys}

    return params, loss_fn, batches


def test_adamw_converges():
    params, loss_fn, batches = _quad_problem()
    cfg = OptConfig(lr=5e-2, total_steps=300, warmup_steps=10,
                    weight_decay=0.0)
    state = init_opt(params, cfg)
    it = batches()
    b = next(it)
    for _ in range(300):
        loss, g = jax.value_and_grad(loss_fn)(params, b)
        params, state = apply_updates(params, g, state, cfg)
    assert float(loss_fn(params, b)) < 1e-2


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    cfg = OptConfig(kind="sgd", lr=1.0, clip_norm=0.1, warmup_steps=0,
                    total_steps=10, momentum=0.0)
    state = init_opt(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    new_params, _ = apply_updates(params, huge, state, cfg)
    assert float(jnp.linalg.norm(new_params["w"])) <= 0.11


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    d = str(tmp_path)
    ckpt.save(d, 7, tree, meta={"next_step": 7})
    assert ckpt.latest_step(d) == 7
    restored, meta = ckpt.restore(d, 7, tree)
    assert meta["next_step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_corruption_detected(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.ones((3,))}
    path = ckpt.save(d, 1, tree)
    with open(os.path.join(path, "arrays.npz"), "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad")
    assert ckpt.latest_step(d) is None       # CRC rejects the torn file
    with pytest.raises(IOError):
        ckpt.restore(d, 1, tree)


def test_checkpoint_torn_write_invisible(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.ones((3,))}
    ckpt.save(d, 1, tree)
    # simulate a crash mid-write: tmp dir left behind
    os.makedirs(os.path.join(d, "step_000000002.tmp-zzz"), exist_ok=True)
    assert ckpt.latest_step(d) == 1
    ckpt.gc_tmp(d)
    assert not any(".tmp-" in n for n in os.listdir(d))


def test_loop_resume_after_crash(tmp_path):
    params, loss_fn, batches = _quad_problem()
    d = str(tmp_path)
    lp = LoopConfig(total_steps=10, ckpt_every=5, ckpt_dir=d, log_every=5)
    oc = OptConfig(lr=1e-2, total_steps=10)
    tl = TrainLoop(loss_fn, params, oc, lp)
    res = tl.run(batches(), steps=7)
    assert res["final_step"] == 7
    # "crash": new loop instance resumes from step 5 checkpoint... the
    # terminal save wrote step 7, so resume lands there
    tl2 = TrainLoop(loss_fn, params, oc, lp)
    assert tl2.start_step == 7
    res2 = tl2.run(batches(), steps=10)
    assert res2["final_step"] == 10


def test_watchdog_flags_stragglers():
    wd = Watchdog(factor=3.0)
    for i in range(20):
        wd.observe(i, 0.01)
    assert wd.observe(21, 0.5) is True
    assert wd.observe(22, 0.011) is False
    assert len(wd.stragglers) == 1


def test_compression_error_feedback_preserves_signal():
    key = jax.random.PRNGKey(2)
    g = {"w": jax.random.normal(key, (64,))}
    err = init_error_state(g)
    cfg = CompressionConfig(kind="topk", topk_frac=0.1)
    total = jnp.zeros((64,))
    for _ in range(30):
        dec, err = compress_tree(g, err, cfg)
        total = total + dec["w"]
    # error feedback: accumulated compressed sum approaches 30 * g
    rel = float(jnp.linalg.norm(total - 30 * g["w"])
                / jnp.linalg.norm(30 * g["w"]))
    assert rel < 0.2


def test_compression_byte_accounting():
    params = {"w": jnp.zeros((1000,), jnp.float32),
              "b": jnp.zeros((10,), jnp.float32)}
    none_b = compressed_bytes(params, CompressionConfig("none"))
    int8_b = compressed_bytes(params, CompressionConfig("int8"))
    topk_b = compressed_bytes(params, CompressionConfig("topk", 0.01))
    assert none_b == 1010 * 4
    assert int8_b < none_b / 3
    assert topk_b < int8_b


def test_int8_compression_roundtrip_quality():
    g = {"w": jax.random.normal(jax.random.PRNGKey(3), (256,))}
    dec, err = compress_tree(g, init_error_state(g),
                             CompressionConfig("int8"))
    rel = float(jnp.linalg.norm(dec["w"] - g["w"])
                / jnp.linalg.norm(g["w"]))
    assert rel < 0.02


def test_microbatch_grads_match_full_batch():
    params, loss_fn, batches = _quad_problem()
    b = next(batches())
    g_full = jax.grad(loss_fn)(params, b)
    g_micro, _ = microbatch_grads(loss_fn, params, b, num_micro=4)
    np.testing.assert_allclose(np.asarray(g_full["w"]),
                               np.asarray(g_micro["w"]), atol=1e-5)


def test_loop_trains_lm_end_to_end(tmp_path):
    from repro.models.transformer import (TransformerConfig, init_params,
                                          lm_loss)
    from repro.data import token_batches, prefetch
    cfg = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=2,
                            n_kv_heads=2, d_ff=64, vocab=64,
                            dtype="float32", loss_chunk=16,
                            attn_impl="naive")
    p = init_params(jax.random.PRNGKey(0), cfg)
    loss_fn = lambda pp, b: lm_loss(pp, cfg, b["tokens"], b["labels"])  # noqa
    tl = TrainLoop(loss_fn, p, OptConfig(lr=3e-3, total_steps=30),
                   LoopConfig(total_steps=30, ckpt_dir=None, log_every=10))
    res = tl.run(prefetch(token_batches(4, 16, 64, seed=1), 2))
    losses = [h["loss"] for h in res["history"]]
    assert res["final_loss"] < losses[0]      # it learns
