"""Model-layer tests: transformer variants, MoE dispatch, GNNs, recsys."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import AttnConfig, _sdpa, blockwise_sdpa
from repro.models.gnn import (GNNConfig, egnn_apply, egnn_init, gin_apply,
                              gin_init, graphcast_apply, graphcast_init,
                              sage_apply, sage_apply_blocks, sage_init)
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.models.recsys import (XDeepFMConfig, cin_apply, retrieval_score,
                                 xdeepfm_apply, xdeepfm_init)
from repro.models.transformer import (TransformerConfig, decode_step,
                                      forward, init_kv_cache, init_params,
                                      lm_loss, prefill)
from repro.graphs import erdos_renyi, sample_blocks
from repro.sparse import embedding_bag

KEY = jax.random.PRNGKey(0)

TINY = TransformerConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                         n_kv_heads=2, d_ff=128, vocab=101, dtype="float32",
                         loss_chunk=8, attn_impl="naive")


def test_blockwise_equals_naive_attention():
    B, T, H, Hk, Dh = 2, 45, 8, 4, 16
    cfg = AttnConfig(d_model=H * Dh, n_heads=H, n_kv_heads=Hk, head_dim=Dh)
    q = jax.random.normal(KEY, (B, T, H, Dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, Hk, Dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, Hk, Dh))
    qp = jnp.arange(T)[:, None]
    kp = jnp.arange(T)[None, :]
    ref = _sdpa(q, k, v, kp <= qp, cfg)
    out = blockwise_sdpa(q, k, v, cfg, jnp.int32(1 << 30), 16, 8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_decode_matches_forward_exactly():
    p = init_params(KEY, TINY)
    toks = jax.random.randint(KEY, (2, 12), 0, TINY.vocab)
    cache = init_kv_cache(TINY, 2, 12, kind="f32")
    for t in range(12):
        logits, cache = decode_step(p, TINY, toks[:, t:t + 1], cache,
                                    jnp.int32(t))
    hs = forward(p, TINY, toks)
    ref = hs[:, -1].astype(jnp.float32) @ p["unembed"]["w"]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               atol=1e-4)


def test_prefill_matches_decode_cache():
    p = init_params(KEY, TINY)
    toks = jax.random.randint(KEY, (2, 10), 0, TINY.vocab)
    logits_p, cache_p = prefill(p, TINY, toks, cache_kind="f32")
    cache_d = init_kv_cache(TINY, 2, 10, kind="f32")
    for t in range(10):
        logits_d, cache_d = decode_step(p, TINY, toks[:, t:t + 1], cache_d,
                                        jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache_p["k"]),
                               np.asarray(cache_d["k"]), atol=1e-5)


def test_gemma2_ring_cache_decode():
    cfg = dataclasses.replace(TINY, n_layers=4, local_window=4,
                              attn_softcap=50.0, final_softcap=30.0,
                              embed_scale=True, vocab=97)
    p = init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(KEY, (2, 14), 0, 97)
    cache = init_kv_cache(cfg, 2, 14, kind="f32")
    assert cache["local"]["k"].shape[2] == 4       # ring = window
    for t in range(14):
        logits, cache = decode_step(p, cfg, toks[:, t:t + 1], cache,
                                    jnp.int32(t))
    hs = forward(p, cfg, toks)
    from repro.models.common import softcap
    ref = softcap(hs[:, -1].astype(jnp.float32) @ p["unembed"]["w"], 30.0)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               atol=2e-4)


def test_gemma2_prefill_ring_layout():
    cfg = dataclasses.replace(TINY, n_layers=2, local_window=4, vocab=97)
    p = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 11), 0, 97)
    logits_p, cache_p = prefill(p, cfg, toks, cache_kind="f32")
    cache_d = init_kv_cache(cfg, 1, 11, kind="f32")
    logits_d = None
    for t in range(11):
        logits_d, cache_d = decode_step(p, cfg, toks[:, t:t + 1], cache_d,
                                        jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(cache_p["local"]["k"]),
                               np.asarray(cache_d["local"]["k"]), atol=1e-5)


def test_int8_cache_bounded_error():
    p = init_params(KEY, TINY)
    toks = jax.random.randint(KEY, (2, 10), 0, TINY.vocab)
    cache8 = init_kv_cache(TINY, 2, 10, kind="int8")
    cachef = init_kv_cache(TINY, 2, 10, kind="f32")
    for t in range(10):
        l8, cache8 = decode_step(p, TINY, toks[:, t:t + 1], cache8,
                                 jnp.int32(t))
        lf, cachef = decode_step(p, TINY, toks[:, t:t + 1], cachef,
                                 jnp.int32(t))
    rel = float(jnp.abs(l8 - lf).max() / (jnp.abs(lf).max() + 1e-9))
    assert rel < 0.05


def test_moe_push_pull_dispatch_equal():
    cfg = MoEConfig(d_model=32, d_ff_expert=16, n_experts=8, top_k=2,
                    n_shared=1)
    params = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 10, 32))
    y_push = moe_apply(params, cfg, x)
    y_pull = moe_apply(params, dataclasses.replace(cfg, dispatch="pull"), x)
    np.testing.assert_allclose(np.asarray(y_push), np.asarray(y_pull),
                               atol=1e-5)


def test_moe_capacity_drops_tokens():
    cfg = MoEConfig(d_model=16, d_ff_expert=8, n_experts=4, top_k=2,
                    capacity_factor=0.25)
    params = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 32, 16))
    _, aux = moe_apply(params, cfg, x, return_aux=True)
    assert float(aux["dropped_frac"]) > 0.0
    assert float(aux["lb_loss"]) > 0.0


def test_lm_loss_grads_finite_all_variants():
    for cfg in (TINY,
                dataclasses.replace(TINY, attn_impl="blockwise", q_chunk=8,
                                    kv_chunk=8),
                dataclasses.replace(
                    TINY, moe=MoEConfig(d_model=64, d_ff_expert=32,
                                        n_experts=4, top_k=2))):
        p = init_params(KEY, cfg)
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
        loss, grads = jax.value_and_grad(
            lambda pp: lm_loss(pp, cfg, toks, toks))(p)
        assert bool(jnp.isfinite(loss))
        assert all(bool(jnp.all(jnp.isfinite(g)))
                   for g in jax.tree.leaves(grads))


# ------------------------------------------------------------- GNNs -----
def test_gnn_push_pull_equal():
    g = erdos_renyi(80, 4.0, seed=9, weighted=True)
    h = jax.random.normal(KEY, (g.n, 8))
    for init_fn, apply_fn, kw in (
            (gin_init, gin_apply, {}),
            (sage_init, sage_apply, {})):
        cfg = GNNConfig(arch="x", n_layers=2, d_hidden=16, d_in=8, d_out=4)
        p = init_fn(KEY, cfg)
        a = apply_fn(p, cfg, g, h, **kw)
        b = apply_fn(p, dataclasses.replace(cfg, direction="push"), g, h,
                     **kw)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_egnn_equivariance():
    g = erdos_renyi(60, 4.0, seed=10, weighted=True)
    cfg = GNNConfig(arch="egnn", n_layers=2, d_hidden=16, d_in=6, d_out=3)
    p = egnn_init(KEY, cfg)
    h = jax.random.normal(KEY, (g.n, 6))
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (g.n, 3))
    out1, x1 = egnn_apply(p, cfg, g, h, x)
    th = 1.1
    R = jnp.array([[np.cos(th), -np.sin(th), 0],
                   [np.sin(th), np.cos(th), 0], [0, 0, 1.0]])
    t = jnp.array([2.0, -1.0, 0.5])
    out2, x2 = egnn_apply(p, cfg, g, h, x @ R.T + t)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(x1 @ R.T + t), np.asarray(x2),
                               atol=1e-3)


def test_sage_blocks_runs():
    g = erdos_renyi(100, 5.0, seed=12)
    cfg = GNNConfig(arch="sage", n_layers=2, d_hidden=16, d_in=8, d_out=4,
                    fanouts=(4, 3))
    p = sage_init(KEY, cfg)
    seeds = jnp.arange(8, dtype=jnp.int32)
    blocks = sample_blocks(g, seeds, (4, 3), KEY)
    h = jax.random.normal(KEY, (g.n, 8))
    hp = jnp.pad(h, ((0, 1), (0, 0)))
    feats = tuple(hp[jnp.minimum(ids, g.n)] for ids in blocks.node_ids)
    out = sage_apply_blocks(p, cfg, blocks, feats)
    assert out.shape == (8, 4)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_graphcast_residual_prediction():
    g = erdos_renyi(70, 4.0, seed=13, weighted=True)
    cfg = GNNConfig(arch="graphcast", n_layers=3, d_hidden=16, d_in=0,
                    d_out=0, n_vars=5)
    p = graphcast_init(KEY, cfg)
    nv = jax.random.normal(KEY, (g.n, 5))
    out = graphcast_apply(p, cfg, g, nv)
    assert out.shape == (g.n, 5)
    assert bool(jnp.all(jnp.isfinite(out)))


# ----------------------------------------------------------- recsys -----
def test_embedding_bag_oracle():
    V, d = 30, 4
    table = jax.random.normal(KEY, (V, d))
    ids = jnp.array([3, 5, 5, 29, 0, 7], jnp.int32)
    bags = jnp.array([0, 0, 1, 1, 2, 2], jnp.int32)
    for comb in ("sum", "mean", "max"):
        out = embedding_bag(table, ids, bags, 3, combiner=comb)
        tab = np.asarray(table)
        want = []
        for b in range(3):
            rows = tab[np.asarray(ids)[np.asarray(bags) == b]]
            want.append({"sum": rows.sum(0), "mean": rows.mean(0),
                         "max": rows.max(0)}[comb])
        np.testing.assert_allclose(np.asarray(out), np.stack(want),
                                   atol=1e-5)


def test_embedding_bag_padding_ids():
    table = jnp.ones((10, 2))
    ids = jnp.array([1, 10, 11], jnp.int32)   # 10,11 out of range = pad
    bags = jnp.array([0, 0, 1], jnp.int32)
    out = embedding_bag(table, ids, bags, 2, combiner="sum")
    np.testing.assert_allclose(np.asarray(out), [[1, 1], [0, 0]])


def test_xdeepfm_structure():
    cfg = XDeepFMConfig(n_fields=5, vocab_per_field=40, embed_dim=4,
                        cin_layers=(6, 6), mlp_dims=(8,))
    p = xdeepfm_init(KEY, cfg)
    ids = jax.random.randint(KEY, (16, 5), 0, 40)
    logits = xdeepfm_apply(p, cfg, ids)
    assert logits.shape == (16,)
    # CIN oracle cross-check (first layer)
    x0 = jnp.stack([p["tables"][f][ids[:, f]] for f in range(5)], axis=1)
    feat = cin_apply(p["cin"], x0)
    assert feat.shape == (16, 12)
    u = jax.random.randint(KEY, (1, 2), 0, 40)
    c = jax.random.randint(KEY, (64, 3), 0, 40)
    sc = retrieval_score(p, cfg, u, c)
    assert sc.shape == (64,)
