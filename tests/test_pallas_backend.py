"""PallasBackend: the push/pull Pallas kernels on the engine hot path.

Three layers of evidence that the kernels are production-grade:

  * kernel vs primitive parity — ``ell_spmv_pallas`` ≡ ``pull_relax_ell``
    and ``coo_push_pallas`` ≡ ``push_relax`` across combine × dtype ×
    payload rank × ragged n, interpret mode (bit-exact wherever the
    reduction order matches, incl. the empty-row combine identity);
  * dispatch — msg_fn classification, the jnp fallback for unsupported
    cells, the traced bin-capacity guard, the shape-keyed autotuner
    cache and its on-disk tier, the backend's per-graph bin-plan cache;
  * end to end — ``solve(..., backend="pallas")`` reproduces the dense
    backend on BFS / PageRank / SSSP for push, pull, and auto policies,
    and ``solve_batch`` runs [n, B] payloads through the kernel path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import Cost, EllBackend, PallasBackend, classify_msg_fn
from repro.core.primitives import (combine_identity, pull_relax_ell,
                                   push_relax)
from repro.graphs import build_graph, erdos_renyi
from repro.kernels.coo_push import (PUSH_STRATEGIES, build_push_plan,
                                    coo_push_pallas)
from repro.kernels.ell_spmv import ell_spmv_pallas
from repro.kernels.tune import pull_candidates, push_candidates

COMBINES = ("sum", "max", "min")
DTYPES = (jnp.float32, jnp.int32, jnp.int64)


@pytest.fixture(scope="module")
def ragged_graph():
    # n = 130: not a multiple of any kernel block size -> exercises the
    # grid padding rows/edges on every call
    return erdos_renyi(130, 4.0, seed=1, weighted=True)


def _payload(g, dtype, batch):
    shape = (g.n,) if batch is None else (g.n, batch)
    key = jax.random.PRNGKey(7)
    if jnp.issubdtype(dtype, jnp.floating):
        return jax.random.normal(key, shape, dtype)
    return jax.random.randint(key, shape, -50, 50).astype(dtype)


def _assert_kernel_equal(got, want, order_matches: bool):
    got, want = np.asarray(got), np.asarray(want)
    assert got.dtype == want.dtype
    if order_matches or got.dtype.kind != "f":
        np.testing.assert_array_equal(got, want)
    else:  # float sums over a different edge order: tight allclose
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# -- kernel vs primitive parity -----------------------------------------
@pytest.mark.parametrize("batch", [None, 3])
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: d.__name__)
@pytest.mark.parametrize("combine", COMBINES)
def test_ell_kernel_matches_pull_primitive(ragged_graph, combine, dtype,
                                           batch):
    """msg="copy" (the primitives' msg_fn=None) — same row reduce, same
    empty-row identity, bit for bit, at a non-block-aligned n."""
    g = ragged_graph
    x = _payload(g, dtype, batch)
    want, _ = pull_relax_ell(g, x, combine=combine)
    xp = jnp.pad(x, [(0, 1)] + [(0, 0)] * (x.ndim - 1))
    got = ell_spmv_pallas(xp, g.ell_idx, g.ell_w, combine=combine,
                          msg="copy", block_n=64)
    _assert_kernel_equal(got, want, order_matches=True)


@pytest.mark.parametrize("strategy", PUSH_STRATEGIES)
@pytest.mark.parametrize("batch", [None, 3])
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: d.__name__)
@pytest.mark.parametrize("combine", COMBINES)
def test_coo_kernel_matches_push_primitive(ragged_graph, combine, dtype,
                                           batch, strategy):
    """Partial frontier push: kernel combine over dst-sorted edges ≡
    push_relax's segment combine (float sums differ only in edge
    order), for both phase-2 reduce strategies."""
    g = ragged_graph
    x = _payload(g, dtype, batch)
    frontier = jax.random.uniform(jax.random.PRNGKey(3), (g.n,)) < 0.4
    want, _ = push_relax(g, x, frontier, combine=combine)
    got = coo_push_pallas(x, frontier, g.coo_src, g.coo_dst, g.coo_w,
                          g.n, combine=combine, msg="copy", block_e=64,
                          block_n=128, strategy=strategy)
    order_matches = not (combine == "sum"
                         and jnp.issubdtype(dtype, jnp.floating))
    _assert_kernel_equal(got, want, order_matches=order_matches)


@pytest.mark.parametrize("msg,msg_fn", [
    ("mul", lambda v, w: v * w), ("add", lambda v, w: v + w)])
def test_kernel_msg_modes_match_msg_fns(ragged_graph, msg, msg_fn):
    g = ragged_graph
    x = _payload(g, jnp.float32, None)
    want, _ = pull_relax_ell(g, x, combine="min", msg_fn=msg_fn)
    got = ell_spmv_pallas(jnp.pad(x, (0, 1)), g.ell_idx, g.ell_w,
                          combine="min", msg=msg, block_n=64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    want, _ = push_relax(g, x, jnp.ones((g.n,), bool), combine="min",
                         msg_fn=msg_fn)
    got = coo_push_pallas(x, jnp.ones((g.n,), bool), g.coo_src,
                          g.coo_dst, g.coo_w, g.n, combine="min",
                          msg=msg, block_e=64, block_n=128)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ell_empty_rows_hold_combine_identity():
    """The old kernel rewrote empty-row ±inf to 0.0; it must return the
    combine identity so mask_untouched/convergence agree bit-for-bit."""
    # vertex 5 has no in-edges at all (edges only among 0..4)
    g = build_graph([0, 1, 2, 3], [1, 2, 3, 4], n=6)
    x = jnp.arange(1.0, 7.0, dtype=jnp.float32)
    for combine in COMBINES:
        got = ell_spmv_pallas(jnp.pad(x, (0, 1)), g.ell_idx, g.ell_w,
                              combine=combine, msg="copy", block_n=8)
        want, _ = pull_relax_ell(g, x, combine=combine)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        ident = combine_identity(combine, jnp.float32)
        assert np.asarray(got)[5] == np.asarray(ident)
        assert np.asarray(got)[0] == np.asarray(ident)  # 0 in-deg too


def test_coo_push_padding_never_aims_at_last_vertex():
    """Regression: padded edges used to carry dst = n-1 (a real
    vertex). A graph whose last vertex has nonzero in-degree must get
    exactly its own messages, for every block shape that forces
    padding."""
    n = 9
    g = build_graph(np.arange(8), np.full(8, 8), n=n)  # all into v8
    x = jnp.arange(1.0, 10.0, dtype=jnp.float32)
    act = jnp.ones((n,), bool)
    want, _ = push_relax(g, x, act)
    for block_e, block_n in ((16, 8), (8, 8), (32, 16)):
        got = coo_push_pallas(x, act, g.coo_src, g.coo_dst, g.coo_w, n,
                              block_e=block_e, block_n=block_n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)
    # min-combine would surface a sentinel aimed at v8 as a wrong 0
    want, _ = push_relax(g, x, act, combine="min")
    got = coo_push_pallas(x, act, g.coo_src, g.coo_dst, g.coo_w, n,
                          combine="min", block_e=16, block_n=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_push_bin_cap_guard_falls_back_correctly():
    """Traced path (the engine jits the graph): when the static bin
    capacity cannot hold the skewest bin, the lax.cond fits guard must
    route to the jnp branch and still produce the primitive's answer;
    with enough capacity the same trace takes the kernel."""
    # 16 edges all into dst 0: bin 0 holds 16 edges
    src = np.arange(16)
    dst = np.zeros(16, np.int64)
    g = build_graph(src, dst, n=24)
    x = jnp.arange(24, dtype=jnp.float32)
    act = jnp.ones((24,), bool)
    want, _ = push_relax(g, x, act)
    for cap in (8, 32):  # 8 < 16 edges -> fallback; 32 -> kernel
        backend = PallasBackend(block_e=8, push_block_n=8,
                                push_strategy="scan", push_bin_cap=cap,
                                autotune=False)
        out = jax.jit(lambda g, v, f, b=backend: b.push(
            g, v, f, "sum", None, Cost())[0])(g, x, act)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-6)


def test_push_edgeless_bin_holds_combine_identity():
    """Regression (mirrors the PR 5 empty-ELL-row fix): a bin whose
    edge block is all padding must return the combine identity without
    reading the sentinel row — a min over negative payloads would
    surface any sentinel read as a wrong value."""
    n = 256
    # edges land only in bin 1 (dst >= 128): bin 0 is pure padding
    src = np.arange(12)
    dst = np.arange(130, 142)
    g = build_graph(src, dst, n=n)
    x = -jnp.arange(1.0, n + 1.0, dtype=jnp.float32)  # all negative
    act = jnp.ones((n,), bool)
    for strategy in PUSH_STRATEGIES:
        for combine in COMBINES:
            plan = build_push_plan(g.coo_src, g.coo_dst, g.coo_w, n,
                                   bin_n=128, align=64)
            got = coo_push_pallas(x, act, g.coo_src, g.coo_dst, g.coo_w,
                                  n, combine=combine, msg="copy",
                                  block_e=64, block_n=128, plan=plan,
                                  strategy=strategy)
            want, _ = push_relax(g, x, act, combine=combine)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))
            ident = combine_identity(combine, jnp.float32)
            assert (np.asarray(got)[:128] == np.asarray(ident)).all()


def test_edgeless_graph_runs_on_pallas():
    """m=0 regression: grid=(0,) pallas_call crashed; every destination
    must hold the combine identity, like the segment primitives."""
    g = build_graph(np.zeros((0,), np.int64), np.zeros((0,), np.int64),
                    n=5)
    x = jnp.arange(5, dtype=jnp.float32)
    act = jnp.ones((5,), bool)
    for combine in COMBINES:
        got = coo_push_pallas(x, act, g.coo_src, g.coo_dst, g.coo_w,
                              g.n, combine=combine, msg="copy")
        want, _ = push_relax(g, x, act, combine=combine)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    r = api.solve(g, "bfs", root=0, backend=PallasBackend())
    assert int(np.asarray(r.state["dist"])[0]) == 0
    assert bool(r.converged)


def test_backend_instances_hash_by_identity():
    """Engine caches key on the backend: differently-configured
    PallasBackends must not compare equal (eq=False alone inherited
    EllBackend's field-blind value equality)."""
    a = PallasBackend()
    b = PallasBackend(autotune=False, block_n=64, block_e=8,
                      push_block_n=8, interpret=True)
    assert a != b                 # value equality would collide caches
    assert a == a
    assert hash(a) == hash(a)     # usable as an engine-cache key
    from repro.core import DistributedBackend
    assert DistributedBackend.__eq__ is not EllBackend.__eq__


# -- dispatch -----------------------------------------------------------
def test_classify_msg_fn_modes():
    assert classify_msg_fn(None) == "copy"
    assert classify_msg_fn(lambda v, w: v) == "copy"
    assert classify_msg_fn(lambda v, w: v * w) == "mul"
    assert classify_msg_fn(lambda v, w: v + w) == "add"
    assert classify_msg_fn(lambda v, w: v * v) is None
    assert classify_msg_fn(lambda v, w: w) is None

    # classification must also work while an outer trace is live (the
    # engine classifies during while_loop tracing)
    seen = []

    def traced(v):
        seen.append(classify_msg_fn(lambda x, w: x + w))
        return v

    jax.jit(traced)(jnp.ones((3,)))
    assert seen == ["add"]


def test_unsupported_msg_fn_falls_back_to_ell_path(ragged_graph):
    g = ragged_graph
    x = _payload(g, jnp.float32, None)
    weird = lambda v, w: v * v  # noqa: E731
    backend = PallasBackend()
    before = dict(backend.stats)
    got, _ = backend.pull(g, x, None, "sum", weird, Cost())
    want, _ = EllBackend().pull(g, x, None, "sum", weird, Cost())
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert backend.stats["fallback_pull"] == before["fallback_pull"] + 1
    got, _ = backend.push(g, x, jnp.ones((g.n,), bool), "sum", weird,
                          Cost())
    want, _ = push_relax(g, x, jnp.ones((g.n,), bool), msg_fn=weird)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)
    assert backend.stats["fallback_push"] == before["fallback_push"] + 1


def test_pallas_pull_charges_ell_cost_and_scans_all(ragged_graph):
    """A touched=None kernel pull scans every edge and charges exactly
    the ELL primitive's counters. pull_scans_all is now False — the
    frontier kernel restricts touched pulls (test_pull_frontier.py) —
    but the dense-destination case must keep the full-scan price."""
    g = ragged_graph
    assert not PallasBackend.pull_scans_all
    x = _payload(g, jnp.float32, None)
    backend = PallasBackend()
    _, c_kernel = backend.pull(g, x, None, "sum", None, Cost())
    _, c_ell = EllBackend().pull(g, x, None, "sum", None, Cost())
    assert c_kernel.as_dict() == c_ell.as_dict()
    _, p_kernel = backend.push(g, x, jnp.ones((g.n,), bool), "sum",
                               None, Cost())
    _, p_dense = push_relax(g, x, jnp.ones((g.n,), bool))
    # kernel push = primitive's charge + the phase-1 binning pass
    # (reads and rewrites every edge once)
    pk, pd = p_kernel.as_dict(), p_dense.as_dict()
    assert pk.pop("reads") == pd.pop("reads") + g.m
    assert pk.pop("writes") == pd.pop("writes") + g.m
    assert pk == pd


def test_autotuner_caches_per_shape(ragged_graph):
    g = ragged_graph
    backend = PallasBackend()
    x = _payload(g, jnp.float32, None)
    backend.pull(g, x, None, "sum", None, Cost())
    keys = set(backend._tuned)
    assert len(keys) == 1
    bn = next(iter(backend._tuned.values()))
    assert bn in pull_candidates(g.n)
    backend.pull(g, x, None, "sum", None, Cost())   # cache hit
    assert set(backend._tuned) == keys
    backend.push(g, x, jnp.ones((g.n,), bool), "sum", None, Cost())
    (pk,) = [k for k in backend._tuned if k[0] == "push"]
    assert backend._tuned[pk] in push_candidates(g.n, g.m)
    be, bn, strat = backend._tuned[pk]
    assert strat in PUSH_STRATEGIES
    # a partial pin overrides only its own component
    half = PallasBackend(push_block_n=512, autotune=False)
    pe, pn, ps = half._push_blocks(g, x, "sum", "copy")
    first = push_candidates(g.n, g.m)[0]
    assert (pe, pn, ps) == (first[0], 512, first[2])


def test_push_plan_cached_per_graph(ragged_graph):
    """Concrete-graph pushes build the phase-1 bin layout once and
    reuse it; a different (bin width, edge block) gets its own entry."""
    g = ragged_graph
    backend = PallasBackend(block_e=64, push_block_n=64,
                            push_strategy="scan", autotune=False)
    x = _payload(g, jnp.float32, None)
    act = jnp.ones((g.n,), bool)
    out, _ = backend.push(g, x, act, "sum", None, Cost())
    want, _ = push_relax(g, x, act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert len(backend._plans) == 1
    plan = next(iter(backend._plans.values()))[1]
    backend.push(g, x, act, "sum", None, Cost())
    assert next(iter(backend._plans.values()))[1] is plan  # cache hit
    assert len(backend._plans) == 1
    wide = PallasBackend(block_e=32, push_block_n=128,
                         push_strategy="scan", autotune=False)
    wide.push(g, x, act, "sum", None, Cost())
    assert next(iter(wide._plans))[1:] == (128, 32)


def test_tuner_disk_cache_round_trip(tmp_path, monkeypatch, ragged_graph):
    """Tuned winners persist under $REPRO_CACHE_DIR and are served from
    disk after the in-memory tier is dropped — without re-probing."""
    import repro.kernels.tune as tune
    g = ragged_graph
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    tune.clear_memory_cache()
    try:
        got = tune.tune_push(g.n, g.m, 1, jnp.float32, "sum", "copy",
                             interpret=True)
        assert got in push_candidates(g.n, g.m)
        import json
        disk = json.loads((tmp_path / "tune.json").read_text())
        assert list(got) in [v for v in disk.values()]
        # second process simulation: cold memory, warm disk
        tune.clear_memory_cache()
        monkeypatch.setattr(tune, "_escaped",
                            lambda fn: pytest.fail("re-probed a cached "
                                                   "configuration"))
        assert tune.tune_push(g.n, g.m, 1, jnp.float32, "sum", "copy",
                              interpret=True) == got
    finally:
        tune.clear_memory_cache()  # drop state pointing at tmp_path


@pytest.mark.parametrize("garbage", [
    "", "{", "not json at all", '[1, 2, 3]', '"a string"', "null",
])
def test_tuner_survives_corrupt_disk_cache(tmp_path, monkeypatch,
                                           garbage):
    """A crashed or racing writer can leave anything in tune.json —
    truncated JSON, garbage bytes, or valid JSON that is not a dict.
    The tuner must fall back to the in-memory tier, still return a
    valid configuration, and atomically rewrite a healthy file."""
    import json

    import repro.kernels.tune as tune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    tune.clear_memory_cache()
    try:
        (tmp_path / "tune.json").write_text(garbage)
        assert tune.tune_pull(64, 8, 1, jnp.float32, "sum", "copy",
                              interpret=True) in pull_candidates(
                                  64, width=1)
        got = tune.tune_push(64, 256, 1, jnp.float32, "sum", "copy",
                             interpret=True)
        assert got in push_candidates(64, 256)
        # the corpse was replaced by a valid cache holding the winner
        disk = json.loads((tmp_path / "tune.json").read_text())
        assert isinstance(disk, dict) and list(got) in disk.values()
    finally:
        tune.clear_memory_cache()


def test_tuner_survives_poisoned_cache_entry(tmp_path, monkeypatch):
    """A key that parses but holds a garbage value (wrong type/arity)
    must not crash the tuner — it re-probes and overwrites the entry."""
    import json

    import repro.kernels.tune as tune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    tune.clear_memory_cache()
    try:
        good = tune.tune_push(64, 256, 1, jnp.float32, "sum", "copy",
                              interpret=True)
        cands = tune.push_candidates(64, 256)
        assert good in cands
        disk = json.loads((tmp_path / "tune.json").read_text())
        poisoned = {k: {"nested": "junk"} for k in disk}
        (tmp_path / "tune.json").write_text(json.dumps(poisoned))
        tune.clear_memory_cache()
        # the re-probe may crown a different near-tie winner under
        # machine load — the contract is a *valid* candidate and a
        # healed disk entry, not winner stability
        assert tune.tune_push(64, 256, 1, jnp.float32, "sum", "copy",
                              interpret=True) in cands
        healed = json.loads((tmp_path / "tune.json").read_text())
        assert all(isinstance(v, list) for v in healed.values())
        assert not any(v == {"nested": "junk"} for v in healed.values())
        assert tune.tune_pull(64, 8, 1, jnp.float32, "sum", "copy",
                              interpret=True) in pull_candidates(
                                  64, width=1)
    finally:
        tune.clear_memory_cache()


def test_tuner_concurrent_writers_keep_cache_valid(tmp_path,
                                                   monkeypatch):
    """Many threads writing winners concurrently: every put lands, the
    final file is valid JSON, and a cold reader sees every entry (the
    tmp-file + os.replace protocol never exposes a half-written
    file)."""
    import json
    import threading

    import repro.kernels.tune as tune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    tune.clear_memory_cache()
    try:
        keys = [f"cpu|race|{i}" for i in range(16)]

        def writer(i):
            tune._cache_put(keys[i], (i, i * 2, "scan"))
            # interleave reads of other threads' keys mid-race
            tune._cache_get(keys[(i + 7) % len(keys)])

        threads = [threading.Thread(target=writer, args=(i,))
                   for i in range(len(keys))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        disk = json.loads((tmp_path / "tune.json").read_text())
        assert isinstance(disk, dict)
        assert set(keys) <= set(disk)
        tune.clear_memory_cache()  # cold reader
        for i, k in enumerate(keys):
            assert tune._cache_get(k) == (i, i * 2, "scan")
    finally:
        tune.clear_memory_cache()


def test_pull_frontier_candidates_ladder():
    """Frontier-block candidates stay within the padded row capacity
    and always offer at least one rung."""
    from repro.kernels.tune import pull_frontier_candidates
    for rows in (8, 24, 256, 4096):
        cands = pull_frontier_candidates(16384, rows)
        r_pad = -(-max(rows, 8) // 8) * 8
        assert cands
        assert all(8 <= c <= r_pad for c in cands)
        assert r_pad in cands


def test_pull_b1_candidates_prefer_sub_n_blocks():
    """The kernel_pull_*_b1 regression: single-column payloads must be
    tuned over sub-n blocks (the full-row rung loses to jnp there), so
    the rmat-sized candidate list drops the full-row rung entirely."""
    n = 16384  # the benchmark rmat scale
    cands = pull_candidates(n, width=1)
    assert cands and all(c < n for c in cands)
    # batched payloads keep the full-row rung as an option
    assert any(c >= n for c in pull_candidates(n, width=8))
    # tiny graphs where no ladder rung fits still get a block
    assert pull_candidates(64, width=1) == (64,)


def test_backend_shorthand_is_shared_singleton(ragged_graph):
    assert api._resolve_backend("pallas") is api.BACKEND_SHORTHANDS[
        "pallas"]
    with pytest.raises(ValueError, match="pallas"):
        api._resolve_backend("nope")
    r = api.solve(ragged_graph, "bfs", root=0, backend="pallas")
    assert int(r.steps) >= 1


# -- end to end ---------------------------------------------------------
def _assert_states_match(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        la, lb = np.asarray(la), np.asarray(lb)
        if la.dtype.kind == "f":
            np.testing.assert_allclose(la, lb, atol=1e-6)
        else:
            np.testing.assert_array_equal(la, lb)


@pytest.mark.parametrize("policy", ["push", "pull", "auto"])
@pytest.mark.parametrize("alg,kw", [
    ("bfs", {"root": 3}),
    ("pagerank", {"iters": 25}),
    ("sssp_delta", {"source": 3, "delta": 2.5}),
])
def test_solve_pallas_matches_dense(small_graph, alg, kw, policy):
    """The acceptance matrix: BFS / PageRank / SSSP × push / pull / auto
    through the kernel path reproduce the dense backend (int states bit
    for bit, float fixpoints at the suite's standard tolerance)."""
    backend = PallasBackend()
    dense = api.solve(small_graph, alg, policy=policy, **kw)
    pallas = api.solve(small_graph, alg, policy=policy, backend=backend,
                       **kw)
    _assert_states_match(dense.state, pallas.state)
    # the run dispatched kernels, not fallbacks (touched pulls now
    # trace through the frontier dispatch)
    assert (backend.stats["kernel_pull"]
            + backend.stats["kernel_pull_frontier"]
            + backend.stats["kernel_push"]) > 0
    assert backend.stats["fallback_pull"] == 0
    assert backend.stats["fallback_push"] == 0


def test_solve_batch_pallas_runs_kernel_path(small_graph):
    """Batched [n, B] payload columns ride the kernels: per-query states
    equal the dense batched run, and the dispatch counters prove the
    kernel path executed for both directions."""
    g = small_graph
    backend = PallasBackend()
    for alg, kw in (("bfs", {}), ("ppr", {"tol": 1e-6}),
                    ("sssp_delta", {"delta": 2.5})):
        dense = api.solve_batch(g, alg, sources=[0, 5, 9], **kw)
        pallas = api.solve_batch(g, alg, sources=[0, 5, 9],
                                 backend=backend, **kw)
        assert pallas.batch == 3
        for i in range(3):
            _assert_states_match(dense.states[i], pallas.states[i])
    assert (backend.stats["kernel_pull"]
            + backend.stats["kernel_pull_frontier"]) > 0
    assert backend.stats["kernel_push"] > 0
    assert backend.stats["fallback_pull"] == 0
    assert backend.stats["fallback_push"] == 0
    # batched shapes were tuned separately from scalar ones
    assert any(k[3] == 3 for k in backend._tuned)


def test_every_algorithm_runs_under_pallas(small_graph):
    """Coverage: all nine registered algorithms (and all policies they
    declare) execute under backend="pallas" — via kernels where the
    cell qualifies, via the transparent fallback elsewhere."""
    KW = {"bfs": {"root": 3}, "pagerank": {"iters": 5},
          "ppr": {"source": 3, "tol": 1e-5}, "wcc": {},
          "pr_delta": {"tol": 1e-5},
          "sssp_delta": {"source": 3, "delta": 2.5},
          "betweenness": {"num_sources": 2},
          "coloring": {"num_parts": 8}, "mst_boruvka": {},
          "triangle_count": {"edge_block": 512}}
    for name in api.algorithms():
        spec = api.get_spec(name)
        assert "pallas" in spec.backends
        r = api.solve(small_graph, name, backend="pallas", **KW[name])
        assert int(r.steps) >= 1
