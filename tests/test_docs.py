"""docs/ stays honest: the algorithms page must cover the live registry,
the docs tree must exist and be linked, and benchmark reports must
validate against the checked-in schema.

These run in tier-1 (and as a dedicated CI step), so registering an
algorithm without documenting it — or changing the report shape without
updating the schema — fails the build."""

import json
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOCS = ROOT / "docs"


def test_docs_tree_exists():
    for page in ("architecture.md", "push-pull.md", "algorithms.md",
                 "kernels.md", "distributed.md", "observability.md",
                 "resilience.md", "results.md"):
        assert (DOCS / page).is_file(), f"missing docs/{page}"


def test_readme_links_docs():
    readme = (ROOT / "README.md").read_text()
    for page in ("docs/architecture.md", "docs/push-pull.md",
                 "docs/algorithms.md", "docs/kernels.md",
                 "docs/distributed.md", "docs/observability.md",
                 "docs/resilience.md", "docs/results.md"):
        assert page in readme, f"README does not link {page}"


def test_kernels_page_covers_dispatch_surface():
    """docs/kernels.md stays honest: both kernels, the backend, the
    fallback axes, and the autotuner are named."""
    page = (DOCS / "kernels.md").read_text()
    for needle in ("ell_spmv_pallas", "coo_push_pallas", "PallasBackend",
                   "build_push_plan", "bin_plan_traced",
                   "pa_regroup_by_dst", "classify_msg_fn", "tune.py",
                   "fallback", "pct_roofline",
                   # PR 8: the frontier-aware pull surface
                   "ell_pull_frontier_pallas", "DualEllLayout",
                   "touched_out_mask", "default_pull_cap",
                   "predict_pull_scan", "frontier_rows"):
        assert needle in page, f"docs/kernels.md does not mention {needle}"
    # the architecture backend table links here, and the cost-model
    # section documents the PR 8 StepStats/pricing additions
    arch = (DOCS / "architecture.md").read_text()
    assert "kernels.md" in arch
    for needle in ("pull_touched_edges", "predict_pull_scan"):
        assert needle in arch, (
            f"docs/architecture.md does not mention {needle}")


def test_distributed_page_covers_shard_surface():
    """docs/distributed.md stays honest: the backend, its entry points,
    the exchange pieces, the compression lever, and the scaling suite
    are all named."""
    page = (DOCS / "distributed.md").read_text()
    for needle in ("ShardedBackend", "shard_map", "make_shard_mesh",
                   "build_topology", "psum_scatter", "all_gather",
                   "predict_comm_bytes", "CompressionConfig",
                   "scaling_throughput", "BENCH_scaling.json",
                   "partition_1d"):
        assert needle in page, (
            f"docs/distributed.md does not mention {needle}")
    # the architecture backend table links here
    assert "distributed.md" in (DOCS / "architecture.md").read_text()


def test_observability_page_covers_obs_surface():
    """docs/observability.md stays honest: the telemetry handle, the
    collectors, the exporters, the audit, the bench wiring, and the
    compare gate are all named."""
    page = (DOCS / "observability.md").read_text()
    for needle in ("Telemetry", "record_solve", "decision_audit",
                   "obs_schema.json", "write_chrome_trace", "Perfetto",
                   "--trace-out", "mispredict", "telemetry_counters",
                   "StepTrace", "overflow", "compare.py", "--fail-below",
                   "repro.obs.report", "MetricRegistry"):
        assert needle in page, (
            f"docs/observability.md does not mention {needle}")
    # the architecture page names the telemetry layer and links here
    arch = (DOCS / "architecture.md").read_text()
    assert "observability.md" in arch
    assert "repro.obs" in arch


def test_resilience_page_covers_fault_surface():
    """docs/resilience.md stays honest: every fault site, the plan
    machinery, each recovery seam, the serving surfaces, and the CI
    chaos job are all named."""
    from repro.resilience import SITES
    page = (DOCS / "resilience.md").read_text()
    for site in SITES:
        assert f"`{site}`" in page, (
            f"docs/resilience.md does not document fault site {site}")
    for needle in ("FaultPlan", "FaultSpec", "REPRO_FAULT_PLAN",
                   "ci-default", "kernels-down", "CircuitBreaker",
                   "deadline_ms", "max_queue", "AdmissionError",
                   "DeadlineExceeded", "checkpoint_every",
                   "SolveInterrupted", "check_finite", "DivergenceError",
                   "ProbeTimeout", "REPRO_TUNE_DEADLINE_S",
                   "collect_resilience", "resilience.fault",
                   "chaos-smoke", "bit-identical"):
        assert needle in page, (
            f"docs/resilience.md does not mention {needle}")
    # the architecture page names the layer and links here
    arch = (DOCS / "architecture.md").read_text()
    assert "resilience.md" in arch
    assert "repro.resilience" in arch


def test_every_registered_algorithm_documented():
    """The CI gate from the issue: import the registry, fail if an
    algorithm is missing from docs/algorithms.md."""
    from repro import api
    page = (DOCS / "algorithms.md").read_text()
    missing = [name for name in api.algorithms()
               if f"## `{name}`" not in page]
    assert not missing, (
        f"algorithms registered but undocumented in docs/algorithms.md: "
        f"{missing} — add a '## `<name>`' section for each")


def test_algorithm_sections_show_solve_calls():
    """Each documented section carries a runnable api.solve call."""
    from repro import api
    page = (DOCS / "algorithms.md").read_text()
    for name in api.algorithms():
        assert f'api.solve(g, "{name}"' in page, (
            f"docs/algorithms.md section for {name} lacks the exact "
            f"api.solve() call")


def test_no_stale_algorithm_sections():
    """Sections for unregistered algorithms are as wrong as missing
    ones."""
    import re
    from repro import api
    page = (DOCS / "algorithms.md").read_text()
    documented = set(re.findall(r"^## `([a-z_0-9]+)`", page, re.M))
    stale = documented - set(api.algorithms())
    assert not stale, f"documented but not registered: {sorted(stale)}"


def test_push_pull_page_covers_all_policies():
    page = (DOCS / "push-pull.md").read_text()
    for policy in ("Fixed", "GenericSwitch", "GreedySwitch", "AutoSwitch"):
        assert policy in page


# -- benchmark report schema --------------------------------------------
def _sample_report():
    return {
        "rows": [
            {"name": "api_bfs_push_dense", "us_per_call": 12.5,
             "derived": "free-text"},
            {"name": "pushpull_bfs_rmat_auto_dense", "us_per_call": 10.0,
             "derived": {
                 "algorithm": "bfs", "graph": "rmat", "n": 128, "m": 982,
                 "policy": "auto", "backend": "dense", "steps": 5,
                 "push_steps": 2, "epochs": 1, "converged": True,
                 "wall_us": 10.0,
                 "counters": {"reads": 1, "writes": 1, "atomics": 0,
                              "locks": 0, "messages": 0,
                              "collective_bytes": 0, "barriers": 5,
                              "iterations": 5},
                 "weighted_total": 2.0}},
            {"name": "kernel_pull_sum_rmat_b8", "us_per_call": 420.0,
             "derived": {
                 "direction": "pull", "combine": "sum", "graph": "rmat",
                 "n": 128, "m": 982, "d_ell": 72, "batch": 8,
                 "dtype": "float32", "msg": "copy", "block_n": 128,
                 "us_jnp": 515.4, "us_pallas": 419.7, "speedup": 1.23,
                 "match": True, "pct_roofline": 0.00041,
                 "bytes_moved": 330240, "flops": 73728}},
            {"name": "scaling_bfs_push_P4", "us_per_call": 150.0,
             "derived": {
                 "algorithm": "bfs", "graph": "orc", "n": 128, "m": 982,
                 "policy": "push", "backend": "shard", "shards": 4,
                 "compression": "none", "wall_us": 150.0,
                 "collective_bytes": 4096, "steps": 5, "push_steps": 5,
                 "cut_edges": 300, "converged": True,
                 "weighted_total": 2.0, "match": True}},
        ],
        "failures": [],
    }


def test_schema_accepts_valid_report():
    from benchmarks.validate import validate_report
    assert validate_report(_sample_report())


def test_schema_rejects_malformed_reports():
    from benchmarks.validate import validate_report
    bad_missing_rows = {"failures": []}
    bad_row = {"rows": [{"name": "x"}], "failures": []}
    bad_cell = _sample_report()
    del bad_cell["rows"][1]["derived"]["counters"]
    bad_policy = _sample_report()
    bad_policy["rows"][1]["derived"]["policy"] = "fastest"
    bad_kernel = _sample_report()
    del bad_kernel["rows"][2]["derived"]["us_pallas"]
    bad_kernel_dir = _sample_report()
    bad_kernel_dir["rows"][2]["derived"]["direction"] = "sideways"
    bad_roof_zero = _sample_report()
    bad_roof_zero["rows"][2]["derived"]["pct_roofline"] = 0.0
    bad_roof_high = _sample_report()
    bad_roof_high["rows"][2]["derived"]["pct_roofline"] = 2.0
    bad_roof_missing = _sample_report()
    del bad_roof_missing["rows"][2]["derived"]["pct_roofline"]
    bad_scaling = _sample_report()
    del bad_scaling["rows"][3]["derived"]["collective_bytes"]
    bad_scaling_comp = _sample_report()
    bad_scaling_comp["rows"][3]["derived"]["compression"] = "gzip"
    for bad in (bad_missing_rows, bad_row, bad_cell, bad_policy,
                bad_kernel, bad_kernel_dir, bad_roof_zero,
                bad_roof_high, bad_roof_missing, bad_scaling,
                bad_scaling_comp):
        with pytest.raises(Exception):
            validate_report(bad)


def test_builtin_validator_matches_schema_subset():
    """The fallback validator (no jsonschema installed) enforces the
    same contract: exercise it directly."""
    from benchmarks.validate import _check, load_schema
    schema = load_schema()
    defs = schema["definitions"]
    _check(_sample_report(), schema, defs)
    with pytest.raises(ValueError, match="expected integer"):
        _check({"rows": [], "failures": []},
               {"type": "object",
                "properties": {"rows": {"type": "integer"}}}, defs)


def test_committed_bench_json_validates():
    """The repo's checked-in BENCH_*.json trajectories stay conformant."""
    from benchmarks.validate import validate_report
    reports = sorted(ROOT.glob("BENCH_*.json"))
    assert reports, "no BENCH_*.json trajectory committed at repo root"
    for path in reports:
        validate_report(json.loads(path.read_text()))


def test_bench_kernels_json_covers_kernel_cells():
    """The committed wall-clock kernel trajectory: both directions, the
    RMAT family, batched cells, and every cell's correctness
    cross-check true. (CI asserts existence/validity, not speedups —
    the interpreter's absolute numbers are machine-relative.)"""
    report = json.loads((ROOT / "BENCH_kernels.json").read_text())
    cells = [r["derived"] for r in report["rows"]
             if r["name"].startswith("kernel_")]
    assert cells, "BENCH_kernels.json has no kernel_* rows"
    assert {c["direction"] for c in cells} == {"push", "pull", "pullf"}
    assert "rmat" in {c["graph"] for c in cells}
    assert any(c["batch"] > 1 for c in cells)
    assert all(c["match"] for c in cells)
    # the PR 8 wall-clock claim: on at least one sparse BFS-shaped
    # touched set (≤10% density) the frontier kernel beats the
    # full-scan kernel it replaced
    pullf = [c for c in cells if c["direction"] == "pullf"]
    assert pullf, "no kernel_pullf_* rows"
    assert all(c["density"] <= 0.10 for c in pullf)
    assert any(c["us_pallas"] < c["us_full_kernel"] for c in pullf), (
        "frontier pull never beats the full-scan kernel")


def test_bench_scaling_json_covers_shard_cells():
    """The committed sharded-scaling trajectory: multiple shard counts
    incl. multi-device, both directions, a compressed cell, every
    cross-check true, and the §6 asymmetry — some frontier-sparse cell
    where push moves fewer bytes than its pull counterpart."""
    report = json.loads((ROOT / "BENCH_scaling.json").read_text())
    cells = [r["derived"] for r in report["rows"]
             if r["name"].startswith("scaling_")]
    assert cells, "BENCH_scaling.json has no scaling_* rows"
    shards = {c["shards"] for c in cells}
    assert 1 in shards and max(shards) >= 4, shards
    assert {c["policy"] for c in cells} >= {"push", "pull", "auto"}
    assert any(c["compression"] != "none" for c in cells)
    assert all(c["match"] for c in cells)
    by_key = {(c["algorithm"], c["policy"], c["compression"],
               c["shards"]): c for c in cells}
    sparse_wins = [
        (alg, P)
        for (alg, pol, comp, P), c in by_key.items()
        if pol == "push" and comp == "none" and P > 1
        and (alg, "pull", "none", P) in by_key
        and c["collective_bytes"]
        < by_key[(alg, "pull", "none", P)]["collective_bytes"]]
    assert sparse_wins, (
        "no cell with push wire bytes < pull wire bytes; the "
        "frontier-sparse asymmetry is the suite's point")


def test_bench_json_covers_matrix():
    """Acceptance: BENCH_pushpull.json covers every registered
    algorithm × ≥4 policies."""
    from repro import api
    report = json.loads((ROOT / "BENCH_pushpull.json").read_text())
    cells = [r["derived"] for r in report["rows"]
             if r["name"].startswith("pushpull_")]
    algs = {c["algorithm"] for c in cells}
    assert algs == set(api.algorithms())
    for alg in algs:
        policies = {c["policy"] for c in cells if c["algorithm"] == alg}
        assert len(policies) >= 4, (alg, policies)
