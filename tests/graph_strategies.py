"""Shared random-graph strategies for the differential harness.

One place defines the adversarial graph families every cross-backend
test draws from, so new backends/kernels get the same gauntlet for
free. Strategies use only the hypothesis subset the offline stand-in in
``conftest.py`` implements (``integers``, ``sampled_from``, ``given``),
so the suite runs identically with or without hypothesis installed —
with it, profiles widen the draw; without it, ``given`` walks the
cartesian product of the fixed samples.

Each case targets a known kernel edge:

  * ``ragged``          — heavy-hub in-degree skew (ELL padding waste,
                          bin-plan imbalance)
  * ``empty_rows``      — vertices with no in-edges (sentinel-only ELL
                          rows must yield the combine identity)
  * ``self_loops``      — i → i edges (gather index == write index)
  * ``duplicate_edges`` — repeated (src, dst) pairs (combine must see
                          every copy; dedup would change ``sum``)
  * ``edgeless``        — m == 0 (degenerate layouts, empty bin plans)
"""

from __future__ import annotations

import numpy as np

CASES = ("ragged", "empty_rows", "self_loops", "duplicate_edges",
         "edgeless")


def graph_cases():
    from hypothesis import strategies as st
    return st.sampled_from(CASES)


def seeds(max_seed: int = 1):
    from hypothesis import strategies as st
    return st.integers(0, max_seed)


def combines():
    from hypothesis import strategies as st
    return st.sampled_from(["sum", "min", "max"])


def build_case(case: str, seed: int, n: int = 24):
    """Materialize one adversarial graph, deterministically in
    (case, seed, n)."""
    from repro.graphs.structure import build_graph
    rng = np.random.RandomState(1009 * seed + 131 * CASES.index(case))
    if case == "edgeless":
        src = np.zeros(0, dtype=np.int64)
        dst = np.zeros(0, dtype=np.int64)
    elif case == "ragged":
        # one hub receives half of all edges; the rest spread thin
        m = 4 * n
        src = rng.randint(0, n, size=m)
        dst = np.where(rng.rand(m) < 0.5, 0, rng.randint(0, n, size=m))
    elif case == "empty_rows":
        # in-edges land only on the first quarter of the vertex range:
        # 3/4 of the ELL rows are all-sentinel
        m = 3 * n
        src = rng.randint(0, n, size=m)
        dst = rng.randint(0, max(n // 4, 1), size=m)
    elif case == "self_loops":
        m = 2 * n
        src = rng.randint(0, n, size=m)
        dst = rng.randint(0, n, size=m)
        loops = rng.choice(n, size=n // 3, replace=False)
        src = np.concatenate([src, loops])
        dst = np.concatenate([dst, loops])
    elif case == "duplicate_edges":
        m = 2 * n
        src = rng.randint(0, n, size=m)
        dst = rng.randint(0, n, size=m)
        dup = rng.choice(m, size=m // 2, replace=True)
        src = np.concatenate([src, src[dup], src[dup]])
        dst = np.concatenate([dst, dst[dup], dst[dup]])
    else:  # pragma: no cover - guarded by CASES
        raise ValueError(case)
    w = rng.uniform(0.5, 2.0, size=src.shape[0]).astype(np.float32)
    return build_graph(src, dst, n=n, weights=w)
