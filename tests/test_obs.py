"""repro.obs — unified telemetry.

Covers the tentpole surfaces: the Telemetry handle (span timing, event
ring bounds), record_solve's counter fidelity (obs totals exactly equal
the engine's §4 Cost charges — the no-double-count property test),
wire-byte agreement with ``ShardedBackend.predict_comm_bytes`` (in
process at P=1, in a fresh 4-device interpreter), the exporters
(JSONL + schema validation + the committed ``benchmarks/obs_schema.json``
contract, Chrome trace loadability), the decision-audit report, the
``telemetry=None`` fast path (bit-identical results, zero events), the
``StepTrace.record`` overflow counter, and the ``benchmarks/compare.py``
regression gate.
"""

from __future__ import annotations

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given

from graph_strategies import build_case, graph_cases, seeds
from repro import api
from repro.core.engine import PushPullEngine
from repro.graphs.generators import erdos_renyi, kronecker
from repro.obs import Telemetry
from repro.obs.export import (OBS_EVENT_SCHEMA, _final_events, load_jsonl,
                              validate_events, validate_trace_file,
                              write_chrome_trace, write_jsonl)
from repro.obs.report import decision_audit, render_report
from repro.shard import ShardedBackend


def _graph():
    return kronecker(8, edge_factor=8, seed=3)


def _tree_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y, equal_nan=True))
        for x, y in zip(la, lb))


# ---------------------------------------------------------------------
# the disabled path: telemetry=None is the untouched solve

def test_telemetry_none_bit_identical_and_zero_events():
    g = _graph()
    tel = Telemetry()
    plain = api.solve(g, "bfs", root=0, policy="auto")
    assert tel.events == [] and len(tel.counters) == 0
    observed = api.solve(g, "bfs", root=0, policy="auto", telemetry=tel)
    assert _tree_equal(plain.state, observed.state)
    assert int(plain.cost.reads) == int(observed.cost.reads)
    assert int(plain.cost.writes) == int(observed.cost.writes)
    assert int(plain.steps) == int(observed.steps)
    assert int(plain.push_steps) == int(observed.push_steps)
    # and the handle now carries the run's events; a further plain
    # solve adds none
    n_events = len(tel.events)
    assert n_events > 0
    api.solve(g, "bfs", root=0, policy="auto")
    assert len(tel.events) == n_events


def test_stepwise_loop_matches_single_dispatch():
    g = _graph()
    spec = api.get_spec("bfs")
    policy = api._resolve_policy("auto")
    backend = api._resolve_backend(None, g)
    program, default_steps = spec.build(g, policy=policy, backend=backend)
    eng = PushPullEngine(program=program, policy=policy,
                         max_steps=default_steps, backend=backend,
                         trace_capacity=64)
    state0, frontier0 = spec.init(g, root=0)
    whole = eng.run(g, state0, frontier0)
    times: dict[int, float] = {}
    stepped = eng.run_stepwise(g, state0, frontier0,
                               on_step=lambda i, us: times.__setitem__(i, us))
    assert _tree_equal(whole.state, stepped.state)
    assert int(whole.steps) == int(stepped.steps) == len(times)
    assert int(whole.cost.reads) == int(stepped.cost.reads)
    assert all(us > 0 for us in times.values())


def test_stepwise_rejects_phase_programs():
    g = _graph()
    spec = api.get_spec("sssp_delta")
    policy = api._resolve_policy("auto")
    backend = api._resolve_backend(None, g)
    program, default_steps = spec.build(g, policy=policy, backend=backend)
    eng = PushPullEngine(program=program, policy=policy,
                         max_steps=default_steps, backend=backend)
    assert not eng.supports_stepwise
    state0, frontier0 = spec.init(g, source=0)
    with pytest.raises(ValueError, match="phase"):
        eng.run_stepwise(g, state0, frontier0)


def test_phase_program_solve_with_telemetry_still_audits():
    # multi-phase programs can't run stepwise; the observed path falls
    # back to single dispatch but still records step rows (no wall us)
    g = _graph()
    tel = Telemetry()
    plain = api.solve(g, "sssp_delta", source=0, policy="auto")
    observed = api.solve(g, "sssp_delta", source=0, policy="auto",
                         telemetry=tel)
    assert _tree_equal(plain.state, observed.state)
    steps = [e for e in tel.events if e["kind"] == "step"]
    assert steps and all("us" not in e for e in steps)
    audits = [e for e in tel.events if e["kind"] == "audit"]
    assert audits and audits[0]["basis"] == "predicted"


# ---------------------------------------------------------------------
# counter fidelity: obs totals == the engine's Cost charges, exactly

@given(case=graph_cases(), seed=seeds())
def test_step_counter_totals_match_cost(case, seed):
    g = build_case(case, seed)
    tel = Telemetry()
    r = api.solve(g, "bfs", root=0, policy="auto", telemetry=tel)
    run_ev = [e for e in tel.events if e["kind"] == "run"][-1]
    steps = [e for e in tel.events if e["kind"] == "step"
             and e["run"] == run_ev["run"]]
    assert len(steps) == int(r.steps)
    for key in ("reads", "writes", "atomics", "locks"):
        assert sum(e[key] for e in steps) == int(getattr(r.cost, key)) \
            == run_ev["counters"][key], key
    assert tel.counters.get("engine.cost.reads") == int(r.cost.reads)
    assert tel.counters.get("engine.steps") == int(r.steps)


def test_counters_accumulate_without_double_count():
    g = _graph()
    tel = Telemetry()
    r1 = api.solve(g, "bfs", root=0, telemetry=tel)
    r2 = api.solve(g, "bfs", root=1, telemetry=tel)
    assert tel.counters.get("engine.runs") == 2
    assert tel.counters.get("engine.cost.reads") == \
        int(r1.cost.reads) + int(r2.cost.reads)


# ---------------------------------------------------------------------
# wire bytes: trace columns == predict_comm_bytes == collective_bytes

def _assert_wire_bytes_consistent(tel, cost_bytes: int):
    steps = [e for e in tel.events if e["kind"] == "step"]
    assert steps
    charged = sum(e["push_wire_bytes"] if e["pushed"]
                  else e["pull_wire_bytes"] for e in steps)
    assert charged == cost_bytes


def test_shard_wire_bytes_match_trace_single_device():
    g = erdos_renyi(96, 6.0, seed=2)
    backend = ShardedBackend.prepare(g, num_shards=1)
    tel = Telemetry()
    r = api.solve(g, "bfs", root=0, policy="auto", backend=backend,
                  telemetry=tel)
    # a single shard has no cut and gathers nothing: both directions
    # price zero wire bytes, and the charge agrees exactly
    pb, lb = backend.predict_comm_bytes(
        g, jnp.zeros((g.n,), jnp.float32),
        jnp.zeros((g.n,), bool).at[0].set(True))
    assert int(pb) == 0 and int(lb) == 0
    assert int(r.cost.collective_bytes) == 0
    _assert_wire_bytes_consistent(tel, 0)
    counters = backend.telemetry_counters()
    assert counters["num_shards"] == 1 and counters["cut_edges"] == 0


WIRE_P4 = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax.numpy as jnp
from repro import api
from repro.graphs.generators import erdos_renyi
from repro.obs import Telemetry
from repro.shard import ShardedBackend

g = erdos_renyi(96, 6.0, seed=2)
backend = ShardedBackend.prepare(g, num_shards=4)
tel = Telemetry()
r = api.solve(g, "bfs", root=0, policy="auto", backend=backend,
              telemetry=tel)
steps = [e for e in tel.events if e["kind"] == "step"]
assert steps, "no step events"
charged = sum(e["push_wire_bytes"] if e["pushed"]
              else e["pull_wire_bytes"] for e in steps)
assert charged == int(r.cost.collective_bytes), (
    charged, int(r.cost.collective_bytes))
assert charged > 0
counters = backend.telemetry_counters()
assert counters["num_shards"] == 4 and counters["cut_edges"] > 0
print("WIRE-P4-OK")
"""


@pytest.mark.dist
@pytest.mark.subprocess
def test_shard_wire_bytes_match_trace_multi_device():
    from pathlib import Path
    import os
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", WIRE_P4],
                          capture_output=True, text=True, timeout=900,
                          env=env, cwd=str(root))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "WIRE-P4-OK" in proc.stdout


# ---------------------------------------------------------------------
# StepTrace overflow (satellite: record() used to drop silently)

def test_trace_overflow_surfaced():
    g = _graph()
    tel = Telemetry()
    r = api.solve(g, "bfs", root=0, policy="auto", trace=2,
                  telemetry=tel)
    assert int(r.steps) > 2
    dropped = int(r.steps) - 2
    assert int(r.trace.overflow) == dropped
    assert r.trace.as_dict(int(r.steps))["overflow"] == dropped
    run_ev = [e for e in tel.events if e["kind"] == "run"][-1]
    assert run_ev["trace_overflow"] == dropped
    report = render_report(_final_events(tel))
    assert "Trace overflow" in report


def test_trace_no_overflow_when_capacity_suffices():
    g = _graph()
    r = api.solve(g, "bfs", root=0, policy="auto", trace=64)
    assert int(r.trace.overflow) == 0


# ---------------------------------------------------------------------
# the Telemetry handle itself

def test_event_ring_bounded_and_counts_drops():
    tel = Telemetry(capacity=4)
    for _ in range(10):
        tel.emit("event", "x")
    assert len(tel.events) == 4 and tel.dropped == 6


def test_span_records_duration_and_fields():
    tel = Telemetry()
    with tel.span("work", phase="test") as sp:
        sp["extra"] = 1
    (ev,) = tel.events
    assert ev["kind"] == "span" and ev["name"] == "work"
    assert ev["dur_us"] >= 0 and ev["phase"] == "test" and ev["extra"] == 1


# ---------------------------------------------------------------------
# exporters + schema

def test_jsonl_round_trip_validates(tmp_path):
    g = _graph()
    tel = Telemetry()
    api.solve(g, "bfs", root=0, policy="auto", telemetry=tel)
    path = tmp_path / "trace.jsonl"
    n = write_jsonl(tel, path)
    assert validate_trace_file(path) == n
    events = load_jsonl(path)
    assert events[0]["kind"] == "meta"
    kinds = {e["kind"] for e in events}
    assert {"run", "step", "audit", "span", "counter"} <= kinds


def test_chrome_trace_loads(tmp_path):
    g = _graph()
    tel = Telemetry()
    api.solve(g, "bfs", root=0, policy="auto", telemetry=tel)
    path = tmp_path / "trace.json"
    write_chrome_trace(tel, path)
    with open(path) as fh:
        obj = json.load(fh)
    evs = obj["traceEvents"]
    assert evs and all("ph" in e and "pid" in e for e in evs)
    # the timed steps are complete events Perfetto can lay out
    xs = [e for e in evs if e["ph"] == "X" and e.get("cat") == "step"]
    assert xs and all(e["dur"] > 0 and e["ts"] >= 0 for e in xs)


def test_validate_events_rejects_bad_events():
    ok = [{"ts_us": 0.0, "kind": "counter", "name": "x", "value": 1}]
    assert validate_events(ok) == []
    assert validate_events([{"ts_us": -1.0, "kind": "counter",
                             "name": "x", "value": 1}])
    assert validate_events([{"ts_us": 0.0, "kind": "nonsense"}])
    assert validate_events([{"ts_us": 0.0, "kind": "run"}])  # missing keys
    assert validate_events([{"kind": "counter", "name": "x", "value": 1}])


def test_committed_obs_schema_in_sync():
    from pathlib import Path
    path = Path(__file__).resolve().parents[1] / "benchmarks" \
        / "obs_schema.json"
    with open(path) as fh:
        committed = json.load(fh)
    assert committed == OBS_EVENT_SCHEMA, (
        "benchmarks/obs_schema.json drifted from "
        "repro.obs.export.OBS_EVENT_SCHEMA — regenerate it with "
        "json.dump(OBS_EVENT_SCHEMA, fh, indent=2)")


# ---------------------------------------------------------------------
# decision audit + report

def test_decision_audit_wall_basis_flags_mispredictions():
    steps = [
        {"kind": "step", "run": 0, "step": 0, "pushed": True,
         "predicted_push": 10.0, "predicted_pull": 100.0, "us": 50.0},
        {"kind": "step", "run": 0, "step": 1, "pushed": False,
         "predicted_push": 100.0, "predicted_pull": 10.0, "us": 50.0},
        # chose push at 400us; pull predicted 10 -> ~50us at the pull
        # rate: mispredicted
        {"kind": "step", "run": 0, "step": 2, "pushed": True,
         "predicted_push": 11.0, "predicted_pull": 10.0, "us": 400.0},
    ]
    audit = decision_audit(steps)
    assert audit["basis"] == "wall"
    assert audit["audited_steps"] == 3
    assert [r["mispredict"] for r in audit["steps"]] == \
        [False, False, True]
    assert audit["flagged"] == 1
    assert audit["mispredict_rate"] == pytest.approx(1 / 3)


def test_decision_audit_predicted_basis_without_timings():
    steps = [
        {"kind": "step", "run": 0, "step": 0, "pushed": True,
         "predicted_push": 5.0, "predicted_pull": 50.0},
        {"kind": "step", "run": 0, "step": 1, "pushed": True,
         "predicted_push": 50.0, "predicted_pull": 5.0},
    ]
    audit = decision_audit(steps)
    assert audit["basis"] == "predicted"
    assert audit["flagged"] == 1
    assert decision_audit([]) is None


def test_report_renders_audit_and_counter_table(tmp_path):
    g = _graph()
    tel = Telemetry()
    api.solve(g, "bfs", root=0, policy="auto", telemetry=tel)
    report = render_report(_final_events(tel))
    assert "Counter totals" in report
    assert "| reads | writes | atomics | locks |" in report
    assert "Decision audit" in report
    assert "steps\nmispredicted" in report.replace("\n", " ") \
        or "mispredicted" in report
    assert "wall basis" in report or "predicted basis" in report
    # and the CLI module renders the same thing from a trace file
    trace = tmp_path / "t.jsonl"
    out = tmp_path / "report.md"
    write_jsonl(tel, trace)
    from repro.obs.report import main
    assert main([str(trace), "--out", str(out)]) == 0
    assert "Decision audit" in out.read_text()


# ---------------------------------------------------------------------
# batch + service + tuner wiring

def test_solve_batch_telemetry_matches_plain():
    g = _graph()
    tel = Telemetry()
    plain = api.solve_batch(g, "bfs", sources=[0, 5])
    observed = api.solve_batch(g, "bfs", sources=[0, 5], telemetry=tel)
    assert _tree_equal(plain.state, observed.state)
    assert [e["kind"] for e in tel.events].count("run") == 1
    assert any(e["kind"] == "step" for e in tel.events)


def test_query_service_emits_events_and_counters():
    from repro.service import QueryService
    g = _graph()
    tel = Telemetry()
    svc = QueryService(g, slots=2, telemetry=tel)
    rids = [svc.submit("bfs", source=s) for s in (0, 1, 0)]
    svc.run_until_complete()
    assert all(svc.poll(r) is not None for r in rids)
    names = {e.get("name") for e in tel.events}
    assert "service.coalesce" in names
    assert "service.batch_start" in names
    assert "service.chunk" in names
    assert tel.counters.get("service.batches_started") >= 1
    assert tel.counters.get("service.cache.misses") >= 1
    assert validate_events(_final_events(tel)) == []


def test_tuner_counters_collected():
    from repro.kernels import tune
    from repro.obs.metrics import collect_tuner
    tel = Telemetry()
    stats = collect_tuner(tel)
    assert set(stats) == {"mem_hits", "disk_hits", "misses", "probes",
                          "writes", "probe_retries", "probe_timeouts",
                          "probe_failures", "probe_degraded",
                          "write_errors"}
    assert tel.counters.get("tuner.probes") == stats["probes"]


def test_backend_telemetry_counters_surface():
    from repro.core.backend import DenseBackend, PallasBackend
    assert DenseBackend().telemetry_counters() == {}
    pb = PallasBackend()
    # dispatch/fallback tallies plus the circuit breaker's gauges
    assert set(pb.telemetry_counters()) == set(pb.stats) | {
        "breaker_opened_total", "breaker_open_now",
        "breaker_half_open_now"}


# ---------------------------------------------------------------------
# benchmarks/compare.py (satellite: the BENCH regression gate)

def _report(cells: dict) -> dict:
    return {"rows": [{"name": k, "us_per_call": v,
                      "derived": {"weighted_total": v * 2}}
                     for k, v in cells.items()],
            "failures": []}


def test_compare_reports_speedups_and_gate(tmp_path):
    from benchmarks.compare import compare_reports, main
    old = _report({"a": 100.0, "b": 100.0, "only_old": 1.0})
    new = _report({"a": 50.0, "b": 200.0, "only_new": 1.0})
    diff = compare_reports(old, new)
    by_name = {c["name"]: c for c in diff["cells"]}
    assert by_name["a"]["speedup"] == pytest.approx(2.0)
    assert by_name["b"]["speedup"] == pytest.approx(0.5)
    assert diff["only_old"] == ["only_old"]
    assert diff["only_new"] == ["only_new"]
    # derived metrics resolve dotted
    d2 = compare_reports(old, new, metric="weighted_total")
    assert {c["name"] for c in d2["cells"]} == {"a", "b"}

    po, pn = tmp_path / "old.json", tmp_path / "new.json"
    po.write_text(json.dumps(old))
    pn.write_text(json.dumps(new))
    assert main([str(po), str(pn)]) == 0
    assert main([str(po), str(pn), "--fail-below", "0.8"]) == 1
    assert main([str(po), str(pn), "--fail-below", "0.4"]) == 0


def test_compare_higher_is_better_flips_the_ratio(tmp_path):
    """For win-metrics (a kernel row's jnp speedup) the gate must fire
    when NEW gets *smaller* — the ratio flips to new/old."""
    from benchmarks.compare import compare_reports, main
    old = _report({"a": 100.0, "b": 100.0})   # metric value = us
    new = _report({"a": 50.0, "b": 200.0})
    d = compare_reports(old, new, higher_is_better=True)
    by_name = {c["name"]: c for c in d["cells"]}
    assert by_name["a"]["speedup"] == pytest.approx(0.5)   # a shrank
    assert by_name["b"]["speedup"] == pytest.approx(2.0)   # b grew

    po, pn = tmp_path / "old.json", tmp_path / "new.json"
    po.write_text(json.dumps(old))
    pn.write_text(json.dumps(new))
    # cost polarity passes at 0.4; win polarity catches cell a halving
    assert main([str(po), str(pn), "--fail-below", "0.4"]) == 0
    assert main([str(po), str(pn), "--higher-is-better",
                 "--fail-below", "0.6"]) == 1
    assert main([str(po), str(pn), "--higher-is-better",
                 "--fail-below", "0.4"]) == 0
