"""repro.service — batched multi-query serving.

The acceptance claims under test:

  * ``api.solve_batch`` results are *bit-identical* to a loop of
    single-source ``api.solve`` calls for BFS, Δ-stepping SSSP, and
    personalized PageRank, on the dense and ELL backends;
  * the batch-aware cost model prices push by union-frontier degree
    sums, so the predicted push→pull crossover moves toward pull as
    the batch widens;
  * batched AutoSwitch's weighted counter total never exceeds the
    better fixed direction at the same batch width;
  * ``QueryService`` serves a mixed stream (more queries than slots)
    to completion, with slot refill, in-flight coalescing, and LRU
    cache hits on repeated (source, algorithm) pairs;
  * ``api.solve`` rejects out-of-range source/root vertex indices
    instead of letting JAX scatter semantics clip them silently.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import DenseBackend, EllBackend
from repro.service import (QueryService, ResultCache, batchable,
                           graph_fingerprint)

SOURCES = [3, 17, 42, 5, 99, 3, 64, 12]

BATCH_KW = {
    "bfs": {},
    "ppr": {"damp": 0.85, "tol": 1e-6},
    "sssp_delta": {"delta": 2.5},
}


def _single_states(g, alg, policy, backend, kw):
    out = []
    for s in SOURCES:
        skw = dict(kw)
        skw["root" if alg == "bfs" else "source"] = s
        out.append(api.solve(g, alg, policy=policy, backend=backend,
                             **skw).state)
    return out


def test_batchable_registry():
    assert batchable() == ["bfs", "ppr", "sssp_delta"]
    for name in batchable():
        assert name in api.algorithms()


@pytest.mark.parametrize("name", sorted(BATCH_KW))
@pytest.mark.parametrize("bname", ["dense", "ell"])
def test_solve_batch_bit_identical(name, bname, power_graph):
    """The flagship acceptance: every column of a batched run equals the
    single-source run bit for bit — same ints, same float bits — under
    push, pull, and the batch-aware AutoSwitch."""
    backend = DenseBackend() if bname == "dense" else EllBackend()
    for policy in ("push", "pull", "auto"):
        br = api.solve_batch(power_graph, name, sources=SOURCES,
                             policy=policy, backend=backend,
                             **BATCH_KW[name])
        assert br.batch == len(SOURCES)
        singles = _single_states(power_graph, name, policy, backend,
                                 BATCH_KW[name])
        for i, ref in enumerate(singles):
            for key in ref:
                a = np.asarray(jnp.asarray(ref[key]))
                b = np.asarray(jnp.asarray(br.states[i][key]))
                assert np.array_equal(a, b, equal_nan=True), (
                    name, policy, bname, i, key)
        assert bool(np.asarray(br.done).all())


def test_solve_batch_duplicate_sources(small_graph):
    """Duplicate sources are legal and produce identical columns."""
    br = api.solve_batch(small_graph, "bfs", sources=[7, 7, 3])
    np.testing.assert_array_equal(np.asarray(br.states[0]["dist"]),
                                  np.asarray(br.states[1]["dist"]))


def test_solve_batch_errors(small_graph):
    with pytest.raises(KeyError, match="batchable"):
        api.solve_batch(small_graph, "wcc", sources=[0, 1])
    with pytest.raises(KeyError, match="registered"):
        api.solve_batch(small_graph, "nope", sources=[0])
    with pytest.raises(ValueError, match="out of range"):
        api.solve_batch(small_graph, "bfs",
                        sources=[0, small_graph.n])
    with pytest.raises(ValueError, match="non-empty"):
        api.solve_batch(small_graph, "bfs", sources=[])
    with pytest.raises(ValueError, match="1-D"):
        api.solve_batch(small_graph, "bfs", sources=3)   # scalar


# -- the batch-aware cost model ------------------------------------------
def test_predictor_crossover_moves_with_batch_width():
    """Push is priced on the union frontier × width, pull on one
    amortized scan × width; with sublinearly overlapping frontiers the
    per-query frontier size at which pull starts winning shrinks as the
    batch widens."""
    from repro.core import CostPredictor, StepStats

    pred = CostPredictor()
    m, n = 100_000, 10_000

    def crossover(batch: int) -> int:
        """Smallest per-query frontier edge count where pull wins."""
        for k in range(1, m):
            union = min(batch * k, m)        # disjoint-ish frontiers
            stats = StepStats(
                frontier_vertices=jnp.asarray(0),
                frontier_edges=jnp.asarray(union),
                pull_edges=jnp.asarray(m), pull_vertices=jnp.asarray(n),
                unvisited_edges=jnp.asarray(0), step=jnp.asarray(0),
                prev_push=jnp.bool_(False), float_data=False,
                k_filter_push=False, width=batch)
            if float(pred.predict_pull(stats)) < \
                    float(pred.predict_push(stats)):
                return k
        return m

    xs = [crossover(b) for b in (1, 2, 4, 8)]
    assert xs == sorted(xs, reverse=True), xs
    assert xs[-1] < xs[0]            # strictly moved toward pull


def test_batched_auto_not_worse_than_fixed(power_graph):
    """Acceptance: at a fixed batch width, batch-aware AutoSwitch's
    weighted counter total never exceeds the better fixed direction
    (the predictor prices exactly what the engine then charges)."""
    for name in sorted(BATCH_KW):
        rp = api.solve_batch(power_graph, name, sources=SOURCES,
                             policy="push", **BATCH_KW[name])
        rl = api.solve_batch(power_graph, name, sources=SOURCES,
                             policy="pull", **BATCH_KW[name])
        ra = api.solve_batch(power_graph, name, sources=SOURCES,
                             policy="auto", **BATCH_KW[name])
        best = min(float(rp.cost.weighted_total()),
                   float(rl.cost.weighted_total()))
        assert float(ra.cost.weighted_total()) <= best, name


def test_stepstats_width_default_is_one(small_graph):
    """Single-query runs keep width=1 semantics: predictions (and so
    AutoSwitch decisions) are unchanged from the pre-batch engine."""
    from repro.core import StepStats
    assert StepStats._field_defaults["width"] == 1


# -- QueryService --------------------------------------------------------
def test_query_service_mixed_stream(power_graph):
    """Acceptance: ≥3 algorithms, ≥16 queries, fewer slots than
    queries, served to completion with correct per-query results and
    cache hits on repeated (source, algorithm) pairs."""
    g = power_graph
    svc = QueryService(g, slots=4, chunk_steps=8)
    expect = {}
    for s in [3, 17, 42, 5, 99, 64, 12, 77, 120, 8]:
        expect[svc.submit("bfs", source=s)] = ("bfs", s)
    for s in [1, 2, 3]:
        expect[svc.submit("ppr", source=s, damp=0.85, tol=1e-6)] = \
            ("ppr", s)
    for s in [0, 7, 9]:
        expect[svc.submit("sssp_delta", source=s, delta=2.5)] = \
            ("sssp_delta", s)
    wcc_rid = svc.submit("wcc")          # unbatchable, same surface
    assert len(expect) + 1 >= 17
    assert svc.pending() == len(expect) + 1
    svc.run_until_complete()
    assert svc.pending() == 0

    for rid, (alg, s) in expect.items():
        got = svc.poll(rid)
        assert got is not None
        if alg == "bfs":
            ref = api.solve(g, "bfs", root=s).state
        elif alg == "ppr":
            ref = api.solve(g, "ppr", source=s, damp=0.85,
                            tol=1e-6).state
        else:
            ref = api.solve(g, "sssp_delta", source=s, delta=2.5).state
        for key in ref:
            assert np.array_equal(np.asarray(jnp.asarray(ref[key])),
                                  np.asarray(jnp.asarray(got[key])),
                                  equal_nan=True), (alg, s, key)
    assert np.array_equal(np.asarray(svc.poll(wcc_rid)),
                          np.asarray(api.solve(g, "wcc").state))

    stats = svc.stats()
    # more queries than slots forced chunked slot refill
    assert stats["batches_started"] >= 1
    assert stats["chunks_run"] > stats["batches_started"]

    # repeated (source, algorithm) pairs hit the LRU cache
    hits0 = svc.cache.hits
    r2 = svc.submit("bfs", source=42)
    r3 = svc.submit("sssp_delta", source=7, delta=2.5)
    assert svc.poll(r2) is not None and svc.poll(r3) is not None
    assert svc.record(r2).cached and svc.record(r3).cached
    assert svc.cache.hits == hits0 + 2


def test_query_service_force_retires_nonconverging(small_graph):
    """A query that can never satisfy its done mask is force-retired
    with its best-effort state after the per-query chunk budget instead
    of wedging the serving loop (tol=0 is unreachable: the residual
    freeze test is `resid >= tol`, so resid can only reach 0, never
    drop below it)."""
    svc = QueryService(small_graph, slots=2, chunk_steps=16,
                       max_chunks_per_query=5)
    rid = svc.submit("ppr", source=1, tol=0.0)
    svc.run_until_complete(max_rounds=50)
    got = svc.poll(rid)
    assert got is not None
    assert svc.stats()["force_retired"] == 1
    assert svc.record(rid).converged is False
    # best-effort ranks are still the (converged-in-practice) fixpoint
    ref = api.solve(small_graph, "ppr", source=1).state["ranks"]
    np.testing.assert_allclose(np.asarray(got["ranks"]),
                               np.asarray(ref), atol=1e-5)
    # a best-effort state is never served as an authoritative cache hit
    rid2 = svc.submit("ppr", source=1, tol=0.0)
    assert not svc.record(rid2).cached and svc.poll(rid2) is None


def test_query_service_evicts_old_done_records(small_graph):
    """Finished records past max_records are dropped (with their result
    pytrees), so a long-lived service does not grow without bound."""
    svc = QueryService(small_graph, slots=4, max_records=4)
    rids = [svc.submit("bfs", source=s) for s in range(8)]
    svc.run_until_complete()
    kept = [r for r in rids if r in svc._records]
    assert len(kept) <= 4
    assert svc.poll(kept[-1]) is not None
    with pytest.raises(KeyError):
        svc.poll(rids[0])                # oldest evicted


def test_solve_batch_rejects_distributed_backend(small_graph):
    """Batched programs guard their backend support like the
    single-query specs do (DistributedBackend charges width-blind
    counters, which would break the batch-aware predictor)."""
    from repro.core import DistributedBackend
    db = DistributedBackend.prepare(small_graph)
    for name in ("bfs", "ppr", "sssp_delta"):
        with pytest.raises(ValueError, match="DistributedBackend"):
            api.solve_batch(small_graph, name, sources=[0, 1],
                            backend=db, **BATCH_KW[name])


def test_graph_fingerprint_sensitive_to_weight_order(small_graph):
    """Swapping two edge weights changes the fingerprint (a shared
    ResultCache must never serve one weighting's results for
    another)."""
    import numpy as onp
    from repro.graphs.structure import build_graph
    src = onp.asarray(small_graph.coo_src)
    dst = onp.asarray(small_graph.coo_dst)
    w = onp.asarray(small_graph.coo_w).copy()
    w[0], w[1] = w[1], w[0]
    assert w[0] != w[1]                  # the swap is observable
    g2 = build_graph(src, dst, n=small_graph.n, weights=w,
                     d_ell=small_graph.d_ell)
    assert graph_fingerprint(small_graph) != graph_fingerprint(g2)


def test_query_service_bad_policy_rejected_at_submit(small_graph):
    svc = QueryService(small_graph, slots=2)
    with pytest.raises(ValueError, match="policy"):
        svc.submit("bfs", source=0, policy="bogus")
    assert svc.pending() == 0            # nothing half-enqueued


def test_query_service_failed_query_does_not_wedge(small_graph):
    """A request whose engine build fails (unsupported backend cell) is
    failed gracefully: the loop still drains, poll raises the chained
    error, and other queries serve normally."""
    from repro.core import DistributedBackend
    svc = QueryService(small_graph, slots=2)
    db = DistributedBackend.prepare(small_graph)
    bad = svc.submit("ppr", source=0, backend=db)  # batched build rejects
    good = svc.submit("bfs", source=1)
    svc.run_until_complete(max_rounds=100)
    with pytest.raises(RuntimeError, match="failed"):
        svc.poll(bad)
    assert svc.poll(good) is not None


def test_query_service_single_group_slot_refill(power_graph):
    """A full-width batch refills retired slots from its own queue: ten
    queries over four slots serve as ONE continuous batch."""
    svc = QueryService(power_graph, slots=4, chunk_steps=4)
    rids = [svc.submit("bfs", source=s)
            for s in [3, 17, 42, 5, 99, 64, 12, 77, 120, 8]]
    svc.run_until_complete()
    assert svc.stats()["batches_started"] == 1
    assert svc.stats()["chunks_run"] >= 3
    ref = api.solve(power_graph, "bfs", root=120).state["dist"]
    np.testing.assert_array_equal(np.asarray(svc.poll(rids[8])["dist"]),
                                  np.asarray(ref))


def test_query_service_underwidth_batch_restarts_wider(small_graph):
    """Queries arriving after an under-width batch started do not
    serialize through its few columns: the narrow batch drains without
    refilling and the backlog restarts at full width."""
    svc = QueryService(small_graph, slots=4, chunk_steps=1)
    first = svc.submit("bfs", source=0)
    svc.step()                 # width-1 batch in flight, not yet done
    late = [svc.submit("bfs", source=s) for s in range(1, 9)]
    svc.run_until_complete()
    # batch 1 = the width-1 run; batch 2 = the 8 late queries at width
    # 4 with continuous refill (serializing would keep batches == 1)
    assert svc.stats()["batches_started"] == 2
    for rid, s in zip([first] + late, range(9)):
        ref = api.solve(small_graph, "bfs", root=s).state["dist"]
        np.testing.assert_array_equal(
            np.asarray(svc.poll(rid)["dist"]), np.asarray(ref))


def test_query_service_no_group_starvation(small_graph):
    """Group selection is FIFO by oldest waiting request, so a minority
    group is served as soon as the requests ahead of it drain — a
    steady majority stream cannot starve it."""
    svc = QueryService(small_graph, slots=2, chunk_steps=64)
    for s in range(4):
        svc.submit("bfs", source=s)
    minority = svc.submit("wcc")
    late = [svc.submit("bfs", source=s) for s in range(4, 10)]
    steps = 0
    while svc.poll(minority) is None:
        svc.step()
        steps += 1
        assert steps < 100
    # the minority query completed before the later-submitted majority
    assert any(not svc.record(r).done for r in late)
    svc.run_until_complete()
    assert not svc._queues                # drained queues are deleted


def test_query_service_caches_unbatchable_with_honest_flag(small_graph):
    """Unbatchable solves are deterministic given their params, so they
    cache even when bounded (pagerank's fixed-iteration
    converged=False) — and cache hits report the true convergence
    flag."""
    svc = QueryService(small_graph, slots=2)
    a = svc.submit("pagerank", iters=5)
    svc.run_until_complete()
    assert svc.record(a).done and svc.record(a).converged is False
    b = svc.submit("pagerank", iters=5)
    assert svc.record(b).cached and svc.record(b).converged is False
    np.testing.assert_array_equal(np.asarray(svc.poll(a)),
                                  np.asarray(svc.poll(b)))


def test_query_service_coalesces_inflight_duplicates(small_graph):
    svc = QueryService(small_graph, slots=2)
    a = svc.submit("bfs", source=5)
    b = svc.submit("bfs", source=5)      # identical, still in flight
    assert svc.stats()["coalesced"] == 1
    svc.run_until_complete()
    np.testing.assert_array_equal(np.asarray(svc.poll(a)["dist"]),
                                  np.asarray(svc.poll(b)["dist"]))


def test_query_service_validates_sources(small_graph):
    svc = QueryService(small_graph, slots=2)
    with pytest.raises(ValueError, match="out of range"):
        svc.submit("bfs", source=-1)
    with pytest.raises(KeyError, match="registered"):
        svc.submit("nope", source=0)
    # a missing source for a source-parameterized algorithm fails at
    # submit, not deep inside the serving loop
    with pytest.raises(ValueError, match="source-parameterized"):
        svc.submit("bfs")
    assert svc.pending() == 0            # nothing half-enqueued


def test_query_service_honors_step_bound(small_graph):
    """A per-query step budget (ppr's iters) bounds the chunked run
    like it bounds a single solve: the query retires best-effort,
    converged=False, uncached — instead of running to convergence and
    caching a result a bounded solve would never produce."""
    svc = QueryService(small_graph, slots=2, chunk_steps=32)
    rid = svc.submit("ppr", source=1, iters=5, tol=1e-12)
    svc.run_until_complete(max_rounds=50)
    rec = svc.record(rid)
    assert rec.done and not rec.converged and not rec.cached
    assert svc.stats()["force_retired"] == 1
    assert len(svc.cache) == 0           # best-effort result not cached
    # the bounded single solve also reports non-convergence
    assert not bool(api.solve(small_graph, "ppr", source=1, iters=5,
                              tol=1e-12).converged)


# -- result cache --------------------------------------------------------
def test_result_cache_lru_eviction():
    c = ResultCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1               # refreshes 'a'
    c.put("c", 3)                        # evicts 'b' (least recent)
    assert "b" not in c and c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    assert c.stats()["hits"] == 3 and c.stats()["misses"] == 1


def test_graph_fingerprint_distinguishes_graphs(small_graph, power_graph):
    assert graph_fingerprint(small_graph) == graph_fingerprint(
        small_graph)
    assert graph_fingerprint(small_graph) != graph_fingerprint(
        power_graph)


# -- api.solve index validation (regression) -----------------------------
def test_solve_rejects_out_of_range_vertices(small_graph):
    """Regression: negative / ≥n roots used to clip/drop silently under
    JAX scatter semantics; now they raise naming the bad index."""
    n = small_graph.n
    with pytest.raises(ValueError, match=f"-1.*n={n}"):
        api.solve(small_graph, "bfs", root=-1)
    with pytest.raises(ValueError, match=f"{n}.*n={n}"):
        api.solve(small_graph, "sssp_delta", source=n, delta=2.0)
    with pytest.raises(ValueError, match="out of range"):
        api.solve(small_graph, "ppr", source=n + 5)
    with pytest.raises(ValueError, match="not a vertex index"):
        api.solve(small_graph, "bfs", root=1.5)
    # in-range values (int, np scalar, jnp scalar) still work
    api.solve(small_graph, "bfs", root=np.int64(n - 1))
    api.solve(small_graph, "bfs", root=jnp.int32(0))


# -- throughput harness row contract -------------------------------------
def test_service_bench_row_validates_against_schema(small_graph):
    """One real solve_batch-backed service row conforms to the schema's
    service_cell shape (the CI smoke runs the full harness)."""
    from benchmarks.validate import _check, load_schema
    r = api.solve_batch(small_graph, "bfs", sources=[0, 1, 2, 3])
    payload = {
        "algorithm": "bfs", "graph": "er", "n": int(small_graph.n),
        "m": int(small_graph.m), "policy": "pull", "backend": "dense",
        "batch": 4, "queries": 4, "us_per_query_batched": 10.0,
        "us_per_query_sequential": 40.0, "qps_batched": 100.0,
        "qps_sequential": 25.0, "speedup": 4.0,
        "steps": int(r.steps), "push_steps": int(r.push_steps),
        "weighted_total": float(r.cost.weighted_total()),
    }
    schema = load_schema()
    defs = schema["definitions"]
    _check(payload, defs["service_cell"], defs)
    report = {"rows": [{"name": "service_bfs_er_pull_b4",
                        "us_per_call": 40.0, "derived": payload}],
              "failures": []}
    from benchmarks.validate import validate_report
    assert validate_report(report)
    bad = dict(payload)
    del bad["qps_batched"]
    with pytest.raises(Exception):
        validate_report({"rows": [{"name": "service_x",
                                   "us_per_call": 1.0, "derived": bad}],
                         "failures": []})
