"""Extensions beyond the base deliverables: WCC, data-driven PR, the
roofline HLO parser, and the train launcher (query serving moved to
repro.service)."""

import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

from repro.core.algorithms import pagerank, pagerank_delta, wcc
from repro.core.direction import Direction, Fixed, GenericSwitch
from repro.graphs import erdos_renyi, kronecker
from repro.roofline.analysis import (collective_bytes_from_hlo,
                                     roofline_report)


@pytest.mark.parametrize("policy", [Fixed(Direction.PUSH),
                                    Fixed(Direction.PULL),
                                    GenericSwitch()])
def test_wcc_matches_networkx(policy, nx_of):
    g = erdos_renyi(250, 1.5, seed=9, weighted=True)
    G = nx_of(g)
    r = wcc(g, policy)
    assert int(r.num_components) == nx.number_connected_components(G)
    # labels constant within each nx component
    labels = np.asarray(r.labels)
    for comp in nx.connected_components(G):
        comp = list(comp)
        assert len(set(labels[comp].tolist())) == 1


def test_wcc_cost_structure():
    g = erdos_renyi(200, 3.0, seed=2)
    push = wcc(g, Fixed(Direction.PUSH)).cost
    pull = wcc(g, Fixed(Direction.PULL)).cost
    assert int(pull.atomics) == 0
    assert int(push.atomics) > 0


def test_pagerank_delta_converges_to_power_iteration():
    g = kronecker(8, 5, seed=3)
    ref = pagerank(g, 150, direction="pull").ranks
    for d in ("push", "pull"):
        r = pagerank_delta(g, tol=1e-8, direction=d)
        np.testing.assert_allclose(np.asarray(r.ranks), np.asarray(ref),
                                   atol=1e-5)
        assert float(r.max_residual) <= 1e-8


def test_pagerank_delta_is_work_efficient():
    """The paper's §3.8 claim quantified: pushing with a shrinking active
    set does less total work than synchronous sweeps."""
    g = kronecker(9, 6, seed=2)
    dd = pagerank_delta(g, tol=1e-8, direction="push").cost
    sync = pagerank(g, 120, direction="push").cost
    assert int(dd.reads) < int(sync.reads)
    assert int(dd.locks) < int(sync.locks)


# ------------------------------------------------------ roofline parser --
HLO_SNIPPET = """
ENTRY %main {
  %ag = bf16[16,128]{1,0} all-gather(%x), dimensions={1}
  %ar.1 = f32[4,4]{1,0} all-reduce(%y), to_apply=%add
  %rs = (f32[8]{0}, f32[8]{0}) reduce-scatter(%a, %b), dimensions={0}
  %a2a-start = s8[2,2,2]{2,1,0} all-to-all-start(%z)
  %a2a-done = s8[2,2,2]{2,1,0} all-to-all-done(%a2a-start)
  %cp = f32[10]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[16,16]{1,0} dot(%p, %q)
}
"""


def test_collective_parser_counts_and_bytes():
    out = collective_bytes_from_hlo(HLO_SNIPPET)
    by = out["by_kind"]
    assert by["all-gather"] == {"count": 1, "bytes": 16 * 128 * 2}
    assert by["all-reduce"] == {"count": 1, "bytes": 4 * 4 * 4}
    assert by["reduce-scatter"]["bytes"] == 2 * 8 * 4
    assert by["all-to-all"] == {"count": 1, "bytes": 8}  # start only
    assert by["collective-permute"]["bytes"] == 40
    # the plain dot must NOT be counted
    assert out["total_count"] == 5


def test_roofline_report_terms():
    fake = {"cost": {"flops": 197e12, "bytes_accessed": 819e9},
            "collectives": {"total_bytes": 25e9}}
    rf = roofline_report(fake)
    assert abs(rf["compute_s"] - 1.0) < 1e-6
    assert abs(rf["memory_s"] - 1.0) < 1e-6
    assert abs(rf["collective_s"] - 0.5) < 1e-6
    assert rf["dominant"] in ("compute", "memory")


# ---------------------------------------------------------- launchers ---
def test_train_launcher_smoke(tmp_path):
    from repro.launch.train import main
    assert main(["--arch", "gin-tu", "--steps", "3",
                 "--ckpt-dir", str(tmp_path)]) == 0


def test_train_launcher_lm(tmp_path):
    from repro.launch.train import main
    assert main(["--arch", "llama3.2-1b", "--steps", "2", "--batch", "2",
                 "--seq", "16"]) == 0


def test_serving_owned_by_service_layer():
    """The LM decode serving stack is gone: graph query serving lives in
    repro.service (QueryService); repro.serve / launch.serve no longer
    exist."""
    import importlib
    import pytest
    for gone in ("repro.serve", "repro.launch.serve"):
        with pytest.raises(ModuleNotFoundError):
            importlib.import_module(gone)
    from repro.service import QueryService  # noqa: F401 — the successor
