import warnings

import numpy as np
import pytest

warnings.filterwarnings("ignore", message=".*x64.*")
warnings.filterwarnings("ignore", category=DeprecationWarning)

from hypothesis import settings, HealthCheck

settings.register_profile(
    "ci", max_examples=20, deadline=None,
    suppress_health_check=[HealthCheck.too_slow,
                           HealthCheck.data_too_large])
settings.load_profile("ci")


@pytest.fixture(scope="session")
def small_graph():
    from repro.graphs import erdos_renyi
    return erdos_renyi(120, 4.0, seed=11, weighted=True)


@pytest.fixture(scope="session")
def power_graph():
    from repro.graphs import kronecker
    return kronecker(8, edge_factor=6, seed=7, weighted=True)


@pytest.fixture(scope="session")
def nx_of():
    import networkx as nx

    def build(g):
        G = nx.Graph()
        G.add_nodes_from(range(g.n))
        for s, d, w in zip(np.asarray(g.coo_src), np.asarray(g.coo_dst),
                           np.asarray(g.coo_w)):
            G.add_edge(int(s), int(d), weight=float(w))
        return G

    return build
