import itertools
import sys
import types
import warnings

import numpy as np
import pytest

warnings.filterwarnings("ignore", message=".*x64.*")
warnings.filterwarnings("ignore", category=DeprecationWarning)

try:
    from hypothesis import settings, HealthCheck

    settings.register_profile(
        "ci", max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("ci")
except ModuleNotFoundError:
    # Offline degradation: install a deterministic stand-in so the
    # property-based tests still collect and run. Each strategy exposes a
    # small fixed sample set; @given runs the cartesian product.

    class _Strategy:
        def __init__(self, values):
            self.values = list(values)

    def _integers(lo, hi):
        mid = lo + (hi - lo) // 2
        return _Strategy(sorted({lo, mid, hi}))

    def _sampled_from(elems):
        return _Strategy(elems)

    def _given(**strategies):
        names = list(strategies)
        combos = list(itertools.product(
            *(strategies[k].values for k in names)))[:32]

        def deco(fn):
            def runner():
                for combo in combos:
                    fn(**dict(zip(names, combo)))

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco

    class _Settings:
        def __init__(self, *a, **k):
            pass

        @staticmethod
        def register_profile(*a, **k):
            pass

        @staticmethod
        def load_profile(*a, **k):
            pass

        def __call__(self, fn):
            return fn

    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _integers
    st.sampled_from = _sampled_from
    hyp.given = _given
    hyp.settings = _Settings
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


@pytest.fixture(scope="session")
def small_graph():
    from repro.graphs import erdos_renyi
    return erdos_renyi(120, 4.0, seed=11, weighted=True)


@pytest.fixture(scope="session")
def power_graph():
    from repro.graphs import kronecker
    return kronecker(8, edge_factor=6, seed=7, weighted=True)


@pytest.fixture(scope="session")
def nx_of():
    import networkx as nx

    def build(g):
        G = nx.Graph()
        G.add_nodes_from(range(g.n))
        for s, d, w in zip(np.asarray(g.coo_src), np.asarray(g.coo_dst),
                           np.asarray(g.coo_w)):
            G.add_edge(int(s), int(d), weight=float(w))
        return G

    return build
