"""Chaos tests for repro.resilience (PR 10).

The invariant under test, at every wired seam: a *transient* injected
fault is recovered (retry / fallback / resume) and the result is
identical to the fault-free run (bit-identical for integer states,
1e-5 for float); a *permanent* fault yields either a correct degraded
result (the jnp fallback carries the solve) or a structured error —
never a hang, never silent corruption.
"""

import json
import time

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro import api
from repro import resilience
from repro.core.backend import DenseBackend, PallasBackend
from repro.core.engine import Checkpoint, PushPullEngine
from repro.graphs import erdos_renyi
from repro.graphs.structure import build_graph
from repro.resilience import (AdmissionError, CircuitBreaker,
                              DeadlineExceeded, DivergenceError,
                              FaultInjected, FaultPlan, FaultSpec,
                              ProbeTimeout, SolveInterrupted,
                              clear_resilience_stats, drain_events,
                              fault_point, inject, named_plans,
                              resilience_stats, resilient_call)
from repro.service.scheduler import QueryService


@pytest.fixture(autouse=True)
def _clean_resilience():
    """Every test starts and ends with no active plan and fresh
    process-wide counters — chaos state must not leak across tests."""
    resilience.deactivate()
    clear_resilience_stats()
    yield
    resilience.deactivate()
    clear_resilience_stats()


def _plan(*specs, name="test", seed=0):
    return FaultPlan(name=name, seed=seed, specs=tuple(specs))


# ---------------------------------------------------------------------------
# FaultPlan / FaultSpec / FaultInjector units
# ---------------------------------------------------------------------------

def test_spec_rejects_unknown_site_kind_error():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec(site="nonsense.site")
    with pytest.raises(ValueError, match="transient"):
        FaultSpec(site="pallas.pull", kind="flaky")
    with pytest.raises(ValueError, match="unknown error class"):
        FaultSpec(site="pallas.pull", error="SegFault")
    with pytest.raises(ValueError, match=">= 1"):
        FaultSpec(site="pallas.pull", every=0)


def test_plan_rejects_duplicate_sites_and_round_trips_json():
    with pytest.raises(ValueError, match="duplicate"):
        _plan(FaultSpec(site="pallas.pull"), FaultSpec(site="pallas.pull"))
    plan = named_plans()["ci-default"]
    again = FaultPlan.from_json(plan.to_json())
    assert again == plan
    assert json.loads(plan.to_json())["name"] == "ci-default"


def test_injector_schedule_is_deterministic_and_transient_recovers():
    plan = _plan(FaultSpec(site="pallas.pull", kind="transient",
                           every=3, start=1))

    def pattern():
        fired = []
        with inject(plan):
            for _ in range(9):
                try:
                    fault_point("pallas.pull")
                    fired.append(False)
                except FaultInjected:
                    fired.append(True)
        return fired

    first = pattern()
    # hits 1, 4, 7 fault; the hit after each scheduled fault is clean,
    # so a single retry always recovers
    assert first == [True, False, False] * 3
    assert pattern() == first            # same plan -> same schedule


def test_rate_schedule_is_seeded_and_reproducible():
    plan = _plan(FaultSpec(site="service.chunk", rate=0.5), seed=42)

    def pattern():
        out = []
        with inject(plan):
            for _ in range(40):
                try:
                    fault_point("service.chunk")
                    out.append(0)
                except FaultInjected:
                    out.append(1)
        return out

    p = pattern()
    assert p == pattern()
    assert 0 < sum(p) < 40               # scattered, not all-or-nothing


def test_fault_point_rejects_unknown_site_only_when_active():
    fault_point("engine.step")           # no plan: pure no-op
    with inject(_plan(FaultSpec(site="engine.step"))):
        with pytest.raises(ValueError, match="unknown fault site"):
            fault_point("not.a.site")


def test_inject_restores_previous_injector():
    outer = _plan(FaultSpec(site="tune.probe", kind="permanent"))
    inner = _plan(FaultSpec(site="engine.step", kind="permanent"))
    with inject(outer):
        with inject(inner):
            assert resilience.active_plan() is inner
        assert resilience.active_plan() is outer
    assert resilience.active_plan() is None


def test_resilient_call_retries_transient_and_exhausts_permanent():
    calls = []
    with inject(_plan(FaultSpec(site="shard.exchange.push",
                                kind="transient", every=99, start=1))):
        out = resilient_call("shard.exchange.push",
                             lambda: calls.append(1) or "ok")
    assert out == "ok" and len(calls) == 1
    assert resilience_stats()["retry.shard.exchange.push"] == 1
    with inject(_plan(FaultSpec(site="shard.exchange.push",
                                kind="permanent"))):
        with pytest.raises(FaultInjected):
            resilient_call("shard.exchange.push", lambda: "never",
                           retries=2)


def test_env_plan_selection(monkeypatch):
    from repro.resilience import faults
    monkeypatch.setenv("REPRO_FAULT_PLAN", "ci-default")
    faults._install_from_env()
    assert resilience.active_plan().name == "ci-default"
    resilience.deactivate()
    monkeypatch.setenv("REPRO_FAULT_PLAN", "no-such-plan")
    with pytest.raises(ValueError, match="REPRO_FAULT_PLAN"):
        faults._install_from_env()


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

def test_breaker_opens_cools_and_half_open_probes():
    br = CircuitBreaker(failure_threshold=2, cooldown=3)
    cell = ("pull", 8)
    assert br.allow(cell)
    assert not br.record_failure(cell)
    assert br.record_failure(cell)       # second failure opens
    assert br.state(cell) == "open"
    assert not br.allow(cell)            # cooldown burns one tick/call
    assert not br.allow(cell)
    assert br.allow(cell)                # exhausting tick -> half-open probe
    assert br.state(cell) == "half-open"
    br.record_success(cell)
    assert br.state(cell) == "closed"
    assert br.stats()["opened_total"] == 1


def test_breaker_reopens_on_failed_probe():
    br = CircuitBreaker(failure_threshold=1, cooldown=2)
    cell = "c"
    assert br.record_failure(cell)
    assert not br.allow(cell)            # burns the first cooldown tick
    assert br.allow(cell)                # half-open probe
    assert br.record_failure(cell)       # probe failed -> re-open
    assert br.state(cell) == "open"
    assert br.stats()["opened_total"] == 2
    assert cell in br.open_cells()


def test_breaker_success_resets_consecutive_failures():
    br = CircuitBreaker(failure_threshold=3, cooldown=2)
    for _ in range(5):                   # never 3 consecutive
        br.record_failure("x")
        br.record_failure("x")
        br.record_success("x")
    assert br.state("x") == "closed"
    assert br.stats()["opened_total"] == 0


# ---------------------------------------------------------------------------
# kernel dispatch degradation ladder
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chaos_graph():
    return erdos_renyi(80, 4.0, seed=3, weighted=True)


def test_kernels_down_degrades_to_jnp_and_matches(chaos_graph):
    ref = np.asarray(api.solve(chaos_graph, "bfs", root=0,
                               backend=DenseBackend()).state["dist"])
    with inject(named_plans()["kernels-down"]):
        be = PallasBackend()
        r = api.solve(chaos_graph, "bfs", root=0, backend=be)
    assert (np.asarray(r.state["dist"]) == ref).all()
    assert be.stats["fault_fallback_pull"] >= 1
    assert be.stats["fault_fallback_push"] >= 1
    stats = resilience_stats()
    assert stats["fallback.pallas.pull"] >= 1
    assert stats["fallback.pallas.push"] >= 1


def test_repeated_kernel_failures_open_the_breaker(chaos_graph):
    g = chaos_graph
    be = PallasBackend(breaker=CircuitBreaker(failure_threshold=2,
                                              cooldown=4))
    values = jnp.full((g.n,), jnp.inf).at[0].set(0.0)
    touched = jnp.ones((g.n,), bool)
    from repro.core.cost_model import Cost
    with inject(_plan(FaultSpec(site="pallas.pull", kind="permanent"))):
        for _ in range(5):
            out, _ = be.pull(g, values, touched, "min", None, Cost())
    # 2 failures open the cell; later calls skip the kernel entirely
    assert be.stats["fault_fallback_pull"] == 2
    assert be.stats["breaker_skip_pull"] == 3
    assert be.stats["breaker_open"] == 1
    assert be.telemetry_counters()["breaker_opened_total"] == 1
    # degraded answers still correct: one min-plus relaxation from
    # vertex 0 must reach its out-neighbors
    ref, _ = DenseBackend().pull(g, values, touched, "min", None, Cost())
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_transient_kernel_fault_recovers_next_dispatch(chaos_graph):
    g = chaos_graph
    be = PallasBackend()
    values = jnp.ones((g.n,), jnp.float32)
    from repro.core.cost_model import Cost
    with inject(_plan(FaultSpec(site="pallas.pull", kind="transient",
                                every=99, start=1))):
        a, _ = be.pull(g, values, None, "sum", None, Cost())   # faults
        b, _ = be.pull(g, values, None, "sum", None, Cost())   # clean
    assert be.stats["fault_fallback_pull"] == 1
    assert be.stats["kernel_pull"] >= 1
    assert np.allclose(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# tuner: retry, degrade, deadline
# ---------------------------------------------------------------------------

def _fresh_tuner(monkeypatch, tmp_path):
    from repro.kernels import tune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    tune.clear_memory_cache()
    tune.clear_stats()
    return tune


def test_tuner_transient_probe_fault_retries(monkeypatch, tmp_path):
    tune = _fresh_tuner(monkeypatch, tmp_path)
    with inject(_plan(FaultSpec(site="tune.probe", kind="transient",
                                every=99, start=1))):
        best = tune.tune_pull(400, 8, 1, jnp.float32, "sum", "copy",
                              interpret=True)
    assert best in tune.pull_candidates(400, 1)
    s = tune.tune_stats()
    assert s["probe_retries"] >= 1 and s["probe_failures"] >= 1
    assert s["probe_degraded"] == 0
    assert s["writes"] == 1              # recovered winner is persisted


def test_tuner_permanent_probe_fault_degrades_unpersisted(monkeypatch,
                                                          tmp_path):
    tune = _fresh_tuner(monkeypatch, tmp_path)
    monkeypatch.setenv("REPRO_TUNE_RETRIES", "1")
    with inject(_plan(FaultSpec(site="tune.probe", kind="permanent"))):
        best = tune.tune_pull(400, 8, 1, jnp.float32, "sum", "copy",
                              interpret=True)
    cands = tune.pull_candidates(400, 1)
    assert best == cands[0]              # the default candidate
    s = tune.tune_stats()
    assert s["probe_degraded"] == 1
    assert s["writes"] == 0              # NOT persisted: healthy runs
    assert not (tmp_path / "tune.json").exists()  # will re-probe
    assert resilience_stats()["degraded.tune.probe"] == 1


def test_probe_deadline_abandons_hung_probe(monkeypatch):
    from repro.kernels import tune
    monkeypatch.setenv("REPRO_TUNE_DEADLINE_S", "0.05")
    monkeypatch.setenv("REPRO_TUNE_RETRIES", "0")
    tune.clear_stats()
    t0 = time.perf_counter()
    winner, probed = tune._probe_guarded(
        "pull", lambda: time.sleep(5) or 1, default=128)
    assert time.perf_counter() - t0 < 2.0     # did not wait 5s
    assert (winner, probed) == (128, False)
    assert tune.tune_stats()["probe_timeouts"] == 1


def test_escaped_raises_probe_timeout():
    from repro.kernels.tune import _escaped
    with pytest.raises(ProbeTimeout, match="deadline"):
        _escaped(lambda: time.sleep(5), deadline=0.05, kernel="push")
    assert _escaped(lambda: 7, deadline=1.0) == 7


def test_tuner_disk_faults_degrade_to_memory_tier(monkeypatch, tmp_path):
    tune = _fresh_tuner(monkeypatch, tmp_path)
    plan = _plan(FaultSpec(site="tune.cache.load", kind="permanent",
                           error="OSError"),
                 FaultSpec(site="tune.cache.write", kind="permanent",
                           error="OSError"))
    with inject(plan):
        best = tune.tune_pull(400, 8, 1, jnp.float32, "sum", "copy",
                              interpret=True)
        # memory tier still serves the winner
        again = tune.tune_pull(400, 8, 1, jnp.float32, "sum", "copy",
                               interpret=True)
    assert best == again
    s = tune.tune_stats()
    assert s["write_errors"] >= 1 and s["mem_hits"] >= 1
    assert not (tmp_path / "tune.json").exists()


# ---------------------------------------------------------------------------
# sharded exchange
# ---------------------------------------------------------------------------

def test_shard_exchange_transient_faults_recover(chaos_graph):
    from repro.shard import ShardedBackend
    ref = np.asarray(api.solve(chaos_graph, "bfs", root=0,
                               backend=DenseBackend()).state["dist"])
    plan = _plan(FaultSpec(site="shard.exchange.push", kind="transient",
                           every=3, start=1),
                 FaultSpec(site="shard.exchange.pull", kind="transient",
                           every=3, start=1))
    with inject(plan) as inj:
        sb = ShardedBackend.prepare(chaos_graph, num_shards=1)
        r = api.solve(chaos_graph, "bfs", root=0, backend=sb)
    assert (np.asarray(r.state["dist"]) == ref).all()
    injected = inj.stats()["injected"]
    assert sum(injected.values()) >= 1
    retries = [k for k in resilience_stats() if k.startswith("retry.shard")]
    assert retries


# ---------------------------------------------------------------------------
# engine guards: check_finite, checkpoint/resume
# ---------------------------------------------------------------------------

def test_check_finite_modes():
    nan_state = {"x": jnp.array([1.0, jnp.nan])}
    inf_state = {"d": jnp.array([0.0, jnp.inf]), "i": jnp.array([1, 2])}
    with pytest.raises(DivergenceError, match="step 3"):
        PushPullEngine._check_finite(nan_state, "nan", 3)
    PushPullEngine._check_finite(inf_state, "nan", 0)  # Inf sentinel ok
    with pytest.raises(DivergenceError):
        PushPullEngine._check_finite(inf_state, "all", 0)
    PushPullEngine._check_finite({"i": jnp.array([1, 2])}, True, 0)


def test_solve_auto_resumes_transient_step_faults(chaos_graph):
    ref = np.asarray(api.solve(chaos_graph, "bfs", root=1).state["dist"])
    # every=4 leaves 3 clean hits between faults, so each resume makes
    # real progress and the 5-step BFS finishes inside max_resumes=4
    plan = _plan(FaultSpec(site="engine.step", kind="transient",
                           every=4, start=2))
    with inject(plan) as inj:
        r = api.solve(chaos_graph, "bfs", root=1, checkpoint_every=1)
    assert (np.asarray(r.state["dist"]) == ref).all()
    assert inj.stats()["injected"]["engine.step"] >= 1
    assert resilience_stats()["resume.engine.step"] >= 1


def test_solve_permanent_step_fault_raises_structured(chaos_graph):
    plan = _plan(FaultSpec(site="engine.step", kind="permanent",
                           start=3))
    with inject(plan):
        with pytest.raises(SolveInterrupted) as ei:
            api.solve(chaos_graph, "bfs", root=0, checkpoint_every=1)
    # exhausted the resume budget: structured, cause-chained, no hang
    assert isinstance(ei.value.__cause__, FaultInjected)
    assert isinstance(ei.value.checkpoint, Checkpoint)
    assert ei.value.checkpoint.step >= 1


def test_manual_checkpoint_resume_is_bit_identical(chaos_graph):
    spec = api.get_spec("bfs")
    policy = api._resolve_policy("auto")
    backend = api._resolve_backend(None, chaos_graph)
    program, default_steps = spec.build(chaos_graph, policy=policy,
                                        backend=backend)
    eng = PushPullEngine(program=program, policy=policy,
                         max_steps=default_steps, backend=backend)
    state0, frontier0 = spec.init(chaos_graph, root=0)
    whole = eng.run_stepwise(chaos_graph, state0, frontier0)
    with inject(_plan(FaultSpec(site="engine.step", kind="permanent",
                                start=3))):
        with pytest.raises(SolveInterrupted) as ei:
            eng.run_stepwise(chaos_graph, state0, frontier0,
                             checkpoint_every=1)
    ckpt = ei.value.checkpoint
    assert isinstance(ckpt, Checkpoint) and ckpt.step >= 1
    resumed = eng.run_stepwise(chaos_graph, state0, frontier0,
                               resume_from=ckpt)
    for a, b in zip(jax.tree_util.tree_leaves(whole.state),
                    jax.tree_util.tree_leaves(resumed.state)):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert int(whole.steps) == int(resumed.steps)


def test_checkpoint_rejected_for_phase_programs(chaos_graph):
    with pytest.raises(ValueError, match="flat programs"):
        api.solve(chaos_graph, "betweenness", checkpoint_every=4)


# ---------------------------------------------------------------------------
# the chaos invariant: differential results under a full plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm,kwargs,key,exact", [
    ("bfs", dict(root=0), "dist", True),
    ("wcc", dict(), None, True),
    ("pagerank", dict(iters=10), None, False),
])
def test_ci_default_plan_preserves_results(chaos_graph, algorithm,
                                           kwargs, key, exact):
    """Every transient fault in the ci-default plan must be recovered:
    results match the fault-free solve (bit-identical for integer
    states; 1e-5 for float, where the jnp fallback may reorder sums)."""
    def run():
        st = api.solve(chaos_graph, algorithm, backend=PallasBackend(),
                       **kwargs).state
        return np.asarray(st if key is None else st[key])

    ref = run()
    with inject(named_plans()["ci-default"]) as inj:
        got = run()
    assert sum(inj.stats()["injected"].values()) >= 1, (
        "plan injected nothing — the chaos run was vacuous")
    if exact:
        assert (got == ref).all()
    else:
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# QueryService failure paths (satellite: scheduler chaos)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def svc_graph():
    src = np.array([0, 1, 2, 3, 4, 0, 2])
    dst = np.array([1, 2, 3, 4, 5, 2, 5])
    return build_graph(src, dst, 6)


def test_fail_records_slot_and_chunk_in_stats(svc_graph):
    svc = QueryService(svc_graph, slots=2)
    rid = svc.submit("bfs", source=0, bogus_kwarg=1)
    svc.run_until_complete()
    assert svc.status(rid)["status"] == "failed"
    with pytest.raises(RuntimeError, match="failed"):
        svc.poll(rid)
    failures = svc.stats()["failures"]
    assert len(failures) == 1
    assert failures[0]["rid"] == rid
    assert failures[0]["slot"] is not None


def test_unbatchable_serve_time_failure_propagates(svc_graph):
    svc = QueryService(svc_graph, slots=2)
    rid = svc.submit("pagerank", iters="not-a-number")
    svc.run_until_complete()
    st = svc.status(rid)
    assert st["status"] == "failed" and "Error" in st["error"]
    assert svc.pending() == 0


def test_permanent_chunk_fault_fails_structurally_no_hang(svc_graph):
    with inject(_plan(FaultSpec(site="service.chunk",
                                kind="permanent"))):
        svc = QueryService(svc_graph, slots=2)
        rids = [svc.submit("bfs", source=s) for s in range(3)]
        svc.run_until_complete()          # must terminate
    for rid in rids:
        st = svc.status(rid)
        assert st["status"] == "failed"
        assert "FaultInjected" in st["error"]
    fails = svc.stats()["failures"]
    assert fails and all(f["error"] == "FaultInjected" for f in fails)


def test_transient_chunk_fault_retries_and_serves(svc_graph):
    ref = np.asarray(api.solve(svc_graph, "bfs", root=0).state["dist"])
    with inject(_plan(FaultSpec(site="service.chunk", kind="transient",
                                every=3, start=1))):
        svc = QueryService(svc_graph, slots=2)
        rid = svc.submit("bfs", source=0)
        svc.run_until_complete()
        got = np.asarray(svc.poll(rid)["dist"])
    assert (got == ref).all()
    assert svc.chunk_retries >= 1
    assert svc.stats()["chunk_retries"] >= 1


def test_cache_faults_degrade_to_recompute(svc_graph):
    plan = _plan(FaultSpec(site="service.cache.get", kind="permanent",
                           error="OSError"),
                 FaultSpec(site="service.cache.put", kind="permanent",
                           error="OSError"))
    ref = np.asarray(api.solve(svc_graph, "bfs", root=2).state["dist"])
    with inject(plan):
        svc = QueryService(svc_graph, slots=2)
        r1 = svc.submit("bfs", source=2)
        svc.run_until_complete()
        r2 = svc.submit("bfs", source=2)   # lookup faults -> recompute
        svc.run_until_complete()
    assert (np.asarray(svc.poll(r1)["dist"]) == ref).all()
    assert (np.asarray(svc.poll(r2)["dist"]) == ref).all()
    assert svc.cache_errors >= 2
    assert not svc.record(r2).cached


def test_admission_control_bounds_the_queue(svc_graph):
    svc = QueryService(svc_graph, slots=2, max_queue=2)
    svc.submit("bfs", source=0)
    svc.submit("bfs", source=1)
    with pytest.raises(AdmissionError, match="max_queue=2"):
        svc.submit("bfs", source=2)
    assert svc.admission_rejected == 1
    # coalesced duplicates bypass admission (no new engine work)
    rid = svc.submit("bfs", source=0)
    assert svc.record(rid).rid == rid
    svc.run_until_complete()
    # a drained queue admits again; cache hits always admitted
    svc2_rid = svc.submit("bfs", source=2)
    assert svc.status(svc2_rid)["status"] in ("pending", "done")


def test_deadline_expires_queued_queries(svc_graph):
    t = [0.0]
    svc = QueryService(svc_graph, slots=2, clock=lambda: t[0])
    rid = svc.submit("bfs", source=0, deadline_ms=50.0)
    ok = svc.submit("bfs", source=1)          # no deadline
    t[0] = 0.2                                 # 200ms later
    svc.run_until_complete()
    st = svc.status(rid)
    assert st["status"] == "failed" and "DeadlineExceeded" in st["error"]
    with pytest.raises(RuntimeError) as ei:
        svc.poll(rid)
    assert isinstance(ei.value.__cause__, DeadlineExceeded)
    assert ei.value.__cause__.where == "queued"
    assert svc.poll(ok) is not None            # the other query served
    assert svc.stats()["deadline_expired"] == 1


def test_deadline_rejects_nonpositive(svc_graph):
    svc = QueryService(svc_graph)
    with pytest.raises(ValueError, match="deadline_ms"):
        svc.submit("bfs", source=0, deadline_ms=0)


def test_status_is_total_poll_is_not(svc_graph):
    svc = QueryService(svc_graph, slots=2)
    rid = svc.submit("bfs", source=3)
    assert svc.status(rid)["status"] == "pending"
    assert svc.status(10_000) == {"rid": 10_000, "status": "unknown"}
    with pytest.raises(KeyError):
        svc.poll(10_000)
    svc.run_until_complete()
    done = svc.status(rid)
    assert done["status"] == "done" and done["converged"]


def test_force_retire_under_faults_returns_best_effort(svc_graph):
    """A query that can't converge inside its chunk budget is
    force-retired with its best-effort state (converged=False, never
    cached) even while transient chunk faults are being retried — a
    non-converging query plus a flaky chunk must not wedge the loop."""
    with inject(_plan(FaultSpec(site="service.chunk", kind="transient",
                                every=3, start=1))):
        svc = QueryService(svc_graph, slots=2, chunk_steps=1,
                           max_chunks_per_query=1)
        rid = svc.submit("ppr", source=0, tol=0.0)   # tol=0 never settles
        svc.run_until_complete()                      # must terminate
    assert svc.stats()["force_retired"] == 1
    rec = svc.record(rid)
    assert rec.done and not rec.converged
    st = svc.status(rid)
    assert st["status"] == "done" and st["converged"] is False
    assert svc.poll(rid) is not None                  # best-effort state
    assert svc.chunk_retries >= 1                     # faults did fire
    # force-retired states depend on scheduler timing: never cached
    rid2 = svc.submit("ppr", source=0, tol=0.0)
    assert not svc.record(rid2).cached


_CHAOS_SITES = ("service.chunk", "service.cache.get",
                "service.cache.put", "pallas.pull", "pallas.push",
                "engine.step", "tune.cache.load", "tune.cache.write")


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**16),
       n_sites=st.integers(1, len(_CHAOS_SITES)),
       order_seed=st.integers(0, 2**16))
def test_scheduler_chaos_schedule(seed, n_sites, order_seed):
    """Hypothesis chaos: random transient fault sites × random arrival
    orders. Invariant: the service always drains, and every query is
    served with the exact fault-free answer (transient faults are
    recoverable by construction)."""
    src = np.array([0, 1, 2, 3, 4, 0, 2])
    dst = np.array([1, 2, 3, 4, 5, 2, 5])
    g = build_graph(src, dst, 6)
    refs = {s: np.asarray(api.solve(g, "bfs", root=s).state["dist"])
            for s in range(6)}
    rng = np.random.default_rng(seed)
    sites = list(rng.choice(_CHAOS_SITES, size=n_sites, replace=False))
    specs = tuple(FaultSpec(site=s, kind="transient",
                            every=int(rng.integers(2, 5)), start=1,
                            error=("OSError" if ".cache." in s
                                   else "FaultInjected"))
                  for s in sites)
    arrival = np.random.default_rng(order_seed).permutation(6)
    resilience.deactivate()
    clear_resilience_stats()
    try:
        with inject(FaultPlan(name="hyp", seed=seed, specs=specs)):
            svc = QueryService(g, slots=3)
            rids = {int(s): svc.submit("bfs", source=int(s))
                    for s in arrival}
            svc.run_until_complete()
            for s, rid in rids.items():
                got = np.asarray(svc.poll(rid)["dist"])
                assert (got == refs[s]).all(), (s, sites)
    finally:
        resilience.deactivate()
        clear_resilience_stats()


# ---------------------------------------------------------------------------
# observability: counters, events, report, zero-overhead
# ---------------------------------------------------------------------------

def test_collect_resilience_counters_events_and_report(chaos_graph):
    from repro.obs import Telemetry, collect_resilience, render_report
    tel = Telemetry()
    plan = _plan(FaultSpec(site="engine.step", kind="transient",
                           every=4, start=2))
    with inject(plan):
        api.solve(chaos_graph, "bfs", root=0, checkpoint_every=1,
                  telemetry=tel)
    counters = tel.counters.as_dict()
    assert counters["resilience.injected.engine.step"] >= 1
    assert counters["resilience.resume.engine.step"] >= 1
    evs = list(tel.events)
    names = {e.get("name") for e in evs if e.get("kind") == "event"}
    assert "resilience.fault" in names
    assert any(n.startswith("resilience.resume") for n in names)
    md = render_report(evs)
    assert "## Resilience" in md and "resilience.fault" in md
    # drained: a second collect adds no new events
    assert drain_events() == []
    collect_resilience(tel)


def test_no_plan_no_overhead_no_counters(chaos_graph):
    """The zero-overhead claim: with no plan and telemetry=None, a solve
    leaves the resilience bookkeeping untouched (fault_point is a
    global read + None check; nothing counts, nothing queues)."""
    clear_resilience_stats()
    r = api.solve(chaos_graph, "bfs", root=0)
    assert r.converged
    assert resilience_stats() == {}
    assert drain_events() == []


def test_solve_results_identical_with_and_without_guards(chaos_graph):
    """check_finite/checkpoint_every change the execution loop, not the
    math: guarded runs reproduce the plain run bit-for-bit."""
    plain = api.solve(chaos_graph, "bfs", root=2)
    guarded = api.solve(chaos_graph, "bfs", root=2,
                        check_finite="nan", checkpoint_every=2)
    assert (np.asarray(plain.state["dist"])
            == np.asarray(guarded.state["dist"])).all()
    assert int(plain.steps) == int(guarded.steps)
    # phase programs accept the guard too, checked at run end
    res = api.solve(chaos_graph, "sssp_delta", source=0, delta=2.0,
                    check_finite="nan")
    assert res.converged
