"""End-to-end behaviour tests for the paper's system."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PushPullEngine, VertexProgram, GenericSwitch, Fixed, Direction
from repro.core.algorithms import bfs, pagerank
from repro.graphs import kronecker, erdos_renyi


def test_engine_runs_reachability():
    """PushPullEngine fixed-point: or/max reachability from vertex 0."""
    g = erdos_renyi(100, 4.0, seed=8)

    def update(state, msgs, step):
        new = jnp.maximum(state, msgs)
        frontier = new > state
        return new, frontier, ~jnp.any(frontier)

    prog = VertexProgram(combine="max", update_fn=update)
    eng = PushPullEngine(program=prog, policy=GenericSwitch(), max_steps=50)
    init = jnp.zeros((g.n,), jnp.int32).at[0].set(1)
    frontier0 = jnp.zeros((g.n,), bool).at[0].set(True)
    res = eng.run(g, init, frontier0)
    reach = np.asarray(res.state) > 0
    want = np.asarray(bfs(g, 0, Fixed(Direction.PUSH)).dist) < 2**31 - 1
    assert np.array_equal(reach, want)


def test_direction_optimized_bfs_examines_fewer_edges():
    """Beamer's claim (paper §1: ~2.4x on power-law graphs): the switch
    does less edge work than either fixed direction."""
    g = kronecker(9, edge_factor=8, seed=1)
    push = bfs(g, 0, Fixed(Direction.PUSH))
    pull = bfs(g, 0, Fixed(Direction.PULL))
    auto = bfs(g, 0, GenericSwitch())
    assert np.array_equal(np.asarray(auto.dist), np.asarray(push.dist))
    r_pull = int(pull.cost.reads)
    r_auto = int(auto.cost.reads)
    assert r_auto < r_pull, "switching must beat pure pull on reads"
    # and it avoids most of push's combining writes in the dense phase
    assert int(auto.cost.atomics) < int(push.cost.atomics)


def test_pagerank_converges_same_fixpoint_both_directions():
    g = kronecker(8, edge_factor=6, seed=3)
    a = pagerank(g, 60, direction="push").ranks
    b = pagerank(g, 60, direction="pull").ranks
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # mass bounded by 1 (dangling vertices leak mass — Algorithm 1 does
    # not redistribute it, matching the paper's formulation)
    assert 0.0 < float(jnp.sum(a)) <= 1.0 + 1e-4
    assert bool(jnp.all(a > 0))
