"""Cross-backend differential harness (PR 8 gate).

Property-based agreement of the relax primitive and of full ``solve``
runs across every shared-memory backend, on the adversarial graph
families from ``graph_strategies``. The three backends implement the
same abstract k-relaxation over different memory layouts (dense segment
ops, jnp ELL, Pallas kernels + frontier dispatch), so any divergence is
a bug in exactly one of them — this harness is what gates new kernels
like ``ell_pull_frontier_pallas`` landing on the hot path.

Agreement policy: integer results (BFS levels, WCC labels) must match
bit for bit; floating-point results agree to 1e-5 (the dense segment
reduce and the ELL row reduce sum in different orders, so bit equality
is only guaranteed within one layout — the frontier-vs-full-scan split
*inside* PallasBackend is covered bit-exactly in
``test_pull_frontier.py``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from graph_strategies import build_case, combines, graph_cases, seeds

from repro import api
from repro.core.backend import DenseBackend, EllBackend, PallasBackend
from repro.core.cost_model import zero_cost

BACKENDS = {
    "dense": DenseBackend(),
    "ell": EllBackend(),
    "pallas": PallasBackend(autotune=False),
}
POLICIES = ("push", "pull", "auto")


@pytest.fixture(scope="module", autouse=True)
def _drop_jit_caches():
    """This module compiles hundreds of distinct engines (graph family ×
    backend × policy × algorithm); free the executables afterwards so
    the process-wide compile budget doesn't starve later modules."""
    yield
    jax.clear_caches()


def _assert_agree(name, ref, got, atol=1e-5):
    ref, got = np.asarray(ref), np.asarray(got)
    assert ref.shape == got.shape, name
    if ref.dtype.kind in "iub":
        assert np.array_equal(ref, got), name
    else:
        assert np.allclose(ref, got, rtol=1e-5, atol=atol,
                           equal_nan=True), name


def _vectors(g, seed):
    rng = np.random.RandomState(7 + seed)
    values = jnp.asarray(rng.rand(g.n).astype(np.float32) + 0.5)
    frontier = jnp.asarray(rng.rand(g.n) < 0.4)
    touched = jnp.asarray(rng.rand(g.n) < 0.3)
    return values, frontier, touched


@given(case=graph_cases(), seed=seeds(), combine=combines())
def test_relax_agrees_across_backends(case, seed, combine):
    """backend.push / backend.pull produce the same combined messages
    on every backend, for every combine, on every adversarial family —
    including a masked touched set and touched=None (all rows)."""
    g = build_case(case, seed)
    values, frontier, touched = _vectors(g, seed)
    for direction in ("push", "pull"):
        outs = {}
        for bname, backend in BACKENDS.items():
            if direction == "push":
                out, _ = backend.push(g, values, frontier, combine,
                                      None, zero_cost())
            else:
                out, _ = backend.pull(g, values, touched, combine,
                                      None, zero_cost())
            outs[bname] = out
        for bname in ("ell", "pallas"):
            _assert_agree(f"{case}/{direction}/{combine}/{bname}",
                          outs["dense"], outs[bname])
    # pull over the full destination set (touched=None)
    full = {b: k.pull(g, values, None, combine, None, zero_cost())[0]
            for b, k in BACKENDS.items()}
    for bname in ("ell", "pallas"):
        _assert_agree(f"{case}/pull-all/{combine}/{bname}",
                      full["dense"], full[bname])


@given(case=graph_cases(), seed=seeds(),
       algorithm=st.sampled_from(["bfs", "wcc", "pagerank"]))
def test_solve_agrees_across_backends(case, seed, algorithm):
    """Full solve runs agree across {dense, ell, pallas} × {push, pull,
    auto} on the adversarial families — the end-to-end differential:
    program logic + direction policy + backend dispatch."""
    g = build_case(case, seed)
    kw = {"root": seed % g.n} if algorithm == "bfs" else (
        {"iters": 5} if algorithm == "pagerank" else {})
    for policy in POLICIES:
        states = {}
        for bname, backend in BACKENDS.items():
            r = api.solve(g, algorithm, policy=policy, backend=backend,
                          **kw)
            states[bname] = jax.tree_util.tree_leaves(r.state)
        for bname in ("ell", "pallas"):
            assert len(states[bname]) == len(states["dense"])
            for ref, got in zip(states["dense"], states[bname]):
                _assert_agree(f"{case}/{algorithm}/{policy}/{bname}",
                              ref, got)


def test_solve_policies_agree_within_backend():
    """Directions are interchangeable implementations: push, pull and
    auto must reach identical fixed points on the same backend."""
    g = build_case("ragged", 0)
    for bname, backend in BACKENDS.items():
        ref = None
        for policy in POLICIES:
            r = api.solve(g, "bfs", root=0, policy=policy,
                          backend=backend)
            dist = np.asarray(r.state["dist"])
            if ref is None:
                ref = dist
            assert np.array_equal(ref, dist), (bname, policy)
