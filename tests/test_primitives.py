"""Push/pull primitive properties — the paper's core equivalences.

Central property (paper §3.8): with every vertex active, push and pull
k-relaxations compute the SAME combined updates; they differ only in Cost
structure (push: combining writes; pull: reads)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import (Cost, spmv_pull, spmspv_push, PLUS_TIMES, MIN_PLUS,
                        OR_AND, push_relax, pull_relax, pull_relax_ell,
                        combine_identity)
from repro.graphs import erdos_renyi


def _rand_graph(seed, n=64, deg=3.0):
    return erdos_renyi(n, deg, seed=seed, weighted=True)


@given(seed=st.integers(0, 50), combine=st.sampled_from(["sum", "min", "max"]))
def test_push_equals_pull_full_frontier(seed, combine):
    g = _rand_graph(seed)
    x = jax.random.normal(jax.random.PRNGKey(seed), (g.n,))
    allv = jnp.ones((g.n,), bool)
    out_push, c_push = push_relax(g, x, allv, combine=combine)
    out_pull, c_pull = pull_relax(g, x, combine=combine)
    ident = combine_identity(combine, out_pull.dtype)
    np.testing.assert_allclose(np.asarray(out_push), np.asarray(out_pull),
                               rtol=1e-5, atol=1e-5)
    # Cost structure: pull never combines concurrently; push always does
    assert int(c_pull.atomics) == 0 and int(c_pull.locks) == 0
    assert int(c_push.locks) == g.m  # float payload -> lock-equivalents
    assert int(c_pull.reads) == g.m


@given(seed=st.integers(0, 30))
def test_pull_ell_equals_pull_coo(seed):
    g = _rand_graph(seed)
    x = jax.random.normal(jax.random.PRNGKey(seed), (g.n,))
    a, _ = pull_relax(g, x, combine="sum")
    b, _ = pull_relax_ell(g, x, combine="sum")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@given(seed=st.integers(0, 30))
def test_push_frontier_masks_sources(seed):
    g = _rand_graph(seed)
    x = jnp.ones((g.n,))
    frontier = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.4, (g.n,))
    out, cost = push_relax(g, x, frontier, combine="sum")
    # reference: dense masked segment count (default msg = x[src], no w)
    src = np.asarray(g.push_src)
    dst = np.asarray(g.push_dst)
    f = np.asarray(frontier)
    want = np.zeros(g.n, np.float32)
    np.add.at(want, dst, np.where(f[src], 1.0, 0.0))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-5)
    # cost charged proportional to frontier out-edges only
    assert int(cost.reads) == int(f[src].sum())


@given(seed=st.integers(0, 30),
       sr_name=st.sampled_from(["plus_times", "min_plus", "or_and"]))
def test_semiring_push_pull_equivalence(seed, sr_name):
    sr = {"plus_times": PLUS_TIMES, "min_plus": MIN_PLUS,
          "or_and": OR_AND}[sr_name]
    g = _rand_graph(seed)
    key = jax.random.PRNGKey(seed + 99)
    x = jax.random.uniform(key, (g.n,), minval=0.1, maxval=2.0)
    nz = jnp.ones((g.n,), bool)
    y_pull, _ = spmv_pull(g, x, sr)
    y_push, _ = spmspv_push(g, x, nz, sr)
    np.testing.assert_allclose(np.asarray(y_pull), np.asarray(y_push),
                               rtol=1e-4, atol=1e-4)


def test_spmv_matches_dense_matmul():
    g = _rand_graph(3, n=40)
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n,))
    A = np.zeros((g.n, g.n), np.float32)
    A[np.asarray(g.coo_dst), np.asarray(g.coo_src)] = np.asarray(g.coo_w)
    y, _ = spmv_pull(g, x, PLUS_TIMES)
    np.testing.assert_allclose(np.asarray(y), A @ np.asarray(x), rtol=1e-4,
                               atol=1e-4)


def test_spmspv_exploits_sparsity_in_cost():
    g = _rand_graph(5, n=80)
    x = jnp.ones((g.n,))
    nz = jnp.zeros((g.n,), bool).at[:8].set(True)
    _, c_sparse = spmspv_push(g, x, nz)
    _, c_dense = spmspv_push(g, x, jnp.ones((g.n,), bool))
    assert int(c_sparse.reads) < int(c_dense.reads)
    assert int(c_dense.reads) == g.m


def test_cost_pytree_arithmetic():
    c = Cost().charge(reads=5).charge(writes=3)
    c2 = c + c
    assert int(c2.reads) == 10 and int(c2.writes) == 6
    c3 = c.charge_combining_writes(7, float_data=True)
    assert int(c3.locks) == 7 and int(c3.atomics) == 0
    c4 = c.charge_combining_writes(7, float_data=False)
    assert int(c4.atomics) == 7 and int(c4.locks) == 0
