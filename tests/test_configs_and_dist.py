"""Assigned-arch smoke tests (deliverable f) + cell/dist invariants."""

import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ALL_ARCHS, ARCH_FAMILY, all_cells, full_config,
                           smoke_config)
from repro.graphs import erdos_renyi
from repro.models import gnn as gnn_mod
from repro.models.recsys import xdeepfm_apply, xdeepfm_init
from repro.models.transformer import init_params, lm_loss
from repro.train import OptConfig, apply_updates, init_opt

KEY = jax.random.PRNGKey(0)


def test_cell_enumeration_is_40():
    cells = all_cells()
    assert len(cells) == 40
    per_family = {}
    for a, s in cells:
        per_family.setdefault(ARCH_FAMILY[a], set()).add(s)
    assert len(per_family["lm"]) == 4
    assert len(per_family["gnn"]) == 4
    assert len(per_family["recsys"]) == 4


def test_full_configs_match_assignment():
    c = full_config("llama3.2-1b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (16, 2048, 32, 8, 8192, 128256)
    c = full_config("qwen1.5-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (64, 5120, 40, 40, 27392, 152064)
    assert c.qkv_bias
    c = full_config("gemma2-9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (42, 3584, 16, 8, 14336, 256000)
    assert c.local_window == 4096 and c.attn_softcap and c.final_softcap
    for arch, L, V in (("moonshot-v1-16b-a3b", 48, 163840),
                       ("deepseek-moe-16b", 28, 102400)):
        c = full_config(arch)
        assert (c.n_layers, c.d_model, c.vocab) == (L, 2048, V)
        assert c.moe.n_experts == 64 and c.moe.top_k == 6
        assert c.moe.n_shared == 2
    g = full_config("graphcast")
    assert g.n_layers == 16 and g.d_hidden == 512 and g.n_vars == 227
    x = full_config("xdeepfm")
    assert x.n_fields == 39 and x.cin_layers == (200, 200, 200)
    assert x.mlp_dims == (400, 400) and x.embed_dim == 10


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if ARCH_FAMILY[a] == "lm"])
def test_smoke_lm_train_step(arch):
    """Reduced same-family config: one forward + optimizer step on CPU."""
    cfg = smoke_config(arch)
    p = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    oc = OptConfig(lr=1e-3, total_steps=10)
    opt = init_opt(p, oc)
    loss, grads = jax.value_and_grad(
        lambda pp: lm_loss(pp, cfg, toks, toks))(p)
    p2, opt2 = apply_updates(p, grads, opt, oc)
    assert bool(jnp.isfinite(loss))
    assert loss.shape == ()
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        assert a.shape == b.shape
        assert bool(jnp.all(jnp.isfinite(b.astype(jnp.float32))))


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if ARCH_FAMILY[a] == "gnn"])
def test_smoke_gnn_train_step(arch):
    cfg = smoke_config(arch)
    g = erdos_renyi(60, 4.0, seed=3, weighted=True)
    init_fn = {"egnn": gnn_mod.egnn_init, "gin-tu": gnn_mod.gin_init,
               "graphsage-reddit": gnn_mod.sage_init,
               "graphcast": gnn_mod.graphcast_init}[arch]
    p = init_fn(KEY, cfg)

    if arch == "graphcast":
        nv = jax.random.normal(KEY, (g.n, cfg.n_vars))
        fn = lambda pp: jnp.mean(  # noqa: E731
            (gnn_mod.graphcast_apply(pp, cfg, g, nv) - nv) ** 2)
    elif arch == "egnn":
        h = jax.random.normal(KEY, (g.n, cfg.d_in))
        x = jax.random.normal(jax.random.fold_in(KEY, 1), (g.n, 3))
        fn = lambda pp: jnp.mean(  # noqa: E731
            gnn_mod.egnn_apply(pp, cfg, g, h, x)[0] ** 2)
    else:
        h = jax.random.normal(KEY, (g.n, cfg.d_in))
        apply_fn = (gnn_mod.gin_apply if arch == "gin-tu"
                    else gnn_mod.sage_apply)
        fn = lambda pp: jnp.mean(apply_fn(pp, cfg, g, h) ** 2)  # noqa

    loss, grads = jax.value_and_grad(fn)(p)
    oc = OptConfig(lr=1e-3, total_steps=10)
    p2, _ = apply_updates(p, grads, init_opt(p, oc), oc)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
               for x in jax.tree.leaves(p2))


def test_smoke_recsys_train_step():
    cfg = smoke_config("xdeepfm")
    p = xdeepfm_init(KEY, cfg)
    ids = jax.random.randint(KEY, (32, cfg.n_fields), 0,
                             cfg.vocab_per_field)
    y = jax.random.bernoulli(KEY, 0.3, (32,)).astype(jnp.float32)
    from repro.train.losses import bce_with_logits
    loss, grads = jax.value_and_grad(
        lambda pp: bce_with_logits(xdeepfm_apply(pp, cfg, ids), y))(p)
    assert bool(jnp.isfinite(loss))
    oc = OptConfig(lr=1e-3, total_steps=10)
    p2, _ = apply_updates(p, grads, init_opt(p, oc), oc)
    assert p2["tables"].shape == p["tables"].shape


DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.graphs import erdos_renyi, partition_1d, pa_split
from repro.graphs.partition import _pack
from repro.dist.collectives import push_exchange, pull_exchange
from repro.dist.overlap import ring_allreduce_psum
from jax.sharding import PartitionSpec as P
import functools

mesh = jax.make_mesh((8, 1), ("data", "model"))
g = erdos_renyi(128, 4.0, seed=5, weighted=True)
part = partition_1d(g.n, 8)
local, remote, stats = pa_split(g, part)
vals = jnp.arange(part.n_padded, dtype=jnp.float32) % 7 + 1.0

# reference: full masked segment sum over remote edges
import numpy as onp
src = onp.asarray(g.push_src); dst = onp.asarray(g.push_dst); w = onp.asarray(g.push_w)
own_s = part.owner_np(src); own_d = part.owner_np(dst)
cut = own_s != own_d
want = onp.zeros(part.n_padded, onp.float32)
onp.add.at(want, dst[cut], onp.asarray(vals)[src[cut]] * w[cut])

out, nbytes = push_exchange(mesh, part, remote, vals)
ok_push = bool(onp.allclose(onp.asarray(out), want, atol=1e-4))
print("push_exchange ok:", ok_push, "bytes:", nbytes)

# pull: group the same cut edges by destination shard
rows = [[] for _ in range(8)]; cols = [[] for _ in range(8)]; ws = [[] for _ in range(8)]
for s, d, ww in zip(src[cut], dst[cut], w[cut]):
    p = int(part.owner_np(onp.array([d]))[0])
    rows[p].append(s); cols[p].append(d); ws[p].append(ww)
rows = [onp.array(r, onp.int64) for r in rows]
cols = [onp.array(c, onp.int64) for c in cols]
ws = [onp.array(x, onp.float32) for x in ws]
edges_by_dst = _pack(rows, cols, ws, 8, g.n, 128)
out2, nbytes2 = pull_exchange(mesh, part, edges_by_dst, vals)
ok_pull = bool(onp.allclose(onp.asarray(out2), want, atol=1e-4))
print("pull_exchange ok:", ok_pull, "bytes:", nbytes2)

# ring allreduce equals psum (each device holds a distinct 8-vector)
x = jnp.arange(64, dtype=jnp.float32)
@functools.partial(jax.shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
def ring(xb):
    return ring_allreduce_psum(xb.reshape(-1), "data", 8).reshape(xb.shape)
@functools.partial(jax.shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
def psum_ref(xb):
    return jax.lax.psum(xb, "data")
a = ring(x); b = psum_ref(x)
print("ring==psum:", bool(onp.allclose(onp.asarray(a), onp.asarray(b))))
assert ok_push and ok_pull

# MoE EP paths vs the single-device reference (psum + a2a schedules)
import dataclasses
from repro.models.moe import MoEConfig, moe_init, moe_apply, moe_apply_ep
from repro.dist.sharding import set_activation_mesh
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
cfg = MoEConfig(d_model=16, d_ff_expert=8, n_experts=8, top_k=2,
                n_shared=1, capacity_factor=8.0, dispatch="pull")
params = moe_init(jax.random.PRNGKey(0), cfg)
xx = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
ref = moe_apply(params, cfg, xx)
set_activation_mesh(mesh2)
got_psum = moe_apply_ep(params, cfg, xx)
got_a2a = moe_apply_ep(params, dataclasses.replace(cfg, ep_mode="a2a"), xx)
set_activation_mesh(None)
# capacity_factor is generous so neither schedule drops tokens
print("moe psum-ep ok:", bool(onp.allclose(onp.asarray(got_psum),
                                           onp.asarray(ref), atol=1e-4)))
print("moe a2a-ep ok:", bool(onp.allclose(onp.asarray(got_a2a),
                                          onp.asarray(ref), atol=1e-4)))
"""


@pytest.mark.dist
@pytest.mark.subprocess
def test_dist_exchanges_multidevice():
    """shard_map exchanges need >1 device: run in a subprocess with 8
    fake host devices."""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", DIST_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env=env, cwd="/root/repo")
    assert "push_exchange ok: True" in r.stdout, r.stdout + r.stderr
    assert "pull_exchange ok: True" in r.stdout, r.stdout + r.stderr
    assert "ring==psum: True" in r.stdout, r.stdout + r.stderr
    assert "moe psum-ep ok: True" in r.stdout, r.stdout + r.stderr
    assert "moe a2a-ep ok: True" in r.stdout, r.stdout + r.stderr
