"""repro.api — the paper's thesis as an interface: every registered
algorithm produces identical states under every direction policy, and the
dense/ELL backends are interchangeable."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import (DenseBackend, Direction, DistributedBackend,
                        EllBackend, Fixed, GenericSwitch, GreedySwitch)

KW = {
    "bfs": {"root": 3},
    "pagerank": {"iters": 25},
    "ppr": {"source": 3, "tol": 1e-7},
    "wcc": {},
    "pr_delta": {"tol": 1e-7},
    "sssp_delta": {"source": 3, "delta": 2.5},
    "betweenness": {"num_sources": 4},
    "coloring": {"num_parts": 8},
    "mst_boruvka": {},
    "triangle_count": {"edge_block": 512},
}

# betweenness sums float32 σ-ratios in edge order, which differs between
# the push-major and pull-major layouts — everything else is exact or
# fixed-point-tight
ATOL = {"betweenness": 1e-3}

POLICIES = [Fixed(Direction.PUSH), Fixed(Direction.PULL), GenericSwitch()]

BACKENDS = {"dense": DenseBackend, "ell": EllBackend}


def _states_equal(a, b, atol):
    fa, fb = jnp.asarray(a), jnp.asarray(b)
    if jnp.issubdtype(fa.dtype, jnp.floating):
        np.testing.assert_allclose(np.asarray(fa), np.asarray(fb),
                                   atol=atol)
    else:
        assert np.array_equal(np.asarray(fa), np.asarray(fb))


def _assert_same_states(ref, got, atol):
    for leaf_r, leaf_g in zip(jax.tree_util.tree_leaves(ref),
                              jax.tree_util.tree_leaves(got)):
        _states_equal(leaf_r, leaf_g, atol=atol)


def test_registry_covers_all_ten():
    assert api.algorithms() == sorted([
        "bfs", "pagerank", "ppr", "wcc", "pr_delta", "sssp_delta",
        "betweenness", "coloring", "mst_boruvka", "triangle_count"])


@pytest.mark.parametrize("name", sorted(KW))
def test_push_pull_switch_equivalence(name, power_graph):
    """solve(..., Fixed(PUSH)) ≡ Fixed(PULL) ≡ GenericSwitch for every
    registered algorithm — the §3.8 equivalence, end to end."""
    ref = api.solve(power_graph, name, policy=POLICIES[0], **KW[name])
    for policy in POLICIES[1:]:
        got = api.solve(power_graph, name, policy=policy, **KW[name])
        _assert_same_states(ref.state, got.state, ATOL.get(name, 1e-6))


@pytest.mark.parametrize("name", sorted(KW))
def test_backend_matrix(name, small_graph):
    """Every algorithm runs under every (policy × backend) cell it
    declares supported and returns the dense-reference states."""
    spec = api.get_spec(name)
    ref = api.solve(small_graph, name, policy=POLICIES[0], **KW[name])
    for bname in spec.backends:
        if bname not in BACKENDS:
            continue
        backend = BACKENDS[bname]()
        for policy in POLICIES:
            got = api.solve(small_graph, name, policy=policy,
                            backend=backend, **KW[name])
            _assert_same_states(ref.state, got.state,
                                ATOL.get(name, 1e-6))


@pytest.mark.parametrize("name", sorted(KW))
def test_runresult_surface(name, small_graph):
    r = api.solve(small_graph, name, **KW[name])
    assert int(r.steps) >= 1
    assert int(r.epochs) >= 1
    assert 0 <= int(r.push_steps) <= int(r.steps)
    assert int(r.cost.iterations) == int(r.steps)
    if name != "pagerank":          # fixed-iteration solves never converge
        assert bool(r.converged)


def test_unsupported_backend_combination_raises(small_graph):
    """Specs with no distributed execution path surface a ValueError
    naming the (policy, backend) combination, not a raw trace error."""
    db = DistributedBackend.prepare(small_graph)
    for name in ("ppr", "sssp_delta", "betweenness", "coloring",
                 "mst_boruvka", "triangle_count"):
        with pytest.raises(ValueError, match=f"{name}.*DistributedBackend"):
            api.solve(small_graph, name, backend=db, **KW[name])


# -- engine ≡ legacy wrappers --------------------------------------------
def test_legacy_wrappers_match_engine(small_graph):
    from repro.core.algorithms import (betweenness_centrality,
                                       boman_coloring, boruvka_mst,
                                       sssp_delta, triangle_count)
    g = small_graph
    r = api.solve(g, "sssp_delta", policy=Fixed(Direction.PUSH),
                  source=3, delta=2.5)
    w = sssp_delta(g, 3, delta=2.5, direction="push")
    np.testing.assert_array_equal(np.asarray(w.dist),
                                  np.asarray(r.state["dist"]))
    assert int(w.epochs) == int(r.epochs)

    r = api.solve(g, "betweenness", policy=Fixed(Direction.PULL),
                  num_sources=4)
    w = betweenness_centrality(g, "pull", num_sources=4)
    np.testing.assert_allclose(np.asarray(w.bc),
                               np.asarray(r.state["bc"]), atol=1e-6)

    r = api.solve(g, "coloring", policy=Fixed(Direction.PUSH),
                  num_parts=8)
    w = boman_coloring(g, num_parts=8, direction="push")
    np.testing.assert_array_equal(np.asarray(w.colors),
                                  np.asarray(r.state["colors"]))

    r = api.solve(g, "mst_boruvka")
    w = boruvka_mst(g, "pull")
    assert float(w.weight) == pytest.approx(float(r.state["weight"]))
    assert int(w.components) == int(r.state["components"])

    r = api.solve(g, "triangle_count")
    w = triangle_count(g, "pull")
    assert int(w.total) == int(r.state["total"])
    np.testing.assert_array_equal(np.asarray(w.per_vertex),
                                  np.asarray(r.state["per_vertex"]))


def test_coloring_validity_across_cells(small_graph):
    """Coloring equivalence is *validity* (the paper's criterion): every
    (policy × backend) cell yields a proper coloring."""
    from repro.core.algorithms import validate_coloring
    for policy in POLICIES:
        for backend in (DenseBackend(), EllBackend()):
            r = api.solve(small_graph, "coloring", policy=policy,
                          backend=backend, num_parts=8)
            assert bool(validate_coloring(small_graph,
                                          r.state["colors"]))
            assert np.all(np.asarray(r.state["colors"]) > 0)


def test_backend_equivalence_dense_ell(small_graph):
    """DenseBackend ≡ EllBackend for PageRank (same fixpoint, the ELL
    layout only restructures the gather)."""
    dense = api.solve(small_graph, "pagerank", iters=25,
                      backend=DenseBackend())
    ell = api.solve(small_graph, "pagerank", iters=25,
                    backend=EllBackend())
    np.testing.assert_allclose(np.asarray(dense.state),
                               np.asarray(ell.state), atol=1e-6)
    # ELL pull still charges pull-structured cost: zero combining writes
    assert int(ell.cost.atomics) == 0 and int(ell.cost.locks) == 0


def test_backend_equivalence_distributed_single_device(small_graph):
    """DistributedBackend (1-device mesh): the PA local/remote split plus
    exchange reproduces the dense states for every direction."""
    db = DistributedBackend.prepare(small_graph)
    for name in ("pagerank", "bfs"):
        for policy in POLICIES:
            a = api.solve(small_graph, name, policy=policy, **KW[name])
            b = api.solve(small_graph, name, policy=policy, backend=db,
                          **KW[name])
            _assert_same_states(a.state, b.state, 1e-6)


def test_unknown_algorithm_raises(small_graph):
    with pytest.raises(KeyError, match="registered"):
        api.solve(small_graph, "nope")


def test_fixed_auto_rejected():
    with pytest.raises(ValueError, match="GenericSwitch"):
        Fixed(Direction.AUTO)


def test_greedy_switch_tail_handoff(small_graph):
    """The GrS hook: a program with a tail_fn exits the parallel loop
    once the active set is tiny and the tail finishes the job."""
    from repro.core.algorithms.wcc import wcc_init, wcc_program
    from repro.core.engine import PushPullEngine, VertexProgram
    import dataclasses

    calls = {}

    def tail(g, state, frontier, cost):
        calls["hit"] = True
        return state, cost.charge(iterations=1)

    prog, _ = wcc_program(small_graph)
    prog = dataclasses.replace(prog, tail_fn=tail)
    # tail_frac=1.0: hand off as soon as the frontier is below n vertices
    eng = PushPullEngine(program=prog, policy=GreedySwitch(tail_frac=1.0),
                         max_steps=100)
    state0, frontier0 = wcc_init(small_graph)
    res = eng.run(small_graph, state0, frontier0)
    assert calls.get("hit")          # tail traced into the cond branch
    assert bool(res.converged)
    assert int(res.steps) < 100
    # the tail charges one extra iteration at *runtime*: the engine
    # charges 1/step, so steps+1 proves the handoff branch actually ran
    assert int(res.cost.iterations) == int(res.steps) + 1


def test_engine_carries_real_unvisited_mask(power_graph):
    """Regression for the GenericSwitch growing-phase bug: the engine must
    feed the policy a shrinking unvisited-edge count, so a BFS-style run
    switches from push to pull as the frontier densifies (and back)."""
    r = api.solve(power_graph, "bfs", root=0, policy=GenericSwitch())
    assert 0 < int(r.push_steps) < int(r.steps)


def test_phase_engine_epoch_surface(small_graph):
    """Phase programs expose their outer structure: Δ-stepping's epochs
    count buckets, BC's count sources, Borůvka's count rounds."""
    r = api.solve(small_graph, "sssp_delta", source=0, delta=2.5)
    assert int(r.epochs) > 1 and int(r.steps) >= int(r.epochs) - 1
    r = api.solve(small_graph, "betweenness", num_sources=3)
    assert int(r.epochs) == 3
    r = api.solve(small_graph, "mst_boruvka")
    assert 1 <= int(r.epochs) <= 64
    # flat programs report a single epoch
    r = api.solve(small_graph, "pagerank", iters=5)
    assert int(r.epochs) == 1


DIST_SOLVE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro import api
from repro.core import DistributedBackend, Fixed, Direction
from repro.graphs import erdos_renyi, kronecker
mesh = jax.make_mesh((8, 1), ("data", "model"))
g = kronecker(7, edge_factor=5, seed=4, weighted=True)
db = DistributedBackend.prepare(g, mesh=mesh)
for policy in (Fixed(Direction.PUSH), Fixed(Direction.PULL)):
    a = api.solve(g, "pagerank", iters=15, policy=policy)
    b = api.solve(g, "pagerank", iters=15, policy=policy, backend=db)
    ok = bool(np.allclose(np.asarray(a.state), np.asarray(b.state),
                          atol=1e-6))
    print(f"{policy.name} dist ok: {ok} bytes:",
          int(b.cost.collective_bytes))
# n=100 not divisible by 8: exercises the n_padded > n pad/slice path
g2 = erdos_renyi(100, 3.0, seed=2, weighted=True)
db2 = DistributedBackend.prepare(g2, mesh=mesh)
a = api.solve(g2, "bfs", root=1)
b = api.solve(g2, "bfs", root=1, backend=db2)
print("padded dist ok:", bool(np.array_equal(
    np.asarray(a.state["dist"]), np.asarray(b.state["dist"]))))
"""


@pytest.mark.dist
@pytest.mark.subprocess
def test_distributed_backend_multidevice():
    """solve() with DistributedBackend over 8 fake host devices matches
    the dense backend — the exchanges really cross shards here."""
    import os
    from pathlib import Path
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", DIST_SOLVE],
                       capture_output=True, text=True, timeout=600,
                       env=env, cwd=str(root))
    assert "push dist ok: True" in r.stdout, r.stdout + r.stderr
    assert "pull dist ok: True" in r.stdout, r.stdout + r.stderr
    assert "padded dist ok: True" in r.stdout, r.stdout + r.stderr


DIST_PARTS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from jax.sharding import Mesh
from repro import api
from repro.core import DistributedBackend, Fixed, Direction
from repro.graphs import kronecker
g = kronecker(7, edge_factor=5, seed=4, weighted=True)
KW = {"bfs": {"root": 1}, "pagerank": {"iters": 12}}
for num_parts in (1, 2, 4):
    mesh = Mesh(np.array(jax.devices()[:num_parts]).reshape(num_parts, 1),
                ("data", "model"))
    db = DistributedBackend.prepare(g, mesh=mesh, num_parts=num_parts)
    for name in ("bfs", "pagerank"):
        for policy in (Fixed(Direction.PUSH), Fixed(Direction.PULL)):
            a = api.solve(g, name, policy=policy, **KW[name])
            b = api.solve(g, name, policy=policy, backend=db, **KW[name])
            la = jax.tree_util.tree_leaves(a.state)
            lb = jax.tree_util.tree_leaves(b.state)
            ok = all(np.allclose(np.asarray(x), np.asarray(y), atol=1e-6)
                     for x, y in zip(la, lb))
            print(f"parts={num_parts} {name} {policy.name} ok: {ok}")
"""


@pytest.mark.dist
@pytest.mark.subprocess
def test_distributed_parity_across_num_parts():
    """DistributedBackend push/pull states match DenseBackend for BFS
    and PageRank at num_parts 1, 2, and 4 — the PA split and both
    exchange directions are partition-count-invariant."""
    import os
    from pathlib import Path
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", DIST_PARTS],
                       capture_output=True, text=True, timeout=600,
                       env=env, cwd=str(root))
    for num_parts in (1, 2, 4):
        for name in ("bfs", "pagerank"):
            for pol in ("push", "pull"):
                line = f"parts={num_parts} {name} {pol} ok: True"
                assert line in r.stdout, (line, r.stdout + r.stderr)
