"""repro.shard — the sharded engine subsystem.

Covers, single-device (in-process): partition/mesh validation (explicit
padding, never truncation; num_parts bounds), the adaptive wire-byte
accounting and its predictor exactness, and the distributed-only
AutoSwitch direction flip. Multi-device behavior (1/2/4/8 shards) runs
in fresh interpreters with XLA faking 8 host devices: solve parity
against the single-device dense backend for BFS / PageRank / SSSP ×
{push, pull, auto}, the ELL/Pallas inner pull executors, batched
multi-query solves, and the over-partition rejection.
"""

from __future__ import annotations

import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.core.backend import DenseBackend
from repro.core.cost_model import (Cost, CostPredictor, CostWeights,
                                   StepStats)
from repro.core.direction import AutoSwitch
from repro.graphs.generators import erdos_renyi
from repro.graphs.partition import partition_1d
from repro.shard import ShardedBackend, build_topology, make_shard_mesh


def _run_sub(script: str) -> subprocess.CompletedProcess:
    import os
    from pathlib import Path
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900,
                          env=env, cwd=str(root))


# ---------------------------------------------------------------------
# partition / mesh validation (satellite S1)

def test_partition_rejects_nonpositive_parts():
    with pytest.raises(ValueError, match="at least one part"):
        partition_1d(10, 0)
    with pytest.raises(ValueError, match="at least one part"):
        partition_1d(10, -3)


def test_partition_rejects_more_parts_than_vertices():
    with pytest.raises(ValueError, match="exceeds the vertex count"):
        partition_1d(10, 11)
    partition_1d(10, 10)      # boundary: one vertex per part is fine


def test_partition_pads_explicitly_never_truncates():
    part = partition_1d(10, 4)
    assert part.shard_size == 3
    assert part.n_padded == 12 and part.n_padded >= part.n
    # every real vertex keeps an owner in range
    import numpy as np
    owners = part.owner_np(np.arange(10))
    assert owners.min() == 0 and owners.max() == 3


def test_mesh_validation():
    with pytest.raises(ValueError, match="at least one shard"):
        make_shard_mesh(0)
    ndev = len(jax.devices())
    with pytest.raises(ValueError, match="exceeds the"):
        make_shard_mesh(ndev + 1)


def test_prepare_validates_num_shards(small_graph):
    with pytest.raises(ValueError):
        ShardedBackend.prepare(small_graph, num_shards=0)
    with pytest.raises(ValueError, match="unknown inner"):
        ShardedBackend.prepare(small_graph, inner="csr")
    mesh = make_shard_mesh(1)
    with pytest.raises(ValueError, match="must equal the mesh"):
        ShardedBackend.prepare(small_graph, mesh=mesh, num_shards=2)


# ---------------------------------------------------------------------
# single-shard semantics + accounting (runs on the 1-device test process)

def test_single_shard_matches_dense(small_graph):
    g = small_graph
    sb = ShardedBackend.prepare(g, num_shards=1)
    for algo, kw, key in (("bfs", {"root": 0}, "dist"),
                          ("pagerank", {"iters": 15}, None)):
        ref = api.solve(g, algo, **kw)
        got = api.solve(g, algo, backend=sb, **kw)
        a = ref.state if key is None else ref.state[key]
        b = got.state if key is None else got.state[key]
        assert bool(jnp.all(a == b)), algo


def test_predictor_matches_charged_bytes(small_graph):
    """predict_comm_bytes must equal what push/pull then charge —
    the exactness AutoSwitch's §6 comm pricing rests on."""
    g = small_graph
    sb = ShardedBackend.prepare(g, num_shards=1)
    vals = jnp.ones((g.n,), jnp.float32)
    frontier = jnp.arange(g.n) % 3 == 0
    pb, lb = sb.predict_comm_bytes(g, vals, frontier)
    _, cp = sb.push(g, vals, frontier, "sum", lambda x, w: x * w, Cost())
    _, cl = sb.pull(g, vals, None, "sum", lambda x, w: x * w, Cost())
    assert int(cp.collective_bytes) == int(pb)
    assert int(cl.collective_bytes) == int(lb)


def test_topology_pull_groups_preserve_coo_order(small_graph):
    """Each shard's pull row must hold its destinations' in-edges in
    global coo order — the invariant that makes sharded pull-sum
    bit-identical to the single-device segment ops."""
    import numpy as np
    g = small_graph
    part = partition_1d(g.n, 4)
    topo = build_topology(g, part)
    dst = np.asarray(g.coo_dst)
    src = np.asarray(g.coo_src)
    own = part.owner_np(dst)
    for p in range(4):
        ok = np.asarray(topo.pull_edges.valid[p])
        np.testing.assert_array_equal(
            np.asarray(topo.pull_edges.src[p])[ok], src[own == p])
        np.testing.assert_array_equal(
            np.asarray(topo.pull_edges.dst[p])[ok], dst[own == p])


def test_autoswitch_flips_for_comm_asymmetry_alone():
    """Two steps identical in every §4 counter, differing only in wire
    bytes: the predictor must order them by the collective term, so a
    distributed backend can flip direction for comm reasons alone."""
    base = dict(frontier_vertices=jnp.int64(8),
                frontier_edges=jnp.int64(100),
                pull_edges=jnp.int64(100), pull_vertices=jnp.int64(50),
                unvisited_edges=jnp.int64(100), step=jnp.int64(1),
                prev_push=jnp.bool_(True))
    predictor = CostPredictor(weights=CostWeights(collective_byte=0.5))
    even = StepStats(**base, push_wire_bytes=jnp.int64(0),
                     pull_wire_bytes=jnp.int64(0))
    push_heavy = StepStats(**base, push_wire_bytes=jnp.int64(10_000),
                           pull_wire_bytes=jnp.int64(0))
    pull_heavy = StepStats(**base, push_wire_bytes=jnp.int64(0),
                           pull_wire_bytes=jnp.int64(10_000))
    auto = AutoSwitch(predictor=predictor)
    # wire bytes shift exactly the collective term
    assert float(predictor.predict_push(push_heavy)) == pytest.approx(
        float(predictor.predict_push(even)) + 10_000 * 0.5)
    assert float(predictor.predict_pull(pull_heavy)) == pytest.approx(
        float(predictor.predict_pull(even)) + 10_000 * 0.5)
    # with a comm-dominant push the decision flips to pull, and back
    g = None
    assert not bool(auto.decide(g, None, push_heavy))
    assert bool(auto.decide(g, None, pull_heavy))


def test_sparse_push_prices_below_pull_on_sparse_frontier():
    """The adaptive push accounting: a near-empty frontier sends a few
    (index, value) pairs — fewer bytes than the all_gather pull — while
    a full frontier falls back to the dense alltoall bound."""
    g = erdos_renyi(120, 4.0, seed=3, weighted=True)
    sb = ShardedBackend.prepare(g, num_shards=1)
    # num_shards=1 has no cut; emulate a 4-part split host-side
    part = partition_1d(g.n, 4)
    topo = build_topology(g, part)
    sb4 = ShardedBackend(mesh=sb.mesh, topo=topo, axis=sb.axis)
    vals = jnp.ones((g.n,), jnp.float32)
    sparse = jnp.zeros((g.n,), bool).at[0].set(True)
    dense = jnp.ones((g.n,), bool)
    pb_sparse, lb = sb4.predict_comm_bytes(g, vals, sparse)
    pb_dense, _ = sb4.predict_comm_bytes(g, vals, dense)
    assert int(pb_sparse) < int(lb)
    assert int(pb_dense) >= int(lb)


def test_shard_shorthand_requires_graph_context():
    with pytest.raises(ValueError, match="graph-specific"):
        api._resolve_backend("shard")


def test_shard_backend_identity_semantics(small_graph):
    """Same-config instances must NOT compare equal: the engine cache
    keys on the backend, and value equality across distinct prepared
    topologies would alias engines across graphs of one shape."""
    a = ShardedBackend.prepare(small_graph, num_shards=1)
    b = ShardedBackend.prepare(small_graph, num_shards=1)
    assert a == a
    assert a != b
    assert len({a, b}) == 2


# ---------------------------------------------------------------------
# multi-device parity (fresh interpreter, 8 fake host devices)

SHARD_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import api
from repro.core.backend import EllBackend
from repro.core.cost_model import Cost
from repro.graphs.generators import erdos_renyi
from repro.shard import ShardedBackend

g = erdos_renyi(130, 4.0, seed=5, weighted=True)   # 130 % 4 != 0: pads
CASES = [
    ("bfs", dict(root=0), ("dist", "parent"), True),
    ("pagerank", dict(iters=20), None, False),
    ("sssp_delta", dict(source=0, delta=2.0), ("dist",), True),
]
for algo, kw, keys, exact in CASES:
    for pol in ("push", "pull", "auto"):
        ref = api.solve(g, algo, policy=pol, **kw)
        for P in (1, 2, 4, 8):
            sb = ShardedBackend.prepare(g, num_shards=P)
            got = api.solve(g, algo, policy=pol, backend=sb, **kw)
            ra = [ref.state] if keys is None else [ref.state[k] for k in keys]
            ga = [got.state] if keys is None else [got.state[k] for k in keys]
            if exact or pol == "pull":
                ok = all(bool(jnp.all(a == b)) for a, b in zip(ra, ga))
            else:
                ok = all(bool(jnp.allclose(a, b, rtol=1e-5, atol=1e-6))
                         for a, b in zip(ra, ga))
            print(f"{algo} {pol} P={P} ok: {ok}")

# inner executors: ELL / Pallas pull matches the EllBackend semantics
vals = jax.random.uniform(jax.random.PRNGKey(0), (g.n,), jnp.float32)
refe, _ = EllBackend().pull(g, vals, None, "sum", lambda x, w: x * w,
                            Cost())
for inner in ("ell", "pallas"):
    for P in (2, 8):
        si = ShardedBackend.prepare(g, num_shards=P, inner=inner)
        gote, _ = si.pull(g, vals, None, "sum", lambda x, w: x * w,
                          Cost())
        print(f"inner={inner} P={P} ok: {bool(jnp.all(refe == gote))}")

# predictor exactness with a real cut (P=4)
sb = ShardedBackend.prepare(g, num_shards=4)
frontier = jnp.arange(g.n) % 7 == 0
pb, lb = sb.predict_comm_bytes(g, vals, frontier)
_, cp = sb.push(g, vals, frontier, "sum", lambda x, w: x * w, Cost())
_, cl = sb.pull(g, vals, None, "sum", lambda x, w: x * w, Cost())
print("predict push ok:", int(pb) == int(cp.collective_bytes))
print("predict pull ok:", int(lb) == int(cl.collective_bytes))

# batched multi-query through the sharded backend
br = api.solve_batch(g, "bfs", sources=[0, 5, 9], backend="shard")
ok = all(bool(jnp.all(br.states[i]["dist"]
                      == api.solve(g, "bfs", root=s).state["dist"]))
         for i, s in enumerate([0, 5, 9]))
print("batch bfs ok:", ok)
br = api.solve_batch(g, "sssp_delta", sources=[0, 5], delta=2.0,
                     backend="shard")
ok = all(bool(jnp.all(br.states[i]["dist"]
                      == api.solve(g, "sssp_delta", source=s,
                                   delta=2.0).state["dist"]))
         for i, s in enumerate([0, 5]))
print("batch sssp ok:", ok)

# more shards than vertices is a hard error, not a silent alias
tiny = erdos_renyi(6, 1.5, seed=1)
try:
    ShardedBackend.prepare(tiny, num_shards=8)
    print("overpartition ok: False")
except ValueError:
    print("overpartition ok: True")
"""


@pytest.mark.dist
@pytest.mark.subprocess
def test_sharded_solve_parity_across_shard_counts():
    """solve(backend=ShardedBackend) at 1/2/4/8 shards reproduces the
    single-device dense states for BFS, PageRank, and Δ-stepping SSSP
    under push, pull, and auto — exact for the min-combines and the
    order-preserving pull, allclose(1e-5) for the reassociated
    psum_scatter push-sum — plus inner-executor parity, wire-byte
    predictor exactness with a real cut, batched solves, and the
    over-partition rejection."""
    r = _run_sub(SHARD_PARITY)
    assert r.returncode == 0, r.stdout + r.stderr
    for algo in ("bfs", "pagerank", "sssp_delta"):
        for pol in ("push", "pull", "auto"):
            for P in (1, 2, 4, 8):
                line = f"{algo} {pol} P={P} ok: True"
                assert line in r.stdout, (line, r.stdout + r.stderr)
    for inner in ("ell", "pallas"):
        for P in (2, 8):
            assert f"inner={inner} P={P} ok: True" in r.stdout, r.stdout
    for line in ("predict push ok: True", "predict pull ok: True",
                 "batch bfs ok: True", "batch sssp ok: True",
                 "overpartition ok: True"):
        assert line in r.stdout, (line, r.stdout + r.stderr)
