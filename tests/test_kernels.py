"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graphs import erdos_renyi, ring, star
from repro.kernels import (cin_layer, flash_attention, pull_spmv,
                           push_combine)
from repro.kernels import ref as R
from repro.kernels.ell_spmv import ell_spmv_pallas
from repro.kernels.coo_push import coo_push_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.cin import cin_layer_pallas


@pytest.mark.parametrize("combine", ["sum", "max", "min"])
@pytest.mark.parametrize("maker,n", [
    (lambda n: erdos_renyi(n, 5.0, seed=1, weighted=True), 200),
    (lambda n: ring(n, weighted=True), 130),
    (lambda n: star(n), 100),
])
def test_ell_spmv_sweep(maker, n, combine):
    g = maker(n)
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n,), jnp.float32)
    out = pull_spmv(g, x, combine)
    want = R.ell_spmv_ref(jnp.pad(x, (0, 1)), g.ell_idx, g.ell_w, combine)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_n", [64, 256])
def test_ell_spmv_blocks(block_n):
    g = erdos_renyi(150, 4.0, seed=2, weighted=True)
    x = jnp.pad(jax.random.normal(jax.random.PRNGKey(1), (g.n,)), (0, 1)
                ).astype(jnp.float32)
    out = ell_spmv_pallas(x, g.ell_idx, g.ell_w, "sum", block_n=block_n)
    want = R.ell_spmv_ref(x, g.ell_idx, g.ell_w, "sum")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("frac", [0.0, 0.3, 1.0])
@pytest.mark.parametrize("maker,n", [
    (lambda n: erdos_renyi(n, 6.0, seed=3, weighted=True), 180),
    (lambda n: ring(n, weighted=True), 90),     # degree-2: window stress
    (lambda n: star(n), 120),                   # hub: combining stress
])
def test_coo_push_sweep(maker, n, frac):
    g = maker(n)
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (g.n,), jnp.float32)
    active = (jax.random.uniform(key, (g.n,)) < frac) if frac < 1.0 \
        else jnp.ones((g.n,), bool)
    out = push_combine(g, x, active)
    want = R.coo_push_ref(x, active, g.coo_src, g.coo_dst, g.coo_w, g.n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_e,block_n", [(128, 64), (512, 256)])
def test_coo_push_blocks(block_e, block_n):
    g = erdos_renyi(220, 2.0, seed=5, weighted=True)
    x = jnp.ones((g.n,), jnp.float32)
    act = jnp.ones((g.n,), bool)
    out = coo_push_pallas(x, act, g.coo_src, g.coo_dst, g.coo_w, g.n,
                          block_e=block_e, block_n=block_n)
    want = R.coo_push_ref(x, act, g.coo_src, g.coo_dst, g.coo_w, g.n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_push_kernel_equals_framework_pull(small_graph):
    """Cross-check the two kernels against each other: same relaxation."""
    g = small_graph
    x = jax.random.normal(jax.random.PRNGKey(7), (g.n,), jnp.float32)
    push = push_combine(g, x, jnp.ones((g.n,), bool))
    pull = pull_spmv(g, x, "sum")
    np.testing.assert_allclose(np.asarray(push), np.asarray(pull),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,H,Hk,d", [(96, 4, 2, 32), (130, 2, 2, 64),
                                      (64, 8, 1, 16)])
def test_flash_attention_sweep(T, H, Hk, d, dtype):
    key = jax.random.PRNGKey(0)
    B = 2
    q = jax.random.normal(key, (B, T, H, d), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hk, d),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hk, d),
                          jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=64)
    kb = jnp.repeat(k, H // Hk, axis=2).transpose(0, 2, 1, 3)
    vb = jnp.repeat(v, H // Hk, axis=2).transpose(0, 2, 1, 3)
    want = R.flash_attention_ref(q.transpose(0, 2, 1, 3), kb, vb
                                 ).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window,softcap", [(17, 0.0), (1 << 30, 20.0),
                                            (9, 30.0)])
def test_flash_attention_window_softcap(window, softcap):
    key = jax.random.PRNGKey(3)
    B, T, H, d = 1, 80, 2, 32
    q = jax.random.normal(key, (B, T, H, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, d))
    out = flash_attention(q, k, v, causal_window=window, softcap=softcap,
                          block_q=16, block_k=16)
    want = R.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal_window=window,
        softcap=softcap).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("B,Hp,F,H,D", [(32, 8, 6, 12, 10), (65, 16, 8, 8, 4),
                                        (128, 200, 39, 200, 10)])
def test_cin_sweep(B, Hp, F, H, D):
    key = jax.random.PRNGKey(1)
    xk = jax.random.normal(key, (B, Hp, D), jnp.float32)
    x0 = jax.random.normal(jax.random.fold_in(key, 1), (B, F, D))
    w = jax.random.normal(jax.random.fold_in(key, 2), (H, Hp, F)) * 0.1
    out = cin_layer(xk, x0, w)
    want = R.cin_layer_ref(xk, x0, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_cin_block_boundary():
    # B not a multiple of block_b exercises padding
    key = jax.random.PRNGKey(2)
    xk = jax.random.normal(key, (37, 5, 6), jnp.float32)
    x0 = jax.random.normal(jax.random.fold_in(key, 1), (37, 4, 6))
    w = jax.random.normal(jax.random.fold_in(key, 2), (7, 5, 4))
    out = cin_layer_pallas(xk, x0, w, block_b=16)
    want = R.cin_layer_ref(xk, x0, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
