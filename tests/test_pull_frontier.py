"""Frontier-aware pull: kernel, dual layout, pricing, AutoSwitch.

The PR 8 surface: ``ell_pull_frontier_pallas`` must be bit-identical to
the full-scan kernel + mask on the rows it touches, the empty-frontier
step must return the combine identity without launching anything, a
100%-touched step must be priced exactly as the old full scan, and the
restricted pricing must (a) match what ``PallasBackend.pull`` actually
charges and (b) move AutoSwitch's predicted push/pull crossover toward
pull.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from graph_strategies import build_case

from repro.core.backend import EllBackend, PallasBackend
from repro.core.cost_model import (CostPredictor, StepStats, counter,
                                   counter_dtype, zero_cost)
from repro.core.primitives import frontier_in_edges, mask_untouched
from repro.graphs import erdos_renyi
from repro.graphs.structure import pad_values
from repro.kernels.ell_pull_frontier import (default_pull_cap,
                                             ell_pull_frontier_full,
                                             ell_pull_frontier_pallas,
                                             frontier_rows)
from repro.kernels.ell_spmv import ell_spmv_pallas
from repro.kernels.layout import build_dual_ell, touched_out_mask
from repro.kernels.tune import pull_frontier_candidates


@pytest.fixture(scope="module", autouse=True)
def _drop_jit_caches():
    """Many distinct kernel/engine shapes get compiled here; free the
    executables afterwards so the process-wide compile budget doesn't
    starve later modules."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="module")
def graph():
    return erdos_renyi(150, 5.0, seed=9, weighted=True)


def _touched(g, frac, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.rand(g.n) < frac)


# ---------------------------------------------------------------------------
# kernel parity


def _same(combine, got, want):
    got, want = np.asarray(got), np.asarray(want)
    if combine == "sum" and got.dtype.kind == "f":
        # XLA schedules the row reduce per tile shape, so float sums
        # differ in ULPs even between block sizes of the SAME kernel;
        # min/max and integer sums are order-independent → bit-exact
        return np.allclose(got, want, rtol=1e-5, atol=1e-6)
    return np.array_equal(got, want)


@pytest.mark.parametrize("combine", ["sum", "min", "max"])
@pytest.mark.parametrize("batch", [1, 4])
def test_frontier_kernel_matches_masked_full_scan(graph, combine,
                                                  batch):
    """The compacted gather + identity scatter equals
    mask_untouched(full kernel): bit-identical for min/max, within
    reduction-order rounding for float sum."""
    g = graph
    shape = (g.n,) if batch == 1 else (g.n, batch)
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    xp = pad_values(x)
    touched = _touched(g, 0.1)
    rows = frontier_rows(touched, int(touched.sum()))
    got = ell_pull_frontier_full(xp, g.ell_idx, g.ell_w, rows,
                                 combine=combine, msg="mul", block_r=32)
    want = mask_untouched(
        ell_spmv_pallas(xp, g.ell_idx, g.ell_w, combine=combine,
                        msg="mul"),
        touched, combine)
    assert _same(combine, got, want)


def test_frontier_kernel_bit_exact_for_int_sum(graph):
    """Integer sums are order-independent, so the full-vector frontier
    result is bit-identical to the masked full scan."""
    g = graph
    x = jax.random.randint(jax.random.PRNGKey(0), (g.n,), -50,
                           50).astype(jnp.int32)
    xp = pad_values(x)
    touched = _touched(g, 0.2)
    rows = frontier_rows(touched, g.n)
    got = ell_pull_frontier_full(xp, g.ell_idx, g.ell_w, rows,
                                 combine="sum", msg="copy", block_r=16)
    want = mask_untouched(
        ell_spmv_pallas(xp, g.ell_idx, g.ell_w, combine="sum",
                        msg="copy"),
        touched, "sum")
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_frontier_kernel_on_adversarial_families():
    for case in ("ragged", "empty_rows", "self_loops",
                 "duplicate_edges"):
        g = build_case(case, 1)
        x = jax.random.normal(jax.random.PRNGKey(2), (g.n,), jnp.float32)
        xp = pad_values(x)
        touched = _touched(g, 0.4, seed=3)
        rows = frontier_rows(touched, g.n)
        got = ell_pull_frontier_full(xp, g.ell_idx, g.ell_w, rows,
                                     combine="sum", msg="mul")
        want = mask_untouched(
            ell_spmv_pallas(xp, g.ell_idx, g.ell_w, combine="sum",
                            msg="mul"),
            touched, "sum")
        assert _same("sum", got, want), case


def test_compact_output_aligns_with_rows(graph):
    g = graph
    x = jax.random.normal(jax.random.PRNGKey(4), (g.n,), jnp.float32)
    touched = _touched(g, 0.05, seed=5)
    rows = frontier_rows(touched, 16)
    compact = ell_pull_frontier_pallas(pad_values(x), g.ell_idx, g.ell_w,
                                       rows, combine="sum", msg="mul",
                                       block_r=16)
    full = ell_spmv_pallas(pad_values(x), g.ell_idx, g.ell_w,
                           combine="sum", msg="mul")
    live = np.asarray(rows) < g.n
    assert np.allclose(np.asarray(compact)[live],
                       np.asarray(full)[np.asarray(rows)[live]],
                       rtol=1e-5, atol=1e-6)
    # sentinel slots carry the combine identity
    assert np.all(np.asarray(compact)[~live] == 0.0)


# ---------------------------------------------------------------------------
# dual layout


def test_dual_ell_out_side_matches_coo(graph):
    g = graph
    layout = build_dual_ell(g)
    assert layout.in_idx is g.ell_idx and layout.n == g.n
    out_idx = np.asarray(layout.out_idx)
    src, dst = np.asarray(g.coo_src), np.asarray(g.coo_dst)
    for v in range(0, g.n, 17):
        want = sorted(dst[src == v])
        got = sorted(out_idx[v][out_idx[v] < g.n])
        assert got == want, v


def test_backend_caches_dual_layout_per_graph(graph):
    b = PallasBackend(autotune=False)
    l1 = b.dual_layout(graph)
    assert b.dual_layout(graph) is l1
    other = erdos_renyi(40, 3.0, seed=1, weighted=True)
    assert b.dual_layout(other) is not l1


def test_touched_out_mask_matches_brute_force():
    g = build_case("ragged", 0)
    layout = build_dual_ell(g)
    frontier = _touched(g, 0.3, seed=7)
    got = np.asarray(touched_out_mask(layout, frontier))
    src, dst = np.asarray(g.coo_src), np.asarray(g.coo_dst)
    want = np.zeros(g.n, bool)
    want[dst[np.asarray(frontier)[src]]] = True
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# dispatch + pricing


def test_empty_frontier_skips_kernel_and_charges_nothing(graph,
                                                         monkeypatch):
    """A 0-touched pull is the combine identity — no Pallas launch, no
    charged traffic."""
    import repro.core.backend as B
    b = PallasBackend(autotune=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (graph.n,), jnp.float32)
    import repro.kernels.ell_pull_frontier as F
    import repro.kernels.ell_spmv as S

    def boom(*a, **k):  # pragma: no cover - would be the failure
        raise AssertionError("kernel launched on an empty frontier")

    monkeypatch.setattr(F, "ell_pull_frontier_pallas", boom)
    monkeypatch.setattr(S.pl, "pallas_call", boom)
    out, cost = b.pull(graph, x, jnp.zeros(graph.n, bool), "min", None,
                       zero_cost())
    assert bool(jnp.all(jnp.isinf(out)))
    assert int(cost.reads) == 0 and int(cost.writes) == 0
    assert b.stats["skip_empty_pull"] == 1
    assert b.stats["kernel_pull_frontier"] == 0


def test_full_frontier_priced_exactly_as_old_full_scan(graph):
    """100% touched overflows the restriction and takes the full-scan
    kernel at the PR 7 price — (m, n), identical to EllBackend's charge.
    The frontier path can never price a step *worse* than before."""
    b = PallasBackend(autotune=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (graph.n,), jnp.float32)
    all_touched = jnp.ones(graph.n, bool)
    out_p, c_p = b.pull(graph, x, all_touched, "sum", None, zero_cost())
    out_e, c_e = EllBackend().pull(graph, x, all_touched, "sum", None,
                                   zero_cost())
    assert int(c_p.reads) == int(c_e.reads) == graph.m
    assert int(c_p.writes) == int(c_e.writes) == graph.n
    assert np.allclose(np.asarray(out_p), np.asarray(out_e),
                       rtol=1e-5, atol=1e-5)
    e, v = b.predict_pull_scan(graph, all_touched, values=x,
                               combine="sum", msg_fn=None)
    assert (int(e), int(v)) == (graph.m, graph.n)


def test_sparse_frontier_charge_matches_prediction(graph):
    """predict_pull_scan and pull() share one formula: the charged
    reads/writes equal the prediction, concretely and in-trace."""
    b = PallasBackend(autotune=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (graph.n,), jnp.float32)
    touched = _touched(graph, 0.05, seed=11)
    cnt = int(touched.sum())
    e, v = b.predict_pull_scan(graph, touched, values=x, combine="sum",
                               msg_fn=None)
    assert (int(e), int(v)) == (cnt * graph.d_ell, cnt)
    _, cost = b.pull(graph, x, touched, "sum", None, zero_cost())
    assert (int(cost.reads), int(cost.writes)) == (int(e), int(v))

    def traced(x, touched):
        _, c = b.pull(graph, x, touched, "sum", None, zero_cost())
        return c.reads, c.writes
    r, w = jax.jit(traced)(x, touched)
    assert (int(r), int(w)) == (int(e), int(v))
    assert b.stats["kernel_pull_frontier"] >= 2


def test_default_cap_keeps_restricted_work_under_half_scan(graph):
    cap = default_pull_cap(graph.n, graph.m, graph.d_ell)
    assert cap * graph.d_ell <= max(graph.m, 8 * graph.d_ell)
    assert cap % 8 == 0 and cap >= 8
    assert pull_frontier_candidates(graph.n, cap)[-1] >= 8


def test_predicted_crossover_moves_pull_ward(graph):
    """The PR 8 pricing claim: with pull_scans_all dropped, there are
    frontier sizes where PR 7's predictor chose push (pull priced at the
    full m-edge scan) but the restricted pricing now correctly prefers
    pull. The crossover frontier size strictly grows."""
    assert not PallasBackend.pull_scans_all
    g = graph
    b = PallasBackend(autotune=False)
    pred = CostPredictor()

    def stats_for(touched, pull_edges, pull_vertices):
        return StepStats(
            frontier_vertices=jnp.sum(touched.astype(counter_dtype())),
            frontier_edges=frontier_in_edges(g, touched),
            pull_edges=pull_edges, pull_vertices=pull_vertices,
            unvisited_edges=counter(g.m), step=counter(1),
            prev_push=jnp.asarray(True), float_data=True, width=1)

    old_cross = new_cross = None
    x = jnp.ones(g.n, jnp.float32)
    for k in range(1, g.n + 1):
        touched = jnp.zeros(g.n, bool).at[jnp.arange(k)].set(True)
        e_new, v_new = b.predict_pull_scan(g, touched, values=x,
                                           combine="sum", msg_fn=None)
        old = pred.predict_pull(
            stats_for(touched, counter(g.m), counter(g.n)))
        new = pred.predict_pull(stats_for(touched, e_new, v_new))
        push = pred.predict_push(stats_for(touched, counter(0),
                                           counter(0)))
        assert float(new) <= float(old) + 1e-6, k
        if old_cross is None and float(old) < float(push):
            old_cross = k
        if new_cross is None and float(new) < float(push):
            new_cross = k
    # pull becomes competitive at a strictly smaller frontier than
    # under full-scan pricing
    assert new_cross is not None
    assert old_cross is None or new_cross < old_cross


def test_autoswitch_never_regresses_vs_scans_all_pricing():
    """AutoSwitch at hysteresis 1.0 with the restricted pricing charges
    no more than the same engine making PR 7's decisions (pull always
    priced as a full scan) — and no more than either fixed direction."""
    from repro import api
    from repro.core.direction import AutoSwitch

    @dataclasses.dataclass(frozen=True, eq=False)
    class ScansAllPallas(PallasBackend):
        def predict_pull_scan(self, g, touched, values=None,
                              combine="sum", msg_fn=None):
            return counter(g.m), counter(g.n)

    g = erdos_renyi(200, 4.0, seed=13, weighted=False)
    pol = AutoSwitch(hysteresis=1.0)

    def total(backend, policy=pol):
        r = api.solve(g, "bfs", root=0, policy=policy, backend=backend)
        return float(r.cost.weighted_total()), np.asarray(r.state["dist"])

    t_new, d_new = total(PallasBackend(autotune=False))
    t_old, d_old = total(ScansAllPallas(autotune=False))
    t_push, _ = total(PallasBackend(autotune=False), "push")
    assert np.array_equal(d_new, d_old)
    assert t_new <= t_old + 1e-6
    # (auto ≤ min(both fixed) is NOT asserted on PallasBackend: its
    # push kernel charges the full bin scan regardless of frontier
    # size, so predict_push is not exact there — a pre-existing push-
    # side gap, orthogonal to this pull pricing. Pull exactness is
    # pinned by test_sparse_frontier_charge_matches_prediction.)
    assert t_new <= t_push + 1e-6


def test_engine_reports_pull_touched_edges(small_graph):
    """StepStats.pull_touched_edges reaches the trace: the layout-
    independent Σ in-degree over touched destinations, ≤ the charged
    pull_edges full scan."""
    from repro import api
    g = small_graph
    r = api.solve(g, "bfs", root=0, policy="pull",
                  backend=PallasBackend(autotune=False), trace=32)
    tr = r.trace.as_dict(int(r.steps))
    assert "pull_touched_edges" in tr
    touched = np.asarray(tr["pull_touched_edges"])
    assert touched.min() >= 0 and touched.max() <= g.m
