"""AutoSwitch — the cost-model-driven direction policy — plus the
policy string shorthands and the per-step StepTrace surface.

The paper's claim under test: the push/pull winner is predictable from
the §4 counters, so a policy that prices both directions each step never
does worse than the better fixed direction, and usually beats both
(it switches mid-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import (AutoSwitch, CostPredictor, CostWeights,
                        Direction, EllBackend, Fixed, GenericSwitch,
                        GreedySwitch, StepStats)

KW = {
    "bfs": {"root": 3},
    "pagerank": {"iters": 10},
    "ppr": {"source": 3, "tol": 1e-7},
    "wcc": {},
    "pr_delta": {"tol": 1e-7},
    "sssp_delta": {"source": 3, "delta": 2.5},
    "betweenness": {"num_sources": 3},
    "coloring": {"num_parts": 8},
    "mst_boruvka": {},
    "triangle_count": {},
}

ATOL = {"betweenness": 1e-3}


def _weighted(r) -> float:
    return float(r.cost.weighted_total())


# -- string shorthands and error paths -----------------------------------
def test_policy_shorthands_resolve(small_graph):
    """Each shorthand string produces the same states as the policy
    instance it names."""
    pairs = [("push", Fixed(Direction.PUSH)),
             ("pull", Fixed(Direction.PULL)),
             ("gs", GenericSwitch()),
             ("grs", GreedySwitch()),
             ("auto", AutoSwitch())]
    for s, inst in pairs:
        a = api.solve(small_graph, "bfs", root=3, policy=s)
        b = api.solve(small_graph, "bfs", root=3, policy=inst)
        np.testing.assert_array_equal(np.asarray(a.state["dist"]),
                                      np.asarray(b.state["dist"]))
        assert int(a.push_steps) == int(b.push_steps)


def test_unknown_policy_string_raises(small_graph):
    """An unknown shorthand names the valid options in the error."""
    with pytest.raises(ValueError) as e:
        api.solve(small_graph, "bfs", root=0, policy="fastest")
    msg = str(e.value)
    for valid in ("auto", "push", "pull", "gs", "grs"):
        assert f"'{valid}'" in msg


def test_fixed_auto_still_rejected():
    """Regression: Fixed(Direction.AUTO) is not a policy; the error
    points at the switching strategies."""
    with pytest.raises(ValueError, match="AutoSwitch"):
        Fixed(Direction.AUTO)


# -- the tentpole claim: auto ≤ min(fixed) -------------------------------
def test_auto_beats_fixed_bfs_on_rmat(power_graph):
    """BFS on an RMAT/Kronecker graph — the paper's motivating case.
    AutoSwitch's weighted counter total must not exceed the better of
    the two fixed directions (and its states must be identical). The
    bound is provable at hysteresis=1.0 (pure per-step optimum); the
    default hysteresis is pinned too on this fixed graph."""
    rp = api.solve(power_graph, "bfs", root=0, policy="push")
    rl = api.solve(power_graph, "bfs", root=0, policy="pull")
    best = min(_weighted(rp), _weighted(rl))
    for policy in ("auto", AutoSwitch(hysteresis=1.0)):
        ra = api.solve(power_graph, "bfs", root=0, policy=policy)
        np.testing.assert_array_equal(np.asarray(rp.state["dist"]),
                                      np.asarray(ra.state["dist"]))
        assert _weighted(ra) <= best
        # on a power-law graph the frontier goes sparse->dense->sparse,
        # so auto must actually switch (some pushes, not all)
        assert 0 < int(ra.push_steps) < int(ra.steps)


def test_auto_beats_fixed_pagerank_dense(power_graph):
    """Dense PageRank iteration: every step pull is cheaper (push pays
    m float locks), so auto must run pure pull and match its total."""
    rp = api.solve(power_graph, "pagerank", iters=10, policy="push")
    rl = api.solve(power_graph, "pagerank", iters=10, policy="pull")
    for policy in ("auto", AutoSwitch(hysteresis=1.0)):
        ra = api.solve(power_graph, "pagerank", iters=10, policy=policy)
        assert int(ra.push_steps) == 0
        assert _weighted(ra) == _weighted(rl)
        assert _weighted(ra) <= min(_weighted(rp), _weighted(rl))


@pytest.mark.parametrize("name", sorted(KW))
def test_auto_runs_every_algorithm(name, small_graph):
    """solve(alg, g, policy="auto") works for every registered
    algorithm and reproduces the fixed-pull states."""
    ref = api.solve(small_graph, name, policy="pull", **KW[name])
    got = api.solve(small_graph, name, policy="auto", **KW[name])
    for lr, lg in zip(jax.tree_util.tree_leaves(ref.state),
                      jax.tree_util.tree_leaves(got.state)):
        lr, lg = jnp.asarray(lr), jnp.asarray(lg)
        if name == "coloring":      # equivalence criterion is validity
            continue
        if jnp.issubdtype(lr.dtype, jnp.floating):
            np.testing.assert_allclose(np.asarray(lr), np.asarray(lg),
                                       atol=ATOL.get(name, 1e-6))
        else:
            assert np.array_equal(np.asarray(lr), np.asarray(lg))
    assert 0 <= int(got.push_steps) <= int(got.steps)


def test_auto_declared_on_every_spec():
    for name in api.algorithms():
        assert "auto" in api.get_spec(name).policies


def test_auto_on_ell_prices_full_scan(small_graph):
    """Under the ELL layout pull always scans all m edges
    (pull_scans_all), so auto's decisions can differ from dense — but
    states must not."""
    a = api.solve(small_graph, "bfs", root=3, policy="auto")
    b = api.solve(small_graph, "bfs", root=3, policy="auto",
                  backend=EllBackend())
    np.testing.assert_array_equal(np.asarray(a.state["dist"]),
                                  np.asarray(b.state["dist"]))


def test_auto_hysteresis_discourages_thrash(power_graph):
    """A large hysteresis factor can only reduce the number of direction
    changes relative to no hysteresis."""
    def flips(r):
        d = r.trace.as_dict(int(r.steps))["pushed"]
        return sum(a != b for a, b in zip(d, d[1:]))
    r1 = api.solve(power_graph, "wcc",
                   policy=AutoSwitch(hysteresis=1.0), trace=64)
    r2 = api.solve(power_graph, "wcc",
                   policy=AutoSwitch(hysteresis=4.0), trace=64)
    assert flips(r2) <= flips(r1)


def test_predictor_matches_charged_cost(small_graph):
    """The predictor is exact for exchange steps: a BFS push step's
    predicted weighted cost equals what the engine then charges
    (modulo the k-filter, whose size is only known post-step)."""
    g = small_graph
    rp = api.solve(g, "bfs", root=3, policy="push", trace=64)
    t = rp.trace.as_dict(int(rp.steps))
    w = CostWeights()
    pred = CostPredictor(weights=w)
    # reconstruct each step's prediction from the traced frontier stats
    for i in range(int(rp.steps)):
        stats = StepStats(
            frontier_vertices=jnp.asarray(0), # unused by predict_push k-term
            frontier_edges=jnp.asarray(t["frontier_edges"][i]),
            pull_edges=jnp.asarray(0), pull_vertices=jnp.asarray(0),
            unvisited_edges=jnp.asarray(0), step=jnp.asarray(i),
            prev_push=jnp.bool_(True), float_data=False,
            k_filter_push=False)
        predicted = float(pred.predict_push(stats))
        charged = (t["reads"][i] * w.read + t["writes"][i] * w.write
                   + t["atomics"][i] * w.atomic + t["locks"][i] * w.lock)
        # charged includes the k-filter (reads+writes of the updated
        # set); prediction without it is a lower bound within 2k
        assert predicted <= charged


# -- StepTrace surface ---------------------------------------------------
def test_trace_records_steps_and_deltas(small_graph):
    r = api.solve(small_graph, "bfs", root=3, policy="auto", trace=32)
    steps = int(r.steps)
    assert r.trace is not None and r.trace.capacity == 32
    d = r.trace.as_dict(steps)
    assert len(d["pushed"]) == steps
    assert sum(d["pushed"]) == int(r.push_steps)
    # per-step deltas sum to the run totals
    assert sum(d["reads"]) == int(r.cost.reads)
    assert sum(d["writes"]) == int(r.cost.writes)
    assert sum(d["atomics"]) == int(r.cost.atomics)
    assert sum(d["locks"]) == int(r.cost.locks)


def test_trace_capacity_overflow_drops(small_graph):
    """Steps beyond the trace capacity are dropped, not wrapped."""
    r = api.solve(small_graph, "pagerank", iters=10, trace=4)
    assert int(r.steps) == 10 and r.trace.capacity == 4
    d = r.trace.as_dict()
    assert len(d["reads"]) == 4 and all(x > 0 for x in d["reads"])


def test_trace_default_off(small_graph):
    assert api.solve(small_graph, "bfs", root=3).trace is None


def test_trace_spans_phases(small_graph):
    """Phase programs trace steps globally across epochs: Δ-stepping's
    slots cover every inner relaxation step in order."""
    r = api.solve(small_graph, "sssp_delta", source=3, delta=2.5,
                  trace=True)
    steps = int(r.steps)
    assert steps > 1
    d = r.trace.as_dict(steps)
    assert sum(d["reads"]) == int(r.cost.reads)
    assert sum(d["pushed"]) == int(r.push_steps)
