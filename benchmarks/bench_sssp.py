"""Fig 2: Δ-Stepping SSSP push vs pull, and the Δ sensitivity (paper
Fig 2c: larger Δ shrinks the push/pull gap)."""

from __future__ import annotations

from repro.core.algorithms import sssp_delta

from .common import emit, graph, timeit


def run():
    for gname in ("pok", "am"):
        g = graph(gname, weighted=True)
        for delta in (2.0, 8.0):
            t_push = timeit(
                lambda: sssp_delta(g, 0, delta, direction="push"), iters=2)
            t_pull = timeit(
                lambda: sssp_delta(g, 0, delta, direction="pull"), iters=2)
            emit(f"sssp_push_{gname}_d{delta:g}", t_push, "")
            emit(f"sssp_pull_{gname}_d{delta:g}", t_pull,
                 f"pull/push={t_pull/t_push:.2f}")
        r = sssp_delta(g, 0, 2.0, direction="push")
        emit(f"sssp_epochs_{gname}", 0.0,
             f"epochs={int(r.epochs)};inner={int(r.inner_iters)}")


if __name__ == "__main__":
    run()
