"""Shared benchmark scaffolding.

Wall-clock numbers come from the CPU container, so they validate the
paper's *relative* push/pull claims; the analytic PRAM counters validate
the *structural* claims (Table 1). Real-world graphs are offline, so the
paper's graphs are structurally matched synthetic stand-ins
(graphs.generators.standin; DESIGN.md §10).
"""

from __future__ import annotations

import time
from functools import lru_cache

import jax
import numpy as np

SCALE = 1.0 / 256    # stand-in scale vs paper sizes (CPU container)

# set by `benchmarks.run --smoke`: suites that honor it shrink their
# graphs to CI-sized instances (seconds, not minutes, per suite)
SMOKE = False

# set by `benchmarks.run --trace-out PATH`: a repro.obs.Telemetry handle
# suites emit into (auto-policy cells run one extra observed solve so
# the trace carries their decision audit; timed runs stay
# telemetry-free so the numbers are untouched)
TELEMETRY = None

ROWS: list[str] = []
# structured mirror of ROWS, consumed by `benchmarks.run --json PATH`
RESULTS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    RESULTS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived})
    print(line, flush=True)


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock microseconds of fn(*args) (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


@lru_cache(maxsize=None)
def graph(name: str, weighted: bool = False, scale: float = SCALE):
    from repro.graphs import standin
    return standin(name, scale=scale, weighted=weighted)


def fmt_count(x: int) -> str:
    if x >= 1_000_000_000:
        return f"{x/1e9:.2f}B"
    if x >= 1_000_000:
        return f"{x/1e6:.2f}M"
    if x >= 1_000:
        return f"{x/1e3:.1f}k"
    return str(x)
