"""Table 1 reproduction: per-algorithm push/pull operation counters.

Paper claims validated structurally:
  * atomics: pull removes them entirely for TC/BFS/SSSP/MST; PR-push uses
    locks (float payloads, no CPU float atomics);
  * reads: pulling traversals read more (BFS pull O(D·m) vs push O(m));
  * PA: push+PA moves most combining writes to the plain-write column.

Graphs are sized per algorithm complexity (TC is O(m·d̂²), BGC's greedy
phase is O(n·d̂·C): both get small sparse stand-ins; linear-work
algorithms use the larger ones).
"""

from __future__ import annotations

import jax

from repro.core.algorithms import (bfs, boman_coloring, boruvka_mst,
                                   pagerank, pagerank_pa, sssp_delta,
                                   triangle_count)
from repro.core.direction import Direction, Fixed

from .common import emit, fmt_count, graph


def run():
    rows = []
    header = ("algo", "graph", "variant", "reads", "writes", "atomics",
              "locks", "iters")
    print("| " + " | ".join(header) + " |")

    def add(algo, gname, variant, cost, iters=""):
        d = cost.as_dict()
        row = (algo, gname, variant, fmt_count(d["reads"]),
               fmt_count(d["writes"]), fmt_count(d["atomics"]),
               fmt_count(d["locks"]), str(iters))
        rows.append((algo, gname, variant, d))
        print("| " + " | ".join(row) + " |")

    # linear-work algorithms: larger stand-ins
    for gname, scale in (("orc", 1.0 / 256), ("rca", 1.0 / 256)):
        g = graph(gname, weighted=True, scale=scale)
        for d in ("push", "pull"):
            add("PR", gname, d, pagerank(g, iters=5, direction=d).cost, 5)
        add("PR", gname, "push+PA", pagerank_pa(g, 16, iters=5).cost, 5)
        for pol in (Fixed(Direction.PUSH), Fixed(Direction.PULL)):
            r = bfs(g, 0, pol)
            add("BFS", gname, pol.name, r.cost, int(r.levels))
        for d in ("push", "pull"):
            r = sssp_delta(g, 0, delta=2.0, direction=d)
            add("SSSP-D", gname, d, r.cost, int(r.epochs))
        for d in ("push", "pull"):
            r = boruvka_mst(g, d)
            add("MST", gname, d, r.cost, int(r.rounds))

    # superlinear algorithms: small sparse stand-ins
    for gname, scale in (("am", 1.0 / 512), ("rca", 1.0 / 1024)):
        g = graph(gname, weighted=True, scale=scale)
        for d in ("push", "pull"):
            add("TC", gname, d, triangle_count(g, d).cost)
        for d in ("push", "pull"):
            r = boman_coloring(g, num_parts=16, C=64, direction=d)
            add("BGC", gname, d, r.cost, int(r.iterations))

    # structural validations (the Table 1 shape)
    by = {(a, g_, v): d for a, g_, v, d in rows}
    checks = [
        ("pull: zero atomics+locks everywhere",
         all(d["atomics"] == 0 and d["locks"] == 0
             for (a, g_, v), d in by.items() if v == "pull")),
        ("PR push uses locks (floats), not atomics",
         all(by[("PR", g_, "push")]["locks"] > 0
             and by[("PR", g_, "push")]["atomics"] == 0
             for g_ in ("orc", "rca"))),
        ("TC/BFS push use integer atomics",
         by[("TC", "am", "push")]["atomics"] > 0
         and by[("BFS", "orc", "push")]["atomics"] > 0),
        ("BFS pull reads > push reads",
         all(by[("BFS", g_, "pull")]["reads"]
             > by[("BFS", g_, "push")]["reads"] for g_ in ("orc", "rca"))),
        ("SSSP pull reads > push reads",
         all(by[("SSSP-D", g_, "pull")]["reads"]
             > by[("SSSP-D", g_, "push")]["reads"]
             for g_ in ("orc", "rca"))),
        ("PA cuts PR combining writes",
         all(by[("PR", g_, "push+PA")]["locks"]
             < by[("PR", g_, "push")]["locks"] for g_ in ("orc", "rca"))),
    ]
    ok = all(c for _, c in checks)
    for name, c in checks:
        print(f"  [{'x' if c else ' '}] {name}")
    emit("table1_counters", 0.0, f"checks_pass={ok}")
    return ok


if __name__ == "__main__":
    run()
