"""Push/pull decision matrix — the ``BENCH_pushpull.json`` trajectory.

Sweeps every registered algorithm × direction policy (Fixed push/pull,
GenericSwitch, GreedySwitch, AutoSwitch) × backend (Dense, ELL) × graph
family (RMAT, uniform, power-law) through ``api.solve`` and emits one row
per cell: steps, epochs, push steps, the paper's §4 counter totals, the
weighted scalar cost, and wall time. This is the perf baseline future
PRs regress against, and the data behind docs/results.md.

One command per artifact (the committed BENCH_pushpull.json baseline is
the full sweep; docs/results.md is the smoke snapshot):

    PYTHONPATH=src python -m benchmarks.run --only pushpull_matrix \
        --json BENCH_pushpull.json
    PYTHONPATH=src python -m benchmarks.run --only pushpull_matrix \
        --smoke --json /tmp/BENCH_smoke.json --markdown docs/results.md

``--smoke`` restricts the sweep to CI-sized graphs (the RMAT family,
both backends); without it the full three-family matrix runs.
"""

from __future__ import annotations

import json

from . import common
from .common import emit, timeit

POLICIES = ("push", "pull", "gs", "grs", "auto")

# kwargs per algorithm, sized for a many-cell sweep (few iterations,
# few BC sources); the *relative* push/pull counter structure is
# iteration-count-independent
KWARGS = {
    "bfs": {"root": 0},
    "pagerank": {"iters": 10},
    "ppr": {"source": 0, "tol": 1e-5},
    "wcc": {},
    "pr_delta": {"tol": 1e-6},
    "sssp_delta": {"source": 0, "delta": 2.0},
    "betweenness": {"num_sources": 2},
    "coloring": {"num_parts": 8},
    "mst_boruvka": {},
    "triangle_count": {},
}


def _graphs(smoke: bool):
    """(family name -> Graph) for the sweep; all weighted (Δ-stepping /
    MST need weights). Triangle counting's all-pairs intersection is
    O(m·d_ell²), so it gets a sparser stand-in per family."""
    from repro.graphs import erdos_renyi, kronecker, road_grid, standin
    if smoke:
        fams = {"rmat": kronecker(7, edge_factor=6, seed=7, weighted=True)}
        tc = {"rmat": road_grid(12, seed=3, weighted=True)}
    else:
        fams = {
            "rmat": kronecker(10, edge_factor=8, seed=7, weighted=True),
            "uniform": erdos_renyi(1024, 8.0, seed=5, weighted=True),
            "powerlaw": standin("orc", scale=1.0 / 2048, weighted=True),
        }
        tc = {
            "rmat": road_grid(24, seed=3, weighted=True),
            "uniform": erdos_renyi(512, 4.0, seed=5, weighted=True),
            "powerlaw": standin("rca", scale=1.0 / 2048, weighted=True),
        }
    return fams, tc


def run():
    import jax
    from repro import api
    from repro.core import DenseBackend, EllBackend

    backends = {"dense": DenseBackend(), "ell": EllBackend()}
    fams, tc_fams = _graphs(common.SMOKE)

    for alg in api.algorithms():
        spec = api.get_spec(alg)
        graphs = tc_fams if alg == "triangle_count" else fams
        for gname, g in graphs.items():
            for pname in POLICIES:
                if pname not in spec.policies:
                    continue
                for bname, backend in backends.items():
                    if bname not in spec.backends:
                        continue

                    def fn():
                        r = api.solve(g, alg, policy=pname,
                                      backend=backend, **KWARGS[alg])
                        jax.block_until_ready(r.cost.reads)
                        return r

                    us = timeit(fn)
                    r = fn()
                    payload = {
                        "algorithm": alg, "graph": gname,
                        "n": int(g.n), "m": int(g.m),
                        "policy": pname, "backend": bname,
                        "steps": int(r.steps),
                        "push_steps": int(r.push_steps),
                        "epochs": int(r.epochs),
                        "converged": bool(r.converged),
                        "wall_us": round(us, 1),
                        "counters": r.cost.as_dict(),
                        "weighted_total": float(r.cost.weighted_total()),
                    }
                    mis = _mispredict_pct(g, alg, pname, backend)
                    if mis is not None:
                        payload["mispredict_pct"] = mis
                    emit(f"pushpull_{alg}_{gname}_{pname}_{bname}", us,
                         json.dumps(payload))


def _mispredict_pct(g, alg, pname, backend):
    """One extra *observed* solve for auto-policy cells when the runner
    is collecting a trace (``--trace-out``): the cell's decision audit
    lands in the shared telemetry handle, and its misprediction rate
    comes back as the row's ``mispredict_pct``. Timed runs above stay
    telemetry-free, so this never perturbs the wall numbers."""
    tel = common.TELEMETRY
    if tel is None or pname != "auto":
        return None
    from repro import api
    api.solve(g, alg, policy=pname, backend=backend,
              telemetry=tel, **KWARGS[alg])
    audits = tel.events_for(tel.last_run, "audit")
    if not audits:
        return None
    return round(100.0 * audits[-1]["mispredict_rate"], 1)


if __name__ == "__main__":
    run()
