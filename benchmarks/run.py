"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus human tables); with
``--json PATH`` the same rows are written as a machine-readable report,
so perf trajectories (``BENCH_*.json``) can be produced mechanically.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--json PATH]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

SUITES = [
    ("api_solve", "bench_api"),
    ("table1_counters", "bench_counters"),
    ("table3_pagerank", "bench_pagerank"),
    ("table3_tc", "bench_tc"),
    ("fig1_table6b_coloring", "bench_coloring"),
    ("fig2_sssp", "bench_sssp"),
    ("fig3_dm_scaling", "bench_dm_scaling"),
    ("fig4_mst", "bench_mst"),
    ("fig5_bc", "bench_bc"),
    ("table6a_strategies", "bench_strategies"),
    ("kernels", "bench_kernels"),
    ("roofline", "roofline_run"),
]


def _json_row(row: dict) -> dict:
    """A report row; `derived` strings that are JSON payloads (bench_api)
    come through parsed."""
    derived = row["derived"]
    if isinstance(derived, str) and derived[:1] in "{[":
        try:
            derived = json.loads(derived)
        except ValueError:
            pass
    return {"name": row["name"], "us_per_call": row["us_per_call"],
            "derived": derived}


def write_json(path: str, failures: list) -> None:
    from . import common
    report = {"rows": [_json_row(r) for r in common.RESULTS],
              "failures": [{"suite": n, "error": e} for n, e in failures]}
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"json report: {path} ({len(report['rows'])} rows)", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the emitted rows as a JSON report")
    args = ap.parse_args()
    failures = []
    for name, mod in SUITES:
        if args.only and args.only not in (name, mod):
            continue
        print(f"\n===== {name} ({mod}) =====", flush=True)
        t0 = time.time()
        try:
            module = __import__(f"benchmarks.{mod}", fromlist=["run"])
            module.run()
            print(f"----- {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            import traceback
            traceback.print_exc()
    if args.json:
        write_json(args.json, failures)
    if failures:
        print("\nFAILED:", failures)
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
