"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus human tables).

    PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""

from __future__ import annotations

import argparse
import sys
import time

SUITES = [
    ("api_solve", "bench_api"),
    ("table1_counters", "bench_counters"),
    ("table3_pagerank", "bench_pagerank"),
    ("table3_tc", "bench_tc"),
    ("fig1_table6b_coloring", "bench_coloring"),
    ("fig2_sssp", "bench_sssp"),
    ("fig3_dm_scaling", "bench_dm_scaling"),
    ("fig4_mst", "bench_mst"),
    ("fig5_bc", "bench_bc"),
    ("table6a_strategies", "bench_strategies"),
    ("kernels", "bench_kernels"),
    ("roofline", "roofline_run"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    failures = []
    for name, mod in SUITES:
        if args.only and args.only not in (name, mod):
            continue
        print(f"\n===== {name} ({mod}) =====", flush=True)
        t0 = time.time()
        try:
            module = __import__(f"benchmarks.{mod}", fromlist=["run"])
            module.run()
            print(f"----- {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            import traceback
            traceback.print_exc()
    if failures:
        print("\nFAILED:", failures)
        sys.exit(1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
