"""Kernel-level push/pull microbenchmarks: Pallas (interpret) kernels vs
their jnp oracles — correctness sweep + relative timing on a stand-in."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import cin_layer, flash_attention, pull_spmv, push_combine
from repro.kernels import ref as R

from .common import emit, graph, timeit


def run():
    g = graph("pok", scale=1.0 / 1024)
    x = jax.random.normal(jax.random.PRNGKey(0), (g.n,), jnp.float32)
    act = jnp.ones((g.n,), bool)

    out = pull_spmv(g, x, "sum")
    want = R.ell_spmv_ref(jnp.pad(x, (0, 1)), g.ell_idx, g.ell_w, "sum")
    ok1 = bool(jnp.allclose(out, want, atol=1e-4))
    t = timeit(lambda: pull_spmv(g, x, "sum"), iters=2)
    emit("kernel_ell_spmv", t, f"allclose={ok1};n={g.n};d_ell={g.d_ell}")

    out = push_combine(g, x, act)
    want = R.coo_push_ref(x, act, g.coo_src, g.coo_dst, g.coo_w, g.n)
    ok2 = bool(jnp.allclose(out, want, atol=1e-4))
    t = timeit(lambda: push_combine(g, x, act), iters=2)
    emit("kernel_coo_push", t, f"allclose={ok2};m={g.m}")

    key = jax.random.PRNGKey(1)
    B, T, H, d = 1, 256, 4, 64
    q = jax.random.normal(key, (B, T, H, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, d))
    out = flash_attention(q, k, v)
    want = R.flash_attention_ref(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    ok3 = bool(jnp.allclose(out, want, atol=1e-3))
    t = timeit(lambda: flash_attention(q, k, v), iters=2)
    emit("kernel_flash_attention", t, f"allclose={ok3};T={T}")

    xk = jax.random.normal(key, (256, 200, 10), jnp.float32)
    x0 = jax.random.normal(jax.random.fold_in(key, 3), (256, 39, 10))
    w = jax.random.normal(jax.random.fold_in(key, 4), (200, 200, 39)) * 0.01
    out = cin_layer(xk, x0, w)
    want = R.cin_layer_ref(xk, x0, w)
    ok4 = bool(jnp.allclose(out, want, rtol=1e-3, atol=1e-3))
    t = timeit(lambda: cin_layer(xk, x0, w), iters=2)
    emit("kernel_cin", t, f"allclose={ok4};B=256;H=200")
    return ok1 and ok2 and ok3 and ok4


if __name__ == "__main__":
    run()
